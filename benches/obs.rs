//! Observability-overhead bench (ISSUE 7): the same seeded
//! `ec2genload`-style workload through the real [`JobScheduler`] at
//! the three telemetry levels —
//!
//! * **off** — every emission site returns after one atomic load;
//! * **metrics** — events fan into the deterministic registry;
//! * **trace** — metrics plus JSONL lines into the in-memory sink.
//!
//! Runs are interleaved and timed best-of-N, and the bench asserts
//! the metrics path costs less than 3% over the disabled path
//! (`overhead_metrics_vs_off < 1.03` in `BENCH_obs.json`, validated
//! by CI). On top of the timing it re-checks the plane's two
//! correctness pillars end to end: two traced runs are bit-identical,
//! and the event counts reconcile with the scheduler's own counters
//! and the billing ledger.
//!
//! Run: `cargo bench --bench obs`

use std::time::Instant;

use p2rac::analytics::script::RUST_SWEEP_TILE;
use p2rac::bench_support::emit_bench_json;
use p2rac::coordinator::{MockEngine, Session};
use p2rac::jobs::genload::{generate, GenJob, GenLoadConfig};
use p2rac::jobs::{AutoscalerConfig, JobScheduler, JobSpecBuilder};
use p2rac::simcloud::SimParams;
use p2rac::telemetry::{EventKind, TelemetryLevel};
use p2rac::util::json::Json;

/// Interleaved timing rounds per level; the minimum is reported.
const ROUNDS: usize = 5;
/// Per-job work-unit cap (keeps one bench run around a second).
const UNIT_CAP: u64 = 6;
/// JSONL lines sampled into `BENCH_obs.json` for the CI
/// well-formedness check.
const TRACE_SAMPLE_LINES: usize = 200;

struct RunOut {
    wall_s: f64,
    submitted: u64,
    rejected: u64,
    events: u64,
    snapshot: String,
    trace: Vec<String>,
    reconcile_ok: bool,
    reconcile_notes: Vec<String>,
    phase_profile: Json,
    events_by_kind: Json,
}

/// One full drain of the seeded workload at `level`. The returned
/// reconciliation verdict cross-checks the registry against the
/// scheduler and the ledger (trivially true at `Off`, where both
/// sides are zero by construction).
fn run_once(level: TelemetryLevel, arrivals: &[GenJob], seed: u64) -> RunOut {
    let mut s = Session::new(SimParams::default(), Box::new(MockEngine::new(10.0)));
    s.cloud.spot.spike_prob = 0.0;
    match level {
        TelemetryLevel::Off => s.cloud.telemetry.set_level(TelemetryLevel::Off),
        TelemetryLevel::Metrics => {}
        TelemetryLevel::Trace => s.cloud.telemetry.enable_memory_trace(),
    }
    // One project per distinct unit count, exactly like `ec2genload`.
    let mut seen = std::collections::BTreeSet::new();
    for g in arrivals {
        let units = g.units.min(UNIT_CAP);
        if seen.insert(units) {
            let n_jobs = units as usize * RUST_SWEEP_TILE;
            s.analyst.write(
                &format!("genload/u{units}/sweep.json"),
                format!(r#"{{"type":"mc_sweep","n_jobs":{n_jobs},"seed":{seed}}}"#).into_bytes(),
            );
        }
    }
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 1,
        max_clusters: 4,
        nodes_per_cluster: 2,
        spot: true,
        ..Default::default()
    });
    js.slice_units = 2;
    s.cloud.faults.spot_interruptions = 4;

    let t0 = Instant::now();
    let now = s.cloud.clock.now_s();
    let (mut submitted, mut rejected) = (0u64, 0u64);
    for (i, g) in arrivals.iter().enumerate() {
        let units = g.units.min(UNIT_CAP);
        let spec = JobSpecBuilder::new(
            &format!("gen-{seed}-{i}"),
            &format!("genload/u{units}"),
            "sweep.json",
        )
        .priority(g.priority)
        .deadline(g.deadline_s.map(|d| now + (d - g.arrival_s)))
        .build();
        match js.admit(&s, spec, false, &g.tenant) {
            Ok(_) => submitted += 1,
            Err(_) => rejected += 1,
        }
    }
    js.run_until_idle(&mut s).unwrap();
    js.shutdown_fleet(&mut s).unwrap();
    let wall_s = t0.elapsed().as_secs_f64();

    let t = &s.cloud.telemetry;
    let mut notes = Vec::new();
    let mut check = |name: &str, lhs: u64, rhs: u64| {
        if lhs != rhs {
            notes.push(format!("{name}: {lhs} != {rhs}"));
        }
    };
    if level != TelemetryLevel::Off {
        check("submits vs scheduler", t.counter("jobs_submitted_total"), submitted);
        check(
            "reclaims vs scheduler",
            t.counter("spot_reclaims_total"),
            js.interruptions_delivered as u64,
        );
        let wan_items = s
            .cloud
            .ledger
            .items()
            .iter()
            .filter(|i| i.detail.starts_with("WAN transfer"))
            .count() as u64;
        check("WAN transfers vs ledger", t.counter("wan_billed_transfers_total"), wan_items);
        check(
            "scale events vs autoscaler",
            t.events_of(EventKind::Scale),
            js.autoscaler.events.len() as u64,
        );
    }

    let mut by_kind = Json::obj();
    for kind in [
        EventKind::Submit,
        EventKind::AdmitReject,
        EventKind::Dispatch,
        EventKind::SliceComplete,
        EventKind::CheckpointCommit,
        EventKind::SpotReclaim,
        EventKind::Scale,
        EventKind::Transfer,
        EventKind::Invoice,
    ] {
        by_kind.set(kind.label(), Json::num(t.events_of(kind) as f64));
    }

    RunOut {
        wall_s,
        submitted,
        rejected,
        events: t.events_emitted(),
        snapshot: t.snapshot_json().to_string_compact(),
        trace: t.take_memory_trace(),
        reconcile_ok: notes.is_empty(),
        reconcile_notes: notes,
        phase_profile: js.profiler.to_json(),
        events_by_kind: by_kind,
    }
}

fn main() {
    println!("=== telemetry overhead: off vs metrics vs trace ===\n");
    let cfg = GenLoadConfig {
        jobs: 150,
        tenants: 12,
        ..GenLoadConfig::default()
    };
    let arrivals = generate(&cfg);

    let levels = [TelemetryLevel::Off, TelemetryLevel::Metrics, TelemetryLevel::Trace];
    let mut best = [f64::INFINITY; 3];
    let mut rounds: Vec<[f64; 3]> = Vec::new();
    let mut last: [Option<RunOut>; 3] = [None, None, None];
    for round in 0..ROUNDS {
        let mut row = [0.0f64; 3];
        for (i, level) in levels.iter().enumerate() {
            let out = run_once(*level, &arrivals, cfg.seed);
            row[i] = out.wall_s;
            best[i] = best[i].min(out.wall_s);
            last[i] = Some(out);
        }
        rounds.push(row);
        println!(
            "  round {round}: off {:.3}s  metrics {:.3}s  trace {:.3}s",
            row[0], row[1], row[2]
        );
    }
    let overhead_metrics = best[1] / best[0].max(1e-9);
    let overhead_trace = best[2] / best[0].max(1e-9);
    println!(
        "\n  best-of-{ROUNDS}: off {:.3}s  metrics {:.3}s ({overhead_metrics:.3}x)  \
         trace {:.3}s ({overhead_trace:.3}x)",
        best[0], best[1], best[2]
    );

    let off = last[0].take().unwrap();
    let metrics = last[1].take().unwrap();
    let trace = last[2].take().unwrap();

    // Determinism: a second traced drain replays identical bytes.
    let replay = run_once(TelemetryLevel::Trace, &arrivals, cfg.seed);
    let snapshot_identical = trace.snapshot == replay.snapshot;
    let trace_identical = trace.trace == replay.trace;
    println!(
        "  determinism: snapshot {}  trace {} ({} lines)",
        snapshot_identical,
        trace_identical,
        trace.trace.len()
    );
    for out in [&metrics, &trace, &replay] {
        for n in &out.reconcile_notes {
            eprintln!("  reconcile mismatch: {n}");
        }
    }

    assert!(off.events == 0, "the Off path must record nothing");
    assert!(trace.events > 0 && !trace.trace.is_empty());
    assert!(snapshot_identical && trace_identical, "telemetry must be deterministic");
    assert!(
        metrics.reconcile_ok && trace.reconcile_ok && replay.reconcile_ok,
        "event counts must reconcile with the scheduler and ledger"
    );
    assert!(
        overhead_metrics < 1.03,
        "metrics-level telemetry must cost <3% over the disabled path, got {overhead_metrics:.3}x"
    );

    let mut report = Json::obj();
    let mut runs = Vec::new();
    for (i, (level, out)) in levels.iter().zip([&off, &metrics, &trace]).enumerate() {
        let mut o = Json::obj();
        o.set("level", Json::str(level.label()));
        o.set("wall_s_best", Json::num(best[i]));
        o.set(
            "wall_s_rounds",
            Json::Arr(rounds.iter().map(|r| Json::num(r[i])).collect()),
        );
        o.set("events", Json::num(out.events as f64));
        o.set("jobs_submitted", Json::num(out.submitted as f64));
        o.set("jobs_rejected", Json::num(out.rejected as f64));
        o.set("reconcile_ok", Json::Bool(out.reconcile_ok));
        runs.push(o);
    }
    report.set("runs", Json::Arr(runs));
    report.set("overhead_metrics_vs_off", Json::num(overhead_metrics));
    report.set("overhead_trace_vs_off", Json::num(overhead_trace));
    report.set(
        "determinism",
        Json::from_pairs(vec![
            ("snapshot_identical", Json::Bool(snapshot_identical)),
            ("trace_identical", Json::Bool(trace_identical)),
        ]),
    );
    report.set("events_by_kind", trace.events_by_kind.clone());
    report.set(
        "trace_sample",
        Json::arr_str(trace.trace.iter().take(TRACE_SAMPLE_LINES).cloned().collect::<Vec<_>>()),
    );
    report.set("phase_profile", metrics.phase_profile.clone());
    match emit_bench_json("obs", &report) {
        Ok(path) => println!("  wrote {}", path.display()),
        Err(e) => eprintln!("  could not write BENCH_obs.json: {e}"),
    }
    println!("\nobs bench complete.");
}
