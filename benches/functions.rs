//! Serverless-tier bench (ISSUE 9): keepalive policies head to head
//! on a seeded diurnal invocation workload.
//!
//! A 100k-invocation day is drawn from `ec2genload`'s arrival model
//! (diurnal rate, skewed tenants, heavy-tailed sizes) and mapped onto
//! the function tier: tenant from the generated job, function identity
//! and footprint derived deterministically from the job's size field.
//! The *same* arrival stream is then replayed against the warm pool
//! under the two keepalive policies:
//!
//! * **fixed-600** — every idle container lives exactly 600 s;
//! * **hybrid-600** — per-function keepalive adapted from the observed
//!   inter-arrival histogram (p99 upper bound + margin, clamped),
//!   falling back to 600 s until the histogram is representative.
//!
//! The report asserts the tentpole claim: hybrid achieves a *strictly
//! lower* cold-start fraction at *no higher* total cost (cold starts
//! pay the WAN project sync; longer keepalives pay idle memory — the
//! policy trade the pool autoscaler also navigates, swept here across
//! idle-memory budgets). A same-seed replay must be bit-identical:
//! dispatch digest, bill and metric snapshot. Results land in
//! `BENCH_functions.json` with a JSONL invocation-trace sample for the
//! CI validator.
//!
//! Run: `cargo bench --bench functions`

use std::collections::BTreeSet;
use std::time::Instant;

use p2rac::bench_support::emit_bench_json;
use p2rac::coordinator::{MockEngine, Session};
use p2rac::jobs::genload::{generate, GenJob, GenLoadConfig};
use p2rac::jobs::{FnInvokeSpec, FnPlatform, KeepalivePolicy, QuotaBook};
use p2rac::simcloud::SimParams;
use p2rac::util::json::Json;

/// Invocations in the main comparison (one simulated day).
const INVOCATIONS: usize = 100_000;
/// Tenants in the arrival stream.
const TENANTS: usize = 50;
/// Function names per tenant: with ~2k invocations/tenant/day this
/// puts the typical per-function inter-arrival time in the hundreds
/// to thousands of seconds — squarely across the 600 s fixed
/// keepalive, where the policies genuinely diverge.
const FNS_PER_TENANT: u64 = 24;
/// Effectively-unbounded idle budget for the policy comparison, so
/// keepalive (not pool pressure) decides every eviction.
const UNBOUNDED_MB: u64 = u64::MAX;
/// Arrival prefix for the idle-budget sweep (keeps the three extra
/// runs cheap; the sweep compares budgets against each other, not
/// against the main runs).
const SWEEP_INVOCATIONS: usize = 25_000;
/// Arrival prefix for the traced sample included in the report.
const TRACE_INVOCATIONS: usize = 150;

fn session() -> Session {
    Session::new(SimParams::default(), Box::new(MockEngine::new(10.0)))
}

/// Map one generated arrival onto a function invocation. Everything
/// is a pure function of the (seeded) `GenJob`, so the invocation
/// stream is reproducible byte for byte.
fn spec_for(g: &GenJob) -> FnInvokeSpec {
    // Spread function identity uniformly (the raw `units` field is
    // heavy-tailed and would pile onto a few names).
    let f = g.units.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 32;
    let f = f % FNS_PER_TENANT;
    FnInvokeSpec {
        fname: format!("f{f}"),
        tenant: g.tenant.clone(),
        digest: f + 1,
        bytes: (16 + (f % 5) * 8) << 20,
        mem_mb: 256 << (f % 3),
        duration_ms: 120 + (g.units % 20) * 40,
    }
}

struct RunOut {
    label: String,
    invocations: u64,
    cold: u64,
    provisioned: u64,
    evicted: u64,
    expired_evictions: u64,
    pressure_evictions: u64,
    idle_gb_hours: f64,
    total_cost_cc: u64,
    fn_invoke_cc: u64,
    fn_pool_cc: u64,
    dispatch_digest: u64,
    metrics_snapshot: String,
    sim_seconds: f64,
    wall_s: f64,
}

impl RunOut {
    fn cold_fraction(&self) -> f64 {
        self.cold as f64 / self.invocations.max(1) as f64
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("label", Json::str(&self.label));
        o.set("invocations", Json::num(self.invocations as f64));
        o.set("cold_starts", Json::num(self.cold as f64));
        o.set("cold_fraction", Json::num(self.cold_fraction()));
        o.set("provisioned", Json::num(self.provisioned as f64));
        o.set("evicted", Json::num(self.evicted as f64));
        o.set("expired_evictions", Json::num(self.expired_evictions as f64));
        o.set("pressure_evictions", Json::num(self.pressure_evictions as f64));
        o.set("idle_gb_hours", Json::num(self.idle_gb_hours));
        o.set("total_cost_cc", Json::num(self.total_cost_cc as f64));
        o.set("fn_invoke_cc", Json::num(self.fn_invoke_cc as f64));
        o.set("fn_pool_cc", Json::num(self.fn_pool_cc as f64));
        o.set("dispatch_digest", Json::str(format!("{:016x}", self.dispatch_digest)));
        o.set("sim_seconds", Json::num(self.sim_seconds));
        o.set("wall_s", Json::num(self.wall_s));
        o
    }

    fn row(&self) -> String {
        format!(
            "{:<12} {:>7} invocations  {:>6} cold ({:>5.2}%)  {:>9} cc total  \
             {:>8.1} idle GB-h  digest {:016x}",
            self.label,
            self.invocations,
            self.cold,
            self.cold_fraction() * 100.0,
            self.total_cost_cc,
            self.idle_gb_hours,
            self.dispatch_digest,
        )
    }
}

/// Replay `arrivals` against a fresh platform under `policy` and
/// `max_idle_mb`. Returns the run summary and (when `traced`) the
/// JSONL event lines.
fn run_policy(
    label: &str,
    policy: KeepalivePolicy,
    arrivals: &[GenJob],
    max_idle_mb: u64,
    traced: bool,
) -> (RunOut, Vec<String>) {
    let mut s = session();
    if traced {
        s.cloud.telemetry.enable_memory_trace();
    }
    let mut p = FnPlatform::new(policy);
    p.autoscaler.max_idle_mb = max_idle_mb;
    let quotas = QuotaBook::default();
    let wall = Instant::now();
    for g in arrivals {
        let now = s.cloud.clock.now_s();
        if g.arrival_s > now {
            s.cloud.clock.advance(g.arrival_s - now);
        }
        p.invoke(&mut s, &quotas, &spec_for(g)).expect("unquota'd invocation admits");
    }
    p.drain(&mut s, &quotas);
    p.flush(&mut s);
    let wall_s = wall.elapsed().as_secs_f64();
    assert!(p.conserved(), "{label}: container conservation broken");
    assert_eq!(p.pool.len(), 0, "{label}: drain + flush must empty the pool");

    // Per-tenant invoices must close against the raw ledger exactly.
    let tenants: BTreeSet<&str> = arrivals.iter().map(|g| g.tenant.as_str()).collect();
    let (mut invoke_cc, mut pool_cc, mut invoiced_cc) = (0u64, 0u64, 0u64);
    for t in tenants {
        let inv = s.cloud.ledger.invoice_for(t);
        invoke_cc += inv.fn_invoke_cc;
        pool_cc += inv.fn_pool_cc;
        invoiced_cc += inv.total_centi_cents();
        assert_eq!(
            inv.total_centi_cents(),
            s.cloud.ledger.total_centi_cents_for(t),
            "{label}: invoice for {t} must reconcile centi-cent-exactly"
        );
    }
    assert_eq!(
        invoiced_cc,
        s.cloud.ledger.total_centi_cents(),
        "{label}: tenant invoices must cover the whole ledger"
    );

    let out = RunOut {
        label: label.to_string(),
        invocations: p.invocations_total,
        cold: p.cold_total,
        provisioned: p.provisioned_total,
        evicted: p.evicted_total,
        expired_evictions: p.expired_evictions,
        pressure_evictions: p.pressure_evictions,
        idle_gb_hours: p.idle_gb_hours(),
        total_cost_cc: s.cloud.ledger.total_centi_cents(),
        fn_invoke_cc: invoke_cc,
        fn_pool_cc: pool_cc,
        dispatch_digest: p.dispatch_digest(),
        metrics_snapshot: s.cloud.telemetry.snapshot_json().to_string_compact(),
        sim_seconds: s.cloud.clock.now_s(),
        wall_s,
    };
    let trace = if traced { s.cloud.telemetry.take_memory_trace() } else { Vec::new() };
    (out, trace)
}

fn main() {
    println!("=== serverless tier: fixed vs hybrid keepalive on a diurnal day ===\n");
    let cfg = GenLoadConfig {
        jobs: INVOCATIONS,
        tenants: TENANTS,
        ..GenLoadConfig::default()
    };
    let arrivals = generate(&cfg);
    let functions: BTreeSet<String> = arrivals
        .iter()
        .map(|g| format!("{}/{}", g.tenant, spec_for(g).fname))
        .collect();
    println!(
        "  workload: {} invocations, {} tenants, {} functions, horizon {:.0}s\n",
        arrivals.len(),
        TENANTS,
        functions.len(),
        cfg.horizon_s
    );

    let (fixed, _) =
        run_policy("fixed-600", KeepalivePolicy::Fixed(600.0), &arrivals, UNBOUNDED_MB, false);
    println!("  {}", fixed.row());
    let (hybrid, _) = run_policy(
        "hybrid-600",
        KeepalivePolicy::Hybrid { default_s: 600.0 },
        &arrivals,
        UNBOUNDED_MB,
        false,
    );
    println!("  {}", hybrid.row());

    // The tentpole claim, asserted: strictly fewer cold starts at no
    // higher total cost.
    assert!(
        hybrid.cold < fixed.cold,
        "hybrid must cold-start strictly less: {} vs {}",
        hybrid.cold,
        fixed.cold
    );
    assert!(
        hybrid.total_cost_cc <= fixed.total_cost_cc,
        "hybrid must cost no more: {} vs {} cc",
        hybrid.total_cost_cc,
        fixed.total_cost_cc
    );
    println!(
        "\n  -> hybrid: {:.2}% cold vs {:.2}% fixed, at {} vs {} cc total\n",
        hybrid.cold_fraction() * 100.0,
        fixed.cold_fraction() * 100.0,
        hybrid.total_cost_cc,
        fixed.total_cost_cc
    );

    // Same seed, same books: the replay must be bit-identical.
    let (hybrid2, _) = run_policy(
        "hybrid-600",
        KeepalivePolicy::Hybrid { default_s: 600.0 },
        &arrivals,
        UNBOUNDED_MB,
        false,
    );
    let deterministic = hybrid.dispatch_digest == hybrid2.dispatch_digest
        && hybrid.total_cost_cc == hybrid2.total_cost_cc
        && hybrid.metrics_snapshot == hybrid2.metrics_snapshot;
    assert!(deterministic, "same-seed replay diverged");
    println!("  -> same-seed replay bit-identical (digest, bill, metrics snapshot)\n");

    // The autoscaler's trade: sweep the idle-memory budget on the
    // hybrid policy. Tighter budgets convert idle GB-hours into
    // pressure evictions — and pressure evictions into cold starts.
    let sweep_arrivals = &arrivals[..SWEEP_INVOCATIONS.min(arrivals.len())];
    let budgets: [(&str, u64); 3] =
        [("8GB", 8_192), ("64GB", 65_536), ("unbounded", UNBOUNDED_MB)];
    let mut sweep_rows = Vec::new();
    let mut sweep_runs = Vec::new();
    for (blabel, mb) in budgets {
        let (r, _) = run_policy(
            &format!("hybrid/{blabel}"),
            KeepalivePolicy::Hybrid { default_s: 600.0 },
            sweep_arrivals,
            mb,
            false,
        );
        println!("  {}", r.row());
        let mut o = r.to_json();
        o.set("max_idle_mb", if mb == UNBOUNDED_MB { Json::Null } else { Json::num(mb as f64) });
        sweep_rows.push(o);
        sweep_runs.push(r);
    }
    let (tight, open) = (&sweep_runs[0], &sweep_runs[sweep_runs.len() - 1]);
    assert!(
        tight.cold_fraction() >= open.cold_fraction(),
        "a tighter idle budget cannot reduce cold starts"
    );
    assert!(
        tight.idle_gb_hours <= open.idle_gb_hours,
        "a tighter idle budget cannot spend more idle memory"
    );
    println!(
        "\n  -> idle-budget trade: 8GB holds idle memory to {:.1} GB-h ({:.2}% cold) vs \
         unbounded {:.1} GB-h ({:.2}% cold)\n",
        tight.idle_gb_hours,
        tight.cold_fraction() * 100.0,
        open.idle_gb_hours,
        open.cold_fraction() * 100.0
    );

    // A short traced replay: the JSONL invocation trace sample the CI
    // validator checks for well-formedness.
    let (_, trace) = run_policy(
        "hybrid/traced",
        KeepalivePolicy::Hybrid { default_s: 600.0 },
        &arrivals[..TRACE_INVOCATIONS.min(arrivals.len())],
        UNBOUNDED_MB,
        true,
    );
    assert!(!trace.is_empty(), "the traced sample must record events");

    let mut report = Json::obj();
    let mut wl = Json::obj();
    wl.set("invocations", Json::num(arrivals.len() as f64));
    wl.set("tenants", Json::num(TENANTS as f64));
    wl.set("functions", Json::num(functions.len() as f64));
    wl.set("horizon_s", Json::num(cfg.horizon_s));
    wl.set("seed", Json::num(cfg.seed as f64));
    report.set("workload", wl);
    report.set("policies", Json::Arr(vec![fixed.to_json(), hybrid.to_json()]));
    report.set("fixed_cold_fraction", Json::num(fixed.cold_fraction()));
    report.set("hybrid_cold_fraction", Json::num(hybrid.cold_fraction()));
    report.set("fixed_cost_cc", Json::num(fixed.total_cost_cc as f64));
    report.set("hybrid_cost_cc", Json::num(hybrid.total_cost_cc as f64));
    report.set(
        "hybrid_beats_fixed_cold",
        Json::Bool(hybrid.cold < fixed.cold),
    );
    report.set(
        "hybrid_cost_no_higher",
        Json::Bool(hybrid.total_cost_cc <= fixed.total_cost_cc),
    );
    report.set("deterministic", Json::Bool(deterministic));
    report.set("budget_sweep", Json::Arr(sweep_rows));
    report.set(
        "trace_sample",
        Json::Arr(trace.iter().map(|l| Json::str(l.as_str())).collect()),
    );
    match emit_bench_json("functions", &report) {
        Ok(path) => println!("  wrote {}", path.display()),
        Err(e) => eprintln!("  could not write BENCH_functions.json: {e}"),
    }
    println!("\nfunctions bench complete.");
}
