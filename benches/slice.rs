//! Slice fast-path bench (ISSUE 8): one seeded multi-slice sweep
//! workload driven through the real [`p2rac::jobs::JobScheduler`]
//! twice — once with the fast path off (every slice re-parses the
//! script, re-forks the sweep plan, round-trips the checkpoint JSON
//! and ships the O(done) full snapshot: the seed's world) and once
//! with it on (warm [`JobWork`] + pooled workers out of the work
//! cache, O(slice) delta links on the checkpoint chain, full-snapshot
//! compaction every K slices).
//!
//! Both modes run the same discrete-event simulation, so before any
//! timing is reported the bench asserts **parity**: the dispatch
//! sequence (job, cluster per dispatch event), the total bill and the
//! result-file digests must be bit-identical. Only then are
//! slices/sec (best of interleaved rounds) and checkpoint bytes
//! shipped compared, and the fast path must clear 2x throughput on
//! strictly fewer shipped bytes. Emits `BENCH_slice.json` at the
//! repository root.
//!
//! Run: `cargo bench --bench slice`

use std::time::Instant;

use p2rac::bench_support::emit_bench_json;
use p2rac::coordinator::{MockEngine, Session};
use p2rac::jobs::{files_digest, AutoscalerConfig, JobScheduler, JobSpecBuilder, JobState};
use p2rac::simcloud::SimParams;
use p2rac::util::json::Json;

/// Jobs per sweep: 100 batches at the 64-job tile, so each of the
/// three queued jobs runs 100 one-unit slices and the rebuild path's
/// O(done) checkpoint work compounds visibly.
const N_JOBS: usize = 6400;
/// Queued sweep jobs (serialised on the single bench cluster).
const SWEEPS: usize = 3;
/// Virtual seconds per MC job — tiny, so wall-clock is dominated by
/// the per-slice bookkeeping under test, not the simulated numerics.
const JOB_COST_S: f64 = 0.05;
/// Interleaved timing rounds; the best round is reported.
const ROUNDS: usize = 3;

/// FNV-1a over a byte string.
fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x0100_0000_01B3);
    }
    h
}

struct RunOut {
    wall_s: f64,
    slices: u64,
    dispatch_digest: u64,
    bill_centi_cents: u64,
    results_digest: u64,
    ckpt_bytes_shipped: u64,
    cache_hits: u64,
    delta_commits: u64,
    completions: usize,
}

/// Drain the whole workload once with the fast path on or off and
/// collect the parity artifacts plus the drain wall time.
fn run(fast: bool) -> RunOut {
    let mut s = Session::new(SimParams::default(), Box::new(MockEngine::new(10.0)));
    s.cloud.spot.spike_prob = 0.0;
    s.cloud.telemetry.enable_memory_trace();
    for i in 0..SWEEPS {
        s.analyst.write(
            &format!("sweep{i}/sweep.json"),
            format!(
                r#"{{"type":"mc_sweep","n_jobs":{N_JOBS},"seed":{},"job_cost_s":{JOB_COST_S}}}"#,
                900 + i
            )
            .into_bytes(),
        );
    }
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 1,
        max_clusters: 1,
        nodes_per_cluster: 2,
        spot: false,
        ..Default::default()
    });
    js.fast_path = fast;
    js.slice_units = 1;
    let ids: Vec<_> = (0..SWEEPS)
        .map(|i| {
            js.submit(
                &s,
                JobSpecBuilder::new(&format!("r{i}"), &format!("sweep{i}"), "sweep.json")
                    .build(),
            )
        })
        .collect();
    let t0 = Instant::now();
    js.run_until_idle(&mut s).unwrap();
    let wall_s = t0.elapsed().as_secs_f64();
    js.shutdown_fleet(&mut s).unwrap();

    let mut completions = 0;
    for &id in &ids {
        if js.queue.get(id).unwrap().state == JobState::Completed {
            completions += 1;
        }
    }
    // The dispatch sequence, independent of per-mode detail fields
    // (the cache hit/miss tag legitimately differs): (job, cluster)
    // per dispatch event, in event order.
    let mut dispatch_digest = 0xcbf2_9ce4_8422_2325u64;
    let mut slices = 0u64;
    for line in s.cloud.telemetry.take_memory_trace() {
        let j = Json::parse(&line).unwrap();
        if j.opt_str("kind").as_deref() != Some("dispatch") {
            continue;
        }
        slices += 1;
        dispatch_digest = fnv1a(dispatch_digest, j.opt_str("job").unwrap_or_default().as_bytes());
        dispatch_digest =
            fnv1a(dispatch_digest, j.opt_str("cluster").unwrap_or_default().as_bytes());
    }
    let mut results: Vec<(String, Vec<u8>)> = Vec::new();
    for i in 0..SWEEPS {
        let dir = format!("sweep{i}_results/r{i}");
        for rel in s.analyst.list_dir(&dir) {
            let bytes = s.analyst.read(&format!("{dir}/{rel}")).unwrap().to_vec();
            results.push((format!("{dir}/{rel}"), bytes));
        }
    }
    results.sort();
    RunOut {
        wall_s,
        slices,
        dispatch_digest,
        bill_centi_cents: s.cloud.ledger.total_centi_cents(),
        results_digest: files_digest(&results),
        ckpt_bytes_shipped: js.ckpt_bytes_shipped,
        cache_hits: js.work_cache_hits,
        delta_commits: js.ckpt_delta_commits,
        completions,
    }
}

fn main() {
    println!(
        "=== slice fast path: warm work cache + delta checkpoints vs per-slice rebuild ===\n\
         {SWEEPS} sweeps x {N_JOBS} MC jobs, one-unit slices on a single cluster\n"
    );

    // Interleaved rounds absorb machine noise; every round must agree
    // on the parity artifacts, the best round carries the timing.
    let mut rebuild = run(false);
    let mut fast = run(true);
    for _ in 1..ROUNDS {
        let r = run(false);
        let f = run(true);
        assert_eq!(r.dispatch_digest, rebuild.dispatch_digest, "rebuild runs must agree");
        assert_eq!(f.dispatch_digest, fast.dispatch_digest, "fast runs must agree");
        if r.wall_s < rebuild.wall_s {
            rebuild = r;
        }
        if f.wall_s < fast.wall_s {
            fast = f;
        }
    }

    // Parity: the fast path must be invisible in everything but time
    // and shipped bytes.
    assert_eq!(rebuild.completions, SWEEPS, "rebuild run must complete all jobs");
    assert_eq!(fast.completions, SWEEPS, "fast run must complete all jobs");
    let dispatch_parity = fast.dispatch_digest == rebuild.dispatch_digest;
    let bill_parity = fast.bill_centi_cents == rebuild.bill_centi_cents;
    let results_parity = fast.results_digest == rebuild.results_digest;
    assert!(dispatch_parity, "dispatch sequence diverged");
    assert!(
        bill_parity,
        "bill diverged: fast {}cc vs rebuild {}cc",
        fast.bill_centi_cents, rebuild.bill_centi_cents
    );
    assert!(results_parity, "result files diverged");
    assert_eq!(fast.slices, rebuild.slices, "slice count diverged");
    assert!(fast.cache_hits > 0, "the fast run must hit the warm cache");
    assert!(fast.delta_commits > 0, "the fast run must ship delta links");

    let sps = |r: &RunOut| r.slices as f64 / r.wall_s.max(1e-9);
    let speedup = sps(&fast) / sps(&rebuild);
    for (label, r) in [("rebuild", &rebuild), ("fast", &fast)] {
        println!(
            "  {label:>8}: {:>4} slices in {:>7.3}s wall = {:>8.1} slices/s, {} ckpt bytes shipped",
            r.slices,
            r.wall_s,
            sps(r),
            r.ckpt_bytes_shipped
        );
    }
    println!(
        "\n  -> speedup {speedup:.2}x, ckpt bytes {} -> {}",
        rebuild.ckpt_bytes_shipped, fast.ckpt_bytes_shipped
    );

    assert!(
        speedup >= 2.0,
        "fast path must clear 2x slices/sec (got {speedup:.2}x)"
    );
    assert!(
        fast.ckpt_bytes_shipped < rebuild.ckpt_bytes_shipped,
        "delta chain must ship strictly fewer bytes ({} vs {})",
        fast.ckpt_bytes_shipped,
        rebuild.ckpt_bytes_shipped
    );

    let mode_json = |r: &RunOut| {
        Json::from_pairs(vec![
            ("wall_s", Json::num(r.wall_s)),
            ("slices", Json::num(r.slices as f64)),
            ("slices_per_s", Json::num(sps(r))),
            ("ckpt_bytes_shipped", Json::num(r.ckpt_bytes_shipped as f64)),
            ("bill_centi_cents", Json::num(r.bill_centi_cents as f64)),
            ("cache_hits", Json::num(r.cache_hits as f64)),
            ("delta_commits", Json::num(r.delta_commits as f64)),
        ])
    };
    let report = Json::from_pairs(vec![
        (
            "workload",
            Json::from_pairs(vec![
                ("sweeps", Json::num(SWEEPS as f64)),
                ("n_jobs", Json::num(N_JOBS as f64)),
                ("slice_units", Json::num(1.0)),
                ("rounds", Json::num(ROUNDS as f64)),
            ]),
        ),
        ("rebuild", mode_json(&rebuild)),
        ("fast", mode_json(&fast)),
        (
            "parity",
            Json::from_pairs(vec![
                ("dispatch", Json::Bool(dispatch_parity)),
                ("bill", Json::Bool(bill_parity)),
                ("results", Json::Bool(results_parity)),
            ]),
        ),
        ("speedup", Json::num(speedup)),
    ]);
    match emit_bench_json("slice", &report) {
        Ok(path) => println!("  wrote {}", path.display()),
        Err(e) => eprintln!("  could not write BENCH_slice.json: {e}"),
    }
    println!("\nslice bench complete.");
}
