//! Figure 6 — management times for the CATopt problem (~300 MB
//! project): time to (a) create the resource, (b) submit the project to
//! an instance / the master, (c) submit to all nodes, (d) fetch results
//! from an instance / the master, (e) fetch from all nodes, (f)
//! terminate, on Instance A/B and Clusters A–D.
//!
//! Expected shape: creation grows with cluster size (~7 min @ 8 nodes,
//! ~8 min @ 16); termination flat; submit/fetch to master flat across
//! resources; submit/fetch to ALL nodes grows with the cluster size.
//!
//! Run: `cargo bench --bench fig6_catopt_mgmt`

use p2rac::bench_support::{
    bench_session, run_on_resource_profile, table1_resources, BenchProfile, Resource, Workload,
};
use p2rac::util::humanfmt::secs;

fn main() {
    run_mgmt_bench(
        "Figure 6: CATopt (~300 MB project)",
        Workload::Catopt,
        // The bench dataset is ~1/64 of the paper's 300 MB table; the
        // network model scales wire time back up.
        64.0,
    );
}

pub fn run_mgmt_bench(title: &str, wl: Workload, data_scale: f64) {
    println!("=== {title} ===\n");
    println!(
        "{:<11} {:>9} {:>13} {:>12} {:>12} {:>11} {:>10}",
        "resource", "create", "submit(mstr)", "submit(all)", "fetch(mstr)", "fetch(all)", "terminate"
    );
    let mut rows = Vec::new();
    for r in table1_resources() {
        if matches!(r, Resource::Desktop(_)) {
            continue; // Figs 6–7 cover cloud resources only
        }
        let mut s = bench_session(data_scale);
        let b = run_on_resource_profile(&mut s, &r, wl, BenchProfile::Management)
            .expect("bench run");
        println!(
            "{:<11} {:>9} {:>13} {:>12} {:>12} {:>11} {:>10}",
            r.label(),
            secs(b.create_s),
            secs(b.submit_master_s),
            if b.submit_all_s > 0.0 { secs(b.submit_all_s) } else { "-".into() },
            secs(b.fetch_master_s),
            if b.fetch_all_s > 0.0 { secs(b.fetch_all_s) } else { "-".into() },
            secs(b.terminate_s),
        );
        rows.push((r.label(), b));
    }

    // ---- paper-shape assertions ----
    let by = |l: &str| rows.iter().find(|(x, _)| x == l).map(|(_, b)| b).unwrap();
    let (ca, cb, cc, cd) = (by("Cluster A"), by("Cluster B"), by("Cluster C"), by("Cluster D"));
    // Creation grows with cluster size; ~7 min at 8 nodes, ~8 min at 16.
    assert!(ca.create_s < cb.create_s && cb.create_s < cc.create_s && cc.create_s < cd.create_s);
    assert!(
        (300.0..600.0).contains(&cc.create_s),
        "8-node create {}s should be ≈7 min",
        cc.create_s
    );
    assert!(
        (420.0..720.0).contains(&cd.create_s),
        "16-node create {}s should be ≈8 min",
        cd.create_s
    );
    // Termination flat ("remains the same").
    let terms: Vec<f64> = rows.iter().map(|(_, b)| b.terminate_s).collect();
    let tmax = terms.iter().cloned().fold(0.0, f64::max);
    let tmin = terms.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(tmax - tmin < 1.0, "terminate must be size-independent");
    // Submit-to-master roughly flat; submit-to-all grows with n.
    assert!(
        (ca.submit_master_s - cd.submit_master_s).abs() < 0.3 * ca.submit_master_s.max(1.0),
        "submit-to-master should not depend on cluster size"
    );
    assert!(
        cd.submit_all_s > ca.submit_all_s,
        "submit-to-all must grow with cluster size"
    );
    println!("\n{} shape checks passed.", title.split(':').next().unwrap());
}
