//! Figure 4 — "Speed-up achieved for the CATopt and Parameter Sweep
//! Problems using P2RAC": relative speed-up vs number of Amazon
//! instances (m2.2xlarge), baseline = 1 instance.
//!
//! Expected shape (paper §4): near-100% parallel efficiency up to 4
//! instances, then a drop caused by communication overheads between
//! virtualised cloud instances; the independent-parallel sweep scales
//! better than the co-operative CATopt at high node counts.
//!
//! Run: `cargo bench --bench fig4_speedup`

use p2rac::bench_support::{bench_session, run_on_resource, Resource, Workload};
use p2rac::util::humanfmt;

fn main() {
    println!("=== Figure 4: relative speed-up vs #instances (m2.2xlarge) ===\n");
    let node_counts = [1usize, 2, 4, 8, 16];

    for wl in [Workload::Catopt, Workload::Sweep] {
        println!("--- {} ---", wl.label());
        println!(
            "{:>10} {:>6} {:>14} {:>9} {:>11}",
            "instances", "cores", "virtual time", "speed-up", "efficiency"
        );
        let mut t1 = 0.0f64;
        let mut speedups = Vec::new();
        for &n in &node_counts {
            let mut s = bench_session(1.0);
            let r = if n == 1 {
                Resource::Instance {
                    label: "n1".into(),
                    itype: "m2.2xlarge".into(),
                }
            } else {
                Resource::Cluster {
                    label: format!("n{n}"),
                    itype: "m2.2xlarge".into(),
                    nodes: n,
                }
            };
            let b = run_on_resource(&mut s, &r, wl).expect("bench run");
            if n == 1 {
                t1 = b.compute_s;
            }
            let sp = t1 / b.compute_s;
            speedups.push((n, sp));
            println!(
                "{:>10} {:>6} {:>14} {:>8.2}x {:>10.0}%",
                n,
                n * 4,
                humanfmt::secs(b.compute_s),
                sp,
                100.0 * sp / n as f64
            );
        }
        // Shape assertions (who wins / where the knee falls).
        let eff = |i: usize| 100.0 * speedups[i].1 / speedups[i].0 as f64;
        assert!(eff(1) > 85.0, "{}: eff(2)={:.0}%", wl.label(), eff(1));
        assert!(
            eff(2) > 70.0,
            "{}: near-linear region must reach 4 instances (eff={:.0}%)",
            wl.label(),
            eff(2)
        );
        assert!(
            eff(4) < eff(2),
            "{}: efficiency must drop past 4 instances",
            wl.label()
        );
        assert!(
            speedups.windows(2).all(|w| w[1].1 >= w[0].1 * 0.99),
            "{}: speed-up should not regress with more instances",
            wl.label()
        );
        println!();
    }

    // Cross-workload comparison at 16 instances.
    let sp16 = |wl: Workload| {
        let t1 = {
            let mut s = bench_session(1.0);
            run_on_resource(
                &mut s,
                &Resource::Instance {
                    label: "b".into(),
                    itype: "m2.2xlarge".into(),
                },
                wl,
            )
            .unwrap()
            .compute_s
        };
        let t16 = {
            let mut s = bench_session(1.0);
            run_on_resource(
                &mut s,
                &Resource::Cluster {
                    label: "c".into(),
                    itype: "m2.2xlarge".into(),
                    nodes: 16,
                },
                wl,
            )
            .unwrap()
            .compute_s
        };
        t1 / t16
    };
    let cat = sp16(Workload::Catopt);
    let swp = sp16(Workload::Sweep);
    println!("at 16 instances: CATopt {cat:.1}x vs sweep {swp:.1}x");
    assert!(
        swp > cat,
        "independent parallelism must out-scale co-operative parallelism"
    );
    println!("\nFigure 4 shape checks passed.");
}
