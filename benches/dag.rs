//! DAG data-aware placement bench (ISSUE 10): one seeded fan-out /
//! fan-in workflow (prep → N parallel sweeps → aggregate, wired with
//! `-after` edges) drained through the real
//! [`p2rac::jobs::JobScheduler`] twice — once data-oblivious (every
//! dependent stage re-stages its parents' result files from the
//! Analyst site over the metered WAN: the pre-DAG world) and once
//! data-aware (finished stages publish outputs to the S3 results
//! bucket over the cluster LAN, digest-deduped, and dispatch prefers
//! the cluster whose LAN already holds the inputs).
//!
//! Both modes run the same discrete-event simulation, so the bench
//! asserts **determinism** first: repeat runs of each mode must agree
//! bit-for-bit on the dispatch sequence, the bill and the result-file
//! digests. Only then are the headline claims checked: data-aware
//! placement must be strictly cheaper in WAN transfer centi-cents and
//! no slower in virtual makespan (so stage throughput is no worse).
//! Emits `BENCH_dag.json` at the repository root.
//!
//! Run: `cargo bench --bench dag`

use std::time::Instant;

use p2rac::bench_support::emit_bench_json;
use p2rac::coordinator::{MockEngine, Session};
use p2rac::jobs::{files_digest, AutoscalerConfig, JobScheduler, JobSpecBuilder, JobState};
use p2rac::simcloud::SimParams;
use p2rac::util::json::Json;

/// Parallel sweep stages between the prep stage and the aggregate.
const FANOUT: usize = 4;
/// MC jobs per stage — enough result bytes that WAN re-staging is
/// visible in both the ledger and the virtual clock.
const N_JOBS: usize = 48;
/// Interleaved timing rounds; every round must agree on the parity
/// artifacts, the best round carries the wall time.
const ROUNDS: usize = 3;

/// FNV-1a over a byte string.
fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x0100_0000_01B3);
    }
    h
}

struct RunOut {
    wall_s: f64,
    makespan_s: f64,
    wan_centi_cents: u64,
    bill_centi_cents: u64,
    dispatch_digest: u64,
    results_digest: u64,
    releases: u64,
    cancels: u64,
    dedup_skips: u64,
    completions: usize,
}

/// Stage names in submission order: prep, the fan-out, the fan-in.
fn stage_names() -> Vec<String> {
    let mut names = vec!["prep".to_string()];
    names.extend((0..FANOUT).map(|i| format!("f{i}")));
    names.push("agg".to_string());
    names
}

/// Drain the fan-out/fan-in workflow once, data-aware or not, and
/// collect the parity artifacts plus cost/makespan.
fn run(aware: bool) -> RunOut {
    let mut s = Session::new(SimParams::default(), Box::new(MockEngine::new(10.0)));
    s.cloud.spot.spike_prob = 0.0;
    s.cloud.telemetry.enable_memory_trace();
    s.analyst.write(
        "pipe/sweep.json",
        format!(r#"{{"type":"mc_sweep","n_jobs":{N_JOBS},"seed":900}}"#).into_bytes(),
    );
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 2,
        max_clusters: 2,
        nodes_per_cluster: 2,
        spot: false,
        ..Default::default()
    });
    js.data_aware = aware;
    let prep = js.submit(&s, JobSpecBuilder::new("prep", "pipe", "sweep.json").build());
    let mids: Vec<_> = (0..FANOUT)
        .map(|i| {
            js.submit(
                &s,
                JobSpecBuilder::new(&format!("f{i}"), "pipe", "sweep.json")
                    .after([prep])
                    .build(),
            )
        })
        .collect();
    let agg = js.submit(
        &s,
        JobSpecBuilder::new("agg", "pipe", "sweep.json")
            .after(mids.iter().copied())
            .build(),
    );
    let t0 = Instant::now();
    js.run_until_idle(&mut s).unwrap();
    let wall_s = t0.elapsed().as_secs_f64();
    let makespan_s = s.cloud.clock.now_s();
    js.shutdown_fleet(&mut s).unwrap();

    let mut completions = 0;
    for id in std::iter::once(prep).chain(mids.iter().copied()).chain([agg]) {
        if js.queue.get(id).unwrap().state == JobState::Completed {
            completions += 1;
        }
    }
    // The dispatch sequence — (job, cluster) per dispatch event, in
    // event order — pins placement for the repeat-determinism check.
    let mut dispatch_digest = 0xcbf2_9ce4_8422_2325u64;
    for line in s.cloud.telemetry.take_memory_trace() {
        let j = Json::parse(&line).unwrap();
        if j.opt_str("kind").as_deref() != Some("dispatch") {
            continue;
        }
        dispatch_digest = fnv1a(dispatch_digest, j.opt_str("job").unwrap_or_default().as_bytes());
        dispatch_digest =
            fnv1a(dispatch_digest, j.opt_str("cluster").unwrap_or_default().as_bytes());
    }
    let mut results: Vec<(String, Vec<u8>)> = Vec::new();
    for name in stage_names() {
        let dir = format!("pipe_results/{name}");
        for rel in s.analyst.list_dir(&dir) {
            let bytes = s.analyst.read(&format!("{dir}/{rel}")).unwrap().to_vec();
            results.push((format!("{dir}/{rel}"), bytes));
        }
    }
    results.sort();
    RunOut {
        wall_s,
        makespan_s,
        wan_centi_cents: s.cloud.ledger.total_wan_transfer_centi_cents(),
        bill_centi_cents: s.cloud.ledger.total_centi_cents(),
        dispatch_digest,
        results_digest: files_digest(&results),
        releases: js.dag_releases,
        cancels: js.dag_cancels,
        dedup_skips: js.dag_dedup_skips,
        completions,
    }
}

fn main() {
    let stages = FANOUT + 2;
    println!(
        "=== DAG data-aware placement: S3 results bucket + LAN routing vs WAN re-staging ===\n\
         prep -> {FANOUT} parallel sweeps -> aggregate ({stages} stages x {N_JOBS} MC jobs) \
         on a 2-cluster fleet\n"
    );

    // Interleaved rounds: repeat runs of a mode must be bit-identical
    // (the simulation is deterministic); the best wall time reports.
    let mut oblivious = run(false);
    let mut aware = run(true);
    let mut oblivious_repeats = true;
    let mut aware_repeats = true;
    for _ in 1..ROUNDS {
        let o = run(false);
        let a = run(true);
        oblivious_repeats &= o.dispatch_digest == oblivious.dispatch_digest
            && o.results_digest == oblivious.results_digest
            && o.bill_centi_cents == oblivious.bill_centi_cents
            && o.wan_centi_cents == oblivious.wan_centi_cents
            && o.makespan_s == oblivious.makespan_s;
        aware_repeats &= a.dispatch_digest == aware.dispatch_digest
            && a.results_digest == aware.results_digest
            && a.bill_centi_cents == aware.bill_centi_cents
            && a.wan_centi_cents == aware.wan_centi_cents
            && a.makespan_s == aware.makespan_s;
        if o.wall_s < oblivious.wall_s {
            oblivious = o;
        }
        if a.wall_s < aware.wall_s {
            aware = a;
        }
    }
    assert!(oblivious_repeats, "data-oblivious runs must be bit-identical");
    assert!(aware_repeats, "data-aware runs must be bit-identical");

    // Both modes run the identical DAG control plane and finish the
    // identical work.
    for (label, r) in [("oblivious", &oblivious), ("aware", &aware)] {
        assert_eq!(r.completions, stages, "{label} run must complete all stages");
        assert_eq!(r.cancels, 0, "{label} run must cancel nothing");
        assert_eq!(
            r.releases,
            (FANOUT + 1) as u64,
            "{label} run must release each held stage exactly once"
        );
    }
    assert_eq!(
        aware.results_digest, oblivious.results_digest,
        "placement must not change the result files"
    );

    let tput = |r: &RunOut| stages as f64 / r.makespan_s.max(1e-9);
    for (label, r) in [("oblivious", &oblivious), ("aware", &aware)] {
        println!(
            "  {label:>9}: {} cc WAN transfer, {} cc total bill, makespan {:>8.1}s \
             ({:.4} stages/virtual-s), {} dedup skip(s), wall {:.3}s",
            r.wan_centi_cents,
            r.bill_centi_cents,
            r.makespan_s,
            tput(r),
            r.dedup_skips,
            r.wall_s,
        );
    }
    println!(
        "\n  -> WAN {} cc -> {} cc, makespan {:.1}s -> {:.1}s",
        oblivious.wan_centi_cents, aware.wan_centi_cents, oblivious.makespan_s, aware.makespan_s
    );

    // The headline claims: strictly cheaper over the WAN, no slower.
    assert!(
        aware.wan_centi_cents < oblivious.wan_centi_cents,
        "data-aware placement must be strictly cheaper in WAN transfer ({} cc vs {} cc)",
        aware.wan_centi_cents,
        oblivious.wan_centi_cents
    );
    assert!(
        aware.makespan_s <= oblivious.makespan_s,
        "data-aware placement must be no slower ({:.3}s vs {:.3}s)",
        aware.makespan_s,
        oblivious.makespan_s
    );
    assert!(
        aware.dedup_skips > 0,
        "identical stage outputs must dedup in the results bucket"
    );

    let mode_json = |r: &RunOut| {
        Json::from_pairs(vec![
            ("wan_centi_cents", Json::num(r.wan_centi_cents as f64)),
            ("bill_centi_cents", Json::num(r.bill_centi_cents as f64)),
            ("makespan_s", Json::num(r.makespan_s)),
            ("stages_per_virtual_s", Json::num(tput(r))),
            ("wall_s", Json::num(r.wall_s)),
            ("releases", Json::num(r.releases as f64)),
            ("dedup_skips", Json::num(r.dedup_skips as f64)),
            ("dispatch_digest", Json::str(&format!("{:016x}", r.dispatch_digest))),
            ("results_digest", Json::str(&format!("{:016x}", r.results_digest))),
        ])
    };
    let report = Json::from_pairs(vec![
        (
            "workload",
            Json::from_pairs(vec![
                ("fanout", Json::num(FANOUT as f64)),
                ("stages", Json::num(stages as f64)),
                ("n_jobs", Json::num(N_JOBS as f64)),
                ("rounds", Json::num(ROUNDS as f64)),
            ]),
        ),
        ("oblivious", mode_json(&oblivious)),
        ("aware", mode_json(&aware)),
        (
            "parity",
            Json::from_pairs(vec![
                ("oblivious_repeats", Json::Bool(oblivious_repeats)),
                ("aware_repeats", Json::Bool(aware_repeats)),
                (
                    "results_match",
                    Json::Bool(aware.results_digest == oblivious.results_digest),
                ),
            ]),
        ),
        (
            "savings",
            Json::from_pairs(vec![
                (
                    "wan_centi_cents_saved",
                    Json::num((oblivious.wan_centi_cents - aware.wan_centi_cents) as f64),
                ),
                (
                    "makespan_ratio",
                    Json::num(aware.makespan_s / oblivious.makespan_s.max(1e-9)),
                ),
            ]),
        ),
    ]);
    match emit_bench_json("dag", &report) {
        Ok(path) => println!("  wrote {}", path.display()),
        Err(e) => eprintln!("  could not write BENCH_dag.json: {e}"),
    }
    println!("\ndag bench complete.");
}
