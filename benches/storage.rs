//! Storage-plane resume scenario: the same long CATopt job on a
//! one-cluster spot fleet whose bid is exceeded at every hour boundary,
//! so the provider reclaims the cluster mid-run and the scheduler must
//! resume the job on replacement capacity —
//!
//! * **WAN-resume baseline**: checkpoints ship to the Analyst site and
//!   the replacement cluster re-syncs the paper-scale project over the
//!   metered WAN (the seed's world);
//! * **LAN-resume resident**: checkpoints live cluster-side (EBS
//!   volume + S3 mirror + EBS snapshot) and replacement capacity
//!   restores project + checkpoint over the LAN from a
//!   snapshot-backed volume (§3.2.1 of the source paper: the
//!   Analyst's data lives in the cloud, so repeated runs pay LAN).
//!
//! Asserts the headline property: the resident resume pays strictly
//! less transfer cost AND strictly less virtual time than the WAN
//! baseline, while both produce results bit-identical to an
//! uninterrupted on-demand run. Emits `BENCH_storage.json` at the
//! repository root.
//!
//! Run: `cargo bench --bench storage`

use p2rac::bench_support::{emit_bench_json, run_storage_scenario};
use p2rac::util::json::Json;

fn main() {
    println!("=== storage plane: WAN-resume vs LAN-resume of a spot-interrupted job ===\n");
    let truth = run_storage_scenario("uninterrupted truth", false, false).unwrap();
    let wan = run_storage_scenario("wan-resume baseline", false, true).unwrap();
    let lan = run_storage_scenario("lan-resume resident", true, true).unwrap();
    for r in [&truth, &wan, &lan] {
        println!("  {}", r.row());
    }

    assert!(
        wan.interruptions >= 1 && lan.interruptions >= 1,
        "both interruptible runs must actually be reclaimed"
    );
    assert_eq!(
        wan.result_digest, truth.result_digest,
        "WAN resume must be bit-identical to the uninterrupted run"
    );
    assert_eq!(
        lan.result_digest, truth.result_digest,
        "LAN resume must be bit-identical to the uninterrupted run"
    );
    assert!(
        lan.wan_transfer_centi_cents < wan.wan_transfer_centi_cents,
        "LAN resume ({}cc) must pay strictly less transfer cost than WAN resume ({}cc)",
        lan.wan_transfer_centi_cents,
        wan.wan_transfer_centi_cents
    );
    assert!(
        lan.makespan_s < wan.makespan_s,
        "LAN resume ({:.0}s) must be strictly faster than WAN resume ({:.0}s)",
        lan.makespan_s,
        wan.makespan_s
    );
    println!(
        "\n  -> cluster-side snapshot resume: {:.0}% of the baseline's WAN transfer bill, \
         {:.0}s less virtual time",
        100.0 * lan.wan_transfer_centi_cents as f64 / wan.wan_transfer_centi_cents.max(1) as f64,
        wan.makespan_s - lan.makespan_s
    );

    let mut report = Json::obj();
    report.set(
        "scenarios",
        Json::Arr(vec![truth.to_json(), wan.to_json(), lan.to_json()]),
    );
    report.set(
        "lan_vs_wan",
        Json::from_pairs(vec![
            (
                "transfer_saving_centi_cents",
                Json::num((wan.wan_transfer_centi_cents - lan.wan_transfer_centi_cents) as f64),
            ),
            ("virtual_time_saving_s", Json::num(wan.makespan_s - lan.makespan_s)),
            ("bit_identical", Json::Bool(true)),
        ]),
    );
    match emit_bench_json("storage", &report) {
        Ok(path) => println!("  wrote {}", path.display()),
        Err(e) => eprintln!("  could not write BENCH_storage.json: {e}"),
    }
    println!("\nstorage bench complete.");
}
