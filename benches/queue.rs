//! Queue-throughput / cost scenario: the same mixed GA/MC workload
//! pushed through the job queue on three fleets —
//!
//! * a static on-demand fleet (the paper's world, made multi-tenant),
//! * an autoscaled on-demand fleet (elasticity without the market),
//! * an autoscaled spot fleet with injected interruptions (the full
//!   stack: queue + autoscaler + spot market + checkpoints).
//!
//! Asserts the headline property: every job survives the
//! interruptions, and the spot fleet bill undercuts the static
//! on-demand bill. Emits `BENCH_queue.json` at the repository root.
//!
//! Run: `cargo bench --bench queue`

use p2rac::bench_support::{emit_bench_json, run_queue_scenario};
use p2rac::util::json::Json;

fn main() {
    println!("=== job queue: static on-demand vs autoscaled spot ===\n");
    let scenarios = [
        ("static on-demand", false, false, 8, 0usize),
        ("autoscaled on-demand", false, true, 8, 0),
        ("autoscaled spot", true, true, 8, 2),
    ];
    let mut reports = Vec::new();
    for (label, spot, autoscale, jobs, interruptions) in scenarios {
        let r = run_queue_scenario(label, spot, autoscale, jobs, interruptions).unwrap();
        println!("  {}", r.row());
        reports.push(r);
    }
    let od = &reports[0];
    let spot = &reports[2];
    assert_eq!(
        spot.completed, spot.jobs,
        "every job must survive the injected spot interruptions"
    );
    assert!(spot.interruptions >= 2, "both armed interruptions must land");
    assert!(
        spot.total_cost_cents < od.total_cost_cents,
        "autoscaled spot ({}c) must undercut static on-demand ({}c)",
        spot.total_cost_cents,
        od.total_cost_cents
    );
    println!(
        "\n  -> autoscaled spot fleet runs the workload for {:.0}% of the static \
         on-demand bill, surviving {} interruption(s)",
        100.0 * spot.total_cost_cents as f64 / od.total_cost_cents.max(1) as f64,
        spot.interruptions
    );

    let report = Json::Arr(reports.iter().map(|r| r.to_json()).collect());
    match emit_bench_json("queue", &report) {
        Ok(path) => println!("  wrote {}", path.display()),
        Err(e) => eprintln!("  could not write BENCH_queue.json: {e}"),
    }
    println!("\nqueue bench complete.");
}
