//! Queue-throughput / cost scenario: the same mixed GA/MC workload
//! pushed through the job queue on three fleets —
//!
//! * a static on-demand fleet (the paper's world, made multi-tenant),
//! * an autoscaled on-demand fleet (elasticity without the market),
//! * an autoscaled spot fleet with injected interruptions (the full
//!   stack: queue + autoscaler + spot market + checkpoints).
//!
//! Asserts the headline property: every job survives the
//! interruptions, and the spot fleet bill undercuts the static
//! on-demand bill.
//!
//! Then the **cost-vs-deadline-miss tradeoff curve** (ISSUE 4): six
//! SLO'd jobs — tight, loose and one infeasible deadline — on a hot
//! spot market, under three policies: all-on-demand (zero feasible
//! misses, full price), all-spot (cheapest, deadlines ignored) and the
//! deadline-aware scheduler (per-slice spot vs on-demand from the
//! forecast's cost/risk curve). Deadlines are calibrated against the
//! measured all-on-demand run, which also defines feasibility. Asserts
//! the tentpole property: the deadline-aware policy meets **every
//! feasible deadline** at **lower cost than all-on-demand**.
//!
//! Emits `BENCH_queue.json` at the repository root with both the
//! scenario table and the curve.
//!
//! Run: `cargo bench --bench queue`

use p2rac::bench_support::{
    emit_bench_json, run_deadline_scenario, run_ordering_scenario, run_queue_scenario,
    DeadlinePolicy, DEADLINE_FACTORS,
};
use p2rac::jobs::QueueOrdering;
use p2rac::util::json::Json;

fn main() {
    println!("=== job queue: static on-demand vs autoscaled spot ===\n");
    let scenarios = [
        ("static on-demand", false, false, 8, 0usize),
        ("autoscaled on-demand", false, true, 8, 0),
        ("autoscaled spot", true, true, 8, 2),
    ];
    let mut reports = Vec::new();
    for (label, spot, autoscale, jobs, interruptions) in scenarios {
        let r = run_queue_scenario(label, spot, autoscale, jobs, interruptions).unwrap();
        println!("  {}", r.row());
        reports.push(r);
    }
    let od = &reports[0];
    let spot = &reports[2];
    assert_eq!(
        spot.completed, spot.jobs,
        "every job must survive the injected spot interruptions"
    );
    assert!(spot.interruptions >= 2, "both armed interruptions must land");
    assert!(
        spot.total_cost_cents < od.total_cost_cents,
        "autoscaled spot ({}c) must undercut static on-demand ({}c)",
        spot.total_cost_cents,
        od.total_cost_cents
    );
    println!(
        "\n  -> autoscaled spot fleet runs the workload for {:.0}% of the static \
         on-demand bill, surviving {} interruption(s)",
        100.0 * spot.total_cost_cents as f64 / od.total_cost_cents.max(1) as f64,
        spot.interruptions
    );

    println!("\n=== cost vs deadline-miss tradeoff (hot spot market) ===\n");
    // Calibrate: the all-on-demand reference durations define the
    // deadlines (factor < 1 = infeasible by construction).
    let reference = run_deadline_scenario(DeadlinePolicy::AllOnDemand, None).unwrap();
    let deadlines: Vec<f64> = reference
        .outcomes
        .iter()
        .zip(DEADLINE_FACTORS)
        .map(|(o, factor)| {
            let duration = o.completed_s.expect("reference run completes every job");
            factor * duration
        })
        .collect();
    // The all-on-demand curve point IS the calibration run re-graded:
    // with no spot capacity, deadlines never influence scheduling, so
    // re-running the identical simulation would only burn time.
    let od_point = {
        let mut r = reference;
        for (o, d) in r.outcomes.iter_mut().zip(&deadlines) {
            o.deadline_s = *d;
            o.met = o.completed_s.map(|c| c <= *d).unwrap_or(false);
        }
        r.met = r.outcomes.iter().filter(|o| o.met).count();
        r.missed = r.jobs - r.met;
        r
    };
    let curve: Vec<_> = std::iter::once(od_point)
        .chain(
            [DeadlinePolicy::AllSpot, DeadlinePolicy::DeadlineAware]
                .into_iter()
                .map(|p| run_deadline_scenario(p, Some(&deadlines)).unwrap()),
        )
        .collect();
    for r in &curve {
        println!("  {}", r.row());
    }
    let od_point = &curve[0];
    let aware = &curve[2];
    // The tentpole property: every deadline the full-price fleet can
    // meet, the deadline-aware policy also meets — at a lower bill.
    for (ref_o, aware_o) in od_point.outcomes.iter().zip(&aware.outcomes) {
        if ref_o.met {
            assert!(
                aware_o.met,
                "deadline-aware policy missed feasible deadline of {} \
                 (deadline t={:.0}s, completed {:?})",
                aware_o.name, aware_o.deadline_s, aware_o.completed_s
            );
        }
    }
    assert!(
        aware.total_cost_cents < od_point.total_cost_cents,
        "deadline-aware ({}c) must undercut all-on-demand ({}c)",
        aware.total_cost_cents,
        od_point.total_cost_cents
    );
    println!(
        "\n  -> deadline-aware fleet meets every feasible deadline for {:.0}% of the \
         all-on-demand bill ({} vs {} deadlines met)",
        100.0 * aware.total_cost_cents as f64 / od_point.total_cost_cents.max(1) as f64,
        aware.met,
        od_point.met,
    );

    println!("\n=== EDF vs FIFO within a priority class (one-cluster serialisation) ===\n");
    // Calibrate: an uncalibrated FIFO reference measures the
    // completion ladder — four identical jobs through one cluster, so
    // completion position k finishes at c[k] whichever job sits there.
    let ladder = run_ordering_scenario(QueueOrdering::FifoWithinClass, None).unwrap();
    let c: Vec<f64> = ladder
        .outcomes
        .iter()
        .map(|o| o.completed_s.expect("reference run completes every job"))
        .collect();
    // Jobs 0 and 1 (submitted first) get loose deadlines both policies
    // meet; jobs 2 and 3 (submitted last) get deadlines only the front
    // of the ladder can meet. FIFO leaves them at the back of the
    // class and misses both; EDF pulls them forward and meets them —
    // the loose early jobs still finish far inside their deadlines.
    let edf_deadlines = [c[3] * 3.0, c[3] * 3.0, c[0] * 1.25, c[1] * 1.25];
    let fifo = run_ordering_scenario(QueueOrdering::FifoWithinClass, Some(&edf_deadlines)).unwrap();
    let edf = run_ordering_scenario(QueueOrdering::EdfWithinClass, Some(&edf_deadlines)).unwrap();
    println!("  {}", fifo.row());
    println!("  {}", edf.row());
    // The ordering property: EDF dominates or ties the PR 4
    // FIFO-within-class policy — every deadline FIFO met, EDF meets
    // too, at no higher cost (identical slices through one on-demand
    // cluster: the bills tie by construction, and the assertion
    // pins that).
    for (f, e) in fifo.outcomes.iter().zip(&edf.outcomes) {
        if f.met {
            assert!(
                e.met,
                "EDF missed deadline of {} that FIFO-within-class met \
                 (deadline t={:.0}s, completed {:?})",
                e.name, e.deadline_s, e.completed_s
            );
        }
    }
    assert!(
        edf.met > fifo.met,
        "EDF must rescue the tight late-submitted deadlines ({} vs {} met)",
        edf.met,
        fifo.met
    );
    assert!(
        edf.total_cost_cents <= fifo.total_cost_cents,
        "EDF ({}c) must not cost more than FIFO ({}c)",
        edf.total_cost_cents,
        fifo.total_cost_cents
    );
    println!(
        "\n  -> EDF-within-class meets {}/{} deadlines vs FIFO's {}/{}, at {}c vs {}c",
        edf.met, edf.jobs, fifo.met, fifo.jobs, edf.total_cost_cents, fifo.total_cost_cents
    );

    let mut report = Json::obj();
    report.set(
        "scenarios",
        Json::Arr(reports.iter().map(|r| r.to_json()).collect()),
    );
    report.set(
        "deadline_tradeoff",
        Json::Arr(curve.iter().map(|r| r.to_json()).collect()),
    );
    report.set(
        "queue_ordering",
        Json::Arr(vec![fifo.to_json(), edf.to_json()]),
    );
    match emit_bench_json("queue", &report) {
        Ok(path) => println!("  wrote {}", path.display()),
        Err(e) => eprintln!("  could not write BENCH_queue.json: {e}"),
    }
    println!("\nqueue bench complete.");
}
