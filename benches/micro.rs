//! Micro/ablation benches (real wall-clock, no criterion offline):
//!
//! * `datasync`  — rsync vs SCP on first copy and re-sync after a small
//!   edit (the §3.2.1 design choice), wire bytes + throughput.
//! * `scheduler` — bynode/byslot placement throughput.
//! * `runtime`   — PJRT artifact execution latency (the L3 hot path),
//!   per-entry, when `artifacts/` is built.
//! * `ga_ops`    — genetic-operator and generation throughput.
//! * `ga_parallel` — real-vs-virtual speedup of the scoped-thread
//!   worker pool on the catopt workload (bit-identical numerics).
//! * `virt_ablation` — Fig-4 knee with the virtualisation overhead
//!   removed (validates the paper's explanation of the efficiency drop).
//!
//! Run: `cargo bench --bench micro`

use p2rac::analytics::catbond::CatBondData;
use p2rac::analytics::cost::{catopt_generation_s, CatoptCost};
use p2rac::bench_support::emit_bench_json;
use p2rac::coordinator::engine::ResourceView;
use p2rac::coordinator::scheduler::{schedule, NodeSpec, Placement};
use p2rac::datasync::{sync_dir, Protocol};
use p2rac::simcloud::{FaultPlan, Link, NetworkModel, SimParams, Vfs};
use p2rac::util::humanfmt;
use p2rac::util::json::Json;
use p2rac::util::prng::Xoshiro256;
use std::time::Instant;

fn time<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn bench_datasync() -> Json {
    println!("--- datasync: rsync vs SCP (1 MiB project file) ---");
    let net = NetworkModel::new(SimParams::default());
    let mut rng = Xoshiro256::seed_from_u64(1);
    let mut src = Vfs::new();
    let data: Vec<u8> = (0..1 << 20).map(|_| rng.next_u32() as u8).collect();
    src.write("p/data.bin", data.clone());

    let mut report = Json::obj();
    for proto in [Protocol::Rsync, Protocol::Scp] {
        let mut dst = Vfs::new();
        let mut f = FaultPlan::none();
        // First copy.
        let first = sync_dir(&src, "p", &mut dst, "d", proto, 2048, &net, Link::Wan, &mut f).unwrap();
        // Small edit + re-sync (the case rsync was chosen for).
        let mut edited = data.clone();
        edited[500_000] ^= 0xFF;
        src.write("p/data.bin", edited);
        let t = Instant::now();
        let re = sync_dir(&src, "p", &mut dst, "d", proto, 2048, &net, Link::Wan, &mut f).unwrap();
        let wall = t.elapsed();
        println!(
            "  {:?}: first={} wire, resync={} wire in {} real ({} virtual)",
            proto,
            humanfmt::bytes(first.wire_bytes()),
            humanfmt::bytes(re.wire_bytes()),
            humanfmt::duration(wall),
            humanfmt::secs(re.elapsed_s),
        );
        report.set(
            &format!("{proto:?}").to_lowercase(),
            Json::from_pairs(vec![
                ("first_wire_bytes", Json::num(first.wire_bytes() as f64)),
                ("resync_wire_bytes", Json::num(re.wire_bytes() as f64)),
                ("resync_wall_s", Json::num(wall.as_secs_f64())),
            ]),
        );
        src.write("p/data.bin", data.clone()); // restore for next proto
    }
    report
}

fn bench_scheduler() -> Json {
    println!("--- scheduler: placement throughput (64 procs, 16 nodes) ---");
    let nodes: Vec<NodeSpec> = (0..16)
        .map(|i| NodeSpec {
            name: format!("n{i}"),
            cores: 4,
            mem_gb: 34.2,
            core_speed: 0.88,
        })
        .collect();
    let mut report = Json::obj();
    for p in [Placement::ByNode, Placement::BySlot] {
        let t = time(10_000, || {
            let a = schedule(64, &nodes, p);
            std::hint::black_box(a);
        });
        println!("  {:?}: {:.2} µs/placement", p, t * 1e6);
        report.set(&format!("{p:?}").to_lowercase(), Json::num(t * 1e6));
    }
    report
}

fn bench_runtime() -> Json {
    println!("--- runtime: PJRT execute latency (L3 hot path) ---");
    let skipped = Json::from_pairs(vec![("skipped", Json::Bool(true))]);
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("  (skipped: run `make artifacts` first)");
        return skipped;
    }
    let rt = match p2rac::runtime::Runtime::load(dir) {
        Ok(rt) => rt,
        Err(e) => {
            println!("  (skipped: runtime unavailable: {e:#})");
            return skipped;
        }
    };
    use p2rac::runtime::TensorF32;
    let (s, k, j) = (
        rt.constant("S").unwrap(),
        rt.constant("K").unwrap(),
        rt.constant("J").unwrap(),
    );
    let mut rng = Xoshiro256::seed_from_u64(2);
    let u: Vec<f32> = (0..s * k).map(|_| rng.next_f32() * 0.999).collect();
    let params: Vec<f32> = (0..j * 2).map(|i| 0.5 + (i % 7) as f32).collect();
    let args = [
        TensorF32::new(vec![s, k], u),
        TensorF32::new(vec![j, 2], params),
    ];
    rt.execute("mc_sweep", &args).unwrap(); // warmup
    let t = time(20, || {
        rt.execute("mc_sweep", &args).unwrap();
    });
    println!(
        "  mc_sweep ({s}x{k} draws, {j} jobs): {:.2} ms/exec = {:.0} job-evals/s",
        t * 1e3,
        j as f64 / t
    );

    let (pop, m, e) = (
        rt.constant("POP").unwrap(),
        rt.constant("M").unwrap(),
        rt.constant("E").unwrap(),
    );
    let w: Vec<f32> = (0..pop * m).map(|_| rng.next_f32() / m as f32).collect();
    let ilt: Vec<f32> = (0..m * e).map(|_| rng.next_f32() * 0.01).collect();
    let cl: Vec<f32> = (0..e).map(|_| rng.next_f32()).collect();
    let args = [
        TensorF32::new(vec![pop, m], w),
        TensorF32::new(vec![m, e], ilt),
        TensorF32::new(vec![e], cl),
        TensorF32::scalar11(0.1),
        TensorF32::scalar11(1.0),
    ];
    rt.execute("catopt_fitness", &args).unwrap(); // warmup
    let t = time(10, || {
        rt.execute("catopt_fitness", &args).unwrap();
    });
    let flops = 2.0 * pop as f64 * m as f64 * e as f64;
    println!(
        "  catopt_fitness ({pop}x{m} @ {m}x{e}): {:.1} ms/exec = {:.2} GFLOP/s effective",
        t * 1e3,
        flops / t / 1e9
    );
    Json::from_pairs(vec![
        ("skipped", Json::Bool(false)),
        ("catopt_fitness_ms", Json::num(t * 1e3)),
        ("catopt_fitness_gflops", Json::num(flops / t / 1e9)),
    ])
}

fn bench_backend() -> Json {
    println!("--- backend: PjrtBackend.eval_population (per GA generation) ---");
    let skipped = Json::from_pairs(vec![("skipped", Json::Bool(true))]);
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("  (skipped: run `make artifacts` first)");
        return skipped;
    }
    use p2rac::analytics::backend::FitnessBackend;
    let rt = match p2rac::runtime::Runtime::load(dir) {
        Ok(rt) => std::sync::Arc::new(rt),
        Err(e) => {
            println!("  (skipped: runtime unavailable: {e:#})");
            return skipped;
        }
    };
    let m = rt.constant("M").unwrap();
    let e = rt.constant("E").unwrap();
    let data = CatBondData::generate(3, m, e);
    let b = p2rac::analytics::PjrtBackend::new(rt, data).unwrap();
    let mut rng = Xoshiro256::seed_from_u64(4);
    let pop: Vec<Vec<f32>> = (0..200)
        .map(|_| (0..m).map(|_| rng.next_f32() * 2.0 / m as f32).collect())
        .collect();
    b.eval_population(&pop).unwrap(); // warmup
    let t = time(10, || {
        b.eval_population(&pop).unwrap();
    });
    println!(
        "  pop=200 (m={m}, e={e}): {:.1} ms/generation = {:.0} candidate-evals/s",
        t * 1e3,
        200.0 / t
    );
    Json::from_pairs(vec![
        ("skipped", Json::Bool(false)),
        ("generation_ms", Json::num(t * 1e3)),
        ("candidate_evals_per_s", Json::num(200.0 / t)),
    ])
}

fn bench_ga_ops() -> Json {
    println!("--- GA: generation throughput (pure-Rust backend) ---");
    let data = CatBondData::generate(3, 64, 256);
    let backend = p2rac::analytics::RustBackend::new(data);
    let cfg = p2rac::analytics::ga::GaConfig {
        pop_size: 64,
        max_generations: 10,
        wait_generations: 10,
        bfgs_every: 0,
        seed: 1,
        ..Default::default()
    };
    let t0 = Instant::now();
    let r = p2rac::analytics::ga::optimizer::run(&backend, &cfg).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "  {} evaluations in {:.2}s = {:.0} eval/s (m=64, e=256)",
        r.total_evaluations,
        wall,
        r.total_evaluations as f64 / wall
    );
    Json::from_pairs(vec![
        ("evaluations", Json::num(r.total_evaluations as f64)),
        ("wall_s", Json::num(wall)),
        ("evals_per_s", Json::num(r.total_evaluations as f64 / wall)),
    ])
}

fn bench_ga_parallel() -> Json {
    println!("--- GA: worker-pool real speedup vs virtual (catopt workload) ---");
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    // One serial baseline, reused for every thread count.
    let base = p2rac::bench_support::speedup_baseline().unwrap();
    let mut rows = Vec::new();
    for threads in [1usize, 2, 4, 8] {
        if threads > avail && threads != 1 {
            println!("  threads={threads}: skipped (host has {avail} cores)");
            continue;
        }
        let r = base.measure(threads).unwrap();
        println!("  {}", r.row());
        rows.push(Json::from_pairs(vec![
            ("threads", Json::num(r.threads as f64)),
            ("real_speedup", Json::num(r.real_speedup())),
            ("virtual_speedup", Json::num(r.virtual_speedup)),
            ("bit_identical", Json::Bool(r.bit_identical)),
        ]));
        // Numerics are deterministic — this must hold on any host.
        assert!(r.bit_identical, "threaded GA must match serial bit-for-bit");
        if threads == 4 && avail >= 4 {
            let target_met = r.real_speedup() > 1.5;
            println!(
                "  acceptance (>1.5x wall-clock at 4 threads): {}",
                if target_met { "PASS" } else { "WARN — not met on this host" }
            );
            // Wall-clock scaling depends on physical cores and load
            // (4 logical hyperthreads often scale <1.5x on FP-bound
            // work), so only strict mode turns the warning into a
            // failure.
            if !target_met && std::env::var("P2RAC_BENCH_STRICT").is_ok() {
                panic!(
                    "P2RAC_BENCH_STRICT: >1.5x at 4 threads required, got {:.2}x",
                    r.real_speedup()
                );
            }
        }
    }
    Json::Arr(rows)
}

fn bench_virt_ablation() -> Json {
    println!("--- ablation: Fig-4 knee vs virtualisation overhead ---");
    let mk_view = |n: usize, virt: f64| {
        let p = SimParams {
            virt_overhead: virt,
            ..SimParams::default()
        };
        let nodes: Vec<NodeSpec> = (0..n)
            .map(|i| NodeSpec {
                name: format!("n{i}"),
                cores: 4,
                mem_gb: 34.2,
                core_speed: 0.88,
            })
            .collect();
        ResourceView {
            assignment: (0..n * 4).map(|x| x % n).collect(),
            nodes,
            net: NetworkModel::new(p),
            resource_name: "ablation".into(),
            real_threads: None,
        }
    };
    // Two candidate causes for the paper's efficiency drop: the serial
    // master-side dispatch (SNOW sends one message per slave) and the
    // virtualised-network factor on the scatter/gather collective.
    println!("  {:>12} {:>6} {:>22}", "dispatch", "virt", "16-node efficiency");
    let mut effs = Vec::new();
    for per_msg in [0.0, 0.025, 0.1] {
        for virt in [1.0, 1.6, 8.0] {
            let cost = CatoptCost {
                per_message_s: per_msg,
                ..CatoptCost::default()
            };
            let t1 = catopt_generation_s(200, &cost, &mk_view(1, virt));
            let t16 = catopt_generation_s(200, &cost, &mk_view(16, virt));
            let eff = t1 / (16.0 * t16) * 100.0;
            println!("  {:>10}ms {:>6.1} {:>21.0}%", per_msg * 1e3, virt, eff);
            effs.push((per_msg, virt, eff));
        }
    }
    let base = effs.iter().find(|e| e.0 == 0.025 && e.1 == 1.6).unwrap().2;
    let no_dispatch = effs.iter().find(|e| e.0 == 0.0 && e.1 == 1.6).unwrap().2;
    assert!(
        no_dispatch > base + 10.0,
        "serial dispatch must be the dominant knee cause ({no_dispatch:.0}% vs {base:.0}%)"
    );
    println!(
        "  → the knee is dominated by serial per-slave dispatch (SNOW master),\n    \
         with the virtualised collective as a second-order term at this payload size."
    );
    Json::Arr(
        effs.iter()
            .map(|(per_msg, virt, eff)| {
                Json::from_pairs(vec![
                    ("dispatch_ms", Json::num(per_msg * 1e3)),
                    ("virt_overhead", Json::num(*virt)),
                    ("efficiency_16_nodes_pct", Json::num(*eff)),
                ])
            })
            .collect(),
    )
}

fn main() {
    println!("=== micro/ablation benches ===\n");
    let mut report = Json::obj();
    report.set("datasync", bench_datasync());
    report.set("scheduler_us", bench_scheduler());
    report.set("runtime", bench_runtime());
    report.set("backend", bench_backend());
    report.set("ga_ops", bench_ga_ops());
    report.set("ga_parallel", bench_ga_parallel());
    report.set("virt_ablation", bench_virt_ablation());
    match emit_bench_json("micro", &report) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\ncould not write BENCH_micro.json: {e}"),
    }
    println!("\nmicro benches complete.");
}
