//! Scale bench (ISSUE 6): the discrete-event core against a large
//! multi-tenant backlog, indexed paths vs honest replicas of the
//! pre-refactor scan paths.
//!
//! Both modes run the *same* event-driven simulation over the real
//! [`p2rac::jobs::JobQueue`] and [`p2rac::simcloud::SpotMarket`] — a
//! synthetic `ec2genload` workload (diurnal arrivals, heavy-tailed
//! sizes, skewed tenants) dispatched onto a mixed spot/on-demand
//! fleet with market-driven reclaims. Only the *lookup structures*
//! differ per mode:
//!
//! * **legacy** — next ready job by collect-and-sort over every job
//!   (the old `ready_ids` shape), idle cluster by fleet walk, next
//!   completion by slice-list walk, next spot reclaim by per-cluster
//!   market scan;
//! * **indexed** — `JobQueue::next_ready` off the ready index, idle
//!   sets, a tombstoned completion heap, and `SpotDirectory` range
//!   queries.
//!
//! Because the semantics are shared, both modes must produce the same
//! dispatch sequence, bill and completion count — asserted on the
//! reduced workload, recorded as `parity` in `BENCH_scale.json`.
//! Demand probes every 256 events additionally check the queue's
//! incremental per-tenant accounting against a full scan.
//!
//! The full workload (10k clusters, 1M-job backlog, one simulated
//! day) is gated behind `P2RAC_SCALE_FULL=1` — CI runs the reduced
//! workload. The legacy baseline for the full-scale speedup is
//! measured at 20k jobs and scaled linearly down to the 1M backlog
//! (legacy dispatch cost is Θ(total jobs) per event, and the true
//! n·log n sort grows *faster* than linear, so the reported speedup
//! is a lower bound).
//!
//! Run: `cargo bench --bench scale`

use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};
use std::time::Instant;

use p2rac::bench_support::emit_bench_json;
use p2rac::jobs::genload::{generate, GenJob, GenLoadConfig};
use p2rac::jobs::spot::SpotDirectory;
use p2rac::jobs::{JobId, JobQueue, JobSpecBuilder, JobState, Priority};
use p2rac::simcloud::SpotMarket;
use p2rac::util::json::Json;

/// Virtual seconds per work unit (every bench job is unit-rate).
const UNIT_S: f64 = 60.0;
/// Fleet instance type (90 cents/hour on demand).
const ITYPE: &str = "m2.2xlarge";
/// On-demand rate in centi-cents/hour.
const OD_RATE_CENTI: u64 = 9000;

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Legacy,
    Indexed,
}

impl Mode {
    fn label(self) -> &'static str {
        match self {
            Mode::Legacy => "legacy",
            Mode::Indexed => "indexed",
        }
    }
}

/// FNV-1a over the little-endian bytes of `x`.
fn fnv1a(mut h: u64, x: u64) -> u64 {
    for b in x.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0100_0000_01B3);
    }
    h
}

/// Total-order bits of an f64 (mirror of the queue's key encoding).
fn order_bits(x: f64) -> u64 {
    let b = x.to_bits();
    if b & (1 << 63) != 0 {
        !b
    } else {
        b | (1 << 63)
    }
}

/// Honest replica of the pre-index `ready_ids` head: walk every job,
/// collect the ready ones, sort the whole vector, take the front.
fn legacy_next_ready(q: &JobQueue) -> Option<JobId> {
    let mut v: Vec<(u8, u64, u64)> = q
        .jobs()
        .filter(|j| matches!(j.state, JobState::Queued | JobState::Interrupted))
        .map(|j| {
            let class = match j.spec.priority {
                Priority::High => 0u8,
                Priority::Normal => 1,
                Priority::Low => 2,
            };
            (
                class,
                order_bits(j.spec.deadline_s.unwrap_or(f64::INFINITY)),
                j.id.0,
            )
        })
        .collect();
    v.sort_unstable();
    v.first().map(|k| JobId(k.2))
}

struct BenchCluster {
    spot: bool,
    bid: u64,
    alive: bool,
    busy: Option<u64>,
}

struct RunResult {
    label: String,
    mode: Mode,
    jobs: usize,
    clusters: usize,
    tenants: usize,
    sim_seconds: f64,
    events: u64,
    wall_s: f64,
    completed: u64,
    reclaims: u64,
    evictions: u64,
    billed_centi_cents: u64,
    dispatch_digest: u64,
    probes: Vec<(u64, u64, u64)>,
    tenant_probes: Vec<Vec<(String, u64, u64)>>,
    loads_match_scan: bool,
}

impl RunResult {
    fn events_per_sec(&self) -> f64 {
        self.events as f64 / self.wall_s.max(1e-9)
    }

    fn wall_per_sim_day(&self) -> f64 {
        self.wall_s * 86_400.0 / self.sim_seconds.max(1.0)
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("label", Json::str(&self.label));
        o.set("mode", Json::str(self.mode.label()));
        o.set("jobs", Json::num(self.jobs as f64));
        o.set("clusters", Json::num(self.clusters as f64));
        o.set("tenants", Json::num(self.tenants as f64));
        o.set("sim_seconds", Json::num(self.sim_seconds));
        o.set("events", Json::num(self.events as f64));
        o.set("wall_s", Json::num(self.wall_s));
        o.set("events_per_sec", Json::num(self.events_per_sec()));
        o.set("wall_clock_per_sim_day_s", Json::num(self.wall_per_sim_day()));
        o.set("completed", Json::num(self.completed as f64));
        o.set("reclaims", Json::num(self.reclaims as f64));
        o.set("evictions", Json::num(self.evictions as f64));
        o.set("billed_centi_cents", Json::num(self.billed_centi_cents as f64));
        o.set("dispatch_digest", Json::str(format!("{:016x}", self.dispatch_digest)));
        o.set("loads_match_scan", Json::Bool(self.loads_match_scan));
        o
    }

    fn row(&self) -> String {
        format!(
            "{:<22} {:>8} jobs {:>6} clusters  {:>10} events  {:>8.3}s wall  {:>12.0} ev/s  \
             digest {:016x}",
            self.label,
            self.jobs,
            self.clusters,
            self.events,
            self.wall_s,
            self.events_per_sec(),
            self.dispatch_digest,
        )
    }
}

/// One full simulation of `arrivals` over `n_clusters` under `mode`.
/// `probe_every` > 0 snapshots the demand picture by O(jobs) full scan
/// at that event cadence — the parity instrument for the reduced
/// legacy/indexed pair. The timing-only runs pass 0: an O(jobs) scan
/// every few hundred events would dominate the 1M-job measurement.
fn run(
    label: &str,
    mode: Mode,
    arrivals: &[GenJob],
    n_clusters: usize,
    tenants: usize,
    probe_every: u64,
) -> RunResult {
    let market = SpotMarket::default();
    let mut queue = JobQueue::new();
    // 60% spot with staggered bids (low bids churn on price jitter,
    // high bids only fall to spikes), 40% on-demand ballast so the
    // backlog always drains.
    let names: Vec<String> = (0..n_clusters).map(|i| format!("fc{i}")).collect();
    let mut fleet: Vec<BenchCluster> = (0..n_clusters)
        .map(|i| {
            let spot = i % 5 < 3;
            BenchCluster {
                spot,
                bid: if spot { 2_250 + (i as u64 % 8) * 965 } else { 0 },
                alive: true,
                busy: None,
            }
        })
        .collect();
    let mut dir = SpotDirectory::default();
    let mut name_pos: BTreeMap<String, usize> = BTreeMap::new();
    if mode == Mode::Indexed {
        for (i, c) in fleet.iter().enumerate() {
            if c.spot {
                dir.insert(&names[i], ITYPE, c.bid, 0.0);
            }
            name_pos.insert(names[i].clone(), i);
        }
    }
    let mut idle: BTreeSet<usize> = (0..n_clusters).collect();
    let mut slices: BTreeMap<u64, (usize, JobId, f64, f64)> = BTreeMap::new();
    let mut heap: BinaryHeap<Reverse<(u64, u64)>> = BinaryHeap::new();
    let mut seq = 0u64;
    let mut remaining: BTreeMap<JobId, f64> = BTreeMap::new();
    let (mut events, mut completions, mut reclaims, mut evictions) = (0u64, 0u64, 0u64, 0u64);
    let mut billed = 0u64;
    let mut digest = 0xcbf2_9ce4_8422_2325u64;
    let mut probes: Vec<(u64, u64, u64)> = Vec::new();
    let mut tenant_probes: Vec<Vec<(String, u64, u64)>> = Vec::new();
    let mut loads_ok = true;
    let mut next_probe = if probe_every > 0 { probe_every } else { u64::MAX };
    let mut ai = 0usize;
    let mut now = 0.0f64;
    let wall = Instant::now();
    loop {
        // Dispatch ready work onto idle capacity.
        loop {
            let slot = match mode {
                Mode::Indexed => idle.iter().next().copied(),
                Mode::Legacy => fleet.iter().position(|c| c.alive && c.busy.is_none()),
            };
            let Some(slot) = slot else { break };
            let jid = match mode {
                Mode::Indexed => queue.next_ready(),
                Mode::Legacy => legacy_next_ready(&queue),
            };
            let Some(jid) = jid else { break };
            let end = now + remaining[&jid];
            {
                let j = queue.get_mut(jid).expect("dispatched job exists");
                j.state = JobState::Running;
                if j.started_at_s.is_none() {
                    j.started_at_s = Some(now);
                }
            }
            seq += 1;
            slices.insert(seq, (slot, jid, now, end));
            fleet[slot].busy = Some(seq);
            if mode == Mode::Indexed {
                idle.remove(&slot);
                heap.push(Reverse((order_bits(end), seq)));
            }
            digest = fnv1a(fnv1a(fnv1a(digest, jid.0), slot as u64), now.to_bits());
            events += 1;
        }
        // Next completion (seq tie-break matches the heap's).
        let next_done: Option<(u64, f64)> = match mode {
            Mode::Indexed => loop {
                match heap.peek().copied() {
                    Some(Reverse((_, s))) => {
                        if let Some(&(_, _, _, end)) = slices.get(&s) {
                            break Some((s, end));
                        }
                        heap.pop();
                    }
                    None => break None,
                }
            },
            Mode::Legacy => {
                let mut best: Option<(u64, f64)> = None;
                for (&s, &(_, _, _, end)) in &slices {
                    let better = match best {
                        Some((_, e)) => end < e,
                        None => true,
                    };
                    if better {
                        best = Some((s, end));
                    }
                }
                best
            }
        };
        let t_arr = arrivals.get(ai).map(|g| g.arrival_s);
        let t_next = match (t_arr, next_done) {
            (None, None) => break,
            (Some(a), None) => a,
            (None, Some((_, e))) => e,
            (Some(a), Some((_, e))) => {
                if e <= a {
                    e
                } else {
                    a
                }
            }
        };
        // Spot reclaims strictly inside (now, t_next] pre-empt the
        // next queue event; every out-bid cluster at the boundary goes.
        let reclaim_t = match mode {
            Mode::Indexed => dir.earliest_reclaim(&market, now, t_next).map(|(_, t)| t),
            Mode::Legacy => {
                let mut best: Option<f64> = None;
                for c in &fleet {
                    if !c.alive || !c.spot {
                        continue;
                    }
                    if let Some(t) = market.first_interruption(ITYPE, c.bid, now, t_next) {
                        let better = match best {
                            Some(b) => t < b,
                            None => true,
                        };
                        if better {
                            best = Some(t);
                        }
                    }
                }
                best
            }
        };
        if let Some(t_r) = reclaim_t {
            now = t_r;
            let hour = SpotMarket::hour_index(t_r);
            let mut victims: Vec<usize> = match mode {
                Mode::Indexed => dir
                    .reclaimed_at_hour(&market, hour)
                    .iter()
                    .map(|n| name_pos[n])
                    .collect(),
                Mode::Legacy => fleet
                    .iter()
                    .enumerate()
                    .filter(|(_, c)| c.alive && c.spot && market.interrupts_at(ITYPE, c.bid, hour))
                    .map(|(i, _)| i)
                    .collect(),
            };
            victims.sort_unstable();
            for slot in victims {
                let bid = fleet[slot].bid;
                fleet[slot].alive = false;
                if mode == Mode::Indexed {
                    dir.remove(&names[slot]);
                    idle.remove(&slot);
                }
                if let Some(s) = fleet[slot].busy.take() {
                    let (_, jid, start, end) = slices.remove(&s).expect("busy slice exists");
                    billed += market.cost_centi_cents(ITYPE, start, t_r, true, bid);
                    remaining.insert(jid, (end - t_r).max(0.0));
                    let j = queue.get_mut(jid).expect("evicted job exists");
                    j.state = JobState::Interrupted;
                    j.interruptions += 1;
                    evictions += 1;
                }
                reclaims += 1;
                events += 1;
            }
        } else {
            now = t_next;
            let take_completion = match (next_done, t_arr) {
                (Some((_, e)), Some(a)) => e <= a,
                (Some(_), None) => true,
                _ => false,
            };
            if take_completion {
                let (s, _) = next_done.expect("completion chosen");
                let (slot, jid, start, end) = slices.remove(&s).expect("completing slice");
                fleet[slot].busy = None;
                if mode == Mode::Indexed {
                    idle.insert(slot);
                }
                billed += if fleet[slot].spot {
                    market.cost_centi_cents(ITYPE, start, end, false, fleet[slot].bid)
                } else {
                    OD_RATE_CENTI * (((end - start) / 3600.0).ceil().max(1.0) as u64)
                };
                remaining.remove(&jid);
                let j = queue.get_mut(jid).expect("completing job exists");
                j.state = JobState::Completed;
                j.units_done = j.units_total;
                j.progress = 1.0;
                j.completed_at_s = Some(end);
                j.compute_s += end - start;
                completions += 1;
                events += 1;
            } else {
                let g = &arrivals[ai];
                ai += 1;
                let id = queue.submit(
                    JobSpecBuilder::new(&format!("s{ai}"), "bench", "sweep.json")
                        .priority(g.priority)
                        .deadline(g.deadline_s)
                        .build(),
                    g.arrival_s,
                );
                let j = queue.get_mut(id).expect("submitted job exists");
                j.analyst = g.tenant.clone();
                j.units_total = g.units as usize;
                remaining.insert(id, g.units as f64 * UNIT_S);
                events += 1;
            }
        }
        // Demand probe: every ~`probe_every` events snapshot the
        // queue-wide and per-tenant load picture by full scan; in
        // indexed mode also cross-check the incremental accounting
        // against that scan.
        if events >= next_probe {
            next_probe += probe_every;
            let mut per: BTreeMap<String, (u64, u64, u64)> = BTreeMap::new();
            let (mut wait_n, mut run_n) = (0u64, 0u64);
            for j in queue.jobs() {
                let e = per.entry(j.analyst.clone()).or_insert((0, 0, 0));
                e.2 += 1;
                match j.state {
                    JobState::Queued | JobState::Interrupted => {
                        e.0 += 1;
                        wait_n += 1;
                    }
                    JobState::Running => {
                        e.1 += 1;
                        run_n += 1;
                    }
                    _ => {}
                }
            }
            probes.push((next_probe - probe_every, wait_n, run_n));
            tenant_probes.push(per.iter().map(|(k, v)| (k.clone(), v.0, v.1)).collect());
            if mode == Mode::Indexed {
                if queue.pending() as u64 != wait_n || queue.running() as u64 != run_n {
                    loads_ok = false;
                }
                for (analyst, load) in queue.tenant_loads() {
                    let &(w, r, n) = per.get(&analyst).unwrap_or(&(0, 0, 0));
                    if load.waiting as u64 != w
                        || load.running as u64 != r
                        || load.jobs as u64 != n
                    {
                        loads_ok = false;
                    }
                }
            }
        }
    }
    let wall_s = wall.elapsed().as_secs_f64();
    assert!(
        queue.all_done() || fleet.iter().all(|c| !c.alive || c.spot),
        "{label}: backlog stranded with live on-demand capacity"
    );
    RunResult {
        label: label.to_string(),
        mode,
        jobs: arrivals.len(),
        clusters: n_clusters,
        tenants,
        sim_seconds: now,
        events,
        wall_s,
        completed: completions,
        reclaims,
        evictions,
        billed_centi_cents: billed,
        dispatch_digest: digest,
        probes,
        tenant_probes,
        loads_match_scan: loads_ok,
    }
}

fn workload(jobs: usize, tenants: usize) -> GenLoadConfig {
    GenLoadConfig {
        jobs,
        tenants,
        ..GenLoadConfig::default()
    }
}

fn main() {
    println!("=== discrete-event core at scale: indexed vs scan paths ===\n");
    let full = std::env::var("P2RAC_SCALE_FULL").map(|v| v == "1").unwrap_or(false);

    // Reduced workload: both paths, full parity checks (this is what
    // the CI smoke job runs).
    let reduced_cfg = workload(4_000, 48);
    let reduced_jobs = generate(&reduced_cfg);
    let legacy_red = run("reduced/legacy", Mode::Legacy, &reduced_jobs, 64, 48, 256);
    println!("  {}", legacy_red.row());
    let indexed_red = run("reduced/indexed", Mode::Indexed, &reduced_jobs, 64, 48, 256);
    println!("  {}", indexed_red.row());

    let digest_eq = legacy_red.dispatch_digest == indexed_red.dispatch_digest;
    let billed_eq = legacy_red.billed_centi_cents == indexed_red.billed_centi_cents;
    let completed_eq = legacy_red.completed == indexed_red.completed;
    let probes_eq = legacy_red.probes == indexed_red.probes
        && legacy_red.tenant_probes == indexed_red.tenant_probes;
    assert!(
        digest_eq,
        "dispatch order diverged: legacy {:016x} vs indexed {:016x}",
        legacy_red.dispatch_digest, indexed_red.dispatch_digest
    );
    assert!(
        billed_eq,
        "bills diverged: legacy {} vs indexed {} centi-cents",
        legacy_red.billed_centi_cents, indexed_red.billed_centi_cents
    );
    assert!(completed_eq, "completion counts diverged");
    assert!(probes_eq, "demand probes diverged between modes");
    assert!(
        indexed_red.loads_match_scan,
        "incremental tenant accounting diverged from the full scan"
    );
    assert_eq!(
        indexed_red.completed as usize, indexed_red.jobs,
        "reduced workload must drain completely"
    );
    let speedup_reduced = indexed_red.events_per_sec() / legacy_red.events_per_sec().max(1e-9);
    println!(
        "\n  -> parity holds (digest/bill/completions/probes identical); \
         indexed is {speedup_reduced:.1}x the scan path at this size\n"
    );

    let mut workload_rows = vec![legacy_red.to_json(), indexed_red.to_json()];
    let mut speedup_vs_legacy = None;
    let mut legacy_full_eps = None;
    if full {
        // Legacy baseline at 20k jobs; its per-event cost is Θ(total
        // jobs), so scaling the measured rate down by 20k/1M gives a
        // conservative (optimistic-for-legacy) 1M-job baseline.
        println!("  running full workload (this takes a while)...");
        let base_cfg = workload(20_000, 100);
        let base_jobs = generate(&base_cfg);
        // probe_every = 0: the timing runs measure the schedulers, not
        // the probe instrument.
        let legacy_base = run("baseline/legacy", Mode::Legacy, &base_jobs, 256, 100, 0);
        println!("  {}", legacy_base.row());
        let full_cfg = workload(1_000_000, 400);
        let full_jobs = generate(&full_cfg);
        let indexed_full = run("full/indexed", Mode::Indexed, &full_jobs, 10_000, 400, 0);
        println!("  {}", indexed_full.row());
        let extrapolated =
            legacy_base.events_per_sec() * (legacy_base.jobs as f64 / indexed_full.jobs as f64);
        let s = indexed_full.events_per_sec() / extrapolated.max(1e-9);
        println!(
            "\n  -> full day, 1M-job backlog: {:.0} ev/s, {:.1}s wall per simulated day; \
             {s:.0}x the extrapolated scan-path baseline",
            indexed_full.events_per_sec(),
            indexed_full.wall_per_sim_day(),
        );
        workload_rows.push(legacy_base.to_json());
        workload_rows.push(indexed_full.to_json());
        legacy_full_eps = Some(extrapolated);
        speedup_vs_legacy = Some(s);
    } else {
        println!("  (set P2RAC_SCALE_FULL=1 for the 10k-cluster / 1M-job workload)");
    }

    let mut report = Json::obj();
    report.set("workloads", Json::Arr(workload_rows));
    let mut parity = Json::obj();
    parity.set("dispatch_digest_equal", Json::Bool(digest_eq));
    parity.set("billed_equal", Json::Bool(billed_eq));
    parity.set("completions_equal", Json::Bool(completed_eq));
    parity.set("demand_probes_equal", Json::Bool(probes_eq));
    parity.set(
        "tenant_loads_match_scan",
        Json::Bool(indexed_red.loads_match_scan),
    );
    report.set("parity", parity);
    report.set("speedup_reduced", Json::num(speedup_reduced));
    report.set(
        "speedup_vs_legacy",
        speedup_vs_legacy.map(Json::num).unwrap_or(Json::Null),
    );
    report.set(
        "legacy_full_eps_extrapolated",
        legacy_full_eps.map(Json::num).unwrap_or(Json::Null),
    );
    match emit_bench_json("scale", &report) {
        Ok(path) => println!("  wrote {}", path.display()),
        Err(e) => eprintln!("  could not write BENCH_scale.json: {e}"),
    }
    println!("\nscale bench complete.");
}
