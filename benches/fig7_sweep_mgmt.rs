//! Figure 7 — management times for the parameter-sweep problem (~3 MB
//! project): the same six bars as Figure 6, showing that for a small
//! project the data-movement bars shrink to seconds while resource
//! creation still dominates ("it may not be worthwhile to spend a lot
//! of time for creating and moving data around resources for small
//! jobs").
//!
//! Run: `cargo bench --bench fig7_sweep_mgmt`

use p2rac::bench_support::{
    bench_session, run_on_resource_profile, table1_resources, BenchProfile, Resource, Workload,
};
use p2rac::util::humanfmt::secs;

#[path = "fig6_catopt_mgmt.rs"]
mod fig6;

fn main() {
    fig6::run_mgmt_bench("Figure 7: parameter sweep (~3 MB project)", Workload::Sweep, 1.0);

    // Extra Fig-7 observation: for the small project, creation dominates
    // every data-movement bar by an order of magnitude.
    let mut s = bench_session(1.0);
    let cluster_c = table1_resources()
        .into_iter()
        .find(|r| r.label() == "Cluster C")
        .unwrap();
    let b = run_on_resource_profile(&mut s, &cluster_c, Workload::Sweep, BenchProfile::Management)
        .expect("bench");
    assert!(
        b.create_s > 10.0 * (b.submit_master_s + b.submit_all_s),
        "small project: creation ({}) must dominate data movement ({} + {})",
        secs(b.create_s),
        secs(b.submit_master_s),
        secs(b.submit_all_s)
    );
    assert!(matches!(cluster_c, Resource::Cluster { .. }));
    println!(
        "small-job observation: create {} vs total data movement {} — paper's conclusion holds.",
        secs(b.create_s),
        secs(b.submit_master_s + b.submit_all_s + b.fetch_master_s + b.fetch_all_s)
    );
}
