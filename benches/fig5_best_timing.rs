//! Figure 5 — "Best-case timing results of the CATopt and Parameter
//! Sweep Problems using P2RAC": total workload time on Desktop A/B,
//! Instance A/B and Clusters A–D.
//!
//! Expected shape: the cloud instances are comparable to (or slightly
//! slower than) the desktops per core; clusters win through scale; the
//! best performance is achieved on Cluster D.
//!
//! Run: `cargo bench --bench fig5_best_timing`

use p2rac::bench_support::{bench_session, run_on_resource, table1_resources, Workload};
use p2rac::util::humanfmt;

fn main() {
    println!("=== Figure 5: best-case timing per resource ===\n");
    let mut results: Vec<(String, f64, f64)> = Vec::new();
    println!(
        "{:<11} {:>16} {:>16}",
        "resource", "CATopt", "param sweep"
    );
    for r in table1_resources() {
        let mut tc = 0.0;
        let mut ts = 0.0;
        for wl in [Workload::Catopt, Workload::Sweep] {
            let mut s = bench_session(1.0);
            let b = run_on_resource(&mut s, &r, wl).expect("bench run");
            match wl {
                Workload::Catopt => tc = b.compute_s,
                Workload::Sweep => ts = b.compute_s,
            }
        }
        println!(
            "{:<11} {:>16} {:>16}",
            r.label(),
            humanfmt::secs(tc),
            humanfmt::secs(ts)
        );
        results.push((r.label(), tc, ts));
    }

    // Paper shape: best performance on Cluster D for both problems.
    for (idx, wl) in [(1usize, "CATopt"), (2, "sweep")] {
        let best = results
            .iter()
            .min_by(|a, b| {
                let av = if idx == 1 { a.1 } else { a.2 };
                let bv = if idx == 1 { b.1 } else { b.2 };
                av.partial_cmp(&bv).unwrap()
            })
            .unwrap();
        assert_eq!(best.0, "Cluster D", "{wl}: fastest resource was {}", best.0);
    }
    // Desktop A beats Desktop B (more, faster cores).
    let da = results.iter().find(|r| r.0 == "Desktop A").unwrap();
    let db = results.iter().find(|r| r.0 == "Desktop B").unwrap();
    assert!(da.1 < db.1 && da.2 < db.2, "Desktop A must beat Desktop B");
    // Instance B (8 cores) beats Instance A (4 cores).
    let ia = results.iter().find(|r| r.0 == "Instance A").unwrap();
    let ib = results.iter().find(|r| r.0 == "Instance B").unwrap();
    assert!(ib.1 < ia.1, "Instance B must beat Instance A on CATopt");
    println!("\nFigure 5 shape checks passed (Cluster D fastest overall).");
}
