//! Table I — "Resources Utilised for Experimental Studies".
//!
//! Prints the resource catalog exactly as the paper tabulates it
//! (provider, processor model for desktops / Amazon type for cloud
//! resources, cores, memory, storage) and validates the cloud rows
//! against the simulated EC2 catalog.
//!
//! Run: `cargo bench --bench table1_resources`

use p2rac::bench_support::{table1_resources, Resource};
use p2rac::simcloud::instance_type;

fn main() {
    println!("=== Table I: Resources Utilised for Experimental Studies ===\n");
    println!(
        "{:<11} {:<11} {:<22} {:>5} {:>9} {:>9} {:>8}",
        "Resource", "Provider", "Processor/Type", "cores", "memory", "storage", "$/hour"
    );
    for r in table1_resources() {
        match r {
            Resource::Desktop(d) => {
                let (proc_name, mem, storage) = if d.name.ends_with('A') {
                    ("Intel i7-2600 @3.4GHz", 16.0, "1.8 TB")
                } else {
                    ("Intel X5660 @2.8GHz", 24.0, "2 TB")
                };
                println!(
                    "{:<11} {:<11} {:<22} {:>5} {:>7}GB {:>9} {:>8}",
                    d.name, "local", proc_name, d.cores, mem, storage, "-"
                );
            }
            Resource::Instance { label, itype } => {
                let t = instance_type(&itype).expect("catalog");
                println!(
                    "{:<11} {:<11} {:<22} {:>5} {:>5.1}GB {:>7.0}GB {:>8.2}",
                    label,
                    "Amazon",
                    itype,
                    t.cores,
                    t.mem_gb,
                    t.storage_gb,
                    t.price_cents_hour as f64 / 100.0
                );
            }
            Resource::Cluster { label, itype, nodes } => {
                let t = instance_type(&itype).expect("catalog");
                println!(
                    "{:<11} {:<11} {:<22} {:>5} {:>5.1}GB {:>7.0}GB {:>8.2}",
                    label,
                    "Amazon",
                    format!("{itype} x {nodes}"),
                    t.cores * nodes,
                    t.mem_gb * nodes as f64,
                    t.storage_gb * nodes as f64,
                    t.price_cents_hour as f64 * nodes as f64 / 100.0
                );
            }
        }
    }

    // Paper-anchored checks.
    let m22 = instance_type("m2.2xlarge").unwrap();
    let m24 = instance_type("m2.4xlarge").unwrap();
    assert_eq!((m22.cores, m22.mem_gb, m22.storage_gb), (4, 34.2, 850.0));
    assert_eq!((m24.cores, m24.mem_gb, m24.storage_gb), (8, 68.4, 1690.0));
    assert_eq!(m22.price_cents_hour, 90, "paper: $0.9/h for m2.2xlarge");
    assert_eq!(m24.price_cents_hour, 180, "paper: $1.8/h for m2.4xlarge");
    println!("\nTable I catalog validated against the simulated EC2 offering.");
}
