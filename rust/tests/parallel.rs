//! Integration tests for the threaded analytics path: the worker pool
//! must be numerics-neutral (bit-identical to serial for a fixed seed)
//! at every layer, and the shard plan derived from the scheduler's
//! assignment must never starve a shard — even oversubscribed.

use p2rac::analytics::ga::optimizer::{self, GaConfig};
use p2rac::analytics::mc::{self, RustSweep, SweepConfig};
use p2rac::analytics::pool::WorkerPool;
use p2rac::analytics::{CatBondData, P2racEngine, RustBackend};
use p2rac::coordinator::engine::{ResourceView, ScriptEngine};
use p2rac::coordinator::scheduler::{schedule, NodeSpec, Placement};
use p2rac::simcloud::{NetworkModel, SimParams, Vfs};
use p2rac::util::json::Json;
use p2rac::util::quickprop;

fn view(nodes: usize, cores: usize, real_threads: Option<usize>) -> ResourceView {
    let ns: Vec<NodeSpec> = (0..nodes)
        .map(|i| NodeSpec {
            name: format!("n{i}"),
            cores,
            mem_gb: 34.2,
            core_speed: 0.88,
        })
        .collect();
    ResourceView {
        assignment: (0..nodes * cores).map(|p| p % nodes).collect(),
        nodes: ns,
        net: NetworkModel::new(SimParams::default()),
        resource_name: "par-test".into(),
        real_threads,
    }
}

#[test]
fn threaded_ga_is_bit_identical_to_serial_for_fixed_seed() {
    let data = CatBondData::generate(31, 32, 128);
    let backend = RustBackend::new(data);
    let cfg = GaConfig {
        pop_size: 30,
        max_generations: 12,
        wait_generations: 12,
        bfgs_every: 4,
        seed: 2024,
        ..Default::default()
    };
    let serial = optimizer::run(&backend, &cfg).unwrap();
    for (threads, shards) in [(2, 4), (4, 16), (3, 30), (8, 5)] {
        let pool = WorkerPool::new(threads, shards);
        let threaded = optimizer::run_with_pool(&backend, &cfg, &pool).unwrap();
        assert_eq!(serial.best, threaded.best, "{threads}t/{shards}s");
        assert_eq!(serial.best_value, threaded.best_value);
        assert_eq!(serial.total_evaluations, threaded.total_evaluations);
        for (a, b) in serial.history.iter().zip(&threaded.history) {
            assert_eq!(a.best_value, b.best_value);
            assert_eq!(a.mean_value, b.mean_value);
            assert_eq!(a.evaluations, b.evaluations);
        }
    }
}

#[test]
fn threaded_mc_sweep_is_bit_identical_to_serial_for_fixed_seed() {
    let cfg = SweepConfig {
        n_jobs: 96,
        seed: 77,
        ..Default::default()
    };
    let serial = mc::run_sweep(&RustSweep, &cfg, 256, 8, 16).unwrap();
    for (threads, shards) in [(2, 2), (4, 6), (6, 32)] {
        let pool = WorkerPool::new(threads, shards);
        let threaded =
            mc::run_sweep_with_pool(&RustSweep, &cfg, 256, 8, 16, &pool).unwrap();
        assert_eq!(serial, threaded, "{threads}t/{shards}s");
    }
}

#[test]
fn engine_reports_same_virtual_time_and_results_for_any_thread_count() {
    // Full engine layer: the `-threads` knob must change wall-clock
    // only — summaries, result files, and billed virtual compute time
    // are invariant.
    let mut project = Vfs::new();
    let data = CatBondData::generate(7, 24, 96);
    for (name, bytes) in data.to_files() {
        project.write(&format!("proj/{name}"), bytes);
    }
    project.write(
        "proj/catopt.json",
        br#"{"type":"catopt","pop_size":20,"max_generations":5,"seed":13,"backend":"rust","bfgs_every":2}"#
            .to_vec(),
    );
    project.write(
        "proj/sweep.json",
        br#"{"type":"mc_sweep","n_jobs":40,"seed":5,"backend":"rust"}"#.to_vec(),
    );

    for script_name in ["catopt.json", "sweep.json"] {
        let script = Json::parse(
            std::str::from_utf8(project.read(&format!("proj/{script_name}")).unwrap()).unwrap(),
        )
        .unwrap();
        let mut outputs = Vec::new();
        for threads in [Some(1), Some(2), Some(4), None] {
            let mut engine = P2racEngine::rust_only();
            let out = engine
                .run(script_name, &script, &project, "proj", &view(4, 4, threads))
                .unwrap();
            outputs.push(out);
        }
        let first = &outputs[0];
        for out in &outputs[1..] {
            assert_eq!(first.compute_s, out.compute_s, "{script_name}");
            assert_eq!(
                first.summary.to_string_compact(),
                out.summary.to_string_compact(),
                "{script_name}"
            );
            assert_eq!(first.master_files, out.master_files, "{script_name}");
        }
    }
}

#[test]
fn property_oversubscribed_assignments_never_starve_a_shard() {
    // For any node set and any nproc — including heavy oversubscription
    // (more processes than total cores) — the pool built from the
    // schedule's assignment gives every shard its fair round-robin
    // share of any workload at least as large as the shard count.
    quickprop::check("no shard starvation under oversubscription", 120, |g| {
        let nn = g.usize(1..7);
        let nodes: Vec<NodeSpec> = (0..nn)
            .map(|i| NodeSpec {
                name: format!("n{i}"),
                cores: g.usize(1..9),
                mem_gb: g.f64(4.0, 64.0),
                core_speed: g.f64(0.5, 1.2),
            })
            .collect();
        let total_cores: usize = nodes.iter().map(|n| n.cores).sum();
        // Oversubscribe up to 3x the core count.
        let nproc = g.usize(1..(3 * total_cores + 2));
        let placement = *g.pick(&[Placement::ByNode, Placement::BySlot]);
        let assignment = schedule(nproc, &nodes, placement);
        assert_eq!(assignment.len(), nproc);

        let rv = ResourceView {
            nodes,
            assignment,
            net: NetworkModel::new(SimParams::default()),
            resource_name: "prop".into(),
            real_threads: Some(g.usize(1..9)),
        };
        let pool = WorkerPool::from_view(&rv);
        assert_eq!(pool.shards(), nproc, "one shard per slave process");

        let n_tasks = nproc + g.usize(0..65);
        let shards = pool.shard_indices(n_tasks);
        assert_eq!(shards.len(), nproc);
        let floor = n_tasks / nproc;
        let mut seen = vec![false; n_tasks];
        for shard in &shards {
            assert!(
                shard.len() >= floor && shard.len() <= floor + 1,
                "starved/overloaded shard: {} tasks, fair share {floor}",
                shard.len()
            );
            for &t in shard {
                assert!(!seen[t], "task {t} assigned twice");
                seen[t] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "every task must be assigned");
    });
}
