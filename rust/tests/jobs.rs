//! Acceptance tests for the jobs subsystem (ISSUE 2): N >= 8
//! mixed-priority jobs submitted concurrently all complete on an
//! autoscaled spot fleet despite >= 2 injected spot interruptions,
//! each job's result is bit-identical to its solo on-demand run, and
//! the ledger shows the spot workload costing less than the same
//! workload on demand.

use p2rac::analytics::CatBondData;
use p2rac::coordinator::{MockEngine, Session};
use p2rac::jobs::{
    files_digest, AutoscalerConfig, FnInvokeSpec, FnPlatform, JobQueue, JobScheduler, JobSpec,
    JobSpecBuilder, JobState, KeepalivePolicy, Priority, QuotaBook, TenantQuota,
};
use p2rac::simcloud::{PriceForecast, SimParams, SpotMarket};
use p2rac::util::quickprop;
use std::collections::BTreeMap;

fn session() -> Session {
    // The jobs runner drives the analytics steppers directly; the
    // session's script engine is never invoked.
    Session::new(SimParams::default(), Box::new(MockEngine::new(10.0)))
}

/// Eight projects: four CATopt optimisations and four MC sweeps with
/// distinct seeds, so every job has its own ground-truth output.
fn write_projects(s: &mut Session) {
    let data = CatBondData::generate(7, 24, 96);
    for i in 0..4u64 {
        let dir = format!("cat{i}");
        for (name, bytes) in data.to_files() {
            s.analyst.write(&format!("{dir}/{name}"), bytes.clone());
        }
        s.analyst.write(
            &format!("{dir}/catopt.json"),
            format!(
                r#"{{"type":"catopt","pop_size":12,"max_generations":4,"seed":{},"bfgs_every":2}}"#,
                100 + i
            )
            .into_bytes(),
        );
        let dir = format!("sweep{i}");
        s.analyst.write(
            &format!("{dir}/sweep.json"),
            format!(r#"{{"type":"mc_sweep","n_jobs":24,"seed":{}}}"#, 500 + i).into_bytes(),
        );
    }
}

fn job_specs() -> Vec<JobSpec> {
    let prios = [
        Priority::High,
        Priority::Low,
        Priority::Normal,
        Priority::High,
        Priority::Low,
        Priority::Normal,
        Priority::Low,
        Priority::High,
    ];
    (0..8)
        .map(|i| {
            let (dir, script) = if i % 2 == 0 {
                (format!("cat{}", i / 2), "catopt.json".to_string())
            } else {
                (format!("sweep{}", i / 2), "sweep.json".to_string())
            };
            JobSpecBuilder::new(&format!("run{i}"), &dir, &script)
                .priority(prios[i])
                .build()
        })
        .collect()
}

fn results_of(s: &Session, dir: &str) -> Vec<(String, Vec<u8>)> {
    let mut files: Vec<(String, Vec<u8>)> = s
        .analyst
        .list_dir(dir)
        .into_iter()
        .map(|rel| {
            let bytes = s.analyst.read(&format!("{dir}/{rel}")).unwrap().to_vec();
            (rel, bytes)
        })
        .collect();
    files.sort();
    files
}

/// Run the full 8-job workload on a fleet; returns per-job result
/// digests, the total bill in centi-cents, and interruptions seen.
fn run_workload(spot: bool, interruptions: usize) -> (BTreeMap<String, u64>, u64, usize) {
    let mut s = session();
    // A spike-free price path: the test's interruptions come from the
    // armed FaultPlan, so the run is deterministic by construction.
    s.cloud.spot.spike_prob = 0.0;
    write_projects(&mut s);
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 1,
        max_clusters: 3,
        nodes_per_cluster: 2,
        spot,
        ..Default::default()
    });
    js.slice_units = 1; // checkpoint after every generation / batch
    s.cloud.faults.spot_interruptions = interruptions;
    let specs = job_specs();
    for spec in &specs {
        js.submit(&s, spec.clone());
    }
    js.run_until_idle(&mut s).unwrap();
    js.shutdown_fleet(&mut s).unwrap();

    let mut digests = BTreeMap::new();
    for (i, spec) in specs.iter().enumerate() {
        let job = js.queue.jobs().find(|j| j.spec.name == spec.name).unwrap();
        assert_eq!(
            job.state,
            JobState::Completed,
            "job {} must complete (spot={spot})",
            spec.name
        );
        let dir = format!("{}_results/run{i}", spec.projectdir);
        let files = results_of(&s, &dir);
        assert!(!files.is_empty(), "no results under {dir}");
        digests.insert(spec.name.clone(), files_digest(&files));
    }
    (
        digests,
        s.cloud.ledger.total_centi_cents(),
        js.interruptions_delivered,
    )
}

/// Solo reference: each job alone on a one-cluster on-demand fleet.
fn solo_digest(spec: &JobSpec) -> u64 {
    let mut s = session();
    write_projects(&mut s);
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 1,
        max_clusters: 1,
        nodes_per_cluster: 2,
        spot: false,
        ..Default::default()
    });
    js.slice_units = 1;
    js.submit(&s, spec.clone());
    js.run_until_idle(&mut s).unwrap();
    let job = js.queue.jobs().next().unwrap();
    assert_eq!(job.state, JobState::Completed);
    let dir = format!(
        "{}_results/{}",
        spec.projectdir,
        spec.name
    );
    files_digest(&results_of(&s, &dir))
}

#[test]
fn eight_mixed_priority_jobs_survive_spot_interruptions_bit_identically() {
    // The acceptance scenario: autoscaled spot fleet, two injected
    // interruptions, all jobs complete.
    let (spot_digests, spot_cost, delivered) = run_workload(true, 2);
    assert!(
        delivered >= 2,
        "expected >= 2 spot interruptions delivered, got {delivered}"
    );

    // Bit-identity: every job's result files match its solo on-demand
    // run exactly, interruptions and checkpoint resumes included.
    for spec in job_specs() {
        let solo = solo_digest(&spec);
        assert_eq!(
            spot_digests.get(&spec.name),
            Some(&solo),
            "job {} diverged from its solo on-demand run",
            spec.name
        );
    }

    // Cost: the same workload on an identically-bounded on-demand
    // fleet (no interruptions) must cost strictly more.
    let (od_digests, od_cost, _) = run_workload(false, 0);
    assert_eq!(
        spot_digests, od_digests,
        "spot and on-demand runs must agree on every result"
    );
    assert!(
        spot_cost < od_cost,
        "spot bill ({spot_cost}cc) must undercut on-demand ({od_cost}cc)"
    );
}

/// Property: the forecast is a pure function of `(market seed, type,
/// window, hour)` — deterministic across instances — and its expected
/// price never undercuts the window's observed spot floor (nor one
/// centi-cent); the interruption likelihood is a probability and
/// decreases as the bid rises.
#[test]
fn property_forecast_is_deterministic_and_never_below_the_spot_floor() {
    quickprop::check("forecast determinism + floor", 200, |g| {
        let seed = g.u64(0..1 << 48);
        let ty = *g.pick(&["m1.large", "m2.2xlarge", "m2.4xlarge", "cc1.4xlarge"]);
        let window = g.u64(1..100);
        let hour = g.u64(0..10_000);
        let m1 = SpotMarket::new(seed);
        let m2 = SpotMarket::new(seed);
        let f = PriceForecast::new(window);
        let e1 = f.expected_price_centi_cents(&m1, ty, hour);
        let e2 = f.expected_price_centi_cents(&m2, ty, hour);
        assert_eq!(e1, e2, "same seed must forecast the same price");
        let floor = f.floor_centi_cents(&m1, ty, hour);
        assert!(e1 >= floor, "expected {e1} under the spot floor {floor}");
        assert!(e1 >= 1, "expected price must never reach zero");
        // Likelihood is a probability, monotone in the bid.
        let lo_bid = g.u64(1..5_000);
        let hi_bid = lo_bid + g.u64(1..50_000);
        let p_lo = f.interruption_likelihood(&m1, ty, lo_bid, hour);
        let p_hi = f.interruption_likelihood(&m1, ty, hi_bid, hour);
        assert!((0.0..=1.0).contains(&p_lo) && (0.0..=1.0).contains(&p_hi));
        assert!(p_hi <= p_lo, "a higher bid cannot be riskier ({p_hi} > {p_lo})");
        assert_eq!(
            p_lo,
            f.interruption_likelihood(&m2, ty, lo_bid, hour),
            "same seed must forecast the same risk"
        );
    });
}

/// A project whose modelled compute spans several virtual hours (a few
/// seconds of real numerics), so hour-boundary spot reclaims genuinely
/// threaten its deadline.
fn write_heavy_sweep(s: &mut Session, dir: &str) {
    s.analyst.write(
        &format!("{dir}/sweep.json"),
        br#"{"type":"mc_sweep","n_jobs":256,"seed":5,"job_cost_s":120}"#.to_vec(),
    );
}

fn heavy_spec(deadline_s: Option<f64>) -> JobSpec {
    JobSpecBuilder::new("slo", "heavy", "sweep.json").deadline(deadline_s).build()
}

/// The tentpole guarantee: a feasible deadline is never missed when
/// on-demand fallback is allowed, even on a market so hostile that
/// spot capacity cannot survive a single hour. The scheduler's
/// forecast sees the permanent spike and routes the job on-demand.
#[test]
fn feasible_deadline_is_met_via_on_demand_fallback() {
    // Reference: the job alone on an on-demand fleet — its duration
    // defines feasibility.
    let duration = {
        let mut s = session();
        write_heavy_sweep(&mut s, "heavy");
        let mut js = JobScheduler::new(AutoscalerConfig {
            min_clusters: 0,
            max_clusters: 2,
            nodes_per_cluster: 2,
            spot: false,
            ..Default::default()
        });
        let id = js.submit(&s, heavy_spec(None));
        js.run_until_idle(&mut s).unwrap();
        let j = js.queue.get(id).unwrap();
        assert_eq!(j.state, JobState::Completed);
        let d = j.completed_at_s.unwrap() - j.submitted_at_s;
        assert!(
            d > 3600.0,
            "the heavy project must span hours for spot to matter, got {d}s"
        );
        d
    };

    // Hostile market: every hour's price spikes above any sane bid, so
    // a spot cluster never survives an hour boundary — a job this size
    // could literally never finish on spot.
    let mut s = session();
    s.cloud.spot.spike_prob = 1.0;
    write_heavy_sweep(&mut s, "heavy");
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 0,
        max_clusters: 2,
        nodes_per_cluster: 2,
        spot: true, // spot fleet *allowed*, on-demand fallback available
        ..Default::default()
    });
    let deadline = s.cloud.clock.now_s() + 3.0 * duration; // comfortably feasible
    let id = js.admit(&s, heavy_spec(Some(deadline)), false, "").unwrap();
    js.run_until_idle(&mut s).unwrap();
    let j = js.queue.get(id).unwrap();
    assert_eq!(j.state, JobState::Completed);
    assert!(
        j.completed_at_s.unwrap() <= deadline,
        "feasible deadline missed: completed t={:.0}s > deadline t={:.0}s",
        j.completed_at_s.unwrap(),
        deadline
    );
    // The guarantee was delivered by the fallback, not by luck: the
    // fleet bought on-demand capacity for the at-risk job and no spot
    // interruption ever fired.
    assert_eq!(js.interruptions_delivered, 0);
    assert!(
        js.autoscaler
            .events
            .iter()
            .any(|e| e.action.contains("scale-up") && e.action.contains("on-demand")),
        "expected an on-demand scale-up, got {:?}",
        js.autoscaler.events.iter().map(|e| &e.action).collect::<Vec<_>>()
    );
}

#[test]
fn quota_zero_queued_jobs_rejects_at_submit() {
    let mut s = session();
    write_projects(&mut s);
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 0,
        max_clusters: 2,
        ..Default::default()
    });
    js.quotas.set(
        "alice",
        TenantQuota {
            max_queued: Some(0),
            ..Default::default()
        },
    );
    let err = js
        .admit(&s, job_specs()[0].clone(), false, "alice")
        .unwrap_err()
        .to_string();
    assert!(
        err.contains("alice") && err.contains("queued-job quota") && err.contains("limit 0"),
        "the error must name the tenant, the limit and the usage: {err}"
    );
    assert_eq!(js.queue.jobs().count(), 0, "a rejected job must not queue");
    assert!(
        js.fleet.is_empty() && s.cloud.live_instances().is_empty(),
        "a quota rejection must never mutate fleet state"
    );
    // Other tenants are unaffected.
    js.admit(&s, job_specs()[1].clone(), false, "bob").unwrap();
    assert_eq!(js.queue.jobs().count(), 1);
    // A zero-cluster quota likewise rejects at submit: the job could
    // never dispatch, and a later drain must not hard-fail on it.
    js.quotas.set(
        "carol",
        TenantQuota {
            max_clusters: Some(0),
            ..Default::default()
        },
    );
    let err = js
        .admit(&s, job_specs()[2].clone(), false, "carol")
        .unwrap_err()
        .to_string();
    assert!(
        err.contains("carol") && err.contains("cluster quota is 0"),
        "{err}"
    );
    assert_eq!(js.queue.jobs().count(), 1, "carol's job must not queue");
}

#[test]
fn autoscaler_never_scales_a_tenant_past_its_cluster_quota() {
    let mut s = session();
    s.cloud.spot.spike_prob = 0.0;
    write_projects(&mut s);
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 0,
        max_clusters: 4,
        nodes_per_cluster: 2,
        spot: false,
        ..Default::default()
    });
    js.quotas.set(
        "alice",
        TenantQuota {
            max_clusters: Some(1),
            ..Default::default()
        },
    );
    // Four jobs from the capped tenant: without the quota the
    // autoscaler would buy four clusters (queue-depth policy).
    for i in [1usize, 3, 5, 7] {
        js.admit(&s, job_specs()[i].clone(), false, "alice").unwrap();
    }
    js.run_until_idle(&mut s).unwrap();
    for j in js.queue.jobs() {
        assert_eq!(j.state, JobState::Completed, "capped work still completes");
    }
    // The demand clamp kept the fleet at the tenant's entitlement:
    // exactly one cluster was ever created.
    let scale_ups = js
        .autoscaler
        .events
        .iter()
        .filter(|e| e.action.contains("scale-up"))
        .count();
    assert_eq!(
        scale_ups,
        1,
        "the fleet must never grow past the tenant quota; events: {:?}",
        js.autoscaler.events.iter().map(|e| &e.action).collect::<Vec<_>>()
    );
    assert!(js.fleet.len() <= 1);
    js.shutdown_fleet(&mut s).unwrap();
}

#[test]
fn quota_compute_budget_rejects_once_exhausted() {
    let mut s = session();
    write_heavy_sweep(&mut s, "heavy");
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 1,
        max_clusters: 1,
        ..Default::default()
    });
    // A zero budget rejects immediately, before any usage exists.
    js.quotas.set(
        "alice",
        TenantQuota {
            max_centihours: Some(0),
            ..Default::default()
        },
    );
    let err = js
        .admit(&s, heavy_spec(None), false, "alice")
        .unwrap_err()
        .to_string();
    assert!(err.contains("compute budget"), "{err}");
    // One centihour of budget (36 virtual seconds): the first job
    // admits, runs (consuming far more), and the next submit bounces.
    js.quotas.set(
        "alice",
        TenantQuota {
            max_centihours: Some(1),
            ..Default::default()
        },
    );
    js.admit(&s, heavy_spec(None), false, "alice").unwrap();
    js.run_until_idle(&mut s).unwrap();
    let used: f64 = js.queue.jobs().map(|j| j.compute_s).sum();
    assert!(
        used > 36.0,
        "the heavy sweep must consume more than one centihour, got {used}s"
    );
    let err = js
        .admit(&s, heavy_spec(None), false, "alice")
        .unwrap_err()
        .to_string();
    assert!(
        err.contains("alice") && err.contains("compute budget"),
        "{err}"
    );
    // Tenants without a quota are unaffected.
    js.admit(&s, heavy_spec(None), false, "bob").unwrap();
}

/// Serverless-tier quota edge (ISSUE 9): the fn admit gate sits on
/// the same centihour budget as the batch tier. An invocation that
/// lands the books *exactly* on the budget boundary still admits;
/// the very next one — now one step past — bounces, and the reject
/// path books nothing.
#[test]
fn fn_quota_admits_at_the_boundary_and_rejects_past_it() {
    let mut s = session();
    let mut p = FnPlatform::new(KeepalivePolicy::Fixed(600.0));
    let mut quotas = QuotaBook::default();
    quotas.set(
        "alice",
        TenantQuota {
            max_centihours: Some(1),
            ..Default::default()
        },
    );
    let spec = |ms: u64| FnInvokeSpec {
        fname: "f".to_string(),
        tenant: "alice".to_string(),
        digest: 1,
        bytes: 1 << 20,
        mem_mb: 512,
        duration_ms: ms,
    };
    // 35.999 s committed: under the 36 s (= 1 centihour) budget.
    p.invoke(&mut s, &quotas, &spec(35_999)).unwrap();
    // Still under at admit time, and this invocation lands the books
    // exactly on the boundary: admitted.
    p.invoke(&mut s, &quotas, &spec(1)).unwrap();
    assert_eq!(p.used_s_for("alice"), 36.0);
    // One centihour is now fully committed: the gate closes.
    let provisioned = p.provisioned_total;
    let billed = s.cloud.ledger.total_centi_cents_for("alice");
    let err = p.invoke(&mut s, &quotas, &spec(1)).unwrap_err().to_string();
    assert!(
        err.contains("alice") && err.contains("compute budget") && err.contains("ec2quota"),
        "{err}"
    );
    assert_eq!(p.rejected_total, 1);
    assert_eq!(
        p.provisioned_total, provisioned,
        "a fn quota reject must provision nothing"
    );
    assert_eq!(
        s.cloud.ledger.total_centi_cents_for("alice"),
        billed,
        "a fn quota reject must bill nothing"
    );
    // Raising the budget reopens the gate; unquota'd tenants never hit it.
    quotas.set(
        "alice",
        TenantQuota {
            max_centihours: Some(2),
            ..Default::default()
        },
    );
    p.invoke(&mut s, &quotas, &spec(1)).unwrap();
    let bob = FnInvokeSpec {
        tenant: "bob".to_string(),
        ..spec(50_000)
    };
    p.invoke(&mut s, &quotas, &bob).unwrap();
}

/// Serverless-tier quota edge (ISSUE 9): a capped tenant's functions
/// rank at zero in the pool autoscaler's demand map — even when their
/// raw arrival rate dominates — so under idle-memory pressure their
/// warm containers are evicted first.
#[test]
fn fn_pool_pressure_evicts_capped_tenants_first() {
    let mut s = session();
    let mut p = FnPlatform::new(KeepalivePolicy::Fixed(7_200.0));
    let mut quotas = QuotaBook::default();
    let spec = |tenant: &str, digest: u64| FnInvokeSpec {
        fname: format!("f{digest}"),
        tenant: tenant.to_string(),
        digest,
        bytes: 1 << 20,
        mem_mb: 512,
        duration_ms: 1_000,
    };
    // Tenant 'capped' invokes four times as often as 'alice': one warm
    // container each, but capped's raw demand dominates.
    for _ in 0..4 {
        p.invoke(&mut s, &quotas, &spec("capped", 1)).unwrap();
        s.cloud.clock.advance(10.0);
    }
    p.invoke(&mut s, &quotas, &spec("alice", 2)).unwrap();
    s.cloud.clock.advance(60.0);
    let now = s.cloud.clock.now_s();
    let raw = p.autoscaler_demand(&quotas, now);
    assert!(
        raw["capped/f1"] > raw["alice/f2"],
        "without the cap, capped's arrival rate must dominate: {raw:?}"
    );
    // Exhaust capped's budget: its demand clamps to zero.
    quotas.set(
        "capped",
        TenantQuota {
            max_centihours: Some(0),
            ..Default::default()
        },
    );
    let clamped = p.autoscaler_demand(&quotas, now);
    assert_eq!(clamped["capped/f1"], 0.0, "a capped tenant must rank at zero demand");
    assert!(clamped["alice/f2"] > 0.0);
    // Idle-memory pressure: budget for one 512 MB container. The
    // autoscaler must evict capped's container, not alice's.
    p.autoscaler.max_idle_mb = 512;
    p.settle(&mut s, &quotas);
    assert_eq!(p.pressure_evictions, 1);
    assert_eq!(p.pool.len(), 1);
    assert!(
        p.pool.values().all(|c| c.tenant == "alice"),
        "pressure must reclaim the capped tenant's warm capacity first"
    );
}

/// Satellite property: EDF-within-class ordering is a total order —
/// priority dominates, deadlines sort non-decreasing within a class
/// (no deadline = infinitely late), and ties break by submission
/// order, so the ordering is stable.
#[test]
fn property_edf_ordering_is_stable_with_ties_by_submit_order() {
    quickprop::check("EDF within class: sorted + stable", 200, |g| {
        let mut q = JobQueue::new();
        let n = g.usize(1..20);
        for i in 0..n {
            let priority = *g.pick(&[Priority::Low, Priority::Normal, Priority::High]);
            // A small deadline alphabet so ties genuinely occur.
            let deadline_s = if g.bool() {
                None
            } else {
                Some(*g.pick(&[100.0, 200.0, 300.0]))
            };
            q.submit(
                JobSpecBuilder::new(&format!("j{i}"), "p", "sweep.json")
                    .priority(priority)
                    .deadline(deadline_s)
                    .build(),
                i as f64,
            );
        }
        let order = q.ready_ids();
        assert_eq!(order.len(), n);
        for w in order.windows(2) {
            let a = q.get(w[0]).unwrap();
            let b = q.get(w[1]).unwrap();
            assert!(
                a.spec.priority >= b.spec.priority,
                "priority must dominate the ordering"
            );
            if a.spec.priority == b.spec.priority {
                let da = a.spec.deadline_s.unwrap_or(f64::INFINITY);
                let db = b.spec.deadline_s.unwrap_or(f64::INFINITY);
                assert!(da <= db, "deadlines must be non-decreasing within a class");
                if da == db {
                    assert!(a.id < b.id, "ties must break by submission order");
                }
            }
        }
    });
}

#[test]
fn invoice_totals_reconcile_with_the_ledger_per_tenant() {
    let mut s = session();
    s.cloud.spot.spike_prob = 0.0;
    write_projects(&mut s);
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 1,
        max_clusters: 2,
        nodes_per_cluster: 2,
        spot: true,
        ..Default::default()
    });
    js.slice_units = 1;
    // Two tenants; alice runs resident so her ledger trail spans every
    // plane: instances, EBS, S3 requests/storage, snapshots and WAN.
    js.admit(&s, job_specs()[0].clone(), true, "alice").unwrap();
    js.admit(&s, job_specs()[1].clone(), false, "bob").unwrap();
    js.run_until_idle(&mut s).unwrap();
    js.shutdown_fleet(&mut s).unwrap();

    let ledger = &s.cloud.ledger;
    let mut tenants = ledger.analysts();
    assert!(tenants.contains(&"alice".to_string()) && tenants.contains(&"bob".to_string()));
    tenants.push(String::new()); // the platform's own share
    let mut sum: u64 = 0;
    for t in &tenants {
        let inv = ledger.invoice_for(t);
        assert_eq!(
            inv.total_centi_cents(),
            ledger.total_centi_cents_for(t),
            "invoice for tenant '{t}' must reconcile exactly (centi-cent equality)"
        );
        sum += inv.total_centi_cents();
    }
    assert_eq!(
        sum,
        ledger.total_centi_cents(),
        "per-tenant invoices must partition the whole bill"
    );
    // The tenants' activity lands in real categories, never 'other'.
    let alice = ledger.invoice_for("alice");
    assert!(alice.wan_transfer_cc > 0, "project sync is metered WAN");
    assert!(
        alice.s3_request_cc > 0,
        "resident checkpoints mirror to S3 under the tenant"
    );
    assert_eq!(alice.other_cc, 0, "every platform charge must be categorised");
}

/// The queued-job quota is a boundary on *waiting* work, tracked by
/// the per-tenant load index: at `maxqueued=1` the second submit
/// bounces while the first waits, and draining the queue releases the
/// slot for the next submit.
#[test]
fn quota_max_queued_boundary_releases_as_the_queue_drains() {
    let mut s = session();
    write_projects(&mut s);
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 0,
        max_clusters: 2,
        nodes_per_cluster: 2,
        spot: false,
        ..Default::default()
    });
    js.quotas.set(
        "alice",
        TenantQuota {
            max_queued: Some(1),
            ..Default::default()
        },
    );
    js.admit(&s, job_specs()[1].clone(), false, "alice").unwrap();
    let err = js
        .admit(&s, job_specs()[3].clone(), false, "alice")
        .unwrap_err()
        .to_string();
    assert!(
        err.contains("alice") && err.contains("limit 1"),
        "the rejection must cite the boundary: {err}"
    );
    js.run_until_idle(&mut s).unwrap();
    // Nothing of alice's is waiting any more — the quota slot frees.
    js.admit(&s, job_specs()[3].clone(), false, "alice").unwrap();
    js.run_until_idle(&mut s).unwrap();
    for j in js.queue.jobs() {
        assert_eq!(j.state, JobState::Completed);
    }
    js.shutdown_fleet(&mut s).unwrap();
}

/// A tenant sitting *exactly* at its cluster cap is skipped by
/// dispatch (>= boundary, not >), its backlog runs later on the
/// clusters it is entitled to, and the fleet never grows past the
/// entitlement even with deeper demand queued.
#[test]
fn tenant_at_exact_cluster_cap_waits_without_losing_work() {
    let mut s = session();
    s.cloud.spot.spike_prob = 0.0;
    write_projects(&mut s);
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 0,
        max_clusters: 4,
        nodes_per_cluster: 2,
        spot: false,
        ..Default::default()
    });
    js.quotas.set(
        "alice",
        TenantQuota {
            max_clusters: Some(2),
            ..Default::default()
        },
    );
    // Four jobs against an entitlement of two: without the dispatch
    // skip the queue-depth policy would buy four clusters.
    for i in [1usize, 3, 5, 7] {
        js.admit(&s, job_specs()[i].clone(), false, "alice").unwrap();
    }
    js.run_until_idle(&mut s).unwrap();
    for j in js.queue.jobs() {
        assert_eq!(j.state, JobState::Completed, "capped work still completes");
    }
    let scale_ups = js
        .autoscaler
        .events
        .iter()
        .filter(|e| e.action.contains("scale-up"))
        .count();
    assert!(
        scale_ups <= 2 && js.fleet.len() <= 2,
        "the fleet must never grow past the two-cluster entitlement; \
         {scale_ups} scale-up(s), {} cluster(s); events: {:?}",
        js.fleet.len(),
        js.autoscaler.events.iter().map(|e| &e.action).collect::<Vec<_>>()
    );
    js.shutdown_fleet(&mut s).unwrap();
}

/// A spot reclaim must release the victim tenant's cluster-cap usage:
/// with `maxclusters=1`, the reclaimed cluster may no longer count
/// against the cap, or the interrupted job could never redispatch and
/// the drain loop would hard-fail with "no capacity is dispatchable".
#[test]
fn cluster_cap_usage_is_released_on_spot_reclaim() {
    let mut s = session();
    s.cloud.spot.spike_prob = 0.0;
    write_projects(&mut s);
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 1,
        max_clusters: 2,
        nodes_per_cluster: 2,
        spot: true,
        ..Default::default()
    });
    js.slice_units = 1;
    js.quotas.set(
        "alice",
        TenantQuota {
            max_clusters: Some(1),
            ..Default::default()
        },
    );
    s.cloud.faults.spot_interruptions = 1;
    let id = js.admit(&s, job_specs()[0].clone(), false, "alice").unwrap();
    js.run_until_idle(&mut s).unwrap();
    let j = js.queue.get(id).unwrap();
    assert_eq!(
        j.state,
        JobState::Completed,
        "the interrupted job must redispatch inside the released cap"
    );
    assert_eq!(j.interruptions, 1, "the reclaim must actually land");
    assert!(
        js.fleet.len() <= 1,
        "replacement capacity still honours the one-cluster cap"
    );
    js.shutdown_fleet(&mut s).unwrap();
}

#[test]
fn interrupted_jobs_record_their_interruptions() {
    let mut s = session();
    s.cloud.spot.spike_prob = 0.0;
    write_projects(&mut s);
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 1,
        max_clusters: 1,
        nodes_per_cluster: 2,
        spot: true,
        ..Default::default()
    });
    js.slice_units = 1;
    s.cloud.faults.spot_interruptions = 1;
    let id = js.submit(
        &s,
        JobSpecBuilder::new("r", "cat0", "catopt.json").build(),
    );
    js.run_until_idle(&mut s).unwrap();
    let j = js.queue.get(id).unwrap();
    assert_eq!(j.state, JobState::Completed);
    assert_eq!(j.interruptions, 1, "the interruption must be attributed");
    assert_eq!(js.interruptions_delivered, 1);
    // The reclaimed cluster was billed with the spot rules.
    assert!(s
        .cloud
        .ledger
        .items()
        .iter()
        .any(|i| i.detail.contains("spot (interrupted")));
}
