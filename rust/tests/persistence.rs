//! Crash-point coverage for the append-log persistence layer
//! (ISSUE 6, satellite c): kill mid-append, kill mid-compaction and
//! legacy `jobs.json` load must each restore a state bit-identical to
//! a clean save.
//!
//! "Bit-identical to a clean save" is checked literally: the crashed
//! directory and a freshly-snapshotted directory are both loaded
//! through `jobs::persist::load` and their `to_json` documents
//! compared as compact strings.

use p2rac::jobs::persist::{self, log_path, snapshot_path, LOG_COMPACT_RECORDS};
use p2rac::jobs::{
    AutoscalerConfig, JobId, JobScheduler, JobSpec, JobSpecBuilder, JobState, Priority,
};
use std::fs;
use std::path::{Path, PathBuf};

/// A scratch directory unique to this test run; recreated empty.
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("p2rac_persist_{}_{}", name, std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

fn spec(i: usize, deadline_s: Option<f64>) -> JobSpec {
    JobSpecBuilder::new(&format!("run{i}"), &format!("proj{}", i % 3), "sweep.json")
        .priority(match i % 3 {
            0 => Priority::High,
            1 => Priority::Normal,
            _ => Priority::Low,
        })
        .deadline(deadline_s)
        .build()
}

/// A scheduler with a mixed backlog: queued, interrupted and completed
/// jobs across three tenants. No `Running` jobs — a running slice is
/// not a persistable state (restart resumes from the last checkpoint),
/// so round-trips are exercised on the states that actually persist.
fn populated_scheduler() -> JobScheduler {
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 0,
        max_clusters: 3,
        nodes_per_cluster: 2,
        ..Default::default()
    });
    for i in 0..6 {
        let deadline = if i % 2 == 0 { Some(5_000.0 + i as f64) } else { None };
        let id = js.queue.submit(spec(i, deadline), 10.0 * i as f64);
        let j = js.queue.get_mut(id).unwrap();
        j.analyst = format!("t{}", i % 3);
        j.units_total = 4 + i;
    }
    // One interrupted, one completed job, so replay covers non-trivial
    // state transitions, not just inserts.
    let j = js.queue.get_mut(JobId(2)).unwrap();
    j.state = JobState::Interrupted;
    j.interruptions = 1;
    j.units_done = 2;
    j.progress = 2.0 / 6.0;
    j.started_at_s = Some(40.0);
    let j = js.queue.get_mut(JobId(3)).unwrap();
    j.state = JobState::Completed;
    j.units_done = j.units_total;
    j.progress = 1.0;
    j.started_at_s = Some(55.0);
    j.completed_at_s = Some(300.0);
    j.compute_s = 245.0;
    js
}

/// Apply a second round of mutations after the first save, so the
/// append log carries a genuine delta.
fn mutate_more(js: &mut JobScheduler) {
    for i in 6..9 {
        let id = js.queue.submit(spec(i, None), 100.0 + i as f64);
        let j = js.queue.get_mut(id).unwrap();
        j.analyst = "t0".to_string();
        j.units_total = 2;
    }
    // A previously-snapshotted job changes state — replay must upsert,
    // not just insert.
    let j = js.queue.get_mut(JobId(1)).unwrap();
    j.state = JobState::Failed;
}

/// Load `dir` and render the restored state canonically.
fn load_compact(dir: &Path) -> String {
    persist::load(dir)
        .unwrap()
        .expect("state must load")
        .to_json()
        .to_string_compact()
}

/// A clean save of `js` into a fresh directory (first save = full
/// snapshot), loaded back — the reference every crash state must
/// match bit for bit.
fn clean_reference(name: &str, js: &mut JobScheduler) -> String {
    let dir = scratch(name);
    persist::save(&dir, js).unwrap();
    load_compact(&dir)
}

#[test]
fn legacy_jobs_json_loads_as_a_snapshot_with_an_empty_log() {
    let dir = scratch("legacy");
    let mut js = populated_scheduler();
    // A pre-append-log session directory: the full document under
    // jobs.json, no jobs.log beside it.
    fs::write(snapshot_path(&dir), js.to_json().to_string_pretty()).unwrap();
    assert!(!log_path(&dir).exists());
    let restored = load_compact(&dir);
    assert_eq!(
        restored,
        clean_reference("legacy_ref", &mut js),
        "a legacy jobs.json must restore bit-identically to a clean save"
    );
}

#[test]
fn append_log_replay_is_bit_identical_to_a_clean_save() {
    let dir = scratch("append");
    let mut js = populated_scheduler();
    persist::save(&dir, &mut js).unwrap(); // snapshot
    mutate_more(&mut js);
    persist::save(&dir, &mut js).unwrap(); // one O(delta) log record
    assert!(log_path(&dir).exists(), "the second save must append, not rewrite");
    let snapshot_before = fs::read_to_string(snapshot_path(&dir)).unwrap();
    let restored = load_compact(&dir);
    assert_eq!(restored, clean_reference("append_ref", &mut js));
    // The snapshot itself was untouched by the append.
    assert_eq!(
        fs::read_to_string(snapshot_path(&dir)).unwrap(),
        snapshot_before
    );
}

#[test]
fn kill_mid_append_discards_the_torn_tail() {
    let dir = scratch("torn");
    let mut js = populated_scheduler();
    persist::save(&dir, &mut js).unwrap();
    mutate_more(&mut js);
    persist::save(&dir, &mut js).unwrap();
    // The crash: a later append died partway through its write. Torn
    // bytes of a would-be record sit at the end of the log.
    let log = fs::read_to_string(log_path(&dir)).unwrap();
    let full_line = log.lines().next().unwrap();
    let torn = &full_line[..full_line.len() / 2];
    fs::write(log_path(&dir), format!("{log}{torn}")).unwrap();
    // Replay stops at the torn record: the state of the last
    // *successful* save is restored exactly.
    let restored = load_compact(&dir);
    assert_eq!(
        restored,
        clean_reference("torn_ref", &mut js),
        "a torn tail must roll back to the previous successful save"
    );
}

#[test]
fn kill_mid_compaction_replays_the_stale_log_idempotently() {
    let dir = scratch("compact_crash");
    let mut js = populated_scheduler();
    persist::save(&dir, &mut js).unwrap();
    mutate_more(&mut js);
    persist::save(&dir, &mut js).unwrap();
    assert!(log_path(&dir).exists());
    // The crash: compaction renamed the fresh full snapshot into place
    // and died before unlinking the log. Every log record's effects
    // are already inside the snapshot.
    fs::write(snapshot_path(&dir), js.to_json().to_string_pretty()).unwrap();
    let restored = load_compact(&dir);
    assert_eq!(
        restored,
        clean_reference("compact_crash_ref", &mut js),
        "replaying a stale log over a fresh snapshot must be a no-op"
    );
}

#[test]
fn compaction_folds_the_log_back_into_a_single_snapshot() {
    let dir = scratch("compact");
    let mut js = populated_scheduler();
    persist::save(&dir, &mut js).unwrap();
    // Enough O(delta) saves to cross the compaction threshold.
    for i in 0..LOG_COMPACT_RECORDS {
        let id = js.queue.submit(spec(9 + i, None), 1_000.0 + i as f64);
        let j = js.queue.get_mut(id).unwrap();
        j.analyst = format!("t{}", i % 3);
        j.units_total = 1;
        persist::save(&dir, &mut js).unwrap();
    }
    assert!(
        !log_path(&dir).exists(),
        "reaching {LOG_COMPACT_RECORDS} records must compact the log away"
    );
    let restored = load_compact(&dir);
    assert_eq!(
        restored,
        clean_reference("compact_ref", &mut js),
        "the compacted snapshot must carry the whole backlog"
    );
}
