//! Acceptance tests for the observability plane (ISSUE 7): a seeded
//! multi-tenant workload run twice produces bit-identical metric
//! snapshots and JSONL traces, the event counts reconcile with the
//! billing ledger and the scheduler's own counters, and the telemetry
//! state survives the session persistence roundtrip.

use p2rac::coordinator::{MockEngine, Session};
use p2rac::jobs::{
    AutoscalerConfig, FnInvokeSpec, FnPlatform, JobScheduler, JobSpec, JobSpecBuilder, JobState,
    KeepalivePolicy, Priority, QuotaBook, TenantQuota,
};
use p2rac::simcloud::SimParams;
use p2rac::telemetry::{trace::TraceSummary, EventKind, Phase};
use p2rac::util::json::Json;

fn session() -> Session {
    Session::new(SimParams::default(), Box::new(MockEngine::new(10.0)))
}

/// Observation count of one histogram series in the bus snapshot
/// (0 if the series never recorded).
fn snap_hist_count(t: &p2rac::telemetry::Telemetry, name: &str) -> u64 {
    t.snapshot_json()
        .path(&["metrics", "histograms", name, "count"])
        .and_then(Json::as_u64)
        .unwrap_or(0)
}

fn write_projects(s: &mut Session) {
    // 200 MC jobs = 4 batches at the 64-job tile: with `slice_units=1`
    // every job runs multiple slices, so intermediate checkpoints (and
    // the slice fast path's warm re-dispatches) genuinely exercise.
    for i in 0..6u64 {
        s.analyst.write(
            &format!("sweep{i}/sweep.json"),
            format!(r#"{{"type":"mc_sweep","n_jobs":200,"seed":{}}}"#, 500 + i).into_bytes(),
        );
    }
}

fn specs(now_s: f64) -> Vec<JobSpec> {
    let prios = [
        Priority::High,
        Priority::Low,
        Priority::Normal,
        Priority::High,
        Priority::Low,
        Priority::Normal,
    ];
    (0..6)
        .map(|i| {
            JobSpecBuilder::new(&format!("run{i}"), &format!("sweep{i}"), "sweep.json")
                .priority(prios[i])
                // One generous deadline so the margin histogram records.
                .deadline(if i == 0 { Some(now_s + 10_000_000.0) } else { None })
                .build()
        })
        .collect()
}

/// The seeded scenario: six jobs, three tenants, spot fleet with two
/// injected interruptions, one quota rejection, one invoice render —
/// every event kind except none. Telemetry records to memory.
fn run_workload() -> (Session, JobScheduler, String, Vec<String>) {
    let mut s = session();
    s.cloud.spot.spike_prob = 0.0;
    s.cloud.telemetry.enable_memory_trace();
    write_projects(&mut s);
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 1,
        max_clusters: 3,
        nodes_per_cluster: 2,
        spot: true,
        ..Default::default()
    });
    js.slice_units = 1;
    s.cloud.faults.spot_interruptions = 2;
    // A rejected submission: tenant 'blocked' may queue nothing.
    js.quotas.set(
        "blocked",
        TenantQuota {
            max_queued: Some(0),
            ..Default::default()
        },
    );
    let all = specs(s.cloud.clock.now_s());
    assert!(js.admit(&s, all[0].clone(), false, "blocked").is_err());
    for (i, spec) in all.iter().enumerate() {
        js.admit(&s, spec.clone(), i == 0, &format!("t{}", i % 3)).unwrap();
    }
    js.run_until_idle(&mut s).unwrap();
    js.shutdown_fleet(&mut s).unwrap();
    for j in js.queue.jobs() {
        assert_eq!(j.state, JobState::Completed);
    }
    // An invoice event on top (what `ec2invoice` emits).
    let inv = s.cloud.ledger.invoice_for("t0");
    s.cloud.telemetry.emit(
        s.cloud.clock.now_s(),
        EventKind::Invoice,
        "t0",
        None,
        None,
        Json::from_pairs(vec![(
            "total_centi_cents",
            Json::num(inv.total_centi_cents() as f64),
        )]),
    );
    let snapshot = s.cloud.telemetry.snapshot_json().to_string_compact();
    let trace = s.cloud.telemetry.take_memory_trace();
    (s, js, snapshot, trace)
}

#[test]
fn two_seeded_runs_produce_bit_identical_telemetry() {
    let (_, _, snap_a, trace_a) = run_workload();
    let (_, _, snap_b, trace_b) = run_workload();
    assert!(!trace_a.is_empty(), "the scenario must record events");
    assert_eq!(snap_a, snap_b, "metric snapshots must be bit-identical");
    assert_eq!(trace_a, trace_b, "JSONL traces must be bit-identical");
}

#[test]
fn event_counts_reconcile_with_ledger_and_scheduler() {
    let (s, js, _, trace) = run_workload();
    let t = &s.cloud.telemetry;

    // Admissions: six jobs queued, one bounced at the quota gate.
    assert_eq!(t.events_of(EventKind::Submit), 6);
    assert_eq!(t.counter("jobs_submitted_total"), 6);
    assert_eq!(t.counter("tenant_jobs_submitted_total{tenant=\"t0\"}"), 2);
    assert_eq!(t.events_of(EventKind::AdmitReject), 1);
    assert_eq!(t.counter("admit_rejects_total{reason=\"quota_queued\"}"), 1);

    // Spot reclaims: one event per interruption the scheduler counted.
    assert_eq!(js.interruptions_delivered, 2);
    assert_eq!(t.events_of(EventKind::SpotReclaim), 2);
    assert_eq!(t.counter("spot_reclaims_total"), 2);

    // Every dispatched slice either completed or was reclaimed
    // mid-slice — the trace itself proves the accounting closes.
    let mid_slice_reclaims = trace
        .iter()
        .map(|l| Json::parse(l).unwrap())
        .filter(|j| {
            j.opt_str("kind").as_deref() == Some("spot-reclaim")
                && j.path(&["detail", "mid_slice"]).and_then(Json::as_bool) == Some(true)
        })
        .count() as u64;
    assert_eq!(
        t.counter("dispatches_total"),
        t.counter("slices_completed_total") + mid_slice_reclaims
    );

    // slice_units=1 on multi-unit jobs: intermediate checkpoints.
    assert!(t.counter("checkpoint_commits_total") > 0);

    // Slice fast path (ISSUE 8): with the cache on, every dispatch is
    // either a warm hit or a cold miss — the two counters partition
    // the dispatch count exactly, and agree with the scheduler's own.
    assert_eq!(
        t.counter("work_cache_hit_total") + t.counter("work_cache_miss_total"),
        t.counter("dispatches_total")
    );
    assert_eq!(t.counter("work_cache_hit_total"), js.work_cache_hits);
    assert_eq!(t.counter("work_cache_miss_total"), js.work_cache_misses);
    assert!(js.work_cache_hits > 0, "consecutive slices must hit the warm cache");
    // A reclaim event flags at most one eviction however many entries
    // it swept, so the event counter lower-bounds the scheduler's
    // per-entry tally and never exceeds the reclaim count.
    assert!(t.counter("work_cache_evict_total") <= t.counter("spot_reclaims_total"));
    assert!(js.work_cache_evictions >= t.counter("work_cache_evict_total"));

    // Every committed checkpoint records its wire size: the bytes
    // histogram count equals the commit counter, and the full/delta
    // split closes against the scheduler's tallies.
    assert_eq!(
        snap_hist_count(t, "checkpoint_bytes"),
        t.counter("checkpoint_commits_total")
    );
    assert_eq!(t.counter("checkpoint_delta_commits_total"), js.ckpt_delta_commits);
    assert_eq!(
        js.ckpt_full_commits + js.ckpt_delta_commits,
        t.counter("checkpoint_commits_total")
    );
    assert!(js.ckpt_delta_commits > 0, "unit slices must ship delta links");

    // Scale decisions mirror the autoscaler's own event log.
    assert_eq!(t.events_of(EventKind::Scale) as usize, js.autoscaler.events.len());

    // WAN billing: the counter equals the ledger's WAN line items.
    let wan_items = s
        .cloud
        .ledger
        .items()
        .iter()
        .filter(|i| i.detail.starts_with("WAN transfer"))
        .count() as u64;
    assert_eq!(t.counter("wan_billed_transfers_total"), wan_items);

    // The invoice gauge carries the exact ledger total for t0.
    let snap = t.snapshot_json();
    assert_eq!(
        snap.path(&["metrics", "gauges", "tenant_billed_centi_cents{tenant=\"t0\"}"])
            .and_then(Json::as_u64),
        Some(s.cloud.ledger.total_centi_cents_for("t0"))
    );

    // The wait histogram saw every dispatch.
    assert_eq!(
        snap.path(&["metrics", "histograms", "queue_wait_s", "count"])
            .and_then(Json::as_u64),
        Some(t.counter("dispatches_total"))
    );
    // The deadlined job completed in time: a non-negative margin.
    let margin_sum = snap
        .path(&["metrics", "histograms", "deadline_margin_s", "sum"])
        .and_then(Json::as_f64)
        .unwrap();
    assert!(margin_sum > 0.0, "margin sum {margin_sum} must be positive");

    // The DES host profiled its own phases (wall-clock, non-zero).
    assert!(js.profiler.entries(Phase::Dispatch) > 0);
    assert!(js.profiler.entries(Phase::Autoscale) > 0);
    assert!(js.profiler.entries(Phase::Complete) > 0);
}

#[test]
fn trace_summary_agrees_with_the_bus() {
    let (s, _, _, trace) = run_workload();
    let summary = TraceSummary::from_lines(trace.iter().map(String::as_str)).unwrap();
    assert_eq!(summary.events, s.cloud.telemetry.events_emitted());
    for kind in [
        EventKind::Submit,
        EventKind::AdmitReject,
        EventKind::Dispatch,
        EventKind::SliceComplete,
        EventKind::CheckpointCommit,
        EventKind::SpotReclaim,
        EventKind::Scale,
        EventKind::Transfer,
        EventKind::Invoice,
    ] {
        assert_eq!(
            summary.by_kind.get(kind.label()).copied().unwrap_or(0),
            s.cloud.telemetry.events_of(kind),
            "trace and registry disagree on '{}'",
            kind.label()
        );
    }
    assert!(summary.tenants.iter().any(|t| t == "t0"));
}

#[test]
fn telemetry_survives_the_session_roundtrip() {
    let (s, _, snapshot, _) = run_workload();
    let j = s.to_json();
    let restored =
        Session::from_json(SimParams::default(), Box::new(MockEngine::new(10.0)), &j).unwrap();
    assert_eq!(
        restored.cloud.telemetry.snapshot_json().to_string_compact(),
        snapshot,
        "the deterministic bus state must persist with the session"
    );
    // A legacy session document without telemetry restores the default.
    let mut legacy = j.clone();
    legacy.set("cloud", {
        let mut c = j.get("cloud").cloned().unwrap();
        c.set("telemetry", Json::Null);
        c
    });
    let fresh =
        Session::from_json(SimParams::default(), Box::new(MockEngine::new(10.0)), &legacy)
            .unwrap();
    assert_eq!(fresh.cloud.telemetry.events_emitted(), 0);
}

// ---------------------------------------------------------------------
// Serverless tier (ISSUE 9): the fn_* metrics reconcile centi-cent-
// exactly with `ec2invoice`'s fn categories, and same-seed runs are
// bit-identical.
// ---------------------------------------------------------------------

/// The seeded serverless scenario: two tenants, three functions, warm
/// hits, keepalive evictions forced by long gaps, one quota rejection,
/// then drain + flush so every idle window is billed before the books
/// are compared. Telemetry records to memory.
fn run_fn_workload() -> (Session, FnPlatform, String, Vec<String>) {
    let mut s = session();
    s.cloud.telemetry.enable_memory_trace();
    let mut p = FnPlatform::new(KeepalivePolicy::Hybrid { default_s: 400.0 });
    let mut quotas = QuotaBook::default();
    // Tenant 'capped' has no compute budget: its invocation bounces at
    // the admit gate before anything is provisioned or billed.
    quotas.set(
        "capped",
        TenantQuota {
            max_centihours: Some(0),
            ..Default::default()
        },
    );
    let blocked = FnInvokeSpec {
        fname: "blocked".to_string(),
        tenant: "capped".to_string(),
        digest: 9,
        bytes: 1 << 20,
        mem_mb: 256,
        duration_ms: 100,
    };
    assert!(p.invoke(&mut s, &quotas, &blocked).is_err());
    for i in 0..24u64 {
        let k = i % 3;
        let spec = FnInvokeSpec {
            fname: format!("f{k}"),
            tenant: if k == 0 { "t0" } else { "t1" }.to_string(),
            digest: k + 1,
            bytes: (k + 1) * (1 << 20),
            mem_mb: 512,
            duration_ms: 200 + 50 * k,
        };
        p.invoke(&mut s, &quotas, &spec).unwrap();
        // Occasional long gaps, so keepalive evictions genuinely fire
        // and the idle windows they bill land in the ledger.
        s.cloud.clock.advance(if i % 8 == 7 { 5_000.0 } else { 240.0 });
    }
    p.drain(&mut s, &quotas);
    p.flush(&mut s);
    // The invoice events `ec2invoice` would emit.
    for tenant in ["t0", "t1"] {
        let inv = s.cloud.ledger.invoice_for(tenant);
        s.cloud.telemetry.emit(
            s.cloud.clock.now_s(),
            EventKind::Invoice,
            tenant,
            None,
            None,
            Json::from_pairs(vec![(
                "total_centi_cents",
                Json::num(inv.total_centi_cents() as f64),
            )]),
        );
    }
    let snapshot = s.cloud.telemetry.snapshot_json().to_string_compact();
    let trace = s.cloud.telemetry.take_memory_trace();
    (s, p, snapshot, trace)
}

#[test]
fn fn_tier_metrics_reconcile_with_the_invoice() {
    let (s, p, _, trace) = run_fn_workload();
    let t = &s.cloud.telemetry;

    // Counters mirror the platform's own tallies exactly.
    assert_eq!(t.counter("fn_invoke_total"), p.invocations_total);
    assert_eq!(t.counter("fn_coldstart_total"), p.cold_total);
    assert!(p.cold_total > 0, "the scenario must cold-start");
    assert!(
        p.cold_total < p.invocations_total,
        "the scenario must also hit the warm pool"
    );
    // Every invocation recorded one latency observation.
    assert_eq!(snap_hist_count(t, "fn_invoke_latency_s"), p.invocations_total);
    // One pool event per provision and per eviction, no more, no less.
    assert_eq!(
        t.events_of(EventKind::FnPool),
        p.provisioned_total + p.evicted_total
    );
    assert_eq!(t.events_of(EventKind::FnInvoke), p.invocations_total);
    // The quota bounce surfaced as an admit-reject on the fn tier.
    assert_eq!(p.rejected_total, 1);
    assert_eq!(t.counter("admit_rejects_total{reason=\"quota_centihours\"}"), 1);

    // After flush the pool is empty and the gauges say so.
    assert!(p.conserved());
    assert_eq!(p.pool.len(), 0);
    let snap = t.snapshot_json();
    assert_eq!(
        snap.path(&["metrics", "gauges", "fn_pool_size"]).and_then(Json::as_f64),
        Some(0.0)
    );
    assert_eq!(
        snap.path(&["metrics", "gauges", "fn_pool_idle_mb"]).and_then(Json::as_f64),
        Some(0.0)
    );

    // The heart of the satellite: per tenant, the billed centi-cents
    // that rode the events reconcile centi-cent-exactly with the
    // invoice's fn categories, and the invoice total closes against
    // the raw ledger.
    for tenant in ["t0", "t1"] {
        let inv = s.cloud.ledger.invoice_for(tenant);
        assert!(inv.fn_invoke_cc > 0, "tenant {tenant} must be billed for invocations");
        assert!(inv.fn_pool_cc > 0, "tenant {tenant} must be billed for idle memory");
        assert_eq!(
            t.counter(&format!("tenant_fn_invoke_centi_cents{{tenant=\"{tenant}\"}}")),
            inv.fn_invoke_cc,
            "invocation billing for {tenant} must reconcile centi-cent-exactly"
        );
        assert_eq!(
            t.counter(&format!("tenant_fn_pool_centi_cents{{tenant=\"{tenant}\"}}")),
            inv.fn_pool_cc,
            "idle-memory billing for {tenant} must reconcile centi-cent-exactly"
        );
        assert_eq!(inv.total_centi_cents(), s.cloud.ledger.total_centi_cents_for(tenant));
    }
    // Nothing was booked against the capped tenant.
    assert_eq!(s.cloud.ledger.total_centi_cents_for("capped"), 0);

    // The JSONL trace is well-formed and agrees with the bus on the
    // new event kinds.
    let summary = TraceSummary::from_lines(trace.iter().map(String::as_str)).unwrap();
    assert_eq!(summary.events, t.events_emitted());
    for kind in [EventKind::FnInvoke, EventKind::FnPool, EventKind::AdmitReject] {
        assert_eq!(
            summary.by_kind.get(kind.label()).copied().unwrap_or(0),
            t.events_of(kind),
            "trace and registry disagree on '{}'",
            kind.label()
        );
    }
}

#[test]
fn two_seeded_fn_runs_produce_bit_identical_telemetry() {
    let (_, p_a, snap_a, trace_a) = run_fn_workload();
    let (_, p_b, snap_b, trace_b) = run_fn_workload();
    assert!(!trace_a.is_empty(), "the fn scenario must record events");
    assert_eq!(snap_a, snap_b, "fn metric snapshots must be bit-identical");
    assert_eq!(trace_a, trace_b, "fn JSONL traces must be bit-identical");
    assert_eq!(p_a.dispatch_digest(), p_b.dispatch_digest());
}

#[test]
fn file_trace_sink_appends_valid_jsonl() {
    let dir = std::env::temp_dir().join(format!("p2rac-trace-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.jsonl");
    let _ = std::fs::remove_file(&path);

    let mut s = session();
    s.cloud.spot.spike_prob = 0.0;
    s.cloud.telemetry.set_trace_file(path.to_str().unwrap());
    write_projects(&mut s);
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 1,
        max_clusters: 2,
        nodes_per_cluster: 2,
        spot: false,
        ..Default::default()
    });
    let all = specs(s.cloud.clock.now_s());
    for spec in all.iter().take(2) {
        js.admit(&s, spec.clone(), false, "alice").unwrap();
    }
    js.run_until_idle(&mut s).unwrap();
    s.cloud.telemetry.flush().unwrap();

    let text = std::fs::read_to_string(&path).unwrap();
    let summary = TraceSummary::from_lines(text.lines()).unwrap();
    assert_eq!(summary.events, s.cloud.telemetry.events_emitted());
    assert!(summary.by_kind.contains_key("dispatch"));
    std::fs::remove_dir_all(&dir).ok();
}
