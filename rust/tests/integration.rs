//! Full-stack integration tests: coordinator + datasync + simcloud +
//! analytics engine, and (when `artifacts/` is built) the PJRT runtime,
//! exercised through the same `Session` API the CLI uses.

use p2rac::analytics::{CatBondData, P2racEngine, PjrtBackend, RustBackend};
use p2rac::analytics::backend::FitnessBackend;
use p2rac::coordinator::{
    CreateClusterOpts, CreateInstanceOpts, Placement, ResultScope, Session,
};
use p2rac::runtime::Runtime;
use p2rac::simcloud::{SimParams, SpanCategory};
use p2rac::util::json::Json;
use std::path::Path;
use std::sync::Arc;

fn artifacts_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// The PJRT runtime when artifacts are built AND the real xla binding
/// is linked; `None` otherwise (offline stub or no artifacts), matching
/// the graceful fallback in `cli::make_engine`.
fn pjrt_runtime() -> Option<Arc<Runtime>> {
    if !artifacts_dir().join("manifest.json").exists() {
        return None;
    }
    match Runtime::load(&artifacts_dir()) {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            eprintln!("artifacts present but runtime unavailable ({e:#}); using rust backend");
            None
        }
    }
}

fn engine() -> Box<P2racEngine> {
    match pjrt_runtime() {
        Some(rt) => Box::new(P2racEngine::with_runtime(rt)),
        None => Box::new(P2racEngine::rust_only()),
    }
}

fn catopt_project(s: &mut Session, dir: &str, m: usize, e: usize, script: &str) {
    let data = CatBondData::generate(7, m, e);
    for (name, bytes) in data.to_files() {
        s.analyst.write(&format!("{dir}/{name}"), bytes);
    }
    s.analyst
        .write(&format!("{dir}/catopt.json"), script.as_bytes().to_vec());
}

#[test]
fn catopt_full_stack_on_cluster() {
    // The complete Fig-3 workflow with the production engine. If the
    // artifacts are built, fitness evaluation goes through PJRT (L1
    // Pallas numerics); otherwise through the Rust oracle.
    // One runtime load serves both the scale decision and the engine.
    let rt = pjrt_runtime();
    let with_pjrt = rt.is_some();
    let eng: Box<P2racEngine> = match rt {
        Some(rt) => Box::new(P2racEngine::with_runtime(rt)),
        None => Box::new(P2racEngine::rust_only()),
    };
    let mut s = Session::new(SimParams::default(), eng);
    let (m, e) = if with_pjrt { (512, 2048) } else { (48, 160) };
    catopt_project(
        &mut s,
        "proj",
        m,
        e,
        r#"{"type":"catopt","pop_size":24,"max_generations":4,"seed":5,"bfgs_every":0}"#,
    );
    s.create_cluster(&CreateClusterOpts {
        cname: Some("c".into()),
        csize: Some(4),
        itype: Some("m2.2xlarge".into()),
        ..Default::default()
    })
    .unwrap();
    s.send_data_to_cluster_nodes(Some("c"), "proj").unwrap();
    let out = s
        .run_on_cluster(Some("c"), "proj", "catopt.json", "t1", Placement::ByNode)
        .unwrap();
    let best = out.summary.get("best_value").and_then(Json::as_f64).unwrap();
    assert!(best.is_finite() && best >= 0.0);
    s.get_results(Some("c"), "proj", "t1", ResultScope::FromMaster)
        .unwrap();
    assert!(s.analyst.exists("proj_results/t1/solution.json"));
    assert!(s.analyst.exists("proj_results/t1/convergence.csv"));
    assert!(s.analyst.exists("proj_results/t1/weights.bin"));
    s.terminate_cluster(Some("c"), true).unwrap();
    assert!(s.cloud.live_instances().is_empty());
    assert!(s.cloud.ledger.total_cents() > 0, "usage must be billed");
}

#[test]
fn pjrt_fitness_agrees_with_rust_oracle() {
    // The PJRT artifact and the Rust reference implement the same
    // objective — cross-check them on the same population.
    let Some(rt) = pjrt_runtime() else {
        eprintln!("skipped: artifacts not built or runtime unavailable");
        return;
    };
    let m = rt.constant("M").unwrap();
    let e = rt.constant("E").unwrap();
    let data = CatBondData::generate(3, m, e);
    let pjrt = PjrtBackend::new(Arc::clone(&rt), data.clone()).unwrap();
    let rust = RustBackend::new(data);
    let mut rng = p2rac::util::prng::Xoshiro256::seed_from_u64(1);
    let pop: Vec<Vec<f32>> = (0..8)
        .map(|_| (0..m).map(|_| rng.next_f32() * 2.0 / m as f32).collect())
        .collect();
    let fa = pjrt.eval_population(&pop).unwrap();
    let fb = rust.eval_population(&pop).unwrap();
    for (i, (a, b)) in fa.iter().zip(&fb).enumerate() {
        let tol = 1e-3 * b.abs().max(1.0);
        assert!(
            (a - b).abs() < tol,
            "candidate {i}: pjrt {a} vs rust {b}"
        );
    }
    // Gradient path too.
    let (va, ga) = pjrt.value_and_grad(&pop[0]).unwrap();
    let (vb, gb) = rust.value_and_grad(&pop[0]).unwrap();
    assert!((va - vb).abs() < 1e-3 * vb.abs().max(1.0), "{va} vs {vb}");
    let dot: f64 = ga.iter().zip(&gb).map(|(&x, &y)| x as f64 * y as f64).sum();
    let na: f64 = ga.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = gb.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    assert!(dot / (na * nb) > 0.999, "gradients must align");
}

#[test]
fn sweep_full_stack_with_worker_gather() {
    let mut s = Session::new(SimParams::default(), engine());
    s.analyst.write(
        "sp/sweep.json",
        br#"{"type":"mc_sweep","n_jobs":48,"seed":2}"#.to_vec(),
    );
    s.create_cluster(&CreateClusterOpts {
        cname: Some("c".into()),
        csize: Some(3),
        ..Default::default()
    })
    .unwrap();
    s.send_data_to_cluster_nodes(Some("c"), "sp").unwrap();
    s.run_on_cluster(Some("c"), "sp", "sweep.json", "r", Placement::BySlot)
        .unwrap();
    let rep = s.get_results(Some("c"), "sp", "r", ResultScope::FromAll).unwrap();
    assert!(rep.files_sent >= 3, "master csv + 2 worker parts");
    assert!(s.analyst.exists("sp_results/r/master/sweep.csv"));
    assert!(s.analyst.exists("sp_results/r/worker0/part_worker0.csv"));
    s.terminate_cluster(Some("c"), false).unwrap();
}

#[test]
fn boot_failure_is_surfaced_and_recoverable() {
    let mut s = Session::new(SimParams::default(), engine());
    s.cloud.faults.boot_failures = 1;
    let err = s.create_cluster(&CreateClusterOpts {
        cname: Some("c".into()),
        csize: Some(2),
        ..Default::default()
    });
    assert!(err.is_err(), "injected capacity failure must surface");
    // Config stays clean; retry succeeds.
    assert!(s.clusters_cfg.names().is_empty());
    s.create_cluster(&CreateClusterOpts {
        cname: Some("c".into()),
        csize: Some(2),
        ..Default::default()
    })
    .unwrap();
}

#[test]
fn interrupted_sync_retries_with_delta_reuse() {
    let mut s = Session::new(SimParams::default(), engine());
    // Multi-file project so the interruption lands mid-list.
    for i in 0..6 {
        s.analyst
            .write(&format!("p/data/part{i}.bin"), vec![i as u8; 50_000]);
    }
    s.analyst
        .write("p/sweep.json", br#"{"type":"mc_sweep","n_jobs":8}"#.to_vec());
    s.create_instance(&CreateInstanceOpts {
        iname: Some("i".into()),
        ..Default::default()
    })
    .unwrap();
    s.cloud.faults.transfer_interrupts = 1;
    assert!(s.send_data_to_instance(Some("i"), "p").is_err());
    // Retry: already-delivered files are skipped as unchanged.
    let rep = s.send_data_to_instance(Some("i"), "p").unwrap();
    assert!(rep.files_unchanged > 0, "retry must reuse delivered files");
    let id = s.instances_cfg.get("i").unwrap().instance_id.clone();
    assert!(s.cloud.instance(&id).unwrap().fs.exists("root/p/data/part5.bin"));
}

#[test]
fn byslot_and_bynode_agree_on_results_but_not_memory() {
    let mut s = Session::new(SimParams::default(), engine());
    s.analyst.write(
        "p/sweep.json",
        br#"{"type":"mc_sweep","n_jobs":32,"seed":9}"#.to_vec(),
    );
    s.create_cluster(&CreateClusterOpts {
        cname: Some("c".into()),
        csize: Some(4),
        ..Default::default()
    })
    .unwrap();
    s.send_data_to_cluster_nodes(Some("c"), "p").unwrap();
    let a = s
        .run_on_cluster(Some("c"), "p", "sweep.json", "rn", Placement::ByNode)
        .unwrap();
    let b = s
        .run_on_cluster(Some("c"), "p", "sweep.json", "rs", Placement::BySlot)
        .unwrap();
    // Same numerics either way (placement affects time, not results).
    assert_eq!(
        a.summary.get("best_att").and_then(Json::as_f64),
        b.summary.get("best_att").and_then(Json::as_f64)
    );
    s.terminate_cluster(Some("c"), false).unwrap();
}

#[test]
fn multi_resource_sessions_share_one_cloud() {
    // Two instances + one cluster coexist; ec2terminateall clears all.
    let mut s = Session::new(SimParams::default(), engine());
    s.create_instance(&CreateInstanceOpts {
        iname: Some("i1".into()),
        ..Default::default()
    })
    .unwrap();
    s.create_instance(&CreateInstanceOpts {
        iname: Some("i2".into()),
        itype: Some("m2.4xlarge".into()),
        ..Default::default()
    })
    .unwrap();
    s.create_cluster(&CreateClusterOpts {
        cname: Some("c1".into()),
        csize: Some(2),
        ..Default::default()
    })
    .unwrap();
    assert_eq!(s.cloud.live_instances().len(), 4);
    let log = s.terminate_all(true, true, true, true).unwrap();
    assert!(log.len() >= 5);
    assert!(s.cloud.live_instances().is_empty());
    assert!(s.cloud.live_volumes().is_empty());
}

#[test]
fn dynamic_cluster_scaling_future_work() {
    // The paper's §5 future work: grow/shrink a cluster mid-session.
    let mut s = Session::new(SimParams::default(), engine());
    s.analyst.write(
        "p/sweep.json",
        br#"{"type":"mc_sweep","n_jobs":64,"seed":4}"#.to_vec(),
    );
    s.create_cluster(&CreateClusterOpts {
        cname: Some("c".into()),
        csize: Some(2),
        ..Default::default()
    })
    .unwrap();
    let t_small = {
        s.send_data_to_cluster_nodes(Some("c"), "p").unwrap();
        s.run_on_cluster(Some("c"), "p", "sweep.json", "r1", Placement::ByNode)
            .unwrap()
            .compute_s
    };
    // Grow 2 -> 8: new workers must NFS-mount the master's volume.
    s.resize_cluster(Some("c"), 8).unwrap();
    let e = s.clusters_cfg.get("c").unwrap().clone();
    assert_eq!(e.size, 8);
    assert_eq!(e.worker_ids.len(), 7);
    for w in &e.worker_ids {
        assert_eq!(
            s.cloud.instance(w).unwrap().nfs_mount_from,
            e.volume_id,
            "grown worker must share the master volume"
        );
    }
    // Newly-added nodes need the project before the next run.
    s.send_data_to_cluster_nodes(Some("c"), "p").unwrap();
    let t_big = s
        .run_on_cluster(Some("c"), "p", "sweep.json", "r2", Placement::ByNode)
        .unwrap()
        .compute_s;
    assert!(t_big < t_small / 2.0, "8 nodes {t_big}s vs 2 nodes {t_small}s");
    // Shrink back 8 -> 3 and verify the dropped workers are gone.
    s.resize_cluster(Some("c"), 3).unwrap();
    assert_eq!(s.clusters_cfg.get("c").unwrap().worker_ids.len(), 2);
    assert_eq!(s.cloud.live_instances().len(), 3);
    // Locked clusters refuse resizing.
    s.set_cluster_lock("c", true).unwrap();
    assert!(s.resize_cluster(Some("c"), 4).is_err());
    s.set_cluster_lock("c", false).unwrap();
    s.terminate_cluster(Some("c"), false).unwrap();
}

#[test]
fn timeline_reproduces_paper_ordering() {
    // Creation must dominate data movement for the small project, and
    // all six Fig-6 categories must be recorded.
    let mut s = Session::new(SimParams::default(), engine());
    s.analyst.write(
        "p/sweep.json",
        br#"{"type":"mc_sweep","n_jobs":16,"seed":1}"#.to_vec(),
    );
    s.create_cluster(&CreateClusterOpts {
        cname: Some("c".into()),
        csize: Some(8),
        ..Default::default()
    })
    .unwrap();
    s.send_data_to_master(Some("c"), "p").unwrap();
    s.send_data_to_cluster_nodes(Some("c"), "p").unwrap();
    s.run_on_cluster(Some("c"), "p", "sweep.json", "r", Placement::ByNode)
        .unwrap();
    s.get_results(Some("c"), "p", "r", ResultScope::FromAll).unwrap();
    s.terminate_cluster(Some("c"), false).unwrap();
    let c = &s.cloud.clock;
    let create = c.category_total_s(SpanCategory::CreateResource);
    let moves = c.category_total_s(SpanCategory::SubmitToMaster)
        + c.category_total_s(SpanCategory::SubmitToAllNodes)
        + c.category_total_s(SpanCategory::FetchFromAllNodes);
    assert!(create > 5.0 * moves, "create {create} vs moves {moves}");
    assert!(c.category_total_s(SpanCategory::TerminateResource) > 0.0);
}
