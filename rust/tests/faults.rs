//! FaultPlan recovery paths, end to end: armed `transfer_interrupts`
//! are survived by an rsync retry that re-sends only the missing
//! blocks, and `exec_failures` on a worker reschedule the slice
//! without corrupting results.

use p2rac::analytics::CatBondData;
use p2rac::coordinator::{CreateInstanceOpts, MockEngine, Placement, Session};
use p2rac::jobs::{
    files_digest, AutoscalerConfig, JobScheduler, JobSpecBuilder, JobState, Priority,
};
use p2rac::simcloud::SimParams;

fn session() -> Session {
    Session::new(SimParams::default(), Box::new(MockEngine::new(10.0)))
}

#[test]
fn interrupted_transfer_retry_resends_only_whats_missing() {
    let mut s = session();
    for i in 0..8u8 {
        s.analyst
            .write(&format!("p/data/part{i}.bin"), vec![i; 40_000]);
    }
    s.analyst
        .write("p/sweep.json", br#"{"type":"mc_sweep","n_jobs":8}"#.to_vec());
    s.create_instance(&CreateInstanceOpts {
        iname: Some("i".into()),
        ..Default::default()
    })
    .unwrap();

    // Reference: what an uninterrupted first copy puts on the wire.
    let full_wire = {
        let mut s2 = session();
        for i in 0..8u8 {
            s2.analyst
                .write(&format!("p/data/part{i}.bin"), vec![i; 40_000]);
        }
        s2.analyst
            .write("p/sweep.json", br#"{"type":"mc_sweep","n_jobs":8}"#.to_vec());
        s2.create_instance(&CreateInstanceOpts {
            iname: Some("i".into()),
            ..Default::default()
        })
        .unwrap();
        s2.send_data_to_instance(Some("i"), "p").unwrap().wire_bytes()
    };

    s.cloud.faults.transfer_interrupts = 1;
    let err = s.send_data_to_instance(Some("i"), "p").unwrap_err();
    assert!(err.to_string().contains("interrupted"), "{err:#}");

    // The retry skips everything already delivered (the interruption
    // lands mid-list, so roughly half the project crossed already):
    // clearly less than a full copy goes over the wire again.
    let retry = s.send_data_to_instance(Some("i"), "p").unwrap();
    assert!(retry.files_unchanged > 0);
    assert!(
        retry.wire_bytes() * 4 < full_wire * 3,
        "retry resent {} of a {} full copy",
        retry.wire_bytes(),
        full_wire
    );
    // Everything landed intact.
    let id = s.instances_cfg.get("i").unwrap().instance_id.clone();
    for i in 0..8u8 {
        assert_eq!(
            s.cloud
                .instance(&id)
                .unwrap()
                .fs
                .read(&format!("root/p/data/part{i}.bin")),
            Some(vec![i; 40_000].as_slice())
        );
    }

    // Block-level reuse: flip one byte mid-file and re-sync — the
    // rsync delta ships a couple of blocks, not the 40 KB file.
    let mut edited = vec![3u8; 40_000];
    edited[20_000] ^= 0xAA;
    s.analyst.write("p/data/part3.bin", edited.clone());
    let delta = s.send_data_to_instance(Some("i"), "p").unwrap();
    assert_eq!(delta.files_sent, 1);
    assert!(
        delta.literal_bytes < 8_000,
        "one flipped byte resent {} literal bytes",
        delta.literal_bytes
    );
    assert_eq!(
        s.cloud
            .instance(&id)
            .unwrap()
            .fs
            .read("root/p/data/part3.bin"),
        Some(edited.as_slice())
    );
}

fn write_catopt(s: &mut Session) {
    let data = CatBondData::generate(9, 24, 96);
    for (name, bytes) in data.to_files() {
        s.analyst.write(&format!("proj/{name}"), bytes);
    }
    s.analyst.write(
        "proj/catopt.json",
        br#"{"type":"catopt","pop_size":12,"max_generations":5,"seed":11,"bfgs_every":2}"#
            .to_vec(),
    );
}

fn run_jobs_with_exec_failures(failures: usize) -> (u64, usize) {
    let mut s = session();
    write_catopt(&mut s);
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 1,
        max_clusters: 2,
        ..Default::default()
    });
    js.slice_units = 1;
    let a = js.submit(
        &s,
        JobSpecBuilder::new("a", "proj", "catopt.json").build(),
    );
    let b = js.submit(
        &s,
        JobSpecBuilder::new("b", "proj", "catopt.json")
            .priority(Priority::High)
            .placement(Placement::BySlot)
            .build(),
    );
    s.cloud.faults.exec_failures = failures;
    js.run_until_idle(&mut s).unwrap();
    for id in [a, b] {
        assert_eq!(js.queue.get(id).unwrap().state, JobState::Completed);
    }
    let retries = js.queue.get(a).unwrap().retries + js.queue.get(b).unwrap().retries;
    let mut files = Vec::new();
    for name in ["a", "b"] {
        let dir = format!("proj_results/{name}");
        for rel in s.analyst.list_dir(&dir) {
            files.push((
                format!("{name}/{rel}"),
                s.analyst.read(&format!("{dir}/{rel}")).unwrap().to_vec(),
            ));
        }
    }
    files.sort();
    (files_digest(&files), retries)
}

#[test]
fn worker_exec_failures_reschedule_without_corrupting_results() {
    let (clean, zero_retries) = run_jobs_with_exec_failures(0);
    assert_eq!(zero_retries, 0);
    let (faulty, retries) = run_jobs_with_exec_failures(2);
    assert_eq!(retries, 2, "both armed exec failures must cost a retry");
    assert_eq!(
        clean, faulty,
        "rescheduled slices must reproduce the clean results bit for bit"
    );
}
