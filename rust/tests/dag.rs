//! DAG workflow edge tests (ISSUE 10): cyclic spec files are rejected
//! at admit with nothing mutated, a diamond's sink is released exactly
//! once, a failed parent cancels its whole subtree (billed only for
//! work actually done), the deadline back-propagation invariant holds
//! on random graphs, and `ec2getresults -froms3` fetches a stage's
//! published outputs from the results bucket.

use p2rac::cli::commands::{apply, apply_with_jobs, registry};
use p2rac::coordinator::{MockEngine, Session};
use p2rac::jobs::{
    AutoscalerConfig, JobId, JobScheduler, JobSpecBuilder, JobState, RESULTS_BUCKET,
};
use p2rac::simcloud::SimParams;
use p2rac::util::quickprop;

fn session() -> Session {
    let mut s = Session::new(SimParams::default(), Box::new(MockEngine::new(10.0)));
    s.cloud.spot.spike_prob = 0.0;
    s
}

fn sweep_project(s: &mut Session, dir: &str, n_jobs: usize, seed: u64) {
    s.analyst.write(
        &format!("{dir}/sweep.json"),
        format!(r#"{{"type":"mc_sweep","n_jobs":{n_jobs},"seed":{seed}}}"#).into_bytes(),
    );
}

fn sched() -> JobScheduler {
    JobScheduler::new(AutoscalerConfig {
        min_clusters: 1,
        max_clusters: 2,
        nodes_per_cluster: 2,
        spot: false,
        ..Default::default()
    })
}

fn run_cli(
    s: &mut Session,
    js: &mut JobScheduler,
    cmd: &str,
    args: &[&str],
) -> anyhow::Result<String> {
    let spec = registry().into_iter().find(|c| c.name == cmd).unwrap();
    let p = spec.parse(args.iter().map(|a| a.to_string())).unwrap();
    apply_with_jobs(s, js, cmd, &p)
}

#[test]
fn cyclic_specfile_is_rejected_with_nothing_mutated() {
    let dir = std::env::temp_dir().join(format!("p2rac-dag-cycle-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cycle.json");
    std::fs::write(
        &path,
        r#"{"projectdir":"proj","stages":[
            {"name":"a","rscript":"sweep.json","after":["c"]},
            {"name":"b","rscript":"sweep.json","after":["a"]},
            {"name":"c","rscript":"sweep.json","after":["b"]}]}"#,
    )
    .unwrap();
    let mut s = session();
    sweep_project(&mut s, "proj", 24, 7);
    let mut js = sched();
    let t0 = s.cloud.clock.now_s();
    let err = format!(
        "{:#}",
        run_cli(
            &mut s,
            &mut js,
            "ec2submitjob",
            &["-specfile", path.to_str().unwrap()],
        )
        .unwrap_err()
    );
    assert!(err.contains("cyclic"), "{err}");
    // Whole-graph validation happens before any submission: nothing
    // was queued, held, counted or billed.
    assert_eq!(js.queue.jobs().count(), 0, "a cyclic graph must not queue");
    assert_eq!(js.dag_releases + js.dag_cancels, 0);
    assert_eq!(s.cloud.clock.now_s(), t0, "the clock must not advance");
    assert!(js.fleet.is_empty(), "no fleet may be provisioned");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn after_flag_holds_children_and_rejects_unknown_parents() {
    let mut s = session();
    sweep_project(&mut s, "proj", 24, 7);
    let mut js = sched();
    let out = run_cli(
        &mut s,
        &mut js,
        "ec2submitjob",
        &["-projectdir", "proj", "-rscript", "sweep.json", "-runname", "parent"],
    )
    .unwrap();
    assert!(out.contains("submitted job-1"), "{out}");
    let out = run_cli(
        &mut s,
        &mut js,
        "ec2submitjob",
        &[
            "-projectdir", "proj", "-rscript", "sweep.json", "-runname", "child",
            "-after", "1",
        ],
    )
    .unwrap();
    assert!(out.contains("after [job-1]"), "{out}");
    assert!(out.contains("held"), "{out}");
    assert_eq!(js.queue.get(JobId(2)).unwrap().state, JobState::Held);
    // An unknown parent is rejected before anything is queued.
    let err = run_cli(
        &mut s,
        &mut js,
        "ec2submitjob",
        &[
            "-projectdir", "proj", "-rscript", "sweep.json", "-runname", "orphan",
            "-after", "99",
        ],
    )
    .unwrap_err();
    assert!(format!("{err:#}").contains("unknown"), "{err:#}");
    assert_eq!(js.queue.jobs().count(), 2);
    // -after and -specfile are mutually exclusive at the parser.
    let spec = registry().into_iter().find(|c| c.name == "ec2submitjob").unwrap();
    let err = spec
        .parse(["-after", "1", "-specfile", "wf.json"].map(String::from))
        .unwrap_err();
    assert!(matches!(err, p2rac::util::argparse::ArgError::Exclusive(_)));
}

#[test]
fn diamond_releases_the_sink_exactly_once() {
    let mut s = session();
    for (d, seed) in [("pa", 11u64), ("pb", 12), ("pc", 13), ("pd", 14)] {
        sweep_project(&mut s, d, 24, seed);
    }
    let mut js = sched();
    let a = js
        .admit(&s, JobSpecBuilder::new("a", "pa", "sweep.json").build(), false, "")
        .unwrap();
    let b = js
        .admit(
            &s,
            JobSpecBuilder::new("b", "pb", "sweep.json").after([a]).build(),
            false,
            "",
        )
        .unwrap();
    let c = js
        .admit(
            &s,
            JobSpecBuilder::new("c", "pc", "sweep.json").after([a]).build(),
            false,
            "",
        )
        .unwrap();
    let d = js
        .admit(
            &s,
            JobSpecBuilder::new("d", "pd", "sweep.json").after([b, c]).build(),
            false,
            "",
        )
        .unwrap();
    for id in [b, c, d] {
        assert_eq!(js.queue.get(id).unwrap().state, JobState::Held);
    }
    js.run_until_idle(&mut s).unwrap();
    js.shutdown_fleet(&mut s).unwrap();
    for id in [a, b, c, d] {
        assert_eq!(
            js.queue.get(id).unwrap().state,
            JobState::Completed,
            "{id} must complete"
        );
    }
    // b, c and d each released exactly once — the diamond's sink is
    // not double-released when its second parent completes.
    assert_eq!(js.dag_releases, 3, "exactly one release per held stage");
    assert_eq!(js.dag_cancels, 0);
    assert!(s.analyst.exists("pd_results/d/summary.json"));
}

#[test]
fn failed_parent_cancels_the_subtree_and_bills_only_work_done() {
    let mut s = session();
    sweep_project(&mut s, "ok", 24, 21);
    // The parent's script does not exist: it fails at first dispatch.
    let mut js = sched();
    let bad = js
        .admit(&s, JobSpecBuilder::new("bad", "nope", "missing.json").build(), false, "t1")
        .unwrap();
    let child = js
        .admit(
            &s,
            JobSpecBuilder::new("child", "ok", "sweep.json").after([bad]).build(),
            false,
            "t1",
        )
        .unwrap();
    let grandchild = js
        .admit(
            &s,
            JobSpecBuilder::new("grandchild", "ok", "sweep.json").after([child]).build(),
            false,
            "t1",
        )
        .unwrap();
    let solo = js
        .admit(&s, JobSpecBuilder::new("solo", "ok", "sweep.json").build(), false, "t2")
        .unwrap();
    js.run_until_idle(&mut s).unwrap();
    js.shutdown_fleet(&mut s).unwrap();
    assert_eq!(js.queue.get(bad).unwrap().state, JobState::Failed);
    for id in [child, grandchild] {
        let j = js.queue.get(id).unwrap();
        assert_eq!(j.state, JobState::Failed, "{id} must be cancelled");
        assert!(
            j.summary.to_string_compact().contains("ancestor job-1 failed"),
            "{id} summary must name the failed ancestor: {}",
            j.summary.to_string_compact()
        );
        assert_eq!(j.compute_s, 0.0, "{id} never ran, so no compute may be billed");
        assert_eq!(j.progress, 0.0);
    }
    assert_eq!(js.dag_cancels, 2);
    assert_eq!(js.dag_releases, 0, "nothing downstream of a failure is released");
    // The unrelated job is untouched and actually did the work.
    let j = js.queue.get(solo).unwrap();
    assert_eq!(j.state, JobState::Completed);
    assert!(j.compute_s > 0.0);
}

#[test]
fn property_deadline_backprop_never_leaves_a_parent_looser_than_its_child() {
    quickprop::check("dag deadline back-propagation", 40, |g| {
        let mut s = session();
        sweep_project(&mut s, "p", 24, 7);
        let mut js = sched();
        let n = g.u64(3..9) as usize;
        let mut ids: Vec<JobId> = Vec::new();
        for i in 0..n {
            let mut deps: Vec<JobId> = Vec::new();
            for &prev in &ids {
                if g.u64(0..3) == 0 {
                    deps.push(prev);
                }
            }
            // The sink carries the only explicit deadline; everything
            // upstream must inherit one at least as tight.
            let deadline = if i == n - 1 {
                Some(1.0e7 + g.u64(0..1000) as f64)
            } else {
                None
            };
            let id = js
                .admit(
                    &s,
                    JobSpecBuilder::new(&format!("j{i}"), "p", "sweep.json")
                        .after(deps.iter().copied())
                        .deadline(deadline)
                        .build(),
                    false,
                    "",
                )
                .unwrap();
            ids.push(id);
        }
        // Invariant: a live parent's effective deadline is never later
        // than any deadlined child's.
        let jobs: Vec<_> = js.queue.jobs().collect();
        for j in &jobs {
            let Some(d) = j.spec.deadline_s else { continue };
            for p in &j.spec.deps {
                let parent = js.queue.get(*p).unwrap();
                if matches!(parent.state, JobState::Completed | JobState::Failed) {
                    continue;
                }
                let pd = parent
                    .spec
                    .deadline_s
                    .unwrap_or_else(|| panic!("parent {p} of deadlined {} has none", j.id));
                assert!(
                    pd <= d,
                    "parent {p} deadline {pd} is looser than child {} deadline {d}",
                    j.id
                );
            }
        }
    });
}

#[test]
fn specfile_pipeline_runs_and_results_fetch_from_s3() {
    let dir = std::env::temp_dir().join(format!("p2rac-dag-wf-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("wf.json");
    std::fs::write(
        &path,
        r#"{"projectdir":"pipe","stages":[
            {"name":"prep","rscript":"sweep.json"},
            {"name":"s1","rscript":"sweep.json","after":["prep"]},
            {"name":"s2","rscript":"sweep.json","after":["prep"]},
            {"name":"agg","rscript":"sweep.json","after":["s1","s2"],"deadline":"10000000"}]}"#,
    )
    .unwrap();
    let mut s = session();
    sweep_project(&mut s, "pipe", 24, 7);
    let mut js = sched();
    let out = run_cli(
        &mut s,
        &mut js,
        "ec2submitjob",
        &["-specfile", path.to_str().unwrap()],
    )
    .unwrap();
    assert!(out.contains("4 stage(s) admitted"), "{out}");
    run_cli(&mut s, &mut js, "ec2jobqueue", &["-drain"]).unwrap();
    assert!(js.queue.all_done());
    assert!(js.dag_dedup_skips + js.dag_releases > 0);
    // prep has dependents, so its outputs were published to the
    // results bucket under job-1/…
    assert!(!s.cloud.s3.list(RESULTS_BUCKET, "job-1/").is_empty());
    // …and the Analyst can pull them over the WAN.
    let spec = registry().into_iter().find(|c| c.name == "ec2getresults").unwrap();
    let p = spec
        .parse(
            ["-froms3", "-jobid", "1", "-projectdir", "pipe", "-runname", "fetched"]
                .map(String::from),
        )
        .unwrap();
    let out = apply(&mut s, "ec2getresults", &p).unwrap();
    assert!(out.contains("fetched"), "{out}");
    assert!(out.contains(RESULTS_BUCKET), "{out}");
    assert!(s.analyst.exists("pipe_results/fetched/summary.json"));
    // A fetch for a stage with no published outputs is a clean error.
    let p = spec
        .parse(["-froms3", "-jobid", "4", "-runname", "x"].map(String::from))
        .unwrap();
    let err = apply(&mut s, "ec2getresults", &p).unwrap_err().to_string();
    assert!(err.contains("no objects"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}
