//! Acceptance tests for the cloud-side storage plane (ISSUE 3): a
//! spot-interrupted job with cluster-resident checkpoints resumes over
//! the LAN from a snapshot-backed volume, bit-identical to both the
//! WAN-resume path and an uninterrupted run, while paying strictly
//! less metered WAN transfer; restore edge cases (different-size
//! replacement cluster, stale checkpoint after a mid-job edit) behave
//! cleanly; idle spot capacity is visible to interruptions and the
//! autoscaler replaces it; and the ledger can be filtered per analyst.

use p2rac::analytics::pool::WorkerPool;
use p2rac::analytics::CatBondData;
use p2rac::coordinator::{CreateClusterOpts, MockEngine, Session};
use p2rac::jobs::{
    files_digest, AutoscalerConfig, FleetCluster, JobScheduler, JobSpec, JobSpecBuilder, JobState,
    JobWork,
};
use p2rac::simcloud::{SimParams, Vfs};

fn session() -> Session {
    Session::new(SimParams::default(), Box::new(MockEngine::new(10.0)))
}

/// A CATopt project whose generations take ~20 virtual minutes
/// (candidate_cost_s), so a 4-generation job spans the first hour
/// boundary and a spike-every-hour spot market reclaims it mid-run —
/// after at least one checkpoint has been committed.
fn write_long_catopt(s: &mut Session, dir: &str, seed: u64) {
    let data = CatBondData::generate(7, 24, 96);
    for (name, bytes) in data.to_files() {
        s.analyst.write(&format!("{dir}/{name}"), bytes);
    }
    s.analyst.write(
        &format!("{dir}/catopt.json"),
        format!(
            r#"{{"type":"catopt","pop_size":12,"max_generations":4,"seed":{seed},"bfgs_every":0,"candidate_cost_s":600.0}}"#
        )
        .into_bytes(),
    );
}

fn spec(name: &str, dir: &str, script: &str) -> JobSpec {
    JobSpecBuilder::new(name, dir, script).build()
}

fn results_of(s: &Session, dir: &str) -> Vec<(String, Vec<u8>)> {
    let mut files: Vec<(String, Vec<u8>)> = s
        .analyst
        .list_dir(dir)
        .into_iter()
        .map(|rel| {
            let bytes = s.analyst.read(&format!("{dir}/{rel}")).unwrap().to_vec();
            (rel, bytes)
        })
        .collect();
    files.sort();
    files
}

fn wan_transfer_cc(s: &Session) -> u64 {
    s.cloud.ledger.total_wan_transfer_centi_cents()
}

/// Run the long CATopt job on a one-cluster fleet. `interruptible`
/// buys spot capacity under a spike-every-hour market (bid = on-demand
/// rate), so the cluster is reclaimed at hour boundaries while the job
/// runs; `false` is the uninterrupted on-demand ground truth.
fn run_resume_scenario(resident: bool, interruptible: bool) -> (Session, JobScheduler, u64) {
    let mut s = session();
    s.cloud.spot.spike_prob = if interruptible { 1.0 } else { 0.0 };
    write_long_catopt(&mut s, "proj", 42);
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 1,
        max_clusters: 1,
        nodes_per_cluster: 2,
        spot: interruptible,
        ..Default::default()
    });
    js.slice_units = 1;
    let id = js.submit_opts(&s, spec("r", "proj", "catopt.json"), resident, "tenant");
    js.run_until_idle(&mut s).unwrap();
    let job = js.queue.get(id).unwrap();
    assert_eq!(job.state, JobState::Completed, "resident={resident}");
    let digest = files_digest(&results_of(&s, "proj_results/r"));
    (s, js, digest)
}

#[test]
fn resident_resume_pays_lan_not_wan_and_stays_bit_identical() {
    let (_truth_s, truth_js, truth_digest) = run_resume_scenario(false, false);
    assert_eq!(truth_js.interruptions_delivered, 0);

    let (wan_s, wan_js, wan_digest) = run_resume_scenario(false, true);
    let (res_s, res_js, res_digest) = run_resume_scenario(true, true);
    assert!(wan_js.interruptions_delivered >= 1, "baseline must be reclaimed");
    assert!(res_js.interruptions_delivered >= 1, "resident must be reclaimed");

    // Bit-identity across all three capacity histories.
    assert_eq!(wan_digest, truth_digest, "WAN resume diverged");
    assert_eq!(res_digest, truth_digest, "LAN resume diverged");

    // The resident job's resume paid LAN: strictly fewer metered WAN
    // centi-cents (no checkpoint shipments, no project re-sync).
    assert!(
        wan_transfer_cc(&res_s) < wan_transfer_cc(&wan_s),
        "resident WAN bill ({}cc) must undercut the baseline ({}cc)",
        wan_transfer_cc(&res_s),
        wan_transfer_cc(&wan_s)
    );

    // The resident machinery actually ran: checkpoints were mirrored
    // to S3 and EBS snapshots were created and later retired (their
    // storage billed).
    let items = res_s.cloud.ledger.items();
    assert!(items.iter().any(|it| it.detail == "S3 PUT request"));
    assert!(items.iter().any(|it| it.detail.starts_with("snapshot ")));
    // Completed job's cluster-side artifacts are cleaned up.
    assert!(res_s.cloud.s3.get("p2rac-checkpoints", "job-1").is_none());
}

#[test]
fn restore_from_snapshot_onto_a_different_size_cluster() {
    let (_s, _js, truth_digest) = run_resume_scenario(false, false);

    let mut s = session();
    s.cloud.spot.spike_prob = 1.0;
    write_long_catopt(&mut s, "proj", 42);
    // Replacement fleet clusters will have 3 nodes…
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 1,
        max_clusters: 1,
        nodes_per_cluster: 3,
        spot: true,
        ..Default::default()
    });
    js.slice_units = 1;
    // …but the job starts on an adopted 2-node spot cluster.
    s.create_cluster(&CreateClusterOpts {
        cname: Some("small".into()),
        csize: Some(2),
        spot: true,
        ..Default::default()
    })
    .unwrap();
    js.fleet.push(FleetCluster {
        name: "small".into(),
        running: None,
        spot: true,
    });
    let id = js.submit_opts(&s, spec("r", "proj", "catopt.json"), true, "");
    js.run_until_idle(&mut s).unwrap();

    assert!(js.interruptions_delivered >= 1, "the 2-node cluster must be reclaimed");
    assert!(s.clusters_cfg.get("small").is_none(), "reclaimed cluster is gone");
    // The replacement the job resumed on has a different shape.
    let replacement = s
        .clusters_cfg
        .names()
        .into_iter()
        .find(|n| n.starts_with("fleet"))
        .expect("autoscaler created a replacement");
    assert_eq!(s.clusters_cfg.get(&replacement).unwrap().size, 3);
    let job = js.queue.get(id).unwrap();
    assert_eq!(job.state, JobState::Completed);
    assert_eq!(
        files_digest(&results_of(&s, "proj_results/r")),
        truth_digest,
        "restore onto a different-size cluster must stay bit-identical"
    );
}

#[test]
fn stale_checkpoint_after_mid_job_edit_fails_cleanly() {
    let mut s = session();
    s.analyst.write(
        "proj/sweep.json",
        br#"{"type":"mc_sweep","n_jobs":24,"seed":21}"#.to_vec(),
    );
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 1,
        max_clusters: 1,
        ..Default::default()
    });
    let id = js.submit(&s, spec("r", "proj", "sweep.json"));
    // A checkpoint taken against a different sweep configuration — what
    // a mid-job script edit leaves behind.
    let stale = {
        let mut v = Vfs::new();
        v.write(
            "proj/sweep.json",
            br#"{"type":"mc_sweep","n_jobs":24,"seed":99}"#.to_vec(),
        );
        let pool = WorkerPool::serial();
        JobWork::from_project(&v, "proj", "sweep.json", None, &pool)
            .unwrap()
            .snapshot()
    };
    js.queue.get_mut(id).unwrap().checkpoint = Some(stale);
    js.run_until_idle(&mut s).unwrap();
    let job = js.queue.get(id).unwrap();
    assert_eq!(job.state, JobState::Failed, "stale checkpoint must fail, not corrupt");
    let msg = job.summary.as_str().unwrap_or_default().to_string();
    assert!(msg.contains("edited mid-job"), "diagnostic missing: {msg}");
}

#[test]
fn idle_spot_capacity_is_reclaimed_and_replaced() {
    let mut s = session();
    s.cloud.spot.spike_prob = 1.0;
    write_long_catopt(&mut s, "proj", 7);
    // Fleet floor of 2: one cluster works the single job, one sits
    // idle. The price spike at the hour boundary must reclaim both —
    // idle capacity is not invisible — and the autoscaler must replace
    // the loss.
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 2,
        max_clusters: 2,
        nodes_per_cluster: 2,
        spot: true,
        ..Default::default()
    });
    js.slice_units = 1;
    let id = js.submit(&s, spec("r", "proj", "catopt.json"));
    js.run_until_idle(&mut s).unwrap();

    assert_eq!(js.queue.get(id).unwrap().state, JobState::Completed);
    assert!(
        js.interruptions_delivered >= 2,
        "busy AND idle clusters must be reclaimed, got {}",
        js.interruptions_delivered
    );
    assert!(
        js.log.iter().any(|l| l.contains("idle cluster")),
        "an idle-capacity reclaim must be delivered: {:?}",
        js.log
    );
    let scale_ups = js
        .autoscaler
        .events
        .iter()
        .filter(|e| e.action.contains("scale-up"))
        .count();
    assert!(
        scale_ups >= 3,
        "autoscaler must replace reclaimed capacity (2 initial + replacements), got {scale_ups}"
    );
}

#[test]
fn ledger_filters_per_analyst() {
    let mut s = session();
    s.cloud.spot.spike_prob = 0.0;
    s.analyst.write(
        "pa/sweep.json",
        br#"{"type":"mc_sweep","n_jobs":24,"seed":1}"#.to_vec(),
    );
    s.analyst.write(
        "pb/sweep.json",
        br#"{"type":"mc_sweep","n_jobs":24,"seed":2}"#.to_vec(),
    );
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 1,
        max_clusters: 2,
        ..Default::default()
    });
    js.submit_opts(&s, spec("ra", "pa", "sweep.json"), false, "alice");
    js.submit_opts(&s, spec("rb", "pb", "sweep.json"), true, "bob");
    js.run_until_idle(&mut s).unwrap();
    js.shutdown_fleet(&mut s).unwrap();

    let l = &s.cloud.ledger;
    let alice = l.total_centi_cents_for("alice");
    let bob = l.total_centi_cents_for("bob");
    let platform = l.total_centi_cents_for("");
    assert!(alice > 0, "alice's job traffic must be attributed");
    assert!(bob > 0, "bob's job traffic must be attributed");
    assert!(platform > 0, "fleet infrastructure stays on the platform bill");
    assert_eq!(alice + bob + platform, l.total_centi_cents());
    assert_eq!(
        l.analysts(),
        vec!["alice".to_string(), "bob".to_string()],
        "both tenants appear in the ledger"
    );
}
