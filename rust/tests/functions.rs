//! Serverless-tier hardening (ISSUE 9 satellites a+b): a quickprop
//! property over arbitrary seeded invocation sequences — no keepalive
//! policy ever evicts a container mid-invocation and pool accounting
//! conserves containers exactly — plus crash-point coverage for the
//! functions append-log (kill mid-append, kill mid-compaction, legacy
//! `functions.json` load), each restoring bit-identically to a clean
//! save, mirroring `tests/persistence.rs`.

use p2rac::coordinator::{MockEngine, Session};
use p2rac::jobs::functions::persist::{self, log_path, snapshot_path, LOG_COMPACT_RECORDS};
use p2rac::jobs::{FnInvokeSpec, FnPlatform, KeepalivePolicy, QuotaBook};
use p2rac::simcloud::SimParams;
use p2rac::util::quickprop;
use std::fs;
use std::path::{Path, PathBuf};

fn session() -> Session {
    Session::new(SimParams::default(), Box::new(MockEngine::new(100.0)))
}

fn spec(tenant: &str, fname: &str, digest: u64, duration_ms: u64) -> FnInvokeSpec {
    FnInvokeSpec {
        fname: fname.to_string(),
        tenant: tenant.to_string(),
        digest,
        bytes: 2 * 1024 * 1024,
        mem_mb: 512,
        duration_ms,
    }
}

// ---------------------------------------------------------------------
// Satellite a: property tests.
// ---------------------------------------------------------------------

/// Under ANY seeded invocation sequence, policy and idle budget:
/// a container that is mid-invocation is never evicted (it is still
/// pooled, still busy, until its completion time passes), and
/// containers are conserved exactly — everything ever provisioned is
/// either still pooled or counted evicted, at every step.
#[test]
fn no_policy_evicts_mid_invocation_and_containers_conserve() {
    quickprop::check("fn pool safety", 30, |g| {
        let mut s = session();
        let policy = if g.bool() {
            KeepalivePolicy::Fixed(g.f64(30.0, 2400.0))
        } else {
            KeepalivePolicy::Hybrid { default_s: g.f64(60.0, 1200.0) }
        };
        let mut p = FnPlatform::new(policy);
        // Sometimes a tight idle budget, so pressure evictions fire too.
        p.autoscaler.max_idle_mb = *g.pick(&[0u64, 512, 1024, 65_536]);
        let quotas = QuotaBook::default();
        let n_fns = g.usize(1..5);
        let steps = g.usize(10..60);
        for _ in 0..steps {
            let fi = g.usize(0..n_fns);
            let sp = spec(
                &format!("t{}", fi % 2),
                &format!("f{fi}"),
                fi as u64 + 1,
                g.u64(50..8_000),
            );
            // Every container mid-invocation right now, before the step.
            let busy_before: Vec<(u64, f64)> = p
                .pool
                .values()
                .filter(|c| c.busy)
                .map(|c| (c.id, c.busy_until_s))
                .collect();
            p.invoke(&mut s, &quotas, &sp).unwrap();
            let now = s.cloud.clock.now_s();
            for (id, until) in busy_before {
                if until > now {
                    let c = p
                        .pool
                        .get(&id)
                        .unwrap_or_else(|| panic!("container c-{id} evicted mid-invocation"));
                    assert!(c.busy, "c-{id} marked idle before its invocation completed");
                }
            }
            assert!(
                p.conserved(),
                "conservation broken: provisioned {} != pool {} + evicted {}",
                p.provisioned_total,
                p.pool.len(),
                p.evicted_total
            );
            s.cloud.clock.advance(g.f64(0.0, 900.0));
        }
        // Drain + flush: afterwards nothing is left and the books
        // still balance.
        p.drain(&mut s, &quotas);
        p.flush(&mut s);
        assert_eq!(p.pool.len(), 0, "drain + flush must empty the pool");
        assert!(p.conserved());
        assert_eq!(p.provisioned_total, p.evicted_total);
    });
}

/// Same-seed sequences are bit-identical: dispatch digest, bill and
/// pool counters all match across two independent runs.
#[test]
fn same_seed_invocation_sequences_are_bit_identical() {
    let run = || {
        let mut s = session();
        let mut p = FnPlatform::new(KeepalivePolicy::Hybrid { default_s: 300.0 });
        let quotas = QuotaBook::default();
        for i in 0..40u64 {
            let sp = spec(
                if i % 3 == 0 { "alice" } else { "bob" },
                &format!("f{}", i % 4),
                (i % 4) + 1,
                100 + (i * 37) % 2_000,
            );
            p.invoke(&mut s, &quotas, &sp).unwrap();
            s.cloud.clock.advance(((i * 131) % 700) as f64);
        }
        p.drain(&mut s, &quotas);
        p.flush(&mut s);
        (
            p.dispatch_digest(),
            s.cloud.ledger.total_centi_cents(),
            p.to_json().to_string_compact(),
        )
    };
    let (d1, b1, j1) = run();
    let (d2, b2, j2) = run();
    assert_eq!(d1, d2, "dispatch digest must be deterministic");
    assert_eq!(b1, b2, "bill must be deterministic");
    assert_eq!(j1, j2, "platform state must be deterministic");
}

// ---------------------------------------------------------------------
// Satellite b: crash-point persistence, mirroring tests/persistence.rs.
// ---------------------------------------------------------------------

/// A scratch directory unique to this test run; recreated empty.
fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("p2rac_fns_{}_{}", name, std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

/// Run a deterministic workload on `p`: two tenants, three functions,
/// warm hits and live pooled containers — so replay covers histograms,
/// counters and the pool, not just inserts.
fn drive(s: &mut Session, p: &mut FnPlatform, rounds: u64, offset: u64) {
    let quotas = QuotaBook::default();
    for i in 0..rounds {
        let k = (i + offset) % 3;
        let tenant = if k == 0 { "alice" } else { "bob" };
        let sp = spec(tenant, &format!("f{k}"), k + 1, 300 + 40 * k);
        p.invoke(s, &quotas, &sp).unwrap();
        s.cloud.clock.advance(200.0 + 30.0 * (i % 5) as f64);
    }
    p.settle(s, &quotas);
}

/// Load `dir` and render the restored state canonically.
fn load_compact(dir: &Path) -> String {
    persist::load(dir)
        .unwrap()
        .expect("functions state must load")
        .to_json()
        .to_string_compact()
}

/// A clean save of `p` into a fresh directory (first save = full
/// snapshot), loaded back — the reference every crash state must
/// match bit for bit.
fn clean_reference(name: &str, p: &mut FnPlatform) -> String {
    let dir = scratch(name);
    persist::save(&dir, p).unwrap();
    load_compact(&dir)
}

#[test]
fn legacy_functions_json_loads_as_a_snapshot_with_an_empty_log() {
    let dir = scratch("legacy");
    let mut s = session();
    let mut p = FnPlatform::default();
    drive(&mut s, &mut p, 8, 0);
    // A pre-append-log directory: the full document under
    // functions.json, no functions.log beside it.
    fs::write(snapshot_path(&dir), p.to_json().to_string_pretty()).unwrap();
    assert!(!log_path(&dir).exists());
    let restored = load_compact(&dir);
    assert_eq!(
        restored,
        clean_reference("legacy_ref", &mut p),
        "a legacy functions.json must restore bit-identically to a clean save"
    );
}

#[test]
fn append_log_replay_is_bit_identical_to_a_clean_save() {
    let dir = scratch("append");
    let mut s = session();
    let mut p = FnPlatform::default();
    drive(&mut s, &mut p, 6, 0);
    persist::save(&dir, &mut p).unwrap(); // snapshot
    drive(&mut s, &mut p, 6, 1);
    persist::save(&dir, &mut p).unwrap(); // one O(delta) log record
    assert!(log_path(&dir).exists(), "the second save must append, not rewrite");
    let snapshot_before = fs::read_to_string(snapshot_path(&dir)).unwrap();
    let restored = load_compact(&dir);
    assert_eq!(restored, clean_reference("append_ref", &mut p));
    // The snapshot itself was untouched by the append.
    assert_eq!(fs::read_to_string(snapshot_path(&dir)).unwrap(), snapshot_before);
}

#[test]
fn kill_mid_append_discards_the_torn_tail() {
    let dir = scratch("torn");
    let mut s = session();
    let mut p = FnPlatform::default();
    drive(&mut s, &mut p, 6, 0);
    persist::save(&dir, &mut p).unwrap();
    drive(&mut s, &mut p, 6, 1);
    persist::save(&dir, &mut p).unwrap();
    // The crash: a later append died partway through its write. Torn
    // bytes of a would-be record sit at the end of the log.
    let log = fs::read_to_string(log_path(&dir)).unwrap();
    let full_line = log.lines().next().unwrap();
    let torn = &full_line[..full_line.len() / 2];
    fs::write(log_path(&dir), format!("{log}{torn}")).unwrap();
    // Replay stops at the torn record: the state of the last
    // *successful* save is restored exactly.
    let restored = load_compact(&dir);
    assert_eq!(
        restored,
        clean_reference("torn_ref", &mut p),
        "a torn tail must roll back to the previous successful save"
    );
}

#[test]
fn kill_mid_compaction_replays_the_stale_log_idempotently() {
    let dir = scratch("compact_crash");
    let mut s = session();
    let mut p = FnPlatform::default();
    drive(&mut s, &mut p, 6, 0);
    persist::save(&dir, &mut p).unwrap();
    drive(&mut s, &mut p, 6, 1);
    persist::save(&dir, &mut p).unwrap();
    assert!(log_path(&dir).exists());
    // The crash: compaction renamed the fresh full snapshot into place
    // and died before unlinking the log. Every log record's effects
    // are already inside the snapshot.
    fs::write(snapshot_path(&dir), p.to_json().to_string_pretty()).unwrap();
    let restored = load_compact(&dir);
    assert_eq!(
        restored,
        clean_reference("compact_crash_ref", &mut p),
        "replaying a stale log over a fresh snapshot must be a no-op"
    );
}

#[test]
fn compaction_folds_the_log_back_into_a_single_snapshot() {
    let dir = scratch("compact");
    let mut s = session();
    let mut p = FnPlatform::default();
    drive(&mut s, &mut p, 4, 0);
    persist::save(&dir, &mut p).unwrap();
    // Enough O(delta) saves to cross the compaction threshold.
    for i in 0..LOG_COMPACT_RECORDS as u64 {
        drive(&mut s, &mut p, 1, i);
        persist::save(&dir, &mut p).unwrap();
    }
    assert!(
        !log_path(&dir).exists(),
        "reaching {LOG_COMPACT_RECORDS} records must compact the log away"
    );
    let restored = load_compact(&dir);
    assert_eq!(
        restored,
        clean_reference("compact_ref", &mut p),
        "the compacted snapshot must carry the whole backlog"
    );
}
