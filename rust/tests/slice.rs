//! Acceptance tests for the slice fast path (ISSUE 8): a spot reclaim
//! mid-slice evicts the warm work cache and the job still resumes
//! bit-identically over both the WAN and LAN (resident) paths; the
//! incremental checkpoint chain — full snapshots every K slices,
//! O(slice) delta links between — restores through compaction; and a
//! finishing slice ships no checkpoint at all (its result files land
//! in the same slice and carry the whole state).

use p2rac::coordinator::{MockEngine, Session};
use p2rac::jobs::{files_digest, AutoscalerConfig, JobScheduler, JobSpec, JobSpecBuilder, JobState};
use p2rac::simcloud::SimParams;

fn session() -> Session {
    Session::new(SimParams::default(), Box::new(MockEngine::new(10.0)))
}

/// A sweep wide enough for four slices at the 64-job tile (200 jobs)
/// whose batches take ~30 virtual minutes each (`job_cost_s`), so the
/// job spans hour boundaries and a spike-every-hour spot market
/// reclaims it mid-run — after delta links have been committed.
fn write_long_sweep(s: &mut Session, dir: &str, seed: u64) {
    s.analyst.write(
        &format!("{dir}/sweep.json"),
        format!(r#"{{"type":"mc_sweep","n_jobs":200,"seed":{seed},"job_cost_s":200.0}}"#)
            .into_bytes(),
    );
}

fn spec(name: &str, dir: &str) -> JobSpec {
    JobSpecBuilder::new(name, dir, "sweep.json").build()
}

fn results_of(s: &Session, dir: &str) -> Vec<(String, Vec<u8>)> {
    let mut files: Vec<(String, Vec<u8>)> = s
        .analyst
        .list_dir(dir)
        .into_iter()
        .map(|rel| {
            let bytes = s.analyst.read(&format!("{dir}/{rel}")).unwrap().to_vec();
            (rel, bytes)
        })
        .collect();
    files.sort();
    files
}

fn wan_transfer_cc(s: &Session) -> u64 {
    s.cloud.ledger.total_wan_transfer_centi_cents()
}

/// Run the long sweep on a one-cluster fleet. `interruptible` buys
/// spot capacity under a spike-every-hour market, so the cluster is
/// reclaimed at hour boundaries while the job runs; `false` is the
/// uninterrupted on-demand ground truth. `ckpt_full_every` sets the
/// chain's compaction cadence.
fn run_scenario(
    resident: bool,
    interruptible: bool,
    ckpt_full_every: usize,
) -> (Session, JobScheduler, u64) {
    let mut s = session();
    s.cloud.spot.spike_prob = if interruptible { 1.0 } else { 0.0 };
    write_long_sweep(&mut s, "proj", 23);
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 1,
        max_clusters: 1,
        nodes_per_cluster: 2,
        spot: interruptible,
        ..Default::default()
    });
    js.slice_units = 1;
    js.ckpt_full_every = ckpt_full_every;
    let id = js.submit_opts(&s, spec("r", "proj"), resident, "tenant");
    js.run_until_idle(&mut s).unwrap();
    let job = js.queue.get(id).unwrap();
    assert_eq!(job.state, JobState::Completed, "resident={resident}");
    let digest = files_digest(&results_of(&s, "proj_results/r"));
    (s, js, digest)
}

#[test]
fn reclaim_mid_slice_evicts_the_cache_and_resumes_bit_identically() {
    let (_, truth_js, truth_digest) = run_scenario(false, false, 8);
    assert_eq!(truth_js.interruptions_delivered, 0);
    // The uninterrupted run lives on the fast path throughout: every
    // re-dispatch after the first hits the warm cache, and every
    // continuing commit after the first extends the delta chain.
    assert!(truth_js.work_cache_hits > 0, "consecutive slices must hit");
    assert!(truth_js.ckpt_delta_commits > 0, "the chain must ship deltas");
    assert_eq!(truth_js.work_cache_evictions, 0);

    let (wan_s, wan_js, wan_digest) = run_scenario(false, true, 8);
    let (res_s, res_js, res_digest) = run_scenario(true, true, 8);
    assert!(wan_js.interruptions_delivered >= 1, "baseline must be reclaimed");
    assert!(res_js.interruptions_delivered >= 1, "resident must be reclaimed");

    // A reclaim tears down the cluster the warm state was built for:
    // the in-flight entry is dropped with its slice.
    assert!(wan_js.work_cache_evictions >= 1, "reclaim must evict warm state");
    assert!(res_js.work_cache_evictions >= 1, "reclaim must evict warm state");

    // Bit-identity across all three capacity histories — the cache
    // and chain machinery must be invisible in the numbers.
    assert_eq!(wan_digest, truth_digest, "WAN resume diverged");
    assert_eq!(res_digest, truth_digest, "LAN resume diverged");

    // The resident path still pays LAN, not WAN, for its commits.
    assert!(
        wan_transfer_cc(&res_s) < wan_transfer_cc(&wan_s),
        "resident WAN bill ({}cc) must undercut the baseline ({}cc)",
        wan_transfer_cc(&res_s),
        wan_transfer_cc(&wan_s)
    );
}

#[test]
fn delta_chain_restores_through_compaction_after_a_reclaim() {
    let (_, truth_js, truth_digest) = run_scenario(false, false, 2);
    // Compaction every 2 slices: the chain alternates full and delta
    // commits, so both forms exercise.
    assert!(truth_js.ckpt_full_commits >= 2, "compaction must re-base the chain");
    assert!(truth_js.ckpt_delta_commits >= 1, "links must extend the chain");

    // The resident reclaim scenario restores from the EBS snapshot by
    // replaying whatever the chain holds at the cut — a base alone
    // right after compaction, base + delta links otherwise — and the
    // result bytes cannot tell the difference.
    let (res_s, res_js, res_digest) = run_scenario(true, true, 2);
    assert!(res_js.interruptions_delivered >= 1, "must be reclaimed");
    assert_eq!(res_digest, truth_digest, "chain restore diverged");

    // The chain artifacts really lived cluster-side: snapshot storage
    // was billed when the job retired them.
    let snap_items = res_s
        .cloud
        .ledger
        .items()
        .iter()
        .filter(|i| i.detail.contains("snapshot"))
        .count();
    assert!(snap_items > 0, "EBS snapshot storage must be billed");
}

#[test]
fn finishing_slices_ship_no_checkpoint() {
    let mut s = session();
    // 40 MC jobs at the 64-job tile: one batch, one slice — the only
    // slice is the finishing slice.
    s.analyst.write(
        "proj/sweep.json",
        br#"{"type":"mc_sweep","n_jobs":40,"seed":3}"#.to_vec(),
    );
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 1,
        max_clusters: 1,
        ..Default::default()
    });
    let id = js.submit(&s, spec("r", "proj"));
    js.run_until_idle(&mut s).unwrap();
    assert_eq!(js.queue.get(id).unwrap().state, JobState::Completed);
    assert_eq!(js.ckpt_bytes_shipped, 0, "a finishing slice must ship nothing");
    assert_eq!(js.ckpt_full_commits + js.ckpt_delta_commits, 0);
    let ship_items = s
        .cloud
        .ledger
        .items()
        .iter()
        .filter(|i| i.detail.contains("checkpoint ship"))
        .count();
    assert_eq!(ship_items, 0, "no checkpoint transfer may be billed");
}
