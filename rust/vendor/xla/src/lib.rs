//! Offline stub of the PJRT/XLA binding surface `p2rac::runtime::pjrt`
//! compiles against.
//!
//! The build environment does not ship the real `xla` crate (it links
//! libxla / a PJRT plugin), so this stub provides the exact API shape
//! with every entry point failing at **runtime**: `PjRtClient::cpu()`
//! returns [`Error::Unavailable`], which `Runtime::load` surfaces and
//! the engine factory catches to fall back to the pure-Rust backends.
//! The PJRT unit/integration tests already skip themselves when
//! `artifacts/manifest.json` is absent, so the stub never executes on
//! the test path.
//!
//! To light up the real L1/L2 artifact path, point the `xla` path
//! dependency in `rust/Cargo.toml` at the actual binding crate — the
//! types and signatures here mirror it 1:1 for the subset p2rac uses.
//! All stub types are plain data, so `Runtime` stays `Send + Sync`
//! (which the analytics worker pool requires; the static assertion in
//! `runtime/pjrt.rs` pins that bound). A real binding whose client or
//! executable handles are not thread-safe needs a thread-safety
//! wrapper there — or a serial-only `PjrtBackend` — before the swap
//! compiles.

use std::fmt;
use std::path::Path;

/// Stub error: every operation reports the binding is unavailable.
#[derive(Clone, Debug)]
pub enum Error {
    /// The real XLA/PJRT binding is not linked into this build.
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => {
                write!(f, "{what}: XLA/PJRT binding not available in this build (offline stub)")
            }
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host-side literal (dense array) crossing the PJRT boundary.
#[derive(Clone, Debug)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal over an f32 slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal {
            data: data.to_vec(),
            dims: vec![data.len() as i64],
        }
    }

    /// Reshape; fails if the element count does not match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error::Unavailable("Literal::reshape element-count mismatch"));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Unpack a tuple literal. The stub never produces tuples.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("Literal::to_tuple"))
    }

    /// Copy out as a typed host vector (stub supports f32 only).
    pub fn to_vec<T: FromLiteralElem>(&self) -> Result<Vec<T>> {
        T::from_f32_slice(&self.data)
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Element types extractable from a stub literal.
pub trait FromLiteralElem: Sized {
    fn from_f32_slice(data: &[f32]) -> Result<Vec<Self>>;
}

impl FromLiteralElem for f32 {
    fn from_f32_slice(data: &[f32]) -> Result<Vec<f32>> {
        Ok(data.to_vec())
    }
}

/// Parsed HLO module proto.
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation ready to compile.
#[derive(Clone, Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-resident buffer returned by an execution.
#[derive(Clone, Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable bound to a client.
#[derive(Clone, Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with borrowed literals; mirrors the real signature
    /// (`args: &[L] where L: Borrow<Literal>`), outputs
    /// `[replica][output]`.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A PJRT client (stub: construction always fails).
#[derive(Clone, Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("offline stub"));
    }

    #[test]
    fn literal_reshape_checks_element_count() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[2, 2]).is_ok());
        assert!(l.reshape(&[3, 2]).is_err());
        assert_eq!(l.to_vec::<f32>().unwrap().len(), 4);
    }
}
