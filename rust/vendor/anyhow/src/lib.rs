//! A vendored, offline subset of the `anyhow` crate API.
//!
//! The P2RAC build environment has no crates.io access, so this crate
//! re-implements exactly the surface the codebase uses:
//!
//! * [`Error`] — a message plus an optional cause chain,
//! * [`Result<T>`] — alias with `Error` as the default error type,
//! * [`anyhow!`], [`bail!`], [`ensure!`] — format-style constructors,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`
//!   and `Option`.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` impl possible (`?` on `io::Error`,
//! `Utf8Error`, `CloudError`, …). `{:#}` prints the full cause chain
//! on one line; `{:?}` prints the anyhow-style "Caused by:" block.

use std::fmt;

/// A dynamic error: a message and an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

/// `Result<T, anyhow::Error>` with the conventional default parameter.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from anything displayable (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error {
            msg: context.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// The innermost error in the chain.
    pub fn root_cause(&self) -> &Error {
        let mut cur = self;
        while let Some(src) = &cur.source {
            cur = src;
        }
        cur
    }

    /// Iterate the chain outermost-first.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }
}

/// Iterator over an error's cause chain.
pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a Error;
    fn next(&mut self) -> Option<&'a Error> {
        let cur = self.next?;
        self.next = cur.source.as_deref();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

// The blanket conversion powering `?`. `Error` itself does not
// implement `std::error::Error`, so this cannot overlap the reflexive
// `From<T> for T` impl — the same trick the real anyhow uses.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        // Preserve the source chain as messages.
        let mut chain = Vec::new();
        chain.push(e.to_string());
        let mut src = std::error::Error::source(&e);
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        let mut err = Error::msg(chain.pop().expect("chain is non-empty"));
        while let Some(msg) = chain.pop() {
            err = err.context(msg);
        }
        err
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e.to_string()).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e.to_string()).context(f()))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string, a displayable value,
/// or a format string with arguments.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Early-return with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(e.to_string().contains("missing"));
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let e: Result<()> = Err(io_err()).context("reading config");
        let e = e.unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing");
        assert_eq!(e.root_cause().to_string(), "missing");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("no value").unwrap_err();
        assert_eq!(e.to_string(), "no value");
    }

    #[test]
    fn macros_compose() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {}", x);
            }
            Err(anyhow!("fallthrough {x}"))
        }
        assert!(f(11).unwrap_err().to_string().contains("too big"));
        assert!(f(7).unwrap_err().to_string().contains("unlucky 7"));
        assert!(f(1).unwrap_err().to_string().contains("fallthrough 1"));
    }

    #[test]
    fn debug_prints_cause_block() {
        let e = Error::msg("inner").context("outer");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer") && dbg.contains("Caused by") && dbg.contains("inner"));
    }
}
