//! Slave-process placement (paper §3.2.2, `ec2runoncluster -bynode |
//! -byslot`).
//!
//! `byslot` is MPI's default: fill every core of node 0, then node 1, …
//! `bynode` (P2RAC's default) round-robins processes across nodes so
//! each process sees the largest memory share — "required to meet the
//! memory constraints of large processes".

/// Compute capability of one node as seen by the scheduler.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeSpec {
    pub name: String,
    pub cores: usize,
    pub mem_gb: f64,
    /// Per-core speed relative to Desktop A = 1.0.
    pub core_speed: f64,
}

impl NodeSpec {
    pub fn power(&self) -> f64 {
        self.cores as f64 * self.core_speed
    }
}

/// Placement policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// Round-robin across nodes (P2RAC default).
    ByNode,
    /// Fill a node's cores before moving on (MPI default).
    BySlot,
}

impl Placement {
    /// Resolve the `-bynode`/`-byslot` switches. bynode is the default
    /// when neither is given (§3.2.2); passing both is a contradiction
    /// and is rejected rather than silently resolved — the old
    /// behaviour picked ByNode, which could mask a memory-infeasible
    /// byslot placement the Analyst explicitly asked to test.
    pub fn parse(bynode: bool, byslot: bool) -> anyhow::Result<Placement> {
        match (bynode, byslot) {
            (true, true) => anyhow::bail!(
                "-bynode and -byslot are mutually exclusive; pick one placement"
            ),
            (false, true) => Ok(Placement::BySlot),
            _ => Ok(Placement::ByNode),
        }
    }
}

/// Assign `nproc` slave processes to nodes; returns the node index of
/// each process. Processes beyond the total core count wrap around
/// (oversubscription), matching MPI slot semantics.
pub fn schedule(nproc: usize, nodes: &[NodeSpec], placement: Placement) -> Vec<usize> {
    assert!(!nodes.is_empty(), "schedule over zero nodes");
    let total_slots: usize = nodes.iter().map(|n| n.cores).sum();
    let mut out = Vec::with_capacity(nproc);
    match placement {
        Placement::ByNode => {
            // Round-robin, skipping nodes whose cores are all taken in
            // the current pass; wraps when all slots are used.
            let mut used = vec![0usize; nodes.len()];
            let mut node = 0usize;
            for p in 0..nproc {
                if p % total_slots == 0 && p > 0 {
                    used.iter_mut().for_each(|u| *u = 0);
                }
                // Advance to next node with free cores this pass.
                let mut hops = 0;
                while used[node] >= nodes[node].cores && hops <= nodes.len() {
                    node = (node + 1) % nodes.len();
                    hops += 1;
                }
                out.push(node);
                used[node] += 1;
                node = (node + 1) % nodes.len();
            }
        }
        Placement::BySlot => {
            for p in 0..nproc {
                let mut slot = p % total_slots;
                let mut node = 0;
                while slot >= nodes[node].cores {
                    slot -= nodes[node].cores;
                    node += 1;
                }
                out.push(node);
            }
        }
    }
    out
}

/// Per-process memory share under an assignment: the binding constraint
/// is the node hosting the most processes relative to its memory.
pub fn min_mem_per_process_gb(assignment: &[usize], nodes: &[NodeSpec]) -> f64 {
    let mut counts = vec![0usize; nodes.len()];
    for &n in assignment {
        counts[n] += 1;
    }
    nodes
        .iter()
        .zip(&counts)
        .filter(|(_, &c)| c > 0)
        .map(|(node, &c)| node.mem_gb / c as f64)
        .fold(f64::INFINITY, f64::min)
}

/// Can `nproc` processes each needing `mem_gb_per_proc` run under this
/// placement?
pub fn feasible(
    nproc: usize,
    mem_gb_per_proc: f64,
    nodes: &[NodeSpec],
    placement: Placement,
) -> bool {
    if nproc == 0 {
        return true;
    }
    let a = schedule(nproc, nodes, placement);
    min_mem_per_process_gb(&a, nodes) >= mem_gb_per_proc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nodes(n: usize, cores: usize, mem: f64) -> Vec<NodeSpec> {
        (0..n)
            .map(|i| NodeSpec {
                name: format!("node{i}"),
                cores,
                mem_gb: mem,
                core_speed: 0.88,
            })
            .collect()
    }

    #[test]
    fn bynode_round_robins() {
        let ns = nodes(4, 4, 34.2);
        let a = schedule(8, &ns, Placement::ByNode);
        assert_eq!(a, vec![0, 1, 2, 3, 0, 1, 2, 3]);
    }

    #[test]
    fn byslot_fills_first_node() {
        let ns = nodes(4, 4, 34.2);
        let a = schedule(8, &ns, Placement::BySlot);
        assert_eq!(a, vec![0, 0, 0, 0, 1, 1, 1, 1]);
    }

    #[test]
    fn default_is_bynode() {
        assert_eq!(Placement::parse(false, false).unwrap(), Placement::ByNode);
        assert_eq!(Placement::parse(true, false).unwrap(), Placement::ByNode);
        assert_eq!(Placement::parse(false, true).unwrap(), Placement::BySlot);
    }

    #[test]
    fn conflicting_placement_flags_rejected() {
        let err = Placement::parse(true, true).unwrap_err();
        assert!(err.to_string().contains("mutually exclusive"));
    }

    #[test]
    fn bynode_gives_more_memory_headroom() {
        // 4 big processes on a 4-node cluster: bynode spreads them
        // (34.2 GB each), byslot stacks them on one node (8.55 GB each).
        let ns = nodes(4, 4, 34.2);
        let by_node = schedule(4, &ns, Placement::ByNode);
        let by_slot = schedule(4, &ns, Placement::BySlot);
        let m_node = min_mem_per_process_gb(&by_node, &ns);
        let m_slot = min_mem_per_process_gb(&by_slot, &ns);
        assert!(m_node > 3.0 * m_slot, "bynode {m_node} vs byslot {m_slot}");
        assert!(feasible(4, 30.0, &ns, Placement::ByNode));
        assert!(!feasible(4, 30.0, &ns, Placement::BySlot));
    }

    #[test]
    fn oversubscription_wraps() {
        let ns = nodes(2, 2, 8.0);
        let a = schedule(6, &ns, Placement::BySlot);
        assert_eq!(a.len(), 6);
        assert_eq!(a, vec![0, 0, 1, 1, 0, 0]);
        let b = schedule(6, &ns, Placement::ByNode);
        assert_eq!(b.len(), 6);
        // Every node is used.
        assert!(b.contains(&0) && b.contains(&1));
    }

    #[test]
    fn property_schedule_covers_all_processes_and_valid_nodes() {
        crate::util::quickprop::check("scheduler validity", 100, |g| {
            let nn = g.usize(1..9);
            let ns: Vec<NodeSpec> = (0..nn)
                .map(|i| NodeSpec {
                    name: format!("n{i}"),
                    cores: g.usize(1..9),
                    mem_gb: g.f64(4.0, 128.0),
                    core_speed: g.f64(0.5, 1.2),
                })
                .collect();
            let nproc = g.usize(1..65);
            for placement in [Placement::ByNode, Placement::BySlot] {
                let a = schedule(nproc, &ns, placement);
                assert_eq!(a.len(), nproc);
                assert!(a.iter().all(|&i| i < nn));
                // Within a full pass no node exceeds its cores.
                let total: usize = ns.iter().map(|n| n.cores).sum();
                let mut counts = vec![0usize; nn];
                for &n in a.iter().take(total.min(nproc)) {
                    counts[n] += 1;
                }
                for (i, &c) in counts.iter().enumerate() {
                    assert!(
                        c <= ns[i].cores,
                        "{placement:?}: node {i} got {c} > {} cores in first pass",
                        ns[i].cores
                    );
                }
            }
        });
    }

    #[test]
    fn property_bynode_never_worse_memory_than_byslot() {
        crate::util::quickprop::check("bynode memory dominance", 60, |g| {
            let nn = g.usize(2..7);
            let ns = nodes(nn, g.usize(1..9), g.f64(8.0, 64.0));
            let nproc = g.usize(1..(nn * 2 + 1));
            let m_node =
                min_mem_per_process_gb(&schedule(nproc, &ns, Placement::ByNode), &ns);
            let m_slot =
                min_mem_per_process_gb(&schedule(nproc, &ns, Placement::BySlot), &ns);
            assert!(
                m_node >= m_slot - 1e-9,
                "nproc={nproc} nodes={nn}: bynode {m_node} < byslot {m_slot}"
            );
        });
    }
}
