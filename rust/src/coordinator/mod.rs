//! The P2RAC coordinator — the paper's platform contribution (§2–§3):
//! resource management, data management and execution management between
//! the Analyst site and the cloud, plus the bynode/byslot scheduler and
//! the script-engine boundary the analytics layer plugs into.

pub mod engine;
pub mod scheduler;
pub mod session;

pub use engine::{MockEngine, ResourceView, ScriptEngine, TaskOutput};
pub use scheduler::{feasible, min_mem_per_process_gb, schedule, NodeSpec, Placement};
pub use session::{
    table1_desktops, CreateClusterOpts, CreateInstanceOpts, DesktopSpec, ResultScope, Session,
};
