//! The P2RAC session: the Analyst-side object every command-line tool
//! operates on. One `Session` owns the simulated cloud, the Analyst
//! workstation filesystem, the four configuration files (paper §3.4)
//! and the script engine, and exposes one method per paper command.

use super::engine::{ResourceView, ScriptEngine, TaskOutput};
use super::scheduler::{self, NodeSpec, Placement};
use crate::config::{
    ClusterEntry, ClustersConfig, InstanceEntry, InstancesConfig, PlatformConfig, RLibsConfig,
    CONFIG_DIR,
};
use crate::datasync::{sync_dir, Protocol, SyncReport, DEFAULT_BLOCK_LEN};
use crate::simcloud::{
    instance_type, CloudError, Lifecycle, Link, SimCloud, SimParams, SpanCategory, Vfs,
};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};

/// Result-gathering scope (paper §3.2.2: the three scenarios).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResultScope {
    FromMaster,
    FromWorkers,
    FromAll,
}

/// A non-cloud resource (paper Table I: Desktop A / Desktop B) on which
/// the same scripts can run for the timing comparison of Fig 5.
#[derive(Clone, Debug)]
pub struct DesktopSpec {
    pub name: String,
    pub cores: usize,
    pub mem_gb: f64,
    pub core_speed: f64,
}

/// The two desktops of Table I.
pub fn table1_desktops() -> Vec<DesktopSpec> {
    vec![
        DesktopSpec {
            name: "Desktop A".into(),
            cores: 8,
            mem_gb: 16.0,
            core_speed: 1.00,
        },
        DesktopSpec {
            name: "Desktop B".into(),
            cores: 6,
            mem_gb: 24.0,
            core_speed: 0.82,
        },
    ]
}

/// Options for `ec2createinstance`.
#[derive(Clone, Debug, Default)]
pub struct CreateInstanceOpts {
    pub iname: Option<String>,
    pub ebsvol: Option<String>,
    pub snap: Option<String>,
    pub itype: Option<String>,
    pub desc: Option<String>,
    /// Request spot capacity (bid = the on-demand rate, the classic
    /// "never outbid, just ride the discount" strategy).
    pub spot: bool,
}

/// Options for `ec2createcluster`.
#[derive(Clone, Debug, Default)]
pub struct CreateClusterOpts {
    pub cname: Option<String>,
    pub csize: Option<usize>,
    pub ebsvol: Option<String>,
    pub snap: Option<String>,
    pub itype: Option<String>,
    pub desc: Option<String>,
    /// Request spot capacity for every node of the cluster.
    pub spot: bool,
}

/// Bid used for `-spot` requests: the on-demand rate in centi-cents.
fn spot_bid(spec: &crate::simcloud::InstanceTypeSpec) -> Lifecycle {
    Lifecycle::Spot {
        bid_centi_cents_hour: spec.price_cents_hour * 100,
    }
}

/// One P2RAC session.
pub struct Session {
    pub cloud: SimCloud,
    /// The Analyst's workstation filesystem (projects + configs).
    pub analyst: Vfs,
    pub platform: PlatformConfig,
    pub instances_cfg: InstancesConfig,
    pub clusters_cfg: ClustersConfig,
    pub rlibs: RLibsConfig,
    /// Real OS threads the analytics engine may use for this
    /// invocation (CLI `-threads`); `None` = host parallelism. A
    /// runtime knob, deliberately not persisted with the session.
    pub threads: Option<usize>,
    engine: Box<dyn ScriptEngine>,
}

fn project_name(projectdir: &str) -> String {
    projectdir
        .trim_end_matches('/')
        .rsplit('/')
        .next()
        .unwrap_or(projectdir)
        .to_string()
}

/// Where a project lands on an instance: "synchronised at the home
/// directory of the root user" (§3.2.1).
fn remote_project_dir(projectdir: &str) -> String {
    format!("root/{}", project_name(projectdir))
}

/// Results directory at the Analyst site: "stored in a directory at the
/// same hierarchical level of the project directory" (§3.2.2).
fn local_results_dir(projectdir: &str) -> String {
    let base = projectdir.trim_end_matches('/');
    match base.rsplit_once('/') {
        Some((parent, name)) => format!("{parent}/{name}_results"),
        None => format!("{base}_results"),
    }
}

impl Session {
    /// Create a session against a fresh simulated cloud. `ec2configurep2rac`
    /// equivalent: seeds the platform config with the cloud's default AMI
    /// and a default snapshot.
    pub fn new(params: SimParams, engine: Box<dyn ScriptEngine>) -> Self {
        let mut cloud = SimCloud::new(params);
        let default_snapshot = cloud.create_snapshot(8.0, Vfs::new(), "p2rac default snapshot");
        let platform = PlatformConfig {
            default_ami: cloud.default_ami(false).id.clone(),
            default_snapshot,
            ..PlatformConfig::default()
        };
        let mut s = Self {
            cloud,
            analyst: Vfs::new(),
            platform,
            instances_cfg: InstancesConfig::default(),
            clusters_cfg: ClustersConfig::default(),
            rlibs: RLibsConfig::default(),
            threads: None,
            engine,
        };
        s.save_configs();
        s
    }

    /// Swap the script engine (used by benches to insert mocks).
    pub fn set_engine(&mut self, engine: Box<dyn ScriptEngine>) {
        self.engine = engine;
    }

    /// Persist the four config files onto the Analyst-site vfs.
    pub fn save_configs(&mut self) {
        self.analyst.write(
            &format!("{CONFIG_DIR}/p2rac.json"),
            self.platform.to_json().to_string_pretty().into_bytes(),
        );
        self.analyst.write(
            &format!("{CONFIG_DIR}/instances.json"),
            self.instances_cfg.to_json().to_string_pretty().into_bytes(),
        );
        self.analyst.write(
            &format!("{CONFIG_DIR}/clusters.json"),
            self.clusters_cfg.to_json().to_string_pretty().into_bytes(),
        );
        self.analyst.write(
            &format!("{CONFIG_DIR}/rlibs.json"),
            self.rlibs.to_json().to_string_pretty().into_bytes(),
        );
    }

    /// Serialize the whole session (cloud + analyst site + configs) for
    /// cross-invocation CLI use.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("cloud", self.cloud.to_json());
        j.set("analyst", self.analyst.to_json());
        j.set("platform", self.platform.to_json());
        j.set("instances", self.instances_cfg.to_json());
        j.set("clusters", self.clusters_cfg.to_json());
        j.set("rlibs", self.rlibs.to_json());
        j
    }

    /// Restore a persisted session with a fresh engine.
    pub fn from_json(
        params: SimParams,
        engine: Box<dyn ScriptEngine>,
        j: &Json,
    ) -> Result<Self> {
        Ok(Self {
            cloud: SimCloud::from_json(
                params,
                j.get("cloud").ok_or_else(|| anyhow!("missing cloud state"))?,
            )?,
            analyst: Vfs::from_json(
                j.get("analyst").ok_or_else(|| anyhow!("missing analyst state"))?,
            )?,
            platform: PlatformConfig::from_json(
                j.get("platform").ok_or_else(|| anyhow!("missing platform"))?,
            )?,
            instances_cfg: InstancesConfig::from_json(
                j.get("instances").ok_or_else(|| anyhow!("missing instances"))?,
            )?,
            clusters_cfg: ClustersConfig::from_json(
                j.get("clusters").ok_or_else(|| anyhow!("missing clusters"))?,
            )?,
            rlibs: RLibsConfig::from_json(
                j.get("rlibs").ok_or_else(|| anyhow!("missing rlibs"))?,
            )?,
            threads: None,
            engine,
        })
    }

    // ===================================================== name resolution

    fn resolve_iname(&self, iname: Option<&str>) -> Result<String> {
        match iname {
            Some(n) => Ok(n.to_string()),
            None => self
                .platform
                .default_instance
                .clone()
                .ok_or_else(|| anyhow!("no -iname given and no default instance configured")),
        }
    }

    fn resolve_cname(&self, cname: Option<&str>) -> Result<String> {
        match cname {
            Some(n) => Ok(n.to_string()),
            None => self
                .platform
                .default_cluster
                .clone()
                .ok_or_else(|| anyhow!("no -cname given and no default cluster configured")),
        }
    }

    fn instance_entry(&self, name: &str) -> Result<&InstanceEntry> {
        self.instances_cfg
            .get(name)
            .ok_or_else(|| anyhow!("no instance named '{name}' in the configuration file"))
    }

    fn cluster_entry(&self, name: &str) -> Result<&ClusterEntry> {
        self.clusters_cfg
            .get(name)
            .ok_or_else(|| anyhow!("no cluster named '{name}' in the configuration file"))
    }

    // ================================================== resource management

    /// `ec2createinstance`.
    pub fn create_instance(&mut self, opts: &CreateInstanceOpts) -> Result<String> {
        let name = opts
            .iname
            .clone()
            .unwrap_or_else(|| format!("instance{}", self.instances_cfg.entries.len() + 1));
        if self.instances_cfg.contains(&name) {
            bail!("an instance named '{name}' already exists (names must be unique)");
        }
        let itype = opts
            .itype
            .clone()
            .unwrap_or_else(|| self.platform.default_type.clone());
        let spec = instance_type(&itype)
            .ok_or_else(|| anyhow!("instance type '{itype}' is not offered"))?;
        let ami = if spec.hvm {
            self.cloud.default_ami(true).id.clone()
        } else {
            self.platform.default_ami.clone()
        };

        let lifecycle = if opts.spot {
            spot_bid(spec)
        } else {
            Lifecycle::OnDemand
        };
        let start = self.cloud.clock.now_s();
        let ids = self
            .cloud
            .run_instances_as(1, &itype, &ami, &self.rlibs.libraries, lifecycle)
            .context("launching instance")?;
        let id = ids[0].clone();
        self.cloud.set_name(&id, &name)?;
        self.cloud.set_tag(&id, "p2rac:name", &name)?;

        // Volume resolution: -ebsvol | -snap | default snapshot.
        let vol_id = match (&opts.ebsvol, &opts.snap) {
            (Some(_), Some(_)) => bail!("-ebsvol and -snap cannot be specified at the same time"),
            (Some(v), None) => {
                self.cloud.volume(v).map_err(|e| anyhow!(e.to_string()))?;
                v.clone()
            }
            (None, Some(s)) => self.cloud.create_volume_from_snapshot(s)?,
            (None, None) => self
                .cloud
                .create_volume_from_snapshot(&self.platform.default_snapshot)?,
        };
        self.cloud.attach_volume(&vol_id, &id)?;
        self.cloud.clock.push_span(
            SpanCategory::CreateResource,
            &format!("create instance {name}"),
            start,
        );

        let inst = self.cloud.instance(&id)?;
        self.instances_cfg.insert(
            &name,
            InstanceEntry {
                instance_id: id.clone(),
                public_dns: inst.public_dns.clone(),
                volume_id: Some(vol_id),
                instance_type: itype,
                description: opts.desc.clone().unwrap_or_default(),
                in_use: false,
            },
        );
        self.platform.default_instance = Some(name.clone());
        self.save_configs();
        Ok(name)
    }

    /// `ec2terminateinstance`.
    pub fn terminate_instance(&mut self, iname: Option<&str>, deletevol: bool) -> Result<()> {
        let name = self.resolve_iname(iname)?;
        let entry = self.instance_entry(&name)?.clone();
        if entry.in_use {
            bail!("instance '{name}' is in use; unlock it with ec2resourcelock -free first");
        }
        let start = self.cloud.clock.now_s();
        if let Some(vol) = &entry.volume_id {
            self.cloud.detach_volume(vol).ok();
        }
        self.cloud
            .terminate_instances(std::slice::from_ref(&entry.instance_id))?;
        if deletevol {
            if let Some(vol) = &entry.volume_id {
                self.cloud.delete_volume(vol)?;
            }
        }
        self.cloud.clock.push_span(
            SpanCategory::TerminateResource,
            &format!("terminate instance {name}"),
            start,
        );
        self.instances_cfg.remove(&name);
        if self.platform.default_instance.as_deref() == Some(name.as_str()) {
            self.platform.default_instance = self.instances_cfg.names().first().cloned();
        }
        self.save_configs();
        Ok(())
    }

    /// `ec2createcluster`.
    pub fn create_cluster(&mut self, opts: &CreateClusterOpts) -> Result<String> {
        let name = opts
            .cname
            .clone()
            .unwrap_or_else(|| format!("cluster{}", self.clusters_cfg.entries.len() + 1));
        if self.clusters_cfg.contains(&name) {
            bail!("a cluster named '{name}' already exists (names must be unique)");
        }
        let csize = opts.csize.unwrap_or(self.platform.default_cluster_size);
        if csize < 2 {
            bail!("cluster size must be at least 2 (1 master + workers), got {csize}");
        }
        let itype = opts
            .itype
            .clone()
            .unwrap_or_else(|| self.platform.default_type.clone());
        let spec = instance_type(&itype)
            .ok_or_else(|| anyhow!("instance type '{itype}' is not offered"))?;
        let ami = if spec.hvm {
            self.cloud.default_ami(true).id.clone()
        } else {
            self.platform.default_ami.clone()
        };

        let lifecycle = if opts.spot {
            spot_bid(spec)
        } else {
            Lifecycle::OnDemand
        };
        let start = self.cloud.clock.now_s();
        let ids = self
            .cloud
            .run_instances_as(csize, &itype, &ami, &self.rlibs.libraries, lifecycle)
            .context("launching cluster instances")?;
        let master = ids[0].clone();
        let workers: Vec<String> = ids[1..].to_vec();
        self.cloud.set_tag(&master, "p2rac:role", &format!("{name}_Master"))?;
        for w in &workers {
            self.cloud.set_tag(w, "p2rac:role", &format!("{name}_Workers"))?;
        }

        let vol_id = match (&opts.ebsvol, &opts.snap) {
            (Some(_), Some(_)) => bail!("-ebsvol and -snap cannot be specified at the same time"),
            (Some(v), None) => {
                self.cloud.volume(v).map_err(|e| anyhow!(e.to_string()))?;
                v.clone()
            }
            (None, Some(s)) => self.cloud.create_volume_from_snapshot(s)?,
            (None, None) => self
                .cloud
                .create_volume_from_snapshot(&self.platform.default_snapshot)?,
        };
        self.cloud.attach_volume(&vol_id, &master)?;
        self.cloud.nfs_export(&master, &vol_id, &workers)?;
        // Master/worker configuration (hosts files, SNOW socket setup).
        let cfg_s = self.cloud.params().cluster_config_base_s;
        self.cloud.clock.advance(cfg_s);
        self.cloud.clock.push_span(
            SpanCategory::CreateResource,
            &format!("create cluster {name} ({csize} nodes)"),
            start,
        );

        let master_dns = self.cloud.instance(&master)?.public_dns.clone();
        let worker_dns: Vec<String> = workers
            .iter()
            .map(|w| self.cloud.instance(w).map(|i| i.public_dns.clone()))
            .collect::<std::result::Result<_, CloudError>>()?;
        self.clusters_cfg.insert(
            &name,
            ClusterEntry {
                size: csize,
                master_id: master,
                master_dns,
                worker_ids: workers,
                worker_dns,
                volume_id: Some(vol_id),
                instance_type: itype,
                description: opts.desc.clone().unwrap_or_default(),
                in_use: false,
            },
        );
        self.platform.default_cluster = Some(name.clone());
        self.save_configs();
        Ok(name)
    }

    /// `ec2terminatecluster`.
    pub fn terminate_cluster(&mut self, cname: Option<&str>, deletevol: bool) -> Result<()> {
        let name = self.resolve_cname(cname)?;
        let entry = self.cluster_entry(&name)?.clone();
        // "whether a cluster is in use is firstly checked" (§3.2.2).
        if entry.in_use {
            bail!("cluster '{name}' is in use and cannot be terminated");
        }
        let start = self.cloud.clock.now_s();
        self.cloud.nfs_unexport(&entry.worker_ids)?;
        if let Some(vol) = &entry.volume_id {
            self.cloud.detach_volume(vol).ok();
        }
        self.cloud.terminate_instances(&entry.all_ids())?;
        if deletevol {
            if let Some(vol) = &entry.volume_id {
                self.cloud.delete_volume(vol)?;
            }
        }
        self.cloud.clock.push_span(
            SpanCategory::TerminateResource,
            &format!("terminate cluster {name}"),
            start,
        );
        self.clusters_cfg.remove(&name);
        if self.platform.default_cluster.as_deref() == Some(name.as_str()) {
            self.platform.default_cluster = self.clusters_cfg.names().first().cloned();
        }
        self.save_configs();
        Ok(())
    }

    /// `ec2resizecluster` — the dynamic scaling the paper lists as
    /// future work (§5): grow or shrink a running cluster. New workers
    /// boot, NFS-mount the master's volume and join the worker pool;
    /// removed workers are drained (refused while the cluster is
    /// locked) and terminated.
    pub fn resize_cluster(&mut self, cname: Option<&str>, new_size: usize) -> Result<()> {
        let name = self.resolve_cname(cname)?;
        let entry = self.cluster_entry(&name)?.clone();
        if entry.in_use {
            bail!("cluster '{name}' is in use; cannot resize mid-run");
        }
        if new_size < 2 {
            bail!("cluster size must be at least 2, got {new_size}");
        }
        if new_size == entry.size {
            return Ok(());
        }
        let start = self.cloud.clock.now_s();
        let mut worker_ids = entry.worker_ids.clone();
        let mut worker_dns = entry.worker_dns.clone();
        if new_size > entry.size {
            // Grow: boot the delta as one batch, mount the shared
            // volume. New workers inherit the master's purchase model
            // (a spot cluster grows with spot capacity).
            let add = new_size - entry.size;
            let (ami, lifecycle) = {
                let inst = self.cloud.instance(&entry.master_id)?;
                (inst.ami_id.clone(), inst.lifecycle)
            };
            let ids = self
                .cloud
                .run_instances_as(add, &entry.instance_type, &ami, &self.rlibs.libraries, lifecycle)
                .context("scaling cluster up")?;
            if let Some(vol) = &entry.volume_id {
                self.cloud.nfs_export(&entry.master_id, vol, &ids)?;
            }
            for id in &ids {
                self.cloud
                    .set_tag(id, "p2rac:role", &format!("{name}_Workers"))?;
                worker_dns.push(self.cloud.instance(id)?.public_dns.clone());
            }
            worker_ids.extend(ids);
        } else {
            // Shrink: drain and terminate the tail workers.
            let drop_n = entry.size - new_size;
            let dropped: Vec<String> = worker_ids.split_off(worker_ids.len() - drop_n);
            worker_dns.truncate(worker_dns.len() - drop_n);
            self.cloud.nfs_unexport(&dropped)?;
            self.cloud.terminate_instances(&dropped)?;
        }
        self.cloud.clock.push_span(
            SpanCategory::CreateResource,
            &format!("resize cluster {name} {} -> {new_size}", entry.size),
            start,
        );
        let e = self.clusters_cfg.get_mut(&name).expect("checked above");
        e.size = new_size;
        e.worker_ids = worker_ids;
        e.worker_dns = worker_dns;
        self.save_configs();
        Ok(())
    }

    /// The provider reclaims a spot cluster (price exceeded the bid).
    /// Unlike [`Session::terminate_cluster`] this ignores the in-use
    /// lock — interruptions do not wait for runs to finish — and bills
    /// every node with the interrupted-partial-hour-free rule. The
    /// shared EBS volume survives, exactly like a real interruption:
    /// anything checkpointed to it is recoverable by replacement
    /// capacity.
    pub fn spot_interrupt_cluster(&mut self, cname: &str) -> Result<()> {
        let entry = self.cluster_entry(cname)?.clone();
        let start = self.cloud.clock.now_s();
        self.cloud.nfs_unexport(&entry.worker_ids)?;
        if let Some(vol) = &entry.volume_id {
            self.cloud.detach_volume(vol).ok();
        }
        self.cloud.spot_interrupt_instances(&entry.all_ids())?;
        self.cloud.clock.push_span(
            SpanCategory::TerminateResource,
            &format!("spot interruption reclaims cluster {cname}"),
            start,
        );
        self.clusters_cfg.remove(cname);
        if self.platform.default_cluster.as_deref() == Some(cname) {
            self.platform.default_cluster = self.clusters_cfg.names().first().cloned();
        }
        self.save_configs();
        Ok(())
    }

    /// `ec2terminateall`.
    pub fn terminate_all(
        &mut self,
        instances: bool,
        clusters: bool,
        ebsvolumes: bool,
        snapshots: bool,
    ) -> Result<Vec<String>> {
        let mut log = Vec::new();
        if clusters {
            for name in self.clusters_cfg.names() {
                // Force-unlock: ec2terminateall is the big red switch.
                if let Some(e) = self.clusters_cfg.get_mut(&name) {
                    e.in_use = false;
                }
                self.terminate_cluster(Some(&name), false)?;
                log.push(format!("terminated cluster {name}"));
            }
        }
        if instances {
            for name in self.instances_cfg.names() {
                if let Some(e) = self.instances_cfg.entries.get_mut(&name) {
                    e.in_use = false;
                }
                let id = self.instance_entry(&name)?.instance_id.clone();
                self.cloud.set_lock(&id, false).ok();
                self.terminate_instance(Some(&name), false)?;
                log.push(format!("terminated instance {name}"));
            }
        }
        if ebsvolumes {
            for v in self
                .cloud
                .live_volumes()
                .iter()
                .map(|v| v.id.clone())
                .collect::<Vec<_>>()
            {
                match self.cloud.delete_volume(&v) {
                    Ok(()) => log.push(format!("deleted volume {v}")),
                    Err(CloudError::VolumeInUse(..)) => {
                        log.push(format!("skipped attached volume {v}"))
                    }
                    Err(e) => return Err(e.into()),
                }
            }
        }
        if snapshots {
            for s in self
                .cloud
                .live_snapshots()
                .iter()
                .map(|s| s.id.clone())
                .collect::<Vec<_>>()
            {
                self.cloud.delete_snapshot(&s)?;
                log.push(format!("deleted snapshot {s}"));
            }
        }
        self.save_configs();
        Ok(log)
    }

    // ====================================================== data management

    /// `ec2senddatatoinstance`.
    pub fn send_data_to_instance(
        &mut self,
        iname: Option<&str>,
        projectdir: &str,
    ) -> Result<SyncReport> {
        let name = self.resolve_iname(iname)?;
        let entry = self.instance_entry(&name)?.clone();
        let dest = remote_project_dir(projectdir);
        let start = self.cloud.clock.now_s();
        let analyst = &self.analyst;
        let rep = self
            .cloud
            .with_instance_fs(&entry.instance_id, |fs, net, faults| {
                sync_dir(
                    analyst,
                    projectdir,
                    fs,
                    &dest,
                    Protocol::Rsync,
                    DEFAULT_BLOCK_LEN,
                    net,
                    Link::Wan,
                    faults,
                )
            })?
            .map_err(|e| anyhow!("sync to instance '{name}': {e}"))?;
        self.cloud.clock.advance(rep.elapsed_s);
        self.cloud.clock.push_span(
            SpanCategory::SubmitToMaster,
            &format!("send {projectdir} to instance {name}"),
            start,
        );
        Ok(rep)
    }

    /// `ec2senddatatomaster`.
    pub fn send_data_to_master(
        &mut self,
        cname: Option<&str>,
        projectdir: &str,
    ) -> Result<SyncReport> {
        let name = self.resolve_cname(cname)?;
        let entry = self.cluster_entry(&name)?.clone();
        let dest = remote_project_dir(projectdir);
        let start = self.cloud.clock.now_s();
        let analyst = &self.analyst;
        let rep = self
            .cloud
            .with_instance_fs(&entry.master_id, |fs, net, faults| {
                sync_dir(
                    analyst,
                    projectdir,
                    fs,
                    &dest,
                    Protocol::Rsync,
                    DEFAULT_BLOCK_LEN,
                    net,
                    Link::Wan,
                    faults,
                )
            })?
            .map_err(|e| anyhow!("sync to master of '{name}': {e}"))?;
        self.cloud.clock.advance(rep.elapsed_s);
        self.cloud.clock.push_span(
            SpanCategory::SubmitToMaster,
            &format!("send {projectdir} to master of {name}"),
            start,
        );
        Ok(rep)
    }

    /// `ec2senddatatoclusternodes`.
    pub fn send_data_to_cluster_nodes(
        &mut self,
        cname: Option<&str>,
        projectdir: &str,
    ) -> Result<Vec<SyncReport>> {
        let name = self.resolve_cname(cname)?;
        let entry = self.cluster_entry(&name)?.clone();
        let dest = remote_project_dir(projectdir);
        let start = self.cloud.clock.now_s();
        let mut reports = Vec::new();
        let ids = entry.all_ids();
        for id in &ids {
            let analyst = &self.analyst;
            let rep = self
                .cloud
                .with_instance_fs(id, |fs, net, faults| {
                    sync_dir(
                        analyst,
                        projectdir,
                        fs,
                        &dest,
                        Protocol::Rsync,
                        DEFAULT_BLOCK_LEN,
                        net,
                        Link::Wan,
                        faults,
                    )
                })?
                .map_err(|e| anyhow!("sync to node of '{name}': {e}"))?;
            reports.push(rep);
        }
        // Fan-out wire time: n copies over the shared Analyst uplink.
        let bytes_each = reports.iter().map(SyncReport::wire_bytes).max().unwrap_or(0);
        let files_each = reports[0].files_sent.max(1);
        let t = self
            .cloud
            .net
            .fanout_s(bytes_each, files_each, ids.len(), Link::Wan);
        self.cloud.clock.advance(t);
        self.cloud.clock.push_span(
            SpanCategory::SubmitToAllNodes,
            &format!("send {projectdir} to all {} nodes of {name}", ids.len()),
            start,
        );
        Ok(reports)
    }

    /// `ec2getresultsfrominstance`.
    pub fn get_results_from_instance(
        &mut self,
        iname: Option<&str>,
        projectdir: &str,
        runname: &str,
    ) -> Result<SyncReport> {
        let name = self.resolve_iname(iname)?;
        let entry = self.instance_entry(&name)?.clone();
        let remote_results = format!("{}/results/{runname}", remote_project_dir(projectdir));
        let local = format!("{}/{runname}", local_results_dir(projectdir));
        let start = self.cloud.clock.now_s();
        let inst = self.cloud.instance(&entry.instance_id)?;
        if !inst.fs.dir_exists(&remote_results) {
            bail!("no results for run '{runname}' on instance '{name}'");
        }
        let src = inst.fs.clone();
        let mut faults = std::mem::take(&mut self.cloud.faults);
        let rep = sync_dir(
            &src,
            &remote_results,
            &mut self.analyst,
            &local,
            Protocol::Rsync,
            DEFAULT_BLOCK_LEN,
            &self.cloud.net,
            Link::Wan,
            &mut faults,
        )
        .map_err(|e| anyhow!("fetch results from '{name}': {e}"))?;
        self.cloud.faults = faults;
        self.cloud.clock.advance(rep.elapsed_s);
        self.cloud.clock.push_span(
            SpanCategory::FetchFromMaster,
            &format!("fetch run {runname} from instance {name}"),
            start,
        );
        Ok(rep)
    }

    /// `ec2getresults` with the three scenarios.
    pub fn get_results(
        &mut self,
        cname: Option<&str>,
        projectdir: &str,
        runname: &str,
        scope: ResultScope,
    ) -> Result<SyncReport> {
        let name = self.resolve_cname(cname)?;
        let entry = self.cluster_entry(&name)?.clone();
        let remote_results = format!("{}/results/{runname}", remote_project_dir(projectdir));
        let local = format!("{}/{runname}", local_results_dir(projectdir));
        let start = self.cloud.clock.now_s();

        let mut sources: Vec<(String, String)> = Vec::new(); // (instance id, label)
        match scope {
            ResultScope::FromMaster => sources.push((entry.master_id.clone(), "master".into())),
            ResultScope::FromWorkers => {
                for (i, w) in entry.worker_ids.iter().enumerate() {
                    sources.push((w.clone(), format!("worker{i}")));
                }
            }
            ResultScope::FromAll => {
                sources.push((entry.master_id.clone(), "master".into()));
                for (i, w) in entry.worker_ids.iter().enumerate() {
                    sources.push((w.clone(), format!("worker{i}")));
                }
            }
        }

        let mut total = SyncReport::default();
        let mut found_any = false;
        let n_src = sources.len();
        let mut faults = std::mem::take(&mut self.cloud.faults);
        for (id, label) in sources {
            let inst = self.cloud.instance(&id)?;
            if !inst.fs.dir_exists(&remote_results) {
                continue;
            }
            found_any = true;
            let src = inst.fs.clone();
            // Multi-source gathers are disambiguated per node.
            let dst_dir = if scope == ResultScope::FromMaster {
                local.clone()
            } else {
                format!("{local}/{label}")
            };
            let rep = sync_dir(
                &src,
                &remote_results,
                &mut self.analyst,
                &dst_dir,
                Protocol::Rsync,
                DEFAULT_BLOCK_LEN,
                &self.cloud.net,
                Link::Wan,
                &mut faults,
            )
            .map_err(|e| anyhow!("fetch results from {label} of '{name}': {e}"))?;
            total.files_examined += rep.files_examined;
            total.files_sent += rep.files_sent;
            total.files_unchanged += rep.files_unchanged;
            total.literal_bytes += rep.literal_bytes;
            total.matched_bytes += rep.matched_bytes;
            total.protocol_bytes += rep.protocol_bytes;
        }
        self.cloud.faults = faults;
        if !found_any {
            bail!("no results for run '{runname}' on cluster '{name}'");
        }
        let cat = match scope {
            ResultScope::FromMaster => SpanCategory::FetchFromMaster,
            _ => SpanCategory::FetchFromAllNodes,
        };
        let t = match scope {
            ResultScope::FromMaster => self
                .cloud
                .net
                .transfer_s(total.wire_bytes(), total.files_sent.max(1), Link::Wan),
            _ => self.cloud.net.gather_s(
                total.wire_bytes() / n_src.max(1) as u64,
                (total.files_sent / n_src.max(1)).max(1),
                n_src,
                Link::Wan,
            ),
        };
        total.elapsed_s = t;
        self.cloud.clock.advance(t);
        self.cloud
            .clock
            .push_span(cat, &format!("fetch run {runname} from {name}"), start);
        Ok(total)
    }

    // ================================================= execution management

    fn load_script(fs: &Vfs, project_dir: &str, rscript: &str) -> Result<Json> {
        let path = format!("{project_dir}/{rscript}");
        let bytes = fs
            .read(&path)
            .ok_or_else(|| anyhow!("script '{rscript}' not found in project directory"))?;
        let text = std::str::from_utf8(bytes).context("script is not UTF-8")?;
        Json::parse(text).map_err(|e| anyhow!("script '{rscript}' is not valid JSON: {e}"))
    }

    /// List candidate scripts in a project dir (used when `-rscript` is
    /// omitted and the CLI prompts the Analyst).
    pub fn list_scripts(&self, projectdir: &str) -> Vec<String> {
        self.analyst
            .list_dir(projectdir)
            .into_iter()
            .filter(|f| f.ends_with(".json") && !f.starts_with("results/"))
            .collect()
    }

    /// `ec2runoninstance`.
    pub fn run_on_instance(
        &mut self,
        iname: Option<&str>,
        projectdir: &str,
        rscript: &str,
        runname: &str,
    ) -> Result<TaskOutput> {
        let name = self.resolve_iname(iname)?;
        let entry = self.instance_entry(&name)?.clone();
        if entry.in_use {
            bail!("instance '{name}' is locked by another run");
        }
        let inst = self.cloud.instance(&entry.instance_id)?;
        let spec = inst.itype;
        let pdir = remote_project_dir(projectdir);
        let project = inst.fs.clone();
        let script = Self::load_script(&project, &pdir, rscript)?;

        // Lock for the duration of the run (§3.2.1).
        self.set_instance_lock(&name, true)?;
        let nodes = vec![NodeSpec {
            name: name.clone(),
            cores: spec.cores,
            mem_gb: spec.mem_gb,
            core_speed: spec.core_speed,
        }];
        let nproc = script
            .get("slaves")
            .and_then(Json::as_usize)
            .unwrap_or(spec.cores);
        let assignment = vec![0usize; nproc];
        let view = ResourceView {
            nodes,
            assignment,
            net: self.cloud.net.clone(),
            resource_name: name.clone(),
            real_threads: self.threads,
        };
        let out = self.engine.run(rscript, &script, &project, &pdir, &view);
        // Always unlock, even on engine failure.
        self.set_instance_lock(&name, false)?;
        let out = out?;

        let start = self.cloud.clock.now_s();
        self.cloud.clock.advance(out.compute_s);
        self.cloud.clock.push_span(
            SpanCategory::Compute,
            &format!("run {rscript} ({runname}) on instance {name}"),
            start,
        );
        // Results land in results/<runname>/ inside the project dir.
        let fs = self.cloud.instance_fs_mut(&entry.instance_id)?;
        for (rel, bytes) in &out.master_files {
            fs.write(&format!("{pdir}/results/{runname}/{rel}"), bytes.clone());
        }
        Ok(out)
    }

    /// `ec2runoncluster`.
    pub fn run_on_cluster(
        &mut self,
        cname: Option<&str>,
        projectdir: &str,
        rscript: &str,
        runname: &str,
        placement: Placement,
    ) -> Result<TaskOutput> {
        let name = self.resolve_cname(cname)?;
        let entry = self.cluster_entry(&name)?.clone();
        if entry.in_use {
            bail!("cluster '{name}' is locked by another run");
        }
        let spec = instance_type(&entry.instance_type)
            .ok_or_else(|| anyhow!("unknown type in config: {}", entry.instance_type))?;
        let pdir = remote_project_dir(projectdir);
        let master = self.cloud.instance(&entry.master_id)?;
        let project = master.fs.clone();
        let script = Self::load_script(&project, &pdir, rscript)?;

        self.set_cluster_lock(&name, true)?;
        let nodes: Vec<NodeSpec> = entry
            .all_ids()
            .iter()
            .enumerate()
            .map(|(i, _)| NodeSpec {
                name: if i == 0 {
                    format!("{name}_Master")
                } else {
                    format!("{name}_Worker{i}")
                },
                cores: spec.cores,
                mem_gb: spec.mem_gb,
                core_speed: spec.core_speed,
            })
            .collect();
        let total_cores: usize = nodes.iter().map(|n| n.cores).sum();
        let nproc = script
            .get("slaves")
            .and_then(Json::as_usize)
            .unwrap_or(total_cores);
        // Memory feasibility check — the reason bynode exists (§3.2.2).
        if let Some(mem) = script.get("mem_gb_per_proc").and_then(Json::as_f64) {
            if !scheduler::feasible(nproc, mem, &nodes, placement) {
                self.set_cluster_lock(&name, false)?;
                bail!(
                    "{nproc} processes needing {mem} GB each do not fit under {placement:?}; \
                     try -bynode or fewer slaves"
                );
            }
        }
        let assignment = scheduler::schedule(nproc, &nodes, placement);
        let view = ResourceView {
            nodes,
            assignment,
            net: self.cloud.net.clone(),
            resource_name: name.clone(),
            real_threads: self.threads,
        };
        let out = self.engine.run(rscript, &script, &project, &pdir, &view);
        self.set_cluster_lock(&name, false)?;
        let out = out?;

        let start = self.cloud.clock.now_s();
        self.cloud.clock.advance(out.compute_s);
        self.cloud.clock.push_span(
            SpanCategory::Compute,
            &format!("run {rscript} ({runname}) on cluster {name}"),
            start,
        );
        // Scenario 1/3 files on the master…
        let master_fs = self.cloud.instance_fs_mut(&entry.master_id)?;
        for (rel, bytes) in &out.master_files {
            master_fs.write(&format!("{pdir}/results/{runname}/{rel}"), bytes.clone());
        }
        // …scenario 2/3 files on the workers.
        for (widx, rel, bytes) in &out.worker_files {
            let Some(wid) = entry.worker_ids.get(*widx) else {
                bail!("engine wrote to nonexistent worker {widx}");
            };
            let fs = self.cloud.instance_fs_mut(wid)?;
            fs.write(&format!("{pdir}/results/{runname}/{rel}"), bytes.clone());
        }
        Ok(out)
    }

    /// Run a script locally on a Table-I desktop (Fig 5 comparison).
    pub fn run_local(
        &mut self,
        desktop: &DesktopSpec,
        projectdir: &str,
        rscript: &str,
        runname: &str,
    ) -> Result<TaskOutput> {
        let script = Self::load_script(&self.analyst, projectdir, rscript)?;
        let nproc = script
            .get("slaves")
            .and_then(Json::as_usize)
            .unwrap_or(desktop.cores);
        let view = ResourceView {
            nodes: vec![NodeSpec {
                name: desktop.name.clone(),
                cores: desktop.cores,
                mem_gb: desktop.mem_gb,
                core_speed: desktop.core_speed,
            }],
            assignment: vec![0; nproc],
            net: self.cloud.net.clone(),
            resource_name: desktop.name.clone(),
            real_threads: self.threads,
        };
        let project = self.analyst.clone();
        let out = self.engine.run(rscript, &script, &project, projectdir, &view)?;
        let start = self.cloud.clock.now_s();
        self.cloud.clock.advance(out.compute_s);
        self.cloud.clock.push_span(
            SpanCategory::Compute,
            &format!("run {rscript} ({runname}) on {}", desktop.name),
            start,
        );
        let local = format!("{}/{runname}", local_results_dir(projectdir));
        for (rel, bytes) in &out.master_files {
            self.analyst.write(&format!("{local}/{rel}"), bytes.clone());
        }
        Ok(out)
    }

    // ========================================================== diagnostics

    /// `ec2resourcelock` on an instance.
    pub fn set_instance_lock(&mut self, iname: &str, in_use: bool) -> Result<()> {
        let entry = self.instance_entry(iname)?.clone();
        self.cloud.set_lock(&entry.instance_id, in_use)?;
        self.instances_cfg
            .entries
            .get_mut(iname)
            .expect("checked above")
            .in_use = in_use;
        self.save_configs();
        Ok(())
    }

    /// `ec2resourcelock` on a cluster.
    pub fn set_cluster_lock(&mut self, cname: &str, in_use: bool) -> Result<()> {
        let entry = self.cluster_entry(cname)?.clone();
        for id in entry.all_ids() {
            self.cloud.set_lock(&id, in_use)?;
        }
        self.clusters_cfg
            .get_mut(cname)
            .expect("checked above")
            .in_use = in_use;
        self.save_configs();
        Ok(())
    }

    /// `ec2listinstances`.
    pub fn list_instances(&self, names_only: bool) -> Vec<String> {
        self.instances_cfg
            .entries
            .iter()
            .map(|(name, e)| {
                if names_only {
                    name.clone()
                } else {
                    format!(
                        "{name}  dns={}  vol={}  type={}  inuse={}  desc={:?}",
                        e.public_dns,
                        e.volume_id.as_deref().unwrap_or("-"),
                        e.instance_type,
                        e.in_use,
                        e.description
                    )
                }
            })
            .collect()
    }

    /// `ec2listclusters`.
    pub fn list_clusters(&self, names_only: bool) -> Vec<String> {
        self.clusters_cfg
            .entries
            .iter()
            .map(|(name, e)| {
                if names_only {
                    name.clone()
                } else {
                    format!(
                        "{name}  size={}  master={}  workers=[{}]  vol={}  inuse={}  desc={:?}",
                        e.size,
                        e.master_dns,
                        e.worker_dns.join(", "),
                        e.volume_id.as_deref().unwrap_or("-"),
                        e.in_use,
                        e.description
                    )
                }
            })
            .collect()
    }

    /// `ec2listallresources`.
    pub fn list_all_resources(
        &self,
        instances: bool,
        ebsvols: bool,
        snapshots: bool,
        amis: bool,
    ) -> Vec<String> {
        let mut out = Vec::new();
        if instances {
            for i in self.cloud.live_instances() {
                out.push(format!(
                    "instance {}  type={}  name={}",
                    i.id,
                    i.itype.api_name,
                    i.name.as_deref().unwrap_or("-")
                ));
            }
        }
        if ebsvols {
            for v in self.cloud.live_volumes() {
                out.push(format!(
                    "volume {}  {:.0}GiB  attached_to={}",
                    v.id,
                    v.size_gb,
                    v.attached_to.as_deref().unwrap_or("-")
                ));
            }
        }
        if snapshots {
            for s in self.cloud.live_snapshots() {
                out.push(format!("snapshot {}  {:.0}GiB  {:?}", s.id, s.size_gb, s.description));
            }
        }
        if amis {
            for a in self.cloud.amis() {
                out.push(format!("ami {}  {}  hvm={}", a.id, a.name, a.hvm));
            }
        }
        out
    }

    /// `ec2logintoinstance` / `ec2logintocluster` (simulated SSH): returns
    /// the login banner for the target machine.
    pub fn login_banner(&self, iname: Option<&str>, cname: Option<&str>) -> Result<String> {
        let (dns, what) = if let Some(c) = cname {
            let e = self.cluster_entry(c)?;
            (e.master_dns.clone(), format!("master of cluster {c}"))
        } else {
            let name = self.resolve_iname(iname)?;
            let e = self.instance_entry(&name)?;
            (e.public_dns.clone(), format!("instance {name}"))
        };
        Ok(format!(
            "ssh root@{dns}\nWelcome to Ubuntu ({what})\nLast login: simulated\nroot@ip:~#"
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::MockEngine;

    fn session() -> Session {
        Session::new(SimParams::default(), Box::new(MockEngine::new(1000.0)))
    }

    fn write_project(s: &mut Session, dir: &str, data_bytes: usize) {
        s.analyst.write(
            &format!("{dir}/sweep.json"),
            br#"{"type":"mock","slaves":4}"#.to_vec(),
        );
        s.analyst
            .write(&format!("{dir}/data/input.bin"), vec![7u8; data_bytes]);
    }

    #[test]
    fn instance_workflow_figure2() {
        // The full Fig-2 workflow: create → send → run → fetch → terminate.
        let mut s = session();
        write_project(&mut s, "home/analyst/sweep", 50_000);
        let name = s
            .create_instance(&CreateInstanceOpts {
                iname: Some("hpc_instance".into()),
                itype: Some("m2.4xlarge".into()),
                desc: Some("For Trial Simulation Run".into()),
                ..Default::default()
            })
            .unwrap();
        assert_eq!(name, "hpc_instance");
        assert!(s.instances_cfg.contains("hpc_instance"));

        let rep = s
            .send_data_to_instance(Some("hpc_instance"), "home/analyst/sweep")
            .unwrap();
        assert_eq!(rep.files_sent, 2);

        let out = s
            .run_on_instance(Some("hpc_instance"), "home/analyst/sweep", "sweep.json", "run1")
            .unwrap();
        assert!(out.compute_s > 0.0);

        let fetched = s
            .get_results_from_instance(Some("hpc_instance"), "home/analyst/sweep", "run1")
            .unwrap();
        assert!(fetched.files_sent >= 1);
        assert!(s
            .analyst
            .exists("home/analyst/sweep_results/run1/summary.json"));

        s.terminate_instance(Some("hpc_instance"), true).unwrap();
        assert!(!s.instances_cfg.contains("hpc_instance"));
        assert!(s.cloud.live_instances().is_empty());
    }

    #[test]
    fn cluster_workflow_figure3() {
        let mut s = session();
        write_project(&mut s, "home/analyst/catopt", 80_000);
        let name = s
            .create_cluster(&CreateClusterOpts {
                cname: Some("hpc_cluster".into()),
                csize: Some(4),
                itype: Some("m2.2xlarge".into()),
                ..Default::default()
            })
            .unwrap();
        let entry = s.clusters_cfg.get(&name).unwrap().clone();
        assert_eq!(entry.size, 4);
        assert_eq!(entry.worker_ids.len(), 3);
        // Master holds the volume; workers NFS-mount it.
        let master = s.cloud.instance(&entry.master_id).unwrap();
        assert!(master.attached_volume.is_some());
        for w in &entry.worker_ids {
            assert_eq!(
                s.cloud.instance(w).unwrap().nfs_mount_from,
                master.attached_volume
            );
        }

        let reps = s
            .send_data_to_cluster_nodes(Some("hpc_cluster"), "home/analyst/catopt")
            .unwrap();
        assert_eq!(reps.len(), 4);
        for id in entry.all_ids() {
            assert!(s
                .cloud
                .instance(&id)
                .unwrap()
                .fs
                .exists("root/catopt/sweep.json"));
        }

        let out = s
            .run_on_cluster(
                Some("hpc_cluster"),
                "home/analyst/catopt",
                "sweep.json",
                "trial1",
                Placement::ByNode,
            )
            .unwrap();
        assert!(out.compute_s > 0.0);

        let rep = s
            .get_results(
                Some("hpc_cluster"),
                "home/analyst/catopt",
                "trial1",
                ResultScope::FromMaster,
            )
            .unwrap();
        assert!(rep.files_sent >= 1);
        assert!(s
            .analyst
            .exists("home/analyst/catopt_results/trial1/summary.json"));

        s.terminate_cluster(Some("hpc_cluster"), false).unwrap();
        assert!(s.cloud.live_instances().is_empty());
        // Volume persisted (no -deletevol).
        assert_eq!(s.cloud.live_volumes().len(), 1);
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut s = session();
        s.create_instance(&CreateInstanceOpts {
            iname: Some("a".into()),
            ..Default::default()
        })
        .unwrap();
        assert!(s
            .create_instance(&CreateInstanceOpts {
                iname: Some("a".into()),
                ..Default::default()
            })
            .is_err());
    }

    #[test]
    fn ebsvol_and_snap_conflict() {
        let mut s = session();
        let e = s.create_instance(&CreateInstanceOpts {
            iname: Some("x".into()),
            ebsvol: Some("vol-1".into()),
            snap: Some("snap-1".into()),
            ..Default::default()
        });
        assert!(e.unwrap_err().to_string().contains("cannot be specified"));
    }

    #[test]
    fn in_use_cluster_refuses_termination() {
        let mut s = session();
        s.create_cluster(&CreateClusterOpts {
            cname: Some("c".into()),
            csize: Some(2),
            ..Default::default()
        })
        .unwrap();
        s.set_cluster_lock("c", true).unwrap();
        assert!(s.terminate_cluster(Some("c"), false).is_err());
        s.set_cluster_lock("c", false).unwrap();
        s.terminate_cluster(Some("c"), false).unwrap();
    }

    #[test]
    fn run_locks_and_unlocks() {
        let mut s = session();
        write_project(&mut s, "p", 1000);
        s.create_instance(&CreateInstanceOpts {
            iname: Some("i".into()),
            ..Default::default()
        })
        .unwrap();
        s.send_data_to_instance(Some("i"), "p").unwrap();
        s.run_on_instance(Some("i"), "p", "sweep.json", "r1").unwrap();
        // Unlocked afterwards.
        assert!(!s.instances_cfg.get("i").unwrap().in_use);
        // Manual lock blocks a run.
        s.set_instance_lock("i", true).unwrap();
        assert!(s.run_on_instance(Some("i"), "p", "sweep.json", "r2").is_err());
    }

    #[test]
    fn missing_script_is_an_error() {
        let mut s = session();
        write_project(&mut s, "p", 100);
        s.create_instance(&CreateInstanceOpts {
            iname: Some("i".into()),
            ..Default::default()
        })
        .unwrap();
        s.send_data_to_instance(Some("i"), "p").unwrap();
        let e = s.run_on_instance(Some("i"), "p", "nope.json", "r");
        assert!(e.unwrap_err().to_string().contains("not found"));
    }

    #[test]
    fn default_names_from_platform_config() {
        let mut s = session();
        write_project(&mut s, "p", 100);
        s.create_instance(&CreateInstanceOpts {
            iname: Some("only".into()),
            ..Default::default()
        })
        .unwrap();
        // iname omitted → default instance from config.
        s.send_data_to_instance(None, "p").unwrap();
        assert!(s
            .cloud
            .find_by_name("only")
            .unwrap()
            .fs
            .exists("root/p/sweep.json"));
    }

    #[test]
    fn terminate_all_clears_everything() {
        let mut s = session();
        s.create_instance(&CreateInstanceOpts {
            iname: Some("i1".into()),
            ..Default::default()
        })
        .unwrap();
        s.create_cluster(&CreateClusterOpts {
            cname: Some("c1".into()),
            csize: Some(2),
            ..Default::default()
        })
        .unwrap();
        let log = s.terminate_all(true, true, true, true).unwrap();
        assert!(log.len() >= 4);
        assert!(s.cloud.live_instances().is_empty());
        assert!(s.cloud.live_volumes().is_empty());
        assert!(s.cloud.live_snapshots().is_empty());
        assert!(s.instances_cfg.names().is_empty());
        assert!(s.clusters_cfg.names().is_empty());
    }

    #[test]
    fn management_spans_recorded_for_figures() {
        let mut s = session();
        write_project(&mut s, "p", 10_000);
        s.create_cluster(&CreateClusterOpts {
            cname: Some("c".into()),
            csize: Some(4),
            ..Default::default()
        })
        .unwrap();
        s.send_data_to_master(Some("c"), "p").unwrap();
        s.send_data_to_cluster_nodes(Some("c"), "p").unwrap();
        s.run_on_cluster(Some("c"), "p", "sweep.json", "r", Placement::ByNode)
            .unwrap();
        s.get_results(Some("c"), "p", "r", ResultScope::FromMaster).unwrap();
        s.terminate_cluster(Some("c"), false).unwrap();
        let cl = &s.cloud.clock;
        assert!(cl.category_total_s(SpanCategory::CreateResource) > 0.0);
        assert!(cl.category_total_s(SpanCategory::SubmitToMaster) > 0.0);
        assert!(cl.category_total_s(SpanCategory::SubmitToAllNodes) > 0.0);
        assert!(cl.category_total_s(SpanCategory::FetchFromMaster) > 0.0);
        assert!(cl.category_total_s(SpanCategory::TerminateResource) > 0.0);
        assert!(cl.category_total_s(SpanCategory::Compute) > 0.0);
        // Creation dominates for small data (paper Figs 6–7 shape).
        assert!(
            cl.category_total_s(SpanCategory::CreateResource)
                > cl.category_total_s(SpanCategory::SubmitToMaster)
        );
    }

    #[test]
    fn worker_results_gathered_fromall() {
        // Engine that writes files on workers (paper's scenario 3).
        struct WorkerEngine;
        impl ScriptEngine for WorkerEngine {
            fn run(
                &mut self,
                _s: &str,
                _j: &Json,
                _p: &Vfs,
                _d: &str,
                r: &ResourceView,
            ) -> anyhow::Result<TaskOutput> {
                Ok(TaskOutput {
                    master_files: vec![("agg.json".into(), b"{}".to_vec())],
                    worker_files: (0..r.nodes.len() - 1)
                        .map(|w| (w, format!("part{w}.bin"), vec![w as u8; 64]))
                        .collect(),
                    compute_s: 10.0,
                    summary: Json::Null,
                })
            }
        }
        let mut s = Session::new(SimParams::default(), Box::new(WorkerEngine));
        write_project(&mut s, "p", 1000);
        s.create_cluster(&CreateClusterOpts {
            cname: Some("c".into()),
            csize: Some(3),
            ..Default::default()
        })
        .unwrap();
        s.send_data_to_cluster_nodes(Some("c"), "p").unwrap();
        s.run_on_cluster(Some("c"), "p", "sweep.json", "r", Placement::ByNode)
            .unwrap();
        let rep = s
            .get_results(Some("c"), "p", "r", ResultScope::FromAll)
            .unwrap();
        assert!(rep.files_sent >= 3);
        assert!(s.analyst.exists("p_results/r/master/agg.json"));
        assert!(s.analyst.exists("p_results/r/worker0/part0.bin"));
        assert!(s.analyst.exists("p_results/r/worker1/part1.bin"));
        // fromworkers only:
        let rep2 = s
            .get_results(Some("c"), "p", "r", ResultScope::FromWorkers)
            .unwrap();
        assert!(rep2.files_unchanged + rep2.files_sent >= 2);
    }

    #[test]
    fn memory_infeasible_byslot_rejected() {
        let mut s = session();
        s.analyst.write(
            "p/big.json",
            br#"{"type":"mock","slaves":4,"mem_gb_per_proc":30.0}"#.to_vec(),
        );
        s.create_cluster(&CreateClusterOpts {
            cname: Some("c".into()),
            csize: Some(4),
            itype: Some("m2.2xlarge".into()),
            ..Default::default()
        })
        .unwrap();
        s.send_data_to_cluster_nodes(Some("c"), "p").unwrap();
        // 4 × 30 GB on one 34.2 GB node → infeasible byslot…
        let e = s.run_on_cluster(Some("c"), "p", "big.json", "r", Placement::BySlot);
        assert!(e.is_err());
        // …but bynode spreads them, one per node.
        assert!(!s.clusters_cfg.get("c").unwrap().in_use, "must unlock after failure");
        s.run_on_cluster(Some("c"), "p", "big.json", "r", Placement::ByNode)
            .unwrap();
    }

    #[test]
    fn login_banner_mentions_dns() {
        let mut s = session();
        s.create_instance(&CreateInstanceOpts {
            iname: Some("i".into()),
            ..Default::default()
        })
        .unwrap();
        let b = s.login_banner(Some("i"), None).unwrap();
        assert!(b.contains("ssh root@ec2-"));
    }

    #[test]
    fn spot_cluster_interruption_reclaims_but_keeps_volume() {
        let mut s = session();
        s.create_cluster(&CreateClusterOpts {
            cname: Some("sc".into()),
            csize: Some(3),
            spot: true,
            ..Default::default()
        })
        .unwrap();
        let e = s.clusters_cfg.get("sc").unwrap().clone();
        let vol = e.volume_id.clone().unwrap();
        for id in e.all_ids() {
            assert!(s.cloud.instance(&id).unwrap().is_spot());
        }
        // A run is in flight — interruptions do not care.
        s.set_cluster_lock("sc", true).unwrap();
        s.spot_interrupt_cluster("sc").unwrap();
        assert!(s.clusters_cfg.get("sc").is_none());
        assert!(s.cloud.live_instances().is_empty());
        assert!(
            s.cloud.volume(&vol).is_ok(),
            "EBS volume must survive the interruption"
        );
    }

    #[test]
    fn desktop_local_run_writes_results() {
        let mut s = session();
        write_project(&mut s, "p", 500);
        let d = table1_desktops();
        let out = s.run_local(&d[0], "p", "sweep.json", "r1").unwrap();
        assert!(out.compute_s > 0.0);
        assert!(s.analyst.exists("p_results/r1/summary.json"));
    }
}
