//! Data management (paper §3.2.1/§3.2.2): project sync to instances
//! and clusters, result gathering under the three scenarios, and the
//! cloud-side storage plane (EBS snapshots of live volumes, S3 object
//! listing). Every byte that crosses a link — rsync project sync,
//! result gather, checkpoint traffic — is accounted through one path,
//! [`crate::simcloud::SimCloud::account_transfer`], so the WAN/LAN
//! billing split is uniform across the whole platform.

use super::{local_results_dir, remote_project_dir, Session};
use crate::datasync::{sync_dir, Protocol, SyncReport, DEFAULT_BLOCK_LEN};
use crate::simcloud::{Link, SpanCategory};
use anyhow::{anyhow, bail, Result};

impl Session {
    /// `ec2senddatatoinstance`.
    pub fn send_data_to_instance(
        &mut self,
        iname: Option<&str>,
        projectdir: &str,
    ) -> Result<SyncReport> {
        let name = self.resolve_iname(iname)?;
        let entry = self.instance_entry(&name)?.clone();
        let dest = remote_project_dir(projectdir);
        let start = self.cloud.clock.now_s();
        let analyst = &self.analyst;
        let rep = self
            .cloud
            .with_instance_fs(&entry.instance_id, |fs, net, faults| {
                sync_dir(
                    analyst,
                    projectdir,
                    fs,
                    &dest,
                    Protocol::Rsync,
                    DEFAULT_BLOCK_LEN,
                    net,
                    Link::Wan,
                    faults,
                )
            })?
            .map_err(|e| anyhow!("sync to instance '{name}': {e}"))?;
        self.cloud
            .account_transfer(&format!("sync {projectdir} -> {name}"), rep.wire_bytes(), Link::Wan);
        self.cloud.clock.advance(rep.elapsed_s);
        self.cloud.clock.push_span(
            SpanCategory::SubmitToMaster,
            &format!("send {projectdir} to instance {name}"),
            start,
        );
        Ok(rep)
    }

    /// `ec2senddatatomaster`.
    pub fn send_data_to_master(
        &mut self,
        cname: Option<&str>,
        projectdir: &str,
    ) -> Result<SyncReport> {
        let name = self.resolve_cname(cname)?;
        let entry = self.cluster_entry(&name)?.clone();
        let dest = remote_project_dir(projectdir);
        let start = self.cloud.clock.now_s();
        let analyst = &self.analyst;
        let rep = self
            .cloud
            .with_instance_fs(&entry.master_id, |fs, net, faults| {
                sync_dir(
                    analyst,
                    projectdir,
                    fs,
                    &dest,
                    Protocol::Rsync,
                    DEFAULT_BLOCK_LEN,
                    net,
                    Link::Wan,
                    faults,
                )
            })?
            .map_err(|e| anyhow!("sync to master of '{name}': {e}"))?;
        self.cloud
            .account_transfer(&format!("sync {projectdir} -> {name}"), rep.wire_bytes(), Link::Wan);
        self.cloud.clock.advance(rep.elapsed_s);
        self.cloud.clock.push_span(
            SpanCategory::SubmitToMaster,
            &format!("send {projectdir} to master of {name}"),
            start,
        );
        Ok(rep)
    }

    /// `ec2senddatatoclusternodes`.
    pub fn send_data_to_cluster_nodes(
        &mut self,
        cname: Option<&str>,
        projectdir: &str,
    ) -> Result<Vec<SyncReport>> {
        let name = self.resolve_cname(cname)?;
        let entry = self.cluster_entry(&name)?.clone();
        let dest = remote_project_dir(projectdir);
        let start = self.cloud.clock.now_s();
        let mut reports = Vec::new();
        let ids = entry.all_ids();
        for id in &ids {
            let analyst = &self.analyst;
            let rep = self
                .cloud
                .with_instance_fs(id, |fs, net, faults| {
                    sync_dir(
                        analyst,
                        projectdir,
                        fs,
                        &dest,
                        Protocol::Rsync,
                        DEFAULT_BLOCK_LEN,
                        net,
                        Link::Wan,
                        faults,
                    )
                })?
                .map_err(|e| anyhow!("sync to node of '{name}': {e}"))?;
            reports.push(rep);
        }
        let total_wire: u64 = reports.iter().map(SyncReport::wire_bytes).sum();
        self.cloud.account_transfer(
            &format!("fanout {projectdir} -> {name}"),
            total_wire,
            Link::Wan,
        );
        // Fan-out wire time: n copies over the shared Analyst uplink.
        let bytes_each = reports.iter().map(SyncReport::wire_bytes).max().unwrap_or(0);
        let files_each = reports[0].files_sent.max(1);
        let t = self
            .cloud
            .net
            .fanout_s(bytes_each, files_each, ids.len(), Link::Wan);
        self.cloud.clock.advance(t);
        self.cloud.clock.push_span(
            SpanCategory::SubmitToAllNodes,
            &format!("send {projectdir} to all {} nodes of {name}", ids.len()),
            start,
        );
        Ok(reports)
    }

    /// `ec2getresultsfrominstance`.
    pub fn get_results_from_instance(
        &mut self,
        iname: Option<&str>,
        projectdir: &str,
        runname: &str,
    ) -> Result<SyncReport> {
        let name = self.resolve_iname(iname)?;
        let entry = self.instance_entry(&name)?.clone();
        let remote_results = format!("{}/results/{runname}", remote_project_dir(projectdir));
        let local = format!("{}/{runname}", local_results_dir(projectdir));
        let start = self.cloud.clock.now_s();
        let inst = self.cloud.instance(&entry.instance_id)?;
        if !inst.fs.dir_exists(&remote_results) {
            bail!("no results for run '{runname}' on instance '{name}'");
        }
        let src = inst.fs.clone();
        let mut faults = std::mem::take(&mut self.cloud.faults);
        let rep = sync_dir(
            &src,
            &remote_results,
            &mut self.analyst,
            &local,
            Protocol::Rsync,
            DEFAULT_BLOCK_LEN,
            &self.cloud.net,
            Link::Wan,
            &mut faults,
        )
        .map_err(|e| anyhow!("fetch results from '{name}': {e}"))?;
        self.cloud.faults = faults;
        self.cloud
            .account_transfer(&format!("fetch {runname} <- {name}"), rep.wire_bytes(), Link::Wan);
        self.cloud.clock.advance(rep.elapsed_s);
        self.cloud.clock.push_span(
            SpanCategory::FetchFromMaster,
            &format!("fetch run {runname} from instance {name}"),
            start,
        );
        Ok(rep)
    }

    /// `ec2getresults` with the three scenarios.
    pub fn get_results(
        &mut self,
        cname: Option<&str>,
        projectdir: &str,
        runname: &str,
        scope: super::ResultScope,
    ) -> Result<SyncReport> {
        use super::ResultScope;
        let name = self.resolve_cname(cname)?;
        let entry = self.cluster_entry(&name)?.clone();
        let remote_results = format!("{}/results/{runname}", remote_project_dir(projectdir));
        let local = format!("{}/{runname}", local_results_dir(projectdir));
        let start = self.cloud.clock.now_s();

        let mut sources: Vec<(String, String)> = Vec::new(); // (instance id, label)
        match scope {
            ResultScope::FromMaster => sources.push((entry.master_id.clone(), "master".into())),
            ResultScope::FromWorkers => {
                for (i, w) in entry.worker_ids.iter().enumerate() {
                    sources.push((w.clone(), format!("worker{i}")));
                }
            }
            ResultScope::FromAll => {
                sources.push((entry.master_id.clone(), "master".into()));
                for (i, w) in entry.worker_ids.iter().enumerate() {
                    sources.push((w.clone(), format!("worker{i}")));
                }
            }
        }

        let mut total = SyncReport::default();
        let mut found_any = false;
        let n_src = sources.len();
        let mut faults = std::mem::take(&mut self.cloud.faults);
        for (id, label) in sources {
            let inst = self.cloud.instance(&id)?;
            if !inst.fs.dir_exists(&remote_results) {
                continue;
            }
            found_any = true;
            let src = inst.fs.clone();
            // Multi-source gathers are disambiguated per node.
            let dst_dir = if scope == ResultScope::FromMaster {
                local.clone()
            } else {
                format!("{local}/{label}")
            };
            let rep = sync_dir(
                &src,
                &remote_results,
                &mut self.analyst,
                &dst_dir,
                Protocol::Rsync,
                DEFAULT_BLOCK_LEN,
                &self.cloud.net,
                Link::Wan,
                &mut faults,
            )
            .map_err(|e| anyhow!("fetch results from {label} of '{name}': {e}"))?;
            total.files_examined += rep.files_examined;
            total.files_sent += rep.files_sent;
            total.files_unchanged += rep.files_unchanged;
            total.literal_bytes += rep.literal_bytes;
            total.matched_bytes += rep.matched_bytes;
            total.protocol_bytes += rep.protocol_bytes;
        }
        self.cloud.faults = faults;
        if !found_any {
            bail!("no results for run '{runname}' on cluster '{name}'");
        }
        self.cloud
            .account_transfer(&format!("fetch {runname} <- {name}"), total.wire_bytes(), Link::Wan);
        let cat = match scope {
            ResultScope::FromMaster => SpanCategory::FetchFromMaster,
            _ => SpanCategory::FetchFromAllNodes,
        };
        let t = match scope {
            ResultScope::FromMaster => self
                .cloud
                .net
                .transfer_s(total.wire_bytes(), total.files_sent.max(1), Link::Wan),
            _ => self.cloud.net.gather_s(
                total.wire_bytes() / n_src.max(1) as u64,
                (total.files_sent / n_src.max(1)).max(1),
                n_src,
                Link::Wan,
            ),
        };
        total.elapsed_s = t;
        self.cloud.clock.advance(t);
        self.cloud
            .clock
            .push_span(cat, &format!("fetch run {runname} from {name}"), start);
        Ok(total)
    }

    // ======================================================= storage plane

    /// `ec2snapshot`: point-in-time EBS snapshot of the volume behind
    /// an instance or a cluster (exactly one of the two). Returns the
    /// snapshot id; the contents are whatever the volume holds now —
    /// for a cluster running resident jobs, that includes the
    /// checkpoints committed so far.
    pub fn snapshot_resource_volume(
        &mut self,
        iname: Option<&str>,
        cname: Option<&str>,
        desc: &str,
    ) -> Result<String> {
        let (vol, what) = if let Some(c) = cname {
            let e = self.cluster_entry(c)?;
            (
                e.volume_id
                    .clone()
                    .ok_or_else(|| anyhow!("cluster '{c}' has no EBS volume"))?,
                format!("cluster {c}"),
            )
        } else {
            let name = self.resolve_iname(iname)?;
            let e = self.instance_entry(&name)?;
            (
                e.volume_id
                    .clone()
                    .ok_or_else(|| anyhow!("instance '{name}' has no EBS volume"))?,
                format!("instance {name}"),
            )
        };
        let start = self.cloud.clock.now_s();
        let snap = self.cloud.snapshot_volume(&vol, desc)?;
        self.cloud.clock.push_span(
            SpanCategory::CreateResource,
            &format!("snapshot {vol} of {what}"),
            start,
        );
        Ok(snap)
    }

    /// `ec2lsobjects`: list the storage plane's objects (all buckets,
    /// or one) with size, content digest and put time.
    pub fn list_storage_objects(&self, bucket: Option<&str>) -> Vec<String> {
        let buckets = match bucket {
            Some(b) => vec![b.to_string()],
            None => self.cloud.s3.bucket_names(),
        };
        let mut out = Vec::new();
        for b in buckets {
            for (key, obj) in self.cloud.s3.objects(&b, "") {
                out.push(format!(
                    "s3://{b}/{key}  {} B  digest={:016x}  put_at={:.0}s",
                    obj.data.len(),
                    obj.digest,
                    obj.put_at_s
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::MockEngine;
    use crate::coordinator::{CreateClusterOpts, CreateInstanceOpts};
    use crate::simcloud::SimParams;

    fn session() -> Session {
        Session::new(SimParams::default(), Box::new(MockEngine::new(100.0)))
    }

    #[test]
    fn wan_syncs_land_on_the_metered_transfer_path() {
        let mut s = session();
        s.analyst.write("p/sweep.json", br#"{"type":"mock"}"#.to_vec());
        s.analyst.write("p/data/big.bin", vec![3u8; 200_000]);
        s.create_instance(&CreateInstanceOpts {
            iname: Some("i".into()),
            ..Default::default()
        })
        .unwrap();
        s.send_data_to_instance(Some("i"), "p").unwrap();
        assert!(
            s.cloud.ledger.total_wan_transfer_centi_cents() >= 1,
            "project sync must book metered WAN bytes"
        );
    }

    #[test]
    fn cluster_volume_snapshot_captures_current_contents() {
        let mut s = session();
        s.create_cluster(&CreateClusterOpts {
            cname: Some("c".into()),
            csize: Some(2),
            ..Default::default()
        })
        .unwrap();
        let vol = s.clusters_cfg.get("c").unwrap().volume_id.clone().unwrap();
        s.cloud
            .volume_fs_mut(&vol)
            .unwrap()
            .write("jobs/job-1/checkpoint.json", b"{}".to_vec());
        let snap = s
            .snapshot_resource_volume(None, Some("c"), "mid-run state")
            .unwrap();
        assert!(s
            .cloud
            .snapshot(&snap)
            .unwrap()
            .fs
            .exists("jobs/job-1/checkpoint.json"));
        // And it shows up in the resource listing.
        let listing = s.list_all_resources(false, false, true, false).join("\n");
        assert!(listing.contains(&snap));
    }

    #[test]
    fn storage_object_listing_shows_digests() {
        let mut s = session();
        s.cloud
            .s3_put("p2rac-checkpoints", "job-1", b"{\"kind\":\"mc_sweep\"}".to_vec(), Link::Lan);
        let lines = s.list_storage_objects(None);
        assert_eq!(lines.len(), 1);
        assert!(lines[0].contains("s3://p2rac-checkpoints/job-1"));
        assert!(lines[0].contains("digest="));
        assert!(s.list_storage_objects(Some("empty-bucket")).is_empty());
    }
}
