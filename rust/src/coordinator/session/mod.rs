//! The P2RAC session: the Analyst-side object every command-line tool
//! operates on. One `Session` owns the simulated cloud, the Analyst
//! workstation filesystem, the four configuration files (paper §3.4)
//! and the script engine, and exposes one method per paper command.
//!
//! The implementation is split along the paper's three management
//! concerns (§3.2): [`resources`] (create/terminate/resize/lock),
//! [`data`] (project sync, result gathering and the storage plane) and
//! [`exec`] (running scripts). This file holds the session state,
//! configuration persistence and name resolution they all share.

mod data;
mod exec;
mod resources;

use super::engine::ScriptEngine;
use crate::config::{
    ClusterEntry, ClustersConfig, InstanceEntry, InstancesConfig, PlatformConfig, RLibsConfig,
    CONFIG_DIR,
};
use crate::simcloud::{Lifecycle, SimCloud, SimParams, Vfs};
use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// Result-gathering scope (paper §3.2.2: the three scenarios).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResultScope {
    FromMaster,
    FromWorkers,
    FromAll,
}

/// A non-cloud resource (paper Table I: Desktop A / Desktop B) on which
/// the same scripts can run for the timing comparison of Fig 5.
#[derive(Clone, Debug)]
pub struct DesktopSpec {
    pub name: String,
    pub cores: usize,
    pub mem_gb: f64,
    pub core_speed: f64,
}

/// The two desktops of Table I.
pub fn table1_desktops() -> Vec<DesktopSpec> {
    vec![
        DesktopSpec {
            name: "Desktop A".into(),
            cores: 8,
            mem_gb: 16.0,
            core_speed: 1.00,
        },
        DesktopSpec {
            name: "Desktop B".into(),
            cores: 6,
            mem_gb: 24.0,
            core_speed: 0.82,
        },
    ]
}

/// Options for `ec2createinstance`.
#[derive(Clone, Debug, Default)]
pub struct CreateInstanceOpts {
    pub iname: Option<String>,
    pub ebsvol: Option<String>,
    pub snap: Option<String>,
    pub itype: Option<String>,
    pub desc: Option<String>,
    /// Request spot capacity (bid = the on-demand rate, the classic
    /// "never outbid, just ride the discount" strategy).
    pub spot: bool,
    /// Tenant the instance (and its usage charges) belongs to.
    pub analyst: Option<String>,
}

/// Options for `ec2createcluster`.
#[derive(Clone, Debug, Default)]
pub struct CreateClusterOpts {
    pub cname: Option<String>,
    pub csize: Option<usize>,
    pub ebsvol: Option<String>,
    pub snap: Option<String>,
    pub itype: Option<String>,
    pub desc: Option<String>,
    /// Request spot capacity for every node of the cluster.
    pub spot: bool,
    /// Spot bid in centi-cents per instance-hour; `None` = the
    /// on-demand rate (the classic "never outbid" default). The jobs
    /// autoscaler sets this from its bid strategy (`ec2autoscale
    /// -bid`).
    pub bid_centi_cents_hour: Option<u64>,
    /// Tenant the cluster (and its usage charges) belongs to.
    pub analyst: Option<String>,
}

/// Bid used for `-spot` requests: `bid` when given, otherwise the
/// on-demand rate in centi-cents.
fn spot_bid(spec: &crate::simcloud::InstanceTypeSpec, bid: Option<u64>) -> Lifecycle {
    Lifecycle::Spot {
        bid_centi_cents_hour: bid.unwrap_or(spec.price_cents_hour * 100).max(1),
    }
}

/// One P2RAC session.
pub struct Session {
    pub cloud: SimCloud,
    /// The Analyst's workstation filesystem (projects + configs).
    pub analyst: Vfs,
    pub platform: PlatformConfig,
    pub instances_cfg: InstancesConfig,
    pub clusters_cfg: ClustersConfig,
    pub rlibs: RLibsConfig,
    /// Real OS threads the analytics engine may use for this
    /// invocation (CLI `-threads`); `None` = host parallelism. A
    /// runtime knob, deliberately not persisted with the session.
    pub threads: Option<usize>,
    engine: Box<dyn ScriptEngine>,
}

fn project_name(projectdir: &str) -> String {
    projectdir
        .trim_end_matches('/')
        .rsplit('/')
        .next()
        .unwrap_or(projectdir)
        .to_string()
}

/// Where a project lands on an instance: "synchronised at the home
/// directory of the root user" (§3.2.1).
fn remote_project_dir(projectdir: &str) -> String {
    format!("root/{}", project_name(projectdir))
}

/// Results directory at the Analyst site: "stored in a directory at the
/// same hierarchical level of the project directory" (§3.2.2).
fn local_results_dir(projectdir: &str) -> String {
    let base = projectdir.trim_end_matches('/');
    match base.rsplit_once('/') {
        Some((parent, name)) => format!("{parent}/{name}_results"),
        None => format!("{base}_results"),
    }
}

impl Session {
    /// Create a session against a fresh simulated cloud. `ec2configurep2rac`
    /// equivalent: seeds the platform config with the cloud's default AMI
    /// and a default snapshot.
    pub fn new(params: SimParams, engine: Box<dyn ScriptEngine>) -> Self {
        let mut cloud = SimCloud::new(params);
        let default_snapshot = cloud.create_snapshot(8.0, Vfs::new(), "p2rac default snapshot");
        let platform = PlatformConfig {
            default_ami: cloud.default_ami(false).id.clone(),
            default_snapshot,
            ..PlatformConfig::default()
        };
        let mut s = Self {
            cloud,
            analyst: Vfs::new(),
            platform,
            instances_cfg: InstancesConfig::default(),
            clusters_cfg: ClustersConfig::default(),
            rlibs: RLibsConfig::default(),
            threads: None,
            engine,
        };
        s.save_configs();
        s
    }

    /// Swap the script engine (used by benches to insert mocks).
    pub fn set_engine(&mut self, engine: Box<dyn ScriptEngine>) {
        self.engine = engine;
    }

    /// Persist the four config files onto the Analyst-site vfs.
    pub fn save_configs(&mut self) {
        self.analyst.write(
            &format!("{CONFIG_DIR}/p2rac.json"),
            self.platform.to_json().to_string_pretty().into_bytes(),
        );
        self.analyst.write(
            &format!("{CONFIG_DIR}/instances.json"),
            self.instances_cfg.to_json().to_string_pretty().into_bytes(),
        );
        self.analyst.write(
            &format!("{CONFIG_DIR}/clusters.json"),
            self.clusters_cfg.to_json().to_string_pretty().into_bytes(),
        );
        self.analyst.write(
            &format!("{CONFIG_DIR}/rlibs.json"),
            self.rlibs.to_json().to_string_pretty().into_bytes(),
        );
    }

    /// Serialize the whole session (cloud + analyst site + configs) for
    /// cross-invocation CLI use.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("cloud", self.cloud.to_json());
        j.set("analyst", self.analyst.to_json());
        j.set("platform", self.platform.to_json());
        j.set("instances", self.instances_cfg.to_json());
        j.set("clusters", self.clusters_cfg.to_json());
        j.set("rlibs", self.rlibs.to_json());
        j
    }

    /// Restore a persisted session with a fresh engine.
    pub fn from_json(
        params: SimParams,
        engine: Box<dyn ScriptEngine>,
        j: &Json,
    ) -> Result<Self> {
        Ok(Self {
            cloud: SimCloud::from_json(
                params,
                j.get("cloud").ok_or_else(|| anyhow!("missing cloud state"))?,
            )?,
            analyst: Vfs::from_json(
                j.get("analyst").ok_or_else(|| anyhow!("missing analyst state"))?,
            )?,
            platform: PlatformConfig::from_json(
                j.get("platform").ok_or_else(|| anyhow!("missing platform"))?,
            )?,
            instances_cfg: InstancesConfig::from_json(
                j.get("instances").ok_or_else(|| anyhow!("missing instances"))?,
            )?,
            clusters_cfg: ClustersConfig::from_json(
                j.get("clusters").ok_or_else(|| anyhow!("missing clusters"))?,
            )?,
            rlibs: RLibsConfig::from_json(
                j.get("rlibs").ok_or_else(|| anyhow!("missing rlibs"))?,
            )?,
            threads: None,
            engine,
        })
    }

    // ===================================================== name resolution

    fn resolve_iname(&self, iname: Option<&str>) -> Result<String> {
        match iname {
            Some(n) => Ok(n.to_string()),
            None => self
                .platform
                .default_instance
                .clone()
                .ok_or_else(|| anyhow!("no -iname given and no default instance configured")),
        }
    }

    fn resolve_cname(&self, cname: Option<&str>) -> Result<String> {
        match cname {
            Some(n) => Ok(n.to_string()),
            None => self
                .platform
                .default_cluster
                .clone()
                .ok_or_else(|| anyhow!("no -cname given and no default cluster configured")),
        }
    }

    fn instance_entry(&self, name: &str) -> Result<&InstanceEntry> {
        self.instances_cfg
            .get(name)
            .ok_or_else(|| anyhow!("no instance named '{name}' in the configuration file"))
    }

    fn cluster_entry(&self, name: &str) -> Result<&ClusterEntry> {
        self.clusters_cfg
            .get(name)
            .ok_or_else(|| anyhow!("no cluster named '{name}' in the configuration file"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{MockEngine, ResourceView, ScriptEngine, TaskOutput};
    use crate::coordinator::scheduler::Placement;
    use crate::simcloud::SpanCategory;

    fn session() -> Session {
        Session::new(SimParams::default(), Box::new(MockEngine::new(1000.0)))
    }

    fn write_project(s: &mut Session, dir: &str, data_bytes: usize) {
        s.analyst.write(
            &format!("{dir}/sweep.json"),
            br#"{"type":"mock","slaves":4}"#.to_vec(),
        );
        s.analyst
            .write(&format!("{dir}/data/input.bin"), vec![7u8; data_bytes]);
    }

    #[test]
    fn instance_workflow_figure2() {
        // The full Fig-2 workflow: create → send → run → fetch → terminate.
        let mut s = session();
        write_project(&mut s, "home/analyst/sweep", 50_000);
        let name = s
            .create_instance(&CreateInstanceOpts {
                iname: Some("hpc_instance".into()),
                itype: Some("m2.4xlarge".into()),
                desc: Some("For Trial Simulation Run".into()),
                ..Default::default()
            })
            .unwrap();
        assert_eq!(name, "hpc_instance");
        assert!(s.instances_cfg.contains("hpc_instance"));

        let rep = s
            .send_data_to_instance(Some("hpc_instance"), "home/analyst/sweep")
            .unwrap();
        assert_eq!(rep.files_sent, 2);

        let out = s
            .run_on_instance(Some("hpc_instance"), "home/analyst/sweep", "sweep.json", "run1")
            .unwrap();
        assert!(out.compute_s > 0.0);

        let fetched = s
            .get_results_from_instance(Some("hpc_instance"), "home/analyst/sweep", "run1")
            .unwrap();
        assert!(fetched.files_sent >= 1);
        assert!(s
            .analyst
            .exists("home/analyst/sweep_results/run1/summary.json"));

        s.terminate_instance(Some("hpc_instance"), true).unwrap();
        assert!(!s.instances_cfg.contains("hpc_instance"));
        assert!(s.cloud.live_instances().is_empty());
    }

    #[test]
    fn cluster_workflow_figure3() {
        let mut s = session();
        write_project(&mut s, "home/analyst/catopt", 80_000);
        let name = s
            .create_cluster(&CreateClusterOpts {
                cname: Some("hpc_cluster".into()),
                csize: Some(4),
                itype: Some("m2.2xlarge".into()),
                ..Default::default()
            })
            .unwrap();
        let entry = s.clusters_cfg.get(&name).unwrap().clone();
        assert_eq!(entry.size, 4);
        assert_eq!(entry.worker_ids.len(), 3);
        // Master holds the volume; workers NFS-mount it.
        let master = s.cloud.instance(&entry.master_id).unwrap();
        assert!(master.attached_volume.is_some());
        for w in &entry.worker_ids {
            assert_eq!(
                s.cloud.instance(w).unwrap().nfs_mount_from,
                master.attached_volume
            );
        }

        let reps = s
            .send_data_to_cluster_nodes(Some("hpc_cluster"), "home/analyst/catopt")
            .unwrap();
        assert_eq!(reps.len(), 4);
        for id in entry.all_ids() {
            assert!(s
                .cloud
                .instance(&id)
                .unwrap()
                .fs
                .exists("root/catopt/sweep.json"));
        }

        let out = s
            .run_on_cluster(
                Some("hpc_cluster"),
                "home/analyst/catopt",
                "sweep.json",
                "trial1",
                Placement::ByNode,
            )
            .unwrap();
        assert!(out.compute_s > 0.0);

        let rep = s
            .get_results(
                Some("hpc_cluster"),
                "home/analyst/catopt",
                "trial1",
                ResultScope::FromMaster,
            )
            .unwrap();
        assert!(rep.files_sent >= 1);
        assert!(s
            .analyst
            .exists("home/analyst/catopt_results/trial1/summary.json"));

        s.terminate_cluster(Some("hpc_cluster"), false).unwrap();
        assert!(s.cloud.live_instances().is_empty());
        // Volume persisted (no -deletevol).
        assert_eq!(s.cloud.live_volumes().len(), 1);
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut s = session();
        s.create_instance(&CreateInstanceOpts {
            iname: Some("a".into()),
            ..Default::default()
        })
        .unwrap();
        assert!(s
            .create_instance(&CreateInstanceOpts {
                iname: Some("a".into()),
                ..Default::default()
            })
            .is_err());
    }

    #[test]
    fn ebsvol_and_snap_conflict() {
        let mut s = session();
        let e = s.create_instance(&CreateInstanceOpts {
            iname: Some("x".into()),
            ebsvol: Some("vol-1".into()),
            snap: Some("snap-1".into()),
            ..Default::default()
        });
        assert!(e.unwrap_err().to_string().contains("cannot be specified"));
    }

    #[test]
    fn in_use_cluster_refuses_termination() {
        let mut s = session();
        s.create_cluster(&CreateClusterOpts {
            cname: Some("c".into()),
            csize: Some(2),
            ..Default::default()
        })
        .unwrap();
        s.set_cluster_lock("c", true).unwrap();
        assert!(s.terminate_cluster(Some("c"), false).is_err());
        s.set_cluster_lock("c", false).unwrap();
        s.terminate_cluster(Some("c"), false).unwrap();
    }

    #[test]
    fn run_locks_and_unlocks() {
        let mut s = session();
        write_project(&mut s, "p", 1000);
        s.create_instance(&CreateInstanceOpts {
            iname: Some("i".into()),
            ..Default::default()
        })
        .unwrap();
        s.send_data_to_instance(Some("i"), "p").unwrap();
        s.run_on_instance(Some("i"), "p", "sweep.json", "r1").unwrap();
        // Unlocked afterwards.
        assert!(!s.instances_cfg.get("i").unwrap().in_use);
        // Manual lock blocks a run.
        s.set_instance_lock("i", true).unwrap();
        assert!(s.run_on_instance(Some("i"), "p", "sweep.json", "r2").is_err());
    }

    #[test]
    fn missing_script_is_an_error() {
        let mut s = session();
        write_project(&mut s, "p", 100);
        s.create_instance(&CreateInstanceOpts {
            iname: Some("i".into()),
            ..Default::default()
        })
        .unwrap();
        s.send_data_to_instance(Some("i"), "p").unwrap();
        let e = s.run_on_instance(Some("i"), "p", "nope.json", "r");
        assert!(e.unwrap_err().to_string().contains("not found"));
    }

    #[test]
    fn default_names_from_platform_config() {
        let mut s = session();
        write_project(&mut s, "p", 100);
        s.create_instance(&CreateInstanceOpts {
            iname: Some("only".into()),
            ..Default::default()
        })
        .unwrap();
        // iname omitted → default instance from config.
        s.send_data_to_instance(None, "p").unwrap();
        assert!(s
            .cloud
            .find_by_name("only")
            .unwrap()
            .fs
            .exists("root/p/sweep.json"));
    }

    #[test]
    fn terminate_all_clears_everything() {
        let mut s = session();
        s.create_instance(&CreateInstanceOpts {
            iname: Some("i1".into()),
            ..Default::default()
        })
        .unwrap();
        s.create_cluster(&CreateClusterOpts {
            cname: Some("c1".into()),
            csize: Some(2),
            ..Default::default()
        })
        .unwrap();
        let log = s.terminate_all(true, true, true, true).unwrap();
        assert!(log.len() >= 4);
        assert!(s.cloud.live_instances().is_empty());
        assert!(s.cloud.live_volumes().is_empty());
        assert!(s.cloud.live_snapshots().is_empty());
        assert!(s.instances_cfg.names().is_empty());
        assert!(s.clusters_cfg.names().is_empty());
    }

    #[test]
    fn management_spans_recorded_for_figures() {
        let mut s = session();
        write_project(&mut s, "p", 10_000);
        s.create_cluster(&CreateClusterOpts {
            cname: Some("c".into()),
            csize: Some(4),
            ..Default::default()
        })
        .unwrap();
        s.send_data_to_master(Some("c"), "p").unwrap();
        s.send_data_to_cluster_nodes(Some("c"), "p").unwrap();
        s.run_on_cluster(Some("c"), "p", "sweep.json", "r", Placement::ByNode)
            .unwrap();
        s.get_results(Some("c"), "p", "r", ResultScope::FromMaster).unwrap();
        s.terminate_cluster(Some("c"), false).unwrap();
        let cl = &s.cloud.clock;
        assert!(cl.category_total_s(SpanCategory::CreateResource) > 0.0);
        assert!(cl.category_total_s(SpanCategory::SubmitToMaster) > 0.0);
        assert!(cl.category_total_s(SpanCategory::SubmitToAllNodes) > 0.0);
        assert!(cl.category_total_s(SpanCategory::FetchFromMaster) > 0.0);
        assert!(cl.category_total_s(SpanCategory::TerminateResource) > 0.0);
        assert!(cl.category_total_s(SpanCategory::Compute) > 0.0);
        // Creation dominates for small data (paper Figs 6–7 shape).
        assert!(
            cl.category_total_s(SpanCategory::CreateResource)
                > cl.category_total_s(SpanCategory::SubmitToMaster)
        );
    }

    #[test]
    fn worker_results_gathered_fromall() {
        // Engine that writes files on workers (paper's scenario 3).
        struct WorkerEngine;
        impl ScriptEngine for WorkerEngine {
            fn run(
                &mut self,
                _s: &str,
                _j: &Json,
                _p: &Vfs,
                _d: &str,
                r: &ResourceView,
            ) -> anyhow::Result<TaskOutput> {
                Ok(TaskOutput {
                    master_files: vec![("agg.json".into(), b"{}".to_vec())],
                    worker_files: (0..r.nodes.len() - 1)
                        .map(|w| (w, format!("part{w}.bin"), vec![w as u8; 64]))
                        .collect(),
                    compute_s: 10.0,
                    summary: Json::Null,
                })
            }
        }
        let mut s = Session::new(SimParams::default(), Box::new(WorkerEngine));
        write_project(&mut s, "p", 1000);
        s.create_cluster(&CreateClusterOpts {
            cname: Some("c".into()),
            csize: Some(3),
            ..Default::default()
        })
        .unwrap();
        s.send_data_to_cluster_nodes(Some("c"), "p").unwrap();
        s.run_on_cluster(Some("c"), "p", "sweep.json", "r", Placement::ByNode)
            .unwrap();
        let rep = s
            .get_results(Some("c"), "p", "r", ResultScope::FromAll)
            .unwrap();
        assert!(rep.files_sent >= 3);
        assert!(s.analyst.exists("p_results/r/master/agg.json"));
        assert!(s.analyst.exists("p_results/r/worker0/part0.bin"));
        assert!(s.analyst.exists("p_results/r/worker1/part1.bin"));
        // fromworkers only:
        let rep2 = s
            .get_results(Some("c"), "p", "r", ResultScope::FromWorkers)
            .unwrap();
        assert!(rep2.files_unchanged + rep2.files_sent >= 2);
    }

    #[test]
    fn memory_infeasible_byslot_rejected() {
        let mut s = session();
        s.analyst.write(
            "p/big.json",
            br#"{"type":"mock","slaves":4,"mem_gb_per_proc":30.0}"#.to_vec(),
        );
        s.create_cluster(&CreateClusterOpts {
            cname: Some("c".into()),
            csize: Some(4),
            itype: Some("m2.2xlarge".into()),
            ..Default::default()
        })
        .unwrap();
        s.send_data_to_cluster_nodes(Some("c"), "p").unwrap();
        // 4 × 30 GB on one 34.2 GB node → infeasible byslot…
        let e = s.run_on_cluster(Some("c"), "p", "big.json", "r", Placement::BySlot);
        assert!(e.is_err());
        // …but bynode spreads them, one per node.
        assert!(!s.clusters_cfg.get("c").unwrap().in_use, "must unlock after failure");
        s.run_on_cluster(Some("c"), "p", "big.json", "r", Placement::ByNode)
            .unwrap();
    }

    #[test]
    fn login_banner_mentions_dns() {
        let mut s = session();
        s.create_instance(&CreateInstanceOpts {
            iname: Some("i".into()),
            ..Default::default()
        })
        .unwrap();
        let b = s.login_banner(Some("i"), None).unwrap();
        assert!(b.contains("ssh root@ec2-"));
    }

    #[test]
    fn spot_cluster_interruption_reclaims_but_keeps_volume() {
        let mut s = session();
        s.create_cluster(&CreateClusterOpts {
            cname: Some("sc".into()),
            csize: Some(3),
            spot: true,
            ..Default::default()
        })
        .unwrap();
        let e = s.clusters_cfg.get("sc").unwrap().clone();
        let vol = e.volume_id.clone().unwrap();
        for id in e.all_ids() {
            assert!(s.cloud.instance(&id).unwrap().is_spot());
        }
        // A run is in flight — interruptions do not care.
        s.set_cluster_lock("sc", true).unwrap();
        s.spot_interrupt_cluster("sc").unwrap();
        assert!(s.clusters_cfg.get("sc").is_none());
        assert!(s.cloud.live_instances().is_empty());
        assert!(
            s.cloud.volume(&vol).is_ok(),
            "EBS volume must survive the interruption"
        );
    }

    #[test]
    fn desktop_local_run_writes_results() {
        let mut s = session();
        write_project(&mut s, "p", 500);
        let d = table1_desktops();
        let out = s.run_local(&d[0], "p", "sweep.json", "r1").unwrap();
        assert!(out.compute_s > 0.0);
        assert!(s.analyst.exists("p_results/r1/summary.json"));
    }

    #[test]
    fn analyst_tag_rides_instances_into_the_ledger() {
        let mut s = session();
        s.create_instance(&CreateInstanceOpts {
            iname: Some("i".into()),
            analyst: Some("alice".into()),
            ..Default::default()
        })
        .unwrap();
        let id = s.instances_cfg.get("i").unwrap().instance_id.clone();
        assert_eq!(
            s.cloud.instance(&id).unwrap().tags.get("p2rac:analyst"),
            Some(&"alice".to_string())
        );
        s.terminate_instance(Some("i"), true).unwrap();
        // The instance-hours landed on alice's side of the ledger.
        assert!(s.cloud.ledger.total_centi_cents_for("alice") > 0);
        assert!(s.cloud.ledger.analysts().contains(&"alice".to_string()));
    }
}
