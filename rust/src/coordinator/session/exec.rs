//! Execution management (paper §3.2.2): running a script on an
//! instance, a cluster (with bynode/byslot placement and the memory
//! feasibility check) or a Table-I desktop.

use super::{local_results_dir, remote_project_dir, Session};
use crate::coordinator::engine::{ResourceView, TaskOutput};
use crate::coordinator::scheduler::{self, NodeSpec, Placement};
use crate::simcloud::{instance_type, SpanCategory, Vfs};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};

impl Session {
    pub(super) fn load_script(fs: &Vfs, project_dir: &str, rscript: &str) -> Result<Json> {
        let path = format!("{project_dir}/{rscript}");
        let bytes = fs
            .read(&path)
            .ok_or_else(|| anyhow!("script '{rscript}' not found in project directory"))?;
        let text = std::str::from_utf8(bytes).context("script is not UTF-8")?;
        Json::parse(text).map_err(|e| anyhow!("script '{rscript}' is not valid JSON: {e}"))
    }

    /// List candidate scripts in a project dir (used when `-rscript` is
    /// omitted and the CLI prompts the Analyst).
    pub fn list_scripts(&self, projectdir: &str) -> Vec<String> {
        self.analyst
            .list_dir(projectdir)
            .into_iter()
            .filter(|f| f.ends_with(".json") && !f.starts_with("results/"))
            .collect()
    }

    /// `ec2runoninstance`.
    pub fn run_on_instance(
        &mut self,
        iname: Option<&str>,
        projectdir: &str,
        rscript: &str,
        runname: &str,
    ) -> Result<TaskOutput> {
        let name = self.resolve_iname(iname)?;
        let entry = self.instance_entry(&name)?.clone();
        if entry.in_use {
            bail!("instance '{name}' is locked by another run");
        }
        let inst = self.cloud.instance(&entry.instance_id)?;
        let spec = inst.itype;
        let pdir = remote_project_dir(projectdir);
        let project = inst.fs.clone();
        let script = Self::load_script(&project, &pdir, rscript)?;

        // Lock for the duration of the run (§3.2.1).
        self.set_instance_lock(&name, true)?;
        let nodes = vec![NodeSpec {
            name: name.clone(),
            cores: spec.cores,
            mem_gb: spec.mem_gb,
            core_speed: spec.core_speed,
        }];
        let nproc = script
            .get("slaves")
            .and_then(Json::as_usize)
            .unwrap_or(spec.cores);
        let assignment = vec![0usize; nproc];
        let view = ResourceView {
            nodes,
            assignment,
            net: self.cloud.net.clone(),
            resource_name: name.clone(),
            real_threads: self.threads,
        };
        let out = self.engine.run(rscript, &script, &project, &pdir, &view);
        // Always unlock, even on engine failure.
        self.set_instance_lock(&name, false)?;
        let out = out?;

        let start = self.cloud.clock.now_s();
        self.cloud.clock.advance(out.compute_s);
        self.cloud.clock.push_span(
            SpanCategory::Compute,
            &format!("run {rscript} ({runname}) on instance {name}"),
            start,
        );
        // Results land in results/<runname>/ inside the project dir.
        let fs = self.cloud.instance_fs_mut(&entry.instance_id)?;
        for (rel, bytes) in &out.master_files {
            fs.write(&format!("{pdir}/results/{runname}/{rel}"), bytes.clone());
        }
        Ok(out)
    }

    /// `ec2runoncluster`.
    pub fn run_on_cluster(
        &mut self,
        cname: Option<&str>,
        projectdir: &str,
        rscript: &str,
        runname: &str,
        placement: Placement,
    ) -> Result<TaskOutput> {
        let name = self.resolve_cname(cname)?;
        let entry = self.cluster_entry(&name)?.clone();
        if entry.in_use {
            bail!("cluster '{name}' is locked by another run");
        }
        let spec = instance_type(&entry.instance_type)
            .ok_or_else(|| anyhow!("unknown type in config: {}", entry.instance_type))?;
        let pdir = remote_project_dir(projectdir);
        let master = self.cloud.instance(&entry.master_id)?;
        let project = master.fs.clone();
        let script = Self::load_script(&project, &pdir, rscript)?;

        self.set_cluster_lock(&name, true)?;
        let nodes: Vec<NodeSpec> = entry
            .all_ids()
            .iter()
            .enumerate()
            .map(|(i, _)| NodeSpec {
                name: if i == 0 {
                    format!("{name}_Master")
                } else {
                    format!("{name}_Worker{i}")
                },
                cores: spec.cores,
                mem_gb: spec.mem_gb,
                core_speed: spec.core_speed,
            })
            .collect();
        let total_cores: usize = nodes.iter().map(|n| n.cores).sum();
        let nproc = script
            .get("slaves")
            .and_then(Json::as_usize)
            .unwrap_or(total_cores);
        // Memory feasibility check — the reason bynode exists (§3.2.2).
        if let Some(mem) = script.get("mem_gb_per_proc").and_then(Json::as_f64) {
            if !scheduler::feasible(nproc, mem, &nodes, placement) {
                self.set_cluster_lock(&name, false)?;
                bail!(
                    "{nproc} processes needing {mem} GB each do not fit under {placement:?}; \
                     try -bynode or fewer slaves"
                );
            }
        }
        let assignment = scheduler::schedule(nproc, &nodes, placement);
        let view = ResourceView {
            nodes,
            assignment,
            net: self.cloud.net.clone(),
            resource_name: name.clone(),
            real_threads: self.threads,
        };
        let out = self.engine.run(rscript, &script, &project, &pdir, &view);
        self.set_cluster_lock(&name, false)?;
        let out = out?;

        let start = self.cloud.clock.now_s();
        self.cloud.clock.advance(out.compute_s);
        self.cloud.clock.push_span(
            SpanCategory::Compute,
            &format!("run {rscript} ({runname}) on cluster {name}"),
            start,
        );
        // Scenario 1/3 files on the master…
        let master_fs = self.cloud.instance_fs_mut(&entry.master_id)?;
        for (rel, bytes) in &out.master_files {
            master_fs.write(&format!("{pdir}/results/{runname}/{rel}"), bytes.clone());
        }
        // …scenario 2/3 files on the workers.
        for (widx, rel, bytes) in &out.worker_files {
            let Some(wid) = entry.worker_ids.get(*widx) else {
                bail!("engine wrote to nonexistent worker {widx}");
            };
            let fs = self.cloud.instance_fs_mut(wid)?;
            fs.write(&format!("{pdir}/results/{runname}/{rel}"), bytes.clone());
        }
        Ok(out)
    }

    /// Run a script locally on a Table-I desktop (Fig 5 comparison).
    pub fn run_local(
        &mut self,
        desktop: &super::DesktopSpec,
        projectdir: &str,
        rscript: &str,
        runname: &str,
    ) -> Result<TaskOutput> {
        let script = Self::load_script(&self.analyst, projectdir, rscript)?;
        let nproc = script
            .get("slaves")
            .and_then(Json::as_usize)
            .unwrap_or(desktop.cores);
        let view = ResourceView {
            nodes: vec![NodeSpec {
                name: desktop.name.clone(),
                cores: desktop.cores,
                mem_gb: desktop.mem_gb,
                core_speed: desktop.core_speed,
            }],
            assignment: vec![0; nproc],
            net: self.cloud.net.clone(),
            resource_name: desktop.name.clone(),
            real_threads: self.threads,
        };
        let project = self.analyst.clone();
        let out = self.engine.run(rscript, &script, &project, projectdir, &view)?;
        let start = self.cloud.clock.now_s();
        self.cloud.clock.advance(out.compute_s);
        self.cloud.clock.push_span(
            SpanCategory::Compute,
            &format!("run {rscript} ({runname}) on {}", desktop.name),
            start,
        );
        let local = format!("{}/{runname}", local_results_dir(projectdir));
        for (rel, bytes) in &out.master_files {
            self.analyst.write(&format!("{local}/{rel}"), bytes.clone());
        }
        Ok(out)
    }
}
