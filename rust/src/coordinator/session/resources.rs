//! Resource management (paper §3.2.1/§3.2.2): creating, terminating,
//! resizing and locking instances and clusters, plus the spot-reclaim
//! teardown path and the `ec2terminateall` big red switch.

use super::{spot_bid, CreateClusterOpts, CreateInstanceOpts, Session};
use crate::config::{ClusterEntry, InstanceEntry};
use crate::simcloud::{instance_type, CloudError, Lifecycle, SpanCategory};
use anyhow::{anyhow, bail, Context, Result};

impl Session {
    /// `ec2createinstance`.
    pub fn create_instance(&mut self, opts: &CreateInstanceOpts) -> Result<String> {
        let name = opts
            .iname
            .clone()
            .unwrap_or_else(|| format!("instance{}", self.instances_cfg.entries.len() + 1));
        if self.instances_cfg.contains(&name) {
            bail!("an instance named '{name}' already exists (names must be unique)");
        }
        let itype = opts
            .itype
            .clone()
            .unwrap_or_else(|| self.platform.default_type.clone());
        let spec = instance_type(&itype)
            .ok_or_else(|| anyhow!("instance type '{itype}' is not offered"))?;
        let ami = if spec.hvm {
            self.cloud.default_ami(true).id.clone()
        } else {
            self.platform.default_ami.clone()
        };

        let lifecycle = if opts.spot {
            spot_bid(spec, None)
        } else {
            Lifecycle::OnDemand
        };
        let start = self.cloud.clock.now_s();
        let ids = self
            .cloud
            .run_instances_as(1, &itype, &ami, &self.rlibs.libraries, lifecycle)
            .context("launching instance")?;
        let id = ids[0].clone();
        self.cloud.set_name(&id, &name)?;
        self.cloud.set_tag(&id, "p2rac:name", &name)?;
        if let Some(a) = &opts.analyst {
            self.cloud.set_tag(&id, "p2rac:analyst", a)?;
        }

        // Volume resolution: -ebsvol | -snap | default snapshot.
        let vol_id = match (&opts.ebsvol, &opts.snap) {
            (Some(_), Some(_)) => bail!("-ebsvol and -snap cannot be specified at the same time"),
            (Some(v), None) => {
                self.cloud.volume(v).map_err(|e| anyhow!(e.to_string()))?;
                v.clone()
            }
            (None, Some(s)) => self.cloud.create_volume_from_snapshot(s)?,
            (None, None) => self
                .cloud
                .create_volume_from_snapshot(&self.platform.default_snapshot)?,
        };
        self.cloud.attach_volume(&vol_id, &id)?;
        self.cloud.clock.push_span(
            SpanCategory::CreateResource,
            &format!("create instance {name}"),
            start,
        );

        let inst = self.cloud.instance(&id)?;
        self.instances_cfg.insert(
            &name,
            InstanceEntry {
                instance_id: id.clone(),
                public_dns: inst.public_dns.clone(),
                volume_id: Some(vol_id),
                instance_type: itype,
                description: opts.desc.clone().unwrap_or_default(),
                in_use: false,
            },
        );
        self.platform.default_instance = Some(name.clone());
        self.save_configs();
        Ok(name)
    }

    /// `ec2terminateinstance`.
    pub fn terminate_instance(&mut self, iname: Option<&str>, deletevol: bool) -> Result<()> {
        let name = self.resolve_iname(iname)?;
        let entry = self.instance_entry(&name)?.clone();
        if entry.in_use {
            bail!("instance '{name}' is in use; unlock it with ec2resourcelock -free first");
        }
        let start = self.cloud.clock.now_s();
        if let Some(vol) = &entry.volume_id {
            self.cloud.detach_volume(vol).ok();
        }
        self.cloud
            .terminate_instances(std::slice::from_ref(&entry.instance_id))?;
        if deletevol {
            if let Some(vol) = &entry.volume_id {
                self.cloud.delete_volume(vol)?;
            }
        }
        self.cloud.clock.push_span(
            SpanCategory::TerminateResource,
            &format!("terminate instance {name}"),
            start,
        );
        self.instances_cfg.remove(&name);
        if self.platform.default_instance.as_deref() == Some(name.as_str()) {
            self.platform.default_instance = self.instances_cfg.names().first().cloned();
        }
        self.save_configs();
        Ok(())
    }

    /// `ec2createcluster`.
    pub fn create_cluster(&mut self, opts: &CreateClusterOpts) -> Result<String> {
        let name = opts
            .cname
            .clone()
            .unwrap_or_else(|| format!("cluster{}", self.clusters_cfg.entries.len() + 1));
        if self.clusters_cfg.contains(&name) {
            bail!("a cluster named '{name}' already exists (names must be unique)");
        }
        let csize = opts.csize.unwrap_or(self.platform.default_cluster_size);
        if csize < 2 {
            bail!("cluster size must be at least 2 (1 master + workers), got {csize}");
        }
        let itype = opts
            .itype
            .clone()
            .unwrap_or_else(|| self.platform.default_type.clone());
        let spec = instance_type(&itype)
            .ok_or_else(|| anyhow!("instance type '{itype}' is not offered"))?;
        let ami = if spec.hvm {
            self.cloud.default_ami(true).id.clone()
        } else {
            self.platform.default_ami.clone()
        };

        let lifecycle = if opts.spot {
            spot_bid(spec, opts.bid_centi_cents_hour)
        } else {
            Lifecycle::OnDemand
        };
        let start = self.cloud.clock.now_s();
        let ids = self
            .cloud
            .run_instances_as(csize, &itype, &ami, &self.rlibs.libraries, lifecycle)
            .context("launching cluster instances")?;
        let master = ids[0].clone();
        let workers: Vec<String> = ids[1..].to_vec();
        self.cloud.set_tag(&master, "p2rac:role", &format!("{name}_Master"))?;
        for w in &workers {
            self.cloud.set_tag(w, "p2rac:role", &format!("{name}_Workers"))?;
        }
        if let Some(a) = &opts.analyst {
            for id in &ids {
                self.cloud.set_tag(id, "p2rac:analyst", a)?;
            }
        }

        let vol_id = match (&opts.ebsvol, &opts.snap) {
            (Some(_), Some(_)) => bail!("-ebsvol and -snap cannot be specified at the same time"),
            (Some(v), None) => {
                self.cloud.volume(v).map_err(|e| anyhow!(e.to_string()))?;
                v.clone()
            }
            (None, Some(s)) => self.cloud.create_volume_from_snapshot(s)?,
            (None, None) => self
                .cloud
                .create_volume_from_snapshot(&self.platform.default_snapshot)?,
        };
        self.cloud.attach_volume(&vol_id, &master)?;
        self.cloud.nfs_export(&master, &vol_id, &workers)?;
        // Master/worker configuration (hosts files, SNOW socket setup).
        let cfg_s = self.cloud.params().cluster_config_base_s;
        self.cloud.clock.advance(cfg_s);
        self.cloud.clock.push_span(
            SpanCategory::CreateResource,
            &format!("create cluster {name} ({csize} nodes)"),
            start,
        );

        let master_dns = self.cloud.instance(&master)?.public_dns.clone();
        let worker_dns: Vec<String> = workers
            .iter()
            .map(|w| self.cloud.instance(w).map(|i| i.public_dns.clone()))
            .collect::<std::result::Result<_, CloudError>>()?;
        self.clusters_cfg.insert(
            &name,
            ClusterEntry {
                size: csize,
                master_id: master,
                master_dns,
                worker_ids: workers,
                worker_dns,
                volume_id: Some(vol_id),
                instance_type: itype,
                description: opts.desc.clone().unwrap_or_default(),
                in_use: false,
            },
        );
        self.platform.default_cluster = Some(name.clone());
        self.save_configs();
        Ok(name)
    }

    /// `ec2terminatecluster`.
    pub fn terminate_cluster(&mut self, cname: Option<&str>, deletevol: bool) -> Result<()> {
        let name = self.resolve_cname(cname)?;
        let entry = self.cluster_entry(&name)?.clone();
        // "whether a cluster is in use is firstly checked" (§3.2.2).
        if entry.in_use {
            bail!("cluster '{name}' is in use and cannot be terminated");
        }
        let start = self.cloud.clock.now_s();
        self.cloud.nfs_unexport(&entry.worker_ids)?;
        if let Some(vol) = &entry.volume_id {
            self.cloud.detach_volume(vol).ok();
        }
        self.cloud.terminate_instances(&entry.all_ids())?;
        if deletevol {
            if let Some(vol) = &entry.volume_id {
                self.cloud.delete_volume(vol)?;
            }
        }
        self.cloud.clock.push_span(
            SpanCategory::TerminateResource,
            &format!("terminate cluster {name}"),
            start,
        );
        self.clusters_cfg.remove(&name);
        if self.platform.default_cluster.as_deref() == Some(name.as_str()) {
            self.platform.default_cluster = self.clusters_cfg.names().first().cloned();
        }
        self.save_configs();
        Ok(())
    }

    /// `ec2resizecluster` — the dynamic scaling the paper lists as
    /// future work (§5): grow or shrink a running cluster. New workers
    /// boot, NFS-mount the master's volume and join the worker pool;
    /// removed workers are drained (refused while the cluster is
    /// locked) and terminated.
    pub fn resize_cluster(&mut self, cname: Option<&str>, new_size: usize) -> Result<()> {
        let name = self.resolve_cname(cname)?;
        let entry = self.cluster_entry(&name)?.clone();
        if entry.in_use {
            bail!("cluster '{name}' is in use; cannot resize mid-run");
        }
        if new_size < 2 {
            bail!("cluster size must be at least 2, got {new_size}");
        }
        if new_size == entry.size {
            return Ok(());
        }
        let start = self.cloud.clock.now_s();
        let mut worker_ids = entry.worker_ids.clone();
        let mut worker_dns = entry.worker_dns.clone();
        if new_size > entry.size {
            // Grow: boot the delta as one batch, mount the shared
            // volume. New workers inherit the master's purchase model
            // (a spot cluster grows with spot capacity).
            let add = new_size - entry.size;
            let (ami, lifecycle, owner) = {
                let inst = self.cloud.instance(&entry.master_id)?;
                (
                    inst.ami_id.clone(),
                    inst.lifecycle,
                    inst.tags.get("p2rac:analyst").cloned(),
                )
            };
            let ids = self
                .cloud
                .run_instances_as(add, &entry.instance_type, &ami, &self.rlibs.libraries, lifecycle)
                .context("scaling cluster up")?;
            if let Some(vol) = &entry.volume_id {
                self.cloud.nfs_export(&entry.master_id, vol, &ids)?;
            }
            for id in &ids {
                self.cloud
                    .set_tag(id, "p2rac:role", &format!("{name}_Workers"))?;
                // Grown capacity belongs to whoever owns the cluster.
                if let Some(a) = &owner {
                    self.cloud.set_tag(id, "p2rac:analyst", a)?;
                }
                worker_dns.push(self.cloud.instance(id)?.public_dns.clone());
            }
            worker_ids.extend(ids);
        } else {
            // Shrink: drain and terminate the tail workers.
            let drop_n = entry.size - new_size;
            let dropped: Vec<String> = worker_ids.split_off(worker_ids.len() - drop_n);
            worker_dns.truncate(worker_dns.len() - drop_n);
            self.cloud.nfs_unexport(&dropped)?;
            self.cloud.terminate_instances(&dropped)?;
        }
        self.cloud.clock.push_span(
            SpanCategory::CreateResource,
            &format!("resize cluster {name} {} -> {new_size}", entry.size),
            start,
        );
        let e = self.clusters_cfg.get_mut(&name).expect("checked above");
        e.size = new_size;
        e.worker_ids = worker_ids;
        e.worker_dns = worker_dns;
        self.save_configs();
        Ok(())
    }

    /// The provider reclaims a spot cluster (price exceeded the bid).
    /// Unlike [`Session::terminate_cluster`] this ignores the in-use
    /// lock — interruptions do not wait for runs to finish — and bills
    /// every node with the interrupted-partial-hour-free rule. The
    /// shared EBS volume survives, exactly like a real interruption:
    /// anything checkpointed to it is recoverable by replacement
    /// capacity.
    pub fn spot_interrupt_cluster(&mut self, cname: &str) -> Result<()> {
        let entry = self.cluster_entry(cname)?.clone();
        let start = self.cloud.clock.now_s();
        self.cloud.nfs_unexport(&entry.worker_ids)?;
        if let Some(vol) = &entry.volume_id {
            self.cloud.detach_volume(vol).ok();
        }
        self.cloud.spot_interrupt_instances(&entry.all_ids())?;
        self.cloud.clock.push_span(
            SpanCategory::TerminateResource,
            &format!("spot interruption reclaims cluster {cname}"),
            start,
        );
        self.clusters_cfg.remove(cname);
        if self.platform.default_cluster.as_deref() == Some(cname) {
            self.platform.default_cluster = self.clusters_cfg.names().first().cloned();
        }
        self.save_configs();
        Ok(())
    }

    /// `ec2terminateall`.
    pub fn terminate_all(
        &mut self,
        instances: bool,
        clusters: bool,
        ebsvolumes: bool,
        snapshots: bool,
    ) -> Result<Vec<String>> {
        let mut log = Vec::new();
        if clusters {
            for name in self.clusters_cfg.names() {
                // Force-unlock: ec2terminateall is the big red switch.
                if let Some(e) = self.clusters_cfg.get_mut(&name) {
                    e.in_use = false;
                }
                self.terminate_cluster(Some(&name), false)?;
                log.push(format!("terminated cluster {name}"));
            }
        }
        if instances {
            for name in self.instances_cfg.names() {
                if let Some(e) = self.instances_cfg.entries.get_mut(&name) {
                    e.in_use = false;
                }
                let id = self.instance_entry(&name)?.instance_id.clone();
                self.cloud.set_lock(&id, false).ok();
                self.terminate_instance(Some(&name), false)?;
                log.push(format!("terminated instance {name}"));
            }
        }
        if ebsvolumes {
            for v in self
                .cloud
                .live_volumes()
                .iter()
                .map(|v| v.id.clone())
                .collect::<Vec<_>>()
            {
                match self.cloud.delete_volume(&v) {
                    Ok(()) => log.push(format!("deleted volume {v}")),
                    Err(CloudError::VolumeInUse(..)) => {
                        log.push(format!("skipped attached volume {v}"))
                    }
                    Err(e) => return Err(e.into()),
                }
            }
        }
        if snapshots {
            for s in self
                .cloud
                .live_snapshots()
                .iter()
                .map(|s| s.id.clone())
                .collect::<Vec<_>>()
            {
                self.cloud.delete_snapshot(&s)?;
                log.push(format!("deleted snapshot {s}"));
            }
        }
        self.save_configs();
        Ok(log)
    }

    // ========================================================== diagnostics

    /// `ec2resourcelock` on an instance.
    pub fn set_instance_lock(&mut self, iname: &str, in_use: bool) -> Result<()> {
        let entry = self.instance_entry(iname)?.clone();
        self.cloud.set_lock(&entry.instance_id, in_use)?;
        self.instances_cfg
            .entries
            .get_mut(iname)
            .expect("checked above")
            .in_use = in_use;
        self.save_configs();
        Ok(())
    }

    /// `ec2resourcelock` on a cluster.
    pub fn set_cluster_lock(&mut self, cname: &str, in_use: bool) -> Result<()> {
        let entry = self.cluster_entry(cname)?.clone();
        for id in entry.all_ids() {
            self.cloud.set_lock(&id, in_use)?;
        }
        self.clusters_cfg
            .get_mut(cname)
            .expect("checked above")
            .in_use = in_use;
        self.save_configs();
        Ok(())
    }

    /// `ec2listinstances`.
    pub fn list_instances(&self, names_only: bool) -> Vec<String> {
        self.instances_cfg
            .entries
            .iter()
            .map(|(name, e)| {
                if names_only {
                    name.clone()
                } else {
                    format!(
                        "{name}  dns={}  vol={}  type={}  inuse={}  desc={:?}",
                        e.public_dns,
                        e.volume_id.as_deref().unwrap_or("-"),
                        e.instance_type,
                        e.in_use,
                        e.description
                    )
                }
            })
            .collect()
    }

    /// `ec2listclusters`.
    pub fn list_clusters(&self, names_only: bool) -> Vec<String> {
        self.clusters_cfg
            .entries
            .iter()
            .map(|(name, e)| {
                if names_only {
                    name.clone()
                } else {
                    format!(
                        "{name}  size={}  master={}  workers=[{}]  vol={}  inuse={}  desc={:?}",
                        e.size,
                        e.master_dns,
                        e.worker_dns.join(", "),
                        e.volume_id.as_deref().unwrap_or("-"),
                        e.in_use,
                        e.description
                    )
                }
            })
            .collect()
    }

    /// Cluster names owned by `analyst` — the clusters whose master
    /// instance carries that `p2rac:analyst` tag (`ec2createcluster
    /// -analyst`). Used by the governance quota check on the create
    /// path.
    pub fn clusters_owned_by(&self, analyst: &str) -> Vec<String> {
        self.clusters_cfg
            .entries
            .iter()
            .filter(|(_, e)| {
                self.cloud
                    .instance(&e.master_id)
                    .ok()
                    .and_then(|i| i.tags.get("p2rac:analyst"))
                    .map(|a| a.as_str() == analyst)
                    .unwrap_or(false)
            })
            .map(|(name, _)| name.clone())
            .collect()
    }

    /// `ec2listallresources`.
    pub fn list_all_resources(
        &self,
        instances: bool,
        ebsvols: bool,
        snapshots: bool,
        amis: bool,
    ) -> Vec<String> {
        let mut out = Vec::new();
        if instances {
            for i in self.cloud.live_instances() {
                out.push(format!(
                    "instance {}  type={}  name={}",
                    i.id,
                    i.itype.api_name,
                    i.name.as_deref().unwrap_or("-")
                ));
            }
        }
        if ebsvols {
            for v in self.cloud.live_volumes() {
                out.push(format!(
                    "volume {}  {:.0}GiB  attached_to={}",
                    v.id,
                    v.size_gb,
                    v.attached_to.as_deref().unwrap_or("-")
                ));
            }
        }
        if snapshots {
            for s in self.cloud.live_snapshots() {
                out.push(format!("snapshot {}  {:.0}GiB  {:?}", s.id, s.size_gb, s.description));
            }
        }
        if amis {
            for a in self.cloud.amis() {
                out.push(format!("ami {}  {}  hvm={}", a.id, a.name, a.hvm));
            }
        }
        out
    }

    /// `ec2logintoinstance` / `ec2logintocluster` (simulated SSH): returns
    /// the login banner for the target machine.
    pub fn login_banner(&self, iname: Option<&str>, cname: Option<&str>) -> Result<String> {
        let (dns, what) = if let Some(c) = cname {
            let e = self.cluster_entry(c)?;
            (e.master_dns.clone(), format!("master of cluster {c}"))
        } else {
            let name = self.resolve_iname(iname)?;
            let e = self.instance_entry(&name)?;
            (e.public_dns.clone(), format!("instance {name}"))
        };
        Ok(format!(
            "ssh root@{dns}\nWelcome to Ubuntu ({what})\nLast login: simulated\nroot@ip:~#"
        ))
    }
}
