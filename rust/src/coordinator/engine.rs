//! The execution-manager ↔ analytics boundary.
//!
//! The paper's platform runs opaque "R scripts"; here a script is a JSON
//! task descriptor in the project directory (e.g. `catopt.json`,
//! `sweep.json`) and a [`ScriptEngine`] is the interpreter that executes
//! it. The `analytics` module provides the production engine (rgenoud
//! GA + Monte-Carlo sweep over the PJRT artifacts); tests plug in mocks.

use super::scheduler::NodeSpec;
use crate::simcloud::network::NetworkModel;
use crate::simcloud::vfs::Vfs;
use crate::util::json::Json;

/// Everything the engine may use about the resource it runs on.
#[derive(Clone, Debug)]
pub struct ResourceView {
    /// Nodes of the cluster (or the single instance / desktop).
    pub nodes: Vec<NodeSpec>,
    /// Node index of each slave process (from the scheduler).
    pub assignment: Vec<usize>,
    /// Network model for pricing collective communication.
    pub net: NetworkModel,
    /// Human-readable resource name ("hpc_cluster", "Desktop A", …).
    pub resource_name: String,
    /// Real OS threads the engine's worker pool may use (`-threads`
    /// knob). `None` = use this host's available parallelism. Affects
    /// wall-clock only — virtual-time accounting always follows
    /// `assignment`.
    pub real_threads: Option<usize>,
}

impl ResourceView {
    /// Total compute power in Desktop-A-core-equivalents.
    pub fn total_power(&self) -> f64 {
        self.nodes.iter().map(NodeSpec::power).sum()
    }

    /// Number of slave processes.
    pub fn nproc(&self) -> usize {
        self.assignment.len()
    }
}

/// Files produced by a run plus the virtual compute time it took.
#[derive(Clone, Debug, Default)]
pub struct TaskOutput {
    /// Files for the master's `results/<runname>/` directory
    /// (path-relative, bytes).
    pub master_files: Vec<(String, Vec<u8>)>,
    /// Files produced on individual workers
    /// `(worker_index, rel_path, bytes)` — the paper's scenario 2/3.
    pub worker_files: Vec<(usize, String, Vec<u8>)>,
    /// Modelled compute duration (virtual seconds) of the whole run.
    pub compute_s: f64,
    /// Machine-readable run summary (logged and used by benches).
    pub summary: Json,
}

/// A script interpreter. `project` is the project directory *as it
/// exists on the resource* (post-sync), `project_dir` its path within
/// that vfs.
pub trait ScriptEngine {
    fn run(
        &mut self,
        script_name: &str,
        script: &Json,
        project: &Vfs,
        project_dir: &str,
        resources: &ResourceView,
    ) -> anyhow::Result<TaskOutput>;
}

/// Test/bench engine: records invocations, emits a fixed result file
/// and a compute time inversely proportional to total power (perfect
/// scaling), so coordinator behaviour can be tested in isolation.
pub struct MockEngine {
    /// Serial work the mock pretends the script costs, in
    /// Desktop-A-core-seconds.
    pub work_units: f64,
    pub calls: Vec<String>,
}

impl MockEngine {
    pub fn new(work_units: f64) -> Self {
        Self {
            work_units,
            calls: Vec::new(),
        }
    }
}

impl ScriptEngine for MockEngine {
    fn run(
        &mut self,
        script_name: &str,
        _script: &Json,
        _project: &Vfs,
        _project_dir: &str,
        resources: &ResourceView,
    ) -> anyhow::Result<TaskOutput> {
        self.calls.push(format!(
            "{script_name}@{}x{}",
            resources.resource_name,
            resources.nproc()
        ));
        let compute_s = self.work_units / resources.total_power().max(1e-9);
        Ok(TaskOutput {
            master_files: vec![(
                "summary.json".to_string(),
                Json::from_pairs(vec![("ok", Json::Bool(true))])
                    .to_string_pretty()
                    .into_bytes(),
            )],
            worker_files: vec![],
            compute_s,
            summary: Json::from_pairs(vec![("compute_s", Json::num(compute_s))]),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcloud::SimParams;

    #[test]
    fn mock_engine_scales_with_power() {
        let mk = |n: usize| ResourceView {
            nodes: (0..n)
                .map(|i| NodeSpec {
                    name: format!("n{i}"),
                    cores: 4,
                    mem_gb: 34.2,
                    core_speed: 1.0,
                })
                .collect(),
            assignment: (0..n * 4).map(|p| p % n).collect(),
            net: NetworkModel::new(SimParams::default()),
            resource_name: format!("cluster{n}"),
            real_threads: None,
        };
        let mut e = MockEngine::new(1000.0);
        let t1 = e.run("s", &Json::Null, &Vfs::new(), "p", &mk(1)).unwrap();
        let t4 = e.run("s", &Json::Null, &Vfs::new(), "p", &mk(4)).unwrap();
        assert!((t1.compute_s / t4.compute_s - 4.0).abs() < 1e-9);
        assert_eq!(e.calls.len(), 2);
    }
}
