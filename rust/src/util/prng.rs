//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so P2RAC carries its own
//! generators: [`SplitMix64`] for seeding and [`Xoshiro256`]
//! (xoshiro256**) as the workhorse generator. Determinism matters: the
//! discrete-event simulation, the synthetic cat-bond dataset and the
//! genetic optimiser must all be exactly reproducible from a seed so
//! that benches and tests are stable across runs.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 by Blackman & Vigna — fast, high-quality, 256-bit
/// state, suitable for Monte-Carlo work (not cryptography).
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of entropy.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // Rejection sampling to remove modulo bias.
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u64();
            let (hi, lo) = {
                let m = (r as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= threshold {
                return hi;
            }
        }
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box–Muller (polar form avoided for determinism
    /// simplicity; trig form is fine here).
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Pareto(scale, shape) sample — heavy-tailed severities for the
    /// synthetic catastrophe event-loss table.
    pub fn next_pareto(&mut self, scale: f64, shape: f64) -> f64 {
        let u = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        scale / u.powf(1.0 / shape)
    }

    /// Exponential(rate).
    pub fn next_exp(&mut self, rate: f64) -> f64 {
        let u = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        -u.ln() / rate
    }

    /// Poisson(lambda) via Knuth for small lambda, normal approx beyond.
    pub fn next_poisson(&mut self, lambda: f64) -> u64 {
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.next_f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = lambda + lambda.sqrt() * self.next_gaussian();
            x.max(0.0).round() as u64
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // Partial Fisher–Yates.
        for i in 0..k {
            let j = i + self.below_usize(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Fork a child generator with a decorrelated stream.
    pub fn fork(&mut self, stream: u64) -> Xoshiro256 {
        Xoshiro256::seed_from_u64(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Expose the raw 256-bit state (job checkpointing): a generator
    /// rebuilt with [`Xoshiro256::from_state`] continues the exact
    /// stream, which is what makes a resumed GA bit-identical to an
    /// uninterrupted one.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a saved state.
    pub fn from_state(s: [u64; 4]) -> Self {
        Self { s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256::seed_from_u64(1);
        let mut b = Xoshiro256::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Xoshiro256::seed_from_u64(9);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            let expect = n / 10;
            assert!(
                (c as i64 - expect as i64).unsigned_abs() < (expect / 10) as u64,
                "bucket count {c} too far from {expect}"
            );
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256::seed_from_u64(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Xoshiro256::seed_from_u64(13);
        let lambda = 4.5;
        let n = 20_000;
        let total: u64 = (0..n).map(|_| r.next_poisson(lambda)).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - lambda).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from_u64(17);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Xoshiro256::seed_from_u64(19);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(idx.iter().all(|&i| i < 50));
    }

    #[test]
    fn state_roundtrip_continues_stream() {
        let mut a = Xoshiro256::seed_from_u64(31);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Xoshiro256::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pareto_is_heavy_tailed() {
        let mut r = Xoshiro256::seed_from_u64(23);
        let xs: Vec<f64> = (0..10_000).map(|_| r.next_pareto(1.0, 2.0)).collect();
        assert!(xs.iter().all(|&x| x >= 1.0));
        let max = xs.iter().cloned().fold(0.0, f64::max);
        assert!(max > 10.0, "expected heavy tail, max={max}");
    }
}
