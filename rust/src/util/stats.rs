//! Small statistics helpers shared by the bench harness, the timing
//! model and the analytics reports (mean/std, percentiles, linear fit,
//! online Welford accumulator).

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (n-1); 0 for n < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile with linear interpolation; `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (q / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Least-squares line fit `y = a + b·x`; returns `(a, b)`.
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if x.is_empty() {
        return (0.0, 0.0);
    }
    let mx = mean(x);
    let my = mean(y);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for i in 0..x.len() {
        sxy += (x[i] - mx) * (y[i] - my);
        sxx += (x[i] - mx) * (x[i] - mx);
    }
    if sxx == 0.0 || n == 0.0 {
        return (my, 0.0);
    }
    let b = sxy / sxx;
    (my - b * mx, b)
}

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }
    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn fit_recovers_line() {
        let x: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 + 2.0 * v).collect();
        let (a, b) = linear_fit(&x, &y);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 4.0, 9.0, 16.0, 25.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.stddev() - stddev(&xs)).abs() < 1e-12);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
