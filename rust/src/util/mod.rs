//! Foundation substrates for the offline build: deterministic PRNG,
//! JSON, CLI parsing, logging, formatting, statistics and a miniature
//! property-testing harness. These replace `rand`, `serde`, `clap`,
//! `log` and `proptest`, none of which are available in the vendored
//! crate set.

pub mod argparse;
pub mod hex;
pub mod humanfmt;
pub mod ids;
pub mod json;
pub mod logger;
pub mod prng;
pub mod quickprop;
pub mod stats;
