//! Tiny leveled logger (no `log`-crate facade needed: the whole stack is
//! in-tree). Controlled by `P2RAC_LOG` = `error|warn|info|debug|trace`;
//! default `info`. Thread-safe, writes to stderr so CLI stdout stays
//! machine-parseable.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(2); // info
static INIT: std::sync::Once = std::sync::Once::new();

/// Initialise from the environment (idempotent; called lazily).
pub fn init() {
    INIT.call_once(|| {
        if let Ok(v) = std::env::var("P2RAC_LOG") {
            if let Some(l) = Level::from_str(&v) {
                MAX_LEVEL.store(l as u8, Ordering::Relaxed);
            }
        }
    });
}

/// Override the level programmatically (tests, `-quiet` flags).
pub fn set_level(l: Level) {
    INIT.call_once(|| {});
    MAX_LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    init();
    (l as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{} {module}] {msg}", l.tag());
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Error, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Warn, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Info, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Debug, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Trace, module_path!(), format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse() {
        assert_eq!(Level::from_str("debug"), Some(Level::Debug));
        assert_eq!(Level::from_str("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_str("nope"), None);
    }

    #[test]
    fn level_ordering_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Debug));
        set_level(Level::Info);
    }
}
