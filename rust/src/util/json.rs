//! Minimal JSON implementation (no `serde` in the offline build).
//!
//! Used for the four Analyst-site configuration files (paper §3.4), the
//! AOT artifact manifest, and the "R script" analog task descriptors
//! that live in project directories. Supports the full JSON grammar with
//! a recursive-descent parser and a stable (sorted-key) pretty writer so
//! config files diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Clone, Debug, Default, PartialEq)]
pub enum Json {
    #[default]
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- constructors ----
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }
    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
    pub fn arr_str<I: IntoIterator<Item = S>, S: Into<String>>(it: I) -> Json {
        Json::Arr(it.into_iter().map(|s| Json::Str(s.into())).collect())
    }

    // ---- accessors ----
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as u64)
            } else {
                None
            }
        })
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    pub fn as_arr_mut(&mut self) -> Option<&mut Vec<Json>> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    pub fn get_mut(&mut self, key: &str) -> Option<&mut Json> {
        match self {
            Json::Obj(o) => o.get_mut(key),
            _ => None,
        }
    }
    /// `obj["a"]["b"]`-style path access.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(o) = self {
            o.insert(key.to_string(), val);
        }
    }
    pub fn remove(&mut self, key: &str) -> Option<Json> {
        if let Json::Obj(o) = self {
            o.remove(key)
        } else {
            None
        }
    }

    // Convenience typed getters with error messages for config loading.
    pub fn req_str(&self, key: &str) -> anyhow::Result<String> {
        self.get(key)
            .and_then(|v| v.as_str())
            .map(str::to_string)
            .ok_or_else(|| anyhow::anyhow!("missing or non-string field '{key}'"))
    }
    pub fn req_u64(&self, key: &str) -> anyhow::Result<u64> {
        self.get(key)
            .and_then(|v| v.as_u64())
            .ok_or_else(|| anyhow::anyhow!("missing or non-integer field '{key}'"))
    }
    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("missing or non-number field '{key}'"))
    }
    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.get(key).and_then(|v| v.as_str()).map(str::to_string)
    }
    pub fn opt_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// Compact single-line encoding.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        write_json(self, &mut s, None, 0);
        s
    }

    /// Pretty 2-space-indented encoding (stable key order).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        write_json(self, &mut s, Some(2), 0);
        s.push('\n');
        s
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

fn write_json(v: &Json, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_json(item, out, indent, depth + 1);
            }
            if !a.is_empty() {
                newline_indent(out, indent, depth);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, item)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_escaped(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_json(item, out, indent, depth + 1);
            }
            if !o.is_empty() {
                newline_indent(out, indent, depth);
            }
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            at: self.i,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let hex2 = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 4;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c).ok_or_else(|| self.err("bad surrogate"))?
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            s.push(ch);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            0xF0..=0xF7 => 4,
                            _ => return Err(self.err("invalid utf8")),
                        };
                        if start + width > self.b.len() {
                            return Err(self.err("truncated utf8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..start + width])
                            .map_err(|_| self.err("invalid utf8"))?;
                        s.push_str(chunk);
                        self.i = start + width;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.path(&["a"]).unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let src = r#"{"name":"hpc_cluster","size":10,"nodes":["a","b"],"inuse":false}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, again);
        let again2 = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, again2);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let j = Json::Str("line1\nline2\t\"q\" \\ \u{1F600}".into());
        let parsed = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(parsed, j);
    }

    #[test]
    fn unicode_escape_parses() {
        let j = Json::parse(r#""A😀""#).unwrap();
        assert_eq!(j.as_str(), Some("A\u{1F600}"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn typed_getters() {
        let j = Json::parse(r#"{"s":"x","n":3,"b":true}"#).unwrap();
        assert_eq!(j.req_str("s").unwrap(), "x");
        assert_eq!(j.req_u64("n").unwrap(), 3);
        assert!(j.opt_bool("b", false));
        assert!(j.req_str("missing").is_err());
    }

    #[test]
    fn integers_encode_without_fraction() {
        assert_eq!(Json::Num(10.0).to_string_compact(), "10");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }
}
