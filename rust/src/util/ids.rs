//! AWS-style resource identifier generation (`i-0a1b...`, `vol-...`,
//! `snap-...`, `ami-...`) backed by a deterministic per-provider counter
//! + hash so simulation runs are reproducible.

/// Deterministic id factory for one simulated cloud account.
#[derive(Clone, Debug)]
pub struct IdFactory {
    counter: u64,
    salt: u64,
}

impl IdFactory {
    pub fn new(salt: u64) -> Self {
        Self { counter: 0, salt }
    }

    /// Current counter (session persistence).
    pub fn counter(&self) -> u64 {
        self.counter
    }

    /// Restore a persisted counter.
    pub fn set_counter(&mut self, counter: u64) {
        self.counter = counter;
    }

    fn next_raw(&mut self) -> u64 {
        self.counter += 1;
        // SplitMix-style scramble so ids look AWS-opaque but stay stable.
        let mut z = self.counter.wrapping_add(self.salt).wrapping_mul(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z ^ (z >> 31)
    }

    fn hex17(&mut self) -> String {
        let a = self.next_raw();
        format!("{:017x}", (a as u128) & 0x1ffff_ffff_ffff_ffff)
    }

    pub fn instance(&mut self) -> String {
        format!("i-{}", self.hex17())
    }
    pub fn volume(&mut self) -> String {
        format!("vol-{}", self.hex17())
    }
    pub fn snapshot(&mut self) -> String {
        format!("snap-{}", self.hex17())
    }
    pub fn ami(&mut self) -> String {
        format!("ami-{}", self.hex17())
    }
    pub fn reservation(&mut self) -> String {
        format!("r-{}", self.hex17())
    }

    /// Public DNS name in the EC2 style for a fresh instance.
    pub fn public_dns(&mut self, region: &str) -> String {
        let a = self.next_raw();
        format!(
            "ec2-{}-{}-{}-{}.{}.compute.amazonaws.com",
            (a >> 24) & 0xff,
            (a >> 16) & 0xff,
            (a >> 8) & 0xff,
            a & 0xff,
            region
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_have_aws_prefixes() {
        let mut f = IdFactory::new(1);
        assert!(f.instance().starts_with("i-"));
        assert!(f.volume().starts_with("vol-"));
        assert!(f.snapshot().starts_with("snap-"));
        assert!(f.ami().starts_with("ami-"));
    }

    #[test]
    fn ids_are_unique_and_deterministic() {
        let mut f1 = IdFactory::new(7);
        let mut f2 = IdFactory::new(7);
        let a: Vec<String> = (0..100).map(|_| f1.instance()).collect();
        let b: Vec<String> = (0..100).map(|_| f2.instance()).collect();
        assert_eq!(a, b);
        let mut dedup = a.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), 100);
    }

    #[test]
    fn dns_shape() {
        let mut f = IdFactory::new(3);
        let d = f.public_dns("us-east-1");
        assert!(d.starts_with("ec2-"));
        assert!(d.ends_with(".us-east-1.compute.amazonaws.com"));
    }
}
