//! Human-readable formatting of bytes, durations and rates for CLI
//! output, bench tables and EXPERIMENTS.md reporting.

use std::time::Duration;

/// `1536` → `"1.5 KiB"`.
pub fn bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    if n < 1024 {
        return format!("{n} B");
    }
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.1} {}", UNITS[u])
}

/// `Duration` → `"2m 35s"` / `"820ms"` / `"1h 03m"`.
pub fn duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s < 1.0 {
        format!("{:.0}ms", s * 1e3)
    } else if s < 60.0 {
        format!("{s:.1}s")
    } else if s < 3600.0 {
        format!("{}m {:02}s", (s as u64) / 60, (s as u64) % 60)
    } else {
        format!("{}h {:02}m", (s as u64) / 3600, ((s as u64) % 3600) / 60)
    }
}

/// Seconds (f64, e.g. from the virtual clock) → human duration.
pub fn secs(s: f64) -> String {
    duration(Duration::from_secs_f64(s.max(0.0)))
}

/// `12_582_912, 1.0s` → `"12.0 MiB/s"`.
pub fn rate(bytes_n: u64, elapsed: Duration) -> String {
    let s = elapsed.as_secs_f64();
    if s <= 0.0 {
        return "inf".to_string();
    }
    format!("{}/s", bytes((bytes_n as f64 / s) as u64))
}

/// Right-pad to width (simple table helper).
pub fn pad(s: &str, w: usize) -> String {
    if s.len() >= w {
        s.to_string()
    } else {
        format!("{s}{}", " ".repeat(w - s.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(1536), "1.5 KiB");
        assert_eq!(bytes(300 * 1024 * 1024), "300.0 MiB");
        assert_eq!(bytes(3 * 1024 * 1024 * 1024), "3.0 GiB");
    }

    #[test]
    fn duration_formats() {
        assert_eq!(duration(Duration::from_millis(820)), "820ms");
        assert_eq!(duration(Duration::from_secs_f64(2.35)), "2.4s");
        assert_eq!(duration(Duration::from_secs(155)), "2m 35s");
        assert_eq!(duration(Duration::from_secs(3780)), "1h 03m");
    }

    #[test]
    fn secs_clamps_negative() {
        assert_eq!(secs(-5.0), "0ms");
    }

    #[test]
    fn rate_format() {
        assert_eq!(rate(12 * 1024 * 1024, Duration::from_secs(1)), "12.0 MiB/s");
    }

    #[test]
    fn pad_widths() {
        assert_eq!(pad("ab", 4), "ab  ");
        assert_eq!(pad("abcdef", 4), "abcdef");
    }
}
