//! Hex encoding for binary file contents in persisted session state.

/// Bytes → lowercase hex.
pub fn encode(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push(char::from_digit((b >> 4) as u32, 16).unwrap());
        s.push(char::from_digit((b & 0xf) as u32, 16).unwrap());
    }
    s
}

/// Hex → bytes (case-insensitive); errors on odd length / bad digits.
pub fn decode(s: &str) -> Result<Vec<u8>, String> {
    if s.len() % 2 != 0 {
        return Err("odd-length hex string".to_string());
    }
    let b = s.as_bytes();
    let mut out = Vec::with_capacity(s.len() / 2);
    for pair in b.chunks_exact(2) {
        let hi = (pair[0] as char)
            .to_digit(16)
            .ok_or_else(|| format!("bad hex digit '{}'", pair[0] as char))?;
        let lo = (pair[1] as char)
            .to_digit(16)
            .ok_or_else(|| format!("bad hex digit '{}'", pair[1] as char))?;
        out.push(((hi << 4) | lo) as u8);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let data: Vec<u8> = (0..=255).collect();
        assert_eq!(decode(&encode(&data)).unwrap(), data);
        assert_eq!(encode(&[0xde, 0xad]), "dead");
        assert_eq!(decode("DEAD").unwrap(), vec![0xde, 0xad]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(decode("abc").is_err());
        assert!(decode("zz").is_err());
        assert_eq!(decode("").unwrap(), Vec::<u8>::new());
    }
}
