//! `quickprop` — a miniature property-based testing harness.
//!
//! The offline build has no `proptest`, so P2RAC carries a small
//! substitute: seeded generators, a configurable number of cases, and
//! greedy shrinking for failing inputs. Used by the coordinator,
//! datasync and GA test suites for invariant checks (routing, batching,
//! round-trips, permutation properties).
//!
//! ```no_run
//! use p2rac::util::quickprop::{check, Gen};
//! check("reverse twice is identity", 200, |g| {
//!     let xs = g.vec_u32(0..256, 0, 64);
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     assert_eq!(xs, ys);
//! });
//! ```

use super::prng::Xoshiro256;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Deterministic input generator handed to each property case.
pub struct Gen {
    rng: Xoshiro256,
    /// Trace of raw choices — reused to replay/shrink.
    pub case_index: usize,
    /// Size hint grows over cases so early cases are small.
    pub size: usize,
}

impl Gen {
    fn new(seed: u64, case_index: usize, total: usize) -> Self {
        // Grow the size hint from 4 → 256 across the run.
        let size = 4 + (252 * case_index) / total.max(1);
        Self {
            rng: Xoshiro256::seed_from_u64(
                seed ^ (case_index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            ),
            case_index,
            size,
        }
    }

    pub fn u64(&mut self, r: Range<u64>) -> u64 {
        assert!(r.start < r.end);
        r.start + self.rng.below(r.end - r.start)
    }
    pub fn u32(&mut self, r: Range<u32>) -> u32 {
        self.u64(r.start as u64..r.end as u64) as u32
    }
    pub fn usize(&mut self, r: Range<usize>) -> usize {
        self.u64(r.start as u64..r.end as u64) as usize
    }
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
    /// Bernoulli with probability `p`.
    pub fn weighted(&mut self, p: f64) -> bool {
        self.rng.next_f64() < p
    }
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below_usize(xs.len())]
    }
    pub fn vec_u32(&mut self, each: Range<u32>, min_len: usize, max_len: usize) -> Vec<u32> {
        let len = self.usize(min_len..max_len.max(min_len + 1) + 1);
        (0..len).map(|_| self.u32(each.clone())).collect()
    }
    pub fn vec_f64(&mut self, lo: f64, hi: f64, min_len: usize, max_len: usize) -> Vec<f64> {
        let len = self.usize(min_len..max_len.max(min_len + 1) + 1);
        (0..len).map(|_| self.f64(lo, hi)).collect()
    }
    pub fn bytes(&mut self, min_len: usize, max_len: usize) -> Vec<u8> {
        let len = self.usize(min_len..max_len.max(min_len + 1) + 1);
        (0..len).map(|_| self.u32(0..256) as u8).collect()
    }
    /// Lowercase ASCII identifier of length 1..=12 (resource names).
    pub fn ident(&mut self) -> String {
        let len = self.usize(1..13);
        (0..len)
            .map(|_| (b'a' + self.u32(0..26) as u8) as char)
            .collect()
    }
    pub fn rng(&mut self) -> &mut Xoshiro256 {
        &mut self.rng
    }
}

/// Seed selection: stable by default, overridable via `QUICKPROP_SEED`.
fn base_seed(name: &str) -> u64 {
    if let Ok(v) = std::env::var("QUICKPROP_SEED") {
        if let Ok(n) = v.parse::<u64>() {
            return n;
        }
    }
    // FNV-1a over the property name: stable across runs/platforms.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Run `cases` instances of `prop`. Panics (failing the test) on the
/// first counterexample, reporting the case index and seed needed to
/// replay it.
pub fn check<F: Fn(&mut Gen)>(name: &str, cases: usize, prop: F) {
    let seed = base_seed(name);
    for i in 0..cases {
        let mut g = Gen::new(seed, i, cases);
        let result = catch_unwind(AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {i}/{cases} \
                 (replay: QUICKPROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Like [`check`] but the property returns `Result`, treated as pass/fail.
pub fn check_result<F: Fn(&mut Gen) -> Result<(), String>>(name: &str, cases: usize, prop: F) {
    check(name, cases, |g| {
        if let Err(m) = prop(g) {
            panic!("{m}");
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("add commutes", 50, |g| {
            let a = g.u64(0..1000);
            let b = g.u64(0..1000);
            assert_eq!(a + b, b + a);
        });
        // check() itself panics on failure; reaching here means pass.
        count += 1;
        assert_eq!(count, 1);
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports() {
        check("always fails", 10, |_g| {
            panic!("intentional");
        });
    }

    #[test]
    fn generators_respect_bounds() {
        check("bounds", 100, |g| {
            let n = g.u64(5..10);
            assert!((5..10).contains(&n));
            let v = g.vec_u32(0..3, 2, 6);
            assert!(v.len() >= 2 && v.len() <= 6);
            assert!(v.iter().all(|&x| x < 3));
            let id = g.ident();
            assert!(!id.is_empty() && id.len() <= 12);
            assert!(id.bytes().all(|b| b.is_ascii_lowercase()));
        });
    }

    #[test]
    fn size_hint_grows() {
        let g0 = Gen::new(1, 0, 100);
        let g99 = Gen::new(1, 99, 100);
        assert!(g0.size < g99.size);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Gen::new(7, 3, 10);
        let mut b = Gen::new(7, 3, 10);
        assert_eq!(a.u64(0..1_000_000), b.u64(0..1_000_000));
        assert_eq!(a.bytes(0, 32), b.bytes(0, 32));
    }
}
