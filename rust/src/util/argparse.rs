//! Command-line argument parser (no `clap` in the offline build).
//!
//! Models the paper's tool syntax exactly: every P2RAC command accepts
//! `-h` (help) and `-v` (version), plus single-dash long options that
//! either take a value (`-iname NAME`) or act as switches
//! (`-deletevol`), and mutually-exclusive groups
//! (`-ebsvol VOL | -snap SNAP`, `-frommaster | -fromworkers | -fromall`).

use std::collections::BTreeMap;

#[derive(Debug, PartialEq)]
pub enum ArgError {
    Unknown(String),
    MissingValue(String),
    Exclusive(String),
    MissingRequired(String),
    UnexpectedPositional(String),
}

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ArgError::Unknown(a) => write!(f, "unknown argument '{a}'"),
            ArgError::MissingValue(a) => write!(f, "argument '{a}' requires a value"),
            ArgError::Exclusive(a) => write!(f, "arguments {a} are mutually exclusive"),
            ArgError::MissingRequired(a) => write!(f, "missing required argument '{a}'"),
            ArgError::UnexpectedPositional(a) => {
                write!(f, "unexpected positional argument '{a}'")
            }
        }
    }
}

impl std::error::Error for ArgError {}

#[derive(Clone, Debug)]
enum Kind {
    Value { required: bool },
    Switch,
}

#[derive(Clone, Debug)]
struct OptSpec {
    name: String,
    kind: Kind,
    help: String,
}

/// Declarative spec for one command.
#[derive(Clone, Debug)]
pub struct CommandSpec {
    pub name: String,
    pub about: String,
    opts: Vec<OptSpec>,
    exclusive: Vec<Vec<String>>,
}

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct ParsedArgs {
    values: BTreeMap<String, String>,
    switches: Vec<String>,
    pub help: bool,
    pub version: bool,
}

impl ParsedArgs {
    pub fn value(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }
    pub fn value_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.value(name).unwrap_or(default)
    }
    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
    pub fn usize_value(&self, name: &str) -> anyhow::Result<Option<usize>> {
        match self.value(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<usize>()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("argument -{name} expects an integer, got '{v}'")),
        }
    }
}

impl CommandSpec {
    pub fn new(name: &str, about: &str) -> Self {
        Self {
            name: name.to_string(),
            about: about.to_string(),
            opts: Vec::new(),
            exclusive: Vec::new(),
        }
    }

    /// Option taking a value, e.g. `-iname INSTANCE_NAME`.
    pub fn value_arg(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            kind: Kind::Value { required: false },
            help: help.to_string(),
        });
        self
    }

    /// Mandatory value option (the paper's `runname`).
    pub fn required_arg(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            kind: Kind::Value { required: true },
            help: help.to_string(),
        });
        self
    }

    /// Boolean switch, e.g. `-deletevol`.
    pub fn switch_arg(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            kind: Kind::Switch,
            help: help.to_string(),
        });
        self
    }

    /// Declare a mutually-exclusive group by option names.
    pub fn exclusive(mut self, names: &[&str]) -> Self {
        self.exclusive
            .push(names.iter().map(|s| s.to_string()).collect());
        self
    }

    fn find(&self, name: &str) -> Option<&OptSpec> {
        self.opts.iter().find(|o| o.name == name)
    }

    /// Parse raw args (after the command name).
    pub fn parse<I: IntoIterator<Item = String>>(&self, args: I) -> Result<ParsedArgs, ArgError> {
        let mut out = ParsedArgs::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if a == "-h" || a == "--help" {
                out.help = true;
                continue;
            }
            if a == "-v" || a == "--version" {
                out.version = true;
                continue;
            }
            let Some(name) = a.strip_prefix('-') else {
                return Err(ArgError::UnexpectedPositional(a));
            };
            let name = name.trim_start_matches('-');
            let Some(spec) = self.find(name) else {
                return Err(ArgError::Unknown(a));
            };
            match spec.kind {
                Kind::Switch => out.switches.push(name.to_string()),
                Kind::Value { .. } => {
                    let val = it.next().ok_or_else(|| ArgError::MissingValue(a.clone()))?;
                    out.values.insert(name.to_string(), val);
                }
            }
        }
        if out.help || out.version {
            return Ok(out);
        }
        // Exclusivity.
        for group in &self.exclusive {
            let present: Vec<&str> = group
                .iter()
                .filter(|n| out.values.contains_key(*n) || out.switch(n))
                .map(|s| s.as_str())
                .collect();
            if present.len() > 1 {
                return Err(ArgError::Exclusive(present.join(", ")));
            }
        }
        // Required.
        for o in &self.opts {
            if let Kind::Value { required: true } = o.kind {
                if !out.values.contains_key(&o.name) {
                    return Err(ArgError::MissingRequired(o.name.clone()));
                }
            }
        }
        Ok(out)
    }

    /// `-h` output, in the paper's usage style.
    pub fn usage(&self) -> String {
        let mut s = format!("usage: {} [-h] [-v]", self.name);
        for o in &self.opts {
            match o.kind {
                Kind::Switch => s.push_str(&format!(" [-{}]", o.name)),
                Kind::Value { required: true } => {
                    s.push_str(&format!(" -{} {}", o.name, o.name.to_uppercase()))
                }
                Kind::Value { required: false } => {
                    s.push_str(&format!(" [-{} {}]", o.name, o.name.to_uppercase()))
                }
            }
        }
        s.push_str(&format!("\n\n{}\n\noptions:\n", self.about));
        s.push_str("  -h             show this help message\n");
        s.push_str("  -v             show the version of P2RAC\n");
        for o in &self.opts {
            s.push_str(&format!("  -{:<13} {}\n", o.name, o.help));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> CommandSpec {
        CommandSpec::new("ec2createinstance", "configure an instance on the cloud")
            .value_arg("iname", "name of the instance")
            .value_arg("ebsvol", "EBS volume id")
            .value_arg("snap", "EBS snapshot id")
            .value_arg("type", "EC2 instance type")
            .switch_arg("deletevol", "delete attached volume")
            .exclusive(&["ebsvol", "snap"])
    }

    #[test]
    fn parses_values_and_switches() {
        let p = spec()
            .parse(
                ["-iname", "hpc_instance", "-type", "m2.4xlarge", "-deletevol"]
                    .map(String::from),
            )
            .unwrap();
        assert_eq!(p.value("iname"), Some("hpc_instance"));
        assert_eq!(p.value("type"), Some("m2.4xlarge"));
        assert!(p.switch("deletevol"));
        assert!(!p.switch("nonexistent"));
    }

    #[test]
    fn help_and_version() {
        let p = spec().parse(["-h".to_string()]).unwrap();
        assert!(p.help);
        let p = spec().parse(["-v".to_string()]).unwrap();
        assert!(p.version);
    }

    #[test]
    fn mutual_exclusion_enforced() {
        let e = spec()
            .parse(["-ebsvol", "vol-1", "-snap", "snap-1"].map(String::from))
            .unwrap_err();
        assert!(matches!(e, ArgError::Exclusive(_)));
    }

    #[test]
    fn missing_value_is_error() {
        let e = spec().parse(["-iname".to_string()]).unwrap_err();
        assert_eq!(e, ArgError::MissingValue("-iname".into()));
    }

    #[test]
    fn unknown_arg_is_error() {
        let e = spec().parse(["-bogus".to_string()]).unwrap_err();
        assert_eq!(e, ArgError::Unknown("-bogus".into()));
    }

    #[test]
    fn required_arg_enforced() {
        let s = CommandSpec::new("ec2runoninstance", "run").required_arg("runname", "run name");
        assert!(matches!(
            s.parse(Vec::<String>::new()).unwrap_err(),
            ArgError::MissingRequired(_)
        ));
        let p = s.parse(["-runname", "r1"].map(String::from)).unwrap();
        assert_eq!(p.value("runname"), Some("r1"));
    }

    #[test]
    fn help_skips_required_check() {
        let s = CommandSpec::new("x", "y").required_arg("runname", "run name");
        assert!(s.parse(["-h".to_string()]).unwrap().help);
    }

    #[test]
    fn usage_mentions_options() {
        let u = spec().usage();
        assert!(u.contains("-iname"));
        assert!(u.contains("ec2createinstance"));
        assert!(u.contains("[-deletevol]"));
    }
}
