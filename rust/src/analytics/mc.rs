//! The parameter-sweep workload (paper §4, second problem): independent
//! Monte-Carlo pricing jobs with no data dependency between runs.
//!
//! Batches draw from **forked per-batch PRNG streams** (see
//! [`Xoshiro256::fork`]): the master RNG forks one child stream per
//! batch in batch order, so the threaded path — which evaluates batches
//! concurrently on the worker pool — produces bit-identical results to
//! the serial path for the same seed.

use crate::analytics::pool::WorkerPool;
use crate::runtime::{Runtime, TensorF32};
use crate::util::json::Json;
use crate::util::prng::Xoshiro256;
use anyhow::Result;
use std::sync::Arc;

/// Severity-model constants — must match kernels/mc.py defaults.
pub const PARETO_SCALE: f32 = 1.0;
pub const PARETO_SHAPE: f32 = 2.5;
pub const SEVERITY_CAP: f32 = 50.0;

/// Sweep configuration ("the same code run hundreds or thousands of
/// times with different input parameters").
#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub n_jobs: usize,
    pub att_range: (f32, f32),
    pub lim_range: (f32, f32),
    pub seed: u64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        Self {
            n_jobs: 512,
            att_range: (0.5, 8.0),
            lim_range: (1.0, 12.0),
            seed: 2012,
        }
    }
}

/// One job's result.
#[derive(Clone, Debug, PartialEq)]
pub struct JobResult {
    pub att: f32,
    pub limit: f32,
    pub mean_recovery: f32,
    pub std_recovery: f32,
}

impl JobResult {
    /// The canonical checkpoint row: `{"att":..,"limit":..,"mean":..,"std":..}`.
    /// Full sweep snapshots and the incremental delta documents both use
    /// this shape, so a delta applied in place serializes bit-identically
    /// to a freshly built full snapshot.
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("att", Json::num(self.att as f64)),
            ("limit", Json::num(self.limit as f64)),
            ("mean", Json::num(self.mean_recovery as f64)),
            ("std", Json::num(self.std_recovery as f64)),
        ])
    }

    /// Parse a checkpoint row written by [`JobResult::to_json`].
    pub fn from_json(row: &Json) -> Result<JobResult> {
        Ok(JobResult {
            att: row.req_f64("att")? as f32,
            limit: row.req_f64("limit")? as f32,
            mean_recovery: row.req_f64("mean")? as f32,
            std_recovery: row.req_f64("std")? as f32,
        })
    }
}

/// Batch evaluator: takes `(S*K)` uniforms and `(J*2)` params, returns
/// `(J*2)` `[mean, std]` rows. `Send + Sync` with `&self` so the
/// worker pool can evaluate independent batches concurrently.
pub trait SweepBackend: Send + Sync {
    fn run_batch(&self, u: &[f32], params: &[f32], s: usize, k: usize, j: usize)
        -> Result<Vec<f32>>;
}

/// Pure-Rust reference (tests + fallback) — mirrors kernels/ref.py.
pub struct RustSweep;

impl SweepBackend for RustSweep {
    fn run_batch(
        &self,
        u: &[f32],
        params: &[f32],
        s: usize,
        k: usize,
        j: usize,
    ) -> Result<Vec<f32>> {
        // Year losses.
        let mut year = vec![0.0f32; s];
        for si in 0..s {
            let mut acc = 0.0f32;
            for ki in 0..k {
                let uu = u[si * k + ki];
                let sev = (PARETO_SCALE / (1.0 - uu).powf(1.0 / PARETO_SHAPE)).min(SEVERITY_CAP);
                acc += sev;
            }
            year[si] = acc;
        }
        let mut out = vec![0.0f32; j * 2];
        for ji in 0..j {
            let att = params[ji * 2];
            let lim = params[ji * 2 + 1];
            let mut sum = 0.0f64;
            let mut sumsq = 0.0f64;
            for &y in &year {
                let r = (y - att).max(0.0).min(lim) as f64;
                sum += r;
                sumsq += r * r;
            }
            let mean = sum / s as f64;
            let var = (sumsq / s as f64 - mean * mean).max(0.0);
            out[ji * 2] = mean as f32;
            out[ji * 2 + 1] = var.sqrt() as f32;
        }
        Ok(out)
    }
}

/// Production backend: the `mc_sweep` PJRT artifact.
pub struct PjrtSweep {
    rt: Arc<Runtime>,
}

impl PjrtSweep {
    pub fn new(rt: Arc<Runtime>) -> Self {
        Self { rt }
    }
}

impl SweepBackend for PjrtSweep {
    fn run_batch(
        &self,
        u: &[f32],
        params: &[f32],
        s: usize,
        k: usize,
        j: usize,
    ) -> Result<Vec<f32>> {
        let out = self.rt.execute(
            "mc_sweep",
            &[
                TensorF32::new(vec![s, k], u.to_vec()),
                TensorF32::new(vec![j, 2], params.to_vec()),
            ],
        )?;
        Ok(out[0].data.clone())
    }
}

/// One batch of jobs ready to evaluate: its parameter tile and its own
/// decorrelated PRNG stream (common random numbers within the batch).
struct Batch {
    jobs: Vec<(f32, f32)>,
    rng: Xoshiro256,
}

/// The full sweep pre-planned into independent batches. Because every
/// batch's PRNG stream is forked up front (in batch order, before any
/// evaluation), any contiguous range of batches can be evaluated at
/// any time — on any capacity — and produce the same numbers: this is
/// what makes a sweep job checkpointable at batch granularity.
pub struct SweepPlan {
    batches: Vec<Batch>,
    j_tile: usize,
}

/// Plan a sweep: parameter grid + per-batch forked streams.
pub fn plan_sweep(cfg: &SweepConfig, j_tile: usize) -> SweepPlan {
    let mut master = Xoshiro256::seed_from_u64(cfg.seed);
    // Parameter grid: jobs vary attachment fastest, limit slowest.
    let params: Vec<(f32, f32)> = (0..cfg.n_jobs)
        .map(|i| {
            let fa = i as f32 / cfg.n_jobs.max(1) as f32;
            let fl = (i * 7 % cfg.n_jobs) as f32 / cfg.n_jobs.max(1) as f32;
            (
                cfg.att_range.0 + fa * (cfg.att_range.1 - cfg.att_range.0),
                cfg.lim_range.0 + fl * (cfg.lim_range.1 - cfg.lim_range.0),
            )
        })
        .collect();

    // Fork the per-batch streams deterministically before any
    // evaluation happens, so the batch order of evaluation (serial or
    // threaded) cannot influence the draws.
    let batches: Vec<Batch> = params
        .chunks(j_tile)
        .enumerate()
        .map(|(bi, chunk)| Batch {
            jobs: chunk.to_vec(),
            rng: master.fork(bi as u64),
        })
        .collect();
    SweepPlan { batches, j_tile }
}

impl SweepPlan {
    /// Number of batches in the plan.
    pub fn len(&self) -> usize {
        self.batches.len()
    }

    pub fn is_empty(&self) -> bool {
        self.batches.is_empty()
    }

    /// Jobs in batches `[from, to)`.
    pub fn jobs_in_range(&self, from: usize, to: usize) -> usize {
        self.batches[from.min(self.batches.len())..to.min(self.batches.len())]
            .iter()
            .map(|b| b.jobs.len())
            .sum()
    }

    /// Evaluate batches `[from, to)` across the pool, returning one
    /// result per job in job order. Identical numbers whatever the
    /// range partition or thread count.
    pub fn run_range(
        &self,
        backend: &dyn SweepBackend,
        s: usize,
        k: usize,
        from: usize,
        to: usize,
        pool: &WorkerPool,
    ) -> Result<Vec<JobResult>> {
        let j_tile = self.j_tile;
        let slice = &self.batches[from.min(self.batches.len())..to.min(self.batches.len())];
        let per_batch = pool.map(slice, |_, batch| {
            // Fresh draws per batch (common random numbers within a batch).
            let mut rng = batch.rng.clone();
            let u: Vec<f32> = (0..s * k).map(|_| rng.next_f32() * 0.999).collect();
            let mut p = Vec::with_capacity(j_tile * 2);
            for &(a, l) in &batch.jobs {
                p.push(a);
                p.push(l);
            }
            // Pad the tile.
            for _ in batch.jobs.len()..j_tile {
                p.push(batch.jobs[0].0);
                p.push(batch.jobs[0].1);
            }
            let out = backend.run_batch(&u, &p, s, k, j_tile)?;
            let results: Vec<JobResult> = batch
                .jobs
                .iter()
                .enumerate()
                .map(|(i, &(att, limit))| JobResult {
                    att,
                    limit,
                    mean_recovery: out[i * 2],
                    std_recovery: out[i * 2 + 1],
                })
                .collect();
            Ok(results)
        })?;
        Ok(per_batch.into_iter().flatten().collect())
    }
}

/// Run a full sweep on the calling thread (serial reference path).
pub fn run_sweep(
    backend: &dyn SweepBackend,
    cfg: &SweepConfig,
    s: usize,
    k: usize,
    j_tile: usize,
) -> Result<Vec<JobResult>> {
    run_sweep_with_pool(backend, cfg, s, k, j_tile, &WorkerPool::serial())
}

/// Run a full sweep with batches fanned out across a [`WorkerPool`]:
/// plan the batches, evaluate them all. One result per job, job order.
pub fn run_sweep_with_pool(
    backend: &dyn SweepBackend,
    cfg: &SweepConfig,
    s: usize,
    k: usize,
    j_tile: usize,
    pool: &WorkerPool,
) -> Result<Vec<JobResult>> {
    let plan = plan_sweep(cfg, j_tile);
    let n = plan.len();
    plan.run_range(backend, s, k, 0, n, pool)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rust_sweep_monotone_in_attachment() {
        let cfg = SweepConfig {
            n_jobs: 16,
            att_range: (0.5, 6.0),
            lim_range: (4.0, 4.0), // fixed limit
            seed: 3,
        };
        let res = run_sweep(&RustSweep, &cfg, 512, 8, 16).unwrap();
        assert_eq!(res.len(), 16);
        for w in res.windows(2) {
            assert!(
                w[1].mean_recovery <= w[0].mean_recovery + 1e-4,
                "mean recovery must fall as attachment rises"
            );
        }
        for r in &res {
            assert!(r.mean_recovery >= 0.0 && r.mean_recovery <= r.limit);
            assert!(r.std_recovery >= 0.0);
        }
    }

    #[test]
    fn batching_is_invariant() {
        let cfg = SweepConfig {
            n_jobs: 24,
            seed: 9,
            ..Default::default()
        };
        let a = run_sweep(&RustSweep, &cfg, 256, 8, 8).unwrap();
        let b = run_sweep(&RustSweep, &cfg, 256, 8, 8).unwrap();
        assert_eq!(a, b, "same seed, same batching => identical results");
    }

    #[test]
    fn pooled_sweep_is_bit_identical_to_serial() {
        let cfg = SweepConfig {
            n_jobs: 40,
            seed: 21,
            ..Default::default()
        };
        let serial = run_sweep(&RustSweep, &cfg, 128, 8, 8).unwrap();
        for pool in [WorkerPool::new(2, 4), WorkerPool::new(4, 16)] {
            let pooled =
                run_sweep_with_pool(&RustSweep, &cfg, 128, 8, 8, &pool).unwrap();
            assert_eq!(serial, pooled, "pool {pool:?} must not change numerics");
        }
    }

    #[test]
    fn range_partition_is_bit_identical_to_full_run() {
        // A sweep interrupted between any two batches and resumed on
        // other capacity concatenates the same results.
        let cfg = SweepConfig {
            n_jobs: 40,
            seed: 33,
            ..Default::default()
        };
        let full = run_sweep(&RustSweep, &cfg, 128, 8, 8).unwrap();
        let plan = plan_sweep(&cfg, 8);
        let pool = WorkerPool::new(3, 5);
        for cut in 0..=plan.len() {
            let mut parts = plan.run_range(&RustSweep, 128, 8, 0, cut, &pool).unwrap();
            parts.extend(plan.run_range(&RustSweep, 128, 8, cut, plan.len(), &pool).unwrap());
            assert_eq!(full, parts, "cut between batches {cut}");
        }
        assert_eq!(plan.jobs_in_range(0, plan.len()), 40);
    }

    #[test]
    fn severity_cap_bounds_year_loss() {
        // With u -> 1 the Pareto quantile explodes; the cap keeps year
        // losses <= K * cap.
        let k = 4;
        let u = vec![0.9989f32; 16 * k];
        let params = vec![0.0f32, 1e9];
        let out = RustSweep.run_batch(&u, &params, 16, k, 1).unwrap();
        assert!(out[0] <= (k as f32) * SEVERITY_CAP);
    }
}
