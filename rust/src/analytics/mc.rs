//! The parameter-sweep workload (paper §4, second problem): independent
//! Monte-Carlo pricing jobs with no data dependency between runs.

use crate::runtime::{Runtime, TensorF32};
use crate::util::prng::Xoshiro256;
use anyhow::Result;
use std::rc::Rc;

/// Severity-model constants — must match kernels/mc.py defaults.
pub const PARETO_SCALE: f32 = 1.0;
pub const PARETO_SHAPE: f32 = 2.5;
pub const SEVERITY_CAP: f32 = 50.0;

/// Sweep configuration ("the same code run hundreds or thousands of
/// times with different input parameters").
#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub n_jobs: usize,
    pub att_range: (f32, f32),
    pub lim_range: (f32, f32),
    pub seed: u64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        Self {
            n_jobs: 512,
            att_range: (0.5, 8.0),
            lim_range: (1.0, 12.0),
            seed: 2012,
        }
    }
}

/// One job's result.
#[derive(Clone, Debug, PartialEq)]
pub struct JobResult {
    pub att: f32,
    pub limit: f32,
    pub mean_recovery: f32,
    pub std_recovery: f32,
}

/// Batch evaluator: takes `(S*K)` uniforms and `(J*2)` params, returns
/// `(J*2)` `[mean, std]` rows.
pub trait SweepBackend {
    fn run_batch(&mut self, u: &[f32], params: &[f32], s: usize, k: usize, j: usize)
        -> Result<Vec<f32>>;
}

/// Pure-Rust reference (tests + fallback) — mirrors kernels/ref.py.
pub struct RustSweep;

impl SweepBackend for RustSweep {
    fn run_batch(
        &mut self,
        u: &[f32],
        params: &[f32],
        s: usize,
        k: usize,
        j: usize,
    ) -> Result<Vec<f32>> {
        // Year losses.
        let mut year = vec![0.0f32; s];
        for si in 0..s {
            let mut acc = 0.0f32;
            for ki in 0..k {
                let uu = u[si * k + ki];
                let sev = (PARETO_SCALE / (1.0 - uu).powf(1.0 / PARETO_SHAPE)).min(SEVERITY_CAP);
                acc += sev;
            }
            year[si] = acc;
        }
        let mut out = vec![0.0f32; j * 2];
        for ji in 0..j {
            let att = params[ji * 2];
            let lim = params[ji * 2 + 1];
            let mut sum = 0.0f64;
            let mut sumsq = 0.0f64;
            for &y in &year {
                let r = (y - att).max(0.0).min(lim) as f64;
                sum += r;
                sumsq += r * r;
            }
            let mean = sum / s as f64;
            let var = (sumsq / s as f64 - mean * mean).max(0.0);
            out[ji * 2] = mean as f32;
            out[ji * 2 + 1] = var.sqrt() as f32;
        }
        Ok(out)
    }
}

/// Production backend: the `mc_sweep` PJRT artifact.
pub struct PjrtSweep {
    rt: Rc<Runtime>,
}

impl PjrtSweep {
    pub fn new(rt: Rc<Runtime>) -> Self {
        Self { rt }
    }
}

impl SweepBackend for PjrtSweep {
    fn run_batch(
        &mut self,
        u: &[f32],
        params: &[f32],
        s: usize,
        k: usize,
        j: usize,
    ) -> Result<Vec<f32>> {
        let out = self.rt.execute(
            "mc_sweep",
            &[
                TensorF32::new(vec![s, k], u.to_vec()),
                TensorF32::new(vec![j, 2], params.to_vec()),
            ],
        )?;
        Ok(out[0].data.clone())
    }
}

/// Run a full sweep: generates the parameter grid and per-batch draws,
/// batches jobs `j_tile` at a time (the artifact's J), returns one
/// result per job.
pub fn run_sweep(
    backend: &mut dyn SweepBackend,
    cfg: &SweepConfig,
    s: usize,
    k: usize,
    j_tile: usize,
) -> Result<Vec<JobResult>> {
    let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
    // Parameter grid: jobs vary attachment fastest, limit slowest.
    let params: Vec<(f32, f32)> = (0..cfg.n_jobs)
        .map(|i| {
            let fa = i as f32 / cfg.n_jobs.max(1) as f32;
            let fl = (i * 7 % cfg.n_jobs) as f32 / cfg.n_jobs.max(1) as f32;
            (
                cfg.att_range.0 + fa * (cfg.att_range.1 - cfg.att_range.0),
                cfg.lim_range.0 + fl * (cfg.lim_range.1 - cfg.lim_range.0),
            )
        })
        .collect();

    let mut results = Vec::with_capacity(cfg.n_jobs);
    for chunk in params.chunks(j_tile) {
        // Fresh draws per batch (common random numbers within a batch).
        let u: Vec<f32> = (0..s * k).map(|_| rng.next_f32() * 0.999).collect();
        let mut p = Vec::with_capacity(j_tile * 2);
        for &(a, l) in chunk {
            p.push(a);
            p.push(l);
        }
        // Pad the tile.
        for _ in chunk.len()..j_tile {
            p.push(chunk[0].0);
            p.push(chunk[0].1);
        }
        let out = backend.run_batch(&u, &p, s, k, j_tile)?;
        for (i, &(att, limit)) in chunk.iter().enumerate() {
            results.push(JobResult {
                att,
                limit,
                mean_recovery: out[i * 2],
                std_recovery: out[i * 2 + 1],
            });
        }
    }
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rust_sweep_monotone_in_attachment() {
        let cfg = SweepConfig {
            n_jobs: 16,
            att_range: (0.5, 6.0),
            lim_range: (4.0, 4.0), // fixed limit
            seed: 3,
        };
        let res = run_sweep(&mut RustSweep, &cfg, 512, 8, 16).unwrap();
        assert_eq!(res.len(), 16);
        for w in res.windows(2) {
            assert!(
                w[1].mean_recovery <= w[0].mean_recovery + 1e-4,
                "mean recovery must fall as attachment rises"
            );
        }
        for r in &res {
            assert!(r.mean_recovery >= 0.0 && r.mean_recovery <= r.limit);
            assert!(r.std_recovery >= 0.0);
        }
    }

    #[test]
    fn batching_is_invariant() {
        let cfg = SweepConfig {
            n_jobs: 24,
            seed: 9,
            ..Default::default()
        };
        let a = run_sweep(&mut RustSweep, &cfg, 256, 8, 8).unwrap();
        let b = run_sweep(&mut RustSweep, &cfg, 256, 8, 8).unwrap();
        assert_eq!(a, b, "same seed, same batching => identical results");
    }

    #[test]
    fn severity_cap_bounds_year_loss() {
        // With u -> 1 the Pareto quantile explodes; the cap keeps year
        // losses <= K * cap.
        let k = 4;
        let u = vec![0.9989f32; 16 * k];
        let params = vec![0.0f32, 1e9];
        let out = RustSweep.run_batch(&u, &params, 16, k, 1).unwrap();
        assert!(out[0] <= (k as f32) * SEVERITY_CAP);
    }
}
