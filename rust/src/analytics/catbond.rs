//! The CATopt problem (paper §4): catastrophe-bond basis-risk data and
//! objective.
//!
//! The paper's event-loss table is proprietary (Flagstone Re), so this
//! module generates a synthetic multi-peril table with the same
//! structure: m region-peril combinations (e.g. `Alabama_Residential`),
//! heavy-tailed (Pareto) event severities with spatial correlation
//! across neighbouring region-perils, and a sponsor loss that is a
//! noisy share of the industry loss — exactly the setting in which
//! minimising basis risk over the weights is non-trivial.
//!
//! The Rust-side objective here mirrors `python/compile/kernels/ref.py`;
//! it is used for unit tests, for verifying the PJRT artifacts, and as
//! the CPU fallback backend.

use crate::util::prng::Xoshiro256;

/// Constraint-penalty coefficients — must match ref.py.
pub const LAM_BOUNDS: f32 = 1e4;
pub const LAM_BUDGET: f32 = 1e3;
pub const LAM_CONC: f32 = 1e3;
pub const BUDGET: f32 = 1.0;
pub const HERFINDAHL_CAP: f32 = 0.02;

/// A synthetic cat-bond calibration dataset.
#[derive(Clone, Debug)]
pub struct CatBondData {
    /// Region-peril count (the optimisation dimensionality).
    pub m: usize,
    /// Event count.
    pub e: usize,
    /// Industry losses, row-major `(E, M)`.
    pub il: Vec<f32>,
    /// Sponsor's actual loss per event `(E,)`.
    pub cl: Vec<f32>,
    /// Trigger attachment point.
    pub att: f32,
    /// Contractual limit.
    pub limit: f32,
    /// Region-peril labels ("R012_Residential", …).
    pub labels: Vec<String>,
}

impl CatBondData {
    /// Generate a dataset. `seed` fixes everything; `m`/`e` control the
    /// scale (paper: m = 2000–4000, table ≈ 300 MB; the AOT default is
    /// m = 512, e = 2048 — DESIGN.md §2 records the scaling).
    pub fn generate(seed: u64, m: usize, e: usize) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let perils = ["Residential", "Commercial", "Industrial", "Auto"];
        let labels: Vec<String> = (0..m)
            .map(|j| format!("R{:03}_{}", j / perils.len(), perils[j % perils.len()]))
            .collect();

        // Per-region-peril exposure scale (some markets are much bigger).
        let exposure: Vec<f32> = (0..m)
            .map(|_| rng.next_pareto(0.2, 1.8).min(50.0) as f32)
            .collect();

        let mut il = vec![0.0f32; e * m];
        let mut cl = vec![0.0f32; e];
        // The sponsor's true (hidden) market shares: sparse-ish, what the
        // optimiser should roughly recover.
        let true_w: Vec<f32> = (0..m)
            .map(|_| {
                if rng.next_f64() < 0.3 {
                    rng.next_f32() * 4.0 / m as f32
                } else {
                    0.2 / m as f32
                }
            })
            .collect();

        for ev in 0..e {
            // Each event strikes a contiguous window of region-perils
            // (spatial correlation), with Pareto severity.
            let center = rng.below_usize(m);
            let radius = 1 + rng.below_usize((m / 16).max(2));
            let severity = rng.next_pareto(0.05, 1.6).min(500.0) as f32;
            let row = &mut il[ev * m..(ev + 1) * m];
            for d in 0..=radius {
                let fall = (-(d as f32) / radius as f32 * 2.0).exp();
                for idx in [center.saturating_sub(d), (center + d).min(m - 1)] {
                    row[idx] += severity * fall * exposure[idx] * (0.5 + rng.next_f32());
                }
            }
            // Sponsor loss: their share of the industry loss plus
            // idiosyncratic noise — the source of basis risk.
            let share: f32 = row.iter().zip(&true_w).map(|(x, w)| x * w).sum();
            let noise = 1.0 + 0.3 * rng.next_gaussian() as f32;
            cl[ev] = (share * noise).max(0.0);
        }

        // Attachment ≈ 70th percentile of sponsor loss, limit ≈ spread
        // to the 99th — the usual cat-bond layering.
        let mut sorted = cl.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let att = sorted[(0.70 * (e - 1) as f32) as usize];
        let limit = (sorted[(0.99 * (e - 1) as f32) as usize] - att).max(att * 0.5);

        Self {
            m,
            e,
            il,
            cl,
            att,
            limit,
            labels,
        }
    }

    /// Serialized size in bytes (for data-management timing; the paper's
    /// table is ~300 MB at m=3000, e≈12k).
    pub fn nbytes(&self) -> u64 {
        (self.il.len() * 4 + self.cl.len() * 4) as u64
    }

    /// Serialize to little-endian f32 project files.
    pub fn to_files(&self) -> Vec<(String, Vec<u8>)> {
        let f32s = |v: &[f32]| -> Vec<u8> { v.iter().flat_map(|x| x.to_le_bytes()).collect() };
        let meta = crate::util::json::Json::from_pairs(vec![
            ("m", crate::util::json::Json::num(self.m as f64)),
            ("e", crate::util::json::Json::num(self.e as f64)),
            ("att", crate::util::json::Json::num(self.att as f64)),
            ("limit", crate::util::json::Json::num(self.limit as f64)),
        ]);
        vec![
            ("data/industry_losses.bin".to_string(), f32s(&self.il)),
            ("data/company_losses.bin".to_string(), f32s(&self.cl)),
            ("data/meta.json".to_string(), meta.to_string_pretty().into_bytes()),
        ]
    }

    /// Parse back from project files (the engine reads these on the
    /// "instance" — the project dir is what got rsynced).
    pub fn from_files(read: impl Fn(&str) -> Option<Vec<u8>>) -> anyhow::Result<Self> {
        let meta_raw = read("data/meta.json")
            .ok_or_else(|| anyhow::anyhow!("project missing data/meta.json"))?;
        let meta = crate::util::json::Json::parse(std::str::from_utf8(&meta_raw)?)
            .map_err(|e| anyhow::anyhow!("meta.json: {e}"))?;
        let m = meta.req_u64("m")? as usize;
        let e = meta.req_u64("e")? as usize;
        let att = meta.req_f64("att")? as f32;
        let limit = meta.req_f64("limit")? as f32;
        let parse = |name: &str, n: usize| -> anyhow::Result<Vec<f32>> {
            let raw = read(name).ok_or_else(|| anyhow::anyhow!("project missing {name}"))?;
            if raw.len() != n * 4 {
                anyhow::bail!("{name}: expected {} bytes, got {}", n * 4, raw.len());
            }
            Ok(raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect())
        };
        Ok(Self {
            il: parse("data/industry_losses.bin", e * m)?,
            cl: parse("data/company_losses.bin", e)?,
            labels: (0..m).map(|j| format!("rp{j}")).collect(),
            m,
            e,
            att,
            limit,
        })
    }
}

/// `min(max(x - att, 0), limit)` — the parametric payout.
#[inline]
pub fn recovery(x: f32, att: f32, limit: f32) -> f32 {
    (x - att).max(0.0).min(limit)
}

/// Basis risk (RMS recovery error) of one candidate — Rust reference of
/// the L1 kernel's maths.
pub fn basis_risk(w: &[f32], data: &CatBondData) -> f32 {
    let (m, e) = (data.m, data.e);
    assert_eq!(w.len(), m);
    let mut sse = 0.0f64;
    for ev in 0..e {
        let row = &data.il[ev * m..(ev + 1) * m];
        let mut idx_loss = 0.0f32;
        for j in 0..m {
            idx_loss += w[j] * row[j];
        }
        let rec = recovery(idx_loss, data.att, data.limit);
        let target = recovery(data.cl[ev], data.att, data.limit);
        let d = (rec - target) as f64;
        sse += d * d;
    }
    ((sse / e as f64) as f32).sqrt()
}

/// Constraint penalties — must track `catopt_penalty_ref` in ref.py.
pub fn penalty(w: &[f32]) -> f32 {
    let mut bounds = 0.0f32;
    let mut sum = 0.0f32;
    let mut sumsq = 0.0f32;
    for &x in w {
        let lo = x.min(0.0);
        let hi = (x - 1.0).max(0.0);
        bounds += lo * lo + hi * hi;
        sum += x;
        sumsq += x * x;
    }
    let budget_err = sum - BUDGET;
    let conc = (sumsq - HERFINDAHL_CAP).max(0.0);
    LAM_BOUNDS * bounds + LAM_BUDGET * budget_err * budget_err + LAM_CONC * conc * conc
}

/// Penalised objective (matches `catopt_objective_ref`).
pub fn objective(w: &[f32], data: &CatBondData) -> f32 {
    basis_risk(w, data) + penalty(w)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> CatBondData {
        CatBondData::generate(7, 64, 256)
    }

    #[test]
    fn generation_is_deterministic() {
        let a = CatBondData::generate(1, 32, 64);
        let b = CatBondData::generate(1, 32, 64);
        assert_eq!(a.il, b.il);
        assert_eq!(a.cl, b.cl);
        let c = CatBondData::generate(2, 32, 64);
        assert_ne!(a.il, c.il);
    }

    #[test]
    fn losses_are_nonnegative_and_heavy_tailed() {
        let d = small();
        assert!(d.il.iter().all(|&x| x >= 0.0 && x.is_finite()));
        assert!(d.cl.iter().all(|&x| x >= 0.0 && x.is_finite()));
        let max = d.cl.iter().cloned().fold(0.0f32, f32::max);
        let mean = d.cl.iter().sum::<f32>() / d.cl.len() as f32;
        assert!(max > 5.0 * mean, "tail max {max} vs mean {mean}");
        assert!(d.att > 0.0 && d.limit > 0.0);
    }

    #[test]
    fn file_roundtrip() {
        let d = small();
        let files = d.to_files();
        let lookup = |name: &str| {
            files
                .iter()
                .find(|(n, _)| n == name)
                .map(|(_, b)| b.clone())
        };
        let back = CatBondData::from_files(lookup).unwrap();
        assert_eq!(back.il, d.il);
        assert_eq!(back.cl, d.cl);
        assert_eq!(back.att, d.att);
        assert_eq!(d.nbytes(), (d.il.len() * 4 + d.cl.len() * 4) as u64);
    }

    #[test]
    fn recovery_clamps() {
        assert_eq!(recovery(-1.0, 0.5, 2.0), 0.0);
        assert_eq!(recovery(0.4, 0.5, 2.0), 0.0);
        assert_eq!(recovery(1.5, 0.5, 2.0), 1.0);
        assert_eq!(recovery(10.0, 0.5, 2.0), 2.0);
    }

    #[test]
    fn zero_weights_risk_equals_target_rms() {
        let d = small();
        let w = vec![0.0f32; d.m];
        let br = basis_risk(&w, &d);
        let mut sse = 0.0f64;
        for &c in &d.cl {
            let t = recovery(c, d.att, d.limit) as f64;
            sse += t * t;
        }
        let want = ((sse / d.e as f64) as f32).sqrt();
        assert!((br - want).abs() < 1e-5);
    }

    #[test]
    fn true_shares_beat_zero_and_random() {
        // The generator hides true shares; a uniform-budget candidate
        // should do better than garbage weights.
        let d = small();
        let uniform = vec![BUDGET / d.m as f32; d.m];
        let zero = vec![0.0f32; d.m];
        let big = vec![1.0f32; d.m];
        assert!(basis_risk(&uniform, &d).is_finite());
        assert!(penalty(&uniform) < 1.0, "uniform is feasible");
        assert!(penalty(&zero) > 100.0, "zero violates the budget");
        assert!(penalty(&big) > penalty(&uniform));
    }

    #[test]
    fn penalty_zero_iff_feasible() {
        let m = 100;
        let w = vec![1.0 / m as f32; m];
        assert!(penalty(&w) < 1e-3);
        let mut w2 = w.clone();
        w2[0] = -0.5;
        assert!(penalty(&w2) > 100.0);
    }
}
