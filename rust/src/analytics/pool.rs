//! Scoped-thread worker pool for the analytics engine (paper §3.2.2:
//! parallel slave processes across cluster cores).
//!
//! The simulation schedules `nproc` virtual slave processes over the
//! cluster's nodes and *accounts* their work in virtual time
//! ([`crate::analytics::cost::parallel_eval_s`] gives task `i` to
//! process `i % nproc`). This pool makes the same fan-out **real**: it
//! shards work round-robin over exactly those `nproc` virtual shards —
//! so wall-clock sharding and virtual-time accounting describe the same
//! partition — and executes the shards on
//! `min(nproc, available_parallelism)` OS threads (overridable with the
//! CLI `-threads` knob via [`ResourceView::real_threads`]).
//!
//! Determinism: a candidate's fitness depends only on the candidate
//! (see [`FitnessBackend::eval_population`]), and results are stitched
//! back by index, so the threaded path is bit-identical to the serial
//! path for the same seed. `std::thread::scope` keeps everything on
//! borrowed data — no new dependencies, no channels.

use crate::analytics::backend::FitnessBackend;
use crate::coordinator::engine::ResourceView;
use anyhow::Result;

/// Number of real threads to run: the CLI/`ResourceView` override if
/// given, otherwise this host's parallelism, clamped to the number of
/// virtual shards (more threads than shards would idle).
pub fn resolve_threads(requested: Option<usize>, shards: usize) -> usize {
    let avail = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    requested.unwrap_or(avail).clamp(1, shards.max(1))
}

/// A sharded execution plan: `shards` virtual slave processes served by
/// `threads` OS threads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkerPool {
    threads: usize,
    shards: usize,
}

impl WorkerPool {
    /// Single-threaded pool (the serial reference path).
    pub fn serial() -> Self {
        Self {
            threads: 1,
            shards: 1,
        }
    }

    /// Explicit pool: `threads` OS threads over `shards` virtual
    /// shards. Both are clamped to at least 1.
    pub fn new(threads: usize, shards: usize) -> Self {
        Self {
            threads: threads.max(1),
            shards: shards.max(1),
        }
    }

    /// Pool matching a resource view: one virtual shard per scheduled
    /// slave process (`view.assignment`), real threads from
    /// [`resolve_threads`] with the view's `-threads` override.
    pub fn from_view(view: &ResourceView) -> Self {
        let shards = view.nproc().max(1);
        Self {
            threads: resolve_threads(view.real_threads, shards),
            shards,
        }
    }

    /// Whether a cached pool still describes `view`'s fan-out — the
    /// slice fast path reuses the pooled plan across consecutive slices
    /// only while the cluster topology it was built for is unchanged.
    pub fn matches_view(&self, view: &ResourceView) -> bool {
        *self == Self::from_view(view)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Task indices per shard: shard `p` gets tasks `p, p + shards,
    /// p + 2*shards, …` — the same round-robin the virtual-time cost
    /// model bills, so no shard is ever starved: every shard receives
    /// at least `n_tasks / shards` (floor) tasks, and every task
    /// appears in exactly one shard.
    pub fn shard_indices(&self, n_tasks: usize) -> Vec<Vec<usize>> {
        shard_indices_n(n_tasks, self.shards)
    }

    /// Evaluate a population through a backend, sharded across the
    /// pool. Bit-identical to `backend.eval_population(pop)`.
    ///
    /// The shard count is clamped so no shard drops below the
    /// backend's [`preferred_batch`](FitnessBackend::preferred_batch):
    /// a tiled backend (PJRT) pads every call to a fixed `POP` tile,
    /// and splitting 200 candidates over 16 virtual shards would
    /// execute 16 padded tiles where the serial path needs 4 —
    /// more total work than it parallelises away. Stitching is by
    /// candidate index, so the clamp cannot change the numbers.
    pub fn eval<B: FitnessBackend + ?Sized>(
        &self,
        backend: &B,
        pop: &[Vec<f32>],
    ) -> Result<Vec<f32>> {
        if self.threads <= 1 || pop.len() <= 1 {
            return backend.eval_population(pop);
        }
        let batch = backend.preferred_batch().max(1);
        let max_useful = pop.len().div_ceil(batch);
        let shard_count = self.shards.min(max_useful).max(1);
        if shard_count <= 1 {
            return backend.eval_population(pop);
        }
        let shards: Vec<Vec<usize>> = shard_indices_n(pop.len(), shard_count)
            .into_iter()
            .filter(|s| !s.is_empty())
            .collect();
        // Each shard owns a contiguous copy of its candidates so the
        // backend sees an ordinary slice.
        let inputs: Vec<Vec<Vec<f32>>> = shards
            .iter()
            .map(|idxs| idxs.iter().map(|&i| pop[i].clone()).collect())
            .collect();
        let results = run_indexed(self.threads, inputs.len(), |si| {
            backend.eval_population(&inputs[si])
        });
        let mut out = vec![0.0f32; pop.len()];
        for (idxs, res) in shards.iter().zip(results) {
            let vals = res?;
            anyhow::ensure!(
                vals.len() == idxs.len(),
                "backend returned {} fitness values for a {}-candidate shard",
                vals.len(),
                idxs.len()
            );
            for (&i, v) in idxs.iter().zip(vals) {
                out[i] = v;
            }
        }
        Ok(out)
    }

    /// Parallel indexed map preserving input order (used for the
    /// Monte-Carlo sweep's independent batches). The first error wins.
    pub fn map<T, R, F>(&self, items: &[T], f: F) -> Result<Vec<R>>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> Result<R> + Sync,
    {
        run_indexed(self.threads, items.len(), |i| f(i, &items[i]))
            .into_iter()
            .collect()
    }
}

/// Round-robin task indices over `shards` buckets.
fn shard_indices_n(n_tasks: usize, shards: usize) -> Vec<Vec<usize>> {
    let shards = shards.max(1);
    let mut out = vec![Vec::new(); shards];
    for i in 0..n_tasks {
        out[i % shards].push(i);
    }
    out
}

/// Run `f(0..n)` on up to `threads` scoped threads (thread `t` takes
/// items `t, t + threads, …`), returning results in index order.
fn run_indexed<R, F>(threads: usize, n: usize, f: F) -> Vec<Result<R>>
where
    R: Send,
    F: Fn(usize) -> Result<R> + Sync,
{
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let fref = &f;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads.min(n))
            .map(|t| {
                s.spawn(move || {
                    (t..n)
                        .step_by(threads)
                        .map(|i| (i, fref(i)))
                        .collect::<Vec<(usize, Result<R>)>>()
                })
            })
            .collect();
        let mut slots: Vec<Option<Result<R>>> = (0..n).map(|_| None).collect();
        for h in handles {
            for (i, r) in h.join().expect("pool worker panicked") {
                slots[i] = Some(r);
            }
        }
        slots
            .into_iter()
            .map(|o| o.expect("pool covered every index"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::backend::RustBackend;
    use crate::analytics::catbond::CatBondData;

    fn pop(n: usize, m: usize) -> Vec<Vec<f32>> {
        let mut rng = crate::util::prng::Xoshiro256::seed_from_u64(11);
        (0..n)
            .map(|_| (0..m).map(|_| rng.next_f32() / m as f32).collect())
            .collect()
    }

    #[test]
    fn shard_indices_cover_all_tasks_exactly_once() {
        let p = WorkerPool::new(3, 5);
        let shards = p.shard_indices(17);
        assert_eq!(shards.len(), 5);
        let mut seen: Vec<usize> = shards.iter().flatten().copied().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..17).collect::<Vec<_>>());
        // Round-robin: no shard starves while another hoards.
        for s in &shards {
            assert!(s.len() >= 17 / 5 && s.len() <= 17 / 5 + 1, "{shards:?}");
        }
    }

    #[test]
    fn threaded_eval_is_bit_identical_to_serial() {
        let data = CatBondData::generate(5, 24, 96);
        let b = RustBackend::new(data);
        let candidates = pop(37, 24);
        let serial = b.eval_population(&candidates).unwrap();
        for (threads, shards) in [(2, 2), (4, 7), (3, 16), (8, 37)] {
            let pooled = WorkerPool::new(threads, shards).eval(&b, &candidates).unwrap();
            assert_eq!(serial, pooled, "threads={threads} shards={shards}");
        }
    }

    #[test]
    fn map_preserves_order_and_propagates_errors() {
        let p = WorkerPool::new(4, 4);
        let items: Vec<u64> = (0..50).collect();
        let out = p.map(&items, |i, &x| Ok(x * 2 + i as u64)).unwrap();
        assert_eq!(out.len(), 50);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as u64 * 3);
        }
        let err = p.map(&items, |_, &x| {
            if x == 31 {
                Err(anyhow::anyhow!("boom at {x}"))
            } else {
                Ok(x)
            }
        });
        assert!(err.unwrap_err().to_string().contains("boom at 31"));
    }

    #[test]
    fn eval_respects_backend_preferred_batch() {
        // A tiled backend must not be fragmented into sub-tile shards:
        // with preferred_batch = 16 and 37 candidates, at most
        // ceil(37/16) = 3 shards may be evaluated, whatever the pool's
        // virtual shard count — and the numbers must not change.
        struct Tiled {
            inner: RustBackend,
            tile: usize,
            calls: std::sync::atomic::AtomicU64,
        }
        impl crate::analytics::backend::FitnessBackend for Tiled {
            fn eval_population(&self, pop: &[Vec<f32>]) -> anyhow::Result<Vec<f32>> {
                self.calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                self.inner.eval_population(pop)
            }
            fn value_and_grad(&self, w: &[f32]) -> anyhow::Result<(f32, Vec<f32>)> {
                self.inner.value_and_grad(w)
            }
            fn dims(&self) -> usize {
                self.inner.dims()
            }
            fn preferred_batch(&self) -> usize {
                self.tile
            }
        }
        let data = CatBondData::generate(5, 24, 96);
        let b = Tiled {
            inner: RustBackend::new(data),
            tile: 16,
            calls: std::sync::atomic::AtomicU64::new(0),
        };
        let candidates = pop(37, 24);
        let serial = b.inner.eval_population(&candidates).unwrap();
        let pooled = WorkerPool::new(8, 16).eval(&b, &candidates).unwrap();
        assert_eq!(serial, pooled);
        let calls = b.calls.load(std::sync::atomic::Ordering::Relaxed);
        assert!(calls <= 3, "tiled backend fragmented into {calls} shard calls");
    }

    #[test]
    fn resolve_threads_clamps_to_shards() {
        assert_eq!(resolve_threads(Some(16), 4), 4);
        assert_eq!(resolve_threads(Some(0), 4), 1);
        assert_eq!(resolve_threads(Some(3), 64), 3);
        assert!(resolve_threads(None, 64) >= 1);
    }

    #[test]
    fn pool_from_view_uses_assignment_length() {
        use crate::coordinator::scheduler::NodeSpec;
        use crate::simcloud::{NetworkModel, SimParams};
        let view = ResourceView {
            nodes: vec![NodeSpec {
                name: "n0".into(),
                cores: 4,
                mem_gb: 34.2,
                core_speed: 0.88,
            }],
            assignment: vec![0; 6],
            net: NetworkModel::new(SimParams::default()),
            resource_name: "t".into(),
            real_threads: Some(2),
        };
        let p = WorkerPool::from_view(&view);
        assert_eq!(p.shards(), 6);
        assert_eq!(p.threads(), 2);
    }
}
