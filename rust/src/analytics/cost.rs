//! Virtual-time cost model for the two workloads.
//!
//! Numerics run for real through PJRT; *time* is accounted in
//! Desktop-A-core-seconds calibrated against the paper's reported
//! scales (CATopt ≈ 200×50 candidate evaluations of a 2000–4000-dim
//! problem over a ~300 MB table; the sweep ≈ hundreds of independent
//! MC jobs). The SNOW master dispatches work messages *serially*, which
//! is what bends the Fig-4 speed-up curve past 4 instances together
//! with the virtualised-network collective penalty.

use crate::coordinator::engine::ResourceView;

/// CATopt cost parameters (overridable from the script descriptor).
#[derive(Clone, Debug)]
pub struct CatoptCost {
    /// Desktop-A-core-seconds to evaluate one candidate at paper scale.
    pub candidate_cost_s: f64,
    /// Core-seconds per gradient evaluation (BFGS polish, master-side).
    pub grad_cost_s: f64,
    /// Serial master-side dispatch cost per slave message per generation.
    pub per_message_s: f64,
    /// Scatter payload per candidate (weights, paper-scale bytes).
    pub scatter_bytes_per_candidate: u64,
    /// Gather payload per candidate (fitness scalar + bookkeeping).
    pub gather_bytes_per_candidate: u64,
}

impl Default for CatoptCost {
    fn default() -> Self {
        Self {
            candidate_cost_s: 1.2,
            grad_cost_s: 1.0,
            per_message_s: 0.025,
            scatter_bytes_per_candidate: 3000 * 4, // ~3000-dim weights
            gather_bytes_per_candidate: 64,
        }
    }
}

/// Sweep cost parameters.
#[derive(Clone, Debug)]
pub struct SweepCost {
    /// Desktop-A-core-seconds per Monte-Carlo job.
    pub job_cost_s: f64,
    /// Serial master-side dispatch cost per job.
    pub per_job_dispatch_s: f64,
    /// Result payload per job.
    pub result_bytes_per_job: u64,
}

impl Default for SweepCost {
    fn default() -> Self {
        Self {
            job_cost_s: 4.0,
            per_job_dispatch_s: 0.01,
            result_bytes_per_job: 128,
        }
    }
}

/// Longest-processor completion time for `n_tasks` identical tasks of
/// `task_cost_s` distributed round-robin over the view's processes.
pub fn parallel_eval_s(n_tasks: usize, task_cost_s: f64, view: &ResourceView) -> f64 {
    let nproc = view.nproc().max(1);
    let mut worst = 0.0f64;
    for (p, &node) in view.assignment.iter().enumerate() {
        // Tasks p, p+nproc, p+2*nproc, … land on process p.
        let count = if p < n_tasks {
            (n_tasks - p - 1) / nproc + 1
        } else {
            0
        };
        let speed = view.nodes[node].core_speed as f64;
        worst = worst.max(count as f64 * task_cost_s / speed.max(1e-9));
    }
    worst
}

/// One generation of the distributed GA: parallel candidate evaluation
/// + serial dispatch + scatter/gather collective (multi-node only).
pub fn catopt_generation_s(evals: usize, cost: &CatoptCost, view: &ResourceView) -> f64 {
    let compute = parallel_eval_s(evals, cost.candidate_cost_s, view);
    let dispatch = cost.per_message_s * view.nproc() as f64;
    let comm = if view.nodes.len() > 1 {
        let bytes = evals as u64
            * (cost.scatter_bytes_per_candidate + cost.gather_bytes_per_candidate);
        view.net.collective_s(bytes, view.nodes.len())
    } else {
        0.0
    };
    compute + dispatch + comm
}

/// BFGS polish runs on the master's first core.
pub fn catopt_polish_s(grad_evals: usize, cost: &CatoptCost, view: &ResourceView) -> f64 {
    let speed = view.nodes[0].core_speed as f64;
    grad_evals as f64 * cost.grad_cost_s / speed.max(1e-9)
}

/// The whole parameter sweep: independent jobs, serial dispatch, one
/// result gather at the end.
pub fn sweep_total_s(n_jobs: usize, cost: &SweepCost, view: &ResourceView) -> f64 {
    let compute = parallel_eval_s(n_jobs, cost.job_cost_s, view);
    let dispatch = cost.per_job_dispatch_s * n_jobs as f64;
    let gather = if view.nodes.len() > 1 {
        view.net
            .collective_s(n_jobs as u64 * cost.result_bytes_per_job, view.nodes.len())
    } else {
        0.0
    };
    compute + dispatch + gather
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::ResourceView;
    use crate::coordinator::scheduler::NodeSpec;
    use crate::simcloud::{NetworkModel, SimParams};

    fn view(nodes: usize, cores: usize) -> ResourceView {
        let ns: Vec<NodeSpec> = (0..nodes)
            .map(|i| NodeSpec {
                name: format!("n{i}"),
                cores,
                mem_gb: 34.2,
                core_speed: 0.88,
            })
            .collect();
        let nproc = nodes * cores;
        ResourceView {
            assignment: (0..nproc).map(|p| p % nodes).collect(),
            nodes: ns,
            net: NetworkModel::new(SimParams::default()),
            resource_name: format!("cluster{nodes}"),
            real_threads: None,
        }
    }

    #[test]
    fn parallel_eval_matches_hand_count() {
        let v = view(2, 4); // 8 procs at 0.88
        // 20 tasks over 8 procs: busiest proc gets 3.
        let t = parallel_eval_s(20, 1.0, &v);
        assert!((t - 3.0 / 0.88).abs() < 1e-9);
        // Fewer tasks than procs: one task each.
        let t2 = parallel_eval_s(3, 1.0, &v);
        assert!((t2 - 1.0 / 0.88).abs() < 1e-9);
    }

    #[test]
    fn efficiency_knee_appears_past_4_nodes() {
        // Paper Fig 4: near-100% efficiency to 4 instances, dropping
        // after. Efficiency(n) = T1 / (n * Tn), CATopt pop=200.
        let cost = CatoptCost::default();
        let t1 = catopt_generation_s(200, &cost, &view(1, 4));
        let eff = |n: usize| {
            let tn = catopt_generation_s(200, &cost, &view(n, 4));
            t1 / (n as f64 * tn)
        };
        assert!(eff(2) > 0.92, "eff(2)={}", eff(2));
        assert!(eff(4) > 0.85, "eff(4)={}", eff(4));
        assert!(eff(16) < 0.75, "eff(16)={} should show the knee", eff(16));
        assert!(eff(8) > eff(16), "efficiency must fall monotonically");
    }

    #[test]
    fn sweep_scales_better_than_catopt_at_16_nodes() {
        let cat = CatoptCost::default();
        let swp = SweepCost::default();
        let speedup_cat = {
            let t1 = 50.0 * catopt_generation_s(200, &cat, &view(1, 4));
            let t16 = 50.0 * catopt_generation_s(200, &cat, &view(16, 4));
            t1 / t16
        };
        let speedup_swp = {
            let t1 = sweep_total_s(512, &swp, &view(1, 4));
            let t16 = sweep_total_s(512, &swp, &view(16, 4));
            t1 / t16
        };
        assert!(
            speedup_swp > speedup_cat,
            "independent sweep ({speedup_swp:.1}x) should beat cooperative GA ({speedup_cat:.1}x)"
        );
        assert!(speedup_cat > 6.0, "CATopt speedup {speedup_cat:.1}");
        assert!(speedup_swp > 9.0, "sweep speedup {speedup_swp:.1}");
    }

    #[test]
    fn polish_uses_master_speed() {
        let v = view(4, 4);
        let t = catopt_polish_s(10, &CatoptCost::default(), &v);
        assert!((t - 10.0 * 1.0 / 0.88).abs() < 1e-9);
    }
}
