//! The production [`ScriptEngine`]: interprets the JSON task
//! descriptors that play the role of the Analyst's R scripts and runs
//! the two paper workloads against the PJRT artifacts (or the pure-Rust
//! fallback when no artifacts are available, e.g. unit tests).
//!
//! Descriptor formats:
//!
//! ```json
//! {"type": "catopt", "pop_size": 200, "max_generations": 50,
//!  "seed": 42, "bfgs_every": 10, "backend": "pjrt"}
//! {"type": "mc_sweep", "n_jobs": 512, "att_min": 0.5, "att_max": 8.0,
//!  "lim_min": 1.0, "lim_max": 12.0, "seed": 7, "backend": "pjrt"}
//! ```

use super::backend::{PjrtBackend, RustBackend};
use super::catbond::CatBondData;
use super::cost::{self, CatoptCost, SweepCost};
use super::ga::optimizer::{self, GaConfig};
use super::mc::{self, PjrtSweep, RustSweep, SweepConfig};
use super::pool::WorkerPool;
use crate::coordinator::engine::{ResourceView, ScriptEngine, TaskOutput};
use crate::runtime::Runtime;
use crate::simcloud::vfs::Vfs;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;

/// Sweep dimensions for the pure-Rust oracle backend. The jobs
/// subsystem uses the same constants, so a queued sweep and
/// `ec2runoncluster -rscript sweep.json` agree on the same seed.
pub const RUST_SWEEP_S: usize = 1024;
pub const RUST_SWEEP_K: usize = 8;
pub const RUST_SWEEP_TILE: usize = 64;

/// GA config from a catopt script descriptor — the single source of
/// the defaults, shared by the engine and the jobs subsystem.
pub fn ga_config_from(script: &Json) -> GaConfig {
    GaConfig {
        pop_size: script.get("pop_size").and_then(Json::as_usize).unwrap_or(200),
        max_generations: script
            .get("max_generations")
            .and_then(Json::as_usize)
            .unwrap_or(50),
        wait_generations: script
            .get("wait_generations")
            .and_then(Json::as_usize)
            .unwrap_or(50),
        bfgs_every: script.get("bfgs_every").and_then(Json::as_usize).unwrap_or(25),
        seed: script.get("seed").and_then(Json::as_u64).unwrap_or(42),
        ..GaConfig::default()
    }
}

/// Sweep config from an mc_sweep script descriptor (shared defaults).
pub fn sweep_config_from(script: &Json) -> SweepConfig {
    SweepConfig {
        n_jobs: script.get("n_jobs").and_then(Json::as_usize).unwrap_or(512),
        att_range: (
            script.get("att_min").and_then(Json::as_f64).unwrap_or(0.5) as f32,
            script.get("att_max").and_then(Json::as_f64).unwrap_or(8.0) as f32,
        ),
        lim_range: (
            script.get("lim_min").and_then(Json::as_f64).unwrap_or(1.0) as f32,
            script.get("lim_max").and_then(Json::as_f64).unwrap_or(12.0) as f32,
        ),
        seed: script.get("seed").and_then(Json::as_u64).unwrap_or(2012),
    }
}

/// Scenario-1 result files for a finished CATopt run (solution.json,
/// convergence.csv, weights.bin) plus the run summary.
pub fn catopt_result_files(
    result: &crate::analytics::ga::GaResult,
    compute_s: f64,
) -> (Vec<(String, Vec<u8>)>, Json) {
    let mut conv = String::from("generation,best_value,mean_value,evaluations\n");
    for h in &result.history {
        conv.push_str(&format!(
            "{},{},{},{}\n",
            h.generation, h.best_value, h.mean_value, h.evaluations
        ));
    }
    let weights_bin: Vec<u8> = result.best.iter().flat_map(|x| x.to_le_bytes()).collect();
    let solution = Json::from_pairs(vec![
        ("best_value", Json::num(result.best_value as f64)),
        ("generations", Json::num(result.generations_run as f64)),
        ("total_evaluations", Json::num(result.total_evaluations as f64)),
        ("weight_sum", Json::num(result.best.iter().sum::<f32>() as f64)),
        ("compute_s", Json::num(compute_s)),
    ]);
    let summary = solution.clone();
    (
        vec![
            ("solution.json".into(), solution.to_string_pretty().into_bytes()),
            ("convergence.csv".into(), conv.into_bytes()),
            ("weights.bin".into(), weights_bin),
        ],
        summary,
    )
}

/// The aggregated sweep CSV (scenario 1, master-side).
pub fn sweep_csv(results: &[mc::JobResult]) -> String {
    let mut csv = String::from("att,limit,mean_recovery,std_recovery\n");
    for r in results {
        csv.push_str(&format!(
            "{},{},{},{}\n",
            r.att, r.limit, r.mean_recovery, r.std_recovery
        ));
    }
    csv
}

/// Sweep run summary (best job + dimensions + billed compute time).
pub fn sweep_summary(
    cfg: &SweepConfig,
    results: &[mc::JobResult],
    s: usize,
    k: usize,
    compute_s: f64,
) -> Result<Json> {
    let best = results
        .iter()
        .max_by(|a, b| a.mean_recovery.partial_cmp(&b.mean_recovery).unwrap())
        .ok_or_else(|| anyhow!("empty sweep"))?;
    Ok(Json::from_pairs(vec![
        ("n_jobs", Json::num(cfg.n_jobs as f64)),
        ("samples_per_job", Json::num(s as f64)),
        ("events_per_year", Json::num(k as f64)),
        ("best_mean_recovery", Json::num(best.mean_recovery as f64)),
        ("best_att", Json::num(best.att as f64)),
        ("best_limit", Json::num(best.limit as f64)),
        ("compute_s", Json::num(compute_s)),
    ]))
}

/// The engine behind `ec2runoninstance` / `ec2runoncluster`.
///
/// Work is fanned out over a [`WorkerPool`] built from the resource
/// view: one virtual shard per scheduled slave process, executed on
/// real threads. Virtual time is still billed from the scheduler's
/// assignment (the cost model and the pool shard identically), so
/// `compute_s` is independent of how many real threads happen to run —
/// and the numerics are bit-identical to the serial path.
pub struct P2racEngine {
    runtime: Option<Arc<Runtime>>,
    pub catopt_cost: CatoptCost,
    pub sweep_cost: SweepCost,
}

impl P2racEngine {
    /// Engine with the PJRT runtime (production path).
    pub fn with_runtime(rt: Arc<Runtime>) -> Self {
        Self {
            runtime: Some(rt),
            catopt_cost: CatoptCost::default(),
            sweep_cost: SweepCost::default(),
        }
    }

    /// Pure-Rust engine (tests / no artifacts built).
    pub fn rust_only() -> Self {
        Self {
            runtime: None,
            catopt_cost: CatoptCost::default(),
            sweep_cost: SweepCost::default(),
        }
    }

    fn run_catopt(
        &mut self,
        script: &Json,
        project: &Vfs,
        project_dir: &str,
        view: &ResourceView,
    ) -> Result<TaskOutput> {
        let data = CatBondData::from_files(|name| {
            project.read(&format!("{project_dir}/{name}")).map(<[u8]>::to_vec)
        })?;

        let cfg = ga_config_from(script);
        if let Some(c) = script.get("candidate_cost_s").and_then(Json::as_f64) {
            self.catopt_cost.candidate_cost_s = c;
        }

        let pool = WorkerPool::from_view(view);
        let want_pjrt = script.opt_str("backend").as_deref() != Some("rust");
        let result = match (&self.runtime, want_pjrt) {
            (Some(rt), true) => {
                let b = PjrtBackend::new(Arc::clone(rt), data)?;
                optimizer::run_with_pool(&b, &cfg, &pool)?
            }
            _ => {
                let b = RustBackend::new(data);
                optimizer::run_with_pool(&b, &cfg, &pool)?
            }
        };

        // Virtual compute time from the per-generation history.
        let mut compute_s = 0.0;
        for h in &result.history {
            compute_s += cost::catopt_generation_s(h.evaluations, &self.catopt_cost, view);
            compute_s += cost::catopt_polish_s(h.grad_evaluations, &self.catopt_cost, view);
        }

        // Result files (paper scenario 1: aggregated on the master).
        let (master_files, summary) = catopt_result_files(&result, compute_s);
        Ok(TaskOutput {
            master_files,
            worker_files: vec![],
            compute_s,
            summary,
        })
    }

    fn run_sweep(
        &mut self,
        script: &Json,
        view: &ResourceView,
    ) -> Result<TaskOutput> {
        let cfg = sweep_config_from(script);
        if let Some(c) = script.get("job_cost_s").and_then(Json::as_f64) {
            self.sweep_cost.job_cost_s = c;
        }

        let pool = WorkerPool::from_view(view);
        let want_pjrt = script.opt_str("backend").as_deref() != Some("rust");
        let (results, s, k) = match (&self.runtime, want_pjrt) {
            (Some(rt), true) => {
                let s = rt.constant("S")?;
                let k = rt.constant("K")?;
                let j = rt.constant("J")?;
                let b = PjrtSweep::new(Arc::clone(rt));
                (mc::run_sweep_with_pool(&b, &cfg, s, k, j, &pool)?, s, k)
            }
            _ => (
                mc::run_sweep_with_pool(
                    &RustSweep,
                    &cfg,
                    RUST_SWEEP_S,
                    RUST_SWEEP_K,
                    RUST_SWEEP_TILE,
                    &pool,
                )?,
                RUST_SWEEP_S,
                RUST_SWEEP_K,
            ),
        };

        let compute_s = cost::sweep_total_s(cfg.n_jobs, &self.sweep_cost, view);

        // Paper scenario 2/3: per-worker partial results on the workers,
        // aggregate on the master. On a single node everything lands on
        // the "master" (the instance itself).
        let n_workers = view.nodes.len().saturating_sub(1);
        let mut worker_files = Vec::new();
        let master_csv = sweep_csv(&results);
        if n_workers > 0 {
            for w in 0..n_workers {
                let mut part = String::from("att,limit,mean_recovery,std_recovery\n");
                for r in results.iter().skip(w).step_by(n_workers) {
                    part.push_str(&format!(
                        "{},{},{},{}\n",
                        r.att, r.limit, r.mean_recovery, r.std_recovery
                    ));
                }
                worker_files.push((w, format!("part_worker{w}.csv"), part.into_bytes()));
            }
        }

        let summary = sweep_summary(&cfg, &results, s, k, compute_s)?;
        Ok(TaskOutput {
            master_files: vec![
                ("sweep.csv".into(), master_csv.into_bytes()),
                ("summary.json".into(), summary.to_string_pretty().into_bytes()),
            ],
            worker_files,
            compute_s,
            summary,
        })
    }
}

impl ScriptEngine for P2racEngine {
    fn run(
        &mut self,
        script_name: &str,
        script: &Json,
        project: &Vfs,
        project_dir: &str,
        resources: &ResourceView,
    ) -> Result<TaskOutput> {
        let ty = script
            .opt_str("type")
            .ok_or_else(|| anyhow!("script '{script_name}' has no \"type\" field"))?;
        match ty.as_str() {
            "catopt" => self.run_catopt(script, project, project_dir, resources),
            "mc_sweep" => self.run_sweep(script, resources),
            other => bail!("script '{script_name}': unknown task type '{other}'"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::NodeSpec;
    use crate::simcloud::{NetworkModel, SimParams};

    fn view(nodes: usize, cores: usize) -> ResourceView {
        let ns: Vec<NodeSpec> = (0..nodes)
            .map(|i| NodeSpec {
                name: format!("n{i}"),
                cores,
                mem_gb: 34.2,
                core_speed: 0.88,
            })
            .collect();
        ResourceView {
            assignment: (0..nodes * cores).map(|p| p % nodes).collect(),
            nodes: ns,
            net: NetworkModel::new(SimParams::default()),
            resource_name: "test".into(),
            real_threads: None,
        }
    }

    fn catopt_project() -> (Vfs, String) {
        let mut v = Vfs::new();
        let data = CatBondData::generate(5, 24, 96);
        for (name, bytes) in data.to_files() {
            v.write(&format!("proj/{name}"), bytes);
        }
        v.write(
            "proj/catopt.json",
            br#"{"type":"catopt","pop_size":16,"max_generations":6,"seed":3,"backend":"rust","bfgs_every":3}"#
                .to_vec(),
        );
        (v, "proj".to_string())
    }

    #[test]
    fn catopt_script_runs_and_reports() {
        let (v, dir) = catopt_project();
        let mut e = P2racEngine::rust_only();
        let script = Json::parse(std::str::from_utf8(v.read("proj/catopt.json").unwrap()).unwrap())
            .unwrap();
        let out = e.run("catopt.json", &script, &v, &dir, &view(4, 4)).unwrap();
        assert!(out.compute_s > 0.0);
        let names: Vec<&str> = out.master_files.iter().map(|(n, _)| n.as_str()).collect();
        assert!(names.contains(&"solution.json"));
        assert!(names.contains(&"convergence.csv"));
        assert!(names.contains(&"weights.bin"));
        assert!(out.summary.get("best_value").is_some());
    }

    #[test]
    fn sweep_script_distributes_worker_files() {
        let mut v = Vfs::new();
        v.write(
            "p/sweep.json",
            br#"{"type":"mc_sweep","n_jobs":32,"seed":1,"backend":"rust"}"#.to_vec(),
        );
        let mut e = P2racEngine::rust_only();
        let script =
            Json::parse(std::str::from_utf8(v.read("p/sweep.json").unwrap()).unwrap()).unwrap();
        let out = e.run("sweep.json", &script, &v, "p", &view(5, 4)).unwrap();
        // 4 workers (5 nodes - master) each get a partial file.
        assert_eq!(out.worker_files.len(), 4);
        assert!(out.master_files.iter().any(|(n, _)| n == "sweep.csv"));
        // Partition covers all jobs exactly once.
        let total_lines: usize = out
            .worker_files
            .iter()
            .map(|(_, _, b)| std::str::from_utf8(b).unwrap().lines().count() - 1)
            .sum();
        assert_eq!(total_lines, 32);
    }

    #[test]
    fn cluster_is_faster_than_instance_in_virtual_time() {
        let (mut v, dir) = catopt_project();
        // Compute-bound config: bigger population, no master-side BFGS
        // (which costs the same everywhere and would mask the scaling).
        v.write(
            "proj/catopt.json",
            br#"{"type":"catopt","pop_size":64,"max_generations":6,"seed":3,"backend":"rust","bfgs_every":0}"#
                .to_vec(),
        );
        let script = Json::parse(std::str::from_utf8(v.read("proj/catopt.json").unwrap()).unwrap())
            .unwrap();
        let mut e = P2racEngine::rust_only();
        let t1 = e.run("s", &script, &v, &dir, &view(1, 4)).unwrap().compute_s;
        let t8 = e.run("s", &script, &v, &dir, &view(8, 4)).unwrap().compute_s;
        assert!(t8 < t1 / 3.0, "8-node {t8}s vs 1-node {t1}s");
    }

    #[test]
    fn thread_count_changes_neither_numerics_nor_virtual_time() {
        // The `-threads` knob controls real parallelism only: summary
        // values and billed virtual compute time must be identical.
        let (v, dir) = catopt_project();
        let script = Json::parse(std::str::from_utf8(v.read("proj/catopt.json").unwrap()).unwrap())
            .unwrap();
        let mut e = P2racEngine::rust_only();
        let mut serial_view = view(4, 4);
        serial_view.real_threads = Some(1);
        let mut threaded_view = view(4, 4);
        threaded_view.real_threads = Some(4);
        let a = e.run("catopt.json", &script, &v, &dir, &serial_view).unwrap();
        let b = e.run("catopt.json", &script, &v, &dir, &threaded_view).unwrap();
        assert_eq!(a.compute_s, b.compute_s);
        assert_eq!(a.summary.to_string_compact(), b.summary.to_string_compact());
        assert_eq!(a.master_files, b.master_files);
    }

    #[test]
    fn unknown_type_rejected() {
        let mut e = P2racEngine::rust_only();
        let script = Json::parse(r#"{"type":"quantum"}"#).unwrap();
        assert!(e.run("x", &script, &Vfs::new(), "p", &view(1, 1)).is_err());
    }

    #[test]
    fn missing_data_files_reported() {
        let mut v = Vfs::new();
        v.write("p/catopt.json", br#"{"type":"catopt","backend":"rust"}"#.to_vec());
        let mut e = P2racEngine::rust_only();
        let script =
            Json::parse(std::str::from_utf8(v.read("p/catopt.json").unwrap()).unwrap()).unwrap();
        let err = e.run("catopt.json", &script, &v, "p", &view(1, 1)).unwrap_err();
        assert!(err.to_string().contains("meta.json"));
    }
}
