//! rgenoud-style genetic optimisation: the nine operators, BFGS
//! refinement, and the generational loop with distributed fitness
//! fan-out.

pub mod bfgs;
pub mod operators;
pub mod optimizer;

pub use bfgs::{minimize, BfgsOptions, BfgsResult};
pub use operators::Domain;
pub use optimizer::{
    run, run_with_pool, GaConfig, GaResult, GaRunner, GenerationStat, OperatorWeights,
};
