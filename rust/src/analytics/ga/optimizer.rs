//! The rgenoud-style distributed genetic optimiser (paper §4: "a
//! distributed genetic algorithm using the rgenoud R package which
//! combines evolutionary search algorithms with derivative-based
//! (Newton or quasi-Newton) methods").
//!
//! Per generation: elitist selection, offspring from the nine operators
//! in configured proportions, population fitness through a
//! [`FitnessBackend`] (the PJRT artifact in production — this is the
//! fan-out the paper distributes over SNOW workers), and periodic BFGS
//! polish of the incumbent.

use super::bfgs::{self, BfgsOptions};
use super::operators::{self, Domain};
use crate::analytics::backend::FitnessBackend;
use crate::analytics::pool::WorkerPool;
use crate::util::json::Json;
use crate::util::prng::Xoshiro256;
use anyhow::{anyhow, Result};

/// Operator mix (counts are normalised into proportions of the
/// offspring pool); defaults follow rgenoud's defaults in spirit.
#[derive(Clone, Debug)]
pub struct OperatorWeights {
    pub cloning: f32,
    pub uniform_mutation: f32,
    pub boundary_mutation: f32,
    pub nonuniform_mutation: f32,
    pub polytope_crossover: f32,
    pub simple_crossover: f32,
    pub whole_nonuniform_mutation: f32,
    pub heuristic_crossover: f32,
    pub local_minimum_crossover: f32,
}

impl Default for OperatorWeights {
    fn default() -> Self {
        Self {
            cloning: 1.0,
            uniform_mutation: 1.0,
            boundary_mutation: 1.0,
            nonuniform_mutation: 1.0,
            polytope_crossover: 1.0,
            simple_crossover: 1.0,
            whole_nonuniform_mutation: 1.0,
            heuristic_crossover: 1.0,
            local_minimum_crossover: 0.5,
        }
    }
}

#[derive(Clone, Debug)]
pub struct GaConfig {
    /// Population size (paper experiment: 200).
    pub pop_size: usize,
    /// Maximum generations (paper experiment: 50).
    pub max_generations: usize,
    /// Stop after this many generations without improvement.
    pub wait_generations: usize,
    /// Run BFGS polish on the incumbent every k generations (0 = never).
    pub bfgs_every: usize,
    pub bfgs: BfgsOptions,
    pub operators: OperatorWeights,
    pub domain: Domain,
    pub seed: u64,
    /// Tournament size for parent selection.
    pub tournament: usize,
}

impl Default for GaConfig {
    fn default() -> Self {
        Self {
            pop_size: 200,
            max_generations: 50,
            wait_generations: 15,
            // Polish sparingly: BFGS runs serially on the SNOW master,
            // so its gradient evaluations cap the parallel speed-up.
            bfgs_every: 25,
            bfgs: BfgsOptions {
                max_iters: 6,
                max_line_steps: 8,
                ..Default::default()
            },
            operators: OperatorWeights::default(),
            domain: Domain { lo: 0.0, hi: 1.0 },
            seed: 42,
            tournament: 3,
        }
    }
}

/// Per-generation record (drives convergence plots and timing models).
#[derive(Clone, Debug)]
pub struct GenerationStat {
    pub generation: usize,
    pub best_value: f32,
    pub mean_value: f32,
    /// Candidate evaluations performed this generation (the unit of
    /// work the paper fans out across SNOW workers).
    pub evaluations: usize,
    /// Gradient evaluations (BFGS polish), master-side work.
    pub grad_evaluations: usize,
}

#[derive(Clone, Debug)]
pub struct GaResult {
    pub best: Vec<f32>,
    pub best_value: f32,
    pub history: Vec<GenerationStat>,
    pub generations_run: usize,
    pub total_evaluations: usize,
}

fn tournament_pick<'a>(
    pop: &'a [Vec<f32>],
    fit: &[f32],
    k: usize,
    rng: &mut Xoshiro256,
) -> &'a Vec<f32> {
    let mut best = rng.below_usize(pop.len());
    for _ in 1..k.max(1) {
        let c = rng.below_usize(pop.len());
        if fit[c] < fit[best] {
            best = c;
        }
    }
    &pop[best]
}

/// Run the optimiser against a backend on the calling thread (serial
/// reference path).
pub fn run(backend: &dyn FitnessBackend, cfg: &GaConfig) -> Result<GaResult> {
    run_with_pool(backend, cfg, &WorkerPool::serial())
}

/// Run the optimiser with population fitness sharded across a
/// [`WorkerPool`] — the paper's SNOW fan-out made real. All evolution
/// (selection, operators, BFGS polish) stays on the calling thread with
/// a single RNG stream, and shard fitness values are stitched back by
/// candidate index, so the result is bit-identical to [`run`] for the
/// same seed regardless of thread count.
pub fn run_with_pool(
    backend: &dyn FitnessBackend,
    cfg: &GaConfig,
    pool: &WorkerPool,
) -> Result<GaResult> {
    let mut runner = GaRunner::new(backend, cfg.clone(), pool)?;
    while !runner.step(backend, pool)? {}
    Ok(runner.result())
}

/// The optimiser's loop state as an explicit, checkpointable machine:
/// [`run_with_pool`] is `new` + `step` until done, and the jobs
/// subsystem drives the same machine one slice at a time, snapshotting
/// between slices. Because [`GaRunner::snapshot`] captures every
/// loop-carried value exactly — including the raw RNG state — a runner
/// restored on replacement capacity continues the identical stream: an
/// interrupted-and-resumed run is bit-identical to an uninterrupted
/// one.
pub struct GaRunner {
    cfg: GaConfig,
    rng: Xoshiro256,
    pop: Vec<Vec<f32>>,
    fit: Vec<f32>,
    history: Vec<GenerationStat>,
    stagnant: usize,
    best_ever_value: f32,
    best_ever: Vec<f32>,
    /// Next generation index to execute.
    generation: usize,
    generations_run: usize,
    total_evaluations: usize,
    finished: bool,
}

impl GaRunner {
    /// Seed the initial population and evaluate it (the one eval that
    /// happens before the first generation).
    pub fn new(backend: &dyn FitnessBackend, cfg: GaConfig, pool: &WorkerPool) -> Result<Self> {
        let n = backend.dims();
        let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
        let dom = cfg.domain;
        // Initial population: feasible-ish around budget/m + exploration.
        let pop: Vec<Vec<f32>> = (0..cfg.pop_size)
            .map(|i| {
                if i == 0 {
                    vec![crate::analytics::catbond::BUDGET / n as f32; n]
                } else {
                    (0..n)
                        .map(|_| (rng.next_f32() * 2.0 / n as f32).min(dom.hi))
                        .collect()
                }
            })
            .collect();
        let fit = pool.eval(backend, &pop)?;
        let total_evaluations = pop.len();
        let best_ever = pop[0].clone();
        Ok(Self {
            cfg,
            rng,
            pop,
            fit,
            history: Vec::new(),
            stagnant: 0,
            best_ever_value: f32::INFINITY,
            best_ever,
            generation: 0,
            generations_run: 0,
            total_evaluations,
            finished: false,
        })
    }

    pub fn is_finished(&self) -> bool {
        self.finished
    }

    /// Generations executed so far.
    pub fn generations_run(&self) -> usize {
        self.generations_run
    }

    /// Upper bound on the number of generations (progress denominator).
    pub fn max_generations(&self) -> usize {
        self.cfg.max_generations
    }

    pub fn history(&self) -> &[GenerationStat] {
        &self.history
    }

    /// Candidate dimensionality of the (restored) population — callers
    /// cross-check this against their backend before stepping.
    pub fn dims(&self) -> usize {
        self.pop.first().map(Vec::len).unwrap_or(0)
    }

    /// Execute one generation; returns `true` once the run is complete
    /// (generation budget exhausted or stagnation stop).
    pub fn step(&mut self, backend: &dyn FitnessBackend, pool: &WorkerPool) -> Result<bool> {
        if self.finished || self.generation >= self.cfg.max_generations {
            self.finished = true;
            return Ok(true);
        }
        let generation = self.generation;
        self.generation += 1;
        self.generations_run = generation + 1;
        let cfg = &self.cfg;
        let dom = cfg.domain;
        let progress = generation as f32 / cfg.max_generations.max(1) as f32;
        let rng = &mut self.rng;
        let pop = &mut self.pop;
        let fit = &mut self.fit;

        // Track incumbent.
        let (bi, bv) = fit
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, &v)| (i, v))
            .unwrap();
        if bv < self.best_ever_value - 1e-9 {
            self.best_ever_value = bv;
            self.best_ever = pop[bi].clone();
            self.stagnant = 0;
        } else {
            self.stagnant += 1;
        }

        let mut grad_evals = 0usize;
        // Periodic BFGS polish of the incumbent (rgenoud hybrid).
        let refined: Option<Vec<f32>> =
            if cfg.bfgs_every > 0 && (generation + 1) % cfg.bfgs_every == 0 {
                let r = bfgs::minimize(backend, &self.best_ever, &cfg.bfgs)?;
                grad_evals += r.grad_evals;
                if r.value < self.best_ever_value {
                    self.best_ever_value = r.value;
                    self.best_ever = r.x.clone();
                    self.stagnant = 0;
                }
                Some(r.x)
            } else {
                None
            };

        let w = &cfg.operators;
        let weights = [
            w.cloning,
            w.uniform_mutation,
            w.boundary_mutation,
            w.nonuniform_mutation,
            w.polytope_crossover,
            w.simple_crossover,
            w.whole_nonuniform_mutation,
            w.heuristic_crossover,
            w.local_minimum_crossover,
        ];
        let wsum: f32 = weights.iter().sum();

        // Offspring pool (elitism: slot 0 is the incumbent clone).
        let mut next: Vec<Vec<f32>> = Vec::with_capacity(cfg.pop_size);
        next.push(self.best_ever.clone());
        while next.len() < cfg.pop_size {
            let pick = rng.next_f32() * wsum;
            let mut acc = 0.0;
            let mut op = 0;
            for (i, &wt) in weights.iter().enumerate() {
                acc += wt;
                if pick <= acc {
                    op = i;
                    break;
                }
            }
            match op {
                0 => next.push(tournament_pick(pop, fit, cfg.tournament, rng).clone()),
                1 => {
                    let mut c = tournament_pick(pop, fit, cfg.tournament, rng).clone();
                    operators::uniform_mutation(&mut c, dom, rng);
                    next.push(c);
                }
                2 => {
                    let mut c = tournament_pick(pop, fit, cfg.tournament, rng).clone();
                    operators::boundary_mutation(&mut c, dom, rng);
                    next.push(c);
                }
                3 => {
                    let mut c = tournament_pick(pop, fit, cfg.tournament, rng).clone();
                    operators::nonuniform_mutation(&mut c, dom, progress, rng);
                    next.push(c);
                }
                4 => {
                    let p1 = tournament_pick(pop, fit, cfg.tournament, rng).clone();
                    let p2 = tournament_pick(pop, fit, cfg.tournament, rng).clone();
                    let p3 = tournament_pick(pop, fit, cfg.tournament, rng).clone();
                    next.push(operators::polytope_crossover(&[&p1, &p2, &p3], rng));
                }
                5 => {
                    let p1 = tournament_pick(pop, fit, cfg.tournament, rng).clone();
                    let p2 = tournament_pick(pop, fit, cfg.tournament, rng).clone();
                    let (c1, c2) = operators::simple_crossover(&p1, &p2, rng);
                    next.push(c1);
                    if next.len() < cfg.pop_size {
                        next.push(c2);
                    }
                }
                6 => {
                    let mut c = tournament_pick(pop, fit, cfg.tournament, rng).clone();
                    operators::whole_nonuniform_mutation(&mut c, dom, progress, rng);
                    next.push(c);
                }
                7 => {
                    let i1 = rng.below_usize(pop.len());
                    let i2 = rng.below_usize(pop.len());
                    let (b, wse) = if fit[i1] <= fit[i2] { (i1, i2) } else { (i2, i1) };
                    next.push(operators::heuristic_crossover(&pop[b], &pop[wse], dom, rng));
                }
                _ => {
                    let base = tournament_pick(pop, fit, cfg.tournament, rng).clone();
                    let target = refined.as_ref().unwrap_or(&self.best_ever);
                    next.push(operators::local_minimum_crossover(&base, target, rng));
                }
            }
        }

        // Fan-out: evaluate the whole offspring pool (the distributed
        // step — the coordinator bills scatter/gather per generation,
        // and the pool shards it over real threads).
        *pop = next;
        *fit = pool.eval(backend, pop)?;
        self.total_evaluations += pop.len();

        let mean = fit.iter().sum::<f32>() / fit.len() as f32;
        let gen_best = fit.iter().cloned().fold(f32::INFINITY, f32::min);
        self.history.push(GenerationStat {
            generation,
            best_value: gen_best.min(self.best_ever_value),
            mean_value: mean,
            evaluations: pop.len(),
            grad_evaluations: grad_evals,
        });

        if self.stagnant >= self.cfg.wait_generations
            || self.generation >= self.cfg.max_generations
        {
            self.finished = true;
        }
        Ok(self.finished)
    }

    /// Finalise into a [`GaResult`] (final incumbent check against the
    /// last evaluated population — identical to the one-shot path).
    pub fn result(&self) -> GaResult {
        let mut best_ever_value = self.best_ever_value;
        let mut best_ever = self.best_ever.clone();
        let (bi, bv) = self
            .fit
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, &v)| (i, v))
            .unwrap();
        if bv < best_ever_value {
            best_ever_value = bv;
            best_ever = self.pop[bi].clone();
        }
        GaResult {
            best: best_ever,
            best_value: best_ever_value,
            history: self.history.clone(),
            generations_run: self.generations_run,
            total_evaluations: self.total_evaluations,
        }
    }

    // ------------------------------------------------- checkpointing

    /// Serialize every loop-carried value exactly. RNG words are hex
    /// strings (JSON numbers are f64 and would corrupt high bits);
    /// f32 values pass through f64 losslessly.
    pub fn snapshot(&self) -> Json {
        let mut j = Json::obj();
        j.set(
            "rng",
            Json::Arr(
                self.rng
                    .state()
                    .iter()
                    .map(|w| Json::str(format!("{w:016x}")))
                    .collect(),
            ),
        );
        j.set("pop", Json::Arr(self.pop.iter().map(|c| f32s_to_json(c)).collect()));
        j.set("fit", f32s_to_json(&self.fit));
        j.set(
            "history",
            Json::Arr(
                self.history
                    .iter()
                    .map(|h| {
                        Json::from_pairs(vec![
                            ("generation", Json::num(h.generation as f64)),
                            ("best_value", Json::num(h.best_value as f64)),
                            ("mean_value", Json::num(h.mean_value as f64)),
                            ("evaluations", Json::num(h.evaluations as f64)),
                            ("grad_evaluations", Json::num(h.grad_evaluations as f64)),
                        ])
                    })
                    .collect(),
            ),
        );
        j.set("stagnant", Json::num(self.stagnant as f64));
        j.set(
            "best_ever_value",
            if self.best_ever_value.is_finite() {
                Json::num(self.best_ever_value as f64)
            } else {
                Json::Null
            },
        );
        j.set("best_ever", f32s_to_json(&self.best_ever));
        j.set("generation", Json::num(self.generation as f64));
        j.set("generations_run", Json::num(self.generations_run as f64));
        j.set("total_evaluations", Json::num(self.total_evaluations as f64));
        j.set("finished", Json::Bool(self.finished));
        j
    }

    /// Rebuild a runner from a snapshot. The config is re-derived from
    /// the job's script by the caller (it is deterministic), so the
    /// checkpoint only carries state.
    pub fn restore(cfg: GaConfig, j: &Json) -> Result<Self> {
        let rng_words = j
            .get("rng")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("checkpoint missing rng state"))?;
        if rng_words.len() != 4 {
            anyhow::bail!("checkpoint rng state must have 4 words");
        }
        let mut state = [0u64; 4];
        for (i, w) in rng_words.iter().enumerate() {
            let s = w.as_str().ok_or_else(|| anyhow!("rng word not a string"))?;
            state[i] = u64::from_str_radix(s, 16)
                .map_err(|e| anyhow!("bad rng word '{s}': {e}"))?;
        }
        let pop = j
            .get("pop")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("checkpoint missing population"))?
            .iter()
            .map(json_to_f32s)
            .collect::<Result<Vec<_>>>()?;
        let fit = json_to_f32s(
            j.get("fit").ok_or_else(|| anyhow!("checkpoint missing fitness"))?,
        )?;
        // Structural validation: a truncated or hand-edited checkpoint
        // must surface as an error here, not as a panic mid-step.
        if pop.is_empty() {
            anyhow::bail!("checkpoint population is empty");
        }
        if fit.len() != pop.len() {
            anyhow::bail!(
                "checkpoint fitness/population mismatch ({} vs {})",
                fit.len(),
                pop.len()
            );
        }
        let dims = pop[0].len();
        if dims == 0 || pop.iter().any(|c| c.len() != dims) {
            anyhow::bail!("checkpoint population has inconsistent dimensions");
        }
        let mut history = Vec::new();
        if let Some(hs) = j.get("history").and_then(Json::as_arr) {
            for h in hs {
                history.push(GenerationStat {
                    generation: h.req_u64("generation")? as usize,
                    best_value: h.req_f64("best_value")? as f32,
                    mean_value: h.req_f64("mean_value")? as f32,
                    evaluations: h.req_u64("evaluations")? as usize,
                    grad_evaluations: h.req_u64("grad_evaluations")? as usize,
                });
            }
        }
        let best_ever_value = match j.get("best_ever_value") {
            Some(Json::Null) | None => f32::INFINITY,
            Some(v) => v.as_f64().ok_or_else(|| anyhow!("bad best_ever_value"))? as f32,
        };
        let best_ever = json_to_f32s(
            j.get("best_ever").ok_or_else(|| anyhow!("checkpoint missing best"))?,
        )?;
        if best_ever.len() != dims {
            anyhow::bail!(
                "checkpoint incumbent has {} dims, population has {dims}",
                best_ever.len()
            );
        }
        Ok(Self {
            cfg,
            rng: Xoshiro256::from_state(state),
            pop,
            fit,
            history,
            stagnant: j.req_u64("stagnant")? as usize,
            best_ever_value,
            best_ever,
            generation: j.req_u64("generation")? as usize,
            generations_run: j.req_u64("generations_run")? as usize,
            total_evaluations: j.req_u64("total_evaluations")? as usize,
            finished: j.opt_bool("finished", false),
        })
    }
}

fn f32s_to_json(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::num(x as f64)).collect())
}

fn json_to_f32s(j: &Json) -> Result<Vec<f32>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("expected an array of numbers"))?
        .iter()
        .map(|v| {
            v.as_f64()
                .map(|x| x as f32)
                .ok_or_else(|| anyhow!("expected a number"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::backend::RustBackend;
    use crate::analytics::catbond::CatBondData;

    fn small_cfg() -> GaConfig {
        GaConfig {
            pop_size: 24,
            max_generations: 20,
            wait_generations: 20,
            bfgs_every: 5,
            seed: 7,
            ..Default::default()
        }
    }

    #[test]
    fn optimiser_improves_over_initial_population() {
        let data = CatBondData::generate(11, 24, 96);
        let b = RustBackend::new(data);
        let m = b.dims();
        let init = b
            .eval_population(&[vec![crate::analytics::catbond::BUDGET / m as f32; m]])
            .unwrap()[0];
        let r = run(&b, &small_cfg()).unwrap();
        assert!(
            r.best_value < init,
            "GA best {} must beat uniform start {init}",
            r.best_value
        );
        assert_eq!(r.history.len(), r.generations_run);
        assert!(r.total_evaluations >= 24 * 2);
    }

    #[test]
    fn best_value_is_monotone_nonincreasing() {
        let data = CatBondData::generate(13, 16, 64);
        let b = RustBackend::new(data);
        let r = run(&b, &small_cfg()).unwrap();
        for w in r.history.windows(2) {
            assert!(
                w[1].best_value <= w[0].best_value + 1e-6,
                "incumbent must never regress: {} -> {}",
                w[0].best_value,
                w[1].best_value
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let data = CatBondData::generate(17, 16, 48);
        let b1 = RustBackend::new(data.clone());
        let b2 = RustBackend::new(data);
        let r1 = run(&b1, &small_cfg()).unwrap();
        let r2 = run(&b2, &small_cfg()).unwrap();
        assert_eq!(r1.best, r2.best);
        assert_eq!(r1.best_value, r2.best_value);
    }

    #[test]
    fn pooled_run_is_bit_identical_to_serial() {
        let data = CatBondData::generate(29, 16, 48);
        let b = RustBackend::new(data);
        let serial = run(&b, &small_cfg()).unwrap();
        for pool in [
            crate::analytics::pool::WorkerPool::new(2, 3),
            crate::analytics::pool::WorkerPool::new(4, 8),
        ] {
            let pooled = run_with_pool(&b, &small_cfg(), &pool).unwrap();
            assert_eq!(serial.best, pooled.best);
            assert_eq!(serial.best_value, pooled.best_value);
            assert_eq!(serial.generations_run, pooled.generations_run);
            for (a, z) in serial.history.iter().zip(&pooled.history) {
                assert_eq!(a.best_value, z.best_value);
                assert_eq!(a.mean_value, z.mean_value);
            }
        }
    }

    #[test]
    fn stepwise_runner_matches_one_shot() {
        let data = CatBondData::generate(31, 16, 48);
        let b = RustBackend::new(data);
        let one_shot = run(&b, &small_cfg()).unwrap();
        let pool = WorkerPool::serial();
        let mut r = GaRunner::new(&b, small_cfg(), &pool).unwrap();
        while !r.step(&b, &pool).unwrap() {}
        let stepped = r.result();
        assert_eq!(one_shot.best, stepped.best);
        assert_eq!(one_shot.best_value, stepped.best_value);
        assert_eq!(one_shot.generations_run, stepped.generations_run);
        assert_eq!(one_shot.total_evaluations, stepped.total_evaluations);
    }

    #[test]
    fn snapshot_restore_is_bit_identical() {
        // Interrupt after k generations, serialize through JSON text
        // (the same path a job checkpoint takes), resume, and compare
        // against the uninterrupted run — bit for bit.
        let data = CatBondData::generate(37, 16, 48);
        let b = RustBackend::new(data);
        let reference = run(&b, &small_cfg()).unwrap();
        let pool = WorkerPool::serial();
        for cut in [0usize, 1, 3, 7] {
            let mut r = GaRunner::new(&b, small_cfg(), &pool).unwrap();
            let mut done = false;
            for _ in 0..cut {
                if r.step(&b, &pool).unwrap() {
                    done = true;
                    break;
                }
            }
            let wire = r.snapshot().to_string_compact();
            let parsed = Json::parse(&wire).unwrap();
            let mut resumed = GaRunner::restore(small_cfg(), &parsed).unwrap();
            if !done {
                while !resumed.step(&b, &pool).unwrap() {}
            }
            let out = resumed.result();
            assert_eq!(reference.best, out.best, "cut at {cut}");
            assert_eq!(reference.best_value, out.best_value, "cut at {cut}");
            assert_eq!(reference.generations_run, out.generations_run);
            for (a, z) in reference.history.iter().zip(&out.history) {
                assert_eq!(a.best_value, z.best_value);
                assert_eq!(a.mean_value, z.mean_value);
            }
        }
    }

    #[test]
    fn corrupt_checkpoint_is_an_error_not_a_panic() {
        // Truncated population: restore must fail cleanly, never panic
        // later in step()/result().
        let j = Json::parse(
            r#"{"rng":["0","1","2","3"],"pop":[],"fit":[],"stagnant":0,
                "best_ever":[],"best_ever_value":null,"generation":0,
                "generations_run":0,"total_evaluations":0,"finished":false}"#,
        )
        .unwrap();
        assert!(GaRunner::restore(GaConfig::default(), &j).is_err());
        // Fitness/population length mismatch.
        let j = Json::parse(
            r#"{"rng":["0","1","2","3"],"pop":[[0.5,0.5]],"fit":[1.0,2.0],
                "stagnant":0,"best_ever":[0.5,0.5],"best_ever_value":null,
                "generation":0,"generations_run":0,"total_evaluations":0,
                "finished":false}"#,
        )
        .unwrap();
        assert!(GaRunner::restore(GaConfig::default(), &j).is_err());
    }

    #[test]
    fn early_stop_on_stagnation() {
        let data = CatBondData::generate(19, 8, 32);
        let b = RustBackend::new(data);
        let cfg = GaConfig {
            pop_size: 10,
            max_generations: 200,
            wait_generations: 3,
            bfgs_every: 0,
            seed: 1,
            ..Default::default()
        };
        let r = run(&b, &cfg).unwrap();
        assert!(
            r.generations_run < 200,
            "should stop early, ran {}",
            r.generations_run
        );
    }

    #[test]
    fn final_best_is_feasible_enough() {
        let data = CatBondData::generate(23, 24, 96);
        let b = RustBackend::new(data.clone());
        let r = run(&b, &small_cfg()).unwrap();
        let pen = crate::analytics::catbond::penalty(&r.best);
        // The penalty terms should have pushed the solution near the
        // feasible region (budget ≈ 1, weights in bounds).
        let sum: f32 = r.best.iter().sum();
        assert!(pen < 50.0, "penalty {pen} too large");
        assert!((0.5..=1.5).contains(&sum), "budget sum {sum}");
    }
}
