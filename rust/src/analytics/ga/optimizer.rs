//! The rgenoud-style distributed genetic optimiser (paper §4: "a
//! distributed genetic algorithm using the rgenoud R package which
//! combines evolutionary search algorithms with derivative-based
//! (Newton or quasi-Newton) methods").
//!
//! Per generation: elitist selection, offspring from the nine operators
//! in configured proportions, population fitness through a
//! [`FitnessBackend`] (the PJRT artifact in production — this is the
//! fan-out the paper distributes over SNOW workers), and periodic BFGS
//! polish of the incumbent.

use super::bfgs::{self, BfgsOptions};
use super::operators::{self, Domain};
use crate::analytics::backend::FitnessBackend;
use crate::analytics::pool::WorkerPool;
use crate::util::prng::Xoshiro256;
use anyhow::Result;

/// Operator mix (counts are normalised into proportions of the
/// offspring pool); defaults follow rgenoud's defaults in spirit.
#[derive(Clone, Debug)]
pub struct OperatorWeights {
    pub cloning: f32,
    pub uniform_mutation: f32,
    pub boundary_mutation: f32,
    pub nonuniform_mutation: f32,
    pub polytope_crossover: f32,
    pub simple_crossover: f32,
    pub whole_nonuniform_mutation: f32,
    pub heuristic_crossover: f32,
    pub local_minimum_crossover: f32,
}

impl Default for OperatorWeights {
    fn default() -> Self {
        Self {
            cloning: 1.0,
            uniform_mutation: 1.0,
            boundary_mutation: 1.0,
            nonuniform_mutation: 1.0,
            polytope_crossover: 1.0,
            simple_crossover: 1.0,
            whole_nonuniform_mutation: 1.0,
            heuristic_crossover: 1.0,
            local_minimum_crossover: 0.5,
        }
    }
}

#[derive(Clone, Debug)]
pub struct GaConfig {
    /// Population size (paper experiment: 200).
    pub pop_size: usize,
    /// Maximum generations (paper experiment: 50).
    pub max_generations: usize,
    /// Stop after this many generations without improvement.
    pub wait_generations: usize,
    /// Run BFGS polish on the incumbent every k generations (0 = never).
    pub bfgs_every: usize,
    pub bfgs: BfgsOptions,
    pub operators: OperatorWeights,
    pub domain: Domain,
    pub seed: u64,
    /// Tournament size for parent selection.
    pub tournament: usize,
}

impl Default for GaConfig {
    fn default() -> Self {
        Self {
            pop_size: 200,
            max_generations: 50,
            wait_generations: 15,
            // Polish sparingly: BFGS runs serially on the SNOW master,
            // so its gradient evaluations cap the parallel speed-up.
            bfgs_every: 25,
            bfgs: BfgsOptions {
                max_iters: 6,
                max_line_steps: 8,
                ..Default::default()
            },
            operators: OperatorWeights::default(),
            domain: Domain { lo: 0.0, hi: 1.0 },
            seed: 42,
            tournament: 3,
        }
    }
}

/// Per-generation record (drives convergence plots and timing models).
#[derive(Clone, Debug)]
pub struct GenerationStat {
    pub generation: usize,
    pub best_value: f32,
    pub mean_value: f32,
    /// Candidate evaluations performed this generation (the unit of
    /// work the paper fans out across SNOW workers).
    pub evaluations: usize,
    /// Gradient evaluations (BFGS polish), master-side work.
    pub grad_evaluations: usize,
}

#[derive(Clone, Debug)]
pub struct GaResult {
    pub best: Vec<f32>,
    pub best_value: f32,
    pub history: Vec<GenerationStat>,
    pub generations_run: usize,
    pub total_evaluations: usize,
}

fn tournament_pick<'a>(
    pop: &'a [Vec<f32>],
    fit: &[f32],
    k: usize,
    rng: &mut Xoshiro256,
) -> &'a Vec<f32> {
    let mut best = rng.below_usize(pop.len());
    for _ in 1..k.max(1) {
        let c = rng.below_usize(pop.len());
        if fit[c] < fit[best] {
            best = c;
        }
    }
    &pop[best]
}

/// Run the optimiser against a backend on the calling thread (serial
/// reference path).
pub fn run(backend: &dyn FitnessBackend, cfg: &GaConfig) -> Result<GaResult> {
    run_with_pool(backend, cfg, &WorkerPool::serial())
}

/// Run the optimiser with population fitness sharded across a
/// [`WorkerPool`] — the paper's SNOW fan-out made real. All evolution
/// (selection, operators, BFGS polish) stays on the calling thread with
/// a single RNG stream, and shard fitness values are stitched back by
/// candidate index, so the result is bit-identical to [`run`] for the
/// same seed regardless of thread count.
pub fn run_with_pool(
    backend: &dyn FitnessBackend,
    cfg: &GaConfig,
    pool: &WorkerPool,
) -> Result<GaResult> {
    let n = backend.dims();
    let mut rng = Xoshiro256::seed_from_u64(cfg.seed);
    let dom = cfg.domain;

    // Initial population: feasible-ish around budget/m plus exploration.
    let mut pop: Vec<Vec<f32>> = (0..cfg.pop_size)
        .map(|i| {
            if i == 0 {
                vec![crate::analytics::catbond::BUDGET / n as f32; n]
            } else {
                (0..n)
                    .map(|_| (rng.next_f32() * 2.0 / n as f32).min(dom.hi))
                    .collect()
            }
        })
        .collect();
    let mut fit = pool.eval(backend, &pop)?;
    let mut total_evals = pop.len();

    let mut history = Vec::with_capacity(cfg.max_generations);
    let mut stagnant = 0usize;
    let mut best_ever_value = f32::INFINITY;
    let mut best_ever: Vec<f32> = pop[0].clone();

    let w = &cfg.operators;
    let weights = [
        w.cloning,
        w.uniform_mutation,
        w.boundary_mutation,
        w.nonuniform_mutation,
        w.polytope_crossover,
        w.simple_crossover,
        w.whole_nonuniform_mutation,
        w.heuristic_crossover,
        w.local_minimum_crossover,
    ];
    let wsum: f32 = weights.iter().sum();

    let mut generations_run = 0;
    for generation in 0..cfg.max_generations {
        generations_run = generation + 1;
        let progress = generation as f32 / cfg.max_generations.max(1) as f32;

        // Track incumbent.
        let (bi, bv) = fit
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, &v)| (i, v))
            .unwrap();
        if bv < best_ever_value - 1e-9 {
            best_ever_value = bv;
            best_ever = pop[bi].clone();
            stagnant = 0;
        } else {
            stagnant += 1;
        }

        let mut grad_evals = 0usize;
        // Periodic BFGS polish of the incumbent (rgenoud hybrid).
        let refined: Option<Vec<f32>> =
            if cfg.bfgs_every > 0 && (generation + 1) % cfg.bfgs_every == 0 {
                let r = bfgs::minimize(backend, &best_ever, &cfg.bfgs)?;
                grad_evals += r.grad_evals;
                if r.value < best_ever_value {
                    best_ever_value = r.value;
                    best_ever = r.x.clone();
                    stagnant = 0;
                }
                Some(r.x)
            } else {
                None
            };

        // Offspring pool (elitism: slot 0 is the incumbent clone).
        let mut next: Vec<Vec<f32>> = Vec::with_capacity(cfg.pop_size);
        next.push(best_ever.clone());
        while next.len() < cfg.pop_size {
            let pick = rng.next_f32() * wsum;
            let mut acc = 0.0;
            let mut op = 0;
            for (i, &wt) in weights.iter().enumerate() {
                acc += wt;
                if pick <= acc {
                    op = i;
                    break;
                }
            }
            match op {
                0 => next.push(tournament_pick(&pop, &fit, cfg.tournament, &mut rng).clone()),
                1 => {
                    let mut c = tournament_pick(&pop, &fit, cfg.tournament, &mut rng).clone();
                    operators::uniform_mutation(&mut c, dom, &mut rng);
                    next.push(c);
                }
                2 => {
                    let mut c = tournament_pick(&pop, &fit, cfg.tournament, &mut rng).clone();
                    operators::boundary_mutation(&mut c, dom, &mut rng);
                    next.push(c);
                }
                3 => {
                    let mut c = tournament_pick(&pop, &fit, cfg.tournament, &mut rng).clone();
                    operators::nonuniform_mutation(&mut c, dom, progress, &mut rng);
                    next.push(c);
                }
                4 => {
                    let p1 = tournament_pick(&pop, &fit, cfg.tournament, &mut rng).clone();
                    let p2 = tournament_pick(&pop, &fit, cfg.tournament, &mut rng).clone();
                    let p3 = tournament_pick(&pop, &fit, cfg.tournament, &mut rng).clone();
                    next.push(operators::polytope_crossover(
                        &[&p1, &p2, &p3],
                        &mut rng,
                    ));
                }
                5 => {
                    let p1 = tournament_pick(&pop, &fit, cfg.tournament, &mut rng).clone();
                    let p2 = tournament_pick(&pop, &fit, cfg.tournament, &mut rng).clone();
                    let (c1, c2) = operators::simple_crossover(&p1, &p2, &mut rng);
                    next.push(c1);
                    if next.len() < cfg.pop_size {
                        next.push(c2);
                    }
                }
                6 => {
                    let mut c = tournament_pick(&pop, &fit, cfg.tournament, &mut rng).clone();
                    operators::whole_nonuniform_mutation(&mut c, dom, progress, &mut rng);
                    next.push(c);
                }
                7 => {
                    let i1 = rng.below_usize(pop.len());
                    let i2 = rng.below_usize(pop.len());
                    let (b, wse) = if fit[i1] <= fit[i2] { (i1, i2) } else { (i2, i1) };
                    next.push(operators::heuristic_crossover(
                        &pop[b], &pop[wse], dom, &mut rng,
                    ));
                }
                _ => {
                    let base = tournament_pick(&pop, &fit, cfg.tournament, &mut rng).clone();
                    let target = refined.as_ref().unwrap_or(&best_ever);
                    next.push(operators::local_minimum_crossover(&base, target, &mut rng));
                }
            }
        }

        // Fan-out: evaluate the whole offspring pool (the distributed
        // step — the coordinator bills scatter/gather per generation,
        // and the pool shards it over real threads).
        pop = next;
        fit = pool.eval(backend, &pop)?;
        total_evals += pop.len();

        let mean = fit.iter().sum::<f32>() / fit.len() as f32;
        let gen_best = fit.iter().cloned().fold(f32::INFINITY, f32::min);
        history.push(GenerationStat {
            generation,
            best_value: gen_best.min(best_ever_value),
            mean_value: mean,
            evaluations: pop.len(),
            grad_evaluations: grad_evals,
        });

        if stagnant >= cfg.wait_generations {
            break;
        }
    }

    // Final incumbent check.
    let (bi, bv) = fit
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, &v)| (i, v))
        .unwrap();
    if bv < best_ever_value {
        best_ever_value = bv;
        best_ever = pop[bi].clone();
    }

    Ok(GaResult {
        best: best_ever,
        best_value: best_ever_value,
        history,
        generations_run,
        total_evaluations: total_evals,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::backend::RustBackend;
    use crate::analytics::catbond::CatBondData;

    fn small_cfg() -> GaConfig {
        GaConfig {
            pop_size: 24,
            max_generations: 20,
            wait_generations: 20,
            bfgs_every: 5,
            seed: 7,
            ..Default::default()
        }
    }

    #[test]
    fn optimiser_improves_over_initial_population() {
        let data = CatBondData::generate(11, 24, 96);
        let b = RustBackend::new(data);
        let m = b.dims();
        let init = b
            .eval_population(&[vec![crate::analytics::catbond::BUDGET / m as f32; m]])
            .unwrap()[0];
        let r = run(&b, &small_cfg()).unwrap();
        assert!(
            r.best_value < init,
            "GA best {} must beat uniform start {init}",
            r.best_value
        );
        assert_eq!(r.history.len(), r.generations_run);
        assert!(r.total_evaluations >= 24 * 2);
    }

    #[test]
    fn best_value_is_monotone_nonincreasing() {
        let data = CatBondData::generate(13, 16, 64);
        let b = RustBackend::new(data);
        let r = run(&b, &small_cfg()).unwrap();
        for w in r.history.windows(2) {
            assert!(
                w[1].best_value <= w[0].best_value + 1e-6,
                "incumbent must never regress: {} -> {}",
                w[0].best_value,
                w[1].best_value
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let data = CatBondData::generate(17, 16, 48);
        let b1 = RustBackend::new(data.clone());
        let b2 = RustBackend::new(data);
        let r1 = run(&b1, &small_cfg()).unwrap();
        let r2 = run(&b2, &small_cfg()).unwrap();
        assert_eq!(r1.best, r2.best);
        assert_eq!(r1.best_value, r2.best_value);
    }

    #[test]
    fn pooled_run_is_bit_identical_to_serial() {
        let data = CatBondData::generate(29, 16, 48);
        let b = RustBackend::new(data);
        let serial = run(&b, &small_cfg()).unwrap();
        for pool in [
            crate::analytics::pool::WorkerPool::new(2, 3),
            crate::analytics::pool::WorkerPool::new(4, 8),
        ] {
            let pooled = run_with_pool(&b, &small_cfg(), &pool).unwrap();
            assert_eq!(serial.best, pooled.best);
            assert_eq!(serial.best_value, pooled.best_value);
            assert_eq!(serial.generations_run, pooled.generations_run);
            for (a, z) in serial.history.iter().zip(&pooled.history) {
                assert_eq!(a.best_value, z.best_value);
                assert_eq!(a.mean_value, z.mean_value);
            }
        }
    }

    #[test]
    fn early_stop_on_stagnation() {
        let data = CatBondData::generate(19, 8, 32);
        let b = RustBackend::new(data);
        let cfg = GaConfig {
            pop_size: 10,
            max_generations: 200,
            wait_generations: 3,
            bfgs_every: 0,
            seed: 1,
            ..Default::default()
        };
        let r = run(&b, &cfg).unwrap();
        assert!(
            r.generations_run < 200,
            "should stop early, ran {}",
            r.generations_run
        );
    }

    #[test]
    fn final_best_is_feasible_enough() {
        let data = CatBondData::generate(23, 24, 96);
        let b = RustBackend::new(data.clone());
        let r = run(&b, &small_cfg()).unwrap();
        let pen = crate::analytics::catbond::penalty(&r.best);
        // The penalty terms should have pushed the solution near the
        // feasible region (budget ≈ 1, weights in bounds).
        let sum: f32 = r.best.iter().sum();
        assert!(pen < 50.0, "penalty {pen} too large");
        assert!((0.5..=1.5).contains(&sum), "budget sum {sum}");
    }
}
