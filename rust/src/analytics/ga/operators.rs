//! The nine genetic operators of `rgenoud` (Mebane & Sekhon 2011), the
//! R package the paper's CATopt script is built on. Operator numbering
//! follows the package documentation:
//!
//! 1. cloning, 2. uniform mutation, 3. boundary mutation,
//! 4. non-uniform mutation, 5. polytope crossover, 6. simple crossover,
//! 7. whole non-uniform mutation, 8. heuristic crossover,
//! 9. local-minimum crossover (gradient blend).

use crate::util::prng::Xoshiro256;

/// Coordinate domain (same bounds for every dimension here: market
/// shares live in [lo, hi]).
#[derive(Clone, Copy, Debug)]
pub struct Domain {
    pub lo: f32,
    pub hi: f32,
}

impl Domain {
    pub fn clamp(&self, x: f32) -> f32 {
        x.max(self.lo).min(self.hi)
    }
    pub fn sample(&self, rng: &mut Xoshiro256) -> f32 {
        self.lo + (self.hi - self.lo) * rng.next_f32()
    }
}

/// Degree of non-uniformity decay for operators 4/7 (rgenoud's B).
const NONUNIF_B: f32 = 3.0;

/// 2. Uniform mutation: one random coordinate resampled uniformly.
pub fn uniform_mutation(x: &mut [f32], dom: Domain, rng: &mut Xoshiro256) {
    let j = rng.below_usize(x.len());
    x[j] = dom.sample(rng);
}

/// 3. Boundary mutation: one random coordinate snapped to a bound.
pub fn boundary_mutation(x: &mut [f32], dom: Domain, rng: &mut Xoshiro256) {
    let j = rng.below_usize(x.len());
    x[j] = if rng.next_f64() < 0.5 { dom.lo } else { dom.hi };
}

/// Shared decay shape for non-uniform mutations: perturbation shrinks
/// as `gen/max_gen` approaches 1.
fn nonuniform_step(x: f32, dom: Domain, progress: f32, rng: &mut Xoshiro256) -> f32 {
    let r = rng.next_f32();
    let scale = (1.0 - progress).max(0.0).powf(NONUNIF_B);
    let delta = if rng.next_f64() < 0.5 {
        (dom.hi - x) * r * scale
    } else {
        -(x - dom.lo) * r * scale
    };
    dom.clamp(x + delta)
}

/// 4. Non-uniform mutation: one coordinate, decaying perturbation.
pub fn nonuniform_mutation(
    x: &mut [f32],
    dom: Domain,
    progress: f32,
    rng: &mut Xoshiro256,
) {
    let j = rng.below_usize(x.len());
    x[j] = nonuniform_step(x[j], dom, progress, rng);
}

/// 7. Whole non-uniform mutation: every coordinate.
pub fn whole_nonuniform_mutation(
    x: &mut [f32],
    dom: Domain,
    progress: f32,
    rng: &mut Xoshiro256,
) {
    for j in 0..x.len() {
        x[j] = nonuniform_step(x[j], dom, progress, rng);
    }
}

/// 5. Polytope crossover: convex combination of `parents` (rgenoud uses
/// max(2, ...) parents with random simplex weights).
pub fn polytope_crossover(parents: &[&[f32]], rng: &mut Xoshiro256) -> Vec<f32> {
    assert!(parents.len() >= 2);
    let n = parents[0].len();
    // Random simplex weights.
    let mut lam: Vec<f32> = (0..parents.len()).map(|_| rng.next_f32().max(1e-6)).collect();
    let s: f32 = lam.iter().sum();
    lam.iter_mut().for_each(|l| *l /= s);
    let mut child = vec![0.0f32; n];
    for (p, &l) in parents.iter().zip(&lam) {
        for j in 0..n {
            child[j] += l * p[j];
        }
    }
    child
}

/// 6. Simple (one-point) crossover.
pub fn simple_crossover(a: &[f32], b: &[f32], rng: &mut Xoshiro256) -> (Vec<f32>, Vec<f32>) {
    let n = a.len();
    let cut = 1 + rng.below_usize(n.max(2) - 1);
    let mut c1 = a.to_vec();
    let mut c2 = b.to_vec();
    for j in cut..n {
        c1[j] = b[j];
        c2[j] = a[j];
    }
    (c1, c2)
}

/// 8. Heuristic crossover: step from the worse parent past the better
/// one — `child = better + r * (better - worse)`.
pub fn heuristic_crossover(
    better: &[f32],
    worse: &[f32],
    dom: Domain,
    rng: &mut Xoshiro256,
) -> Vec<f32> {
    let r = rng.next_f32();
    better
        .iter()
        .zip(worse)
        .map(|(&b, &w)| dom.clamp(b + r * (b - w)))
        .collect()
}

/// 9. Local-minimum crossover: blend a candidate with one
/// gradient-refined step from it (rgenoud's BFGS hybrid; the caller
/// supplies the refined point from the grad artifact / BFGS module).
pub fn local_minimum_crossover(x: &[f32], refined: &[f32], rng: &mut Xoshiro256) -> Vec<f32> {
    let t = rng.next_f32();
    x.iter()
        .zip(refined)
        .map(|(&a, &b)| (1.0 - t) * a + t * b)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOM: Domain = Domain { lo: 0.0, hi: 1.0 };

    fn rng() -> Xoshiro256 {
        Xoshiro256::seed_from_u64(11)
    }

    fn genome(n: usize) -> Vec<f32> {
        (0..n).map(|i| (i as f32 / n as f32) * 0.5 + 0.1).collect()
    }

    #[test]
    fn mutations_stay_in_domain_and_change_one_coord() {
        let mut r = rng();
        for op in [uniform_mutation, boundary_mutation] {
            let orig = genome(20);
            let mut x = orig.clone();
            op(&mut x, DOM, &mut r);
            let changed = x.iter().zip(&orig).filter(|(a, b)| a != b).count();
            assert!(changed <= 1);
            assert!(x.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn nonuniform_decays_with_progress() {
        let mut r = rng();
        let orig = genome(50);
        // Near the end of the run perturbations become tiny.
        let mut late = orig.clone();
        whole_nonuniform_mutation(&mut late, DOM, 0.99, &mut r);
        let late_delta: f32 = late.iter().zip(&orig).map(|(a, b)| (a - b).abs()).sum();
        let mut early = orig.clone();
        whole_nonuniform_mutation(&mut early, DOM, 0.0, &mut r);
        let early_delta: f32 = early.iter().zip(&orig).map(|(a, b)| (a - b).abs()).sum();
        assert!(late_delta < early_delta / 10.0, "{late_delta} vs {early_delta}");
    }

    #[test]
    fn polytope_stays_in_convex_hull() {
        let mut r = rng();
        let p1 = vec![0.0f32; 8];
        let p2 = vec![1.0f32; 8];
        let p3 = vec![0.5f32; 8];
        let child = polytope_crossover(&[&p1, &p2, &p3], &mut r);
        assert!(child.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn simple_crossover_swaps_suffix() {
        let mut r = rng();
        let a = vec![0.0f32; 10];
        let b = vec![1.0f32; 10];
        let (c1, c2) = simple_crossover(&a, &b, &mut r);
        // Each child is a prefix of one parent + suffix of the other.
        let cut = c1.iter().position(|&v| v == 1.0).unwrap();
        assert!(c1[..cut].iter().all(|&v| v == 0.0));
        assert!(c1[cut..].iter().all(|&v| v == 1.0));
        assert!(c2[..cut].iter().all(|&v| v == 1.0));
        assert!(c2[cut..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn heuristic_moves_past_better_parent() {
        let mut r = rng();
        let better = vec![0.6f32; 4];
        let worse = vec![0.4f32; 4];
        let c = heuristic_crossover(&better, &worse, DOM, &mut r);
        assert!(c.iter().all(|&v| v >= 0.6 - 1e-6), "child {c:?} should extrapolate");
    }

    #[test]
    fn local_minimum_crossover_interpolates() {
        let mut r = rng();
        let x = vec![0.0f32; 4];
        let refined = vec![1.0f32; 4];
        let c = local_minimum_crossover(&x, &refined, &mut r);
        let t = c[0];
        assert!(c.iter().all(|&v| (v - t).abs() < 1e-6));
        assert!((0.0..=1.0).contains(&t));
    }
}
