//! BFGS quasi-Newton refinement — the derivative half of rgenoud's
//! "evolutionary search + derivative-based (Newton or quasi-Newton)
//! methods" hybrid (paper §4). Dense inverse-Hessian update with an
//! Armijo backtracking line search; gradients come from whichever
//! [`FitnessBackend`](crate::analytics::backend::FitnessBackend) is
//! plugged in (PJRT `catopt_grad` artifact in production).

use crate::analytics::backend::FitnessBackend;
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct BfgsOptions {
    pub max_iters: usize,
    pub grad_tol: f32,
    /// Armijo slope fraction.
    pub c1: f32,
    /// Line-search backtracking factor and cap.
    pub backtrack: f32,
    pub max_line_steps: usize,
}

impl Default for BfgsOptions {
    fn default() -> Self {
        Self {
            max_iters: 20,
            grad_tol: 1e-5,
            c1: 1e-4,
            backtrack: 0.5,
            max_line_steps: 25,
        }
    }
}

#[derive(Clone, Debug)]
pub struct BfgsResult {
    pub x: Vec<f32>,
    pub value: f32,
    pub iters: usize,
    pub grad_evals: usize,
    pub converged: bool,
}

fn dot(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// Minimise `backend`'s objective from `x0`. BFGS is inherently
/// sequential (each step depends on the last gradient), so it runs on
/// the calling thread — the paper's SNOW master — while the population
/// fan-out is what the worker pool parallelises.
pub fn minimize(
    backend: &dyn FitnessBackend,
    x0: &[f32],
    opts: &BfgsOptions,
) -> Result<BfgsResult> {
    let n = x0.len();
    let mut x = x0.to_vec();
    let (mut f, mut g) = backend.value_and_grad(&x)?;
    let mut grad_evals = 1usize;

    // Dense inverse Hessian estimate, H = I initially.
    let mut h = vec![0.0f32; n * n];
    for i in 0..n {
        h[i * n + i] = 1.0;
    }

    let mut iters = 0;
    let mut converged = false;
    for _ in 0..opts.max_iters {
        iters += 1;
        let gnorm = dot(&g, &g).sqrt();
        if gnorm < opts.grad_tol as f64 {
            converged = true;
            break;
        }
        // Direction d = -H g.
        let mut d = vec![0.0f32; n];
        for i in 0..n {
            let row = &h[i * n..(i + 1) * n];
            d[i] = -(dot(row, &g) as f32);
        }
        let mut slope = dot(&d, &g);
        if slope >= 0.0 {
            // H lost positive-definiteness (f32 noise) — reset to steepest descent.
            for i in 0..n {
                d[i] = -g[i];
            }
            slope = -dot(&g, &g);
            for i in 0..n {
                for j in 0..n {
                    h[i * n + j] = if i == j { 1.0 } else { 0.0 };
                }
            }
        }

        // Armijo backtracking.
        let mut alpha = 1.0f32;
        let mut accepted = None;
        for _ in 0..opts.max_line_steps {
            let xt: Vec<f32> = x.iter().zip(&d).map(|(&xi, &di)| xi + alpha * di).collect();
            let (ft, gt) = backend.value_and_grad(&xt)?;
            grad_evals += 1;
            if (ft as f64) <= f as f64 + opts.c1 as f64 * alpha as f64 * slope {
                accepted = Some((xt, ft, gt, alpha));
                break;
            }
            alpha *= opts.backtrack;
        }
        let Some((xt, ft, gt, alpha)) = accepted else {
            break; // no progress possible at f32 resolution
        };

        // BFGS update: s = alpha d, y = gt - g.
        let s: Vec<f32> = d.iter().map(|&di| alpha * di).collect();
        let y: Vec<f32> = gt.iter().zip(&g).map(|(&a, &b)| a - b).collect();
        let sy = dot(&s, &y);
        if sy > 1e-10 {
            let rho = 1.0 / sy;
            // H <- (I - rho s y^T) H (I - rho y s^T) + rho s s^T
            let mut hy = vec![0.0f64; n];
            for i in 0..n {
                let row = &h[i * n..(i + 1) * n];
                hy[i] = dot(row, &y);
            }
            let yhy = y.iter().zip(&hy).map(|(&yi, &hyi)| yi as f64 * hyi).sum::<f64>();
            for i in 0..n {
                for j in 0..n {
                    let hij = h[i * n + j] as f64;
                    let term = -rho * (s[i] as f64 * hy[j] + hy[i] * s[j] as f64)
                        + rho * rho * yhy * s[i] as f64 * s[j] as f64
                        + rho * s[i] as f64 * s[j] as f64;
                    h[i * n + j] = (hij + term) as f32;
                }
            }
        }
        x = xt;
        f = ft;
        g = gt;
    }

    Ok(BfgsResult {
        x,
        value: f,
        iters,
        grad_evals,
        converged,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::Result;

    /// Quadratic bowl backend: f = 0.5 (x-c)^T A (x-c), diagonal A.
    struct Quad {
        c: Vec<f32>,
        a: Vec<f32>,
    }

    impl FitnessBackend for Quad {
        fn eval_population(&self, pop: &[Vec<f32>]) -> Result<Vec<f32>> {
            Ok(pop
                .iter()
                .map(|x| {
                    x.iter()
                        .zip(&self.c)
                        .zip(&self.a)
                        .map(|((&xi, &ci), &ai)| 0.5 * ai * (xi - ci) * (xi - ci))
                        .sum()
                })
                .collect())
        }
        fn value_and_grad(&self, w: &[f32]) -> Result<(f32, Vec<f32>)> {
            let v = self.eval_population(&[w.to_vec()])?[0];
            let g = w
                .iter()
                .zip(&self.c)
                .zip(&self.a)
                .map(|((&xi, &ci), &ai)| ai * (xi - ci))
                .collect();
            Ok((v, g))
        }
        fn dims(&self) -> usize {
            self.c.len()
        }
    }

    #[test]
    fn minimizes_ill_conditioned_quadratic() {
        let n = 12;
        let b = Quad {
            c: (0..n).map(|i| i as f32 * 0.1).collect(),
            a: (0..n).map(|i| 1.0 + 9.0 * (i as f32 / n as f32)).collect(),
        };
        let x0 = vec![5.0f32; n];
        let r = minimize(&b, &x0, &BfgsOptions::default()).unwrap();
        assert!(r.value < 1e-6, "value {}", r.value);
        for (xi, ci) in r.x.iter().zip(&b.c) {
            assert!((xi - ci).abs() < 1e-2, "{xi} vs {ci}");
        }
    }

    #[test]
    fn rosenbrock_2d_progress() {
        struct Rosen;
        impl FitnessBackend for Rosen {
            fn eval_population(&self, pop: &[Vec<f32>]) -> Result<Vec<f32>> {
                Ok(pop
                    .iter()
                    .map(|x| {
                        let (a, b) = (x[0], x[1]);
                        (1.0 - a).powi(2) + 100.0 * (b - a * a).powi(2)
                    })
                    .collect())
            }
            fn value_and_grad(&self, w: &[f32]) -> Result<(f32, Vec<f32>)> {
                let (a, b) = (w[0], w[1]);
                let v = self.eval_population(&[w.to_vec()])?[0];
                Ok((
                    v,
                    vec![
                        -2.0 * (1.0 - a) - 400.0 * a * (b - a * a),
                        200.0 * (b - a * a),
                    ],
                ))
            }
            fn dims(&self) -> usize {
                2
            }
        }
        let r = minimize(
            &Rosen,
            &[-1.2, 1.0],
            &BfgsOptions {
                max_iters: 200,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(r.value < 1e-3, "rosenbrock value {}", r.value);
    }

    #[test]
    fn improves_catbond_objective() {
        use crate::analytics::backend::RustBackend;
        use crate::analytics::catbond::CatBondData;
        let data = CatBondData::generate(9, 32, 96);
        let m = data.m;
        let b = RustBackend::new(data);
        let x0 = vec![1.0 / m as f32; m];
        let f0 = b.eval_population(&[x0.clone()]).unwrap()[0];
        let r = minimize(
            &b,
            &x0,
            &BfgsOptions {
                max_iters: 15,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(r.value <= f0, "BFGS must not worsen: {} vs {f0}", r.value);
        assert!(r.grad_evals >= r.iters);
    }
}
