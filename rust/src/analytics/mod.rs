//! The analytics engine — the workloads the paper's Analysts bring to
//! P2RAC, rebuilt on the three-layer stack: the CATopt cat-bond
//! basis-risk optimisation (rgenoud-style GA + BFGS over the PJRT
//! `catopt_fitness`/`catopt_grad` artifacts) and the Monte-Carlo
//! parameter sweep (`mc_sweep` artifact), plus the virtual-time cost
//! model that maps their work onto Table-I resources.

pub mod backend;
pub mod catbond;
pub mod cost;
pub mod ga;
pub mod mc;
pub mod pool;
pub mod script;

pub use backend::{FitnessBackend, PjrtBackend, RustBackend};
pub use catbond::CatBondData;
pub use cost::{CatoptCost, SweepCost};
pub use pool::WorkerPool;
pub use script::P2racEngine;
