//! Fitness-evaluation backends for the optimiser.
//!
//! * [`RustBackend`] — pure-Rust objective (tests, CPU fallback, and the
//!   oracle the PJRT path is verified against).
//! * [`PjrtBackend`] — the production path: population fitness through
//!   the AOT-compiled `catopt_fitness` artifact and gradients through
//!   `catopt_grad`, both executed by the PJRT CPU client.
//!
//! Both backends are `Send + Sync` and evaluate through `&self`, so the
//! worker pool ([`crate::analytics::pool`]) can fan shards of a
//! population out across scoped threads sharing one backend reference.

use super::catbond::{self, CatBondData};
use crate::runtime::{Runtime, TensorF32};
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// What the GA and BFGS need from an objective.
///
/// `Send + Sync` with `&self` evaluation is the contract that makes the
/// engine parallel: shard threads call [`eval_population`] concurrently
/// on the same backend, so implementations keep their counters atomic
/// and their state otherwise immutable during a run.
///
/// [`eval_population`]: FitnessBackend::eval_population
pub trait FitnessBackend: Send + Sync {
    /// Penalised objective for each candidate (lower is better). Must
    /// be safe to call concurrently from several threads, and the
    /// result for a candidate must not depend on the other candidates
    /// in the slice (the pool relies on this for bit-identical
    /// sharding).
    fn eval_population(&self, pop: &[Vec<f32>]) -> Result<Vec<f32>>;
    /// Value and gradient at one point (for quasi-Newton refinement).
    fn value_and_grad(&self, w: &[f32]) -> Result<(f32, Vec<f32>)>;
    /// Problem dimensionality.
    fn dims(&self) -> usize;
    /// Number of artifact executions so far (perf accounting).
    fn exec_count(&self) -> u64 {
        0
    }
    /// Smallest population slice this backend evaluates efficiently.
    /// The worker pool will not split the population into shards
    /// smaller than this: a tiled backend (PJRT pads every chunk to
    /// its fixed `POP` tile) would otherwise burn a full tile per
    /// tiny shard and lose the speedup to padding.
    fn preferred_batch(&self) -> usize {
        1
    }
}

// ------------------------------------------------------------------ rust

/// Pure-Rust backend over a [`CatBondData`].
pub struct RustBackend {
    pub data: CatBondData,
    evals: AtomicU64,
}

impl RustBackend {
    pub fn new(data: CatBondData) -> Self {
        Self {
            data,
            evals: AtomicU64::new(0),
        }
    }
}

impl FitnessBackend for RustBackend {
    fn eval_population(&self, pop: &[Vec<f32>]) -> Result<Vec<f32>> {
        self.evals.fetch_add(pop.len() as u64, Ordering::Relaxed);
        Ok(pop.iter().map(|w| catbond::objective(w, &self.data)).collect())
    }

    fn value_and_grad(&self, w: &[f32]) -> Result<(f32, Vec<f32>)> {
        self.evals.fetch_add(1, Ordering::Relaxed);
        Ok(analytic_value_and_grad(w, &self.data))
    }

    fn dims(&self) -> usize {
        self.data.m
    }

    fn exec_count(&self) -> u64 {
        self.evals.load(Ordering::Relaxed)
    }
}

/// Analytic gradient of the penalised objective (matches the JAX
/// autodiff of `catopt_objective_ref` up to f32 noise).
pub fn analytic_value_and_grad(w: &[f32], data: &CatBondData) -> (f32, Vec<f32>) {
    let (m, e) = (data.m, data.e);
    let mut grad = vec![0.0f32; m];

    // Basis-risk part: br = sqrt(mean(err^2));
    // d br / d w_j = (1 / (br * E)) * sum_i err_i * 1{0 < idx-att < lim} * IL_ij
    let mut sse = 0.0f64;
    let mut gacc = vec![0.0f64; m];
    for ev in 0..e {
        let row = &data.il[ev * m..(ev + 1) * m];
        let mut idx = 0.0f32;
        for j in 0..m {
            idx += w[j] * row[j];
        }
        let x = idx - data.att;
        let rec = x.max(0.0).min(data.limit);
        let target = catbond::recovery(data.cl[ev], data.att, data.limit);
        let err = rec - target;
        sse += (err as f64) * (err as f64);
        let active = x > 0.0 && x < data.limit;
        if active && err != 0.0 {
            for j in 0..m {
                gacc[j] += err as f64 * row[j] as f64;
            }
        }
    }
    let br = ((sse / e as f64).max(0.0)).sqrt();
    let val_br = br as f32;
    if br > 1e-12 {
        let scale = 1.0 / (br * e as f64);
        for j in 0..m {
            grad[j] += (gacc[j] * scale) as f32;
        }
    }

    // Penalty part.
    let mut sum = 0.0f32;
    let mut sumsq = 0.0f32;
    for &x in w {
        sum += x;
        sumsq += x * x;
    }
    let budget_err = sum - catbond::BUDGET;
    let conc = (sumsq - catbond::HERFINDAHL_CAP).max(0.0);
    for j in 0..m {
        let x = w[j];
        let lo = x.min(0.0);
        let hi = (x - 1.0).max(0.0);
        grad[j] += catbond::LAM_BOUNDS * 2.0 * (lo + hi);
        grad[j] += catbond::LAM_BUDGET * 2.0 * budget_err;
        if conc > 0.0 {
            grad[j] += catbond::LAM_CONC * 2.0 * conc * 2.0 * x;
        }
    }
    (val_br + catbond::penalty(w), grad)
}

// ------------------------------------------------------------------ pjrt

/// Production backend: fitness/gradients via the PJRT artifacts.
///
/// The loop-invariant arguments (transposed loss table, sponsor losses,
/// trigger scalars) are prepared as PJRT literals **once** — rebuilding
/// the 4 MiB table literal every generation cost ~20% of the hot path
/// (EXPERIMENTS.md §Perf L3). The per-tile population buffer is built
/// on the calling thread's stack so shard threads never contend.
pub struct PjrtBackend {
    rt: Arc<Runtime>,
    data: CatBondData,
    lit_ilt: crate::runtime::pjrt::PreparedArg,
    lit_cl: crate::runtime::pjrt::PreparedArg,
    lit_att: crate::runtime::pjrt::PreparedArg,
    lit_lim: crate::runtime::pjrt::PreparedArg,
    pop_tile: usize,
}

impl PjrtBackend {
    /// `data.m`/`data.e` must match the artifact constants `M`/`E`.
    pub fn new(rt: Arc<Runtime>, data: CatBondData) -> Result<Self> {
        let m = rt.constant("M")?;
        let e = rt.constant("E")?;
        anyhow::ensure!(
            data.m == m && data.e == e,
            "dataset ({}, {}) does not match artifact shapes ({m}, {e})",
            data.m,
            data.e
        );
        let mut ilt = vec![0.0f32; m * e];
        for ev in 0..e {
            for j in 0..m {
                ilt[j * e + ev] = data.il[ev * m + j];
            }
        }
        let pop_tile = rt.constant("POP")?;
        let lit_ilt = rt.prepare(&TensorF32::new(vec![m, e], ilt))?;
        let lit_cl = rt.prepare(&TensorF32::new(vec![e], data.cl.clone()))?;
        let lit_att = rt.prepare(&TensorF32::scalar11(data.att))?;
        let lit_lim = rt.prepare(&TensorF32::scalar11(data.limit))?;
        Ok(Self {
            rt,
            data,
            lit_ilt,
            lit_cl,
            lit_att,
            lit_lim,
            pop_tile,
        })
    }

    pub fn data(&self) -> &CatBondData {
        &self.data
    }
}

impl FitnessBackend for PjrtBackend {
    fn eval_population(&self, pop: &[Vec<f32>]) -> Result<Vec<f32>> {
        let m = self.data.m;
        let mut out = Vec::with_capacity(pop.len());
        let mut w_buf: Vec<f32> = Vec::with_capacity(self.pop_tile * m);
        for chunk in pop.chunks(self.pop_tile) {
            // Pad the tile with copies of the first candidate. The
            // artifact computes rows independently, so padding (and the
            // shard a candidate lands in) cannot change its fitness.
            w_buf.clear();
            for cand in chunk {
                anyhow::ensure!(cand.len() == m, "candidate dim {} != {m}", cand.len());
                w_buf.extend_from_slice(cand);
            }
            for _ in chunk.len()..self.pop_tile {
                w_buf.extend_from_slice(&chunk[0]);
            }
            let lit_w = self
                .rt
                .prepare(&TensorF32::new(vec![self.pop_tile, m], w_buf.clone()))?;
            let res = self.rt.execute_prepared(
                "catopt_fitness",
                &[&lit_w, &self.lit_ilt, &self.lit_cl, &self.lit_att, &self.lit_lim],
            )?;
            out.extend_from_slice(&res[0].data[..chunk.len()]);
        }
        Ok(out)
    }

    fn value_and_grad(&self, w: &[f32]) -> Result<(f32, Vec<f32>)> {
        let m = self.data.m;
        let lit_w = self.rt.prepare(&TensorF32::new(vec![m], w.to_vec()))?;
        let res = self.rt.execute_prepared(
            "catopt_grad",
            &[&lit_w, &self.lit_ilt, &self.lit_cl, &self.lit_att, &self.lit_lim],
        )?;
        Ok((res[0].data[0], res[1].data.clone()))
    }

    fn dims(&self) -> usize {
        self.data.m
    }

    fn exec_count(&self) -> u64 {
        self.rt.exec_count.load(Ordering::Relaxed)
    }

    /// One artifact tile: shards smaller than this execute the same
    /// padded `POP x M` computation for fewer useful rows.
    fn preferred_batch(&self) -> usize {
        self.pop_tile
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_grad_matches_finite_difference() {
        let data = CatBondData::generate(3, 48, 128);
        let m = data.m;
        let mut rng = crate::util::prng::Xoshiro256::seed_from_u64(1);
        let w: Vec<f32> = (0..m).map(|_| rng.next_f32() * 2.0 / m as f32).collect();
        let (v0, g) = analytic_value_and_grad(&w, &data);
        assert!(v0.is_finite());
        for probe in [0usize, 7, 23, m - 1] {
            let eps = 1e-3f32;
            let mut wp = w.clone();
            wp[probe] += eps;
            let vp = catbond::objective(&wp, &data);
            let fd = (vp - v0) / eps;
            let tol = 0.05 * g[probe].abs().max(1.0);
            assert!(
                (fd - g[probe]).abs() <= tol,
                "coord {probe}: fd {fd} vs analytic {}",
                g[probe]
            );
        }
    }

    #[test]
    fn rust_backend_counts_evals() {
        let data = CatBondData::generate(5, 16, 32);
        let b = RustBackend::new(data);
        let pop: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32 * 0.01; 16]).collect();
        let f = b.eval_population(&pop).unwrap();
        assert_eq!(f.len(), 4);
        assert_eq!(b.exec_count(), 4);
        assert_eq!(b.dims(), 16);
    }

    #[test]
    fn backends_are_shareable_across_threads() {
        // The worker pool relies on `&RustBackend` crossing scoped
        // threads and on concurrent eval calls agreeing with serial.
        let data = CatBondData::generate(5, 16, 32);
        let b = RustBackend::new(data);
        let pop: Vec<Vec<f32>> = (0..8).map(|i| vec![i as f32 * 0.01; 16]).collect();
        let serial = b.eval_population(&pop).unwrap();
        let (lo, hi) = pop.split_at(4);
        let (a, z) = std::thread::scope(|s| {
            let h1 = s.spawn(|| b.eval_population(lo).unwrap());
            let h2 = s.spawn(|| b.eval_population(hi).unwrap());
            (h1.join().unwrap(), h2.join().unwrap())
        });
        let stitched: Vec<f32> = a.into_iter().chain(z).collect();
        assert_eq!(serial, stitched);
        assert_eq!(b.exec_count(), 16);
    }
}
