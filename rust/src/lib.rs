//! # P2RAC — Platform for Parallel R-based Analytics on the Cloud
//!
//! A Rust + JAX + Pallas reproduction of Patel, Rau-Chaplin & Varghese,
//! *"Accelerating R-based Analytics on the Cloud"* (Concurrency and
//! Computation: Practice and Experience, 2013).
//!
//! The platform sits between an Analyst and a (simulated) IaaS cloud and
//! provides resource / data / execution management for analytical
//! workloads, exactly mirroring the paper's command set
//! (`ec2createinstance`, `ec2createcluster`, `ec2senddata*`,
//! `ec2runon*`, `ec2getresults*`, diagnostics and locks).
//!
//! Architecture (see DESIGN.md):
//! * **L3 (this crate)** — coordinator: resource/data/execution managers,
//!   bynode/byslot scheduler, rsync-algorithm data sync, the simulated
//!   EC2/EBS/S3 substrate (with a deterministic spot-instance market),
//!   and the analytics engine (rgenoud-style GA + Monte-Carlo sweep)
//!   that plays the role of the Analyst's R scripts. On top of the
//!   coordinator, the `jobs` subsystem turns the one-shot session into
//!   a multi-tenant platform: a priority job queue, an elastic
//!   autoscaled fleet (bid against a deterministic spot-price
//!   forecast), deadline/SLO-aware spot-vs-on-demand placement per
//!   checkpointed slice, and execution that survives spot
//!   interruptions bit-identically. `docs/MANUAL.md` is the operator
//!   reference for the whole command set.
//! * **L2** — JAX compute graphs (`python/compile/model.py`), AOT-lowered
//!   to HLO text at build time.
//! * **L1** — Pallas kernels (`python/compile/kernels/`), fused into the
//!   same HLO; executed from Rust via the PJRT CPU client.

pub mod analytics;
pub mod bench_support;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod datasync;
pub mod jobs;
pub mod runtime;
pub mod simcloud;
pub mod telemetry;
pub mod util;

/// Version string reported by every command's `-v` switch.
pub const VERSION: &str = concat!("P2RAC ", env!("CARGO_PKG_VERSION"));
