//! `p2rac` — the Analyst-facing command-line binary.
//!
//! Usage: `p2rac <ec2command> [args...]`. Every tool from the paper's §3
//! is available as a subcommand; `p2rac help` lists them.

fn main() {
    let code = p2rac::cli::main_entry(std::env::args().skip(1).collect());
    std::process::exit(code);
}
