//! Shared harness for the paper-figure benches (`benches/*.rs`).
//!
//! No `criterion` exists in the offline vendored set, so the benches are
//! `harness = false` binaries built on this module: it enumerates the
//! Table-I resource set, runs the two workloads on each through the full
//! coordinator, and returns the virtual-time measurements the figures
//! plot.

use crate::analytics::{CatBondData, P2racEngine};
use crate::coordinator::{
    table1_desktops, CreateClusterOpts, CreateInstanceOpts, DesktopSpec, Placement, ResultScope,
    Session,
};
use crate::simcloud::{SimParams, SpanCategory};
use anyhow::Result;

/// One Table-I resource.
#[derive(Clone, Debug)]
pub enum Resource {
    Desktop(DesktopSpec),
    Instance { label: String, itype: String },
    Cluster { label: String, itype: String, nodes: usize },
}

impl Resource {
    pub fn label(&self) -> String {
        match self {
            Resource::Desktop(d) => d.name.clone(),
            Resource::Instance { label, .. } | Resource::Cluster { label, .. } => label.clone(),
        }
    }
}

/// The paper's full resource set (Table I rows).
pub fn table1_resources() -> Vec<Resource> {
    let mut out: Vec<Resource> = table1_desktops().into_iter().map(Resource::Desktop).collect();
    out.push(Resource::Instance {
        label: "Instance A".into(),
        itype: "m2.2xlarge".into(),
    });
    out.push(Resource::Instance {
        label: "Instance B".into(),
        itype: "m2.4xlarge".into(),
    });
    for (label, nodes) in [("Cluster A", 2), ("Cluster B", 4), ("Cluster C", 8), ("Cluster D", 16)] {
        out.push(Resource::Cluster {
            label: label.into(),
            itype: "m2.2xlarge".into(),
            nodes,
        });
    }
    out
}

/// Which workload to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    Catopt,
    Sweep,
}

impl Workload {
    pub fn label(self) -> &'static str {
        match self {
            Workload::Catopt => "CATopt",
            Workload::Sweep => "Parameter sweep",
        }
    }
}

/// A fresh session with the pure-Rust engine (fast, deterministic) and
/// the given paper-data scale factor for wire-time modelling.
pub fn bench_session(data_scale: f64) -> Session {
    let mut params = SimParams::default();
    params.data_scale = data_scale;
    Session::new(params, Box::new(P2racEngine::rust_only()))
}

/// What a bench run is measuring, which changes what the project must
/// be faithful to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BenchProfile {
    /// Figs 4–5: the virtual *compute* time matters (paper GA params:
    /// pop 200 × 50 generations / 512 jobs); the dataset is tiny so the
    /// real numerics finish quickly.
    Compute,
    /// Figs 6–7: the *data volume* on the wire matters (artifact-scale
    /// ~4.5 MiB table, scaled ×64 to the paper's ~300 MB by
    /// `SimParams::data_scale`); the GA itself is shortened.
    Management,
}

/// Write a bench project for the given workload and profile.
pub fn write_project(s: &mut Session, dir: &str, wl: Workload, profile: BenchProfile) {
    match wl {
        Workload::Catopt => {
            let (m, e) = match profile {
                BenchProfile::Compute => (48, 160),
                BenchProfile::Management => (512, 2048),
            };
            let data = CatBondData::generate(7, m, e);
            for (name, bytes) in data.to_files() {
                s.analyst.write(&format!("{dir}/{name}"), bytes);
            }
            let script = match profile {
                BenchProfile::Compute => {
                    r#"{"type":"catopt","pop_size":200,"max_generations":50,"wait_generations":50,"seed":42,"bfgs_every":10,"backend":"rust"}"#
                }
                BenchProfile::Management => {
                    r#"{"type":"catopt","pop_size":16,"max_generations":2,"seed":42,"bfgs_every":0,"backend":"rust"}"#
                }
            };
            s.analyst
                .write(&format!("{dir}/catopt.json"), script.as_bytes().to_vec());
        }
        Workload::Sweep => {
            s.analyst.write(
                &format!("{dir}/sweep.json"),
                br#"{"type":"mc_sweep","n_jobs":512,"seed":2012,"backend":"rust"}"#.to_vec(),
            );
            // The paper's sweep project input is ~3 MB.
            let mut rng = crate::util::prng::Xoshiro256::seed_from_u64(99);
            let blob: Vec<u8> = (0..3 * 1024 * 1024).map(|_| rng.next_u32() as u8).collect();
            s.analyst.write(&format!("{dir}/data/params.bin"), blob);
        }
    }
}

fn script_name(wl: Workload) -> &'static str {
    match wl {
        Workload::Catopt => "catopt.json",
        Workload::Sweep => "sweep.json",
    }
}

/// Management-time breakdown for one resource (the six bars of
/// Figs 6–7) plus the compute time (Fig 5).
#[derive(Clone, Debug, Default)]
pub struct Breakdown {
    pub create_s: f64,
    pub submit_master_s: f64,
    pub submit_all_s: f64,
    pub compute_s: f64,
    pub fetch_master_s: f64,
    pub fetch_all_s: f64,
    pub terminate_s: f64,
}

/// Run a workload on a resource end-to-end and collect the breakdown
/// (compute-profile project).
pub fn run_on_resource(s: &mut Session, r: &Resource, wl: Workload) -> Result<Breakdown> {
    run_on_resource_profile(s, r, wl, BenchProfile::Compute)
}

/// Run with an explicit bench profile.
pub fn run_on_resource_profile(
    s: &mut Session,
    r: &Resource,
    wl: Workload,
    profile: BenchProfile,
) -> Result<Breakdown> {
    let dir = "bench_proj";
    if !s.analyst.dir_exists(dir) {
        write_project(s, dir, wl, profile);
    }
    s.cloud.clock.clear_timeline();
    let script = script_name(wl);
    match r {
        Resource::Desktop(d) => {
            let out = s.run_local(d, dir, script, "bench")?;
            Ok(Breakdown {
                compute_s: out.compute_s,
                ..Breakdown::default()
            })
        }
        Resource::Instance { label, itype } => {
            s.create_instance(&CreateInstanceOpts {
                iname: Some(label.clone()),
                itype: Some(itype.clone()),
                ..Default::default()
            })?;
            s.send_data_to_instance(Some(label), dir)?;
            let out = s.run_on_instance(Some(label), dir, script, "bench")?;
            s.get_results_from_instance(Some(label), dir, "bench")?;
            s.terminate_instance(Some(label), true)?;
            Ok(read_breakdown(s, out.compute_s))
        }
        Resource::Cluster { label, itype, nodes } => {
            s.create_cluster(&CreateClusterOpts {
                cname: Some(label.clone()),
                csize: Some(*nodes),
                itype: Some(itype.clone()),
                ..Default::default()
            })?;
            s.send_data_to_master(Some(label), dir)?;
            s.send_data_to_cluster_nodes(Some(label), dir)?;
            let out = s.run_on_cluster(Some(label), dir, script, "bench", Placement::ByNode)?;
            s.get_results(Some(label), dir, "bench", ResultScope::FromMaster)?;
            // fetch-from-all series (scenario 3).
            s.get_results(Some(label), dir, "bench", ResultScope::FromAll)
                .ok();
            s.terminate_cluster(Some(label), true)?;
            Ok(read_breakdown(s, out.compute_s))
        }
    }
}

fn read_breakdown(s: &Session, compute_s: f64) -> Breakdown {
    let c = &s.cloud.clock;
    Breakdown {
        create_s: c.category_total_s(SpanCategory::CreateResource),
        submit_master_s: c.category_total_s(SpanCategory::SubmitToMaster),
        submit_all_s: c.category_total_s(SpanCategory::SubmitToAllNodes),
        compute_s,
        fetch_master_s: c.category_total_s(SpanCategory::FetchFromMaster),
        fetch_all_s: c.category_total_s(SpanCategory::FetchFromAllNodes),
        terminate_s: c.category_total_s(SpanCategory::TerminateResource),
    }
}

/// Pretty row printer shared by the bench binaries.
pub fn print_row(cols: &[String], widths: &[usize]) {
    let line: Vec<String> = cols
        .iter()
        .zip(widths)
        .map(|(c, w)| format!("{c:>w$}", w = w))
        .collect();
    println!("{}", line.join("  "));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resource_set_matches_table1() {
        let rs = table1_resources();
        assert_eq!(rs.len(), 8);
        assert_eq!(rs[0].label(), "Desktop A");
        assert_eq!(rs[7].label(), "Cluster D");
    }

    #[test]
    fn sweep_runs_on_every_resource() {
        for r in table1_resources() {
            let mut s = bench_session(1.0);
            let b = run_on_resource(&mut s, &r, Workload::Sweep).unwrap();
            assert!(b.compute_s > 0.0, "{}: no compute time", r.label());
            if matches!(r, Resource::Cluster { .. }) {
                assert!(b.create_s > 0.0 && b.terminate_s > 0.0);
                assert!(b.submit_all_s > 0.0);
            }
        }
    }

    #[test]
    fn cluster_d_is_fastest_compute() {
        // Paper Fig 5: the best performance is achieved on Cluster D.
        let rs = table1_resources();
        let mut times = Vec::new();
        for r in &rs {
            let mut s = bench_session(1.0);
            let b = run_on_resource(&mut s, r, Workload::Sweep).unwrap();
            times.push((r.label(), b.compute_s));
        }
        let best = times
            .iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        assert_eq!(best.0, "Cluster D", "fastest was {best:?}");
    }
}
