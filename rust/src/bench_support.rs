//! Shared harness for the paper-figure benches (`benches/*.rs`).
//!
//! No `criterion` exists in the offline vendored set, so the benches are
//! `harness = false` binaries built on this module: it enumerates the
//! Table-I resource set, runs the two workloads on each through the full
//! coordinator, and returns the virtual-time measurements the figures
//! plot.

use crate::analytics::cost::{catopt_generation_s, CatoptCost};
use crate::analytics::ga::optimizer::{self, GaConfig, GaResult};
use crate::analytics::pool::WorkerPool;
use crate::analytics::{CatBondData, P2racEngine, RustBackend};
use crate::coordinator::{
    table1_desktops, CreateClusterOpts, CreateInstanceOpts, DesktopSpec, NodeSpec, Placement,
    ResourceView, ResultScope, Session,
};
use crate::jobs::{
    AutoscalerConfig, BidStrategy, JobScheduler, JobSpec, JobSpecBuilder, JobState, Priority,
    QueueOrdering, ScalePolicy,
};
use crate::simcloud::{NetworkModel, SimParams, SpanCategory};
use crate::util::json::Json;
use anyhow::Result;

/// One Table-I resource.
#[derive(Clone, Debug)]
pub enum Resource {
    Desktop(DesktopSpec),
    Instance { label: String, itype: String },
    Cluster { label: String, itype: String, nodes: usize },
}

impl Resource {
    pub fn label(&self) -> String {
        match self {
            Resource::Desktop(d) => d.name.clone(),
            Resource::Instance { label, .. } | Resource::Cluster { label, .. } => label.clone(),
        }
    }
}

/// The paper's full resource set (Table I rows).
pub fn table1_resources() -> Vec<Resource> {
    let mut out: Vec<Resource> = table1_desktops().into_iter().map(Resource::Desktop).collect();
    out.push(Resource::Instance {
        label: "Instance A".into(),
        itype: "m2.2xlarge".into(),
    });
    out.push(Resource::Instance {
        label: "Instance B".into(),
        itype: "m2.4xlarge".into(),
    });
    for (label, nodes) in [("Cluster A", 2), ("Cluster B", 4), ("Cluster C", 8), ("Cluster D", 16)] {
        out.push(Resource::Cluster {
            label: label.into(),
            itype: "m2.2xlarge".into(),
            nodes,
        });
    }
    out
}

/// Which workload to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    Catopt,
    Sweep,
}

impl Workload {
    pub fn label(self) -> &'static str {
        match self {
            Workload::Catopt => "CATopt",
            Workload::Sweep => "Parameter sweep",
        }
    }
}

/// A fresh session with the pure-Rust engine (fast, deterministic) and
/// the given paper-data scale factor for wire-time modelling.
pub fn bench_session(data_scale: f64) -> Session {
    let params = SimParams {
        data_scale,
        ..SimParams::default()
    };
    Session::new(params, Box::new(P2racEngine::rust_only()))
}

/// What a bench run is measuring, which changes what the project must
/// be faithful to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BenchProfile {
    /// Figs 4–5: the virtual *compute* time matters (paper GA params:
    /// pop 200 × 50 generations / 512 jobs); the dataset is tiny so the
    /// real numerics finish quickly.
    Compute,
    /// Figs 6–7: the *data volume* on the wire matters (artifact-scale
    /// ~4.5 MiB table, scaled ×64 to the paper's ~300 MB by
    /// `SimParams::data_scale`); the GA itself is shortened.
    Management,
}

/// Write a bench project for the given workload and profile.
pub fn write_project(s: &mut Session, dir: &str, wl: Workload, profile: BenchProfile) {
    match wl {
        Workload::Catopt => {
            let (m, e) = match profile {
                BenchProfile::Compute => (48, 160),
                BenchProfile::Management => (512, 2048),
            };
            let data = CatBondData::generate(7, m, e);
            for (name, bytes) in data.to_files() {
                s.analyst.write(&format!("{dir}/{name}"), bytes);
            }
            let script = match profile {
                BenchProfile::Compute => {
                    r#"{"type":"catopt","pop_size":200,"max_generations":50,"wait_generations":50,"seed":42,"bfgs_every":10,"backend":"rust"}"#
                }
                BenchProfile::Management => {
                    r#"{"type":"catopt","pop_size":16,"max_generations":2,"seed":42,"bfgs_every":0,"backend":"rust"}"#
                }
            };
            s.analyst
                .write(&format!("{dir}/catopt.json"), script.as_bytes().to_vec());
        }
        Workload::Sweep => {
            s.analyst.write(
                &format!("{dir}/sweep.json"),
                br#"{"type":"mc_sweep","n_jobs":512,"seed":2012,"backend":"rust"}"#.to_vec(),
            );
            // The paper's sweep project input is ~3 MB.
            let mut rng = crate::util::prng::Xoshiro256::seed_from_u64(99);
            let blob: Vec<u8> = (0..3 * 1024 * 1024).map(|_| rng.next_u32() as u8).collect();
            s.analyst.write(&format!("{dir}/data/params.bin"), blob);
        }
    }
}

fn script_name(wl: Workload) -> &'static str {
    match wl {
        Workload::Catopt => "catopt.json",
        Workload::Sweep => "sweep.json",
    }
}

/// Management-time breakdown for one resource (the six bars of
/// Figs 6–7) plus the compute time (Fig 5).
#[derive(Clone, Debug, Default)]
pub struct Breakdown {
    pub create_s: f64,
    pub submit_master_s: f64,
    pub submit_all_s: f64,
    pub compute_s: f64,
    pub fetch_master_s: f64,
    pub fetch_all_s: f64,
    pub terminate_s: f64,
}

/// Run a workload on a resource end-to-end and collect the breakdown
/// (compute-profile project).
pub fn run_on_resource(s: &mut Session, r: &Resource, wl: Workload) -> Result<Breakdown> {
    run_on_resource_profile(s, r, wl, BenchProfile::Compute)
}

/// Run with an explicit bench profile.
pub fn run_on_resource_profile(
    s: &mut Session,
    r: &Resource,
    wl: Workload,
    profile: BenchProfile,
) -> Result<Breakdown> {
    let dir = "bench_proj";
    if !s.analyst.dir_exists(dir) {
        write_project(s, dir, wl, profile);
    }
    s.cloud.clock.clear_timeline();
    let script = script_name(wl);
    match r {
        Resource::Desktop(d) => {
            let out = s.run_local(d, dir, script, "bench")?;
            Ok(Breakdown {
                compute_s: out.compute_s,
                ..Breakdown::default()
            })
        }
        Resource::Instance { label, itype } => {
            s.create_instance(&CreateInstanceOpts {
                iname: Some(label.clone()),
                itype: Some(itype.clone()),
                ..Default::default()
            })?;
            s.send_data_to_instance(Some(label), dir)?;
            let out = s.run_on_instance(Some(label), dir, script, "bench")?;
            s.get_results_from_instance(Some(label), dir, "bench")?;
            s.terminate_instance(Some(label), true)?;
            Ok(read_breakdown(s, out.compute_s))
        }
        Resource::Cluster { label, itype, nodes } => {
            s.create_cluster(&CreateClusterOpts {
                cname: Some(label.clone()),
                csize: Some(*nodes),
                itype: Some(itype.clone()),
                ..Default::default()
            })?;
            s.send_data_to_master(Some(label), dir)?;
            s.send_data_to_cluster_nodes(Some(label), dir)?;
            let out = s.run_on_cluster(Some(label), dir, script, "bench", Placement::ByNode)?;
            s.get_results(Some(label), dir, "bench", ResultScope::FromMaster)?;
            // fetch-from-all series (scenario 3).
            s.get_results(Some(label), dir, "bench", ResultScope::FromAll)
                .ok();
            s.terminate_cluster(Some(label), true)?;
            Ok(read_breakdown(s, out.compute_s))
        }
    }
}

fn read_breakdown(s: &Session, compute_s: f64) -> Breakdown {
    let c = &s.cloud.clock;
    Breakdown {
        create_s: c.category_total_s(SpanCategory::CreateResource),
        submit_master_s: c.category_total_s(SpanCategory::SubmitToMaster),
        submit_all_s: c.category_total_s(SpanCategory::SubmitToAllNodes),
        compute_s,
        fetch_master_s: c.category_total_s(SpanCategory::FetchFromMaster),
        fetch_all_s: c.category_total_s(SpanCategory::FetchFromAllNodes),
        terminate_s: c.category_total_s(SpanCategory::TerminateResource),
    }
}

// ===================================================== real vs virtual

/// Wall-clock measurement of the worker pool against the serial path
/// on the same workload — the "real" column next to the simulator's
/// virtual-time speedups (Fig 4).
#[derive(Clone, Debug)]
pub struct SpeedupReport {
    /// Real threads used by the threaded run.
    pub threads: usize,
    /// Wall-clock of the serial reference run.
    pub wall_serial_s: f64,
    /// Wall-clock of the pool run.
    pub wall_threaded_s: f64,
    /// Virtual-time speedup the simulator bills for the same fan-out
    /// (the cost model's round-robin over `threads` slave processes on
    /// one node, including the serial master-side dispatch — so it is
    /// sub-linear, like the paper's Fig 4).
    pub virtual_speedup: f64,
    /// Whether the threaded run reproduced the serial result bit for
    /// bit (it must — sharding is numerics-neutral).
    pub bit_identical: bool,
}

impl SpeedupReport {
    pub fn real_speedup(&self) -> f64 {
        self.wall_serial_s / self.wall_threaded_s.max(1e-12)
    }

    pub fn row(&self) -> String {
        format!(
            "threads={:<2} wall {:>7.3}s -> {:>7.3}s  real {:>5.2}x  virtual {:>5.2}x  bit-identical={}",
            self.threads,
            self.wall_serial_s,
            self.wall_threaded_s,
            self.real_speedup(),
            self.virtual_speedup,
            self.bit_identical
        )
    }
}

/// The catopt workload used for real-speedup measurement: heavy enough
/// per candidate (objective is `O(m*e)`) that sharding dominates the
/// pool's thread-spawn overhead.
fn speedup_workload() -> (CatBondData, GaConfig) {
    let data = CatBondData::generate(7, 96, 4096);
    let cfg = GaConfig {
        pop_size: 128,
        max_generations: 4,
        wait_generations: 4,
        bfgs_every: 0,
        seed: 42,
        ..Default::default()
    };
    (data, cfg)
}

/// The simulator's billed speedup for fanning one GA generation of
/// `evals` candidates over `nproc` slave processes on a single node
/// (no collective over the wire, but the serial master dispatch of
/// `CatoptCost::per_message_s` still applies — the same model behind
/// Fig 4's knee).
pub fn virtual_speedup(evals: usize, nproc: usize) -> f64 {
    let mk = |nproc: usize| ResourceView {
        nodes: vec![NodeSpec {
            name: "speedup-host".into(),
            cores: nproc,
            mem_gb: 34.2,
            core_speed: 1.0,
        }],
        assignment: vec![0; nproc],
        net: NetworkModel::new(SimParams::default()),
        resource_name: "speedup-host".into(),
        real_threads: None,
    };
    let cost = CatoptCost::default();
    let t1 = catopt_generation_s(evals, &cost, &mk(1));
    let tn = catopt_generation_s(evals, &cost, &mk(nproc.max(1)));
    t1 / tn.max(1e-12)
}

/// The serial reference run, measured once and reused for every
/// thread count (`bench_ga_parallel` sweeps 1/2/4/8 threads — re-
/// running the multi-second serial GA per sweep point would double
/// the bench and flatter the threaded runs with freshly warmed
/// caches).
pub struct SpeedupBaseline {
    data: CatBondData,
    cfg: GaConfig,
    pub wall_serial_s: f64,
    serial: GaResult,
}

/// Run the serial catopt reference once.
pub fn speedup_baseline() -> Result<SpeedupBaseline> {
    let (data, cfg) = speedup_workload();
    let backend = RustBackend::new(data.clone());
    let t0 = std::time::Instant::now();
    let serial = optimizer::run(&backend, &cfg)?;
    Ok(SpeedupBaseline {
        data,
        cfg,
        wall_serial_s: t0.elapsed().as_secs_f64(),
        serial,
    })
}

impl SpeedupBaseline {
    /// Measure a `threads`-wide pool run against this baseline.
    pub fn measure(&self, threads: usize) -> Result<SpeedupReport> {
        let backend = RustBackend::new(self.data.clone());
        let pool = WorkerPool::new(threads, threads.max(1));
        let t1 = std::time::Instant::now();
        let threaded = optimizer::run_with_pool(&backend, &self.cfg, &pool)?;
        let wall_threaded_s = t1.elapsed().as_secs_f64();
        Ok(SpeedupReport {
            threads: pool.threads(),
            wall_serial_s: self.wall_serial_s,
            wall_threaded_s,
            virtual_speedup: virtual_speedup(self.cfg.pop_size, pool.threads()),
            bit_identical: self.serial.best == threaded.best
                && self.serial.best_value == threaded.best_value,
        })
    }
}

/// One-shot convenience: serial baseline + one threaded measurement.
pub fn measure_real_speedup(threads: usize) -> Result<SpeedupReport> {
    speedup_baseline()?.measure(threads)
}

// ================================================== queue/cost scenario

/// Outcome of one queue-throughput/cost scenario run.
#[derive(Clone, Debug)]
pub struct QueueScenarioReport {
    pub label: String,
    pub jobs: usize,
    pub completed: usize,
    /// Virtual time from first submission to queue drained + fleet
    /// released.
    pub makespan_s: f64,
    pub total_cost_cents: u64,
    pub interruptions: usize,
    pub scale_events: usize,
}

impl QueueScenarioReport {
    pub fn row(&self) -> String {
        format!(
            "{:<22} jobs {:>2}/{:<2}  makespan {:>9.0}s  cost {:>7}c  interruptions {}  scale events {}",
            self.label,
            self.completed,
            self.jobs,
            self.makespan_s,
            self.total_cost_cents,
            self.interruptions,
            self.scale_events
        )
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("label", Json::str(&self.label)),
            ("jobs", Json::num(self.jobs as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("makespan_s", Json::num(self.makespan_s)),
            ("total_cost_cents", Json::num(self.total_cost_cents as f64)),
            ("interruptions", Json::num(self.interruptions as f64)),
            ("scale_events", Json::num(self.scale_events as f64)),
        ])
    }
}

/// Run a mixed GA/MC workload through the job queue on a fleet:
/// static on-demand (`autoscale = false`: a fixed two-cluster fleet)
/// vs autoscaled spot (`spot = true, autoscale = true`), optionally
/// with `armed_interruptions` spot reclaims injected via `FaultPlan`.
pub fn run_queue_scenario(
    label: &str,
    spot: bool,
    autoscale: bool,
    n_jobs: usize,
    armed_interruptions: usize,
) -> Result<QueueScenarioReport> {
    let mut s = bench_session(1.0);
    // Pin a spike-free price path: interruptions are injected
    // explicitly through `FaultPlan`, so the cost comparison across
    // PRs measures scheduling and billing, not price-path luck.
    s.cloud.spot.spike_prob = 0.0;
    // Two small projects: a CATopt optimisation and an MC sweep.
    let data = CatBondData::generate(7, 24, 96);
    for (name, bytes) in data.to_files() {
        s.analyst.write(&format!("qcat/{name}"), bytes);
    }
    s.analyst.write(
        "qcat/catopt.json",
        br#"{"type":"catopt","pop_size":12,"max_generations":4,"seed":42,"bfgs_every":0}"#
            .to_vec(),
    );
    s.analyst.write(
        "qsweep/sweep.json",
        br#"{"type":"mc_sweep","n_jobs":64,"seed":2012}"#.to_vec(),
    );

    let cfg = AutoscalerConfig {
        min_clusters: if autoscale { 1 } else { 2 },
        max_clusters: if autoscale { 3 } else { 2 },
        nodes_per_cluster: 2,
        spot,
        policy: ScalePolicy::QueueDepth,
        ..Default::default()
    };
    let mut js = JobScheduler::new(cfg);
    s.cloud.faults.spot_interruptions = armed_interruptions;
    let t0 = s.cloud.clock.now_s();
    let prios = [Priority::Low, Priority::Normal, Priority::High];
    for i in 0..n_jobs {
        let (dir, script) = if i % 2 == 0 {
            ("qsweep", "sweep.json")
        } else {
            ("qcat", "catopt.json")
        };
        js.submit(
            &s,
            JobSpecBuilder::new(&format!("run{i}"), dir, script)
                .priority(prios[i % prios.len()])
                .build(),
        );
    }
    js.run_until_idle(&mut s)?;
    js.shutdown_fleet(&mut s)?;
    Ok(QueueScenarioReport {
        label: label.to_string(),
        jobs: n_jobs,
        completed: js
            .queue
            .jobs()
            .filter(|j| j.state == JobState::Completed)
            .count(),
        makespan_s: s.cloud.clock.now_s() - t0,
        total_cost_cents: s.cloud.ledger.total_cents(),
        interruptions: js.interruptions_delivered,
        scale_events: js.autoscaler.events.len(),
    })
}

// ============================================ deadline/SLO scenario

/// Fleet purchase policy of one deadline scenario run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeadlinePolicy {
    /// Everything on-demand: the zero-miss, full-price reference that
    /// also defines which deadlines are *feasible*.
    AllOnDemand,
    /// Everything on spot, deadlines ignored by the scheduler (they
    /// are only graded afterwards): the cheapest corner of the curve.
    AllSpot,
    /// The deadline-aware scheduler: per-slice spot vs on-demand from
    /// the forecast's cost/risk curve.
    DeadlineAware,
}

impl DeadlinePolicy {
    /// Row label used in the emitted curve.
    pub fn label(self) -> &'static str {
        match self {
            DeadlinePolicy::AllOnDemand => "all-ondemand",
            DeadlinePolicy::AllSpot => "all-spot",
            DeadlinePolicy::DeadlineAware => "deadline-aware",
        }
    }
}

/// One job's deadline outcome in a scenario run.
#[derive(Clone, Debug)]
pub struct DeadlineJobOutcome {
    pub name: String,
    /// Absolute virtual-time deadline the job was graded against.
    pub deadline_s: f64,
    /// Completion time, `None` if the job did not complete.
    pub completed_s: Option<f64>,
    pub met: bool,
}

/// Outcome of one point on the cost-vs-deadline-miss tradeoff curve.
#[derive(Clone, Debug)]
pub struct DeadlineScenarioReport {
    pub label: String,
    pub jobs: usize,
    pub met: usize,
    pub missed: usize,
    pub total_cost_cents: u64,
    pub makespan_s: f64,
    pub interruptions: usize,
    pub outcomes: Vec<DeadlineJobOutcome>,
}

impl DeadlineScenarioReport {
    pub fn row(&self) -> String {
        format!(
            "{:<16} deadlines met {:>2}/{:<2}  cost {:>7}c  makespan {:>8.0}s  interruptions {}",
            self.label,
            self.met,
            self.jobs,
            self.total_cost_cents,
            self.makespan_s,
            self.interruptions
        )
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("label", Json::str(&self.label)),
            ("jobs", Json::num(self.jobs as f64)),
            ("deadlines_met", Json::num(self.met as f64)),
            ("deadlines_missed", Json::num(self.missed as f64)),
            ("total_cost_cents", Json::num(self.total_cost_cents as f64)),
            ("makespan_s", Json::num(self.makespan_s)),
            ("interruptions", Json::num(self.interruptions as f64)),
            (
                "outcomes",
                Json::Arr(
                    self.outcomes
                        .iter()
                        .map(|o| {
                            Json::from_pairs(vec![
                                ("name", Json::str(&o.name)),
                                ("deadline_s", Json::num(o.deadline_s)),
                                (
                                    "completed_s",
                                    o.completed_s.map(Json::num).unwrap_or(Json::Null),
                                ),
                                ("met", Json::Bool(o.met)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Virtual-hours-heavy projects for the deadline scenario: a few
/// seconds of real numerics whose *modelled* cost spans hours, so
/// hour-boundary spot reclaims genuinely threaten deadlines.
fn write_deadline_projects(s: &mut Session) {
    s.analyst.write(
        "dsweep/sweep.json",
        br#"{"type":"mc_sweep","n_jobs":256,"seed":2012,"job_cost_s":120}"#.to_vec(),
    );
    let data = CatBondData::generate(7, 24, 96);
    for (name, bytes) in data.to_files() {
        s.analyst.write(&format!("dcat/{name}"), bytes);
    }
    s.analyst.write(
        "dcat/catopt.json",
        br#"{"type":"catopt","pop_size":12,"max_generations":8,"seed":42,"bfgs_every":0,"candidate_cost_s":320}"#
            .to_vec(),
    );
}

/// The scenario's job mix: six jobs alternating sweep / CATopt.
/// `deadline_factors[i]` scales job `i`'s deadline relative to its
/// measured all-on-demand duration (1.0 = exactly as fast as the
/// full-price reference ran it): < 1 is infeasible by construction,
/// ~1.25 is tight (the cost/risk curve forces on-demand under a hot
/// market), >= 5 is loose (safe to ride spot).
pub const DEADLINE_FACTORS: [f64; 6] = [1.25, 5.0, 0.15, 5.0, 1.25, 5.0];

fn deadline_specs(deadlines: Option<&[f64]>) -> Vec<JobSpec> {
    (0..DEADLINE_FACTORS.len())
        .map(|i| {
            let (dir, script) = if i % 2 == 0 {
                ("dsweep", "sweep.json")
            } else {
                ("dcat", "catopt.json")
            };
            JobSpecBuilder::new(&format!("slo{i}"), dir, script)
                .deadline(deadlines.map(|d| d[i]))
                .build()
        })
        .collect()
}

/// Run one point of the cost-vs-deadline-miss curve.
///
/// `deadlines`: absolute virtual-time deadlines per job, graded for
/// every policy but only *scheduled against* under `DeadlineAware`
/// (and `AllOnDemand`, where they change nothing: the fleet is already
/// the premium one). `None` runs uncalibrated (used once to measure
/// the all-on-demand reference durations the deadlines derive from).
pub fn run_deadline_scenario(
    policy: DeadlinePolicy,
    deadlines: Option<&[f64]>,
) -> Result<DeadlineScenarioReport> {
    let mut s = bench_session(1.0);
    // A hot but deterministic market: one hour in four spikes above
    // every bid. The seed is chosen so two spikes land inside the
    // workload's first hours (this path: hours 1, 2, 12, 16, 17) —
    // multi-hour spot jobs really are reclaimed mid-run, which is what
    // puts the "risk" in the cost/risk curve.
    s.cloud.spot.seed = 109;
    s.cloud.spot.spike_prob = 0.25;
    write_deadline_projects(&mut s);
    let cfg = AutoscalerConfig {
        min_clusters: 0,
        max_clusters: DEADLINE_FACTORS.len(),
        nodes_per_cluster: 2,
        spot: policy != DeadlinePolicy::AllOnDemand,
        policy: ScalePolicy::Work,
        bid: BidStrategy::ForecastMargin,
        ..Default::default()
    };
    let mut js = JobScheduler::new(cfg);
    let t0 = s.cloud.clock.now_s();
    let scheduler_sees = match policy {
        // The cost-optimal corner ignores deadlines at scheduling
        // time; they are graded afterwards.
        DeadlinePolicy::AllSpot => None,
        _ => deadlines,
    };
    let specs = deadline_specs(scheduler_sees);
    for spec in &specs {
        js.submit(&s, spec.clone());
    }
    js.run_until_idle(&mut s)?;
    js.shutdown_fleet(&mut s)?;

    let graded: Vec<f64> = match deadlines {
        Some(d) => d.to_vec(),
        None => vec![f64::INFINITY; specs.len()],
    };
    let mut outcomes = Vec::new();
    for (i, spec) in specs.iter().enumerate() {
        let job = js
            .queue
            .jobs()
            .find(|j| j.spec.name == spec.name)
            .expect("submitted job exists");
        let completed = (job.state == JobState::Completed)
            .then_some(job.completed_at_s)
            .flatten();
        outcomes.push(DeadlineJobOutcome {
            name: spec.name.clone(),
            deadline_s: graded[i],
            completed_s: completed,
            met: completed.map(|c| c <= graded[i]).unwrap_or(false),
        });
    }
    let met = outcomes.iter().filter(|o| o.met).count();
    Ok(DeadlineScenarioReport {
        label: policy.label().to_string(),
        jobs: specs.len(),
        met,
        missed: specs.len() - met,
        total_cost_cents: s.cloud.ledger.total_cents(),
        makespan_s: s.cloud.clock.now_s() - t0,
        interruptions: js.interruptions_delivered,
        outcomes,
    })
}

// ======================================= EDF queue-ordering scenario

/// Jobs in the EDF-vs-FIFO ordering comparison.
pub const ORDERING_JOBS: usize = 4;

/// Run the queue-ordering comparison scenario: `ORDERING_JOBS`
/// identical equal-priority sweeps on **one** on-demand cluster, so
/// strict serialisation makes dispatch order the only variable and the
/// bill is free of market noise (both orderings run the same slices
/// for the same makespan, so their costs tie — EDF buys its extra
/// deadlines for free).
///
/// Jobs are submitted loose-deadline first: under the PR 4
/// FIFO-within-class policy the late-submitted tight deadlines wait at
/// the back of the class and miss; EDF pulls them forward. `deadlines`
/// are absolute virtual times per job (`None` = an uncalibrated
/// reference run used to measure the completion ladder the deadlines
/// derive from).
pub fn run_ordering_scenario(
    ordering: QueueOrdering,
    deadlines: Option<&[f64]>,
) -> Result<DeadlineScenarioReport> {
    let mut s = bench_session(1.0);
    s.cloud.spot.spike_prob = 0.0;
    // One multi-hour sweep project shared by every job: each job is
    // several checkpointed slices long, so the queue re-sorts many
    // times and the ordering genuinely drives the schedule.
    s.analyst.write(
        "edf/sweep.json",
        br#"{"type":"mc_sweep","n_jobs":64,"seed":2012,"job_cost_s":120}"#.to_vec(),
    );
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 1,
        max_clusters: 1,
        nodes_per_cluster: 2,
        spot: false,
        policy: ScalePolicy::QueueDepth,
        ..Default::default()
    });
    js.queue.ordering = ordering;
    let t0 = s.cloud.clock.now_s();
    let mut names = Vec::new();
    for i in 0..ORDERING_JOBS {
        let name = format!("edf{i}");
        js.submit(
            &s,
            JobSpecBuilder::new(&name, "edf", "sweep.json")
                .deadline(deadlines.map(|d| d[i]))
                .build(),
        );
        names.push(name);
    }
    js.run_until_idle(&mut s)?;
    js.shutdown_fleet(&mut s)?;

    let graded: Vec<f64> = match deadlines {
        Some(d) => d.to_vec(),
        None => vec![f64::INFINITY; ORDERING_JOBS],
    };
    let mut outcomes = Vec::new();
    for (i, name) in names.iter().enumerate() {
        let job = js
            .queue
            .jobs()
            .find(|j| j.spec.name == *name)
            .expect("submitted job exists");
        let completed = (job.state == JobState::Completed)
            .then_some(job.completed_at_s)
            .flatten();
        outcomes.push(DeadlineJobOutcome {
            name: name.clone(),
            deadline_s: graded[i],
            completed_s: completed,
            met: completed.map(|c| c <= graded[i]).unwrap_or(false),
        });
    }
    let met = outcomes.iter().filter(|o| o.met).count();
    Ok(DeadlineScenarioReport {
        label: format!("{}-within-class", ordering.label()),
        jobs: ORDERING_JOBS,
        met,
        missed: ORDERING_JOBS - met,
        total_cost_cents: s.cloud.ledger.total_cents(),
        makespan_s: s.cloud.clock.now_s() - t0,
        interruptions: js.interruptions_delivered,
        outcomes,
    })
}

// ============================================== storage-plane scenario

/// Outcome of one storage-plane resume scenario (WAN vs LAN resume of
/// a spot-interrupted job).
#[derive(Clone, Debug)]
pub struct StorageScenarioReport {
    pub label: String,
    /// Cluster-resident checkpoints (LAN resume) vs Analyst-site
    /// checkpoints (WAN resume).
    pub resident: bool,
    /// Virtual time from submission to completed results + released
    /// fleet.
    pub makespan_s: f64,
    /// Metered WAN transfer charges only (the cost the storage plane
    /// exists to avoid).
    pub wan_transfer_centi_cents: u64,
    pub total_centi_cents: u64,
    pub interruptions: usize,
    /// Bit-identity fingerprint of the job's result files.
    pub result_digest: u64,
}

impl StorageScenarioReport {
    pub fn row(&self) -> String {
        format!(
            "{:<22} makespan {:>8.0}s  wan-transfer {:>6}cc  total {:>8}cc  interruptions {}",
            self.label,
            self.makespan_s,
            self.wan_transfer_centi_cents,
            self.total_centi_cents,
            self.interruptions
        )
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("label", Json::str(&self.label)),
            ("resident", Json::Bool(self.resident)),
            ("makespan_s", Json::num(self.makespan_s)),
            (
                "wan_transfer_centi_cents",
                Json::num(self.wan_transfer_centi_cents as f64),
            ),
            ("total_centi_cents", Json::num(self.total_centi_cents as f64)),
            ("interruptions", Json::num(self.interruptions as f64)),
            ("result_digest", Json::str(format!("{:016x}", self.result_digest))),
        ])
    }
}

/// Run one long CATopt job on a one-cluster spot fleet whose bid (the
/// on-demand rate) is exceeded at **every** hour boundary
/// (`spike_prob = 1`), so the provider reclaims the cluster while the
/// job is mid-flight and the scheduler must resume it on replacement
/// capacity — over the WAN (baseline) or over the LAN from the
/// cluster-side snapshot (`resident = true`). `interruptible = false`
/// runs the uninterrupted on-demand ground truth for the bit-identity
/// check. The project is paper-scale on the wire (`data_scale`), which
/// is exactly what makes the WAN re-sync the dominant resume cost.
pub fn run_storage_scenario(
    label: &str,
    resident: bool,
    interruptible: bool,
) -> Result<StorageScenarioReport> {
    let mut s = bench_session(256.0);
    s.cloud.spot.spike_prob = if interruptible { 1.0 } else { 0.0 };
    // ~17 MB of real loss-table bytes (≈ 4.3 GB at paper scale).
    let data = CatBondData::generate(7, 1024, 4096);
    for (name, bytes) in data.to_files() {
        s.analyst.write(&format!("stor/{name}"), bytes);
    }
    // candidate_cost_s makes each generation ~20 virtual minutes, so
    // the job spans hour boundaries and the reclaim lands mid-run.
    s.analyst.write(
        "stor/catopt.json",
        br#"{"type":"catopt","pop_size":12,"max_generations":4,"seed":42,"bfgs_every":0,"candidate_cost_s":600.0}"#
            .to_vec(),
    );
    let mut js = JobScheduler::new(AutoscalerConfig {
        min_clusters: 1,
        max_clusters: 1,
        nodes_per_cluster: 2,
        spot: interruptible,
        policy: ScalePolicy::QueueDepth,
        ..Default::default()
    });
    js.slice_units = 1;
    let t0 = s.cloud.clock.now_s();
    let id = js.submit_opts(
        &s,
        JobSpecBuilder::new("resume", "stor", "catopt.json").build(),
        resident,
        "bench",
    );
    js.run_until_idle(&mut s)?;
    js.shutdown_fleet(&mut s)?;
    let job = js.queue.get(id).expect("job exists");
    anyhow::ensure!(
        job.state == JobState::Completed,
        "{label}: job must complete, got {:?}",
        job.state
    );
    let mut files: Vec<(String, Vec<u8>)> = s
        .analyst
        .list_dir("stor_results/resume")
        .into_iter()
        .map(|rel| {
            let bytes = s.analyst.read(&format!("stor_results/resume/{rel}")).unwrap().to_vec();
            (rel, bytes)
        })
        .collect();
    files.sort();
    let wan_cc = s.cloud.ledger.total_wan_transfer_centi_cents();
    Ok(StorageScenarioReport {
        label: label.to_string(),
        resident,
        makespan_s: s.cloud.clock.now_s() - t0,
        wan_transfer_centi_cents: wan_cc,
        total_centi_cents: s.cloud.ledger.total_centi_cents(),
        interruptions: js.interruptions_delivered,
        result_digest: crate::jobs::files_digest(&files),
    })
}

/// Write `BENCH_<name>.json` at the repository root so the perf
/// trajectory is tracked across PRs (machine-readable counterpart of
/// the bench stdout).
pub fn emit_bench_json(name: &str, report: &Json) -> std::io::Result<std::path::PathBuf> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join(format!("BENCH_{name}.json"));
    std::fs::write(&path, report.to_string_pretty())?;
    Ok(path)
}

/// Pretty row printer shared by the bench binaries.
pub fn print_row(cols: &[String], widths: &[usize]) {
    let line: Vec<String> = cols
        .iter()
        .zip(widths)
        .map(|(c, w)| format!("{c:>w$}", w = w))
        .collect();
    println!("{}", line.join("  "));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resource_set_matches_table1() {
        let rs = table1_resources();
        assert_eq!(rs.len(), 8);
        assert_eq!(rs[0].label(), "Desktop A");
        assert_eq!(rs[7].label(), "Cluster D");
    }

    #[test]
    fn sweep_runs_on_every_resource() {
        for r in table1_resources() {
            let mut s = bench_session(1.0);
            let b = run_on_resource(&mut s, &r, Workload::Sweep).unwrap();
            assert!(b.compute_s > 0.0, "{}: no compute time", r.label());
            if matches!(r, Resource::Cluster { .. }) {
                assert!(b.create_s > 0.0 && b.terminate_s > 0.0);
                assert!(b.submit_all_s > 0.0);
            }
        }
    }

    #[test]
    fn real_speedup_report_is_sound() {
        // No wall-clock assertion here (CI machines may be single-core);
        // the >1.5x-at-4-threads check lives in `cargo bench --bench
        // micro`. Here we pin the invariants: bit-identical numerics
        // and sane timings.
        let r = measure_real_speedup(2).unwrap();
        assert!(r.bit_identical, "threaded GA must reproduce serial bits");
        assert!(r.wall_serial_s > 0.0 && r.wall_threaded_s > 0.0);
        assert!(r.threads >= 1 && r.threads <= 2);
        assert!(r.row().contains("bit-identical=true"));
        // The billed (virtual) speedup follows the cost model: sub-
        // linear because of the serial master dispatch, but close to
        // the process count for a compute-bound generation.
        assert!(
            r.virtual_speedup > 1.5 && r.virtual_speedup < 2.0,
            "virtual speedup {} out of model range",
            r.virtual_speedup
        );
    }

    #[test]
    fn queue_scenario_autoscaled_spot_undercuts_static_on_demand() {
        let od = run_queue_scenario("static on-demand", false, false, 4, 0).unwrap();
        let spot = run_queue_scenario("autoscaled spot", true, true, 4, 1).unwrap();
        assert_eq!(od.completed, 4, "on-demand scenario must finish all jobs");
        assert_eq!(spot.completed, 4, "spot scenario must finish all jobs");
        assert!(spot.interruptions >= 1, "the armed interruption must land");
        assert!(
            spot.total_cost_cents < od.total_cost_cents,
            "spot fleet ({}c) must undercut on-demand ({}c)",
            spot.total_cost_cents,
            od.total_cost_cents
        );
        assert!(spot.scale_events > 0);
    }

    #[test]
    fn cluster_d_is_fastest_compute() {
        // Paper Fig 5: the best performance is achieved on Cluster D.
        let rs = table1_resources();
        let mut times = Vec::new();
        for r in &rs {
            let mut s = bench_session(1.0);
            let b = run_on_resource(&mut s, r, Workload::Sweep).unwrap();
            times.push((r.label(), b.compute_s));
        }
        let best = times
            .iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        assert_eq!(best.0, "Cluster D", "fastest was {best:?}");
    }
}
