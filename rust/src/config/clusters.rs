//! Cluster registry config file (paper §3.4, file 3): per-cluster name,
//! size, public DNS of master and workers, shared EBS volume id,
//! description, and the in-use flag that guards `ec2terminatecluster`.

use crate::util::json::Json;
use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub struct ClusterEntry {
    /// Total node count (1 master + n-1 workers).
    pub size: usize,
    pub master_id: String,
    pub master_dns: String,
    pub worker_ids: Vec<String>,
    pub worker_dns: Vec<String>,
    /// EBS volume attached to the master and NFS-shared to workers.
    pub volume_id: Option<String>,
    pub instance_type: String,
    pub description: String,
    pub in_use: bool,
}

impl ClusterEntry {
    pub fn all_ids(&self) -> Vec<String> {
        let mut v = vec![self.master_id.clone()];
        v.extend(self.worker_ids.iter().cloned());
        v
    }
}

#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClustersConfig {
    pub entries: BTreeMap<String, ClusterEntry>,
}

impl ClustersConfig {
    pub fn insert(&mut self, name: &str, e: ClusterEntry) {
        self.entries.insert(name.to_string(), e);
    }
    pub fn remove(&mut self, name: &str) -> Option<ClusterEntry> {
        self.entries.remove(name)
    }
    pub fn get(&self, name: &str) -> Option<&ClusterEntry> {
        self.entries.get(name)
    }
    pub fn get_mut(&mut self, name: &str) -> Option<&mut ClusterEntry> {
        self.entries.get_mut(name)
    }
    pub fn contains(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }
    pub fn names(&self) -> Vec<String> {
        self.entries.keys().cloned().collect()
    }

    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        for (name, e) in &self.entries {
            let mut j = Json::obj();
            j.set("size", Json::num(e.size as f64));
            j.set("master_id", Json::str(&e.master_id));
            j.set("master_dns", Json::str(&e.master_dns));
            j.set("worker_ids", Json::arr_str(e.worker_ids.clone()));
            j.set("worker_dns", Json::arr_str(e.worker_dns.clone()));
            j.set(
                "volume_id",
                e.volume_id.as_ref().map(Json::str).unwrap_or(Json::Null),
            );
            j.set("instance_type", Json::str(&e.instance_type));
            j.set("description", Json::str(&e.description));
            j.set("in_use", Json::Bool(e.in_use));
            root.set(name, j);
        }
        root
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let mut cfg = Self::default();
        let obj = j
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("clusters config must be an object"))?;
        for (name, e) in obj {
            let strs = |key: &str| -> anyhow::Result<Vec<String>> {
                e.get(key)
                    .and_then(|v| v.as_arr())
                    .map(|a| {
                        a.iter()
                            .filter_map(|x| x.as_str().map(str::to_string))
                            .collect()
                    })
                    .ok_or_else(|| anyhow::anyhow!("missing array field '{key}'"))
            };
            cfg.entries.insert(
                name.clone(),
                ClusterEntry {
                    size: e.req_u64("size")? as usize,
                    master_id: e.req_str("master_id")?,
                    master_dns: e.req_str("master_dns")?,
                    worker_ids: strs("worker_ids")?,
                    worker_dns: strs("worker_dns")?,
                    volume_id: e.opt_str("volume_id"),
                    instance_type: e.req_str("instance_type")?,
                    description: e.req_str("description")?,
                    in_use: e.opt_bool("in_use", false),
                },
            );
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(n: usize) -> ClusterEntry {
        ClusterEntry {
            size: n,
            master_id: "i-m".into(),
            master_dns: "master.dns".into(),
            worker_ids: (1..n).map(|i| format!("i-w{i}")).collect(),
            worker_dns: (1..n).map(|i| format!("w{i}.dns")).collect(),
            volume_id: Some("vol-1".into()),
            instance_type: "m2.2xlarge".into(),
            description: "hpc".into(),
            in_use: true,
        }
    }

    #[test]
    fn roundtrip() {
        let mut c = ClustersConfig::default();
        c.insert("hpc_cluster", entry(4));
        let back =
            ClustersConfig::from_json(&Json::parse(&c.to_json().to_string_pretty()).unwrap())
                .unwrap();
        assert_eq!(back, c);
        assert_eq!(back.get("hpc_cluster").unwrap().worker_ids.len(), 3);
    }

    #[test]
    fn all_ids_master_first() {
        let e = entry(3);
        assert_eq!(e.all_ids(), vec!["i-m", "i-w1", "i-w2"]);
    }
}
