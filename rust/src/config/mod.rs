//! The four Analyst-site configuration files (paper §3.4):
//!
//! 1. [`PlatformConfig`] — variables required by the command-line tools:
//!    defaults (AMI, snapshot, instance type, cluster size), region and
//!    access-key references.
//! 2. [`InstancesConfig`] — registry of created instances (name, public
//!    DNS, volume id, description, in-use flag).
//! 3. [`ClustersConfig`] — registry of created clusters (name, size,
//!    DNS of master and workers, shared volume id, description, in-use).
//! 4. [`RLibsConfig`] — R library packages installed on instances at
//!    creation, on top of the base AMI.
//!
//! All four serialise to stable pretty JSON via `util::json` and are
//! kept on the Analyst-site [`Vfs`](crate::simcloud::Vfs) under
//! `.p2rac/`, exactly where the paper's tools keep them.

pub mod clusters;
pub mod instances;
pub mod platform;
pub mod rlibs;

pub use clusters::{ClusterEntry, ClustersConfig};
pub use instances::{InstanceEntry, InstancesConfig};
pub use platform::PlatformConfig;
pub use rlibs::RLibsConfig;

/// Where the config files live on the Analyst site.
pub const CONFIG_DIR: &str = ".p2rac";
