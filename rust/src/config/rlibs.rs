//! R-library manifest config file (paper §3.4, file 4): packages an
//! Analyst's project needs beyond the base AMI. Installed on every
//! instance of a cluster at creation time.

use crate::util::json::Json;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct RLibsConfig {
    pub libraries: Vec<String>,
}

impl RLibsConfig {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("libraries", Json::arr_str(self.libraries.clone()));
        j
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let libs = j
            .get("libraries")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("rlibs config needs a 'libraries' array"))?;
        Ok(Self {
            libraries: libs
                .iter()
                .filter_map(|x| x.as_str().map(str::to_string))
                .collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let c = RLibsConfig {
            libraries: vec!["rgenoud".into(), "snow".into(), "quantmod".into()],
        };
        let back = RLibsConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn empty_list_ok() {
        let c = RLibsConfig::default();
        assert_eq!(RLibsConfig::from_json(&c.to_json()).unwrap(), c);
    }
}
