//! Platform configuration file: tool-wide defaults and account
//! references (paper §3.4, file 1).

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct PlatformConfig {
    /// Default AMI id used when `ec2createinstance` gets no override.
    pub default_ami: String,
    /// Default EBS snapshot materialised when neither `-ebsvol` nor
    /// `-snap` is given.
    pub default_snapshot: String,
    /// Default EC2 instance type.
    pub default_type: String,
    /// Default cluster size for `ec2createcluster`.
    pub default_cluster_size: usize,
    /// Region (informational in the simulation).
    pub region: String,
    /// Reference to the AWS access-key pair (never the secret itself).
    pub access_key_ref: String,
    /// Default instance / cluster to use when `-iname`/`-cname` is
    /// omitted (updated by the create commands).
    pub default_instance: Option<String>,
    pub default_cluster: Option<String>,
}

impl Default for PlatformConfig {
    fn default() -> Self {
        Self {
            default_ami: String::new(),
            default_snapshot: String::new(),
            default_type: "m2.2xlarge".to_string(),
            default_cluster_size: 4,
            region: "us-east-1".to_string(),
            access_key_ref: "~/.aws/p2rac-keypair".to_string(),
            default_instance: None,
            default_cluster: None,
        }
    }
}

impl PlatformConfig {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("default_ami", Json::str(&self.default_ami));
        j.set("default_snapshot", Json::str(&self.default_snapshot));
        j.set("default_type", Json::str(&self.default_type));
        j.set("default_cluster_size", Json::num(self.default_cluster_size as f64));
        j.set("region", Json::str(&self.region));
        j.set("access_key_ref", Json::str(&self.access_key_ref));
        j.set(
            "default_instance",
            self.default_instance
                .as_ref()
                .map(Json::str)
                .unwrap_or(Json::Null),
        );
        j.set(
            "default_cluster",
            self.default_cluster
                .as_ref()
                .map(Json::str)
                .unwrap_or(Json::Null),
        );
        j
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        Ok(Self {
            default_ami: j.req_str("default_ami")?,
            default_snapshot: j.req_str("default_snapshot")?,
            default_type: j.req_str("default_type")?,
            default_cluster_size: j.req_u64("default_cluster_size")? as usize,
            region: j.req_str("region")?,
            access_key_ref: j.req_str("access_key_ref")?,
            default_instance: j.opt_str("default_instance"),
            default_cluster: j.opt_str("default_cluster"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let c = PlatformConfig {
            default_ami: "ami-abc".into(),
            default_instance: Some("hpc_instance".into()),
            ..PlatformConfig::default()
        };
        let j = c.to_json();
        let back = PlatformConfig::from_json(&Json::parse(&j.to_string_pretty()).unwrap()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn null_defaults_roundtrip() {
        let c = PlatformConfig::default();
        let back = PlatformConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.default_instance, None);
    }
}
