//! Instance registry config file (paper §3.4, file 2): one section per
//! created instance with its public DNS, volume, description and in-use
//! flag. `ec2createinstance` appends a section; `ec2terminateinstance`
//! removes it.

use crate::util::json::Json;
use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub struct InstanceEntry {
    /// Cloud-side instance id.
    pub instance_id: String,
    pub public_dns: String,
    /// Attached EBS volume, if any.
    pub volume_id: Option<String>,
    pub instance_type: String,
    pub description: String,
    pub in_use: bool,
}

#[derive(Clone, Debug, Default, PartialEq)]
pub struct InstancesConfig {
    /// Analyst-facing name → entry.
    pub entries: BTreeMap<String, InstanceEntry>,
}

impl InstancesConfig {
    pub fn insert(&mut self, name: &str, e: InstanceEntry) {
        self.entries.insert(name.to_string(), e);
    }

    pub fn remove(&mut self, name: &str) -> Option<InstanceEntry> {
        self.entries.remove(name)
    }

    pub fn get(&self, name: &str) -> Option<&InstanceEntry> {
        self.entries.get(name)
    }

    pub fn contains(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    pub fn names(&self) -> Vec<String> {
        self.entries.keys().cloned().collect()
    }

    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        for (name, e) in &self.entries {
            let mut j = Json::obj();
            j.set("instance_id", Json::str(&e.instance_id));
            j.set("public_dns", Json::str(&e.public_dns));
            j.set(
                "volume_id",
                e.volume_id.as_ref().map(Json::str).unwrap_or(Json::Null),
            );
            j.set("instance_type", Json::str(&e.instance_type));
            j.set("description", Json::str(&e.description));
            j.set("in_use", Json::Bool(e.in_use));
            root.set(name, j);
        }
        root
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let mut cfg = Self::default();
        let obj = j
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("instances config must be an object"))?;
        for (name, e) in obj {
            cfg.entries.insert(
                name.clone(),
                InstanceEntry {
                    instance_id: e.req_str("instance_id")?,
                    public_dns: e.req_str("public_dns")?,
                    volume_id: e.opt_str("volume_id"),
                    instance_type: e.req_str("instance_type")?,
                    description: e.req_str("description")?,
                    in_use: e.opt_bool("in_use", false),
                },
            );
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry() -> InstanceEntry {
        InstanceEntry {
            instance_id: "i-0abc".into(),
            public_dns: "ec2-1-2-3-4.us-east-1.compute.amazonaws.com".into(),
            volume_id: Some("vol-0def".into()),
            instance_type: "m2.4xlarge".into(),
            description: "For Trial Simulation Run".into(),
            in_use: false,
        }
    }

    #[test]
    fn insert_remove_roundtrip() {
        let mut c = InstancesConfig::default();
        c.insert("hpc_instance", entry());
        assert!(c.contains("hpc_instance"));
        let j = c.to_json();
        let back =
            InstancesConfig::from_json(&Json::parse(&j.to_string_pretty()).unwrap()).unwrap();
        assert_eq!(back, c);
        assert!(back.get("hpc_instance").unwrap().volume_id.is_some());
    }

    #[test]
    fn remove_deletes_section() {
        let mut c = InstancesConfig::default();
        c.insert("a", entry());
        assert!(c.remove("a").is_some());
        assert!(c.remove("a").is_none());
        assert!(c.names().is_empty());
    }
}
