//! Simulated Elastic Block Storage: persistent volumes, snapshots, and
//! the attachment rules the paper's tools rely on (one volume attaches
//! to at most one instance; snapshots materialise new volumes; volumes
//! outlive instances unless `-deletevol` is passed).

use super::vfs::Vfs;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VolumeState {
    Available,
    Attached,
    Deleted,
}

/// A persistent EBS volume; its `fs` survives instance termination.
#[derive(Clone, Debug)]
pub struct Volume {
    pub id: String,
    pub size_gb: f64,
    pub state: VolumeState,
    /// Instance id the volume is attached to, if any.
    pub attached_to: Option<String>,
    /// Snapshot this volume was created from, if any.
    pub source_snapshot: Option<String>,
    /// Persistent contents (the Analyst's large, rarely-changing data).
    pub fs: Vfs,
}

impl Volume {
    pub fn is_live(&self) -> bool {
        self.state != VolumeState::Deleted
    }
}

/// A point-in-time snapshot of a volume, stored (conceptually) in S3.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub id: String,
    pub size_gb: f64,
    /// Frozen copy of the source volume's contents.
    pub fs: Vfs,
    pub description: String,
    pub deleted: bool,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn volume_liveness() {
        let mut v = Volume {
            id: "vol-1".into(),
            size_gb: 100.0,
            state: VolumeState::Available,
            attached_to: None,
            source_snapshot: None,
            fs: Vfs::new(),
        };
        assert!(v.is_live());
        v.state = VolumeState::Deleted;
        assert!(!v.is_live());
    }
}
