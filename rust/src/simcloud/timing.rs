//! Calibration constants for the simulated cloud's timing model.
//!
//! Anchored to the numbers the paper reports (DESIGN.md §7): single
//! instances come up in ~3 min, an 8-node m2.2xlarge cluster in ~7 min,
//! a 16-node one in ~8 min; termination time is size-independent;
//! intra-cluster communication carries a virtualisation penalty that
//! produces the Fig-4 efficiency knee past 4 instances.

/// All tunables in one place so benches and tests can scale or distort
/// the model (e.g. ablations on the virtualisation overhead).
#[derive(Clone, Debug)]
pub struct SimParams {
    // ---- resource lifecycle ----
    /// Base EC2 instance provisioning latency (request→running), seconds.
    pub instance_boot_s: f64,
    /// Additional serial AWS-API cost per instance in a batch launch.
    pub per_instance_extra_s: f64,
    /// Cluster-only configuration (master/worker setup, NFS export).
    pub cluster_config_base_s: f64,
    /// Per-worker NFS mount + hosts configuration.
    pub per_worker_config_s: f64,
    /// Install time per R library listed in the rlibs config file.
    pub rlib_install_s: f64,
    /// EBS volume attach / detach.
    pub volume_attach_s: f64,
    /// EBS volume creation from a snapshot (plus per-GiB cost).
    pub volume_from_snap_base_s: f64,
    pub volume_from_snap_s_per_gb: f64,
    /// Point-in-time snapshot of a live volume (plus per-GiB cost):
    /// incremental S3-backed copy, cheaper than full hydration.
    pub snapshot_base_s: f64,
    pub snapshot_s_per_gb: f64,
    /// Instance/cluster termination (paper: flat, size-independent).
    pub terminate_s: f64,

    // ---- network ----
    /// Analyst site ↔ cloud uplink (rsync path), bytes/second.
    pub wan_bw_bytes_s: f64,
    /// WAN round-trip latency, seconds.
    pub wan_rtt_s: f64,
    /// Intra-cluster (instance↔instance) bandwidth, bytes/second.
    pub lan_bw_bytes_s: f64,
    /// LAN round-trip latency, seconds.
    pub lan_rtt_s: f64,
    /// Multiplier on collective-communication time capturing the
    /// virtualised-network penalty the paper blames for the efficiency
    /// drop beyond 4 instances.
    pub virt_overhead: f64,
    /// Per-file protocol overhead for rsync-style sync, seconds.
    pub per_file_overhead_s: f64,
    /// Number of parallel rsync streams the Analyst uplink sustains when
    /// fanning a project out to all cluster nodes.
    pub fanout_streams: usize,

    // ---- compute speed model (Table I) ----
    /// Reference per-core speed: Desktop A (i7-2600 @ 3.4 GHz) = 1.0.
    pub desktop_a_core_speed: f64,
    /// Desktop B (Xeon X5660 @ 2.8 GHz).
    pub desktop_b_core_speed: f64,
    /// m2.2xlarge / m2.4xlarge per-core speed relative to Desktop A.
    pub ec2_core_speed: f64,

    /// Scale factor mapping bench workload bytes → paper-scale bytes
    /// (benches run a reduced dataset; the time model multiplies sizes
    /// back up so reported times are paper-scale).
    pub data_scale: f64,
}

impl Default for SimParams {
    fn default() -> Self {
        Self {
            instance_boot_s: 150.0,
            per_instance_extra_s: 13.0,
            cluster_config_base_s: 110.0,
            per_worker_config_s: 4.0,
            rlib_install_s: 18.0,
            volume_attach_s: 12.0,
            volume_from_snap_base_s: 25.0,
            volume_from_snap_s_per_gb: 0.05,
            snapshot_base_s: 5.0,
            snapshot_s_per_gb: 0.2,
            terminate_s: 35.0,

            wan_bw_bytes_s: 12.0 * 1024.0 * 1024.0,
            wan_rtt_s: 0.080,
            lan_bw_bytes_s: 120.0 * 1024.0 * 1024.0,
            lan_rtt_s: 0.0004,
            virt_overhead: 1.6,
            per_file_overhead_s: 0.01,
            fanout_streams: 4,

            desktop_a_core_speed: 1.00,
            desktop_b_core_speed: 0.82,
            ec2_core_speed: 0.88,

            data_scale: 1.0,
        }
    }
}

impl SimParams {
    /// Boot time for a batch of `n` instances launched together.
    pub fn batch_boot_s(&self, n: usize) -> f64 {
        self.instance_boot_s + self.per_instance_extra_s * n as f64
    }

    /// Full cluster-creation time: batch boot + master/worker + NFS
    /// config + library installs (parallel across nodes → counted once).
    pub fn cluster_create_s(&self, n_nodes: usize, n_rlibs: usize) -> f64 {
        self.batch_boot_s(n_nodes)
            + self.cluster_config_base_s
            + self.per_worker_config_s * n_nodes.saturating_sub(1) as f64
            + self.rlib_install_s * n_rlibs as f64
    }

    /// Single-instance creation time.
    pub fn instance_create_s(&self, n_rlibs: usize) -> f64 {
        self.batch_boot_s(1) + self.rlib_install_s * n_rlibs as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_create_matches_paper_anchors() {
        let p = SimParams::default();
        // Paper: ~7 minutes for an 8-node m2.2xlarge cluster.
        let t8 = p.cluster_create_s(8, 0);
        assert!(
            (360.0..=480.0).contains(&t8),
            "8-node create {t8}s outside 6–8 min"
        );
        // Paper: ~8 minutes for a 16-node cluster.
        let t16 = p.cluster_create_s(16, 0);
        assert!(
            (450.0..=570.0).contains(&t16),
            "16-node create {t16}s outside 7.5–9.5 min"
        );
        assert!(t16 > t8, "creation time must grow with cluster size");
    }

    #[test]
    fn instance_create_is_minutes_scale() {
        let p = SimParams::default();
        let t = p.instance_create_s(0);
        assert!((120.0..=240.0).contains(&t), "instance create {t}s");
    }

    #[test]
    fn rlibs_add_install_time() {
        let p = SimParams::default();
        assert!(p.cluster_create_s(4, 3) > p.cluster_create_s(4, 0));
    }
}
