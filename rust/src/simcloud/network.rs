//! Network time model for the simulated cloud.
//!
//! Two link classes: WAN (Analyst site ↔ cloud, the rsync path) and LAN
//! (instance ↔ instance inside a cluster placement group). Collective
//! operations pay the virtualisation overhead the paper identifies as
//! the cause of the parallel-efficiency drop beyond 4 instances.

use super::timing::SimParams;

/// Which link a transfer crosses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Link {
    /// Analyst workstation ↔ cloud front door.
    Wan,
    /// Between instances inside the cloud (NFS, MPI-style traffic).
    Lan,
}

/// Pure-function network model (all state lives in `SimParams`).
#[derive(Clone, Debug)]
pub struct NetworkModel {
    params: SimParams,
}

impl NetworkModel {
    pub fn new(params: SimParams) -> Self {
        Self { params }
    }

    pub fn params(&self) -> &SimParams {
        &self.params
    }

    fn bw(&self, link: Link) -> f64 {
        match link {
            Link::Wan => self.params.wan_bw_bytes_s,
            Link::Lan => self.params.lan_bw_bytes_s,
        }
    }

    fn rtt(&self, link: Link) -> f64 {
        match link {
            Link::Wan => self.params.wan_rtt_s,
            Link::Lan => self.params.lan_rtt_s,
        }
    }

    /// Point-to-point transfer of `bytes` (+ per-file protocol chatter).
    pub fn transfer_s(&self, bytes: u64, n_files: usize, link: Link) -> f64 {
        let payload = bytes as f64 * self.params.data_scale;
        self.rtt(link) + payload / self.bw(link) + self.params.per_file_overhead_s * n_files as f64
    }

    /// Fan-out of the same `bytes` payload to `n_dest` destinations over
    /// a shared uplink with `fanout_streams` concurrent streams: the
    /// paper observes submit-to-all-nodes time growing with cluster
    /// size even though transfers are "parallel in nature".
    pub fn fanout_s(&self, bytes: u64, n_files: usize, n_dest: usize, link: Link) -> f64 {
        if n_dest == 0 {
            return 0.0;
        }
        let streams = self.params.fanout_streams.max(1).min(n_dest);
        let waves = n_dest.div_ceil(streams);
        // Each wave moves `streams` copies concurrently over the shared
        // uplink, so each copy gets bw/streams.
        let payload = bytes as f64 * self.params.data_scale;
        let wave_s = self.rtt(link)
            + payload / (self.bw(link) / streams as f64)
            + self.params.per_file_overhead_s * n_files as f64;
        wave_s * waves as f64
    }

    /// Gather of per-node payloads back to one sink (results fetch):
    /// same contention structure as fan-out.
    pub fn gather_s(&self, bytes_each: u64, n_files_each: usize, n_src: usize, link: Link) -> f64 {
        self.fanout_s(bytes_each, n_files_each, n_src, link)
    }

    /// One scatter+gather round of a co-operative parallel job across
    /// `n` workers (per-generation GA sync): tree latency + payload,
    /// times the virtualisation overhead factor.
    pub fn collective_s(&self, bytes_total: u64, n: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let hops = (n as f64).log2().ceil();
        let payload = bytes_total as f64 * self.params.data_scale;
        let one_way = self.rtt(Link::Lan) * hops + payload / self.bw(Link::Lan);
        2.0 * one_way * self.params.virt_overhead
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net() -> NetworkModel {
        NetworkModel::new(SimParams::default())
    }

    #[test]
    fn wan_slower_than_lan() {
        let n = net();
        let b = 100 * 1024 * 1024;
        assert!(n.transfer_s(b, 1, Link::Wan) > n.transfer_s(b, 1, Link::Lan));
    }

    #[test]
    fn transfer_scales_with_bytes() {
        let n = net();
        let t1 = n.transfer_s(10 * 1024 * 1024, 1, Link::Wan);
        let t2 = n.transfer_s(100 * 1024 * 1024, 1, Link::Wan);
        assert!(t2 > 5.0 * t1);
    }

    #[test]
    fn paper_anchor_300mb_sync_takes_tens_of_seconds() {
        // The CATopt project (~300 MB) syncs over the WAN in well under
        // the creation time (~minutes) per Fig 6.
        let n = net();
        let t = n.transfer_s(300 * 1024 * 1024, 40, Link::Wan);
        assert!((15.0..120.0).contains(&t), "300MB WAN sync = {t}s");
    }

    #[test]
    fn fanout_grows_with_destinations() {
        let n = net();
        let b = 3 * 1024 * 1024;
        let t4 = n.fanout_s(b, 5, 4, Link::Wan);
        let t16 = n.fanout_s(b, 5, 16, Link::Wan);
        assert!(t16 > t4, "fanout must grow with cluster size");
        assert_eq!(n.fanout_s(b, 5, 0, Link::Wan), 0.0);
    }

    #[test]
    fn collective_grows_with_n_and_overhead() {
        let n = net();
        let b = 2 * 1024 * 1024;
        let t2 = n.collective_s(b, 2);
        let t16 = n.collective_s(b, 16);
        assert!(t16 > t2);
        assert_eq!(n.collective_s(b, 1), 0.0);

        let cheap = SimParams {
            virt_overhead: 1.0,
            ..SimParams::default()
        };
        let bare = NetworkModel::new(cheap);
        assert!(bare.collective_s(b, 16) < t16);
    }

    #[test]
    fn data_scale_multiplies_payload() {
        let p = SimParams {
            data_scale: 64.0,
            ..SimParams::default()
        };
        let scaled = NetworkModel::new(p);
        let base = net();
        let b = 1024 * 1024;
        assert!(scaled.transfer_s(b, 1, Link::Wan) > 30.0 * base.transfer_s(b, 1, Link::Wan));
    }
}
