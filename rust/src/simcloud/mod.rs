//! Simulated IaaS substrate (the paper's Amazon EC2/EBS/S3).
//!
//! No AWS account exists in this environment (reproduction band 0/5), so
//! P2RAC drives a deterministic simulated cloud instead: the Table-I
//! instance catalog, AMIs, volumes/snapshots, a WAN/LAN network model
//! with a virtualisation penalty, per-instance virtual filesystems with
//! *real bytes* (so the rsync data sync is genuine), usage billing, and
//! a virtual clock that every operation advances by a calibrated
//! duration (DESIGN.md §2, §7).
//!
//! The simulation is **discrete-event**: nothing happens "while time
//! passes" — operations compute a duration from the models here
//! (network shape, instance speeds, EBS hydration, cluster
//! configuration) and advance [`Clock`] by it, and anything
//! time-driven (spot reclaims at hour boundaries, hourly prices,
//! billing periods) is a pure function of the resulting timestamps.
//! That is what makes every run bit-reproducible: the world has no
//! state outside the clock, the seeds, and the bytes. Two modules are
//! explicitly stochastic-looking but seeded: [`spot`] (the hourly
//! price path, a pure function of `(seed, type, hour)`) and its
//! summary [`pricing::PriceForecast`] (rolling-window expected price
//! and interruption likelihood — the basis of the jobs scheduler's
//! deadline cost/risk decisions and the autoscaler's bids).

pub mod clock;
pub mod cloud;
pub mod ebs;
pub mod ec2;
pub mod faults;
pub mod network;
pub mod pricing;
pub mod s3;
pub mod spot;
pub mod timing;
pub mod vfs;

pub use clock::{Clock, Span, SpanCategory};
pub use cloud::{CloudError, SimCloud};
pub use ebs::{Snapshot, Volume, VolumeState};
pub use ec2::{
    instance_type, Ami, Instance, InstanceState, InstanceTypeSpec, Lifecycle, INSTANCE_TYPES,
};
pub use faults::FaultPlan;
pub use network::{Link, NetworkModel};
pub use pricing::{Invoice, Ledger, LineItem, PriceForecast};
pub use s3::{content_digest, digest_update, S3Object, DIGEST_SEED, S3};
pub use spot::SpotMarket;
pub use timing::SimParams;
pub use vfs::Vfs;
