//! Simulated EC2: the instance-type catalog (paper Table I, 2012
//! pricing), Amazon Machine Images, and instance records with the
//! Pending → Running → Terminated lifecycle.

use super::vfs::Vfs;
use std::collections::BTreeMap;

/// An EC2 instance type. Speeds are relative per-core factors against
/// Desktop A (i7-2600 @ 3.4 GHz) = 1.0, per DESIGN.md §7.
#[derive(Clone, Debug, PartialEq)]
pub struct InstanceTypeSpec {
    pub api_name: &'static str,
    pub cores: usize,
    /// EC2 compute units (Amazon's 2012 marketing unit, informational).
    pub ecu: f64,
    pub mem_gb: f64,
    pub storage_gb: f64,
    /// USD cents per instance-hour (paper: m2.2xlarge $0.90/h,
    /// m2.4xlarge $1.80/h).
    pub price_cents_hour: u64,
    /// Per-core relative speed vs Desktop A.
    pub core_speed: f64,
    /// Hardware-virtual-machine (cluster-compute style) image required?
    pub hvm: bool,
}

/// The catalog used in the paper's experiments plus the two types its
/// examples mention.
pub const INSTANCE_TYPES: &[InstanceTypeSpec] = &[
    InstanceTypeSpec {
        api_name: "m1.large",
        cores: 2,
        ecu: 4.0,
        mem_gb: 7.5,
        storage_gb: 850.0,
        price_cents_hour: 32,
        core_speed: 0.70,
        hvm: false,
    },
    InstanceTypeSpec {
        api_name: "m2.2xlarge",
        cores: 4,
        ecu: 13.0,
        mem_gb: 34.2,
        storage_gb: 850.0,
        price_cents_hour: 90,
        core_speed: 0.88,
        hvm: false,
    },
    InstanceTypeSpec {
        api_name: "m2.4xlarge",
        cores: 8,
        ecu: 26.0,
        mem_gb: 68.4,
        storage_gb: 1690.0,
        price_cents_hour: 180,
        core_speed: 0.88,
        hvm: false,
    },
    InstanceTypeSpec {
        api_name: "cc1.4xlarge",
        cores: 8,
        ecu: 33.5,
        mem_gb: 23.0,
        storage_gb: 1690.0,
        price_cents_hour: 130,
        core_speed: 0.95,
        hvm: true,
    },
];

pub fn instance_type(api_name: &str) -> Option<&'static InstanceTypeSpec> {
    INSTANCE_TYPES.iter().find(|t| t.api_name == api_name)
}

/// An Amazon Machine Image. The paper uses two Ubuntu AMIs: one HVM
/// (cluster-compute) and one paravirtual.
#[derive(Clone, Debug, PartialEq)]
pub struct Ami {
    pub id: String,
    pub name: String,
    pub hvm: bool,
    /// Pre-installed libraries (the base image the paper describes ships
    /// R + SNOW; extra libs come from the rlibs config file at boot).
    pub preinstalled: Vec<String>,
}

/// Instance lifecycle state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InstanceState {
    Pending,
    Running,
    ShuttingDown,
    Terminated,
}

/// How an instance is purchased and billed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lifecycle {
    /// Fixed hourly rate, never reclaimed.
    OnDemand,
    /// Market-priced capacity: billed per started hour at the spot
    /// market's hourly price, reclaimed whenever the price exceeds the
    /// bid (see `simcloud::spot`).
    Spot {
        /// The Analyst's bid in centi-cents per instance-hour.
        bid_centi_cents_hour: u64,
    },
}

/// One simulated EC2 instance.
#[derive(Clone, Debug)]
pub struct Instance {
    pub id: String,
    /// Analyst-facing name tag (unique among live instances).
    pub name: Option<String>,
    pub itype: &'static InstanceTypeSpec,
    pub ami_id: String,
    pub state: InstanceState,
    pub public_dns: String,
    pub tags: BTreeMap<String, String>,
    /// Attached EBS volume, if any.
    pub attached_volume: Option<String>,
    /// NFS mount of a volume exported by another instance (cluster
    /// workers mount the master's volume).
    pub nfs_mount_from: Option<String>,
    /// Local instance storage: project dirs, results, installed libs.
    pub fs: Vfs,
    /// Installed library packages (base AMI + rlibs config).
    pub installed_libs: Vec<String>,
    /// Purchase model (on-demand or spot with a bid).
    pub lifecycle: Lifecycle,
    /// Locked for a run (`ec2resourcelock -inuse`).
    pub locked: bool,
    /// Virtual time the instance entered Running (for billing).
    pub launched_at_s: f64,
    /// Virtual time it terminated, if it did.
    pub terminated_at_s: Option<f64>,
    pub description: String,
}

impl Instance {
    pub fn is_live(&self) -> bool {
        matches!(self.state, InstanceState::Pending | InstanceState::Running)
    }

    pub fn is_spot(&self) -> bool {
        matches!(self.lifecycle, Lifecycle::Spot { .. })
    }

    /// Effective compute throughput in Desktop-A-core-equivalents.
    pub fn compute_power(&self) -> f64 {
        self.itype.cores as f64 * self.itype.core_speed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_matches_table1() {
        let m22 = instance_type("m2.2xlarge").unwrap();
        assert_eq!(m22.cores, 4);
        assert_eq!(m22.mem_gb, 34.2);
        assert_eq!(m22.storage_gb, 850.0);
        assert_eq!(m22.price_cents_hour, 90);

        let m24 = instance_type("m2.4xlarge").unwrap();
        assert_eq!(m24.cores, 8);
        assert_eq!(m24.mem_gb, 68.4);
        assert_eq!(m24.storage_gb, 1690.0);
        assert_eq!(m24.price_cents_hour, 180);
    }

    #[test]
    fn unknown_type_is_none() {
        assert!(instance_type("z9.mega").is_none());
    }

    #[test]
    fn compute_power_scales_with_cores() {
        let mk = |t: &'static InstanceTypeSpec| Instance {
            id: "i-x".into(),
            name: None,
            itype: t,
            ami_id: "ami-x".into(),
            state: InstanceState::Running,
            public_dns: "d".into(),
            tags: BTreeMap::new(),
            attached_volume: None,
            nfs_mount_from: None,
            fs: Vfs::new(),
            installed_libs: vec![],
            lifecycle: Lifecycle::OnDemand,
            locked: false,
            launched_at_s: 0.0,
            terminated_at_s: None,
            description: String::new(),
        };
        let a = mk(instance_type("m2.2xlarge").unwrap());
        let b = mk(instance_type("m2.4xlarge").unwrap());
        assert!((b.compute_power() / a.compute_power() - 2.0).abs() < 1e-9);
    }
}
