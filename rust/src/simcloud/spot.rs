//! Deterministic spot-instance market (EC2-2012 style).
//!
//! Each instance type has an hourly spot price drawn from a seeded,
//! query-order-independent PRNG: the price of hour `h` for type `t` is
//! a pure function of `(seed, t, h)`, so every observer — billing,
//! interruption scanning, benches — sees the same path. Most hours the
//! price sits around `base_fraction` of the on-demand rate with a small
//! jitter; with probability `spike_prob` an hour spikes *above* the
//! on-demand rate, which interrupts every instance whose bid is at or
//! below the spike.
//!
//! Billing follows the classic spot rules: each **started** hour is
//! charged at that hour's market price; when the *provider* interrupts
//! an instance the final partial hour is free, while a self-initiated
//! termination pays for it (minimum one hour, like on-demand).

use super::ec2::instance_type;
use crate::util::prng::SplitMix64;

/// The market model. All fields are public so benches and tests can
/// distort the price path (e.g. a spike-free market for ablations).
#[derive(Clone, Debug)]
pub struct SpotMarket {
    /// Seed of the price path (part of the simulated world's identity).
    pub seed: u64,
    /// Mean spot price as a fraction of the on-demand rate.
    pub base_fraction: f64,
    /// Half-width of the hourly jitter around `base_fraction`.
    pub jitter_fraction: f64,
    /// Probability that an hour's price spikes above on-demand.
    pub spike_prob: f64,
    /// Spike level as a fraction of the on-demand rate (> 1.0 so a
    /// bid at the on-demand price is interrupted by every spike).
    pub spike_fraction: f64,
}

impl Default for SpotMarket {
    fn default() -> Self {
        Self {
            seed: 0x2012_51B0,
            base_fraction: 0.30,
            jitter_fraction: 0.10,
            spike_prob: 0.04,
            spike_fraction: 1.35,
        }
    }
}

impl SpotMarket {
    pub fn new(seed: u64) -> Self {
        Self {
            seed,
            ..Self::default()
        }
    }

    /// Hour index containing virtual time `t_s`.
    pub fn hour_index(t_s: f64) -> u64 {
        (t_s.max(0.0) / 3600.0).floor() as u64
    }

    /// Two independent uniforms for `(type, hour)` — pure function of
    /// the market seed, so the path never depends on query order.
    fn hour_draw(&self, api_name: &str, hour: u64) -> (f64, f64) {
        let mut h = self.seed ^ hour.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        for b in api_name.bytes() {
            h = h.wrapping_mul(0x0100_0000_01B3).wrapping_add(b as u64);
        }
        let mut sm = SplitMix64::new(h);
        let u1 = (sm.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let u2 = (sm.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        (u1, u2)
    }

    /// Spot price of one `api_name` instance-hour, in centi-cents
    /// (hundredths of a cent), for the given hour of the simulation.
    /// Unknown types price at zero (launch would have failed earlier).
    pub fn price_centi_cents_hour(&self, api_name: &str, hour: u64) -> u64 {
        let Some(spec) = instance_type(api_name) else {
            return 0;
        };
        let on_demand = spec.price_cents_hour as f64 * 100.0;
        let (u_spike, u_jitter) = self.hour_draw(api_name, hour);
        let fraction = if u_spike < self.spike_prob {
            self.spike_fraction
        } else {
            (self.base_fraction + self.jitter_fraction * (2.0 * u_jitter - 1.0)).max(0.05)
        };
        ((on_demand * fraction).round() as u64).max(1)
    }

    /// Is hour `hour` a spike above `bid_centi_cents_hour` for this type?
    pub fn interrupts_at(&self, api_name: &str, bid_centi_cents_hour: u64, hour: u64) -> bool {
        self.price_centi_cents_hour(api_name, hour) > bid_centi_cents_hour
    }

    /// First market-driven interruption strictly after `t0_s` and at or
    /// before `t1_s`: the earliest hour boundary in `(t0, t1]` whose
    /// price exceeds the bid. (An instance running at `t0` already
    /// survived the hour containing `t0`.)
    pub fn first_interruption(
        &self,
        api_name: &str,
        bid_centi_cents_hour: u64,
        t0_s: f64,
        t1_s: f64,
    ) -> Option<f64> {
        if t1_s <= t0_s {
            return None;
        }
        let mut boundary = (Self::hour_index(t0_s) + 1) as f64 * 3600.0;
        while boundary <= t1_s {
            let hour = Self::hour_index(boundary);
            if self.interrupts_at(api_name, bid_centi_cents_hour, hour) {
                return Some(boundary);
            }
            boundary += 3600.0;
        }
        None
    }

    /// Total spot charge for an instance that ran `[start_s, end_s)`:
    /// every started hour at that hour's price **capped at the bid** —
    /// a spot customer never pays above their bid, so capacity that
    /// happens to survive a spike (only busy fleet clusters are
    /// scanned for reclaims) is not billed spike prices. The final
    /// partial hour is free when the provider interrupted the
    /// instance; a self-terminated instance pays at least one hour.
    pub fn cost_centi_cents(
        &self,
        api_name: &str,
        start_s: f64,
        end_s: f64,
        interrupted: bool,
        bid_centi_cents_hour: u64,
    ) -> u64 {
        let dur = (end_s - start_s).max(0.0);
        let full_hours = (dur / 3600.0).floor() as u64;
        let partial = dur - full_hours as f64 * 3600.0 > 1e-9;
        let billed = if interrupted {
            full_hours
        } else {
            (full_hours + u64::from(partial)).max(1)
        };
        let h0 = Self::hour_index(start_s);
        (0..billed)
            .map(|i| {
                self.price_centi_cents_hour(api_name, h0 + i)
                    .min(bid_centi_cents_hour)
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn price_path_is_deterministic_and_order_independent() {
        let m = SpotMarket::default();
        let a: Vec<u64> = (0..50).map(|h| m.price_centi_cents_hour("m2.2xlarge", h)).collect();
        let b: Vec<u64> = (0..50).rev().map(|h| m.price_centi_cents_hour("m2.2xlarge", h)).collect();
        let b_fwd: Vec<u64> = b.into_iter().rev().collect();
        assert_eq!(a, b_fwd);
        // Different seeds give different paths.
        let other = SpotMarket::new(99);
        let c: Vec<u64> = (0..50).map(|h| other.price_centi_cents_hour("m2.2xlarge", h)).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn mean_price_is_a_deep_discount() {
        let m = SpotMarket::default();
        let on_demand = 90.0 * 100.0; // m2.2xlarge centi-cents/hour
        let n = 2000u64;
        let total: u64 = (0..n).map(|h| m.price_centi_cents_hour("m2.2xlarge", h)).sum();
        let mean = total as f64 / n as f64;
        // ~0.30 of on-demand plus a small spike contribution.
        assert!(mean < 0.5 * on_demand, "mean spot {mean} vs od {on_demand}");
        assert!(mean > 0.15 * on_demand, "mean spot {mean} suspiciously low");
    }

    #[test]
    fn spikes_exist_and_interrupt_on_demand_bids() {
        let m = SpotMarket::default();
        let bid = 90 * 100; // bid = on-demand price
        let spikes = (0..2000).filter(|&h| m.interrupts_at("m2.2xlarge", bid, h)).count();
        // spike_prob = 4%: expect roughly 80/2000, generously bounded.
        assert!(spikes > 20 && spikes < 250, "spikes = {spikes}");
    }

    #[test]
    fn first_interruption_is_an_hour_boundary_in_window() {
        let m = SpotMarket::default();
        let bid = 90 * 100;
        let t = m.first_interruption("m2.2xlarge", bid, 0.0, 3600.0 * 2000.0).unwrap();
        assert!(t > 0.0 && t % 3600.0 == 0.0);
        assert!(m.interrupts_at("m2.2xlarge", bid, SpotMarket::hour_index(t)));
        // No interruption in an empty window.
        assert_eq!(m.first_interruption("m2.2xlarge", bid, t, t), None);
        // An unbeatable bid is never interrupted.
        assert_eq!(
            m.first_interruption("m2.2xlarge", u64::MAX, 0.0, 3600.0 * 500.0),
            None
        );
    }

    #[test]
    fn spot_hours_cost_less_than_on_demand() {
        let m = SpotMarket::default();
        let dur = 3600.0 * 48.0;
        let bid = 180 * 100; // bid = on-demand rate
        let spot = m.cost_centi_cents("m2.4xlarge", 0.0, dur, false, bid);
        let on_demand = 48 * 180 * 100;
        assert!(spot < on_demand / 2, "spot {spot} vs on-demand {on_demand}");
    }

    #[test]
    fn interrupted_partial_hour_is_free() {
        let m = SpotMarket::default();
        let bid = 90 * 100;
        // 90 minutes, provider-interrupted: only the first (full) hour bills.
        let a = m.cost_centi_cents("m2.2xlarge", 0.0, 5400.0, true, bid);
        assert_eq!(a, m.price_centi_cents_hour("m2.2xlarge", 0).min(bid));
        // Interrupted inside the first hour: free.
        assert_eq!(m.cost_centi_cents("m2.2xlarge", 0.0, 1800.0, true, bid), 0);
        // Self-terminated pays the started hour (minimum one).
        let b = m.cost_centi_cents("m2.2xlarge", 0.0, 1800.0, false, bid);
        assert_eq!(b, m.price_centi_cents_hour("m2.2xlarge", 0).min(bid));
        assert!(m.cost_centi_cents("m2.2xlarge", 100.0, 100.0, false, bid) > 0);
    }

    #[test]
    fn billed_hours_never_exceed_the_bid() {
        // A market that spikes every hour: the customer still pays at
        // most their bid per hour (they would have been reclaimed, not
        // gouged — see the doc on cost_centi_cents).
        let m = SpotMarket {
            spike_prob: 1.0,
            ..SpotMarket::default()
        };
        let bid = 90 * 100;
        let cost = m.cost_centi_cents("m2.2xlarge", 0.0, 10.0 * 3600.0, false, bid);
        assert_eq!(cost, 10 * bid);
    }
}
