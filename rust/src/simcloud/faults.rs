//! Deterministic failure injection for tests and benches.
//!
//! Real EC2 launches fail, volumes wedge, and transfers drop. Tests arm
//! specific faults; the simulated cloud consumes them at the next
//! matching operation, so failure handling in the coordinator is
//! exercised without nondeterminism.

#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Fail the next `n` instance launches (insufficient capacity).
    pub boot_failures: usize,
    /// Fail the next `n` volume attachments.
    pub attach_failures: usize,
    /// Interrupt the next `n` data transfers mid-flight (the transfer
    /// must be retried; rsync then only re-sends missing blocks).
    pub transfer_interrupts: usize,
    /// Fail the next `n` script executions on a worker.
    pub exec_failures: usize,
    /// Reclaim the spot capacity under the next `n` job slices: the
    /// jobs scheduler delivers each as a spot interruption on the
    /// virtual timeline (independent of the market's own price path).
    pub spot_interruptions: usize,
    /// Armed spot interruptions hold their fire until this virtual
    /// time (benches use it to land a reclaim after a checkpoint has
    /// been committed rather than mid-first-slice). 0.0 = fire in the
    /// first scan window, the historical behaviour.
    pub spot_interrupt_not_before_s: f64,
}

impl FaultPlan {
    pub fn none() -> Self {
        Self::default()
    }

    /// Consume one armed boot failure, if any.
    pub fn take_boot_failure(&mut self) -> bool {
        take(&mut self.boot_failures)
    }
    pub fn take_attach_failure(&mut self) -> bool {
        take(&mut self.attach_failures)
    }
    pub fn take_transfer_interrupt(&mut self) -> bool {
        take(&mut self.transfer_interrupts)
    }
    pub fn take_exec_failure(&mut self) -> bool {
        take(&mut self.exec_failures)
    }
    pub fn take_spot_interruption(&mut self) -> bool {
        take(&mut self.spot_interruptions)
    }
}

fn take(n: &mut usize) -> bool {
    if *n > 0 {
        *n -= 1;
        true
    } else {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn faults_consume_once() {
        let mut f = FaultPlan {
            boot_failures: 2,
            ..FaultPlan::none()
        };
        assert!(f.take_boot_failure());
        assert!(f.take_boot_failure());
        assert!(!f.take_boot_failure());
        assert!(!f.take_attach_failure());
    }
}
