//! Usage-based billing, EC2-2012 style: instance-hours are billed in
//! whole-hour increments from launch to termination; EBS is billed per
//! GiB-month (pro-rated here per virtual hour); the storage plane adds
//! S3 request + storage charges and a metered WAN link (per-GiB data
//! transfer — LAN traffic inside the cloud is free, which is exactly
//! why cluster-resident checkpoints are worth having).
//!
//! Sub-cent amounts are carried in **centi-cents** per line item and
//! rounded exactly once, in [`Ledger::total_cents`]. The earlier
//! per-item `/ 100` truncation meant any volume-hour total under 100
//! centi-cents billed 0¢ — a fleet of small volumes never cost
//! anything, no matter how many accumulated.
//!
//! Every line item carries the **analyst id** that was active on the
//! ledger when the charge was booked (empty = platform/untagged), so
//! the bill can be filtered per tenant, and [`Ledger::invoice_for`]
//! folds a tenant's items into an itemised [`Invoice`] whose category
//! totals reconcile *exactly* (centi-cent equality) with
//! [`Ledger::total_centi_cents_for`].
//!
//! This module also hosts [`PriceForecast`], the *predictive* side of
//! pricing: deterministic rolling-window statistics over the spot
//! market's price path that the deadline scheduler and the autoscaler
//! price their decisions against.

use super::network::Link;
use super::spot::SpotMarket;
use crate::util::json::Json;

/// Deterministic spot-price forecast: rolling-window statistics over
/// the market's seeded price path.
///
/// The spot path is a pure function of `(seed, type, hour)` (see
/// [`SpotMarket`]), so a trailing window ending at the query hour is
/// both an honest "observed history" forecast *and* perfectly
/// reproducible: every component that consults it — the deadline-aware
/// `JobScheduler` choosing spot vs on-demand per slice, the
/// `Autoscaler` pricing its bids — sees the same numbers in the same
/// simulated world. The expected price is the window mean (never below
/// the window's observed floor, never below one centi-cent); the
/// interruption likelihood is the fraction of window hours whose price
/// would have exceeded a given bid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PriceForecast {
    /// Trailing hours aggregated per query (>= 1; default 24).
    pub window_hours: u64,
}

impl Default for PriceForecast {
    fn default() -> Self {
        Self { window_hours: 24 }
    }
}

impl PriceForecast {
    /// A forecast over a trailing window of `window_hours` (clamped to
    /// at least one hour).
    pub fn new(window_hours: u64) -> Self {
        Self {
            window_hours: window_hours.max(1),
        }
    }

    /// The window of hour indices the statistics aggregate for a query
    /// at `hour`: the trailing `window_hours` ending at `hour` once
    /// that much history exists. Before then the path's first
    /// `window_hours` serve as the warm-up sample — the simulated
    /// stand-in for the price history an operator brings to a fresh
    /// session; without it a query at hour 0 would "forecast" from a
    /// single observation and flap between certainties.
    fn window(&self, hour: u64) -> std::ops::RangeInclusive<u64> {
        let w = self.window_hours.max(1);
        if hour < w {
            0..=w - 1
        } else {
            hour - w + 1..=hour
        }
    }

    /// Expected spot price of one `api_name` instance-hour in
    /// centi-cents: the mean over the trailing window ending at
    /// `hour`. Always >= the window's observed floor (a mean cannot
    /// undercut its minimum) and >= 1.
    pub fn expected_price_centi_cents(
        &self,
        market: &SpotMarket,
        api_name: &str,
        hour: u64,
    ) -> u64 {
        let mut sum: u64 = 0;
        let mut n: u64 = 0;
        for h in self.window(hour) {
            sum += market.price_centi_cents_hour(api_name, h);
            n += 1;
        }
        ((sum as f64 / n.max(1) as f64).round() as u64).max(1)
    }

    /// Cheapest hour in the trailing window — the "spot floor" the
    /// expected price can never undercut.
    pub fn floor_centi_cents(&self, market: &SpotMarket, api_name: &str, hour: u64) -> u64 {
        self.window(hour)
            .map(|h| market.price_centi_cents_hour(api_name, h))
            .min()
            .unwrap_or(1)
            .max(1)
    }

    /// Likelihood in `[0, 1]` that one hour reclaims capacity bid at
    /// `bid_centi_cents_hour`: the fraction of window hours whose
    /// price exceeded the bid.
    pub fn interruption_likelihood(
        &self,
        market: &SpotMarket,
        api_name: &str,
        bid_centi_cents_hour: u64,
        hour: u64,
    ) -> f64 {
        let mut hit: u64 = 0;
        let mut n: u64 = 0;
        for h in self.window(hour) {
            if market.interrupts_at(api_name, bid_centi_cents_hour, h) {
                hit += 1;
            }
            n += 1;
        }
        hit as f64 / n.max(1) as f64
    }

}

/// One billed line item. Amounts are stored in hundredths of a cent so
/// small EBS charges are not truncated away item by item.
#[derive(Clone, Debug, PartialEq)]
pub struct LineItem {
    pub resource_id: String,
    pub detail: String,
    pub centi_cents: u64,
    /// Tenant the charge is attributed to ("" = platform/untagged).
    pub analyst: String,
}

impl LineItem {
    /// Whole cents of this item alone (display only — totals must sum
    /// centi-cents first, see [`Ledger::total_cents`]).
    pub fn cents(&self) -> u64 {
        self.centi_cents / 100
    }
}

/// Account ledger accumulating charges over the simulation.
#[derive(Clone, Debug, Default)]
pub struct Ledger {
    items: Vec<LineItem>,
    /// Tenant stamped onto subsequently booked items.
    analyst: String,
}

/// EBS price per GiB-hour in hundredths of a cent (≈ $0.10/GiB-month).
const EBS_CENTI_CENTS_PER_GB_HOUR: u64 = 1;
/// S3/snapshot storage per GiB-hour in hundredths of a cent.
const S3_CENTI_CENTS_PER_GB_HOUR: u64 = 1;
/// Flat per-request S3 charge (PUT/GET/DEL), hundredths of a cent.
const S3_REQUEST_CENTI_CENTS: u64 = 1;
/// Metered WAN transfer, hundredths of a cent per GiB (≈ $0.12/GiB,
/// the 2012 Internet data-transfer rate). LAN transfer is free.
const WAN_CENTI_CENTS_PER_GB: u64 = 1200;
/// Flat per-request charge on every function invocation, hundredths
/// of a cent (≈ $0.20 per million requests).
pub const FN_REQUEST_CENTI_CENTS: u64 = 1;
/// Function compute rate: MB-milliseconds of execution per hundredth
/// of a cent (≈ $0.06 per GB-hour, rounded up per invocation).
pub const FN_MB_MS_PER_CENTI_CENT: u64 = 6_000_000;
/// Warm idle memory rate: MB-milliseconds of pooled idle time per
/// hundredth of a cent (≈ 30x cheaper than executing — keeping a
/// container warm costs far less than running it, which is the whole
/// point of the pool). Floored, so short windows book nothing.
pub const FN_IDLE_MB_MS_PER_CENTI_CENT: u64 = 200_000_000;

impl Ledger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the tenant subsequent charges are attributed to ("" clears).
    pub fn set_analyst(&mut self, analyst: &str) {
        self.analyst = analyst.to_string();
    }

    pub fn analyst(&self) -> &str {
        &self.analyst
    }

    fn push(&mut self, resource_id: String, detail: String, centi_cents: u64) {
        self.items.push(LineItem {
            resource_id,
            detail,
            centi_cents,
            analyst: self.analyst.clone(),
        });
    }

    /// Bill an instance that ran from `start_s` to `end_s` virtual time.
    pub fn bill_instance(
        &mut self,
        id: &str,
        api_name: &str,
        price_cents_hour: u64,
        start_s: f64,
        end_s: f64,
    ) {
        let hours = ((end_s - start_s).max(0.0) / 3600.0).ceil().max(1.0) as u64;
        self.push(
            id.to_string(),
            format!("{api_name} x {hours} instance-hour(s)"),
            hours * price_cents_hour * 100,
        );
    }

    /// Bill a volume's storage for its lifetime. The centi-cent amount
    /// is kept exact; rounding happens once at the total.
    pub fn bill_volume(&mut self, id: &str, size_gb: f64, start_s: f64, end_s: f64) {
        let hours = ((end_s - start_s).max(0.0) / 3600.0).ceil().max(1.0) as u64;
        let centi_cents = (size_gb.ceil() as u64) * hours * EBS_CENTI_CENTS_PER_GB_HOUR;
        self.push(
            id.to_string(),
            format!("EBS {size_gb:.0} GiB x {hours} hour(s)"),
            centi_cents,
        );
    }

    /// Bill a snapshot's S3-backed storage for its lifetime.
    pub fn bill_snapshot_storage(&mut self, id: &str, size_gb: f64, start_s: f64, end_s: f64) {
        let hours = ((end_s - start_s).max(0.0) / 3600.0).ceil().max(1.0) as u64;
        let centi_cents = (size_gb.ceil() as u64) * hours * S3_CENTI_CENTS_PER_GB_HOUR;
        self.push(
            id.to_string(),
            format!("snapshot {size_gb:.0} GiB x {hours} hour(s)"),
            centi_cents,
        );
    }

    /// Bill one S3 API request (PUT/GET/DEL).
    pub fn bill_s3_request(&mut self, id: &str, op: &str) {
        self.push(id.to_string(), format!("S3 {op} request"), S3_REQUEST_CENTI_CENTS);
    }

    /// Bill an object's storage for its lifetime (booked at delete,
    /// like volumes).
    pub fn bill_s3_storage(&mut self, id: &str, bytes: u64, start_s: f64, end_s: f64) {
        let hours = ((end_s - start_s).max(0.0) / 3600.0).ceil().max(1.0) as u64;
        let gb = (bytes as f64 / (1024.0 * 1024.0 * 1024.0)).ceil().max(1.0) as u64;
        self.push(
            id.to_string(),
            format!("S3 storage {bytes} B x {hours} hour(s)"),
            gb * hours * S3_CENTI_CENTS_PER_GB_HOUR,
        );
    }

    /// Bill the bytes a transfer put on a link: WAN traffic is metered
    /// per GiB (any nonzero transfer books at least one centi-cent);
    /// LAN traffic inside the cloud is free and books nothing. This is
    /// the single billing path every transfer — project sync, result
    /// gather, checkpoint shipment — goes through.
    pub fn bill_data_transfer(&mut self, id: &str, bytes: u64, link: Link) {
        if bytes == 0 || link == Link::Lan {
            return;
        }
        let gb = bytes as f64 / (1024.0 * 1024.0 * 1024.0);
        let centi_cents = (gb * WAN_CENTI_CENTS_PER_GB as f64).ceil().max(1.0) as u64;
        self.push(
            id.to_string(),
            format!("WAN transfer {bytes} B"),
            centi_cents,
        );
    }

    /// Bill one function invocation: a flat request charge plus
    /// MB-ms compute, rounded up per invocation. Returns the exact
    /// centi-cents booked so callers (telemetry, the dispatch digest)
    /// carry the same number the invoice will fold.
    pub fn bill_fn_invocation(
        &mut self,
        id: &str,
        fname: &str,
        mem_mb: u64,
        duration_ms: u64,
    ) -> u64 {
        let mb_ms = mem_mb * duration_ms;
        let compute_cc = mb_ms.div_ceil(FN_MB_MS_PER_CENTI_CENT);
        let cc = FN_REQUEST_CENTI_CENTS + compute_cc;
        self.push(
            id.to_string(),
            format!("fn invoke {fname}: {mem_mb} MB x {duration_ms} ms"),
            cc,
        );
        cc
    }

    /// Bill a warm container's idle memory window, floored — a window
    /// too short to reach one centi-cent books nothing (and no line
    /// item). Returns the exact centi-cents booked.
    pub fn bill_fn_idle(&mut self, id: &str, mem_mb: u64, idle_ms: u64) -> u64 {
        let cc = (mem_mb * idle_ms) / FN_IDLE_MB_MS_PER_CENTI_CENT;
        if cc > 0 {
            self.push(
                id.to_string(),
                format!("fn idle: {mem_mb} MB x {idle_ms} ms"),
                cc,
            );
        }
        cc
    }

    /// Bill a spot instance's usage. The amount is pre-computed by the
    /// market (`SpotMarket::cost_centi_cents` sums each started hour at
    /// that hour's price); this records it with a detail line that
    /// distinguishes provider interruptions from clean terminations.
    pub fn bill_spot_instance(
        &mut self,
        id: &str,
        api_name: &str,
        centi_cents: u64,
        interrupted: bool,
    ) {
        let detail = if interrupted {
            format!("{api_name} spot (interrupted, partial hour free)")
        } else {
            format!("{api_name} spot")
        };
        self.push(id.to_string(), detail, centi_cents);
    }

    /// Re-book a persisted line item verbatim (session restore), with
    /// its original tenant attribution.
    pub fn push_raw_as(
        &mut self,
        resource_id: &str,
        detail: &str,
        centi_cents: u64,
        analyst: &str,
    ) {
        self.items.push(LineItem {
            resource_id: resource_id.to_string(),
            detail: detail.to_string(),
            centi_cents,
            analyst: analyst.to_string(),
        });
    }

    /// Re-book a persisted line item under the current tenant context.
    pub fn push_raw(&mut self, resource_id: &str, detail: &str, centi_cents: u64) {
        let analyst = self.analyst.clone();
        self.push_raw_as(resource_id, detail, centi_cents, &analyst);
    }

    /// Total in whole cents: centi-cents are summed exactly and rounded
    /// once here, so many sub-cent items still add up to real money.
    pub fn total_cents(&self) -> u64 {
        self.total_centi_cents() / 100
    }

    /// Exact total in hundredths of a cent.
    pub fn total_centi_cents(&self) -> u64 {
        self.items.iter().map(|i| i.centi_cents).sum()
    }

    /// Exact metered-WAN-transfer total — the line items booked by
    /// [`Ledger::bill_data_transfer`]. Lives here, next to the detail
    /// format it matches, so benches and tests share one definition.
    pub fn total_wan_transfer_centi_cents(&self) -> u64 {
        self.items
            .iter()
            .filter(|i| i.detail.starts_with("WAN transfer"))
            .map(|i| i.centi_cents)
            .sum()
    }

    /// Exact per-tenant total ("" = platform/untagged items).
    pub fn total_centi_cents_for(&self, analyst: &str) -> u64 {
        self.items
            .iter()
            .filter(|i| i.analyst == analyst)
            .map(|i| i.centi_cents)
            .sum()
    }

    /// Distinct analyst ids with at least one line item (excluding "").
    pub fn analysts(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for i in &self.items {
            if !i.analyst.is_empty() && !out.contains(&i.analyst) {
                out.push(i.analyst.clone());
            }
        }
        out.sort();
        out
    }

    pub fn items(&self) -> &[LineItem] {
        &self.items
    }

    pub fn total_dollars(&self) -> f64 {
        self.total_centi_cents() as f64 / 10_000.0
    }

    /// Fold one tenant's line items into an itemised [`Invoice`]
    /// (`ec2invoice`). Every item lands in **exactly one** category —
    /// anything the detail patterns below do not recognise goes to
    /// `other_cc` — so the invoice total reconciles exactly with
    /// [`Ledger::total_centi_cents_for`], by construction. The
    /// patterns match the detail strings the `bill_*` methods above
    /// write; keep the two in sync.
    pub fn invoice_for(&self, analyst: &str) -> Invoice {
        let mut inv = Invoice {
            analyst: analyst.to_string(),
            ..Default::default()
        };
        for item in self.items.iter().filter(|i| i.analyst == analyst) {
            inv.line_items += 1;
            let d = item.detail.as_str();
            let cc = item.centi_cents;
            if d.contains("instance-hour(s)") {
                inv.ondemand_instance_cc += cc; // bill_instance
            } else if d.contains(" spot") {
                inv.spot_instance_cc += cc; // bill_spot_instance
            } else if d.starts_with("EBS ") {
                inv.ebs_cc += cc; // bill_volume
            } else if d.starts_with("snapshot ") {
                inv.snapshot_cc += cc; // bill_snapshot_storage
            } else if d.starts_with("S3 storage") {
                inv.s3_storage_cc += cc; // bill_s3_storage
            } else if d.starts_with("S3 ") && d.ends_with("request") {
                inv.s3_request_cc += cc; // bill_s3_request
            } else if d.starts_with("WAN transfer") {
                inv.wan_transfer_cc += cc; // bill_data_transfer
            } else if d.starts_with("fn invoke") {
                inv.fn_invoke_cc += cc; // bill_fn_invocation
            } else if d.starts_with("fn idle") {
                inv.fn_pool_cc += cc; // bill_fn_idle
            } else {
                inv.other_cc += cc;
            }
        }
        inv
    }
}

/// One tenant's itemised bill: the ledger's line items folded into
/// billing categories (instance-hours split spot vs on-demand,
/// EBS/snapshot/S3 GiB-hours, S3 requests, metered WAN transfer).
/// Amounts are exact centi-cents; [`Invoice::total_centi_cents`] is
/// the sum of every category and reconciles exactly with
/// [`Ledger::total_centi_cents_for`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Invoice {
    /// Tenant the invoice is for ("" = platform/untagged).
    pub analyst: String,
    /// On-demand instance-hours.
    pub ondemand_instance_cc: u64,
    /// Spot instance usage (per started hour at that hour's price).
    pub spot_instance_cc: u64,
    /// EBS volume GiB-hours.
    pub ebs_cc: u64,
    /// EBS snapshot (S3-backed) GiB-hours.
    pub snapshot_cc: u64,
    /// S3 API requests (PUT/GET/DEL).
    pub s3_request_cc: u64,
    /// S3 object storage GiB-hours.
    pub s3_storage_cc: u64,
    /// Metered WAN data transfer.
    pub wan_transfer_cc: u64,
    /// Function invocations (request + MB-ms compute).
    pub fn_invoke_cc: u64,
    /// Warm function pool idle memory.
    pub fn_pool_cc: u64,
    /// Line items no category pattern recognised.
    pub other_cc: u64,
    /// How many ledger line items the invoice folds.
    pub line_items: usize,
}

impl Invoice {
    /// Exact total in centi-cents (the sum of every category).
    pub fn total_centi_cents(&self) -> u64 {
        self.ondemand_instance_cc
            + self.spot_instance_cc
            + self.ebs_cc
            + self.snapshot_cc
            + self.s3_request_cc
            + self.s3_storage_cc
            + self.wan_transfer_cc
            + self.fn_invoke_cc
            + self.fn_pool_cc
            + self.other_cc
    }

    /// Human-readable rendering (`ec2invoice`).
    pub fn lines(&self) -> Vec<String> {
        fn row(label: &str, cc: u64) -> String {
            format!("  {:<26} {:>12} cc  (${:.4})", label, cc, cc as f64 / 10_000.0)
        }
        let who = if self.analyst.is_empty() {
            "(platform)"
        } else {
            self.analyst.as_str()
        };
        let mut out = vec![format!(
            "invoice for tenant '{}' — {} line item(s)",
            who, self.line_items
        )];
        out.push(row("on-demand instance-hours", self.ondemand_instance_cc));
        out.push(row("spot instance usage", self.spot_instance_cc));
        out.push(row("EBS GiB-hours", self.ebs_cc));
        out.push(row("snapshot GiB-hours", self.snapshot_cc));
        out.push(row("S3 requests", self.s3_request_cc));
        out.push(row("S3 storage GiB-hours", self.s3_storage_cc));
        out.push(row("WAN transfer", self.wan_transfer_cc));
        if self.fn_invoke_cc > 0 {
            out.push(row("fn invocations", self.fn_invoke_cc));
        }
        if self.fn_pool_cc > 0 {
            out.push(row("fn pool idle memory", self.fn_pool_cc));
        }
        if self.other_cc > 0 {
            out.push(row("other", self.other_cc));
        }
        out.push(row("total", self.total_centi_cents()));
        out
    }

    /// Machine-readable rendering (`ec2invoice -json`).
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("analyst", Json::str(&self.analyst)),
            ("line_items", Json::num(self.line_items as f64)),
            (
                "ondemand_instance_cc",
                Json::num(self.ondemand_instance_cc as f64),
            ),
            ("spot_instance_cc", Json::num(self.spot_instance_cc as f64)),
            ("ebs_cc", Json::num(self.ebs_cc as f64)),
            ("snapshot_cc", Json::num(self.snapshot_cc as f64)),
            ("s3_request_cc", Json::num(self.s3_request_cc as f64)),
            ("s3_storage_cc", Json::num(self.s3_storage_cc as f64)),
            ("wan_transfer_cc", Json::num(self.wan_transfer_cc as f64)),
            ("fn_invoke_cc", Json::num(self.fn_invoke_cc as f64)),
            ("fn_pool_cc", Json::num(self.fn_pool_cc as f64)),
            ("other_cc", Json::num(self.other_cc as f64)),
            (
                "total_centi_cents",
                Json::num(self.total_centi_cents() as f64),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partial_hours_round_up() {
        let mut l = Ledger::new();
        // 90 virtual minutes of an m2.2xlarge ($0.90/h) → 2 hours → $1.80.
        l.bill_instance("i-1", "m2.2xlarge", 90, 0.0, 5400.0);
        assert_eq!(l.total_cents(), 180);
    }

    #[test]
    fn minimum_one_hour() {
        let mut l = Ledger::new();
        l.bill_instance("i-1", "m2.4xlarge", 180, 100.0, 160.0);
        assert_eq!(l.total_cents(), 180);
    }

    #[test]
    fn paper_cluster_d_cost_shape() {
        // Cluster D = 16 x m2.2xlarge for one hour ≈ $14.40.
        let mut l = Ledger::new();
        for i in 0..16 {
            l.bill_instance(&format!("i-{i}"), "m2.2xlarge", 90, 0.0, 3000.0);
        }
        assert_eq!(l.total_dollars(), 14.40);
    }

    #[test]
    fn volume_billing_is_cheap() {
        let mut l = Ledger::new();
        l.bill_volume("vol-1", 100.0, 0.0, 3600.0);
        assert!(l.total_cents() <= 1);
    }

    #[test]
    fn small_volumes_accumulate_instead_of_truncating_to_zero() {
        // 250 one-GiB volume-hours = 250 centi-cents. The old per-item
        // `/ 100` truncation billed each as 0¢ and the fleet rode free;
        // the ledger must now see 2 whole cents.
        let mut l = Ledger::new();
        for i in 0..250 {
            l.bill_volume(&format!("vol-{i}"), 1.0, 0.0, 3600.0);
        }
        assert_eq!(l.total_centi_cents(), 250);
        assert_eq!(l.total_cents(), 2);
        // Per-item display still shows sub-cent items as 0¢.
        assert_eq!(l.items()[0].cents(), 0);
    }

    #[test]
    fn restore_preserves_exact_centi_cents() {
        let mut a = Ledger::new();
        a.bill_volume("vol-1", 3.0, 0.0, 7200.0); // 6 centi-cents
        a.bill_instance("i-1", "m1.large", 32, 0.0, 100.0);
        let mut b = Ledger::new();
        for item in a.items() {
            b.push_raw_as(&item.resource_id, &item.detail, item.centi_cents, &item.analyst);
        }
        assert_eq!(a.total_centi_cents(), b.total_centi_cents());
        assert_eq!(a.items(), b.items());
    }

    #[test]
    fn wan_transfer_is_metered_and_lan_is_free() {
        let mut l = Ledger::new();
        l.bill_data_transfer("sync", 1024 * 1024 * 1024, Link::Wan);
        assert_eq!(l.total_centi_cents(), 1200); // 12 cents per GiB
        l.bill_data_transfer("nfs", 10 * 1024 * 1024 * 1024, Link::Lan);
        assert_eq!(l.total_centi_cents(), 1200, "LAN bytes must be free");
        // Any nonzero WAN transfer books at least one centi-cent.
        l.bill_data_transfer("ckpt", 512, Link::Wan);
        assert_eq!(l.total_centi_cents(), 1201);
        l.bill_data_transfer("noop", 0, Link::Wan);
        assert_eq!(l.total_centi_cents(), 1201);
    }

    #[test]
    fn line_items_carry_the_active_analyst() {
        let mut l = Ledger::new();
        l.bill_instance("i-1", "m2.2xlarge", 90, 0.0, 3600.0);
        l.set_analyst("alice");
        l.bill_instance("i-2", "m2.2xlarge", 90, 0.0, 3600.0);
        l.bill_s3_request("s3://b/k", "PUT");
        l.set_analyst("bob");
        l.bill_volume("vol-1", 8.0, 0.0, 3600.0);
        l.set_analyst("");
        assert_eq!(l.total_centi_cents_for("alice"), 9000 + 1);
        assert_eq!(l.total_centi_cents_for("bob"), 8);
        assert_eq!(l.total_centi_cents_for(""), 9000);
        assert_eq!(
            l.total_centi_cents(),
            l.total_centi_cents_for("alice")
                + l.total_centi_cents_for("bob")
                + l.total_centi_cents_for("")
        );
        assert_eq!(l.analysts(), vec!["alice".to_string(), "bob".to_string()]);
    }

    #[test]
    fn invoice_reconciles_exactly_and_categorises_every_item() {
        let mut l = Ledger::new();
        l.set_analyst("alice");
        l.bill_instance("i-1", "m2.2xlarge", 90, 0.0, 3600.0); // 9000 cc
        l.bill_spot_instance("i-2", "m2.2xlarge", 1234, true);
        l.bill_volume("vol-1", 8.0, 0.0, 3600.0); // 8 cc
        l.bill_snapshot_storage("snap-1", 4.0, 0.0, 3600.0); // 4 cc
        l.bill_s3_request("s3://b/k", "PUT"); // 1 cc
        l.bill_s3_storage("s3://b/k", 1024, 0.0, 3600.0); // 1 cc
        l.bill_data_transfer("sync", 1024 * 1024 * 1024, Link::Wan); // 1200 cc
        l.push_raw("legacy", "some unrecognised detail", 77);
        l.set_analyst("bob");
        l.bill_instance("i-3", "m1.large", 32, 0.0, 3600.0);
        l.set_analyst("");
        l.bill_volume("vol-2", 1.0, 0.0, 3600.0);

        for tenant in ["alice", "bob", ""] {
            let inv = l.invoice_for(tenant);
            assert_eq!(
                inv.total_centi_cents(),
                l.total_centi_cents_for(tenant),
                "invoice for '{tenant}' must reconcile exactly with the ledger"
            );
        }
        let alice = l.invoice_for("alice");
        assert_eq!(alice.ondemand_instance_cc, 9000);
        assert_eq!(alice.spot_instance_cc, 1234);
        assert_eq!(alice.ebs_cc, 8);
        assert_eq!(alice.snapshot_cc, 4);
        assert_eq!(alice.s3_request_cc, 1);
        assert_eq!(alice.s3_storage_cc, 1);
        assert_eq!(alice.wan_transfer_cc, 1200);
        assert_eq!(alice.other_cc, 77, "unrecognised items must not be dropped");
        assert_eq!(alice.line_items, 8);
        // Rendering carries the exact total; JSON mirrors it.
        let total = alice.total_centi_cents();
        assert!(alice.lines().last().unwrap().contains(&total.to_string()));
        assert_eq!(
            alice.to_json().get("total_centi_cents").and_then(Json::as_u64),
            Some(total)
        );
        // A tenant with no charges gets a clean zero invoice.
        let ghost = l.invoice_for("carol");
        assert_eq!(ghost.total_centi_cents(), 0);
        assert_eq!(ghost.line_items, 0);
    }

    #[test]
    fn forecast_mean_sits_between_window_floor_and_ceiling() {
        let m = SpotMarket::default();
        let f = PriceForecast::default();
        for hour in [0u64, 23, 24, 500, 4999] {
            let e = f.expected_price_centi_cents(&m, "m2.2xlarge", hour);
            let floor = f.floor_centi_cents(&m, "m2.2xlarge", hour);
            // Same window the forecast uses (24 h, warm-up before
            // hour 24).
            let (lo, hi) = if hour < 24 { (0, 23) } else { (hour - 23, hour) };
            let ceil = (lo..=hi)
                .map(|h| m.price_centi_cents_hour("m2.2xlarge", h))
                .max()
                .unwrap();
            assert!(e >= floor, "hour {hour}: mean {e} under floor {floor}");
            assert!(e <= ceil, "hour {hour}: mean {e} over ceiling {ceil}");
        }
        // Warm-up: every query inside the first window sees the same
        // sample, so early decisions cannot flap between certainties.
        assert_eq!(
            f.expected_price_centi_cents(&m, "m2.2xlarge", 0),
            f.expected_price_centi_cents(&m, "m2.2xlarge", 23),
        );
    }

    #[test]
    fn forecast_interruption_likelihood_tracks_the_bid() {
        let m = SpotMarket::default();
        let f = PriceForecast::new(2000);
        let od = 90 * 100; // m2.2xlarge on-demand, centi-cents
        // An unbeatable bid is never at risk; a floor bid always is.
        assert_eq!(f.interruption_likelihood(&m, "m2.2xlarge", u64::MAX, 1999), 0.0);
        assert_eq!(f.interruption_likelihood(&m, "m2.2xlarge", 0, 1999), 1.0);
        // A bid at the on-demand rate is exposed to spikes only:
        // roughly spike_prob of the window.
        let p = f.interruption_likelihood(&m, "m2.2xlarge", od, 1999);
        assert!(p > 0.005 && p < 0.15, "spike fraction {p}");
    }

    #[test]
    fn forecast_expected_discount_is_deep() {
        // The paper-era market sits around 30% of on-demand; the
        // forecast must see that discount, not mistake spikes for the
        // norm.
        let m = SpotMarket::default();
        let f = PriceForecast::new(500);
        let od = 90 * 100; // m2.2xlarge on-demand, centi-cents
        let e = f.expected_price_centi_cents(&m, "m2.2xlarge", 499);
        let frac = e as f64 / od as f64;
        assert!(frac > 0.15 && frac < 0.6, "expected fraction {frac}");
    }

    #[test]
    fn s3_requests_and_storage_bill() {
        let mut l = Ledger::new();
        l.bill_s3_request("s3://b/k", "PUT");
        l.bill_s3_storage("s3://b/k", 1024, 0.0, 7200.0);
        // 1 request + (1 GiB minimum) x 2 hours.
        assert_eq!(l.total_centi_cents(), 1 + 2);
        l.bill_snapshot_storage("snap-1", 8.0, 0.0, 3600.0);
        assert_eq!(l.total_centi_cents(), 1 + 2 + 8);
    }
}
