//! Usage-based billing, EC2-2012 style: instance-hours are billed in
//! whole-hour increments from launch to termination; EBS is billed per
//! GiB-month (pro-rated here per virtual hour).
//!
//! Sub-cent amounts are carried in **centi-cents** per line item and
//! rounded exactly once, in [`Ledger::total_cents`]. The earlier
//! per-item `/ 100` truncation meant any volume-hour total under 100
//! centi-cents billed 0¢ — a fleet of small volumes never cost
//! anything, no matter how many accumulated.

/// One billed line item. Amounts are stored in hundredths of a cent so
/// small EBS charges are not truncated away item by item.
#[derive(Clone, Debug, PartialEq)]
pub struct LineItem {
    pub resource_id: String,
    pub detail: String,
    pub centi_cents: u64,
}

impl LineItem {
    /// Whole cents of this item alone (display only — totals must sum
    /// centi-cents first, see [`Ledger::total_cents`]).
    pub fn cents(&self) -> u64 {
        self.centi_cents / 100
    }
}

/// Account ledger accumulating charges over the simulation.
#[derive(Clone, Debug, Default)]
pub struct Ledger {
    items: Vec<LineItem>,
}

/// EBS price per GiB-hour in hundredths of a cent (≈ $0.10/GiB-month).
const EBS_CENTI_CENTS_PER_GB_HOUR: u64 = 1;

impl Ledger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bill an instance that ran from `start_s` to `end_s` virtual time.
    pub fn bill_instance(
        &mut self,
        id: &str,
        api_name: &str,
        price_cents_hour: u64,
        start_s: f64,
        end_s: f64,
    ) {
        let hours = ((end_s - start_s).max(0.0) / 3600.0).ceil().max(1.0) as u64;
        self.items.push(LineItem {
            resource_id: id.to_string(),
            detail: format!("{api_name} x {hours} instance-hour(s)"),
            centi_cents: hours * price_cents_hour * 100,
        });
    }

    /// Bill a volume's storage for its lifetime. The centi-cent amount
    /// is kept exact; rounding happens once at the total.
    pub fn bill_volume(&mut self, id: &str, size_gb: f64, start_s: f64, end_s: f64) {
        let hours = ((end_s - start_s).max(0.0) / 3600.0).ceil().max(1.0) as u64;
        let centi_cents = (size_gb.ceil() as u64) * hours * EBS_CENTI_CENTS_PER_GB_HOUR;
        self.items.push(LineItem {
            resource_id: id.to_string(),
            detail: format!("EBS {size_gb:.0} GiB x {hours} hour(s)"),
            centi_cents,
        });
    }

    /// Bill a spot instance's usage. The amount is pre-computed by the
    /// market (`SpotMarket::cost_centi_cents` sums each started hour at
    /// that hour's price); this records it with a detail line that
    /// distinguishes provider interruptions from clean terminations.
    pub fn bill_spot_instance(
        &mut self,
        id: &str,
        api_name: &str,
        centi_cents: u64,
        interrupted: bool,
    ) {
        let detail = if interrupted {
            format!("{api_name} spot (interrupted, partial hour free)")
        } else {
            format!("{api_name} spot")
        };
        self.items.push(LineItem {
            resource_id: id.to_string(),
            detail,
            centi_cents,
        });
    }

    /// Re-book a persisted line item verbatim (session restore).
    pub fn push_raw(&mut self, resource_id: &str, detail: &str, centi_cents: u64) {
        self.items.push(LineItem {
            resource_id: resource_id.to_string(),
            detail: detail.to_string(),
            centi_cents,
        });
    }

    /// Total in whole cents: centi-cents are summed exactly and rounded
    /// once here, so many sub-cent items still add up to real money.
    pub fn total_cents(&self) -> u64 {
        self.total_centi_cents() / 100
    }

    /// Exact total in hundredths of a cent.
    pub fn total_centi_cents(&self) -> u64 {
        self.items.iter().map(|i| i.centi_cents).sum()
    }

    pub fn items(&self) -> &[LineItem] {
        &self.items
    }

    pub fn total_dollars(&self) -> f64 {
        self.total_centi_cents() as f64 / 10_000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partial_hours_round_up() {
        let mut l = Ledger::new();
        // 90 virtual minutes of an m2.2xlarge ($0.90/h) → 2 hours → $1.80.
        l.bill_instance("i-1", "m2.2xlarge", 90, 0.0, 5400.0);
        assert_eq!(l.total_cents(), 180);
    }

    #[test]
    fn minimum_one_hour() {
        let mut l = Ledger::new();
        l.bill_instance("i-1", "m2.4xlarge", 180, 100.0, 160.0);
        assert_eq!(l.total_cents(), 180);
    }

    #[test]
    fn paper_cluster_d_cost_shape() {
        // Cluster D = 16 x m2.2xlarge for one hour ≈ $14.40.
        let mut l = Ledger::new();
        for i in 0..16 {
            l.bill_instance(&format!("i-{i}"), "m2.2xlarge", 90, 0.0, 3000.0);
        }
        assert_eq!(l.total_dollars(), 14.40);
    }

    #[test]
    fn volume_billing_is_cheap() {
        let mut l = Ledger::new();
        l.bill_volume("vol-1", 100.0, 0.0, 3600.0);
        assert!(l.total_cents() <= 1);
    }

    #[test]
    fn small_volumes_accumulate_instead_of_truncating_to_zero() {
        // 250 one-GiB volume-hours = 250 centi-cents. The old per-item
        // `/ 100` truncation billed each as 0¢ and the fleet rode free;
        // the ledger must now see 2 whole cents.
        let mut l = Ledger::new();
        for i in 0..250 {
            l.bill_volume(&format!("vol-{i}"), 1.0, 0.0, 3600.0);
        }
        assert_eq!(l.total_centi_cents(), 250);
        assert_eq!(l.total_cents(), 2);
        // Per-item display still shows sub-cent items as 0¢.
        assert_eq!(l.items()[0].cents(), 0);
    }

    #[test]
    fn restore_preserves_exact_centi_cents() {
        let mut a = Ledger::new();
        a.bill_volume("vol-1", 3.0, 0.0, 7200.0); // 6 centi-cents
        a.bill_instance("i-1", "m1.large", 32, 0.0, 100.0);
        let mut b = Ledger::new();
        for item in a.items() {
            b.push_raw(&item.resource_id, &item.detail, item.centi_cents);
        }
        assert_eq!(a.total_centi_cents(), b.total_centi_cents());
        assert_eq!(a.items(), b.items());
    }
}
