//! Usage-based billing, EC2-2012 style: instance-hours are billed in
//! whole-hour increments from launch to termination; EBS is billed per
//! GiB-month (pro-rated here per virtual hour).

/// One billed line item.
#[derive(Clone, Debug, PartialEq)]
pub struct LineItem {
    pub resource_id: String,
    pub detail: String,
    pub cents: u64,
}

/// Account ledger accumulating charges over the simulation.
#[derive(Clone, Debug, Default)]
pub struct Ledger {
    items: Vec<LineItem>,
}

/// EBS price per GiB-hour in hundredths of a cent (≈ $0.10/GiB-month).
const EBS_CENTI_CENTS_PER_GB_HOUR: u64 = 1;

impl Ledger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bill an instance that ran from `start_s` to `end_s` virtual time.
    pub fn bill_instance(
        &mut self,
        id: &str,
        api_name: &str,
        price_cents_hour: u64,
        start_s: f64,
        end_s: f64,
    ) {
        let hours = ((end_s - start_s).max(0.0) / 3600.0).ceil().max(1.0) as u64;
        self.items.push(LineItem {
            resource_id: id.to_string(),
            detail: format!("{api_name} x {hours} instance-hour(s)"),
            cents: hours * price_cents_hour,
        });
    }

    /// Bill a volume's storage for its lifetime.
    pub fn bill_volume(&mut self, id: &str, size_gb: f64, start_s: f64, end_s: f64) {
        let hours = ((end_s - start_s).max(0.0) / 3600.0).ceil().max(1.0) as u64;
        let centi_cents = (size_gb.ceil() as u64) * hours * EBS_CENTI_CENTS_PER_GB_HOUR;
        self.items.push(LineItem {
            resource_id: id.to_string(),
            detail: format!("EBS {size_gb:.0} GiB x {hours} hour(s)"),
            cents: centi_cents / 100,
        });
    }

    /// Re-book a persisted line item verbatim (session restore).
    pub fn push_raw(&mut self, resource_id: &str, detail: &str, cents: u64) {
        self.items.push(LineItem {
            resource_id: resource_id.to_string(),
            detail: detail.to_string(),
            cents,
        });
    }

    pub fn total_cents(&self) -> u64 {
        self.items.iter().map(|i| i.cents).sum()
    }

    pub fn items(&self) -> &[LineItem] {
        &self.items
    }

    pub fn total_dollars(&self) -> f64 {
        self.total_cents() as f64 / 100.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partial_hours_round_up() {
        let mut l = Ledger::new();
        // 90 virtual minutes of an m2.2xlarge ($0.90/h) → 2 hours → $1.80.
        l.bill_instance("i-1", "m2.2xlarge", 90, 0.0, 5400.0);
        assert_eq!(l.total_cents(), 180);
    }

    #[test]
    fn minimum_one_hour() {
        let mut l = Ledger::new();
        l.bill_instance("i-1", "m2.4xlarge", 180, 100.0, 160.0);
        assert_eq!(l.total_cents(), 180);
    }

    #[test]
    fn paper_cluster_d_cost_shape() {
        // Cluster D = 16 x m2.2xlarge for one hour ≈ $14.40.
        let mut l = Ledger::new();
        for i in 0..16 {
            l.bill_instance(&format!("i-{i}"), "m2.2xlarge", 90, 0.0, 3000.0);
        }
        assert_eq!(l.total_dollars(), 14.40);
    }

    #[test]
    fn volume_billing_is_cheap() {
        let mut l = Ledger::new();
        l.bill_volume("vol-1", 100.0, 0.0, 3600.0);
        assert!(l.total_cents() <= 1);
    }
}
