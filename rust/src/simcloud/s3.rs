//! Simulated Simple Storage Service — the cloud side of the storage
//! plane (paper §3.2.1: the Analyst's project and results live in the
//! cloud, so repeated runs pay LAN, not WAN).
//!
//! Objects are first-class: every `put` records a content digest
//! (FNV-1a over the bytes) and the virtual put time, so callers can
//! fingerprint cloud-side artifacts for cheap, correct re-execution
//! and the ledger can bill storage for an object's lifetime. Transfer
//! time and request/storage billing live on [`crate::simcloud::SimCloud`]
//! (`s3_put` / `s3_get` / `s3_delete`); this module is the pure store.

use std::collections::BTreeMap;

/// FNV-1a offset basis — seed of an incremental digest.
pub const DIGEST_SEED: u64 = 0xcbf2_9ce4_8422_2325;

/// Fold `bytes` into an incremental FNV-1a digest state. Chaining
/// calls is identical to digesting the concatenation, so callers can
/// stream multi-part content without materialising it.
pub fn digest_update(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// FNV-1a digest of a byte string — the content fingerprint recorded
/// on every stored object.
pub fn content_digest(bytes: &[u8]) -> u64 {
    digest_update(DIGEST_SEED, bytes)
}

/// One stored object: bytes plus the metadata the storage plane needs.
#[derive(Clone, Debug)]
pub struct S3Object {
    pub data: Vec<u8>,
    /// Content fingerprint (FNV-1a), recorded at put time.
    pub digest: u64,
    /// Virtual time of the put (storage billing runs from here).
    pub put_at_s: f64,
}

/// Bucket → key → object.
#[derive(Clone, Debug, Default)]
pub struct S3 {
    buckets: BTreeMap<String, BTreeMap<String, S3Object>>,
}

impl S3 {
    pub fn new() -> Self {
        Self::default()
    }

    /// Store an object at virtual time zero (tests / pre-seeded data).
    /// Returns the content digest.
    pub fn put(&mut self, bucket: &str, key: &str, data: Vec<u8>) -> u64 {
        self.put_at(bucket, key, data, 0.0)
    }

    /// Store an object, recording its digest and put time.
    pub fn put_at(&mut self, bucket: &str, key: &str, data: Vec<u8>, now_s: f64) -> u64 {
        let digest = content_digest(&data);
        self.buckets.entry(bucket.to_string()).or_default().insert(
            key.to_string(),
            S3Object {
                data,
                digest,
                put_at_s: now_s,
            },
        );
        digest
    }

    pub fn get(&self, bucket: &str, key: &str) -> Option<&[u8]> {
        self.object(bucket, key).map(|o| o.data.as_slice())
    }

    /// Full object (bytes + digest + put time).
    pub fn object(&self, bucket: &str, key: &str) -> Option<&S3Object> {
        self.buckets.get(bucket).and_then(|b| b.get(key))
    }

    pub fn delete(&mut self, bucket: &str, key: &str) -> bool {
        self.take(bucket, key).is_some()
    }

    /// Remove and return an object (the caller bills its storage).
    pub fn take(&mut self, bucket: &str, key: &str) -> Option<S3Object> {
        self.buckets.get_mut(bucket).and_then(|b| b.remove(key))
    }

    pub fn list(&self, bucket: &str, prefix: &str) -> Vec<String> {
        self.buckets
            .get(bucket)
            .map(|b| {
                b.keys()
                    .filter(|k| k.starts_with(prefix))
                    .cloned()
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Every bucket name with at least one object.
    pub fn bucket_names(&self) -> Vec<String> {
        self.buckets
            .iter()
            .filter(|(_, b)| !b.is_empty())
            .map(|(name, _)| name.clone())
            .collect()
    }

    /// First key in `bucket` whose object has this content digest
    /// (lexicographic order, so the answer is deterministic). The
    /// dedup probe behind [`crate::simcloud::SimCloud::s3_put_dedup`].
    pub fn find_by_digest(&self, bucket: &str, digest: u64) -> Option<&str> {
        self.buckets.get(bucket).and_then(|b| {
            b.iter()
                .find(|(_, o)| o.digest == digest)
                .map(|(k, _)| k.as_str())
        })
    }

    /// `(key, object)` pairs of a bucket under a prefix.
    pub fn objects(&self, bucket: &str, prefix: &str) -> Vec<(String, &S3Object)> {
        self.buckets
            .get(bucket)
            .map(|b| {
                b.iter()
                    .filter(|(k, _)| k.starts_with(prefix))
                    .map(|(k, o)| (k.clone(), o))
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Serialize (session persistence).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut root = Json::obj();
        for (bucket, objs) in &self.buckets {
            let mut b = Json::obj();
            for (key, obj) in objs {
                let mut o = Json::obj();
                o.set("data", Json::str(crate::util::hex::encode(&obj.data)));
                o.set("put_at_s", Json::num(obj.put_at_s));
                b.set(key, o);
            }
            root.set(bucket, b);
        }
        root
    }

    /// Restore from [`S3::to_json`]. Accepts the pre-storage-plane
    /// format too (bare hex strings, no metadata): digests are
    /// recomputed from the bytes either way.
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<Self> {
        let mut s = S3::new();
        let root = j
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("s3 state must be an object"))?;
        for (bucket, objs) in root {
            let o = objs
                .as_obj()
                .ok_or_else(|| anyhow::anyhow!("bucket '{bucket}' must be an object"))?;
            for (key, val) in o {
                let (hexs, put_at) = match val {
                    crate::util::json::Json::Str(h) => (h.as_str(), 0.0),
                    other => (
                        other
                            .get("data")
                            .and_then(|d| d.as_str())
                            .ok_or_else(|| anyhow::anyhow!("object '{key}' missing data"))?,
                        other.get("put_at_s").and_then(|t| t.as_f64()).unwrap_or(0.0),
                    ),
                };
                let data = crate::util::hex::decode(hexs).map_err(|e| anyhow::anyhow!(e))?;
                s.put_at(bucket, key, data, put_at);
            }
        }
        Ok(s)
    }

    pub fn bucket_size(&self, bucket: &str) -> u64 {
        self.buckets
            .get(bucket)
            .map(|b| b.values().map(|o| o.data.len() as u64).sum())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_delete() {
        let mut s = S3::new();
        s.put("risk-data", "losses/2012.bin", vec![1, 2, 3]);
        assert_eq!(s.get("risk-data", "losses/2012.bin"), Some([1u8, 2, 3].as_slice()));
        assert_eq!(s.bucket_size("risk-data"), 3);
        assert!(s.delete("risk-data", "losses/2012.bin"));
        assert!(!s.delete("risk-data", "losses/2012.bin"));
        assert_eq!(s.get("risk-data", "losses/2012.bin"), None);
    }

    #[test]
    fn list_by_prefix() {
        let mut s = S3::new();
        s.put("b", "a/1", vec![]);
        s.put("b", "a/2", vec![]);
        s.put("b", "c/3", vec![]);
        assert_eq!(s.list("b", "a/").len(), 2);
        assert_eq!(s.list("nope", "").len(), 0);
    }

    #[test]
    fn digests_fingerprint_content() {
        let mut s = S3::new();
        let d1 = s.put_at("b", "k", vec![1, 2, 3], 42.0);
        assert_eq!(d1, content_digest(&[1, 2, 3]));
        let obj = s.object("b", "k").unwrap();
        assert_eq!(obj.digest, d1);
        assert_eq!(obj.put_at_s, 42.0);
        // Same bytes, same digest; different bytes, different digest.
        assert_eq!(content_digest(&[1, 2, 3]), d1);
        assert_ne!(content_digest(&[1, 2, 4]), d1);
    }

    #[test]
    fn json_roundtrip_keeps_metadata_and_reads_legacy() {
        let mut s = S3::new();
        s.put_at("b", "k", vec![9, 9], 77.0);
        let back = S3::from_json(&s.to_json()).unwrap();
        let o = back.object("b", "k").unwrap();
        assert_eq!(o.data, vec![9, 9]);
        assert_eq!(o.put_at_s, 77.0);
        assert_eq!(o.digest, content_digest(&[9, 9]));
        // Legacy format: bare hex string per key.
        let legacy = crate::util::json::Json::parse(r#"{"b":{"k":"0909"}}"#).unwrap();
        let old = S3::from_json(&legacy).unwrap();
        assert_eq!(old.get("b", "k"), Some([9u8, 9].as_slice()));
        assert_eq!(old.object("b", "k").unwrap().digest, content_digest(&[9, 9]));
    }

    #[test]
    fn bucket_and_object_enumeration() {
        let mut s = S3::new();
        s.put("alpha", "x/1", vec![1]);
        s.put("beta", "y/2", vec![2, 2]);
        assert_eq!(s.bucket_names(), vec!["alpha".to_string(), "beta".to_string()]);
        let objs = s.objects("beta", "y/");
        assert_eq!(objs.len(), 1);
        assert_eq!(objs[0].0, "y/2");
        assert_eq!(objs[0].1.data.len(), 2);
    }
}
