//! Simulated Simple Storage Service. The paper uses S3 as the common
//! source that multiple EBS snapshots materialise from when several
//! instances/clusters need the same dataset.

use std::collections::BTreeMap;

/// Bucket → key → object bytes.
#[derive(Clone, Debug, Default)]
pub struct S3 {
    buckets: BTreeMap<String, BTreeMap<String, Vec<u8>>>,
}

impl S3 {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put(&mut self, bucket: &str, key: &str, data: Vec<u8>) {
        self.buckets
            .entry(bucket.to_string())
            .or_default()
            .insert(key.to_string(), data);
    }

    pub fn get(&self, bucket: &str, key: &str) -> Option<&[u8]> {
        self.buckets
            .get(bucket)
            .and_then(|b| b.get(key))
            .map(|v| v.as_slice())
    }

    pub fn delete(&mut self, bucket: &str, key: &str) -> bool {
        self.buckets
            .get_mut(bucket)
            .map(|b| b.remove(key).is_some())
            .unwrap_or(false)
    }

    pub fn list(&self, bucket: &str, prefix: &str) -> Vec<String> {
        self.buckets
            .get(bucket)
            .map(|b| {
                b.keys()
                    .filter(|k| k.starts_with(prefix))
                    .cloned()
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Serialize (session persistence).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut root = Json::obj();
        for (bucket, objs) in &self.buckets {
            let mut b = Json::obj();
            for (key, data) in objs {
                b.set(key, Json::str(crate::util::hex::encode(data)));
            }
            root.set(bucket, b);
        }
        root
    }

    /// Restore from [`S3::to_json`].
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<Self> {
        let mut s = S3::new();
        let root = j
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("s3 state must be an object"))?;
        for (bucket, objs) in root {
            let o = objs
                .as_obj()
                .ok_or_else(|| anyhow::anyhow!("bucket '{bucket}' must be an object"))?;
            for (key, val) in o {
                let hexs = val
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("object '{key}' not hex"))?;
                s.put(
                    bucket,
                    key,
                    crate::util::hex::decode(hexs).map_err(|e| anyhow::anyhow!(e))?,
                );
            }
        }
        Ok(s)
    }

    pub fn bucket_size(&self, bucket: &str) -> u64 {
        self.buckets
            .get(bucket)
            .map(|b| b.values().map(|v| v.len() as u64).sum())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_delete() {
        let mut s = S3::new();
        s.put("risk-data", "losses/2012.bin", vec![1, 2, 3]);
        assert_eq!(s.get("risk-data", "losses/2012.bin"), Some([1u8, 2, 3].as_slice()));
        assert_eq!(s.bucket_size("risk-data"), 3);
        assert!(s.delete("risk-data", "losses/2012.bin"));
        assert!(!s.delete("risk-data", "losses/2012.bin"));
        assert_eq!(s.get("risk-data", "losses/2012.bin"), None);
    }

    #[test]
    fn list_by_prefix() {
        let mut s = S3::new();
        s.put("b", "a/1", vec![]);
        s.put("b", "a/2", vec![]);
        s.put("b", "c/3", vec![]);
        assert_eq!(s.list("b", "a/").len(), 2);
        assert_eq!(s.list("nope", "").len(), 0);
    }
}
