//! The simulated-cloud facade: one `SimCloud` owns every EC2/EBS/S3
//! entity, the virtual clock, the network model and the billing ledger.
//! P2RAC's coordinator drives this exactly as it would drive AWS through
//! BOTO — the lifecycle rules (unique live names, one attachment per
//! volume, in-use resources refuse termination) are enforced here and
//! exercised by the test suite.

use super::clock::Clock;
use super::ebs::{Snapshot, Volume, VolumeState};
use super::ec2::{instance_type, Ami, Instance, InstanceState, Lifecycle};
use super::faults::FaultPlan;
use super::network::{Link, NetworkModel};
use super::pricing::Ledger;
use super::s3::S3;
use super::spot::SpotMarket;
use super::timing::SimParams;
use super::vfs::Vfs;
use crate::telemetry::{EventKind, Telemetry};
use crate::util::ids::IdFactory;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Errors surfaced to the coordinator / CLI.
#[derive(Debug)]
pub enum CloudError {
    UnknownInstanceType(String),
    NoSuchInstance(String),
    NoSuchVolume(String),
    NoSuchSnapshot(String),
    NoSuchAmi(String),
    NoSuchObject(String),
    VolumeInUse(String, String),
    VolumeNotAttached(String),
    VolumeDeleted(String),
    NotRunning(String),
    Locked(String),
    BootFailure,
    AttachFailure,
    HvmRequired(String),
}

impl std::fmt::Display for CloudError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CloudError::UnknownInstanceType(t) => write!(f, "instance type '{t}' is not offered"),
            CloudError::NoSuchInstance(i) => write!(f, "no such instance '{i}'"),
            CloudError::NoSuchVolume(v) => write!(f, "no such volume '{v}'"),
            CloudError::NoSuchSnapshot(s) => write!(f, "no such snapshot '{s}'"),
            CloudError::NoSuchAmi(a) => write!(f, "no such AMI '{a}'"),
            CloudError::NoSuchObject(o) => write!(f, "no such storage object '{o}'"),
            CloudError::VolumeInUse(v, i) => {
                write!(f, "volume '{v}' is attached to instance '{i}'")
            }
            CloudError::VolumeNotAttached(v) => write!(f, "volume '{v}' is not attached"),
            CloudError::VolumeDeleted(v) => write!(f, "volume '{v}' has been deleted"),
            CloudError::NotRunning(i) => write!(f, "instance '{i}' is not running"),
            CloudError::Locked(r) => write!(f, "resource '{r}' is locked (in use)"),
            CloudError::BootFailure => write!(f, "insufficient capacity: instance launch failed"),
            CloudError::AttachFailure => write!(f, "volume attachment failed"),
            CloudError::HvmRequired(t) => write!(f, "instance type '{t}' requires an HVM AMI"),
        }
    }
}

impl std::error::Error for CloudError {}

/// The simulated IaaS account.
pub struct SimCloud {
    pub clock: Clock,
    pub net: NetworkModel,
    pub s3: S3,
    pub ledger: Ledger,
    pub faults: FaultPlan,
    /// Deterministic spot price path + interruption source.
    pub spot: SpotMarket,
    /// The observability bus: every subsystem emits typed events here.
    pub telemetry: Telemetry,
    params: SimParams,
    ids: IdFactory,
    region: String,
    amis: Vec<Ami>,
    instances: BTreeMap<String, Instance>,
    volumes: BTreeMap<String, Volume>,
    snapshots: BTreeMap<String, Snapshot>,
    volume_created_at: BTreeMap<String, f64>,
    snapshot_created_at: BTreeMap<String, f64>,
}

impl SimCloud {
    pub fn new(params: SimParams) -> Self {
        let mut ids = IdFactory::new(0x9A2C);
        // The two Ubuntu AMIs from the paper (§3.1).
        let amis = vec![
            Ami {
                id: ids.ami(),
                name: "ubuntu-11.10-r-paravirtual".to_string(),
                hvm: false,
                preinstalled: vec!["r-base".into(), "snow".into(), "rgenoud".into()],
            },
            Ami {
                id: ids.ami(),
                name: "ubuntu-11.10-r-hvm-cluster-compute".to_string(),
                hvm: true,
                preinstalled: vec!["r-base".into(), "snow".into(), "rgenoud".into()],
            },
        ];
        Self {
            clock: Clock::new(),
            net: NetworkModel::new(params.clone()),
            s3: S3::new(),
            ledger: Ledger::new(),
            faults: FaultPlan::none(),
            spot: SpotMarket::default(),
            telemetry: Telemetry::default(),
            params,
            ids,
            region: "us-east-1".to_string(),
            amis,
            instances: BTreeMap::new(),
            volumes: BTreeMap::new(),
            snapshots: BTreeMap::new(),
            volume_created_at: BTreeMap::new(),
            snapshot_created_at: BTreeMap::new(),
        }
    }

    pub fn params(&self) -> &SimParams {
        &self.params
    }

    // ---------------------------------------------------------------- AMIs

    pub fn default_ami(&self, hvm: bool) -> &Ami {
        self.amis
            .iter()
            .find(|a| a.hvm == hvm)
            .expect("default AMIs registered in new()")
    }

    pub fn ami(&self, id: &str) -> Result<&Ami, CloudError> {
        self.amis
            .iter()
            .find(|a| a.id == id)
            .ok_or_else(|| CloudError::NoSuchAmi(id.to_string()))
    }

    pub fn amis(&self) -> &[Ami] {
        &self.amis
    }

    // ----------------------------------------------------------- snapshots

    /// Register a snapshot whose contents come from an S3-sourced vfs
    /// (the paper's "snapshot from the same source located on S3").
    pub fn create_snapshot(&mut self, size_gb: f64, fs: Vfs, description: &str) -> String {
        let id = self.ids.snapshot();
        self.snapshots.insert(
            id.clone(),
            Snapshot {
                id: id.clone(),
                size_gb,
                fs,
                description: description.to_string(),
                deleted: false,
            },
        );
        self.snapshot_created_at.insert(id.clone(), self.clock.now_s());
        id
    }

    /// Point-in-time snapshot of a live volume's contents (advances
    /// virtual time: incremental S3-backed copy, base + per-GiB). This
    /// is how cluster-resident job state becomes durable — the
    /// snapshot outlives any spot reclaim of the instances around it.
    pub fn snapshot_volume(
        &mut self,
        vol_id: &str,
        description: &str,
    ) -> Result<String, CloudError> {
        let v = self.volume(vol_id)?;
        let (size_gb, fs) = (v.size_gb, v.fs.clone());
        let dt = self.params.snapshot_base_s + self.params.snapshot_s_per_gb * size_gb;
        self.clock.advance(dt);
        Ok(self.create_snapshot(size_gb, fs, description))
    }

    pub fn snapshot(&self, id: &str) -> Result<&Snapshot, CloudError> {
        self.snapshots
            .get(id)
            .filter(|s| !s.deleted)
            .ok_or_else(|| CloudError::NoSuchSnapshot(id.to_string()))
    }

    pub fn delete_snapshot(&mut self, id: &str) -> Result<(), CloudError> {
        let created = self.snapshot_created_at.get(id).copied().unwrap_or(0.0);
        let now = self.clock.now_s();
        let s = self
            .snapshots
            .get_mut(id)
            .ok_or_else(|| CloudError::NoSuchSnapshot(id.to_string()))?;
        s.deleted = true;
        let (sid, size) = (s.id.clone(), s.size_gb);
        self.ledger.bill_snapshot_storage(&sid, size, created, now);
        Ok(())
    }

    pub fn live_snapshots(&self) -> Vec<&Snapshot> {
        self.snapshots.values().filter(|s| !s.deleted).collect()
    }

    // -------------------------------------------------- storage plane

    /// Store an object: the bytes cross `link` (virtual wire time), a
    /// PUT request is billed, and the transfer goes through the shared
    /// metered path. Returns the content digest.
    pub fn s3_put(&mut self, bucket: &str, key: &str, data: Vec<u8>, link: Link) -> u64 {
        let id = format!("s3://{bucket}/{key}");
        let bytes = data.len() as u64;
        let t = self.net.transfer_s(bytes, 1, link);
        self.clock.advance(t);
        // Overwrites bill the replaced object's storage lifetime first
        // (otherwise a repeatedly-rewritten key would only ever pay
        // from its final put to its delete).
        if let Some(old) = self.s3.take(bucket, key) {
            let now = self.clock.now_s();
            self.ledger
                .bill_s3_storage(&id, old.data.len() as u64, old.put_at_s, now);
        }
        self.ledger.bill_s3_request(&id, "PUT");
        self.account_transfer(&id, bytes, link);
        self.s3.put_at(bucket, key, data, self.clock.now_s())
    }

    /// [`SimCloud::s3_put`] with content-digest dedup: when an object
    /// with identical bytes already sits in `bucket`, the wire crossing
    /// is skipped entirely — only the PUT request is billed and the
    /// object is stored server-side (an S3 `CopyObject` of the
    /// duplicate). Returns `(digest, deduped)`, so callers can count
    /// skipped uploads.
    pub fn s3_put_dedup(
        &mut self,
        bucket: &str,
        key: &str,
        data: Vec<u8>,
        link: Link,
    ) -> (u64, bool) {
        let digest = crate::simcloud::s3::content_digest(&data);
        if self.s3.object(bucket, key).is_none() && self.s3.find_by_digest(bucket, digest).is_some()
        {
            let id = format!("s3://{bucket}/{key}");
            self.ledger.bill_s3_request(&id, "PUT");
            return (self.s3.put_at(bucket, key, data, self.clock.now_s()), true);
        }
        (self.s3_put(bucket, key, data, link), false)
    }

    /// Fetch an object over `link` (wire time + GET request billed).
    pub fn s3_get(&mut self, bucket: &str, key: &str, link: Link) -> Result<Vec<u8>, CloudError> {
        let id = format!("s3://{bucket}/{key}");
        let data = self
            .s3
            .get(bucket, key)
            .ok_or_else(|| CloudError::NoSuchObject(id.clone()))?
            .to_vec();
        let t = self.net.transfer_s(data.len() as u64, 1, link);
        self.clock.advance(t);
        self.ledger.bill_s3_request(&id, "GET");
        self.account_transfer(&id, data.len() as u64, link);
        Ok(data)
    }

    /// Delete an object, billing its storage from put to now.
    pub fn s3_delete(&mut self, bucket: &str, key: &str) -> Result<(), CloudError> {
        let id = format!("s3://{bucket}/{key}");
        let obj = self
            .s3
            .take(bucket, key)
            .ok_or_else(|| CloudError::NoSuchObject(id.clone()))?;
        let now = self.clock.now_s();
        self.ledger.bill_s3_request(&id, "DEL");
        self.ledger.bill_s3_storage(&id, obj.data.len() as u64, obj.put_at_s, now);
        Ok(())
    }

    /// The single transfer-accounting path every byte crossing a link
    /// goes through: project sync, result gather, checkpoint shipment
    /// and S3 traffic all end up here. WAN bytes are metered (scaled
    /// by `data_scale`, the same factor the time model applies); LAN
    /// bytes are free.
    pub fn account_transfer(&mut self, label: &str, bytes: u64, link: Link) {
        let scaled = (bytes as f64 * self.params.data_scale) as u64;
        self.ledger.bill_data_transfer(label, scaled, link);
        if self.telemetry.on() {
            // `billed` mirrors bill_data_transfer's early return, so the
            // count reconciles exactly with the ledger's WAN line items.
            let billed = scaled > 0 && link == Link::Wan;
            self.telemetry.emit(
                self.clock.now_s(),
                EventKind::Transfer,
                self.ledger.analyst(),
                None,
                None,
                Json::from_pairs(vec![
                    ("label", Json::str(label)),
                    ("bytes", Json::num(scaled as f64)),
                    (
                        "link",
                        Json::str(if link == Link::Wan { "wan" } else { "lan" }),
                    ),
                    ("billed", Json::Bool(billed)),
                ]),
            );
        }
    }

    // ------------------------------------------------------------- volumes

    /// Create an empty volume (no time cost beyond the API call).
    pub fn create_volume(&mut self, size_gb: f64) -> String {
        let id = self.ids.volume();
        self.volumes.insert(
            id.clone(),
            Volume {
                id: id.clone(),
                size_gb,
                state: VolumeState::Available,
                attached_to: None,
                source_snapshot: None,
                fs: Vfs::new(),
            },
        );
        self.volume_created_at.insert(id.clone(), self.clock.now_s());
        id
    }

    /// Materialise a new volume from a snapshot (advances virtual time —
    /// EBS lazily hydrates, modelled as base + per-GiB).
    pub fn create_volume_from_snapshot(&mut self, snap_id: &str) -> Result<String, CloudError> {
        let snap = self.snapshot(snap_id)?.clone();
        let dt = self.params.volume_from_snap_base_s
            + self.params.volume_from_snap_s_per_gb * snap.size_gb;
        self.clock.advance(dt);
        let id = self.ids.volume();
        self.volumes.insert(
            id.clone(),
            Volume {
                id: id.clone(),
                size_gb: snap.size_gb,
                state: VolumeState::Available,
                attached_to: None,
                source_snapshot: Some(snap_id.to_string()),
                fs: snap.fs,
            },
        );
        self.volume_created_at.insert(id.clone(), self.clock.now_s());
        Ok(id)
    }

    pub fn volume(&self, id: &str) -> Result<&Volume, CloudError> {
        self.volumes
            .get(id)
            .filter(|v| v.is_live())
            .ok_or_else(|| CloudError::NoSuchVolume(id.to_string()))
    }

    pub fn volume_fs_mut(&mut self, id: &str) -> Result<&mut Vfs, CloudError> {
        let v = self
            .volumes
            .get_mut(id)
            .filter(|v| v.is_live())
            .ok_or_else(|| CloudError::NoSuchVolume(id.to_string()))?;
        Ok(&mut v.fs)
    }

    pub fn live_volumes(&self) -> Vec<&Volume> {
        self.volumes.values().filter(|v| v.is_live()).collect()
    }

    pub fn attach_volume(&mut self, vol_id: &str, inst_id: &str) -> Result<(), CloudError> {
        if self.faults.take_attach_failure() {
            return Err(CloudError::AttachFailure);
        }
        let inst_exists = self
            .instances
            .get(inst_id)
            .map(|i| i.is_live())
            .unwrap_or(false);
        if !inst_exists {
            return Err(CloudError::NoSuchInstance(inst_id.to_string()));
        }
        let v = self
            .volumes
            .get_mut(vol_id)
            .ok_or_else(|| CloudError::NoSuchVolume(vol_id.to_string()))?;
        match v.state {
            VolumeState::Deleted => return Err(CloudError::VolumeDeleted(vol_id.to_string())),
            VolumeState::Attached => {
                return Err(CloudError::VolumeInUse(
                    vol_id.to_string(),
                    v.attached_to.clone().unwrap_or_default(),
                ))
            }
            VolumeState::Available => {}
        }
        v.state = VolumeState::Attached;
        v.attached_to = Some(inst_id.to_string());
        self.instances.get_mut(inst_id).unwrap().attached_volume = Some(vol_id.to_string());
        self.clock.advance(self.params.volume_attach_s);
        Ok(())
    }

    pub fn detach_volume(&mut self, vol_id: &str) -> Result<(), CloudError> {
        let v = self
            .volumes
            .get_mut(vol_id)
            .ok_or_else(|| CloudError::NoSuchVolume(vol_id.to_string()))?;
        let Some(inst) = v.attached_to.take() else {
            return Err(CloudError::VolumeNotAttached(vol_id.to_string()));
        };
        v.state = VolumeState::Available;
        if let Some(i) = self.instances.get_mut(&inst) {
            i.attached_volume = None;
        }
        self.clock.advance(self.params.volume_attach_s);
        Ok(())
    }

    pub fn delete_volume(&mut self, vol_id: &str) -> Result<(), CloudError> {
        let created = self.volume_created_at.get(vol_id).copied().unwrap_or(0.0);
        let now = self.clock.now_s();
        let v = self
            .volumes
            .get_mut(vol_id)
            .ok_or_else(|| CloudError::NoSuchVolume(vol_id.to_string()))?;
        if let Some(inst) = &v.attached_to {
            return Err(CloudError::VolumeInUse(vol_id.to_string(), inst.clone()));
        }
        if v.state == VolumeState::Deleted {
            return Err(CloudError::VolumeDeleted(vol_id.to_string()));
        }
        v.state = VolumeState::Deleted;
        let size = v.size_gb;
        let id = v.id.clone();
        self.ledger.bill_volume(&id, size, created, now);
        Ok(())
    }

    // ----------------------------------------------------------- instances

    /// Launch a batch of `n` on-demand instances (one AWS RunInstances
    /// call). Advances the clock by the batch boot time; installs
    /// `extra_libs` (the rlibs config file) on every instance.
    pub fn run_instances(
        &mut self,
        n: usize,
        type_name: &str,
        ami_id: &str,
        extra_libs: &[String],
    ) -> Result<Vec<String>, CloudError> {
        self.run_instances_as(n, type_name, ami_id, extra_libs, Lifecycle::OnDemand)
    }

    /// Launch a batch with an explicit purchase model (spot requests
    /// carry the Analyst's bid; interruptions and billing then follow
    /// the market's price path).
    pub fn run_instances_as(
        &mut self,
        n: usize,
        type_name: &str,
        ami_id: &str,
        extra_libs: &[String],
        lifecycle: Lifecycle,
    ) -> Result<Vec<String>, CloudError> {
        let itype = instance_type(type_name)
            .ok_or_else(|| CloudError::UnknownInstanceType(type_name.to_string()))?;
        let ami = self.ami(ami_id)?.clone();
        if itype.hvm && !ami.hvm {
            return Err(CloudError::HvmRequired(type_name.to_string()));
        }
        if self.faults.take_boot_failure() {
            // The failed API call still costs a round trip.
            self.clock.advance(self.params.per_instance_extra_s);
            return Err(CloudError::BootFailure);
        }
        self.clock.advance(self.params.batch_boot_s(n));
        if !extra_libs.is_empty() {
            // Installs run in parallel across the batch; pay once.
            self.clock
                .advance(self.params.rlib_install_s * extra_libs.len() as f64);
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let id = self.ids.instance();
            let dns = self.ids.public_dns(&self.region);
            let mut libs = ami.preinstalled.clone();
            libs.extend(extra_libs.iter().cloned());
            self.instances.insert(
                id.clone(),
                Instance {
                    id: id.clone(),
                    name: None,
                    itype,
                    ami_id: ami.id.clone(),
                    state: InstanceState::Running,
                    public_dns: dns,
                    tags: BTreeMap::new(),
                    attached_volume: None,
                    nfs_mount_from: None,
                    fs: Vfs::new(),
                    installed_libs: libs,
                    lifecycle,
                    locked: false,
                    launched_at_s: self.clock.now_s(),
                    terminated_at_s: None,
                    description: String::new(),
                },
            );
            out.push(id);
        }
        Ok(out)
    }

    pub fn instance(&self, id: &str) -> Result<&Instance, CloudError> {
        self.instances
            .get(id)
            .ok_or_else(|| CloudError::NoSuchInstance(id.to_string()))
    }

    pub fn instance_mut(&mut self, id: &str) -> Result<&mut Instance, CloudError> {
        self.instances
            .get_mut(id)
            .ok_or_else(|| CloudError::NoSuchInstance(id.to_string()))
    }

    pub fn instance_fs_mut(&mut self, id: &str) -> Result<&mut Vfs, CloudError> {
        let i = self.instance_mut(id)?;
        if i.state != InstanceState::Running {
            return Err(CloudError::NotRunning(id.to_string()));
        }
        Ok(&mut i.fs)
    }

    /// Split-borrow helper: hand out the instance's filesystem together
    /// with the network model and fault plan (needed by the data-sync
    /// layer, which reads `net`, mutates the fs and may consume faults).
    pub fn with_instance_fs<T>(
        &mut self,
        id: &str,
        f: impl FnOnce(&mut Vfs, &NetworkModel, &mut FaultPlan) -> T,
    ) -> Result<T, CloudError> {
        let i = self
            .instances
            .get_mut(id)
            .ok_or_else(|| CloudError::NoSuchInstance(id.to_string()))?;
        if i.state != InstanceState::Running {
            return Err(CloudError::NotRunning(id.to_string()));
        }
        Ok(f(&mut i.fs, &self.net, &mut self.faults))
    }

    /// Same split-borrow helper for a volume's persistent filesystem.
    pub fn with_volume_fs<T>(
        &mut self,
        id: &str,
        f: impl FnOnce(&mut Vfs, &NetworkModel, &mut FaultPlan) -> T,
    ) -> Result<T, CloudError> {
        let v = self
            .volumes
            .get_mut(id)
            .filter(|v| v.is_live())
            .ok_or_else(|| CloudError::NoSuchVolume(id.to_string()))?;
        Ok(f(&mut v.fs, &self.net, &mut self.faults))
    }

    pub fn live_instances(&self) -> Vec<&Instance> {
        self.instances.values().filter(|i| i.is_live()).collect()
    }

    /// Find a live instance by its Analyst-facing name tag.
    pub fn find_by_name(&self, name: &str) -> Option<&Instance> {
        self.instances
            .values()
            .find(|i| i.is_live() && i.name.as_deref() == Some(name))
    }

    pub fn set_name(&mut self, id: &str, name: &str) -> Result<(), CloudError> {
        self.instance_mut(id)?.name = Some(name.to_string());
        Ok(())
    }

    pub fn set_tag(&mut self, id: &str, key: &str, value: &str) -> Result<(), CloudError> {
        self.instance_mut(id)?
            .tags
            .insert(key.to_string(), value.to_string());
        Ok(())
    }

    pub fn set_lock(&mut self, id: &str, locked: bool) -> Result<(), CloudError> {
        self.instance_mut(id)?.locked = locked;
        Ok(())
    }

    /// Export `vol_id` (attached to `master`) over NFS to `workers`.
    pub fn nfs_export(
        &mut self,
        master: &str,
        vol_id: &str,
        workers: &[String],
    ) -> Result<(), CloudError> {
        let m = self.instance(master)?;
        if m.attached_volume.as_deref() != Some(vol_id) {
            return Err(CloudError::VolumeNotAttached(vol_id.to_string()));
        }
        for w in workers {
            self.instance_mut(w)?.nfs_mount_from = Some(vol_id.to_string());
        }
        // Mounting happens in parallel; single config cost.
        self.clock
            .advance(self.params.per_worker_config_s * workers.len() as f64);
        Ok(())
    }

    pub fn nfs_unexport(&mut self, workers: &[String]) -> Result<(), CloudError> {
        for w in workers {
            self.instance_mut(w)?.nfs_mount_from = None;
        }
        Ok(())
    }

    /// Terminate a batch of instances in parallel (one API call): detach
    /// volumes, bill usage, advance by the flat termination time.
    pub fn terminate_instances(&mut self, ids: &[String]) -> Result<(), CloudError> {
        // Validate first: refuse if any is locked.
        for id in ids {
            let i = self.instance(id)?;
            if i.locked {
                return Err(CloudError::Locked(id.clone()));
            }
        }
        self.clock.advance(self.params.terminate_s);
        let end = self.clock.now_s();
        for id in ids {
            self.release_instance(id, end, false);
        }
        Ok(())
    }

    /// The provider reclaims a batch of spot instances (market price
    /// exceeded the bid, or a `FaultPlan`-armed interruption). Unlike
    /// [`terminate_instances`] this ignores locks — AWS does not ask —
    /// and bills with the interrupted-partial-hour-free rule. The
    /// caller (jobs scheduler) decides when on the timeline this
    /// happens; no clock advance here.
    pub fn spot_interrupt_instances(&mut self, ids: &[String]) -> Result<(), CloudError> {
        for id in ids {
            let i = self.instance(id)?;
            if !i.is_live() {
                return Err(CloudError::NotRunning(id.clone()));
            }
        }
        let end = self.clock.now_s();
        for id in ids {
            self.release_instance(id, end, true);
        }
        Ok(())
    }

    /// Shared teardown: detach volume, flip state, bill by lifecycle.
    fn release_instance(&mut self, id: &str, end: f64, interrupted: bool) {
        // Detach any volume (without extra per-instance time).
        let vol = self.instances.get(id).and_then(|i| i.attached_volume.clone());
        if let Some(v) = vol {
            if let Some(volume) = self.volumes.get_mut(&v) {
                volume.state = VolumeState::Available;
                volume.attached_to = None;
            }
        }
        let i = self.instances.get_mut(id).unwrap();
        i.attached_volume = None;
        i.nfs_mount_from = None;
        i.state = InstanceState::Terminated;
        i.terminated_at_s = Some(end);
        i.locked = false;
        let (iid, api, price, start, lifecycle) = (
            i.id.clone(),
            i.itype.api_name,
            i.itype.price_cents_hour,
            i.launched_at_s,
            i.lifecycle,
        );
        // Attribute the charge to the tenant that owns the instance
        // (the `p2rac:analyst` tag), not whoever triggered teardown.
        let owner = i.tags.get("p2rac:analyst").cloned();
        let saved = self.ledger.analyst().to_string();
        if let Some(a) = &owner {
            self.ledger.set_analyst(a);
        }
        match lifecycle {
            Lifecycle::OnDemand => {
                self.ledger.bill_instance(&iid, api, price, start, end);
            }
            Lifecycle::Spot {
                bid_centi_cents_hour,
            } => {
                let cc =
                    self.spot
                        .cost_centi_cents(api, start, end, interrupted, bid_centi_cents_hour);
                self.ledger.bill_spot_instance(&iid, api, cc, interrupted);
            }
        }
        if owner.is_some() {
            self.ledger.set_analyst(&saved);
        }
    }
}

// -------------------------------------------------------- persistence

impl SimCloud {
    /// Serialize the account state (live resources, billing, clock
    /// position) for cross-invocation CLI sessions. Terminated
    /// instances and deleted volumes/snapshots are dropped — their
    /// billing is already in the ledger items.
    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        root.set("now_s", Json::num(self.clock.now_s()));
        root.set("id_counter", Json::num(self.ids.counter() as f64));
        let mut insts = Json::obj();
        for i in self.instances.values().filter(|i| i.is_live()) {
            let mut o = Json::obj();
            o.set("name", i.name.as_ref().map(Json::str).unwrap_or(Json::Null));
            o.set("type", Json::str(i.itype.api_name));
            o.set("ami", Json::str(&i.ami_id));
            o.set("dns", Json::str(&i.public_dns));
            let mut tags = Json::obj();
            for (k, v) in &i.tags {
                tags.set(k, Json::str(v));
            }
            o.set("tags", tags);
            o.set(
                "volume",
                i.attached_volume.as_ref().map(Json::str).unwrap_or(Json::Null),
            );
            o.set(
                "nfs_from",
                i.nfs_mount_from.as_ref().map(Json::str).unwrap_or(Json::Null),
            );
            o.set("fs", i.fs.to_json());
            o.set("libs", Json::arr_str(i.installed_libs.clone()));
            o.set(
                "spot_bid",
                match i.lifecycle {
                    Lifecycle::OnDemand => Json::Null,
                    Lifecycle::Spot { bid_centi_cents_hour } => {
                        Json::num(bid_centi_cents_hour as f64)
                    }
                },
            );
            o.set("locked", Json::Bool(i.locked));
            o.set("launched_at_s", Json::num(i.launched_at_s));
            o.set("description", Json::str(&i.description));
            insts.set(&i.id, o);
        }
        root.set("instances", insts);
        let mut vols = Json::obj();
        for v in self.volumes.values().filter(|v| v.is_live()) {
            let mut o = Json::obj();
            o.set("size_gb", Json::num(v.size_gb));
            o.set(
                "attached_to",
                v.attached_to.as_ref().map(Json::str).unwrap_or(Json::Null),
            );
            o.set(
                "snapshot",
                v.source_snapshot.as_ref().map(Json::str).unwrap_or(Json::Null),
            );
            o.set("fs", v.fs.to_json());
            o.set(
                "created_at_s",
                Json::num(self.volume_created_at.get(&v.id).copied().unwrap_or(0.0)),
            );
            vols.set(&v.id, o);
        }
        root.set("volumes", vols);
        let mut snaps = Json::obj();
        for s in self.snapshots.values().filter(|s| !s.deleted) {
            let mut o = Json::obj();
            o.set("size_gb", Json::num(s.size_gb));
            o.set("description", Json::str(&s.description));
            o.set("fs", s.fs.to_json());
            o.set(
                "created_at_s",
                Json::num(self.snapshot_created_at.get(&s.id).copied().unwrap_or(0.0)),
            );
            snaps.set(&s.id, o);
        }
        root.set("snapshots", snaps);
        root.set("s3", self.s3.to_json());
        let mut ledger = Vec::new();
        for item in self.ledger.items() {
            ledger.push(Json::from_pairs(vec![
                ("id", Json::str(&item.resource_id)),
                ("detail", Json::str(&item.detail)),
                // Centi-cents: sub-cent EBS charges survive a restore.
                ("centi_cents", Json::num(item.centi_cents as f64)),
                ("analyst", Json::str(&item.analyst)),
            ]));
        }
        root.set("ledger", Json::Arr(ledger));
        root.set("telemetry", self.telemetry.to_json());
        root
    }

    /// Restore a persisted account into a fresh `SimCloud` with the
    /// given params.
    pub fn from_json(params: SimParams, j: &Json) -> anyhow::Result<Self> {
        let mut c = SimCloud::new(params);
        c.clock.restore(j.req_f64("now_s")?);
        c.ids.set_counter(j.req_u64("id_counter")?);
        for (id, o) in j
            .get("snapshots")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("missing snapshots"))?
        {
            c.snapshots.insert(
                id.clone(),
                Snapshot {
                    id: id.clone(),
                    size_gb: o.req_f64("size_gb")?,
                    fs: Vfs::from_json(o.get("fs").unwrap_or(&Json::obj()))?,
                    description: o.req_str("description")?,
                    deleted: false,
                },
            );
            c.snapshot_created_at
                .insert(id.clone(), o.req_f64("created_at_s").unwrap_or(0.0));
        }
        for (id, o) in j
            .get("volumes")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("missing volumes"))?
        {
            let attached = o.opt_str("attached_to");
            c.volumes.insert(
                id.clone(),
                Volume {
                    id: id.clone(),
                    size_gb: o.req_f64("size_gb")?,
                    state: if attached.is_some() {
                        VolumeState::Attached
                    } else {
                        VolumeState::Available
                    },
                    attached_to: attached,
                    source_snapshot: o.opt_str("snapshot"),
                    fs: Vfs::from_json(o.get("fs").unwrap_or(&Json::obj()))?,
                },
            );
            c.volume_created_at
                .insert(id.clone(), o.req_f64("created_at_s").unwrap_or(0.0));
        }
        for (id, o) in j
            .get("instances")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow::anyhow!("missing instances"))?
        {
            let tname = o.req_str("type")?;
            let itype = instance_type(&tname)
                .ok_or_else(|| anyhow::anyhow!("unknown persisted type {tname}"))?;
            let mut tags = BTreeMap::new();
            if let Some(t) = o.get("tags").and_then(Json::as_obj) {
                for (k, v) in t {
                    if let Some(s) = v.as_str() {
                        tags.insert(k.clone(), s.to_string());
                    }
                }
            }
            let libs = o
                .get("libs")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|x| x.as_str().map(str::to_string)).collect())
                .unwrap_or_default();
            c.instances.insert(
                id.clone(),
                Instance {
                    id: id.clone(),
                    name: o.opt_str("name"),
                    itype,
                    ami_id: o.req_str("ami")?,
                    state: InstanceState::Running,
                    public_dns: o.req_str("dns")?,
                    tags,
                    attached_volume: o.opt_str("volume"),
                    nfs_mount_from: o.opt_str("nfs_from"),
                    fs: Vfs::from_json(o.get("fs").unwrap_or(&Json::obj()))?,
                    installed_libs: libs,
                    lifecycle: match o.get("spot_bid").and_then(Json::as_u64) {
                        Some(bid) => Lifecycle::Spot {
                            bid_centi_cents_hour: bid,
                        },
                        None => Lifecycle::OnDemand,
                    },
                    locked: o.opt_bool("locked", false),
                    launched_at_s: o.req_f64("launched_at_s")?,
                    terminated_at_s: None,
                    description: o.opt_str("description").unwrap_or_default(),
                },
            );
        }
        if let Some(s3) = j.get("s3") {
            c.s3 = S3::from_json(s3)?;
        }
        if let Some(items) = j.get("ledger").and_then(Json::as_arr) {
            for item in items {
                // Re-book as flat items (already-computed amounts).
                // Pre-centi-cent sessions persisted whole "cents".
                let centi = match item.get("centi_cents").and_then(Json::as_u64) {
                    Some(cc) => cc,
                    None => item.req_u64("cents")? * 100,
                };
                let analyst = item.opt_str("analyst").unwrap_or_default();
                c.ledger.push_raw_as(
                    &item.req_str("id")?,
                    &item.req_str("detail")?,
                    centi,
                    &analyst,
                );
            }
        }
        if let Some(t) = j.get("telemetry") {
            c.telemetry = Telemetry::from_json(t)?;
        }
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cloud() -> SimCloud {
        SimCloud::new(SimParams::default())
    }

    #[test]
    fn launch_and_terminate_lifecycle() {
        let mut c = cloud();
        let ami = c.default_ami(false).id.clone();
        let ids = c.run_instances(2, "m2.2xlarge", &ami, &[]).unwrap();
        assert_eq!(ids.len(), 2);
        assert!(c.clock.now_s() > 0.0);
        for id in &ids {
            assert_eq!(c.instance(id).unwrap().state, InstanceState::Running);
        }
        c.terminate_instances(&ids).unwrap();
        for id in &ids {
            assert_eq!(c.instance(id).unwrap().state, InstanceState::Terminated);
        }
        assert!(c.ledger.total_cents() >= 180, "two m2.2xlarge hours");
        assert_eq!(c.live_instances().len(), 0);
    }

    #[test]
    fn unknown_type_and_ami_fail() {
        let mut c = cloud();
        let ami = c.default_ami(false).id.clone();
        assert!(matches!(
            c.run_instances(1, "z9.mega", &ami, &[]),
            Err(CloudError::UnknownInstanceType(_))
        ));
        assert!(matches!(
            c.run_instances(1, "m2.2xlarge", "ami-nope", &[]),
            Err(CloudError::NoSuchAmi(_))
        ));
    }

    #[test]
    fn hvm_type_needs_hvm_ami() {
        let mut c = cloud();
        let pv = c.default_ami(false).id.clone();
        assert!(matches!(
            c.run_instances(1, "cc1.4xlarge", &pv, &[]),
            Err(CloudError::HvmRequired(_))
        ));
        let hvm = c.default_ami(true).id.clone();
        assert!(c.run_instances(1, "cc1.4xlarge", &hvm, &[]).is_ok());
    }

    #[test]
    fn volume_attach_rules() {
        let mut c = cloud();
        let ami = c.default_ami(false).id.clone();
        let ids = c.run_instances(2, "m2.2xlarge", &ami, &[]).unwrap();
        let vol = c.create_volume(100.0);
        c.attach_volume(&vol, &ids[0]).unwrap();
        // One volume attaches to at most one instance (paper §3.2.1).
        assert!(matches!(
            c.attach_volume(&vol, &ids[1]),
            Err(CloudError::VolumeInUse(_, _))
        ));
        // Attached volumes refuse deletion.
        assert!(matches!(
            c.delete_volume(&vol),
            Err(CloudError::VolumeInUse(_, _))
        ));
        c.detach_volume(&vol).unwrap();
        c.delete_volume(&vol).unwrap();
        assert!(matches!(c.volume(&vol), Err(CloudError::NoSuchVolume(_))));
    }

    #[test]
    fn snapshot_materialises_contents() {
        let mut c = cloud();
        let mut fs = Vfs::new();
        fs.write("losses/industry.bin", vec![9u8; 1024]);
        let snap = c.create_snapshot(10.0, fs, "event-loss table");
        let vol = c.create_volume_from_snapshot(&snap).unwrap();
        assert_eq!(
            c.volume(&vol).unwrap().fs.read("losses/industry.bin"),
            Some(vec![9u8; 1024].as_slice())
        );
        assert_eq!(c.volume(&vol).unwrap().source_snapshot.as_deref(), Some(snap.as_str()));
    }

    #[test]
    fn volume_survives_instance_termination() {
        let mut c = cloud();
        let ami = c.default_ami(false).id.clone();
        let ids = c.run_instances(1, "m2.4xlarge", &ami, &[]).unwrap();
        let vol = c.create_volume(50.0);
        c.attach_volume(&vol, &ids[0]).unwrap();
        c.instance_fs_mut(&ids[0]).unwrap().write("tmp", vec![1]);
        c.volume_fs_mut(&vol).unwrap().write("persist.bin", vec![2]);
        c.terminate_instances(&ids).unwrap();
        // EBS persistence: volume and its data outlive the instance.
        let v = c.volume(&vol).unwrap();
        assert_eq!(v.state, VolumeState::Available);
        assert_eq!(v.fs.read("persist.bin"), Some([2u8].as_slice()));
    }

    #[test]
    fn locked_instance_refuses_termination() {
        let mut c = cloud();
        let ami = c.default_ami(false).id.clone();
        let ids = c.run_instances(1, "m2.2xlarge", &ami, &[]).unwrap();
        c.set_lock(&ids[0], true).unwrap();
        assert!(matches!(
            c.terminate_instances(&ids),
            Err(CloudError::Locked(_))
        ));
        c.set_lock(&ids[0], false).unwrap();
        c.terminate_instances(&ids).unwrap();
    }

    #[test]
    fn boot_fault_injection() {
        let mut c = cloud();
        c.faults.boot_failures = 1;
        let ami = c.default_ami(false).id.clone();
        assert!(matches!(
            c.run_instances(4, "m2.2xlarge", &ami, &[]),
            Err(CloudError::BootFailure)
        ));
        // Retry succeeds.
        assert!(c.run_instances(4, "m2.2xlarge", &ami, &[]).is_ok());
    }

    #[test]
    fn nfs_export_to_workers() {
        let mut c = cloud();
        let ami = c.default_ami(false).id.clone();
        let ids = c.run_instances(3, "m2.2xlarge", &ami, &[]).unwrap();
        let vol = c.create_volume(10.0);
        c.attach_volume(&vol, &ids[0]).unwrap();
        c.nfs_export(&ids[0], &vol, &ids[1..].to_vec()).unwrap();
        assert_eq!(
            c.instance(&ids[1]).unwrap().nfs_mount_from.as_deref(),
            Some(vol.as_str())
        );
        // Export requires the volume to actually be on the master.
        let vol2 = c.create_volume(10.0);
        assert!(matches!(
            c.nfs_export(&ids[0], &vol2, &ids[1..].to_vec()),
            Err(CloudError::VolumeNotAttached(_))
        ));
    }

    #[test]
    fn names_resolve_to_live_instances_only() {
        let mut c = cloud();
        let ami = c.default_ami(false).id.clone();
        let ids = c.run_instances(1, "m2.2xlarge", &ami, &[]).unwrap();
        c.set_name(&ids[0], "hpc_instance").unwrap();
        assert!(c.find_by_name("hpc_instance").is_some());
        c.terminate_instances(&ids).unwrap();
        assert!(c.find_by_name("hpc_instance").is_none());
    }

    #[test]
    fn spot_instances_bill_at_market_rates() {
        let mut c = cloud();
        c.spot.spike_prob = 0.0; // spike-free path: every hour is ~30% of on-demand
        let ami = c.default_ami(false).id.clone();
        let bid = 90 * 100; // on-demand price of m2.2xlarge
        let ids = c
            .run_instances_as(
                2,
                "m2.2xlarge",
                &ami,
                &[],
                Lifecycle::Spot {
                    bid_centi_cents_hour: bid,
                },
            )
            .unwrap();
        assert!(c.instance(&ids[0]).unwrap().is_spot());
        c.clock.advance(2.0 * 3600.0);
        c.terminate_instances(&ids).unwrap();
        let spot_total = c.ledger.total_centi_cents();
        // The same usage on demand: 2 instances x >=3 started hours x 90c.
        let mut od = cloud();
        let ami2 = od.default_ami(false).id.clone();
        let ids2 = od.run_instances(2, "m2.2xlarge", &ami2, &[]).unwrap();
        od.clock.advance(2.0 * 3600.0);
        od.terminate_instances(&ids2).unwrap();
        assert!(
            spot_total < od.ledger.total_centi_cents(),
            "spot {spot_total} must undercut on-demand {}",
            od.ledger.total_centi_cents()
        );
    }

    #[test]
    fn spot_interruption_ignores_locks_and_frees_partial_hour() {
        let mut c = cloud();
        let ami = c.default_ami(false).id.clone();
        let ids = c
            .run_instances_as(
                1,
                "m2.2xlarge",
                &ami,
                &[],
                Lifecycle::Spot {
                    bid_centi_cents_hour: 1,
                },
            )
            .unwrap();
        c.set_lock(&ids[0], true).unwrap();
        let launch = c.instance(&ids[0]).unwrap().launched_at_s;
        c.clock.advance(1800.0); // interrupted mid-first-hour
        c.spot_interrupt_instances(&ids).unwrap();
        let i = c.instance(&ids[0]).unwrap();
        assert_eq!(i.state, InstanceState::Terminated);
        // Provider interruption within the first hour bills nothing.
        let billed: u64 = c
            .ledger
            .items()
            .iter()
            .filter(|it| it.resource_id == ids[0])
            .map(|it| it.centi_cents)
            .sum();
        assert_eq!(
            billed,
            c.spot
                .cost_centi_cents("m2.2xlarge", launch, launch + 1800.0, true, 1)
        );
    }

    #[test]
    fn spot_lifecycle_survives_persistence() {
        let mut c = cloud();
        let ami = c.default_ami(false).id.clone();
        c.run_instances_as(
            1,
            "m2.2xlarge",
            &ami,
            &[],
            Lifecycle::Spot {
                bid_centi_cents_hour: 4321,
            },
        )
        .unwrap();
        let j = c.to_json();
        let back = SimCloud::from_json(SimParams::default(), &j).unwrap();
        let inst = back.live_instances()[0];
        assert_eq!(
            inst.lifecycle,
            Lifecycle::Spot {
                bid_centi_cents_hour: 4321
            }
        );
    }

    #[test]
    fn snapshot_volume_freezes_contents_and_advances_time() {
        let mut c = cloud();
        let vol = c.create_volume(8.0);
        c.volume_fs_mut(&vol).unwrap().write("jobs/j1/ck.json", vec![1, 2]);
        let t0 = c.clock.now_s();
        let snap = c.snapshot_volume(&vol, "resident state").unwrap();
        assert!(c.clock.now_s() > t0, "snapshotting takes virtual time");
        // Later volume edits do not leak into the snapshot.
        c.volume_fs_mut(&vol).unwrap().write("jobs/j1/ck.json", vec![9]);
        assert_eq!(
            c.snapshot(&snap).unwrap().fs.read("jobs/j1/ck.json"),
            Some([1u8, 2].as_slice())
        );
        // Restore path: a new volume hydrates the frozen bytes.
        let vol2 = c.create_volume_from_snapshot(&snap).unwrap();
        assert_eq!(
            c.volume(&vol2).unwrap().fs.read("jobs/j1/ck.json"),
            Some([1u8, 2].as_slice())
        );
        // Deleting the snapshot bills its storage lifetime.
        let before = c.ledger.items().len();
        c.delete_snapshot(&snap).unwrap();
        assert!(c.ledger.items().len() > before);
    }

    #[test]
    fn s3_plane_bills_requests_and_meters_wan_only() {
        let mut c = cloud();
        let t0 = c.clock.now_s();
        let digest = c.s3_put("ckpts", "job-1", vec![7; 4096], Link::Wan);
        assert!(c.clock.now_s() > t0, "the put crossed the wire");
        assert_eq!(digest, super::super::s3::content_digest(&[7; 4096]));
        let wan_cc = c.ledger.total_centi_cents();
        assert!(wan_cc >= 2, "PUT request + metered WAN bytes");
        // The same put over LAN: request billed, bytes free.
        let before = c.ledger.total_centi_cents();
        c.s3_put("ckpts", "job-2", vec![7; 4096], Link::Lan);
        assert_eq!(c.ledger.total_centi_cents(), before + 1);
        // Get round-trips the bytes; delete bills storage.
        let data = c.s3_get("ckpts", "job-1", Link::Lan).unwrap();
        assert_eq!(data, vec![7; 4096]);
        assert!(matches!(
            c.s3_get("ckpts", "nope", Link::Lan),
            Err(CloudError::NoSuchObject(_))
        ));
        c.s3_delete("ckpts", "job-1").unwrap();
        assert_eq!(c.s3.get("ckpts", "job-1"), None);
    }

    #[test]
    fn boot_time_grows_with_batch_size() {
        let mut a = cloud();
        let ami_a = a.default_ami(false).id.clone();
        a.run_instances(2, "m2.2xlarge", &ami_a, &[]).unwrap();
        let t2 = a.clock.now_s();
        let mut b = cloud();
        let ami_b = b.default_ami(false).id.clone();
        b.run_instances(16, "m2.2xlarge", &ami_b, &[]).unwrap();
        let t16 = b.clock.now_s();
        assert!(t16 > t2);
    }
}
