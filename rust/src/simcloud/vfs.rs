//! In-memory virtual filesystem.
//!
//! Every simulated site — the Analyst workstation, each EC2 instance,
//! each EBS volume — carries a `Vfs`. Project directories, script files,
//! datasets and results are *real bytes* here, so the rsync-algorithm
//! data sync computes genuine checksums and deltas rather than
//! stopwatch stubs.

use std::collections::BTreeMap;

/// One file: content + a logical modification counter (virtual mtime).
#[derive(Clone, Debug, PartialEq)]
pub struct FileNode {
    pub data: Vec<u8>,
    pub mtime: u64,
}

/// Flat path→file map with directory semantics derived from `/`
/// separators (like an object store with list-by-prefix).
#[derive(Clone, Debug, Default)]
pub struct Vfs {
    files: BTreeMap<String, FileNode>,
    mtime_counter: u64,
}

fn normalize(path: &str) -> String {
    let mut parts: Vec<&str> = Vec::new();
    for p in path.split('/') {
        match p {
            "" | "." => {}
            ".." => {
                parts.pop();
            }
            p => parts.push(p),
        }
    }
    parts.join("/")
}

impl Vfs {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write (create or replace) a file.
    pub fn write(&mut self, path: &str, data: impl Into<Vec<u8>>) {
        let p = normalize(path);
        assert!(!p.is_empty(), "empty path");
        self.mtime_counter += 1;
        self.files.insert(
            p,
            FileNode {
                data: data.into(),
                mtime: self.mtime_counter,
            },
        );
    }

    pub fn read(&self, path: &str) -> Option<&[u8]> {
        self.files.get(&normalize(path)).map(|f| f.data.as_slice())
    }

    pub fn node(&self, path: &str) -> Option<&FileNode> {
        self.files.get(&normalize(path))
    }

    pub fn exists(&self, path: &str) -> bool {
        self.files.contains_key(&normalize(path))
    }

    pub fn remove(&mut self, path: &str) -> bool {
        self.files.remove(&normalize(path)).is_some()
    }

    /// Remove a whole subtree; returns number of files removed.
    pub fn remove_dir(&mut self, dir: &str) -> usize {
        let prefix = format!("{}/", normalize(dir));
        let keys: Vec<String> = self
            .files
            .keys()
            .filter(|k| k.starts_with(&prefix))
            .cloned()
            .collect();
        for k in &keys {
            self.files.remove(k);
        }
        keys.len()
    }

    /// All file paths under `dir` (recursive), relative to `dir`.
    pub fn list_dir(&self, dir: &str) -> Vec<String> {
        let d = normalize(dir);
        if d.is_empty() {
            return self.files.keys().cloned().collect();
        }
        let prefix = format!("{d}/");
        self.files
            .keys()
            .filter(|k| k.starts_with(&prefix))
            .map(|k| k[prefix.len()..].to_string())
            .collect()
    }

    /// Does any file live under `dir`?
    pub fn dir_exists(&self, dir: &str) -> bool {
        !self.list_dir(dir).is_empty()
    }

    /// Total bytes under `dir` (recursive); whole vfs if `dir` is empty.
    pub fn dir_size(&self, dir: &str) -> u64 {
        let d = normalize(dir);
        let prefix = if d.is_empty() { String::new() } else { format!("{d}/") };
        self.files
            .iter()
            .filter(|(k, _)| k.starts_with(&prefix))
            .map(|(_, f)| f.data.len() as u64)
            .sum()
    }

    pub fn file_count(&self) -> usize {
        self.files.len()
    }

    /// Iterate over every (path, node) — session persistence.
    pub fn iter(&self) -> impl Iterator<Item = (&String, &FileNode)> {
        self.files.iter()
    }

    /// Serialize to JSON (paths → hex contents).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut o = Json::obj();
        for (path, node) in &self.files {
            o.set(path, Json::str(crate::util::hex::encode(&node.data)));
        }
        o
    }

    /// Restore from [`Vfs::to_json`] output.
    pub fn from_json(j: &crate::util::json::Json) -> anyhow::Result<Self> {
        let mut v = Vfs::new();
        let obj = j
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("vfs state must be an object"))?;
        for (path, val) in obj {
            let hexs = val
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("vfs file '{path}' not hex"))?;
            let data = crate::util::hex::decode(hexs).map_err(|e| anyhow::anyhow!(e))?;
            v.write(path, data);
        }
        Ok(v)
    }

    /// Copy a subtree into another vfs (used by NFS share / snapshot).
    pub fn copy_dir_to(&self, dir: &str, dest: &mut Vfs, dest_dir: &str) -> usize {
        let mut n = 0;
        for rel in self.list_dir(dir) {
            let src_path = format!("{}/{rel}", normalize(dir));
            let data = self.read(&src_path).unwrap().to_vec();
            dest.write(&format!("{}/{rel}", normalize(dest_dir)), data);
            n += 1;
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_read_roundtrip() {
        let mut v = Vfs::new();
        v.write("project/script.json", b"{}".to_vec());
        assert_eq!(v.read("project/script.json"), Some(b"{}".as_slice()));
        assert_eq!(v.read("./project//script.json"), Some(b"{}".as_slice()));
        assert!(v.exists("project/script.json"));
        assert!(!v.exists("project/other"));
    }

    #[test]
    fn mtime_increases_on_rewrite() {
        let mut v = Vfs::new();
        v.write("a", b"1".to_vec());
        let m1 = v.node("a").unwrap().mtime;
        v.write("a", b"2".to_vec());
        assert!(v.node("a").unwrap().mtime > m1);
    }

    #[test]
    fn list_dir_is_relative_and_recursive() {
        let mut v = Vfs::new();
        v.write("proj/data/events.bin", vec![0; 10]);
        v.write("proj/script.json", vec![1; 5]);
        v.write("other/x", vec![2; 1]);
        let mut ls = v.list_dir("proj");
        ls.sort();
        assert_eq!(ls, vec!["data/events.bin", "script.json"]);
        assert_eq!(v.dir_size("proj"), 15);
        assert_eq!(v.dir_size(""), 16);
    }

    #[test]
    fn remove_dir_prunes_subtree() {
        let mut v = Vfs::new();
        v.write("p/a", vec![0]);
        v.write("p/b/c", vec![0]);
        v.write("q/z", vec![0]);
        assert_eq!(v.remove_dir("p"), 2);
        assert!(!v.dir_exists("p"));
        assert!(v.exists("q/z"));
    }

    #[test]
    fn copy_dir_between_sites() {
        let mut src = Vfs::new();
        src.write("proj/a.bin", vec![7; 32]);
        src.write("proj/results/r1.json", b"{}".to_vec());
        let mut dst = Vfs::new();
        let n = src.copy_dir_to("proj", &mut dst, "home/proj");
        assert_eq!(n, 2);
        assert_eq!(dst.read("home/proj/a.bin"), Some(vec![7; 32].as_slice()));
    }

    #[test]
    fn normalize_handles_dotdot() {
        assert_eq!(normalize("a/b/../c"), "a/c");
        assert_eq!(normalize("/a//b/./"), "a/b");
    }
}
