//! Virtual time for the simulated cloud.
//!
//! This host has a single CPU core and no AWS account, so wall-clock
//! measurement of an elastic cluster is impossible (reproduction band
//! 0/5 — see DESIGN.md §2). Instead every simulated operation advances a
//! virtual clock by a modelled duration; parallel activities advance it
//! by the *maximum* of their member durations (span-parallel discrete
//! event accounting). All management-time figures (paper Figs 6–7) and
//! speed-up curves (Fig 4) are read off this clock, while workload
//! numerics are computed for real through the PJRT runtime.

/// A labelled interval on the virtual timeline, used to regenerate the
/// paper's management-time bar charts.
#[derive(Clone, Debug, PartialEq)]
pub struct Span {
    pub label: String,
    pub category: SpanCategory,
    pub start_s: f64,
    pub end_s: f64,
}

impl Span {
    pub fn duration_s(&self) -> f64 {
        self.end_s - self.start_s
    }
}

/// The six bar groups of Figs 6–7, plus compute/other.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SpanCategory {
    CreateResource,
    SubmitToMaster,
    SubmitToAllNodes,
    FetchFromMaster,
    FetchFromAllNodes,
    TerminateResource,
    Compute,
    Other,
}

/// Virtual clock + recorded timeline.
#[derive(Debug, Default)]
pub struct Clock {
    now_s: f64,
    timeline: Vec<Span>,
}

impl Clock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current virtual time in seconds since simulation start.
    pub fn now_s(&self) -> f64 {
        self.now_s
    }

    /// Advance by `dt` seconds (sequential activity).
    pub fn advance(&mut self, dt_s: f64) {
        assert!(dt_s >= 0.0, "negative time advance: {dt_s}");
        self.now_s += dt_s;
    }

    /// Advance by the longest of a set of concurrent activities
    /// (e.g. booting n instances in parallel).
    pub fn advance_parallel(&mut self, durations_s: &[f64]) {
        let max = durations_s.iter().cloned().fold(0.0, f64::max);
        self.advance(max);
    }

    /// Run `f`, record the elapsed virtual interval under `label`.
    pub fn span<T>(
        &mut self,
        category: SpanCategory,
        label: &str,
        f: impl FnOnce(&mut Clock) -> T,
    ) -> T {
        let start = self.now_s;
        let out = f(self);
        let end = self.now_s;
        self.timeline.push(Span {
            label: label.to_string(),
            category,
            start_s: start,
            end_s: end,
        });
        out
    }

    /// Record an already-computed duration as a span and advance.
    pub fn record(&mut self, category: SpanCategory, label: &str, dt_s: f64) {
        let start = self.now_s;
        self.advance(dt_s);
        self.timeline.push(Span {
            label: label.to_string(),
            category,
            start_s: start,
            end_s: self.now_s,
        });
    }

    /// Record a span from an explicit earlier start time to now (used
    /// by the coordinator, which interleaves operations on several
    /// sub-objects before closing the span).
    pub fn push_span(&mut self, category: SpanCategory, label: &str, start_s: f64) {
        assert!(start_s <= self.now_s, "span starts in the future");
        self.timeline.push(Span {
            label: label.to_string(),
            category,
            start_s,
            end_s: self.now_s,
        });
    }

    pub fn timeline(&self) -> &[Span] {
        &self.timeline
    }

    /// Total recorded time in one category (for the bar charts).
    pub fn category_total_s(&self, cat: SpanCategory) -> f64 {
        self.timeline
            .iter()
            .filter(|s| s.category == cat)
            .map(Span::duration_s)
            .sum()
    }

    /// Restore a persisted clock position (timeline is not persisted —
    /// the bar-chart spans belong to the run that produced them).
    pub fn restore(&mut self, now_s: f64) {
        self.now_s = now_s;
    }

    /// Drop recorded spans (keep the clock) — used between bench phases.
    pub fn clear_timeline(&mut self) {
        self.timeline.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_monotonically() {
        let mut c = Clock::new();
        c.advance(5.0);
        c.advance(2.5);
        assert_eq!(c.now_s(), 7.5);
    }

    #[test]
    #[should_panic(expected = "negative")]
    fn rejects_negative_advance() {
        Clock::new().advance(-1.0);
    }

    #[test]
    fn parallel_takes_max() {
        let mut c = Clock::new();
        c.advance_parallel(&[3.0, 9.0, 1.0]);
        assert_eq!(c.now_s(), 9.0);
        c.advance_parallel(&[]);
        assert_eq!(c.now_s(), 9.0);
    }

    #[test]
    fn spans_record_intervals() {
        let mut c = Clock::new();
        c.span(SpanCategory::CreateResource, "create hpc_cluster", |c| {
            c.advance(420.0);
        });
        c.record(SpanCategory::TerminateResource, "terminate", 35.0);
        assert_eq!(c.timeline().len(), 2);
        assert_eq!(c.timeline()[0].duration_s(), 420.0);
        assert_eq!(c.category_total_s(SpanCategory::CreateResource), 420.0);
        assert_eq!(c.category_total_s(SpanCategory::TerminateResource), 35.0);
        assert_eq!(c.category_total_s(SpanCategory::Compute), 0.0);
        assert_eq!(c.now_s(), 455.0);
    }
}
