//! Virtual time for the simulated cloud.
//!
//! This host has a single CPU core and no AWS account, so wall-clock
//! measurement of an elastic cluster is impossible (reproduction band
//! 0/5 — see DESIGN.md §2). Instead every simulated operation advances a
//! virtual clock by a modelled duration; parallel activities advance it
//! by the *maximum* of their member durations (span-parallel discrete
//! event accounting). All management-time figures (paper Figs 6–7) and
//! speed-up curves (Fig 4) are read off this clock, while workload
//! numerics are computed for real through the PJRT runtime.

/// A labelled interval on the virtual timeline, used to regenerate the
/// paper's management-time bar charts.
#[derive(Clone, Debug, PartialEq)]
pub struct Span {
    pub label: String,
    pub category: SpanCategory,
    pub start_s: f64,
    pub end_s: f64,
}

impl Span {
    pub fn duration_s(&self) -> f64 {
        self.end_s - self.start_s
    }
}

/// The six bar groups of Figs 6–7, plus compute/other.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SpanCategory {
    CreateResource,
    SubmitToMaster,
    SubmitToAllNodes,
    FetchFromMaster,
    FetchFromAllNodes,
    TerminateResource,
    Compute,
    Other,
}

/// Detailed spans kept verbatim on the timeline. Past this cap new
/// spans fold into per-(category, label) aggregates, so a 1M-job
/// drain (`P2RAC_SCALE_FULL=1`) records bounded memory instead of one
/// `Span` per event while `category_total_s` stays exact.
pub const TIMELINE_DETAIL_CAP: usize = 4096;

/// Distinct (category, label) aggregate keys kept once the detail cap
/// is hit; further new labels fold into `"(other)"`.
const AGG_LABEL_CAP: usize = 512;

/// Where capped-out spans go: total virtual time and span count per
/// (category, label).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SpanAgg {
    /// Summed `duration_s` of the folded spans.
    pub total_s: f64,
    /// How many spans folded into this key.
    pub count: u64,
}

/// Virtual clock + recorded timeline (bounded: detailed up to
/// [`TIMELINE_DETAIL_CAP`] spans, aggregated past it).
#[derive(Debug, Default)]
pub struct Clock {
    now_s: f64,
    timeline: Vec<Span>,
    aggregates: std::collections::BTreeMap<(SpanCategory, String), SpanAgg>,
    /// Incremental per-category totals over *every* recorded span,
    /// detailed or aggregated — the single source of
    /// `category_total_s`, maintained in each push path.
    totals: std::collections::BTreeMap<SpanCategory, f64>,
    total_spans: u64,
}

impl Clock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current virtual time in seconds since simulation start.
    pub fn now_s(&self) -> f64 {
        self.now_s
    }

    /// Advance by `dt` seconds (sequential activity).
    pub fn advance(&mut self, dt_s: f64) {
        assert!(dt_s >= 0.0, "negative time advance: {dt_s}");
        self.now_s += dt_s;
    }

    /// Advance by the longest of a set of concurrent activities
    /// (e.g. booting n instances in parallel).
    pub fn advance_parallel(&mut self, durations_s: &[f64]) {
        let max = durations_s.iter().cloned().fold(0.0, f64::max);
        self.advance(max);
    }

    /// Run `f`, record the elapsed virtual interval under `label`.
    pub fn span<T>(
        &mut self,
        category: SpanCategory,
        label: &str,
        f: impl FnOnce(&mut Clock) -> T,
    ) -> T {
        let start = self.now_s;
        let out = f(self);
        let end = self.now_s;
        self.push(category, label, start, end);
        out
    }

    /// Record an already-computed duration as a span and advance.
    pub fn record(&mut self, category: SpanCategory, label: &str, dt_s: f64) {
        let start = self.now_s;
        self.advance(dt_s);
        self.push(category, label, start, self.now_s);
    }

    /// Record a span from an explicit earlier start time to now (used
    /// by the coordinator, which interleaves operations on several
    /// sub-objects before closing the span).
    pub fn push_span(&mut self, category: SpanCategory, label: &str, start_s: f64) {
        assert!(start_s <= self.now_s, "span starts in the future");
        self.push(category, label, start_s, self.now_s);
    }

    /// The single recording path behind `span`/`record`/`push_span`:
    /// the per-category total is always updated exactly; the span
    /// itself stays detailed below [`TIMELINE_DETAIL_CAP`] and folds
    /// into the (category, label) aggregates past it.
    fn push(&mut self, category: SpanCategory, label: &str, start_s: f64, end_s: f64) {
        *self.totals.entry(category).or_insert(0.0) += end_s - start_s;
        self.total_spans += 1;
        if self.timeline.len() < TIMELINE_DETAIL_CAP {
            self.timeline.push(Span {
                label: label.to_string(),
                category,
                start_s,
                end_s,
            });
            return;
        }
        let key = (category, label.to_string());
        let agg = if self.aggregates.contains_key(&key) || self.aggregates.len() < AGG_LABEL_CAP {
            self.aggregates.entry(key).or_default()
        } else {
            self.aggregates.entry((category, "(other)".to_string())).or_default()
        };
        agg.total_s += end_s - start_s;
        agg.count += 1;
    }

    /// The detailed (pre-cap) spans.
    pub fn timeline(&self) -> &[Span] {
        &self.timeline
    }

    /// Post-cap spans, aggregated per (category, label).
    pub fn aggregated(&self) -> &std::collections::BTreeMap<(SpanCategory, String), SpanAgg> {
        &self.aggregates
    }

    /// Every span ever recorded since the last `clear_timeline`,
    /// detailed or aggregated.
    pub fn total_spans(&self) -> u64 {
        self.total_spans
    }

    /// Total recorded time in one category (for the bar charts).
    /// Exact whether or not the detail cap was hit, and O(log n).
    pub fn category_total_s(&self, cat: SpanCategory) -> f64 {
        self.totals.get(&cat).copied().unwrap_or(0.0)
    }

    /// Restore a persisted clock position (timeline is not persisted —
    /// the bar-chart spans belong to the run that produced them).
    pub fn restore(&mut self, now_s: f64) {
        self.now_s = now_s;
    }

    /// Drop recorded spans (keep the clock) — used between bench phases.
    pub fn clear_timeline(&mut self) {
        self.timeline.clear();
        self.aggregates.clear();
        self.totals.clear();
        self.total_spans = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advances_monotonically() {
        let mut c = Clock::new();
        c.advance(5.0);
        c.advance(2.5);
        assert_eq!(c.now_s(), 7.5);
    }

    #[test]
    #[should_panic(expected = "negative")]
    fn rejects_negative_advance() {
        Clock::new().advance(-1.0);
    }

    #[test]
    fn parallel_takes_max() {
        let mut c = Clock::new();
        c.advance_parallel(&[3.0, 9.0, 1.0]);
        assert_eq!(c.now_s(), 9.0);
        c.advance_parallel(&[]);
        assert_eq!(c.now_s(), 9.0);
    }

    #[test]
    fn spans_record_intervals() {
        let mut c = Clock::new();
        c.span(SpanCategory::CreateResource, "create hpc_cluster", |c| {
            c.advance(420.0);
        });
        c.record(SpanCategory::TerminateResource, "terminate", 35.0);
        assert_eq!(c.timeline().len(), 2);
        assert_eq!(c.timeline()[0].duration_s(), 420.0);
        assert_eq!(c.category_total_s(SpanCategory::CreateResource), 420.0);
        assert_eq!(c.category_total_s(SpanCategory::TerminateResource), 35.0);
        assert_eq!(c.category_total_s(SpanCategory::Compute), 0.0);
        assert_eq!(c.now_s(), 455.0);
    }

    #[test]
    fn timeline_caps_but_totals_stay_exact() {
        let mut c = Clock::new();
        let n = TIMELINE_DETAIL_CAP + 100;
        for i in 0..n {
            // Few distinct labels: post-cap spans aggregate per label.
            c.record(SpanCategory::Compute, &format!("slice on fleet{}", i % 3), 2.0);
        }
        assert_eq!(c.timeline().len(), TIMELINE_DETAIL_CAP, "detail is bounded");
        assert_eq!(c.total_spans(), n as u64);
        let agg_count: u64 = c.aggregated().values().map(|a| a.count).sum();
        assert_eq!(agg_count, 100, "overflow lands in aggregates");
        let agg_total: f64 = c.aggregated().values().map(|a| a.total_s).sum();
        assert_eq!(agg_total, 200.0);
        // The bar-chart total never loses a span to the cap.
        assert_eq!(c.category_total_s(SpanCategory::Compute), 2.0 * n as f64);
        c.clear_timeline();
        assert_eq!(c.total_spans(), 0);
        assert!(c.aggregated().is_empty());
        assert_eq!(c.category_total_s(SpanCategory::Compute), 0.0);
    }

    #[test]
    fn aggregate_labels_fold_to_other_past_their_cap() {
        let mut c = Clock::new();
        for i in 0..(TIMELINE_DETAIL_CAP + 600) {
            // Every label unique: the aggregate key set itself must
            // stay bounded by folding the tail into "(other)".
            c.record(SpanCategory::Other, &format!("op-{i}"), 1.0);
        }
        assert!(c.aggregated().len() <= 513, "got {}", c.aggregated().len());
        let other = c
            .aggregated()
            .get(&(SpanCategory::Other, "(other)".to_string()))
            .copied()
            .unwrap();
        assert_eq!(other.count, 600 - 512);
        assert_eq!(
            c.category_total_s(SpanCategory::Other),
            (TIMELINE_DETAIL_CAP + 600) as f64
        );
    }
}
