//! Rolling (weak) and strong checksums for the rsync algorithm.
//!
//! The weak checksum is Adler-32-style (rsync's original), cheap to
//! slide one byte at a time across the receiver's view of a file. The
//! strong checksum is FNV-1a-128 folded — not cryptographic, but with a
//! 64-bit output the collision probability across the block counts seen
//! here is negligible, and it keeps the build dependency-free.

/// rsync's weak rolling checksum over a window of bytes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Rolling {
    a: u32,
    b: u32,
    len: usize,
}

const MOD: u32 = 1 << 16;

impl Rolling {
    /// Checksum of a full block.
    pub fn of(block: &[u8]) -> Self {
        let mut a: u32 = 0;
        let mut b: u32 = 0;
        let n = block.len() as u32;
        for (i, &x) in block.iter().enumerate() {
            a = (a + x as u32) % MOD;
            b = (b + (n - i as u32) * x as u32) % MOD;
        }
        Self {
            a,
            b,
            len: block.len(),
        }
    }

    /// Slide the window one byte: drop `out`, append `inn`.
    pub fn roll(&mut self, out: u8, inn: u8) {
        let n = self.len as u32;
        self.a = (self.a + MOD - out as u32 + inn as u32) % MOD;
        self.b = (self.b + MOD - (n * out as u32) % MOD + self.a) % MOD;
        // NOTE: the classic formulation updates b using the *new* a.
    }

    pub fn digest(&self) -> u32 {
        self.a | (self.b << 16)
    }
}

/// 64-bit strong hash (FNV-1a with avalanche finisher).
pub fn strong_hash(block: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in block {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    // splitmix finisher to decorrelate short inputs.
    h = (h ^ (h >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    h = (h ^ (h >> 27)).wrapping_mul(0x94D049BB133111EB);
    h ^ (h >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rolling_matches_recompute() {
        // Sliding across a buffer must equal recomputing from scratch.
        let data: Vec<u8> = (0..200u32).map(|i| (i * 37 % 251) as u8).collect();
        let w = 16;
        let mut r = Rolling::of(&data[0..w]);
        for start in 1..(data.len() - w) {
            r.roll(data[start - 1], data[start + w - 1]);
            let fresh = Rolling::of(&data[start..start + w]);
            assert_eq!(r.digest(), fresh.digest(), "mismatch at offset {start}");
        }
    }

    #[test]
    fn different_blocks_differ_mostly() {
        let a = Rolling::of(b"hello world blok").digest();
        let b = Rolling::of(b"hello world blov").digest();
        assert_ne!(a, b);
    }

    #[test]
    fn strong_hash_sensitivity() {
        let h1 = strong_hash(b"block contents A");
        let h2 = strong_hash(b"block contents B");
        assert_ne!(h1, h2);
        assert_eq!(strong_hash(b""), strong_hash(b""));
    }

    #[test]
    fn empty_block() {
        let r = Rolling::of(b"");
        assert_eq!(r.digest(), 0);
    }
}
