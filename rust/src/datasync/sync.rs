//! Directory synchronisation between two simulated sites (Analyst
//! workstation ↔ instance) using the rsync algorithm from
//! [`super::delta`], with an SCP-style full-copy baseline for the
//! paper's rsync-vs-SCP design choice (§3.2.1: "rsync … transfers data
//! quicker than SCP [and] in subsequent data transfers only
//! synchronises the data changed at the source").
//!
//! The functions mutate real bytes in the destination [`Vfs`] and return
//! a [`SyncReport`] with the wire-byte counts; the caller converts those
//! to virtual time through the [`NetworkModel`] and advances the clock.

use super::delta::{apply_delta, compute_delta, signature};
use super::rolling::strong_hash;
use crate::simcloud::network::{Link, NetworkModel};
use crate::simcloud::vfs::Vfs;
use crate::simcloud::FaultPlan;

/// Wire cost of one block signature (index + weak + strong).
const SIG_ENTRY_BYTES: u64 = 20;
/// Default rsync block length.
pub const DEFAULT_BLOCK_LEN: usize = 2048;

/// Transfer protocol choice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Protocol {
    /// Full-file copy every time (the baseline the paper rejected).
    Scp,
    /// Block-delta sync (what P2RAC uses).
    Rsync,
}

#[derive(Clone, Debug, Default, PartialEq)]
pub struct SyncReport {
    pub files_examined: usize,
    pub files_sent: usize,
    pub files_unchanged: usize,
    /// Bytes of new content that crossed the wire.
    pub literal_bytes: u64,
    /// Bytes reconstructed from data already at the destination.
    pub matched_bytes: u64,
    /// Signature/metadata chatter that crossed the wire.
    pub protocol_bytes: u64,
    /// Modelled wall time of the transfer, seconds.
    pub elapsed_s: f64,
}

impl SyncReport {
    pub fn wire_bytes(&self) -> u64 {
        self.literal_bytes + self.protocol_bytes
    }
}

#[derive(Debug)]
pub enum SyncError {
    Interrupted {
        synced: usize,
        total: usize,
        partial: SyncReport,
    },
    EmptySource(String),
}

impl std::fmt::Display for SyncError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SyncError::Interrupted { synced, total, .. } => {
                write!(f, "transfer interrupted after {synced} of {total} files")
            }
            SyncError::EmptySource(d) => {
                write!(f, "source directory '{d}' does not exist or is empty")
            }
        }
    }
}

impl std::error::Error for SyncError {}

/// Synchronise `src_dir` (in `src`) into `dst_dir` (in `dst`).
///
/// `faults` may inject a mid-flight interruption: files synced before
/// the cut stay applied (so a retry benefits from rsync's delta reuse),
/// and the error carries the partial report.
#[allow(clippy::too_many_arguments)]
pub fn sync_dir(
    src: &Vfs,
    src_dir: &str,
    dst: &mut Vfs,
    dst_dir: &str,
    protocol: Protocol,
    block_len: usize,
    net: &NetworkModel,
    link: Link,
    faults: &mut FaultPlan,
) -> Result<SyncReport, SyncError> {
    let files = src.list_dir(src_dir);
    if files.is_empty() {
        return Err(SyncError::EmptySource(src_dir.to_string()));
    }
    let interrupt_at = if faults.take_transfer_interrupt() {
        Some(files.len() / 2)
    } else {
        None
    };

    let mut rep = SyncReport {
        files_examined: files.len(),
        ..SyncReport::default()
    };

    for (i, rel) in files.iter().enumerate() {
        if interrupt_at == Some(i) {
            rep.elapsed_s = net.transfer_s(rep.wire_bytes(), rep.files_sent, link);
            let total = files.len();
            return Err(SyncError::Interrupted {
                synced: i,
                total,
                partial: rep,
            });
        }
        let src_path = format!("{src_dir}/{rel}");
        let dst_path = format!("{dst_dir}/{rel}");
        let new_data = src.read(&src_path).expect("listed file exists");
        let old_data = dst.read(&dst_path);

        match protocol {
            Protocol::Scp => {
                // SCP always ships the whole file.
                rep.literal_bytes += new_data.len() as u64;
                rep.files_sent += 1;
                dst.write(&dst_path, new_data.to_vec());
            }
            Protocol::Rsync => {
                match old_data {
                    Some(old) if strong_hash(old) == strong_hash(new_data) && old == new_data => {
                        // Quick-check: unchanged file, metadata chatter only.
                        rep.files_unchanged += 1;
                        rep.protocol_bytes += 64;
                    }
                    Some(old) => {
                        let sig = signature(old, block_len);
                        rep.protocol_bytes += 64 + sig.blocks.len() as u64 * SIG_ENTRY_BYTES;
                        let delta = compute_delta(new_data, &sig);
                        rep.literal_bytes += delta.literal_bytes;
                        rep.matched_bytes += delta.matched_bytes;
                        let rebuilt = apply_delta(old, &delta);
                        debug_assert_eq!(rebuilt, new_data);
                        dst.write(&dst_path, rebuilt);
                        rep.files_sent += 1;
                    }
                    None => {
                        // New file: all literal.
                        rep.protocol_bytes += 64;
                        rep.literal_bytes += new_data.len() as u64;
                        dst.write(&dst_path, new_data.to_vec());
                        rep.files_sent += 1;
                    }
                }
            }
        }
    }

    rep.elapsed_s = net.transfer_s(rep.wire_bytes(), rep.files_sent.max(1), link);
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcloud::SimParams;

    fn net() -> NetworkModel {
        NetworkModel::new(SimParams::default())
    }

    fn project(seed: u8, nbytes: usize) -> Vfs {
        let mut v = Vfs::new();
        v.write("proj/script.json", br#"{"type":"mc_sweep"}"#.to_vec());
        v.write(
            "proj/data/events.bin",
            (0..nbytes).map(|i| ((i as u64 * 31 + seed as u64) % 251) as u8).collect::<Vec<u8>>(),
        );
        v.write("proj/data/params.csv", vec![seed; 300]);
        v
    }

    #[test]
    fn initial_sync_copies_everything() {
        let src = project(1, 10_000);
        let mut dst = Vfs::new();
        let mut f = FaultPlan::none();
        let rep = sync_dir(
            &src, "proj", &mut dst, "home/proj",
            Protocol::Rsync, 512, &net(), Link::Wan, &mut f,
        )
        .unwrap();
        assert_eq!(rep.files_sent, 3);
        assert_eq!(rep.files_unchanged, 0);
        assert_eq!(dst.read("home/proj/script.json"), src.read("proj/script.json"));
        assert!(rep.literal_bytes >= 10_000);
        assert!(rep.elapsed_s > 0.0);
    }

    #[test]
    fn resync_of_unchanged_project_is_nearly_free() {
        let src = project(1, 100_000);
        let mut dst = Vfs::new();
        let mut f = FaultPlan::none();
        let first = sync_dir(
            &src, "proj", &mut dst, "home/proj",
            Protocol::Rsync, 512, &net(), Link::Wan, &mut f,
        )
        .unwrap();
        let second = sync_dir(
            &src, "proj", &mut dst, "home/proj",
            Protocol::Rsync, 512, &net(), Link::Wan, &mut f,
        )
        .unwrap();
        assert_eq!(second.files_unchanged, 3);
        assert_eq!(second.literal_bytes, 0);
        assert!(second.wire_bytes() < first.wire_bytes() / 100);
    }

    #[test]
    fn rsync_beats_scp_on_resync_but_not_first_copy() {
        let mut src = project(1, 200_000);
        let mut dst_r = Vfs::new();
        let mut dst_s = Vfs::new();
        let mut f = FaultPlan::none();
        let n = net();
        sync_dir(&src, "proj", &mut dst_r, "p", Protocol::Rsync, 2048, &n, Link::Wan, &mut f).unwrap();
        sync_dir(&src, "proj", &mut dst_s, "p", Protocol::Scp, 2048, &n, Link::Wan, &mut f).unwrap();
        // Small edit, then re-sync both ways.
        let mut data = src.read("proj/data/events.bin").unwrap().to_vec();
        data[1000] ^= 0xAA;
        src.write("proj/data/events.bin", data);
        let r = sync_dir(&src, "proj", &mut dst_r, "p", Protocol::Rsync, 2048, &n, Link::Wan, &mut f).unwrap();
        let s = sync_dir(&src, "proj", &mut dst_s, "p", Protocol::Scp, 2048, &n, Link::Wan, &mut f).unwrap();
        assert!(
            r.wire_bytes() * 10 < s.wire_bytes(),
            "rsync {} should be ≪ scp {}",
            r.wire_bytes(),
            s.wire_bytes()
        );
        assert_eq!(dst_r.read("p/data/events.bin"), dst_s.read("p/data/events.bin"));
    }

    #[test]
    fn empty_source_is_an_error() {
        let src = Vfs::new();
        let mut dst = Vfs::new();
        let mut f = FaultPlan::none();
        assert!(matches!(
            sync_dir(&src, "nope", &mut dst, "p", Protocol::Rsync, 512, &net(), Link::Wan, &mut f),
            Err(SyncError::EmptySource(_))
        ));
    }

    #[test]
    fn interrupted_transfer_retries_cheaply() {
        let src = project(2, 150_000);
        let mut dst = Vfs::new();
        let mut f = FaultPlan {
            transfer_interrupts: 1,
            ..FaultPlan::none()
        };
        let n = net();
        let err = sync_dir(&src, "proj", &mut dst, "p", Protocol::Rsync, 1024, &n, Link::Wan, &mut f)
            .unwrap_err();
        let SyncError::Interrupted { synced, total, .. } = err else {
            panic!("expected interruption");
        };
        assert!(synced < total);
        // Retry completes; files already shipped are skipped as unchanged.
        let rep = sync_dir(&src, "proj", &mut dst, "p", Protocol::Rsync, 1024, &n, Link::Wan, &mut f)
            .unwrap();
        assert_eq!(rep.files_unchanged, synced);
        assert_eq!(dst.read("p/data/events.bin"), src.read("proj/data/events.bin"));
    }

    #[test]
    fn property_sync_makes_dirs_identical() {
        crate::util::quickprop::check("sync_dir convergence", 40, |g| {
            let mut src = Vfs::new();
            let nfiles = g.usize(1..6);
            for i in 0..nfiles {
                src.write(&format!("proj/f{i}"), g.bytes(0, 4096));
            }
            let mut dst = Vfs::new();
            // Optionally pre-populate dst with stale versions.
            if g.bool() {
                for i in 0..nfiles {
                    if g.bool() {
                        dst.write(&format!("p/f{i}"), g.bytes(0, 4096));
                    }
                }
            }
            let mut f = FaultPlan::none();
            let n = NetworkModel::new(SimParams::default());
            sync_dir(&src, "proj", &mut dst, "p", Protocol::Rsync, 256, &n, Link::Wan, &mut f)
                .unwrap();
            for i in 0..nfiles {
                assert_eq!(
                    dst.read(&format!("p/f{i}")),
                    src.read(&format!("proj/f{i}")),
                    "file f{i} differs after sync"
                );
            }
        });
    }
}
