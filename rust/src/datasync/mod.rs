//! rsync-algorithm data synchronisation (paper §3.2: data management).
//!
//! P2RAC moves Analyst project directories to cloud resources with a
//! block-delta protocol: rolling weak checksum + strong hash block
//! matching ([`rolling`], [`delta`]) and a directory-level sync driver
//! with an SCP full-copy baseline ([`sync`]). All of it operates on real
//! bytes in the simulated filesystems; only the *wire time* comes from
//! the network model.

pub mod delta;
pub mod rolling;
pub mod sync;

pub use delta::{apply_delta, compute_delta, signature, Delta, Signature, Token};
pub use sync::{sync_dir, Protocol, SyncError, SyncReport, DEFAULT_BLOCK_LEN};
