//! The rsync algorithm: block signatures, delta computation and delta
//! application.
//!
//! The receiver (cloud instance) publishes per-block signatures of the
//! file it already holds; the sender (Analyst site) slides a window over
//! its copy, emitting `Copy` tokens for blocks the receiver already has
//! and `Literal` bytes otherwise. This is why P2RAC chose rsync over
//! SCP (paper §3.2.1): re-synchronising a project after a small edit
//! moves only the changed blocks.

use super::rolling::{strong_hash, Rolling};
use std::collections::HashMap;

/// Signature of one receiver-side block.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockSig {
    pub index: usize,
    pub weak: u32,
    pub strong: u64,
}

/// Per-file signature set.
#[derive(Clone, Debug)]
pub struct Signature {
    pub block_len: usize,
    pub blocks: Vec<BlockSig>,
    pub total_len: usize,
}

/// One token of a delta stream.
#[derive(Clone, Debug, PartialEq)]
pub enum Token {
    /// Receiver already holds this block — copy it locally.
    Copy { block_index: usize },
    /// Fresh bytes the receiver lacks.
    Literal(Vec<u8>),
}

/// A computed delta plus the statistics the sync layer bills time for.
#[derive(Clone, Debug)]
pub struct Delta {
    pub block_len: usize,
    pub tokens: Vec<Token>,
    /// Bytes of literal payload that must cross the wire.
    pub literal_bytes: u64,
    /// Bytes satisfied from the receiver's existing copy.
    pub matched_bytes: u64,
}

/// Compute the signature of the receiver's current file contents.
pub fn signature(data: &[u8], block_len: usize) -> Signature {
    assert!(block_len > 0);
    let mut blocks = Vec::with_capacity(data.len().div_ceil(block_len));
    for (index, chunk) in data.chunks(block_len).enumerate() {
        blocks.push(BlockSig {
            index,
            weak: Rolling::of(chunk).digest(),
            strong: strong_hash(chunk),
        });
    }
    Signature {
        block_len,
        blocks,
        total_len: data.len(),
    }
}

/// Compute the delta that turns the receiver's file (described by `sig`)
/// into `new_data`. Only full-length blocks are matched (rsync matches
/// the trailing short block too; we emit it as literal for simplicity —
/// a bounded waste of < block_len bytes per file).
pub fn compute_delta(new_data: &[u8], sig: &Signature) -> Delta {
    let bl = sig.block_len;
    // weak → candidate blocks (handle collisions).
    let mut index: HashMap<u32, Vec<&BlockSig>> = HashMap::with_capacity(sig.blocks.len());
    for b in &sig.blocks {
        // Only full blocks are matchable by the sliding window.
        let is_full = (b.index + 1) * bl <= sig.total_len;
        if is_full {
            index.entry(b.weak).or_default().push(b);
        }
    }

    let mut tokens: Vec<Token> = Vec::new();
    let mut literal: Vec<u8> = Vec::new();
    let mut literal_bytes = 0u64;
    let mut matched_bytes = 0u64;

    let flush = |literal: &mut Vec<u8>, tokens: &mut Vec<Token>| {
        if !literal.is_empty() {
            tokens.push(Token::Literal(std::mem::take(literal)));
        }
    };

    if new_data.len() < bl || index.is_empty() {
        literal_bytes = new_data.len() as u64;
        if !new_data.is_empty() {
            tokens.push(Token::Literal(new_data.to_vec()));
        }
        return Delta {
            block_len: bl,
            tokens,
            literal_bytes,
            matched_bytes,
        };
    }

    let mut pos = 0usize;
    let mut roll = Rolling::of(&new_data[0..bl]);
    loop {
        let mut matched = None;
        if let Some(cands) = index.get(&roll.digest()) {
            let strong = strong_hash(&new_data[pos..pos + bl]);
            if let Some(hit) = cands.iter().find(|c| c.strong == strong) {
                matched = Some(hit.index);
            }
        }
        if let Some(block_index) = matched {
            flush(&mut literal, &mut tokens);
            tokens.push(Token::Copy { block_index });
            matched_bytes += bl as u64;
            pos += bl;
            if pos + bl > new_data.len() {
                break;
            }
            roll = Rolling::of(&new_data[pos..pos + bl]);
        } else {
            literal.push(new_data[pos]);
            literal_bytes += 1;
            if pos + bl >= new_data.len() {
                pos += 1;
                break;
            }
            roll.roll(new_data[pos], new_data[pos + bl]);
            pos += 1;
        }
    }
    // Tail that never fit a full window.
    if pos < new_data.len() {
        literal.extend_from_slice(&new_data[pos..]);
        literal_bytes += (new_data.len() - pos) as u64;
    }
    flush(&mut literal, &mut tokens);

    Delta {
        block_len: bl,
        tokens,
        literal_bytes,
        matched_bytes,
    }
}

/// Apply a delta against the receiver's old contents.
pub fn apply_delta(old_data: &[u8], delta: &Delta) -> Vec<u8> {
    let bl = delta.block_len;
    let mut out = Vec::with_capacity(old_data.len());
    for t in &delta.tokens {
        match t {
            Token::Copy { block_index } => {
                let start = block_index * bl;
                let end = (start + bl).min(old_data.len());
                out.extend_from_slice(&old_data[start..end]);
            }
            Token::Literal(bytes) => out.extend_from_slice(bytes),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    fn roundtrip(old: &[u8], new: &[u8], bl: usize) -> Delta {
        let sig = signature(old, bl);
        let d = compute_delta(new, &sig);
        let rebuilt = apply_delta(old, &d);
        assert_eq!(rebuilt, new, "delta round-trip failed");
        d
    }

    #[test]
    fn identical_files_send_no_literals() {
        let mut r = Xoshiro256::seed_from_u64(1);
        let data: Vec<u8> = (0..4096).map(|_| r.next_u32() as u8).collect();
        let d = roundtrip(&data, &data, 512);
        assert_eq!(d.literal_bytes, 0);
        assert_eq!(d.matched_bytes, 4096);
    }

    #[test]
    fn small_edit_sends_small_delta() {
        let mut r = Xoshiro256::seed_from_u64(2);
        let old: Vec<u8> = (0..64 * 1024).map(|_| r.next_u32() as u8).collect();
        let mut new = old.clone();
        // Edit 10 bytes in the middle.
        for i in 0..10 {
            new[30_000 + i] ^= 0xFF;
        }
        let d = roundtrip(&old, &new, 1024);
        // rsync property: literals ≈ one damaged block, not the file.
        assert!(
            d.literal_bytes <= 2 * 1024,
            "literal {} should be ~1 block",
            d.literal_bytes
        );
    }

    #[test]
    fn insertion_resyncs_alignment() {
        let mut r = Xoshiro256::seed_from_u64(3);
        let old: Vec<u8> = (0..32 * 1024).map(|_| r.next_u32() as u8).collect();
        let mut new = old.clone();
        new.splice(10_000..10_000, [1u8, 2, 3].iter().cloned());
        let d = roundtrip(&old, &new, 512);
        // The rolling window must re-find alignment after the insert:
        // most of the file still matches.
        assert!(
            d.matched_bytes > 28 * 1024,
            "matched {} too low after insertion",
            d.matched_bytes
        );
    }

    #[test]
    fn empty_and_fresh_files() {
        let d = roundtrip(b"", b"brand new content", 8);
        assert_eq!(d.literal_bytes, 17);
        let d2 = roundtrip(b"whatever", b"", 4);
        assert_eq!(d2.literal_bytes, 0);
        assert!(d2.tokens.is_empty());
    }

    #[test]
    fn short_file_below_block_len() {
        roundtrip(b"abc", b"abcd", 16);
    }

    #[test]
    fn property_random_edits_roundtrip() {
        crate::util::quickprop::check("rsync delta round-trip", 60, |g| {
            let old = g.bytes(0, 8192);
            let mut new = old.clone();
            // random edits: flips, truncation, append
            if !new.is_empty() && g.bool() {
                let at = g.usize(0..new.len());
                new[at] ^= 0x5A;
            }
            if g.bool() {
                let extra = g.bytes(0, 512);
                new.extend_from_slice(&extra);
            }
            if !new.is_empty() && g.weighted(0.3) {
                let keep = g.usize(0..new.len());
                new.truncate(keep);
            }
            let bl = *g.pick(&[64usize, 128, 701]);
            let sig = signature(&old, bl);
            let d = compute_delta(&new, &sig);
            assert_eq!(apply_delta(&old, &d), new);
            assert_eq!(d.literal_bytes + d.matched_bytes, new.len() as u64);
        });
    }
}
