//! The P2RAC command-line interface — every tool from the paper's §3 as
//! a subcommand of the `p2rac` binary, with the session (simulated
//! cloud + Analyst site) persisted between invocations under
//! `$P2RAC_HOME` (default `./.p2rac_session`), so the workflows of
//! Figs 2–3 replay exactly as printed in the paper:
//!
//! ```text
//! p2rac ec2configurep2rac
//! p2rac mkproject -projectdir catopt_proj -kind catopt
//! p2rac ec2createcluster -cname hpc_cluster -csize 4 -type m2.2xlarge
//! p2rac ec2senddatatoclusternodes -cname hpc_cluster -projectdir catopt_proj
//! p2rac ec2runoncluster -cname hpc_cluster -projectdir catopt_proj \
//!       -rscript catopt.json -runname trial1 -bynode
//! p2rac ec2getresults -cname hpc_cluster -projectdir catopt_proj \
//!       -runname trial1 -frommaster
//! p2rac ec2terminatecluster -cname hpc_cluster
//! ```

pub mod commands;
pub mod data;
pub mod functions;
pub mod jobs;
pub mod obs;
pub mod resources;

use crate::analytics::P2racEngine;
use crate::coordinator::{ScriptEngine, Session};
use crate::jobs::{AutoscalerConfig, FnPlatform, JobScheduler, QuotaBook};
use crate::runtime::Runtime;
use crate::simcloud::SimParams;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::Arc;

/// Where the persisted session lives.
pub fn session_dir() -> PathBuf {
    std::env::var("P2RAC_HOME")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(".p2rac_session"))
}

fn session_path() -> PathBuf {
    session_dir().join("session.json")
}

/// Build the production engine: PJRT artifacts when present, otherwise
/// the pure-Rust fallback (still a complete implementation).
pub fn make_engine() -> Box<dyn ScriptEngine> {
    let dir = std::env::var("P2RAC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"));
    if dir.join("manifest.json").exists() {
        match Runtime::load(&dir) {
            Ok(rt) => return Box::new(P2racEngine::with_runtime(Arc::new(rt))),
            Err(e) => {
                crate::log_warn!("artifacts unusable ({e:#}); falling back to rust backend");
            }
        }
    }
    Box::new(P2racEngine::rust_only())
}

/// Load the persisted session, or create a fresh one.
pub fn load_session(engine: Box<dyn ScriptEngine>) -> Result<Session> {
    let path = session_path();
    if path.exists() {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("corrupt session: {e}"))?;
        Session::from_json(SimParams::default(), engine, &j)
    } else {
        Ok(Session::new(SimParams::default(), engine))
    }
}

/// Persist the session. Also the telemetry flush point: buffered
/// JSONL trace lines reach their `-trace` file exactly when the
/// session state they describe reaches disk.
pub fn save_session(session: &Session) -> Result<()> {
    let dir = session_dir();
    std::fs::create_dir_all(&dir)?;
    if let Err(e) = session.cloud.telemetry.flush() {
        crate::log_warn!("telemetry trace flush failed: {e}");
    }
    std::fs::write(session_path(), session.to_json().to_string_compact())
        .with_context(|| format!("writing {}", session_path().display()))
}

fn quotas_path() -> PathBuf {
    session_dir().join("quotas.json")
}

/// Load the persisted job-queue/autoscaler state (plus the tenant
/// quota book persisted beside it), or a fresh default. Reads the
/// snapshot + append log via [`crate::jobs::persist`]; legacy
/// `jobs.json`-only session directories load unchanged.
pub fn load_jobs() -> Result<JobScheduler> {
    let dir = session_dir();
    let mut js = crate::jobs::persist::load(&dir)
        .with_context(|| format!("loading jobs state from {}", dir.display()))?
        .unwrap_or_else(|| JobScheduler::new(AutoscalerConfig::default()));
    let qpath = quotas_path();
    if qpath.exists() {
        let text = std::fs::read_to_string(&qpath)
            .with_context(|| format!("reading {}", qpath.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("corrupt quota book: {e}"))?;
        js.quotas = QuotaBook::from_json(&j)?;
    }
    Ok(js)
}

/// Persist the job-queue/autoscaler state and the tenant quota book.
/// Jobs persist through the append log (O(mutated jobs) per command,
/// periodically compacted); the small quota book still rewrites. The
/// wall-clock cost lands in the scheduler's `persist` profile phase.
pub fn save_jobs(js: &mut JobScheduler) -> Result<()> {
    let t0 = std::time::Instant::now();
    let dir = session_dir();
    std::fs::create_dir_all(&dir)?;
    crate::jobs::persist::save(&dir, js)
        .with_context(|| format!("saving jobs state to {}", dir.display()))?;
    std::fs::write(quotas_path(), js.quotas.to_json().to_string_compact())
        .with_context(|| format!("writing {}", quotas_path().display()))?;
    js.profiler.add(crate::telemetry::Phase::Persist, t0.elapsed());
    Ok(())
}

/// Load the persisted serverless function platform (snapshot + append
/// log via [`crate::jobs::functions::persist`]), or a fresh default.
pub fn load_fns() -> Result<FnPlatform> {
    let dir = session_dir();
    Ok(crate::jobs::functions::persist::load(&dir)
        .with_context(|| format!("loading functions state from {}", dir.display()))?
        .unwrap_or_default())
}

/// Persist the serverless function platform through its append log.
pub fn save_fns(fns: &mut FnPlatform) -> Result<()> {
    let dir = session_dir();
    std::fs::create_dir_all(&dir)?;
    crate::jobs::functions::persist::save(&dir, fns)
        .with_context(|| format!("saving functions state to {}", dir.display()))
}

/// Entry point used by `main.rs`; returns the process exit code.
pub fn main_entry(args: Vec<String>) -> i32 {
    crate::util::logger::init();
    let Some((cmd, rest)) = args.split_first() else {
        eprintln!("{}", commands::global_help());
        return 2;
    };
    match commands::dispatch(cmd, rest.to_vec()) {
        Ok(output) => {
            if !output.is_empty() {
                println!("{output}");
            }
            0
        }
        Err(e) => {
            eprintln!("p2rac: {e:#}");
            1
        }
    }
}
