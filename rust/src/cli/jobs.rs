//! Jobs domain: script execution, the elastic job queue, DAG
//! workflows, the autoscaler and per-tenant governance. The legacy
//! `ec2submitjob` flags are a thin parse layer over
//! [`crate::jobs::JobSpecBuilder`]; `-after` and `-specfile` grow the
//! same command into the DAG workflow surface (stages admitted Held
//! until their parents complete — see `jobs::dag`).

use std::collections::BTreeMap;

use super::commands::{json_envelope, pick_script, project_dir, report, CmdCtx, Command};
use crate::coordinator::{table1_desktops, Placement, Session};
use crate::jobs::{
    parse_deadline, BidStrategy, JobId, JobScheduler, JobSpecBuilder, JobState, Priority,
    ScalePolicy, WorkflowSpec,
};
use crate::util::argparse::{CommandSpec, ParsedArgs};
use crate::util::humanfmt;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};

/// The jobs / execution command domain.
pub struct Jobs;

impl Command for Jobs {
    fn domain(&self) -> &'static str {
        "jobs"
    }

    fn specs(&self) -> Vec<CommandSpec> {
        vec![
            CommandSpec::new("ec2runoninstance", "execute a script on an instance (locks it)")
                .value_arg("iname", "target instance")
                .value_arg("projectdir", "project directory")
                .value_arg("rscript", "script to execute from the project directory")
                .value_arg("threads", "real worker threads for the engine (default: all cores)")
                .required_arg("runname", "name for this run"),
            CommandSpec::new("ec2runoncluster", "execute a script on a cluster (locks it)")
                .value_arg("cname", "target cluster")
                .value_arg("projectdir", "project directory")
                .value_arg("rscript", "script to execute")
                .value_arg("threads", "real worker threads for the engine (default: all cores)")
                .required_arg("runname", "name for this run")
                .switch_arg("bynode", "round-robin slave placement (default)")
                .switch_arg("byslot", "fill each node's cores before the next")
                .exclusive(&["bynode", "byslot"]),
            CommandSpec::new("ec2submitjob", "queue an analytics job (or a workflow DAG) for the elastic fleet")
                .value_arg("projectdir", "project directory at the Analyst site")
                .value_arg("rscript", "script to execute from the project directory")
                .value_arg("priority", "low | normal | high (default normal)")
                .value_arg("analyst", "tenant id the job's charges are attributed to")
                .value_arg(
                    "deadline",
                    "complete-by time: seconds from now, or RFC 3339 (virtual t=0 is 2012-01-01T00:00:00Z)",
                )
                .value_arg("runname", "name for this job's results (required without -specfile)")
                .value_arg(
                    "after",
                    "parent job ids this job depends on (e.g. 2,5 or job-2,job-5); held until they complete",
                )
                .value_arg("specfile", "workflow JSON describing a whole stage graph to submit")
                .switch_arg("bynode", "round-robin slave placement (default)")
                .switch_arg("byslot", "fill each node's cores before the next")
                .switch_arg(
                    "resident",
                    "keep checkpoints cluster-side (EBS+S3+snapshot); resume pays LAN, not WAN",
                )
                .value_arg("trace", "append JSONL telemetry events to this file (raises level to trace)")
                .exclusive(&["bynode", "byslot"])
                .exclusive(&["after", "specfile"])
                .exclusive(&["runname", "specfile"]),
            CommandSpec::new("ec2jobstatus", "show one job (or every job) in the queue")
                .value_arg("jobid", "job id (e.g. 3 or job-3; omit for all)")
                .switch_arg("json", "emit machine-readable JSON instead of text"),
            CommandSpec::new("ec2jobqueue", "inspect or drain the job queue")
                .switch_arg("drain", "run the scheduler until every job completes")
                .switch_arg("shutdown", "terminate the fleet and bill its usage")
                .switch_arg("json", "emit queue depth and per-tenant load as JSON")
                .switch_arg("profile", "show wall-clock per scheduler phase for this invocation")
                .switch_arg("nofastpath", "disable the slice fast path (work cache + delta checkpoints)")
                .switch_arg("nodataaware", "disable data-aware DAG placement (dependents re-stage over the WAN)")
                .value_arg("ckptfull", "ship a full checkpoint every N slices, deltas between (default 8)"),
            CommandSpec::new("ec2genload", "submit a synthetic multi-tenant workload to the queue")
                .value_arg("jobs", "number of jobs to generate (default 200)")
                .value_arg("tenants", "number of distinct tenants (default 8)")
                .value_arg("seed", "workload seed (default 7)")
                .value_arg("trace", "append JSONL telemetry events to this file (raises level to trace)")
                .switch_arg("json", "emit a summary of the generated workload as JSON"),
            CommandSpec::new("ec2autoscale", "configure the elastic fleet autoscaler")
                .value_arg("min", "minimum fleet clusters")
                .value_arg("max", "maximum fleet clusters")
                .value_arg("csize", "nodes per fleet cluster")
                .value_arg("maxcsize", "node cap for the elastic policy")
                .value_arg("type", "EC2 instance type for fleet clusters")
                .value_arg("policy", "depth | elastic | work")
                .value_arg("bid", "spot bid strategy: ondemand | forecast+margin | capped")
                .value_arg(
                    "target",
                    "work policy: drain the estimated backlog within this many seconds (default 3600)",
                )
                .switch_arg("spot", "buy fleet capacity on the spot market")
                .switch_arg("ondemand", "buy fleet capacity on demand")
                .exclusive(&["spot", "ondemand"]),
            CommandSpec::new("ec2quota", "set, show or clear per-tenant governance quotas")
                .value_arg("analyst", "tenant id the quota applies to (omit to list all quotas)")
                .value_arg(
                    "maxclusters",
                    "max clusters per pool: concurrent fleet clusters, and owned created clusters",
                )
                .value_arg("maxcentihour", "compute budget in centihours (1/100 instance-hour)")
                .value_arg("maxqueued", "max jobs the tenant may have queued at once")
                .switch_arg("clear", "remove the tenant's quota (back to unlimited)"),
            CommandSpec::new("report", "show virtual-time, billing and workflow-span report"),
            CommandSpec::new("desktoprun", "run a script locally on a Table-I desktop (comparison)")
                .value_arg("desktop", "A | B")
                .value_arg("projectdir", "project directory")
                .value_arg("rscript", "script to execute")
                .value_arg("threads", "real worker threads for the engine (default: all cores)")
                .required_arg("runname", "name for this run"),
        ]
    }

    fn run(&self, ctx: CmdCtx<'_>, cmd: &str, p: &ParsedArgs) -> Result<String> {
        let CmdCtx { s, js, .. } = ctx;
        // The direct-execution commands run against the session alone.
        match cmd {
            "ec2runoninstance" => {
                let rscript = pick_script(s, p)?;
                s.threads = p.usize_value("threads")?;
                let out = s.run_on_instance(
                    p.value("iname"),
                    project_dir(p),
                    &rscript,
                    p.value("runname").unwrap(),
                )?;
                return Ok(format!(
                    "run complete in {} (virtual)\nsummary: {}",
                    humanfmt::secs(out.compute_s),
                    out.summary
                ));
            }
            "ec2runoncluster" => {
                let rscript = pick_script(s, p)?;
                let placement = Placement::parse(p.switch("bynode"), p.switch("byslot"))?;
                s.threads = p.usize_value("threads")?;
                let out = s.run_on_cluster(
                    p.value("cname"),
                    project_dir(p),
                    &rscript,
                    p.value("runname").unwrap(),
                    placement,
                )?;
                return Ok(format!(
                    "run complete in {} (virtual, {placement:?})\nsummary: {}",
                    humanfmt::secs(out.compute_s),
                    out.summary
                ));
            }
            "desktoprun" => {
                let which = p.value_or("desktop", "A");
                let desktops = table1_desktops();
                let d = desktops
                    .iter()
                    .find(|d| d.name.ends_with(which))
                    .ok_or_else(|| anyhow!("desktop must be A or B"))?;
                let rscript = pick_script(s, p)?;
                s.threads = p.usize_value("threads")?;
                let out = s.run_local(d, project_dir(p), &rscript, p.value("runname").unwrap())?;
                return Ok(format!(
                    "run complete on {} in {} (virtual)\nsummary: {}",
                    d.name,
                    humanfmt::secs(out.compute_s),
                    out.summary
                ));
            }
            // `report` renders with or without the persisted queue
            // state; the SLO rollup rides along only when the
            // scheduler was loaded.
            "report" => {
                let mut out = report(s);
                if let Some(js) = js {
                    let slo = js.slo_lines(s);
                    if !slo.is_empty() {
                        out.push_str(&slo.join("\n"));
                        out.push('\n');
                    }
                }
                return Ok(out);
            }
            _ => {}
        }
        // Everything below operates on the persisted queue state.
        let Some(js) = js else {
            bail!("unhandled command '{cmd}'");
        };
        match cmd {
            "ec2submitjob" => {
                if let Some(path) = p.value("trace") {
                    s.cloud.telemetry.set_trace_file(path);
                }
                if let Some(file) = p.value("specfile") {
                    return submit_workflow(s, js, p, file);
                }
                let runname = p
                    .value("runname")
                    .ok_or_else(|| anyhow!("-runname is required (or submit a graph with -specfile)"))?;
                let rscript = pick_script(s, p)?;
                let priority = Priority::parse(p.value_or("priority", "normal"))?;
                let placement = Placement::parse(p.switch("bynode"), p.switch("byslot"))?;
                let resident = p.switch("resident");
                let deadline_s = match p.value("deadline") {
                    Some(v) => Some(parse_deadline(v, s.cloud.clock.now_s())?),
                    None => None,
                };
                let deps = match p.value("after") {
                    Some(v) => parse_after(v)?,
                    None => Vec::new(),
                };
                let id = js.admit(
                    s,
                    JobSpecBuilder::new(runname, project_dir(p), &rscript)
                        .priority(priority)
                        .placement(placement)
                        .deadline(deadline_s)
                        .after(deps.iter().copied())
                        .build(),
                    resident,
                    p.value_or("analyst", ""),
                )?;
                let held = js
                    .queue
                    .get(id)
                    .is_some_and(|j| j.state == JobState::Held);
                Ok(format!(
                    "submitted {id} (priority {}{}{}{}, {} pending){}",
                    priority.label(),
                    if resident { ", resident" } else { "" },
                    deadline_s
                        .map(|d| format!(", deadline t={d:.0}s"))
                        .unwrap_or_default(),
                    if deps.is_empty() {
                        String::new()
                    } else {
                        format!(", after [{}]", id_list(&deps))
                    },
                    js.queue.pending(),
                    if held { " (held until parents complete)" } else { "" },
                ))
            }
            "ec2quota" => {
                let Some(analyst) = p.value("analyst") else {
                    let lines = js.quotas.lines();
                    return Ok(if lines.is_empty() {
                        "no tenant quotas set (every tenant is unlimited)".into()
                    } else {
                        lines.join("\n")
                    });
                };
                if p.switch("clear") {
                    return Ok(match js.quotas.remove(analyst) {
                        Some(_) => format!("cleared quota for tenant '{analyst}'"),
                        None => format!("tenant '{analyst}' had no quota set"),
                    });
                }
                let mut q = js.quotas.get(analyst).cloned().unwrap_or_default();
                if let Some(v) = p.usize_value("maxclusters")? {
                    q.max_clusters = Some(v);
                }
                if let Some(v) = p.value("maxcentihour") {
                    q.max_centihours = Some(v.parse::<u64>().map_err(|_| {
                        anyhow!("-maxcentihour expects a whole number of centihours, got '{v}'")
                    })?);
                }
                if let Some(v) = p.usize_value("maxqueued")? {
                    q.max_queued = Some(v);
                }
                let summary = q.summary();
                js.quotas.set(analyst, q);
                Ok(format!("quota for tenant '{analyst}': {summary}"))
            }
            "ec2jobstatus" => match p.value("jobid") {
                Some(v) => {
                    let n: u64 = v
                        .trim_start_matches("job-")
                        .parse()
                        .map_err(|_| anyhow!("-jobid expects a number or job-N, got '{v}'"))?;
                    let j = js
                        .queue
                        .get(JobId(n))
                        .ok_or_else(|| anyhow!("no such job 'job-{n}'"))?;
                    if p.switch("json") {
                        let mut o = js.queue.job_json(JobId(n)).unwrap();
                        if let Some(line) = js.deadline_status(s, j) {
                            o.set("deadline_status", Json::str(line));
                        }
                        return Ok(json_envelope("ec2jobstatus", o).to_string_pretty());
                    }
                    let deadline = js
                        .deadline_status(s, j)
                        .map(|line| format!("\n{line}"))
                        .unwrap_or_default();
                    Ok(format!(
                        "{} {}  progress={:.0}%  interruptions={}  retries={}  compute={}{}\nsummary: {}",
                        j.id,
                        j.state.label(),
                        j.progress * 100.0,
                        j.interruptions,
                        j.retries,
                        humanfmt::secs(j.compute_s),
                        deadline,
                        j.summary
                    ))
                }
                None => {
                    if p.switch("json") {
                        let mut o = Json::obj();
                        o.set(
                            "jobs",
                            Json::Arr(
                                js.queue
                                    .jobs()
                                    .filter_map(|j| js.queue.job_json(j.id))
                                    .collect(),
                            ),
                        );
                        o.set("pending", Json::num(js.queue.pending() as f64));
                        o.set("running", Json::num(js.queue.running() as f64));
                        return Ok(json_envelope("ec2jobstatus", o).to_string_pretty());
                    }
                    let mut out = js.status();
                    out.extend(js.slo_lines(s));
                    Ok(out.join("\n"))
                }
            },
            "ec2jobqueue" => {
                let mut out = Vec::new();
                let mut released: Vec<String> = Vec::new();
                if p.switch("nofastpath") {
                    js.fast_path = false;
                    out.push("slice fast path disabled".to_string());
                }
                if p.switch("nodataaware") {
                    js.data_aware = false;
                    out.push("data-aware placement disabled".to_string());
                }
                if let Some(n) = p.usize_value("ckptfull")? {
                    js.ckpt_full_every = n.max(1);
                    out.push(format!("full checkpoint every {} slice(s)", js.ckpt_full_every));
                }
                if p.switch("drain") {
                    js.run_until_idle(s)?;
                    out.push("queue drained".to_string());
                }
                if p.switch("shutdown") {
                    released = js.shutdown_fleet(s)?;
                    out.push(format!("fleet released: [{}]", released.join(", ")));
                }
                if p.switch("json") {
                    let mut o = Json::obj();
                    o.set("pending", Json::num(js.queue.pending() as f64));
                    o.set("running", Json::num(js.queue.running() as f64));
                    o.set("all_done", Json::Bool(js.queue.all_done()));
                    o.set("ordering", Json::str(js.queue.ordering.label()));
                    o.set("fleet_clusters", Json::num(js.fleet.len() as f64));
                    o.set("drained", Json::Bool(p.switch("drain")));
                    o.set("released", Json::arr_str(released));
                    let tenants: Vec<Json> = js
                        .queue
                        .tenant_loads()
                        .into_iter()
                        .map(|(analyst, load)| {
                            Json::from_pairs(vec![
                                ("analyst", Json::str(analyst)),
                                ("waiting", Json::num(load.waiting as f64)),
                                ("running", Json::num(load.running as f64)),
                                ("jobs", Json::num(load.jobs as f64)),
                            ])
                        })
                        .collect();
                    o.set("tenants", Json::Arr(tenants));
                    o.set("data_aware", Json::Bool(js.data_aware));
                    o.set(
                        "dag",
                        Json::from_pairs(vec![
                            ("releases", Json::num(js.dag_releases as f64)),
                            ("cancels", Json::num(js.dag_cancels as f64)),
                            ("dedup_skips", Json::num(js.dag_dedup_skips as f64)),
                        ]),
                    );
                    if p.switch("profile") {
                        o.set("profile", js.profiler.to_json());
                    }
                    return Ok(json_envelope("ec2jobqueue", o).to_string_pretty());
                }
                out.extend(js.status());
                if p.switch("profile") {
                    let lines = js.profiler.lines();
                    if lines.is_empty() {
                        out.push("no scheduler phases profiled this invocation".to_string());
                    } else {
                        out.extend(lines);
                    }
                }
                Ok(out.join("\n"))
            }
            "ec2genload" => {
                if let Some(path) = p.value("trace") {
                    s.cloud.telemetry.set_trace_file(path);
                }
                let cfg = crate::jobs::genload::GenLoadConfig {
                    jobs: p.usize_value("jobs")?.unwrap_or(200),
                    tenants: p.usize_value("tenants")?.unwrap_or(8).max(1),
                    seed: match p.value("seed") {
                        Some(v) => v
                            .parse::<u64>()
                            .map_err(|_| anyhow!("-seed expects a number, got '{v}'"))?,
                        None => 7,
                    },
                    ..Default::default()
                };
                let generated = crate::jobs::genload::generate(&cfg);
                let now = s.cloud.clock.now_s();
                let mut projects: std::collections::BTreeSet<u64> =
                    std::collections::BTreeSet::new();
                let (mut submitted, mut rejected) = (0usize, 0usize);
                for (i, g) in generated.iter().enumerate() {
                    // The engine derives a job's work units from its sweep
                    // config: n_jobs = units * tile. Cap per-job units so a
                    // heavy-tailed outlier cannot stall an interactive CLI
                    // session (the scale bench runs uncapped workloads).
                    let units = g.units.min(64);
                    let dir = format!("genload/u{units}");
                    if projects.insert(units) {
                        let n_jobs = units as usize * crate::analytics::script::RUST_SWEEP_TILE;
                        s.analyst.write(
                            &format!("{dir}/sweep.json"),
                            format!(
                                r#"{{"type":"mc_sweep","n_jobs":{n_jobs},"seed":{}}}"#,
                                cfg.seed
                            )
                            .into_bytes(),
                        );
                    }
                    let spec = JobSpecBuilder::new(&format!("gen-{}-{i}", cfg.seed), &dir, "sweep.json")
                        .priority(g.priority)
                        // Arrivals collapse to "now"; deadlines keep their
                        // slack relative to the generated arrival.
                        .deadline(g.deadline_s.map(|d| now + (d - g.arrival_s)))
                        .build();
                    match js.admit(s, spec, false, &g.tenant) {
                        Ok(_) => submitted += 1,
                        Err(_) => rejected += 1,
                    }
                }
                if p.switch("json") {
                    let mut o = Json::obj();
                    o.set("generated", Json::num(generated.len() as f64));
                    o.set("submitted", Json::num(submitted as f64));
                    o.set("rejected", Json::num(rejected as f64));
                    o.set("tenants", Json::num(cfg.tenants as f64));
                    o.set("seed", Json::num(cfg.seed as f64));
                    o.set("pending", Json::num(js.queue.pending() as f64));
                    return Ok(o.to_string_pretty());
                }
                Ok(format!(
                    "generated {} jobs across {} tenants (seed {}): {} submitted, {} rejected \
                     by quota, {} pending",
                    generated.len(),
                    cfg.tenants,
                    cfg.seed,
                    submitted,
                    rejected,
                    js.queue.pending()
                ))
            }
            "ec2autoscale" => {
                let cfg = &mut js.autoscaler.cfg;
                if let Some(v) = p.usize_value("min")? {
                    cfg.min_clusters = v;
                }
                if let Some(v) = p.usize_value("max")? {
                    cfg.max_clusters = v;
                }
                if let Some(v) = p.usize_value("csize")? {
                    cfg.nodes_per_cluster = v.max(2);
                }
                if let Some(v) = p.usize_value("maxcsize")? {
                    cfg.max_nodes_per_cluster = v.max(2);
                }
                if let Some(t) = p.value("type") {
                    cfg.itype = t.to_string();
                }
                if let Some(pol) = p.value("policy") {
                    cfg.policy = ScalePolicy::parse(pol)?;
                }
                if let Some(b) = p.value("bid") {
                    cfg.bid = BidStrategy::parse(b)?;
                }
                if let Some(t) = p.value("target") {
                    cfg.work_target_s = t
                        .parse::<f64>()
                        .ok()
                        .filter(|v| v.is_finite() && *v >= 1.0)
                        .ok_or_else(|| anyhow!("-target expects seconds >= 1, got '{t}'"))?;
                }
                if p.switch("spot") {
                    cfg.spot = true;
                }
                if p.switch("ondemand") {
                    cfg.spot = false;
                }
                Ok(format!(
                    "autoscaler: clusters [{}..{}] x {} nodes (elastic cap {}), type {}, {}, \
                     policy {} (target {:.0}s), bid {}",
                    cfg.min_clusters,
                    cfg.max_clusters,
                    cfg.nodes_per_cluster,
                    cfg.max_nodes_per_cluster,
                    cfg.itype,
                    if cfg.spot { "spot" } else { "on-demand" },
                    cfg.policy.label(),
                    cfg.work_target_s,
                    cfg.bid.label()
                ))
            }
            other => bail!("unhandled command '{other}'"),
        }
    }
}

/// `-after` parse: a comma list of job ids, `2,5` or `job-2,job-5`.
fn parse_after(v: &str) -> Result<Vec<JobId>> {
    let mut deps = Vec::new();
    for part in v.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let n: u64 = part.trim_start_matches("job-").parse().map_err(|_| {
            anyhow!("-after expects job ids like 2,5 or job-2,job-5, got '{part}'")
        })?;
        deps.push(JobId(n));
    }
    if deps.is_empty() {
        bail!("-after lists no job ids");
    }
    Ok(deps)
}

fn id_list(deps: &[JobId]) -> String {
    deps.iter()
        .map(|d| d.to_string())
        .collect::<Vec<_>>()
        .join(", ")
}

/// `ec2submitjob -specfile workflow.json`: admit a whole stage graph.
///
/// The spec is parsed and checked for acyclicity, unknown `after`
/// references and bad per-stage priorities/deadlines **before any
/// stage is admitted** — a cyclic or malformed workflow is rejected
/// with the queue untouched. Stages are then admitted in topological
/// order (parents first), resolving stage names to the job ids they
/// were assigned; dependent stages sit Held until their parents
/// complete.
fn submit_workflow(
    s: &mut Session,
    js: &mut JobScheduler,
    p: &ParsedArgs,
    file: &str,
) -> Result<String> {
    let text = std::fs::read_to_string(file)
        .map_err(|e| anyhow!("cannot read workflow spec '{file}': {e}"))?;
    let doc = Json::parse(&text).map_err(|e| anyhow!("workflow spec '{file}': {e}"))?;
    let wf = WorkflowSpec::parse(&doc)?;
    let order = wf.topo_order()?;
    let now = s.cloud.clock.now_s();
    let resident = p.switch("resident");
    let analyst = p.value_or("analyst", "");
    // Resolve and validate every stage up front: a bad deadline in
    // stage 4 must not leave stages 1-3 admitted.
    let mut prepared: Vec<(String, Priority, Option<f64>)> = Vec::with_capacity(wf.stages.len());
    for st in &wf.stages {
        let dir = st
            .projectdir
            .as_deref()
            .or(wf.projectdir.as_deref())
            .or(p.value("projectdir"))
            .unwrap_or("current_project")
            .to_string();
        let priority = Priority::parse(st.priority.as_deref().unwrap_or("normal"))
            .map_err(|e| e.context(format!("workflow stage '{}'", st.name)))?;
        let deadline_s = match st.deadline.as_deref() {
            Some(v) => Some(
                parse_deadline(v, now)
                    .map_err(|e| e.context(format!("workflow stage '{}'", st.name)))?,
            ),
            None => None,
        };
        prepared.push((dir, priority, deadline_s));
    }
    let mut ids: BTreeMap<&str, JobId> = BTreeMap::new();
    let mut lines = Vec::new();
    for idx in order {
        let st = &wf.stages[idx];
        let (dir, priority, deadline_s) = prepared[idx].clone();
        let deps: Vec<JobId> = st
            .after
            .iter()
            .map(|n| *ids.get(n.as_str()).expect("topo order admits parents first"))
            .collect();
        let id = js
            .admit(
                s,
                JobSpecBuilder::new(&st.name, &dir, &st.rscript)
                    .priority(priority)
                    .deadline(deadline_s)
                    .after(deps.iter().copied())
                    .build(),
                resident,
                analyst,
            )
            .map_err(|e| e.context(format!("workflow stage '{}'", st.name)))?;
        ids.insert(st.name.as_str(), id);
        let held = js
            .queue
            .get(id)
            .is_some_and(|j| j.state == JobState::Held);
        lines.push(format!(
            "submitted {id} '{}'{}{}",
            st.name,
            if deps.is_empty() {
                String::new()
            } else {
                format!(" after [{}]", id_list(&deps))
            },
            if held { " (held)" } else { "" },
        ));
    }
    lines.push(format!(
        "workflow '{file}': {} stage(s) admitted, {} pending",
        wf.stages.len(),
        js.queue.pending()
    ));
    Ok(lines.join("\n"))
}
