//! Serverless-tier domain: function invocation on the warm-container
//! pool and pool inspection/configuration. These commands run against
//! the persisted function platform plus a read-only view of the
//! tenant quota book (the fn tier enforces but never edits quotas).

use super::commands::{project_dir, CmdCtx, Command};
use crate::jobs::{FnInvokeSpec, KeepalivePolicy};
use crate::util::argparse::{CommandSpec, ParsedArgs};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};

/// The serverless function-tier command domain.
pub struct Functions;

impl Command for Functions {
    fn domain(&self) -> &'static str {
        "functions"
    }

    fn specs(&self) -> Vec<CommandSpec> {
        vec![
            CommandSpec::new("ec2invoke", "invoke a function on the serverless warm-container tier")
                .required_arg("fname", "function name (unique per tenant)")
                .value_arg("analyst", "tenant id the invocation bills and counts quota against")
                .value_arg("projectdir", "project directory whose content digest keys the warm pool")
                .value_arg("mem", "container memory in MB (default 512)")
                .value_arg("ms", "execution time in milliseconds (default 200)")
                .value_arg("repeat", "invoke this many times back to back (default 1)")
                .value_arg("gap", "virtual seconds between repeated invocations (default 60)")
                .switch_arg("json", "emit the outcome(s) as JSON instead of text"),
            CommandSpec::new("ec2fnpool", "inspect or configure the serverless container pool")
                .value_arg("policy", "keepalive policy: fixed | hybrid (adaptive per-function histogram)")
                .value_arg("keepalive", "base keepalive window in seconds (fixed value / hybrid fallback)")
                .value_arg("maxidlemb", "autoscaler idle-memory budget in MB (0 keeps nothing idle)")
                .switch_arg("drain", "advance the clock until every running invocation completes")
                .switch_arg("flush", "evict every idle container now (bills their idle memory)")
                .switch_arg("json", "emit pool status as JSON instead of text"),
        ]
    }

    fn run(&self, ctx: CmdCtx<'_>, cmd: &str, p: &ParsedArgs) -> Result<String> {
        let CmdCtx { s, quotas, fns, .. } = ctx;
        // Without the loaded platform (plain `apply`) these commands
        // are unavailable, exactly as before the split.
        let (Some(quotas), Some(fns)) = (quotas, fns) else {
            bail!("unhandled command '{cmd}'");
        };
        match cmd {
            "ec2invoke" => {
                let fname = p.value("fname").unwrap();
                let tenant = p.value_or("analyst", "");
                let dir = project_dir(p);
                let (digest, bytes) = crate::jobs::functions::project_fingerprint(s, dir)
                    .ok_or_else(|| {
                        anyhow!("no files under project directory '{dir}' — create one with mkproject")
                    })?;
                let mem_mb = p.usize_value("mem")?.unwrap_or(512).max(1) as u64;
                let duration_ms = p.usize_value("ms")?.unwrap_or(200).max(1) as u64;
                let repeat = p.usize_value("repeat")?.unwrap_or(1).max(1);
                let gap_s: f64 = p
                    .value_or("gap", "60")
                    .parse()
                    .map_err(|_| anyhow!("-gap expects seconds, got '{}'", p.value_or("gap", "60")))?;
                if gap_s < 0.0 {
                    bail!("-gap must be non-negative");
                }
                let spec = FnInvokeSpec {
                    fname: fname.to_string(),
                    tenant: tenant.to_string(),
                    digest,
                    bytes,
                    mem_mb,
                    duration_ms,
                };
                let mut outs = Vec::new();
                for i in 0..repeat {
                    if i > 0 {
                        s.cloud.clock.advance(gap_s);
                    }
                    outs.push(fns.invoke(s, quotas, &spec)?);
                }
                if p.switch("json") {
                    let arr: Vec<Json> = outs
                        .iter()
                        .map(|o| {
                            Json::from_pairs(vec![
                                ("container", Json::str(&format!("c-{}", o.container))),
                                ("cold", Json::Bool(o.cold)),
                                ("latency_s", Json::num(o.latency_s)),
                                ("billed_cc", Json::num(o.billed_cc as f64)),
                            ])
                        })
                        .collect();
                    let mut o = fns.status_json();
                    o.set("outcomes", Json::Arr(arr));
                    return Ok(o.to_string_pretty());
                }
                let mut lines: Vec<String> = outs
                    .iter()
                    .map(|o| {
                        format!(
                            "invoked '{fname}' on c-{} ({}, {:.2}s latency, {} cc)",
                            o.container,
                            if o.cold { "cold" } else { "warm" },
                            o.latency_s,
                            o.billed_cc,
                        )
                    })
                    .collect();
                lines.push(format!(
                    "pool: {} container(s) ({} warm / {} busy), lifetime cold fraction {:.1}%",
                    fns.pool.len(),
                    fns.warm_count(),
                    fns.busy_count(),
                    fns.cold_fraction() * 100.0,
                ));
                Ok(lines.join("\n"))
            }
            "ec2fnpool" => {
                if p.value("policy").is_some() || p.value("keepalive").is_some() {
                    let kind = p.value_or("policy", fns.policy.label()).to_string();
                    let base: f64 = match p.value("keepalive") {
                        Some(v) => v
                            .parse()
                            .map_err(|_| anyhow!("-keepalive expects seconds, got '{v}'"))?,
                        None => fns.policy.base_s(),
                    };
                    if base <= 0.0 {
                        bail!("-keepalive must be positive");
                    }
                    fns.policy = KeepalivePolicy::parse(&kind, base)?;
                }
                if let Some(mb) = p.usize_value("maxidlemb")? {
                    fns.autoscaler.max_idle_mb = mb as u64;
                }
                if p.switch("drain") {
                    fns.drain(s, quotas);
                } else {
                    fns.settle(s, quotas);
                }
                if p.switch("flush") {
                    fns.flush(s);
                }
                if p.switch("json") {
                    return Ok(fns.status_json().to_string_pretty());
                }
                Ok(fns.status_lines().join("\n"))
            }
            other => bail!("unhandled command '{other}'"),
        }
    }
}
