//! Implementations of the 19 paper commands plus three quality-of-life
//! extras (`mkproject`, `batch`, `report`) needed because the Analyst
//! "workstation" is itself part of the simulation.

use super::{load_jobs, load_session, make_engine, save_jobs, save_session};
use crate::analytics::CatBondData;
use crate::coordinator::{
    table1_desktops, CreateClusterOpts, CreateInstanceOpts, Placement, ResultScope, Session,
};
use crate::jobs::{
    parse_deadline, BidStrategy, JobId, JobScheduler, JobSpec, Priority, ScalePolicy,
};
use crate::simcloud::SpanCategory;
use crate::telemetry::{trace, EventKind, TelemetryLevel};
use crate::util::argparse::{CommandSpec, ParsedArgs};
use crate::util::humanfmt;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};

/// All commands with their specs, paper-accurate syntax.
pub fn registry() -> Vec<CommandSpec> {
    vec![
        CommandSpec::new("ec2configurep2rac", "initialise a fresh P2RAC session and configuration files"),
        CommandSpec::new("ec2createinstance", "configure an instance on the cloud")
            .value_arg("iname", "name of the instance")
            .value_arg("ebsvol", "EBS volume ID to attach")
            .value_arg("snap", "EBS snapshot ID to materialise a volume from")
            .value_arg("type", "EC2 instance type (e.g. m2.4xlarge)")
            .value_arg("desc", "description of the instance")
            .value_arg("analyst", "tenant id to tag the instance and its charges with")
            .switch_arg("spot", "request spot-market capacity (bid = on-demand rate)")
            .exclusive(&["ebsvol", "snap"]),
        CommandSpec::new("ec2terminateinstance", "safely release an instance")
            .value_arg("iname", "name of the instance to terminate")
            .switch_arg("deletevol", "also delete the attached EBS volume"),
        CommandSpec::new("ec2senddatatoinstance", "synchronise a project directory onto an instance")
            .value_arg("iname", "target instance")
            .value_arg("projectdir", "source project directory at the Analyst site"),
        CommandSpec::new("ec2getresultsfrominstance", "fetch results of a run from an instance")
            .value_arg("iname", "source instance")
            .value_arg("projectdir", "project directory at the Analyst site")
            .required_arg("runname", "name of the run whose results to gather"),
        CommandSpec::new("ec2runoninstance", "execute a script on an instance (locks it)")
            .value_arg("iname", "target instance")
            .value_arg("projectdir", "project directory")
            .value_arg("rscript", "script to execute from the project directory")
            .value_arg("threads", "real worker threads for the engine (default: all cores)")
            .required_arg("runname", "name for this run"),
        CommandSpec::new("ec2createcluster", "gather and configure a pool of instances as a cluster")
            .value_arg("cname", "name of the cluster")
            .value_arg("csize", "cluster size (1 master + workers)")
            .value_arg("ebsvol", "EBS volume ID to attach to the master")
            .value_arg("snap", "EBS snapshot ID to materialise a volume from")
            .value_arg("type", "EC2 instance type")
            .value_arg("desc", "description of the cluster")
            .value_arg("analyst", "tenant id to tag the cluster and its charges with")
            .switch_arg("spot", "request spot-market capacity for every node")
            .exclusive(&["ebsvol", "snap"]),
        CommandSpec::new("ec2terminatecluster", "safely release a cluster")
            .value_arg("cname", "name of the cluster")
            .switch_arg("deletevol", "also delete the shared EBS volume"),
        CommandSpec::new("ec2terminateall", "terminate everything on the cloud")
            .switch_arg("instances", "terminate all instances")
            .switch_arg("clusters", "terminate all clusters")
            .switch_arg("ebsvolumes", "delete all EBS volumes")
            .switch_arg("snapshots", "delete all snapshots"),
        CommandSpec::new("ec2senddatatoclusternodes", "synchronise a project onto every node of a cluster")
            .value_arg("cname", "target cluster")
            .value_arg("projectdir", "source project directory"),
        CommandSpec::new("ec2senddatatomaster", "synchronise a project onto the master instance only")
            .value_arg("cname", "target cluster")
            .value_arg("projectdir", "source project directory"),
        CommandSpec::new("ec2getresults", "gather results from a cluster")
            .value_arg("cname", "source cluster")
            .value_arg("projectdir", "project directory")
            .required_arg("runname", "run whose results to gather")
            .switch_arg("frommaster", "scenario 1: results aggregated on the master")
            .switch_arg("fromworkers", "scenario 2: results on the workers")
            .switch_arg("fromall", "scenario 3: results on master and workers")
            .exclusive(&["frommaster", "fromworkers", "fromall"]),
        CommandSpec::new("ec2runoncluster", "execute a script on a cluster (locks it)")
            .value_arg("cname", "target cluster")
            .value_arg("projectdir", "project directory")
            .value_arg("rscript", "script to execute")
            .value_arg("threads", "real worker threads for the engine (default: all cores)")
            .required_arg("runname", "name for this run")
            .switch_arg("bynode", "round-robin slave placement (default)")
            .switch_arg("byslot", "fill each node's cores before the next")
            .exclusive(&["bynode", "byslot"]),
        CommandSpec::new("ec2listinstances", "list instances created by the Analyst")
            .switch_arg("names", "names only"),
        CommandSpec::new("ec2listclusters", "list clusters created by the Analyst")
            .switch_arg("names", "names only"),
        CommandSpec::new("ec2listallresources", "list raw cloud resources")
            .switch_arg("instances", "list instances")
            .switch_arg("ebsvols", "list EBS volumes")
            .switch_arg("snapshots", "list snapshots")
            .switch_arg("amis", "list machine images"),
        CommandSpec::new("ec2logintoinstance", "open a (simulated) SSH session to an instance")
            .value_arg("iname", "instance to log in to"),
        CommandSpec::new("ec2logintocluster", "open a (simulated) SSH session to a cluster master")
            .value_arg("cname", "cluster whose master to log in to"),
        CommandSpec::new("ec2resourcelock", "lock or unlock an instance or cluster")
            .value_arg("iname", "instance name")
            .value_arg("cname", "cluster name")
            .switch_arg("free", "unlock the resource")
            .switch_arg("inuse", "lock the resource")
            .exclusive(&["iname", "cname"])
            .exclusive(&["free", "inuse"]),
        CommandSpec::new("ec2resizecluster", "grow or shrink a running cluster (dynamic scaling)")
            .value_arg("cname", "cluster to resize")
            .required_arg("csize", "new cluster size (1 master + workers)"),
        CommandSpec::new("ec2submitjob", "queue an analytics job for the elastic fleet")
            .value_arg("projectdir", "project directory at the Analyst site")
            .value_arg("rscript", "script to execute from the project directory")
            .value_arg("priority", "low | normal | high (default normal)")
            .value_arg("analyst", "tenant id the job's charges are attributed to")
            .value_arg(
                "deadline",
                "complete-by time: seconds from now, or RFC 3339 (virtual t=0 is 2012-01-01T00:00:00Z)",
            )
            .required_arg("runname", "name for this job's results")
            .switch_arg("bynode", "round-robin slave placement (default)")
            .switch_arg("byslot", "fill each node's cores before the next")
            .switch_arg(
                "resident",
                "keep checkpoints cluster-side (EBS+S3+snapshot); resume pays LAN, not WAN",
            )
            .value_arg("trace", "append JSONL telemetry events to this file (raises level to trace)")
            .exclusive(&["bynode", "byslot"]),
        CommandSpec::new("ec2snapshot", "point-in-time EBS snapshot of a resource's volume")
            .value_arg("iname", "instance whose volume to snapshot")
            .value_arg("cname", "cluster whose shared volume to snapshot")
            .value_arg("desc", "description of the snapshot")
            .exclusive(&["iname", "cname"]),
        CommandSpec::new("ec2lsobjects", "list the storage plane's objects with content digests")
            .value_arg("bucket", "bucket to list (default: all buckets)"),
        CommandSpec::new("ec2jobstatus", "show one job (or every job) in the queue")
            .value_arg("jobid", "job id (e.g. 3 or job-3; omit for all)")
            .switch_arg("json", "emit machine-readable JSON instead of text"),
        CommandSpec::new("ec2quota", "set, show or clear per-tenant governance quotas")
            .value_arg("analyst", "tenant id the quota applies to (omit to list all quotas)")
            .value_arg(
                "maxclusters",
                "max clusters per pool: concurrent fleet clusters, and owned created clusters",
            )
            .value_arg("maxcentihour", "compute budget in centihours (1/100 instance-hour)")
            .value_arg("maxqueued", "max jobs the tenant may have queued at once")
            .switch_arg("clear", "remove the tenant's quota (back to unlimited)"),
        CommandSpec::new("ec2invoice", "itemised per-tenant bill from the usage ledger")
            .value_arg("analyst", "tenant id to invoice (as tagged on jobs/resources)")
            .switch_arg("json", "emit the invoice as JSON instead of text"),
        CommandSpec::new("ec2invoke", "invoke a function on the serverless warm-container tier")
            .required_arg("fname", "function name (unique per tenant)")
            .value_arg("analyst", "tenant id the invocation bills and counts quota against")
            .value_arg("projectdir", "project directory whose content digest keys the warm pool")
            .value_arg("mem", "container memory in MB (default 512)")
            .value_arg("ms", "execution time in milliseconds (default 200)")
            .value_arg("repeat", "invoke this many times back to back (default 1)")
            .value_arg("gap", "virtual seconds between repeated invocations (default 60)")
            .switch_arg("json", "emit the outcome(s) as JSON instead of text"),
        CommandSpec::new("ec2fnpool", "inspect or configure the serverless container pool")
            .value_arg("policy", "keepalive policy: fixed | hybrid (adaptive per-function histogram)")
            .value_arg("keepalive", "base keepalive window in seconds (fixed value / hybrid fallback)")
            .value_arg("maxidlemb", "autoscaler idle-memory budget in MB (0 keeps nothing idle)")
            .switch_arg("drain", "advance the clock until every running invocation completes")
            .switch_arg("flush", "evict every idle container now (bills their idle memory)")
            .switch_arg("json", "emit pool status as JSON instead of text"),
        CommandSpec::new("ec2jobqueue", "inspect or drain the job queue")
            .switch_arg("drain", "run the scheduler until every job completes")
            .switch_arg("shutdown", "terminate the fleet and bill its usage")
            .switch_arg("json", "emit queue depth and per-tenant load as JSON")
            .switch_arg("profile", "show wall-clock per scheduler phase for this invocation")
            .switch_arg("nofastpath", "disable the slice fast path (work cache + delta checkpoints)")
            .value_arg("ckptfull", "ship a full checkpoint every N slices, deltas between (default 8)"),
        CommandSpec::new("ec2genload", "submit a synthetic multi-tenant workload to the queue")
            .value_arg("jobs", "number of jobs to generate (default 200)")
            .value_arg("tenants", "number of distinct tenants (default 8)")
            .value_arg("seed", "workload seed (default 7)")
            .value_arg("trace", "append JSONL telemetry events to this file (raises level to trace)")
            .switch_arg("json", "emit a summary of the generated workload as JSON"),
        CommandSpec::new("ec2autoscale", "configure the elastic fleet autoscaler")
            .value_arg("min", "minimum fleet clusters")
            .value_arg("max", "maximum fleet clusters")
            .value_arg("csize", "nodes per fleet cluster")
            .value_arg("maxcsize", "node cap for the elastic policy")
            .value_arg("type", "EC2 instance type for fleet clusters")
            .value_arg("policy", "depth | elastic | work")
            .value_arg("bid", "spot bid strategy: ondemand | forecast+margin | capped")
            .value_arg(
                "target",
                "work policy: drain the estimated backlog within this many seconds (default 3600)",
            )
            .switch_arg("spot", "buy fleet capacity on the spot market")
            .switch_arg("ondemand", "buy fleet capacity on demand")
            .exclusive(&["spot", "ondemand"]),
        CommandSpec::new("ec2metrics", "deterministic metrics snapshot from the telemetry bus")
            .value_arg("level", "set the recording level first: off | metrics | trace")
            .switch_arg("json", "emit the snapshot as JSON instead of text")
            .switch_arg("prom", "emit Prometheus-style exposition text")
            .exclusive(&["json", "prom"]),
        CommandSpec::new("ec2trace", "summarise or export a recorded JSONL telemetry trace")
            .value_arg("file", "trace file to read (default: the session's -trace sink)")
            .value_arg("chrome", "also write a Chrome trace-event JSON file to this path")
            .switch_arg("json", "emit the summary as JSON instead of text"),
        CommandSpec::new("mkproject", "create an example analytics project at the Analyst site")
            .value_arg("projectdir", "project directory to create")
            .value_arg("kind", "catopt | sweep")
            .value_arg("seed", "dataset seed (default 7)"),
        CommandSpec::new("batch", "run a file of p2rac commands (batch-mode execution)")
            .value_arg("file", "command file, one command per line"),
        CommandSpec::new("report", "show virtual-time, billing and workflow-span report"),
        CommandSpec::new("desktoprun", "run a script locally on a Table-I desktop (comparison)")
            .value_arg("desktop", "A | B")
            .value_arg("projectdir", "project directory")
            .value_arg("rscript", "script to execute")
            .value_arg("threads", "real worker threads for the engine (default: all cores)")
            .required_arg("runname", "name for this run"),
    ]
}

pub fn global_help() -> String {
    let mut s = String::from(
        "P2RAC — Platform for Parallel R-based Analytics on the Cloud\n\
         usage: p2rac <command> [args]   (every command supports -h and -v)\n\ncommands:\n",
    );
    for c in registry() {
        s.push_str(&format!("  {:<28} {}\n", c.name, c.about));
    }
    s
}

fn find_spec(name: &str) -> Result<CommandSpec> {
    registry()
        .into_iter()
        .find(|c| c.name == name)
        .ok_or_else(|| anyhow!("unknown command '{name}'\n\n{}", global_help()))
}

/// Parse and run one command; returns its stdout text.
pub fn dispatch(cmd: &str, args: Vec<String>) -> Result<String> {
    let spec = find_spec(cmd)?;
    let parsed = spec.parse(args).map_err(|e| anyhow!("{e}\n\n{}", spec.usage()))?;
    if parsed.help {
        return Ok(spec.usage());
    }
    if parsed.version {
        return Ok(crate::VERSION.to_string());
    }
    run_command(cmd, &parsed)
}

fn run_command(cmd: &str, p: &ParsedArgs) -> Result<String> {
    // ec2configurep2rac starts from scratch; everything else loads.
    if cmd == "ec2configurep2rac" {
        let s = Session::new(crate::simcloud::SimParams::default(), make_engine());
        save_session(&s)?;
        return Ok(format!(
            "P2RAC configured. Session state: {}\nDefault type: {}, default snapshot: {}",
            super::session_dir().display(),
            s.platform.default_type,
            s.platform.default_snapshot
        ));
    }
    if cmd == "batch" {
        return run_batch(p.value("file").ok_or_else(|| anyhow!("-file required"))?);
    }

    let mut s = load_session(make_engine())?;
    if is_fn_command(cmd) {
        // The function tier reads the quota book persisted with the
        // jobs state but never mutates it, so jobs state is loaded
        // read-only (no save — no spurious append-log record).
        let js = load_jobs()?;
        let mut fns = super::load_fns()?;
        let out = apply_with_fns(&mut s, &js.quotas, &mut fns, cmd, p)?;
        super::save_fns(&mut fns)?;
        save_session(&s)?;
        return Ok(out);
    }
    if is_jobs_command(cmd) {
        let mut js = load_jobs()?;
        js.prune_fleet(&s);
        let out = apply_with_jobs(&mut s, &mut js, cmd, p)?;
        save_jobs(&mut js)?;
        save_session(&s)?;
        return Ok(out);
    }
    let out = apply(&mut s, cmd, p)?;
    save_session(&s)?;
    Ok(out)
}

/// Commands that operate on the persisted job-queue state (including
/// the quota book persisted beside it, which `ec2createcluster`
/// consults on its create path and `report` for the SLO rollup).
fn is_jobs_command(cmd: &str) -> bool {
    matches!(
        cmd,
        "ec2submitjob"
            | "ec2genload"
            | "ec2jobstatus"
            | "ec2jobqueue"
            | "ec2autoscale"
            | "ec2quota"
            | "ec2createcluster"
            | "report"
    )
}

/// Commands that operate on the persisted serverless function
/// platform (they also read the quota book for the admit gate and the
/// autoscaler's demand ranking).
fn is_fn_command(cmd: &str) -> bool {
    matches!(cmd, "ec2invoke" | "ec2fnpool")
}

/// Batch-mode execution (paper §3.4): commands listed in a script file,
/// executed without Analyst intervention.
fn run_batch(file: &str) -> Result<String> {
    let text = std::fs::read_to_string(file)?;
    let mut out = String::new();
    let mut s = load_session(make_engine())?;
    let mut js = load_jobs()?;
    js.prune_fleet(&s);
    // The function platform loads lazily: batches that never touch the
    // fn tier don't create (or append to) its persistence files.
    let mut fns: Option<crate::jobs::FnPlatform> = None;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace().map(str::to_string);
        let cmd = parts.next().unwrap();
        let cmd = cmd.strip_prefix("p2rac").map(str::trim).filter(|c| !c.is_empty())
            .map(str::to_string)
            .unwrap_or(cmd);
        let spec = find_spec(&cmd)?;
        let parsed = spec
            .parse(parts.collect::<Vec<_>>())
            .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
        out.push_str(&format!("$ {line}\n"));
        if is_fn_command(&cmd) {
            if fns.is_none() {
                fns = Some(super::load_fns()?);
            }
            out.push_str(&apply_with_fns(
                &mut s,
                &js.quotas,
                fns.as_mut().unwrap(),
                &cmd,
                &parsed,
            )?);
        } else {
            out.push_str(&apply_with_jobs(&mut s, &mut js, &cmd, &parsed)?);
        }
        out.push('\n');
    }
    if let Some(mut f) = fns {
        super::save_fns(&mut f)?;
    }
    save_jobs(&mut js)?;
    save_session(&s)?;
    Ok(out)
}

/// Execute one already-parsed command against a session.
pub fn apply(s: &mut Session, cmd: &str, p: &ParsedArgs) -> Result<String> {
    match cmd {
        "ec2createinstance" => {
            let name = s.create_instance(&CreateInstanceOpts {
                iname: p.value("iname").map(str::to_string),
                ebsvol: p.value("ebsvol").map(str::to_string),
                snap: p.value("snap").map(str::to_string),
                itype: p.value("type").map(str::to_string),
                desc: p.value("desc").map(str::to_string),
                spot: p.switch("spot"),
                analyst: p.value("analyst").map(str::to_string),
            })?;
            let e = s.instances_cfg.get(&name).unwrap();
            Ok(format!(
                "created instance '{name}' ({}{}) dns={} volume={}",
                e.instance_type,
                if p.switch("spot") { ", spot" } else { "" },
                e.public_dns,
                e.volume_id.as_deref().unwrap_or("-")
            ))
        }
        "ec2terminateinstance" => {
            s.terminate_instance(p.value("iname"), p.switch("deletevol"))?;
            Ok("instance terminated".into())
        }
        "ec2senddatatoinstance" => {
            let rep = s.send_data_to_instance(p.value("iname"), project_dir(p))?;
            Ok(format!(
                "synchronised {} files ({} on the wire) in {}",
                rep.files_examined,
                humanfmt::bytes(rep.wire_bytes()),
                humanfmt::secs(rep.elapsed_s)
            ))
        }
        "ec2getresultsfrominstance" => {
            let rep = s.get_results_from_instance(
                p.value("iname"),
                project_dir(p),
                p.value("runname").unwrap(),
            )?;
            Ok(format!(
                "fetched {} result files ({}) in {}",
                rep.files_sent + rep.files_unchanged,
                humanfmt::bytes(rep.wire_bytes()),
                humanfmt::secs(rep.elapsed_s)
            ))
        }
        "ec2runoninstance" => {
            let rscript = pick_script(s, p)?;
            s.threads = p.usize_value("threads")?;
            let out = s.run_on_instance(
                p.value("iname"),
                project_dir(p),
                &rscript,
                p.value("runname").unwrap(),
            )?;
            Ok(format!(
                "run complete in {} (virtual)\nsummary: {}",
                humanfmt::secs(out.compute_s),
                out.summary
            ))
        }
        "ec2createcluster" => {
            let name = s.create_cluster(&CreateClusterOpts {
                cname: p.value("cname").map(str::to_string),
                csize: p.usize_value("csize")?,
                ebsvol: p.value("ebsvol").map(str::to_string),
                snap: p.value("snap").map(str::to_string),
                itype: p.value("type").map(str::to_string),
                desc: p.value("desc").map(str::to_string),
                spot: p.switch("spot"),
                bid_centi_cents_hour: None,
                analyst: p.value("analyst").map(str::to_string),
            })?;
            let e = s.clusters_cfg.get(&name).unwrap();
            Ok(format!(
                "created cluster '{name}': {} x {}{} (1 master + {} workers), volume={}",
                e.size,
                e.instance_type,
                if p.switch("spot") { " spot" } else { "" },
                e.worker_ids.len(),
                e.volume_id.as_deref().unwrap_or("-")
            ))
        }
        "ec2terminatecluster" => {
            s.terminate_cluster(p.value("cname"), p.switch("deletevol"))?;
            Ok("cluster terminated".into())
        }
        "ec2terminateall" => {
            let none = !(p.switch("instances")
                || p.switch("clusters")
                || p.switch("ebsvolumes")
                || p.switch("snapshots"));
            let log = s.terminate_all(
                p.switch("instances") || none,
                p.switch("clusters") || none,
                p.switch("ebsvolumes") || none,
                p.switch("snapshots") || none,
            )?;
            Ok(log.join("\n"))
        }
        "ec2senddatatoclusternodes" => {
            let reps = s.send_data_to_cluster_nodes(p.value("cname"), project_dir(p))?;
            Ok(format!(
                "synchronised project to {} nodes ({} each)",
                reps.len(),
                humanfmt::bytes(reps[0].wire_bytes())
            ))
        }
        "ec2senddatatomaster" => {
            let rep = s.send_data_to_master(p.value("cname"), project_dir(p))?;
            Ok(format!(
                "synchronised {} files to master ({}) in {}",
                rep.files_examined,
                humanfmt::bytes(rep.wire_bytes()),
                humanfmt::secs(rep.elapsed_s)
            ))
        }
        "ec2getresults" => {
            let scope = if p.switch("fromworkers") {
                ResultScope::FromWorkers
            } else if p.switch("fromall") {
                ResultScope::FromAll
            } else {
                ResultScope::FromMaster // default: scenario 1
            };
            let rep = s.get_results(
                p.value("cname"),
                project_dir(p),
                p.value("runname").unwrap(),
                scope,
            )?;
            Ok(format!(
                "gathered {} result files ({}) in {}",
                rep.files_sent + rep.files_unchanged,
                humanfmt::bytes(rep.wire_bytes()),
                humanfmt::secs(rep.elapsed_s)
            ))
        }
        "ec2runoncluster" => {
            let rscript = pick_script(s, p)?;
            let placement = Placement::parse(p.switch("bynode"), p.switch("byslot"))?;
            s.threads = p.usize_value("threads")?;
            let out = s.run_on_cluster(
                p.value("cname"),
                project_dir(p),
                &rscript,
                p.value("runname").unwrap(),
                placement,
            )?;
            Ok(format!(
                "run complete in {} (virtual, {placement:?})\nsummary: {}",
                humanfmt::secs(out.compute_s),
                out.summary
            ))
        }
        "ec2resizecluster" => {
            let size = p
                .usize_value("csize")?
                .ok_or_else(|| anyhow!("-csize is required"))?;
            s.resize_cluster(p.value("cname"), size)?;
            Ok(format!("cluster resized to {size} nodes"))
        }
        "ec2listinstances" => Ok(s.list_instances(p.switch("names")).join("\n")),
        "ec2listclusters" => Ok(s.list_clusters(p.switch("names")).join("\n")),
        "ec2listallresources" => {
            let none = !(p.switch("instances")
                || p.switch("ebsvols")
                || p.switch("snapshots")
                || p.switch("amis"));
            Ok(s
                .list_all_resources(
                    p.switch("instances") || none,
                    p.switch("ebsvols") || none,
                    p.switch("snapshots") || none,
                    p.switch("amis") || none,
                )
                .join("\n"))
        }
        "ec2snapshot" => {
            let snap = s.snapshot_resource_volume(
                p.value("iname"),
                p.value("cname"),
                p.value_or("desc", "manual snapshot"),
            )?;
            Ok(format!("created snapshot {snap}"))
        }
        "ec2lsobjects" => {
            let lines = s.list_storage_objects(p.value("bucket"));
            if lines.is_empty() {
                Ok("no objects in the storage plane".into())
            } else {
                Ok(lines.join("\n"))
            }
        }
        "ec2logintoinstance" => s.login_banner(p.value("iname"), None),
        "ec2logintocluster" => {
            let cname = p
                .value("cname")
                .map(str::to_string)
                .or(s.platform.default_cluster.clone())
                .ok_or_else(|| anyhow!("no -cname and no default cluster"))?;
            s.login_banner(None, Some(&cname))
        }
        "ec2resourcelock" => {
            let in_use = if p.switch("inuse") {
                true
            } else if p.switch("free") {
                false
            } else {
                bail!("specify -free or -inuse");
            };
            if let Some(c) = p.value("cname") {
                s.set_cluster_lock(c, in_use)?;
            } else if let Some(i) = p.value("iname") {
                s.set_instance_lock(i, in_use)?;
            } else {
                bail!("specify -iname or -cname");
            }
            Ok(format!("resource marked {}", if in_use { "inuse" } else { "free" }))
        }
        "mkproject" => {
            let dir = project_dir(p).to_string();
            let kind = p.value_or("kind", "sweep");
            let seed = p
                .value("seed")
                .map(|v| v.parse::<u64>())
                .transpose()
                .map_err(|_| anyhow!("-seed must be an integer"))?
                .unwrap_or(7);
            mkproject(s, &dir, kind, seed)
        }
        "desktoprun" => {
            let which = p.value_or("desktop", "A");
            let desktops = table1_desktops();
            let d = desktops
                .iter()
                .find(|d| d.name.ends_with(which))
                .ok_or_else(|| anyhow!("desktop must be A or B"))?;
            let rscript = pick_script(s, p)?;
            s.threads = p.usize_value("threads")?;
            let out = s.run_local(d, project_dir(p), &rscript, p.value("runname").unwrap())?;
            Ok(format!(
                "run complete on {} in {} (virtual)\nsummary: {}",
                d.name,
                humanfmt::secs(out.compute_s),
                out.summary
            ))
        }
        "ec2invoice" => {
            let analyst = p.value("analyst").ok_or_else(|| {
                anyhow!("-analyst is required (run `report` to see tenants with charges)")
            })?;
            let inv = s.cloud.ledger.invoice_for(analyst);
            if s.cloud.telemetry.on() {
                s.cloud.telemetry.emit(
                    s.cloud.clock.now_s(),
                    EventKind::Invoice,
                    analyst,
                    None,
                    None,
                    Json::from_pairs(vec![
                        ("total_centi_cents", Json::num(inv.total_centi_cents() as f64)),
                        ("lines", Json::num(inv.lines().len() as f64)),
                    ]),
                );
            }
            if p.switch("json") {
                Ok(inv.to_json().to_string_pretty())
            } else {
                Ok(inv.lines().join("\n"))
            }
        }
        "ec2metrics" => {
            if let Some(lvl) = p.value("level") {
                let level = match lvl {
                    "off" => TelemetryLevel::Off,
                    "metrics" => TelemetryLevel::Metrics,
                    "trace" => TelemetryLevel::Trace,
                    other => bail!("unknown telemetry level '{other}' (off | metrics | trace)"),
                };
                s.cloud.telemetry.set_level(level);
            }
            if p.switch("json") {
                Ok(s.cloud.telemetry.snapshot_json().to_string_pretty())
            } else if p.switch("prom") {
                Ok(s.cloud.telemetry.prometheus_text())
            } else {
                Ok(s.cloud.telemetry.text_lines().join("\n"))
            }
        }
        "ec2trace" => {
            let path = match p.value("file") {
                Some(f) => f.to_string(),
                None => s.cloud.telemetry.trace_path().ok_or_else(|| {
                    anyhow!(
                        "-file is required (this session has no -trace sink; \
                         record one with ec2genload -trace <path>)"
                    )
                })?,
            };
            let text = std::fs::read_to_string(&path)
                .map_err(|e| anyhow!("cannot read trace '{path}': {e}"))?;
            let summary = trace::TraceSummary::from_lines(text.lines())?;
            if let Some(out) = p.value("chrome") {
                let doc = trace::chrome_from_lines(text.lines())?;
                std::fs::write(out, doc.to_string_pretty())
                    .map_err(|e| anyhow!("cannot write '{out}': {e}"))?;
                return Ok(format!(
                    "wrote Chrome trace ({} events) to {out}\nopen it in chrome://tracing or Perfetto",
                    summary.events
                ));
            }
            if p.switch("json") {
                Ok(summary.to_json().to_string_pretty())
            } else {
                Ok(summary.lines().join("\n"))
            }
        }
        "report" => Ok(report(s)),
        other => bail!("unhandled command '{other}'"),
    }
}

/// Execute one command against a session and the persisted job
/// scheduler: the queue/autoscaler/governance commands live here
/// (plus the quota gate on `ec2createcluster` and the SLO rollup on
/// `report`); everything else falls through to [`apply`].
pub fn apply_with_jobs(
    s: &mut Session,
    js: &mut JobScheduler,
    cmd: &str,
    p: &ParsedArgs,
) -> Result<String> {
    match cmd {
        "ec2submitjob" => {
            if let Some(path) = p.value("trace") {
                s.cloud.telemetry.set_trace_file(path);
            }
            let rscript = pick_script(s, p)?;
            let priority = Priority::parse(p.value_or("priority", "normal"))?;
            let placement = Placement::parse(p.switch("bynode"), p.switch("byslot"))?;
            let resident = p.switch("resident");
            let deadline_s = match p.value("deadline") {
                Some(v) => Some(parse_deadline(v, s.cloud.clock.now_s())?),
                None => None,
            };
            let id = js.admit(
                s,
                JobSpec {
                    name: p.value("runname").unwrap().to_string(),
                    projectdir: project_dir(p).to_string(),
                    rscript,
                    priority,
                    placement,
                    deadline_s,
                },
                resident,
                p.value_or("analyst", ""),
            )?;
            Ok(format!(
                "submitted {id} (priority {}{}{}, {} pending)",
                priority.label(),
                if resident { ", resident" } else { "" },
                deadline_s
                    .map(|d| format!(", deadline t={d:.0}s"))
                    .unwrap_or_default(),
                js.queue.pending()
            ))
        }
        "ec2quota" => {
            let Some(analyst) = p.value("analyst") else {
                let lines = js.quotas.lines();
                return Ok(if lines.is_empty() {
                    "no tenant quotas set (every tenant is unlimited)".into()
                } else {
                    lines.join("\n")
                });
            };
            if p.switch("clear") {
                return Ok(match js.quotas.remove(analyst) {
                    Some(_) => format!("cleared quota for tenant '{analyst}'"),
                    None => format!("tenant '{analyst}' had no quota set"),
                });
            }
            let mut q = js.quotas.get(analyst).cloned().unwrap_or_default();
            if let Some(v) = p.usize_value("maxclusters")? {
                q.max_clusters = Some(v);
            }
            if let Some(v) = p.value("maxcentihour") {
                q.max_centihours = Some(v.parse::<u64>().map_err(|_| {
                    anyhow!("-maxcentihour expects a whole number of centihours, got '{v}'")
                })?);
            }
            if let Some(v) = p.usize_value("maxqueued")? {
                q.max_queued = Some(v);
            }
            let summary = q.summary();
            js.quotas.set(analyst, q);
            Ok(format!("quota for tenant '{analyst}': {summary}"))
        }
        "ec2createcluster" => {
            // Governance gate on the create path: a tenant at its
            // cluster quota is refused before anything is launched
            // (the fleet and the cloud stay untouched).
            if let Some(analyst) = p.value("analyst") {
                if let Some(limit) = js.quotas.get(analyst).and_then(|q| q.max_clusters) {
                    let owned = s.clusters_owned_by(analyst).len();
                    if owned >= limit {
                        bail!(
                            "tenant '{analyst}': cluster quota reached (limit {limit}, \
                             currently owns {owned} cluster(s)); terminate one or raise \
                             the limit with ec2quota -analyst {analyst} -maxclusters N"
                        );
                    }
                }
            }
            apply(s, cmd, p)
        }
        "report" => {
            let mut out = report(s);
            let slo = js.slo_lines(s);
            if !slo.is_empty() {
                out.push_str(&slo.join("\n"));
                out.push('\n');
            }
            Ok(out)
        }
        "ec2jobstatus" => match p.value("jobid") {
            Some(v) => {
                let n: u64 = v
                    .trim_start_matches("job-")
                    .parse()
                    .map_err(|_| anyhow!("-jobid expects a number or job-N, got '{v}'"))?;
                let j = js
                    .queue
                    .get(JobId(n))
                    .ok_or_else(|| anyhow!("no such job 'job-{n}'"))?;
                if p.switch("json") {
                    let mut o = js.queue.job_json(JobId(n)).unwrap();
                    if let Some(line) = js.deadline_status(s, j) {
                        o.set("deadline_status", Json::str(line));
                    }
                    return Ok(o.to_string_pretty());
                }
                let deadline = js
                    .deadline_status(s, j)
                    .map(|line| format!("\n{line}"))
                    .unwrap_or_default();
                Ok(format!(
                    "{} {}  progress={:.0}%  interruptions={}  retries={}  compute={}{}\nsummary: {}",
                    j.id,
                    j.state.label(),
                    j.progress * 100.0,
                    j.interruptions,
                    j.retries,
                    humanfmt::secs(j.compute_s),
                    deadline,
                    j.summary
                ))
            }
            None => {
                if p.switch("json") {
                    let mut o = Json::obj();
                    o.set(
                        "jobs",
                        Json::Arr(
                            js.queue
                                .jobs()
                                .filter_map(|j| js.queue.job_json(j.id))
                                .collect(),
                        ),
                    );
                    o.set("pending", Json::num(js.queue.pending() as f64));
                    o.set("running", Json::num(js.queue.running() as f64));
                    return Ok(o.to_string_pretty());
                }
                let mut out = js.status();
                out.extend(js.slo_lines(s));
                Ok(out.join("\n"))
            }
        },
        "ec2jobqueue" => {
            let mut out = Vec::new();
            let mut released: Vec<String> = Vec::new();
            if p.switch("nofastpath") {
                js.fast_path = false;
                out.push("slice fast path disabled".to_string());
            }
            if let Some(n) = p.usize_value("ckptfull")? {
                js.ckpt_full_every = n.max(1);
                out.push(format!("full checkpoint every {} slice(s)", js.ckpt_full_every));
            }
            if p.switch("drain") {
                js.run_until_idle(s)?;
                out.push("queue drained".to_string());
            }
            if p.switch("shutdown") {
                released = js.shutdown_fleet(s)?;
                out.push(format!("fleet released: [{}]", released.join(", ")));
            }
            if p.switch("json") {
                let mut o = Json::obj();
                o.set("pending", Json::num(js.queue.pending() as f64));
                o.set("running", Json::num(js.queue.running() as f64));
                o.set("all_done", Json::Bool(js.queue.all_done()));
                o.set("ordering", Json::str(js.queue.ordering.label()));
                o.set("fleet_clusters", Json::num(js.fleet.len() as f64));
                o.set("drained", Json::Bool(p.switch("drain")));
                o.set("released", Json::arr_str(released));
                let tenants: Vec<Json> = js
                    .queue
                    .tenant_loads()
                    .into_iter()
                    .map(|(analyst, load)| {
                        Json::from_pairs(vec![
                            ("analyst", Json::str(analyst)),
                            ("waiting", Json::num(load.waiting as f64)),
                            ("running", Json::num(load.running as f64)),
                            ("jobs", Json::num(load.jobs as f64)),
                        ])
                    })
                    .collect();
                o.set("tenants", Json::Arr(tenants));
                if p.switch("profile") {
                    o.set("profile", js.profiler.to_json());
                }
                return Ok(o.to_string_pretty());
            }
            out.extend(js.status());
            if p.switch("profile") {
                let lines = js.profiler.lines();
                if lines.is_empty() {
                    out.push("no scheduler phases profiled this invocation".to_string());
                } else {
                    out.extend(lines);
                }
            }
            Ok(out.join("\n"))
        }
        "ec2genload" => {
            if let Some(path) = p.value("trace") {
                s.cloud.telemetry.set_trace_file(path);
            }
            let cfg = crate::jobs::genload::GenLoadConfig {
                jobs: p.usize_value("jobs")?.unwrap_or(200),
                tenants: p.usize_value("tenants")?.unwrap_or(8).max(1),
                seed: match p.value("seed") {
                    Some(v) => v
                        .parse::<u64>()
                        .map_err(|_| anyhow!("-seed expects a number, got '{v}'"))?,
                    None => 7,
                },
                ..Default::default()
            };
            let generated = crate::jobs::genload::generate(&cfg);
            let now = s.cloud.clock.now_s();
            let mut projects: std::collections::BTreeSet<u64> = std::collections::BTreeSet::new();
            let (mut submitted, mut rejected) = (0usize, 0usize);
            for (i, g) in generated.iter().enumerate() {
                // The engine derives a job's work units from its sweep
                // config: n_jobs = units * tile. Cap per-job units so a
                // heavy-tailed outlier cannot stall an interactive CLI
                // session (the scale bench runs uncapped workloads).
                let units = g.units.min(64);
                let dir = format!("genload/u{units}");
                if projects.insert(units) {
                    let n_jobs = units as usize * crate::analytics::script::RUST_SWEEP_TILE;
                    s.analyst.write(
                        &format!("{dir}/sweep.json"),
                        format!(
                            r#"{{"type":"mc_sweep","n_jobs":{n_jobs},"seed":{}}}"#,
                            cfg.seed
                        )
                        .into_bytes(),
                    );
                }
                let spec = JobSpec {
                    name: format!("gen-{}-{i}", cfg.seed),
                    projectdir: dir,
                    rscript: "sweep.json".to_string(),
                    priority: g.priority,
                    placement: Placement::ByNode,
                    // Arrivals collapse to "now"; deadlines keep their
                    // slack relative to the generated arrival.
                    deadline_s: g.deadline_s.map(|d| now + (d - g.arrival_s)),
                };
                match js.admit(s, spec, false, &g.tenant) {
                    Ok(_) => submitted += 1,
                    Err(_) => rejected += 1,
                }
            }
            if p.switch("json") {
                let mut o = Json::obj();
                o.set("generated", Json::num(generated.len() as f64));
                o.set("submitted", Json::num(submitted as f64));
                o.set("rejected", Json::num(rejected as f64));
                o.set("tenants", Json::num(cfg.tenants as f64));
                o.set("seed", Json::num(cfg.seed as f64));
                o.set("pending", Json::num(js.queue.pending() as f64));
                return Ok(o.to_string_pretty());
            }
            Ok(format!(
                "generated {} jobs across {} tenants (seed {}): {} submitted, {} rejected \
                 by quota, {} pending",
                generated.len(),
                cfg.tenants,
                cfg.seed,
                submitted,
                rejected,
                js.queue.pending()
            ))
        }
        "ec2autoscale" => {
            let cfg = &mut js.autoscaler.cfg;
            if let Some(v) = p.usize_value("min")? {
                cfg.min_clusters = v;
            }
            if let Some(v) = p.usize_value("max")? {
                cfg.max_clusters = v;
            }
            if let Some(v) = p.usize_value("csize")? {
                cfg.nodes_per_cluster = v.max(2);
            }
            if let Some(v) = p.usize_value("maxcsize")? {
                cfg.max_nodes_per_cluster = v.max(2);
            }
            if let Some(t) = p.value("type") {
                cfg.itype = t.to_string();
            }
            if let Some(pol) = p.value("policy") {
                cfg.policy = ScalePolicy::parse(pol)?;
            }
            if let Some(b) = p.value("bid") {
                cfg.bid = BidStrategy::parse(b)?;
            }
            if let Some(t) = p.value("target") {
                cfg.work_target_s = t
                    .parse::<f64>()
                    .ok()
                    .filter(|v| v.is_finite() && *v >= 1.0)
                    .ok_or_else(|| anyhow!("-target expects seconds >= 1, got '{t}'"))?;
            }
            if p.switch("spot") {
                cfg.spot = true;
            }
            if p.switch("ondemand") {
                cfg.spot = false;
            }
            Ok(format!(
                "autoscaler: clusters [{}..{}] x {} nodes (elastic cap {}), type {}, {}, \
                 policy {} (target {:.0}s), bid {}",
                cfg.min_clusters,
                cfg.max_clusters,
                cfg.nodes_per_cluster,
                cfg.max_nodes_per_cluster,
                cfg.itype,
                if cfg.spot { "spot" } else { "on-demand" },
                cfg.policy.label(),
                cfg.work_target_s,
                cfg.bid.label()
            ))
        }
        other => apply(s, other, p),
    }
}

/// Execute one serverless-tier command (`ec2invoke` / `ec2fnpool`)
/// against a session, the tenant quota book (read-only: the fn tier
/// enforces but never edits quotas) and the function platform.
pub fn apply_with_fns(
    s: &mut Session,
    quotas: &crate::jobs::QuotaBook,
    fns: &mut crate::jobs::FnPlatform,
    cmd: &str,
    p: &ParsedArgs,
) -> Result<String> {
    use crate::jobs::{FnInvokeSpec, KeepalivePolicy};
    match cmd {
        "ec2invoke" => {
            let fname = p.value("fname").unwrap();
            let tenant = p.value_or("analyst", "");
            let dir = project_dir(p);
            let (digest, bytes) = crate::jobs::functions::project_fingerprint(s, dir)
                .ok_or_else(|| {
                    anyhow!("no files under project directory '{dir}' — create one with mkproject")
                })?;
            let mem_mb = p.usize_value("mem")?.unwrap_or(512).max(1) as u64;
            let duration_ms = p.usize_value("ms")?.unwrap_or(200).max(1) as u64;
            let repeat = p.usize_value("repeat")?.unwrap_or(1).max(1);
            let gap_s: f64 = p
                .value_or("gap", "60")
                .parse()
                .map_err(|_| anyhow!("-gap expects seconds, got '{}'", p.value_or("gap", "60")))?;
            if gap_s < 0.0 {
                bail!("-gap must be non-negative");
            }
            let spec = FnInvokeSpec {
                fname: fname.to_string(),
                tenant: tenant.to_string(),
                digest,
                bytes,
                mem_mb,
                duration_ms,
            };
            let mut outs = Vec::new();
            for i in 0..repeat {
                if i > 0 {
                    s.cloud.clock.advance(gap_s);
                }
                outs.push(fns.invoke(s, quotas, &spec)?);
            }
            if p.switch("json") {
                let arr: Vec<Json> = outs
                    .iter()
                    .map(|o| {
                        Json::from_pairs(vec![
                            ("container", Json::str(&format!("c-{}", o.container))),
                            ("cold", Json::Bool(o.cold)),
                            ("latency_s", Json::num(o.latency_s)),
                            ("billed_cc", Json::num(o.billed_cc as f64)),
                        ])
                    })
                    .collect();
                let mut o = fns.status_json();
                o.set("outcomes", Json::Arr(arr));
                return Ok(o.to_string_pretty());
            }
            let mut lines: Vec<String> = outs
                .iter()
                .map(|o| {
                    format!(
                        "invoked '{fname}' on c-{} ({}, {:.2}s latency, {} cc)",
                        o.container,
                        if o.cold { "cold" } else { "warm" },
                        o.latency_s,
                        o.billed_cc,
                    )
                })
                .collect();
            lines.push(format!(
                "pool: {} container(s) ({} warm / {} busy), lifetime cold fraction {:.1}%",
                fns.pool.len(),
                fns.warm_count(),
                fns.busy_count(),
                fns.cold_fraction() * 100.0,
            ));
            Ok(lines.join("\n"))
        }
        "ec2fnpool" => {
            if p.value("policy").is_some() || p.value("keepalive").is_some() {
                let kind = p.value_or("policy", fns.policy.label()).to_string();
                let base: f64 = match p.value("keepalive") {
                    Some(v) => v
                        .parse()
                        .map_err(|_| anyhow!("-keepalive expects seconds, got '{v}'"))?,
                    None => fns.policy.base_s(),
                };
                if base <= 0.0 {
                    bail!("-keepalive must be positive");
                }
                fns.policy = KeepalivePolicy::parse(&kind, base)?;
            }
            if let Some(mb) = p.usize_value("maxidlemb")? {
                fns.autoscaler.max_idle_mb = mb as u64;
            }
            if p.switch("drain") {
                fns.drain(s, quotas);
            } else {
                fns.settle(s, quotas);
            }
            if p.switch("flush") {
                fns.flush(s);
            }
            if p.switch("json") {
                return Ok(fns.status_json().to_string_pretty());
            }
            Ok(fns.status_lines().join("\n"))
        }
        other => bail!("'{other}' is not a serverless-tier command"),
    }
}

fn project_dir<'a>(p: &'a ParsedArgs) -> &'a str {
    // Paper: "should the project directory not be specified then the
    // current working directory at the Analyst site is used".
    p.value_or("projectdir", "current_project")
}

/// When `-rscript` is omitted the Analyst is shown the candidates
/// (paper: "the user is prompted to select from a list").
fn pick_script(s: &Session, p: &ParsedArgs) -> Result<String> {
    if let Some(r) = p.value("rscript") {
        return Ok(r.to_string());
    }
    let scripts = s.list_scripts(project_dir(p));
    match scripts.len() {
        0 => bail!("no scripts in project directory"),
        1 => Ok(scripts[0].clone()),
        _ => bail!(
            "multiple scripts available, pass -rscript one of: {}",
            scripts.join(", ")
        ),
    }
}

/// Create an example project on the Analyst site.
pub fn mkproject(s: &mut Session, dir: &str, kind: &str, seed: u64) -> Result<String> {
    match kind {
        "catopt" => {
            // Scaled dataset matching the AOT artifact shapes.
            let (m, e) = (512, 2048);
            let data = CatBondData::generate(seed, m, e);
            for (name, bytes) in data.to_files() {
                s.analyst.write(&format!("{dir}/{name}"), bytes);
            }
            s.analyst.write(
                &format!("{dir}/catopt.json"),
                br#"{"type":"catopt","pop_size":200,"max_generations":50,"seed":42,"bfgs_every":25}"#
                    .to_vec(),
            );
            Ok(format!(
                "created CATopt project '{dir}' (m={m}, e={e}, {} of loss data)",
                humanfmt::bytes(data.nbytes())
            ))
        }
        "sweep" => {
            s.analyst.write(
                &format!("{dir}/sweep.json"),
                br#"{"type":"mc_sweep","n_jobs":512,"att_min":0.5,"att_max":8.0,"lim_min":1.0,"lim_max":12.0,"seed":2012}"#
                    .to_vec(),
            );
            s.analyst
                .write(&format!("{dir}/data/params_note.txt"), b"parameter sweep project".to_vec());
            Ok(format!("created parameter-sweep project '{dir}'"))
        }
        other => bail!("unknown project kind '{other}' (catopt | sweep)"),
    }
}

/// Virtual-time + billing report.
pub fn report(s: &Session) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "virtual time elapsed: {}\n",
        humanfmt::secs(s.cloud.clock.now_s())
    ));
    out.push_str(&format!(
        "billed so far: ${:.2} ({} line items)\n",
        s.cloud.ledger.total_dollars(),
        s.cloud.ledger.items().len()
    ));
    let tenants = s.cloud.ledger.analysts();
    if !tenants.is_empty() {
        out.push_str("billed by analyst:\n");
        for a in &tenants {
            out.push_str(&format!(
                "  {:<20} ${:.2}\n",
                a,
                s.cloud.ledger.total_centi_cents_for(a) as f64 / 10_000.0
            ));
        }
        let untagged = s.cloud.ledger.total_centi_cents_for("");
        if untagged > 0 {
            out.push_str(&format!(
                "  {:<20} ${:.2}\n",
                "(platform)",
                untagged as f64 / 10_000.0
            ));
        }
    }
    let cats = [
        (SpanCategory::CreateResource, "create resources"),
        (SpanCategory::SubmitToMaster, "submit to instance/master"),
        (SpanCategory::SubmitToAllNodes, "submit to all nodes"),
        (SpanCategory::Compute, "compute"),
        (SpanCategory::FetchFromMaster, "fetch from instance/master"),
        (SpanCategory::FetchFromAllNodes, "fetch from all nodes"),
        (SpanCategory::TerminateResource, "terminate resources"),
    ];
    out.push_str("time by category (this invocation):\n");
    for (c, label) in cats {
        let t = s.cloud.clock.category_total_s(c);
        if t > 0.0 {
            out.push_str(&format!("  {:<28} {}\n", label, humanfmt::secs(t)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockEngine;
    use crate::simcloud::SimParams;

    fn session() -> Session {
        Session::new(SimParams::default(), Box::new(MockEngine::new(100.0)))
    }

    fn run(s: &mut Session, cmd: &str, args: &[&str]) -> Result<String> {
        let spec = registry().into_iter().find(|c| c.name == cmd).unwrap();
        let p = spec.parse(args.iter().map(|a| a.to_string())).unwrap();
        apply(s, cmd, &p)
    }

    #[test]
    fn full_cli_cluster_workflow() {
        let mut s = session();
        run(&mut s, "mkproject", &["-projectdir", "proj", "-kind", "sweep"]).unwrap();
        let out = run(
            &mut s,
            "ec2createcluster",
            &["-cname", "hpc_cluster", "-csize", "4", "-type", "m2.2xlarge"],
        )
        .unwrap();
        assert!(out.contains("hpc_cluster"));
        run(&mut s, "ec2senddatatoclusternodes", &["-cname", "hpc_cluster", "-projectdir", "proj"])
            .unwrap();
        let out = run(
            &mut s,
            "ec2runoncluster",
            &["-cname", "hpc_cluster", "-projectdir", "proj", "-rscript", "sweep.json", "-runname", "r1", "-bynode"],
        )
        .unwrap();
        assert!(out.contains("run complete"));
        run(
            &mut s,
            "ec2getresults",
            &["-cname", "hpc_cluster", "-projectdir", "proj", "-runname", "r1", "-frommaster"],
        )
        .unwrap();
        let listing = run(&mut s, "ec2listclusters", &[]).unwrap();
        assert!(listing.contains("hpc_cluster"));
        let rep = report(&s);
        assert!(rep.contains("virtual time"));
        run(&mut s, "ec2terminatecluster", &["-cname", "hpc_cluster"]).unwrap();
        assert!(s.clusters_cfg.names().is_empty());
    }

    #[test]
    fn mkproject_catopt_writes_dataset() {
        let mut s = session();
        let out = run(&mut s, "mkproject", &["-projectdir", "cp", "-kind", "catopt"]).unwrap();
        assert!(out.contains("CATopt"));
        assert!(s.analyst.exists("cp/catopt.json"));
        assert!(s.analyst.exists("cp/data/industry_losses.bin"));
        assert!(s.analyst.dir_size("cp") > 1_000_000);
    }

    #[test]
    fn pick_script_prompts_on_ambiguity() {
        let mut s = session();
        s.analyst.write("p/a.json", b"{}".to_vec());
        s.analyst.write("p/b.json", b"{}".to_vec());
        let spec = registry().into_iter().find(|c| c.name == "ec2runoninstance").unwrap();
        let p = spec
            .parse(["-projectdir", "p", "-runname", "r"].map(String::from))
            .unwrap();
        let err = pick_script(&s, &p).unwrap_err();
        assert!(err.to_string().contains("a.json"));
    }

    #[test]
    fn resourcelock_requires_target_and_mode() {
        let mut s = session();
        run(&mut s, "ec2createinstance", &["-iname", "i1"]).unwrap();
        assert!(run(&mut s, "ec2resourcelock", &["-iname", "i1"]).is_err());
        run(&mut s, "ec2resourcelock", &["-iname", "i1", "-inuse"]).unwrap();
        assert!(s.instances_cfg.get("i1").unwrap().in_use);
        run(&mut s, "ec2resourcelock", &["-iname", "i1", "-free"]).unwrap();
        assert!(!s.instances_cfg.get("i1").unwrap().in_use);
    }

    #[test]
    fn global_help_lists_all_paper_commands() {
        let h = global_help();
        for c in [
            "ec2createinstance",
            "ec2terminateinstance",
            "ec2senddatatoinstance",
            "ec2getresultsfrominstance",
            "ec2runoninstance",
            "ec2createcluster",
            "ec2terminatecluster",
            "ec2terminateall",
            "ec2senddatatoclusternodes",
            "ec2senddatatomaster",
            "ec2getresults",
            "ec2runoncluster",
            "ec2listinstances",
            "ec2listclusters",
            "ec2listallresources",
            "ec2logintoinstance",
            "ec2logintocluster",
            "ec2resourcelock",
            "ec2configurep2rac",
            "ec2submitjob",
            "ec2jobstatus",
            "ec2jobqueue",
            "ec2autoscale",
            "ec2snapshot",
            "ec2lsobjects",
            "ec2quota",
            "ec2invoice",
            "ec2genload",
            "ec2metrics",
            "ec2trace",
            "ec2invoke",
            "ec2fnpool",
        ] {
            assert!(h.contains(c), "help missing {c}");
        }
    }

    #[test]
    fn metrics_command_reports_the_bus() {
        let mut s = session();
        s.cloud.telemetry.emit(0.0, EventKind::Submit, "alice", None, None, Json::obj());
        let out = run(&mut s, "ec2metrics", &[]).unwrap();
        assert!(out.contains("telemetry level metrics"), "{out}");
        assert!(out.contains("jobs_submitted_total"), "{out}");
        let out = run(&mut s, "ec2metrics", &["-json"]).unwrap();
        let j = Json::parse(&out).unwrap();
        assert_eq!(j.opt_str("level").as_deref(), Some("metrics"));
        assert_eq!(j.get("events").and_then(Json::as_u64), Some(1));
        let out = run(&mut s, "ec2metrics", &["-prom"]).unwrap();
        assert!(out.contains("p2rac_jobs_submitted_total 1"), "{out}");
        // The level switch round-trips.
        run(&mut s, "ec2metrics", &["-level", "off"]).unwrap();
        assert!(!s.cloud.telemetry.on());
        assert!(run(&mut s, "ec2metrics", &["-level", "loud"]).is_err());
    }

    #[test]
    fn trace_command_summarises_and_exports() {
        let dir = std::env::temp_dir().join(format!("p2rac-cli-trace-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        std::fs::write(
            &path,
            "{\"detail\":{},\"kind\":\"submit\",\"seq\":1,\"t_s\":0,\"tenant\":\"a\"}\n\
             {\"cluster\":\"fleet1\",\"detail\":{\"duration_s\":60,\"from_s\":5},\"job\":\"job-1\",\
             \"kind\":\"slice-complete\",\"seq\":2,\"t_s\":65,\"tenant\":\"a\"}\n",
        )
        .unwrap();
        let mut s = session();
        // No sink configured and no -file: a clean error.
        assert!(run(&mut s, "ec2trace", &[]).is_err());
        let p = path.to_str().unwrap();
        let out = run(&mut s, "ec2trace", &["-file", p]).unwrap();
        assert!(out.contains("2 events"), "{out}");
        let out = run(&mut s, "ec2trace", &["-file", p, "-json"]).unwrap();
        let j = Json::parse(&out).unwrap();
        assert_eq!(j.path(&["by_kind", "slice-complete"]).and_then(Json::as_u64), Some(1));
        let chrome = dir.join("t.chrome.json");
        let c = chrome.to_str().unwrap();
        run(&mut s, "ec2trace", &["-file", p, "-chrome", c]).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&chrome).unwrap()).unwrap();
        assert_eq!(doc.get("traceEvents").and_then(Json::as_arr).map(|a| a.len()), Some(2));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn snapshot_and_lsobjects_commands() {
        let mut s = session();
        run(&mut s, "ec2createcluster", &["-cname", "c", "-csize", "2"]).unwrap();
        let out = run(&mut s, "ec2snapshot", &["-cname", "c", "-desc", "state"]).unwrap();
        assert!(out.contains("created snapshot snap-"), "{out}");
        // Empty storage plane lists cleanly…
        let out = run(&mut s, "ec2lsobjects", &[]).unwrap();
        assert!(out.contains("no objects"), "{out}");
        // …and objects show up once something is stored.
        s.cloud
            .s3_put("p2rac-checkpoints", "job-1", b"{}".to_vec(), crate::simcloud::Link::Lan);
        let out = run(&mut s, "ec2lsobjects", &["-bucket", "p2rac-checkpoints"]).unwrap();
        assert!(out.contains("job-1") && out.contains("digest="), "{out}");
    }

    #[test]
    fn resident_submit_flag_reaches_the_queue() {
        let mut s = session();
        run(&mut s, "mkproject", &["-projectdir", "proj", "-kind", "sweep"]).unwrap();
        let mut js = JobScheduler::new(crate::jobs::AutoscalerConfig::default());
        let out = run_jobs(
            &mut s,
            &mut js,
            "ec2submitjob",
            &[
                "-projectdir",
                "proj",
                "-rscript",
                "sweep.json",
                "-runname",
                "r1",
                "-resident",
                "-analyst",
                "alice",
            ],
        )
        .unwrap();
        assert!(out.contains("resident"), "{out}");
        let job = js.queue.jobs().next().unwrap();
        assert!(job.resident);
        assert_eq!(job.analyst, "alice");
    }

    fn run_jobs(
        s: &mut Session,
        js: &mut JobScheduler,
        cmd: &str,
        args: &[&str],
    ) -> Result<String> {
        let spec = registry().into_iter().find(|c| c.name == cmd).unwrap();
        let p = spec.parse(args.iter().map(|a| a.to_string())).unwrap();
        apply_with_jobs(s, js, cmd, &p)
    }

    fn run_fns(
        s: &mut Session,
        quotas: &crate::jobs::QuotaBook,
        fns: &mut crate::jobs::FnPlatform,
        cmd: &str,
        args: &[&str],
    ) -> Result<String> {
        let spec = registry().into_iter().find(|c| c.name == cmd).unwrap();
        let p = spec.parse(args.iter().map(|a| a.to_string())).unwrap();
        apply_with_fns(s, quotas, fns, cmd, &p)
    }

    #[test]
    fn invoke_command_goes_cold_then_warm() {
        let mut s = session();
        run(&mut s, "mkproject", &["-projectdir", "proj", "-kind", "sweep"]).unwrap();
        let quotas = crate::jobs::QuotaBook::default();
        let mut fns = crate::jobs::FnPlatform::default();
        let out = run_fns(
            &mut s,
            &quotas,
            &mut fns,
            "ec2invoke",
            &["-fname", "score", "-projectdir", "proj", "-analyst", "alice", "-repeat", "3"],
        )
        .unwrap();
        assert!(out.contains("cold"), "{out}");
        assert!(out.contains("warm"), "{out}");
        assert_eq!(fns.invocations_total, 3);
        assert_eq!(fns.cold_total, 1, "repeats within the gap must stay warm");
        // A missing project is a clean error, not a provision.
        let err = run_fns(
            &mut s,
            &quotas,
            &mut fns,
            "ec2invoke",
            &["-fname", "score", "-projectdir", "nope"],
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("mkproject"), "{err}");
    }

    #[test]
    fn fnpool_command_configures_and_reports() {
        let mut s = session();
        run(&mut s, "mkproject", &["-projectdir", "proj", "-kind", "sweep"]).unwrap();
        let quotas = crate::jobs::QuotaBook::default();
        let mut fns = crate::jobs::FnPlatform::default();
        let out = run_fns(
            &mut s,
            &quotas,
            &mut fns,
            "ec2fnpool",
            &["-policy", "fixed", "-keepalive", "240", "-maxidlemb", "2048"],
        )
        .unwrap();
        assert!(out.contains("policy fixed (base 240s)"), "{out}");
        assert_eq!(fns.autoscaler.max_idle_mb, 2048);
        run_fns(
            &mut s,
            &quotas,
            &mut fns,
            "ec2invoke",
            &["-fname", "score", "-projectdir", "proj"],
        )
        .unwrap();
        let st = run_fns(&mut s, &quotas, &mut fns, "ec2fnpool", &["-drain", "-flush", "-json"])
            .unwrap();
        let j = Json::parse(&st).unwrap();
        assert_eq!(j.get("pool").and_then(Json::as_u64), Some(0));
        assert_eq!(j.get("evicted_total").and_then(Json::as_u64), Some(1));
        assert_eq!(j.get("invocations_total").and_then(Json::as_u64), Some(1));
        let bad = run_fns(&mut s, &quotas, &mut fns, "ec2fnpool", &["-policy", "lru"])
            .unwrap_err()
            .to_string();
        assert!(bad.contains("unknown keepalive policy"), "{bad}");
    }

    #[test]
    fn job_queue_cli_workflow() {
        let mut s = session();
        run(&mut s, "mkproject", &["-projectdir", "proj", "-kind", "sweep"]).unwrap();
        let mut js = JobScheduler::new(crate::jobs::AutoscalerConfig {
            min_clusters: 1,
            max_clusters: 1,
            ..Default::default()
        });
        let out = run_jobs(
            &mut s,
            &mut js,
            "ec2autoscale",
            &["-min", "1", "-max", "2", "-policy", "elastic", "-spot"],
        )
        .unwrap();
        assert!(out.contains("spot") && out.contains("elastic"));
        let out = run_jobs(
            &mut s,
            &mut js,
            "ec2submitjob",
            &["-projectdir", "proj", "-rscript", "sweep.json", "-runname", "r1", "-priority", "high"],
        )
        .unwrap();
        assert!(out.contains("submitted job-1"), "{out}");
        let out = run_jobs(&mut s, &mut js, "ec2jobqueue", &["-drain"]).unwrap();
        assert!(out.contains("queue drained"), "{out}");
        let out = run_jobs(&mut s, &mut js, "ec2jobstatus", &["-jobid", "1"]).unwrap();
        assert!(out.contains("completed"), "{out}");
        assert!(s.analyst.exists("proj_results/r1/summary.json"));
        let out = run_jobs(&mut s, &mut js, "ec2jobqueue", &["-shutdown"]).unwrap();
        assert!(out.contains("fleet released"), "{out}");
        assert!(s.cloud.live_instances().is_empty());
    }

    #[test]
    fn quota_cli_sets_lists_clears_and_gates_cluster_creation() {
        let mut s = session();
        let mut js = JobScheduler::new(crate::jobs::AutoscalerConfig::default());
        // Set, show, update.
        let out = run_jobs(
            &mut s,
            &mut js,
            "ec2quota",
            &["-analyst", "alice", "-maxclusters", "1", "-maxqueued", "4"],
        )
        .unwrap();
        assert!(out.contains("maxclusters 1") && out.contains("maxqueued 4"), "{out}");
        assert!(out.contains("maxcentihour unlimited"), "{out}");
        let listing = run_jobs(&mut s, &mut js, "ec2quota", &[]).unwrap();
        assert!(listing.contains("alice"), "{listing}");
        // The create path is gated: alice may own one cluster, not two.
        run_jobs(
            &mut s,
            &mut js,
            "ec2createcluster",
            &["-cname", "a1", "-csize", "2", "-analyst", "alice"],
        )
        .unwrap();
        let err = run_jobs(
            &mut s,
            &mut js,
            "ec2createcluster",
            &["-cname", "a2", "-csize", "2", "-analyst", "alice"],
        )
        .unwrap_err()
        .to_string();
        assert!(
            err.contains("alice") && err.contains("limit 1") && err.contains("owns 1"),
            "the error must name the tenant, the limit and the usage: {err}"
        );
        assert!(!s.clusters_cfg.contains("a2"), "a refused cluster must not exist");
        // Other tenants (and untagged creates) are unaffected.
        run_jobs(
            &mut s,
            &mut js,
            "ec2createcluster",
            &["-cname", "b1", "-csize", "2", "-analyst", "bob"],
        )
        .unwrap();
        // Clear restores unlimited.
        let out = run_jobs(&mut s, &mut js, "ec2quota", &["-analyst", "alice", "-clear"]).unwrap();
        assert!(out.contains("cleared"), "{out}");
        run_jobs(
            &mut s,
            &mut js,
            "ec2createcluster",
            &["-cname", "a2", "-csize", "2", "-analyst", "alice"],
        )
        .unwrap();
        assert!(s.clusters_cfg.contains("a2"));
    }

    #[test]
    fn invoice_cli_renders_text_and_json() {
        let mut s = session();
        s.cloud.ledger.set_analyst("alice");
        s.cloud
            .ledger
            .bill_instance("i-1", "m2.2xlarge", 90, 0.0, 3600.0);
        s.cloud.ledger.set_analyst("");
        let out = run(&mut s, "ec2invoice", &["-analyst", "alice"]).unwrap();
        assert!(out.contains("invoice for tenant 'alice'"), "{out}");
        assert!(out.contains("on-demand instance-hours"), "{out}");
        assert!(out.contains("9000"), "exact centi-cents must render: {out}");
        let out = run(&mut s, "ec2invoice", &["-analyst", "alice", "-json"]).unwrap();
        let j = crate::util::json::Json::parse(&out).unwrap();
        assert_eq!(
            j.get("total_centi_cents").and_then(crate::util::json::Json::as_u64),
            Some(s.cloud.ledger.total_centi_cents_for("alice"))
        );
        // -analyst is required.
        assert!(run(&mut s, "ec2invoice", &[]).is_err());
    }

    #[test]
    fn report_and_jobstatus_carry_the_slo_rollup() {
        let mut s = session();
        run(&mut s, "mkproject", &["-projectdir", "proj", "-kind", "sweep"]).unwrap();
        let mut js = JobScheduler::new(crate::jobs::AutoscalerConfig {
            min_clusters: 1,
            max_clusters: 1,
            ..Default::default()
        });
        run_jobs(
            &mut s,
            &mut js,
            "ec2submitjob",
            &[
                "-projectdir",
                "proj",
                "-rscript",
                "sweep.json",
                "-runname",
                "r1",
                "-deadline",
                "86400",
                "-analyst",
                "alice",
            ],
        )
        .unwrap();
        let out = run_jobs(&mut s, &mut js, "ec2jobstatus", &[]).unwrap();
        assert!(out.contains("deadline SLOs by analyst:"), "{out}");
        assert!(out.contains("alice"), "{out}");
        let out = run_jobs(&mut s, &mut js, "report", &[]).unwrap();
        assert!(out.contains("deadline SLOs by analyst:"), "{out}");
        run_jobs(&mut s, &mut js, "ec2jobqueue", &["-drain"]).unwrap();
        let out = run_jobs(&mut s, &mut js, "report", &[]).unwrap();
        assert!(out.contains("met 1"), "{out}");
        // No deadlines anywhere -> no SLO section.
        let js2 = JobScheduler::new(crate::jobs::AutoscalerConfig::default());
        assert!(js2.slo_lines(&s).is_empty());
    }

    #[test]
    fn manual_documents_every_ec2_command() {
        // The operator manual must carry a `## `ec2…`` section for
        // every registered ec2* subcommand (CI runs the same check as
        // a grep so doc drift fails fast either way).
        let manual = std::fs::read_to_string(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/../docs/MANUAL.md"
        ))
        .expect("docs/MANUAL.md must exist");
        for c in registry() {
            if !c.name.starts_with("ec2") {
                continue;
            }
            assert!(
                manual.contains(&format!("## `{}`", c.name)),
                "docs/MANUAL.md has no section for {}",
                c.name
            );
        }
    }

    #[test]
    fn manual_coverage_script_agrees_with_the_registry() {
        // The CI manual-coverage gate lives in ci/check_manual.py;
        // this guard runs the same script so the workflow and the
        // test suite cannot drift. Skipped silently where python3 is
        // unavailable — the pure-Rust twin above still enforces the
        // invariant there.
        let root = concat!(env!("CARGO_MANIFEST_DIR"), "/..");
        let out = match std::process::Command::new("python3")
            .arg("ci/check_manual.py")
            .current_dir(root)
            .output()
        {
            Ok(o) => o,
            Err(_) => return, // no python3 on this machine
        };
        assert!(
            out.status.success(),
            "ci/check_manual.py failed:\n{}{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
    }

    #[test]
    fn submitjob_deadline_flag_validates_and_reaches_the_queue() {
        let mut s = session();
        run(&mut s, "mkproject", &["-projectdir", "proj", "-kind", "sweep"]).unwrap();
        let mut js = JobScheduler::new(crate::jobs::AutoscalerConfig::default());
        // A deadline before the virtual epoch can only be in the past.
        let err = run_jobs(
            &mut s,
            &mut js,
            "ec2submitjob",
            &[
                "-projectdir",
                "proj",
                "-rscript",
                "sweep.json",
                "-runname",
                "r0",
                "-deadline",
                "2011-12-31T00:00:00Z",
            ],
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("past"), "{err:#}");
        assert_eq!(js.queue.jobs().count(), 0, "a rejected job must not queue");
        // A sane relative deadline is echoed and lands on the job.
        let out = run_jobs(
            &mut s,
            &mut js,
            "ec2submitjob",
            &[
                "-projectdir",
                "proj",
                "-rscript",
                "sweep.json",
                "-runname",
                "r1",
                "-deadline",
                "86400",
            ],
        )
        .unwrap();
        assert!(out.contains("deadline t="), "{out}");
        let job = js.queue.jobs().next().unwrap();
        assert!(job.spec.deadline_s.is_some());
        // ec2jobstatus reports eta + margin from the estimator.
        let out = run_jobs(&mut s, &mut js, "ec2jobstatus", &["-jobid", "1"]).unwrap();
        assert!(out.contains("deadline t=") && out.contains("green"), "{out}");
    }

    #[test]
    fn autoscale_bid_and_work_policy_flags() {
        let mut s = session();
        let mut js = JobScheduler::new(crate::jobs::AutoscalerConfig::default());
        let out = run_jobs(
            &mut s,
            &mut js,
            "ec2autoscale",
            &["-policy", "work", "-target", "1800", "-bid", "forecast+margin", "-spot"],
        )
        .unwrap();
        assert!(out.contains("work") && out.contains("forecast+margin"), "{out}");
        assert_eq!(js.autoscaler.cfg.work_target_s, 1800.0);
        assert_eq!(js.autoscaler.cfg.bid, crate::jobs::BidStrategy::ForecastMargin);
        // Bad values are rejected cleanly.
        assert!(run_jobs(&mut s, &mut js, "ec2autoscale", &["-bid", "yolo"]).is_err());
        assert!(run_jobs(&mut s, &mut js, "ec2autoscale", &["-target", "0"]).is_err());
    }

    #[test]
    fn conflicting_placement_flags_rejected_by_parser() {
        let spec = registry()
            .into_iter()
            .find(|c| c.name == "ec2runoncluster")
            .unwrap();
        let err = spec
            .parse(["-runname", "r", "-bynode", "-byslot"].map(String::from))
            .unwrap_err();
        assert!(matches!(err, crate::util::argparse::ArgError::Exclusive(_)));
    }

    #[test]
    fn spot_switch_creates_spot_capacity() {
        let mut s = session();
        let out = run(&mut s, "ec2createcluster", &["-cname", "sc", "-csize", "2", "-spot"]).unwrap();
        assert!(out.contains("spot"), "{out}");
        let e = s.clusters_cfg.get("sc").unwrap().clone();
        for id in e.all_ids() {
            assert!(s.cloud.instance(&id).unwrap().is_spot());
        }
    }

    #[test]
    fn session_json_roundtrip_preserves_state() {
        let mut s = session();
        run(&mut s, "mkproject", &["-projectdir", "proj", "-kind", "sweep"]).unwrap();
        run(&mut s, "ec2createinstance", &["-iname", "i1", "-type", "m2.4xlarge"]).unwrap();
        run(&mut s, "ec2senddatatoinstance", &["-iname", "i1", "-projectdir", "proj"]).unwrap();
        let j = s.to_json();
        let s2 = Session::from_json(
            SimParams::default(),
            Box::new(MockEngine::new(100.0)),
            &j,
        )
        .unwrap();
        assert!(s2.instances_cfg.contains("i1"));
        assert_eq!(s2.cloud.clock.now_s(), s.cloud.clock.now_s());
        let id = s2.instances_cfg.get("i1").unwrap().instance_id.clone();
        let inst = s2.cloud.instance(&id).unwrap();
        assert!(inst.fs.exists("root/proj/sweep.json"));
        assert_eq!(
            inst.attached_volume,
            s.cloud.instance(&id).unwrap().attached_volume
        );
        // New resources after restore get fresh ids.
        let mut s3 = s2;
        run(&mut s3, "ec2createinstance", &["-iname", "i2"]).unwrap();
        let id2 = s3.instances_cfg.get("i2").unwrap().instance_id.clone();
        assert_ne!(id, id2);
    }
}
