//! Command registry and dispatcher for the P2RAC CLI.
//!
//! The 19 paper commands plus three quality-of-life extras
//! (`mkproject`, `batch`, `report`) are implemented by five per-domain
//! modules — [`super::resources`], [`super::data`], [`super::jobs`],
//! [`super::functions`] and [`super::obs`] — each exposing one
//! [`Command`] implementation. This module owns the shared contract
//! every domain follows:
//!
//! - **Registry**: [`registry`] is the concatenation of every domain's
//!   [`Command::specs`]; `-h`/`-v`, exclusive-flag groups and required
//!   args are enforced uniformly by the arg parser before any domain
//!   code runs.
//! - **Exit codes** (see [`super::main_entry`]): `0` command ran and
//!   printed its output; `1` the command failed (parse error, unknown
//!   command, or a domain error — the message lands on stderr
//!   prefixed `p2rac:`); `2` no command was given (the global help is
//!   printed).
//! - **`-json` envelope**: machine-readable output from the
//!   queue-inspection commands is wrapped by [`json_envelope`] as
//!   `{"command": <name>, "ok": true, "data": {…}}` so scripts can
//!   key on stable top-level fields. (Pre-envelope emitters such as
//!   `ec2invoice`/`ec2metrics` keep their historical top-level shape.)
//! - **State routing**: [`run_command`] decides which persisted state
//!   loads (session only, session+jobs, or session+functions) and the
//!   domains receive it through [`CmdCtx`], with absent planes as
//!   `None`.

use super::{load_jobs, load_session, make_engine, save_jobs, save_session};
use crate::analytics::CatBondData;
use crate::coordinator::Session;
use crate::jobs::JobScheduler;
use crate::simcloud::SpanCategory;
use crate::util::argparse::{CommandSpec, ParsedArgs};
use crate::util::humanfmt;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};

/// One CLI command domain: a named group of command specs plus the
/// execution logic for every command it owns.
pub trait Command {
    /// Short domain name (for diagnostics and docs).
    fn domain(&self) -> &'static str;
    /// The command specs this domain registers.
    fn specs(&self) -> Vec<CommandSpec>;
    /// Whether this domain implements `cmd`.
    fn owns(&self, cmd: &str) -> bool {
        self.specs().iter().any(|c| c.name == cmd)
    }
    /// Execute one already-parsed command; returns its stdout text.
    fn run(&self, ctx: CmdCtx<'_>, cmd: &str, p: &ParsedArgs) -> Result<String>;
}

/// Everything a command may operate on. The session is always loaded;
/// the job scheduler and the serverless planes are `None` unless the
/// dispatcher loaded them for this command (see [`run_command`]).
pub struct CmdCtx<'a> {
    /// The simulated cloud + Analyst site session.
    pub s: &'a mut Session,
    /// The persisted job queue / autoscaler / quota state, when loaded.
    pub js: Option<&'a mut JobScheduler>,
    /// Read-only tenant quota book for the serverless admit gate.
    pub quotas: Option<&'a crate::jobs::QuotaBook>,
    /// The persisted serverless function platform, when loaded.
    pub fns: Option<&'a mut crate::jobs::FnPlatform>,
}

/// The five command domains, in registry (help) order.
pub fn domains() -> Vec<Box<dyn Command>> {
    vec![
        Box::new(super::resources::Resources),
        Box::new(super::data::Data),
        Box::new(super::jobs::Jobs),
        Box::new(super::functions::Functions),
        Box::new(super::obs::Obs),
    ]
}

/// All commands with their specs, paper-accurate syntax.
pub fn registry() -> Vec<CommandSpec> {
    domains().into_iter().flat_map(|d| d.specs()).collect()
}

/// The shared machine-readable output envelope:
/// `{"command": <name>, "ok": true, "data": {…}}`.
pub fn json_envelope(command: &str, data: Json) -> Json {
    Json::from_pairs(vec![
        ("command", Json::str(command)),
        ("ok", Json::Bool(true)),
        ("data", data),
    ])
}

pub fn global_help() -> String {
    let mut s = String::from(
        "P2RAC — Platform for Parallel R-based Analytics on the Cloud\n\
         usage: p2rac <command> [args]   (every command supports -h and -v)\n\ncommands:\n",
    );
    for c in registry() {
        s.push_str(&format!("  {:<28} {}\n", c.name, c.about));
    }
    s
}

fn find_spec(name: &str) -> Result<CommandSpec> {
    registry()
        .into_iter()
        .find(|c| c.name == name)
        .ok_or_else(|| anyhow!("unknown command '{name}'\n\n{}", global_help()))
}

/// Parse and run one command; returns its stdout text.
pub fn dispatch(cmd: &str, args: Vec<String>) -> Result<String> {
    let spec = find_spec(cmd)?;
    let parsed = spec.parse(args).map_err(|e| anyhow!("{e}\n\n{}", spec.usage()))?;
    if parsed.help {
        return Ok(spec.usage());
    }
    if parsed.version {
        return Ok(crate::VERSION.to_string());
    }
    run_command(cmd, &parsed)
}

fn run_command(cmd: &str, p: &ParsedArgs) -> Result<String> {
    // ec2configurep2rac starts from scratch; everything else loads.
    if cmd == "ec2configurep2rac" {
        let s = Session::new(crate::simcloud::SimParams::default(), make_engine());
        save_session(&s)?;
        return Ok(format!(
            "P2RAC configured. Session state: {}\nDefault type: {}, default snapshot: {}",
            super::session_dir().display(),
            s.platform.default_type,
            s.platform.default_snapshot
        ));
    }
    if cmd == "batch" {
        return run_batch(p.value("file").ok_or_else(|| anyhow!("-file required"))?);
    }

    let mut s = load_session(make_engine())?;
    if is_fn_command(cmd) {
        // The function tier reads the quota book persisted with the
        // jobs state but never mutates it, so jobs state is loaded
        // read-only (no save — no spurious append-log record).
        let js = load_jobs()?;
        let mut fns = super::load_fns()?;
        let out = apply_with_fns(&mut s, &js.quotas, &mut fns, cmd, p)?;
        super::save_fns(&mut fns)?;
        save_session(&s)?;
        return Ok(out);
    }
    if is_jobs_command(cmd) {
        let mut js = load_jobs()?;
        js.prune_fleet(&s);
        let out = apply_with_jobs(&mut s, &mut js, cmd, p)?;
        save_jobs(&mut js)?;
        save_session(&s)?;
        return Ok(out);
    }
    let out = apply(&mut s, cmd, p)?;
    save_session(&s)?;
    Ok(out)
}

/// Commands that operate on the persisted job-queue state (including
/// the quota book persisted beside it, which `ec2createcluster`
/// consults on its create path and `report` for the SLO rollup).
fn is_jobs_command(cmd: &str) -> bool {
    matches!(
        cmd,
        "ec2submitjob"
            | "ec2genload"
            | "ec2jobstatus"
            | "ec2jobqueue"
            | "ec2autoscale"
            | "ec2quota"
            | "ec2createcluster"
            | "report"
    )
}

/// Commands that operate on the persisted serverless function
/// platform (they also read the quota book for the admit gate and the
/// autoscaler's demand ranking).
fn is_fn_command(cmd: &str) -> bool {
    matches!(cmd, "ec2invoke" | "ec2fnpool")
}

/// Batch-mode execution (paper §3.4): commands listed in a script file,
/// executed without Analyst intervention.
fn run_batch(file: &str) -> Result<String> {
    let text = std::fs::read_to_string(file)?;
    let mut out = String::new();
    let mut s = load_session(make_engine())?;
    let mut js = load_jobs()?;
    js.prune_fleet(&s);
    // The function platform loads lazily: batches that never touch the
    // fn tier don't create (or append to) its persistence files.
    let mut fns: Option<crate::jobs::FnPlatform> = None;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace().map(str::to_string);
        let cmd = parts.next().unwrap();
        let cmd = cmd.strip_prefix("p2rac").map(str::trim).filter(|c| !c.is_empty())
            .map(str::to_string)
            .unwrap_or(cmd);
        let spec = find_spec(&cmd)?;
        let parsed = spec
            .parse(parts.collect::<Vec<_>>())
            .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
        out.push_str(&format!("$ {line}\n"));
        if is_fn_command(&cmd) {
            if fns.is_none() {
                fns = Some(super::load_fns()?);
            }
            out.push_str(&apply_with_fns(
                &mut s,
                &js.quotas,
                fns.as_mut().unwrap(),
                &cmd,
                &parsed,
            )?);
        } else {
            out.push_str(&apply_with_jobs(&mut s, &mut js, &cmd, &parsed)?);
        }
        out.push('\n');
    }
    if let Some(mut f) = fns {
        super::save_fns(&mut f)?;
    }
    save_jobs(&mut js)?;
    save_session(&s)?;
    Ok(out)
}

/// Route an already-parsed command to the domain that owns it.
fn route(ctx: CmdCtx<'_>, cmd: &str, p: &ParsedArgs) -> Result<String> {
    let Some(d) = domains().into_iter().find(|d| d.owns(cmd)) else {
        bail!("unhandled command '{cmd}'");
    };
    d.run(ctx, cmd, p)
}

/// Execute one already-parsed command against a session.
pub fn apply(s: &mut Session, cmd: &str, p: &ParsedArgs) -> Result<String> {
    route(CmdCtx { s, js: None, quotas: None, fns: None }, cmd, p)
}

/// Execute one command against a session and the persisted job
/// scheduler: the queue/autoscaler/governance commands live here
/// (plus the quota gate on `ec2createcluster` and the SLO rollup on
/// `report`); everything else behaves as under [`apply`].
pub fn apply_with_jobs(
    s: &mut Session,
    js: &mut JobScheduler,
    cmd: &str,
    p: &ParsedArgs,
) -> Result<String> {
    route(CmdCtx { s, js: Some(js), quotas: None, fns: None }, cmd, p)
}

/// Execute one serverless-tier command (`ec2invoke` / `ec2fnpool`)
/// against a session, the tenant quota book (read-only: the fn tier
/// enforces but never edits quotas) and the function platform.
pub fn apply_with_fns(
    s: &mut Session,
    quotas: &crate::jobs::QuotaBook,
    fns: &mut crate::jobs::FnPlatform,
    cmd: &str,
    p: &ParsedArgs,
) -> Result<String> {
    if !is_fn_command(cmd) {
        bail!("'{cmd}' is not a serverless-tier command");
    }
    route(CmdCtx { s, js: None, quotas: Some(quotas), fns: Some(fns) }, cmd, p)
}

pub(super) fn project_dir<'a>(p: &'a ParsedArgs) -> &'a str {
    // Paper: "should the project directory not be specified then the
    // current working directory at the Analyst site is used".
    p.value_or("projectdir", "current_project")
}

/// When `-rscript` is omitted the Analyst is shown the candidates
/// (paper: "the user is prompted to select from a list").
pub(super) fn pick_script(s: &Session, p: &ParsedArgs) -> Result<String> {
    if let Some(r) = p.value("rscript") {
        return Ok(r.to_string());
    }
    let scripts = s.list_scripts(project_dir(p));
    match scripts.len() {
        0 => bail!("no scripts in project directory"),
        1 => Ok(scripts[0].clone()),
        _ => bail!(
            "multiple scripts available, pass -rscript one of: {}",
            scripts.join(", ")
        ),
    }
}

/// Create an example project on the Analyst site.
pub fn mkproject(s: &mut Session, dir: &str, kind: &str, seed: u64) -> Result<String> {
    match kind {
        "catopt" => {
            // Scaled dataset matching the AOT artifact shapes.
            let (m, e) = (512, 2048);
            let data = CatBondData::generate(seed, m, e);
            for (name, bytes) in data.to_files() {
                s.analyst.write(&format!("{dir}/{name}"), bytes);
            }
            s.analyst.write(
                &format!("{dir}/catopt.json"),
                br#"{"type":"catopt","pop_size":200,"max_generations":50,"seed":42,"bfgs_every":25}"#
                    .to_vec(),
            );
            Ok(format!(
                "created CATopt project '{dir}' (m={m}, e={e}, {} of loss data)",
                humanfmt::bytes(data.nbytes())
            ))
        }
        "sweep" => {
            s.analyst.write(
                &format!("{dir}/sweep.json"),
                br#"{"type":"mc_sweep","n_jobs":512,"att_min":0.5,"att_max":8.0,"lim_min":1.0,"lim_max":12.0,"seed":2012}"#
                    .to_vec(),
            );
            s.analyst
                .write(&format!("{dir}/data/params_note.txt"), b"parameter sweep project".to_vec());
            Ok(format!("created parameter-sweep project '{dir}'"))
        }
        other => bail!("unknown project kind '{other}' (catopt | sweep)"),
    }
}

/// Virtual-time + billing report.
pub fn report(s: &Session) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "virtual time elapsed: {}\n",
        humanfmt::secs(s.cloud.clock.now_s())
    ));
    out.push_str(&format!(
        "billed so far: ${:.2} ({} line items)\n",
        s.cloud.ledger.total_dollars(),
        s.cloud.ledger.items().len()
    ));
    let tenants = s.cloud.ledger.analysts();
    if !tenants.is_empty() {
        out.push_str("billed by analyst:\n");
        for a in &tenants {
            out.push_str(&format!(
                "  {:<20} ${:.2}\n",
                a,
                s.cloud.ledger.total_centi_cents_for(a) as f64 / 10_000.0
            ));
        }
        let untagged = s.cloud.ledger.total_centi_cents_for("");
        if untagged > 0 {
            out.push_str(&format!(
                "  {:<20} ${:.2}\n",
                "(platform)",
                untagged as f64 / 10_000.0
            ));
        }
    }
    let cats = [
        (SpanCategory::CreateResource, "create resources"),
        (SpanCategory::SubmitToMaster, "submit to instance/master"),
        (SpanCategory::SubmitToAllNodes, "submit to all nodes"),
        (SpanCategory::Compute, "compute"),
        (SpanCategory::FetchFromMaster, "fetch from instance/master"),
        (SpanCategory::FetchFromAllNodes, "fetch from all nodes"),
        (SpanCategory::TerminateResource, "terminate resources"),
    ];
    out.push_str("time by category (this invocation):\n");
    for (c, label) in cats {
        let t = s.cloud.clock.category_total_s(c);
        if t > 0.0 {
            out.push_str(&format!("  {:<28} {}\n", label, humanfmt::secs(t)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MockEngine;
    use crate::simcloud::SimParams;
    use crate::telemetry::EventKind;

    fn session() -> Session {
        Session::new(SimParams::default(), Box::new(MockEngine::new(100.0)))
    }

    fn run(s: &mut Session, cmd: &str, args: &[&str]) -> Result<String> {
        let spec = registry().into_iter().find(|c| c.name == cmd).unwrap();
        let p = spec.parse(args.iter().map(|a| a.to_string())).unwrap();
        apply(s, cmd, &p)
    }

    #[test]
    fn full_cli_cluster_workflow() {
        let mut s = session();
        run(&mut s, "mkproject", &["-projectdir", "proj", "-kind", "sweep"]).unwrap();
        let out = run(
            &mut s,
            "ec2createcluster",
            &["-cname", "hpc_cluster", "-csize", "4", "-type", "m2.2xlarge"],
        )
        .unwrap();
        assert!(out.contains("hpc_cluster"));
        run(&mut s, "ec2senddatatoclusternodes", &["-cname", "hpc_cluster", "-projectdir", "proj"])
            .unwrap();
        let out = run(
            &mut s,
            "ec2runoncluster",
            &["-cname", "hpc_cluster", "-projectdir", "proj", "-rscript", "sweep.json", "-runname", "r1", "-bynode"],
        )
        .unwrap();
        assert!(out.contains("run complete"));
        run(
            &mut s,
            "ec2getresults",
            &["-cname", "hpc_cluster", "-projectdir", "proj", "-runname", "r1", "-frommaster"],
        )
        .unwrap();
        let listing = run(&mut s, "ec2listclusters", &[]).unwrap();
        assert!(listing.contains("hpc_cluster"));
        let rep = report(&s);
        assert!(rep.contains("virtual time"));
        run(&mut s, "ec2terminatecluster", &["-cname", "hpc_cluster"]).unwrap();
        assert!(s.clusters_cfg.names().is_empty());
    }

    #[test]
    fn mkproject_catopt_writes_dataset() {
        let mut s = session();
        let out = run(&mut s, "mkproject", &["-projectdir", "cp", "-kind", "catopt"]).unwrap();
        assert!(out.contains("CATopt"));
        assert!(s.analyst.exists("cp/catopt.json"));
        assert!(s.analyst.exists("cp/data/industry_losses.bin"));
        assert!(s.analyst.dir_size("cp") > 1_000_000);
    }

    #[test]
    fn pick_script_prompts_on_ambiguity() {
        let mut s = session();
        s.analyst.write("p/a.json", b"{}".to_vec());
        s.analyst.write("p/b.json", b"{}".to_vec());
        let spec = registry().into_iter().find(|c| c.name == "ec2runoninstance").unwrap();
        let p = spec
            .parse(["-projectdir", "p", "-runname", "r"].map(String::from))
            .unwrap();
        let err = pick_script(&s, &p).unwrap_err();
        assert!(err.to_string().contains("a.json"));
    }

    #[test]
    fn resourcelock_requires_target_and_mode() {
        let mut s = session();
        run(&mut s, "ec2createinstance", &["-iname", "i1"]).unwrap();
        assert!(run(&mut s, "ec2resourcelock", &["-iname", "i1"]).is_err());
        run(&mut s, "ec2resourcelock", &["-iname", "i1", "-inuse"]).unwrap();
        assert!(s.instances_cfg.get("i1").unwrap().in_use);
        run(&mut s, "ec2resourcelock", &["-iname", "i1", "-free"]).unwrap();
        assert!(!s.instances_cfg.get("i1").unwrap().in_use);
    }

    #[test]
    fn global_help_lists_all_paper_commands() {
        let h = global_help();
        for c in [
            "ec2createinstance",
            "ec2terminateinstance",
            "ec2senddatatoinstance",
            "ec2getresultsfrominstance",
            "ec2runoninstance",
            "ec2createcluster",
            "ec2terminatecluster",
            "ec2terminateall",
            "ec2senddatatoclusternodes",
            "ec2senddatatomaster",
            "ec2getresults",
            "ec2runoncluster",
            "ec2listinstances",
            "ec2listclusters",
            "ec2listallresources",
            "ec2logintoinstance",
            "ec2logintocluster",
            "ec2resourcelock",
            "ec2configurep2rac",
            "ec2submitjob",
            "ec2jobstatus",
            "ec2jobqueue",
            "ec2autoscale",
            "ec2snapshot",
            "ec2lsobjects",
            "ec2quota",
            "ec2invoice",
            "ec2genload",
            "ec2metrics",
            "ec2trace",
            "ec2invoke",
            "ec2fnpool",
        ] {
            assert!(h.contains(c), "help missing {c}");
        }
    }

    #[test]
    fn every_command_is_owned_by_exactly_one_domain() {
        for c in registry() {
            let owners: Vec<&'static str> = domains()
                .into_iter()
                .filter(|d| d.owns(c.name))
                .map(|d| d.domain())
                .collect();
            assert_eq!(owners.len(), 1, "'{}' owned by {owners:?}", c.name);
        }
    }

    #[test]
    fn metrics_command_reports_the_bus() {
        let mut s = session();
        s.cloud.telemetry.emit(0.0, EventKind::Submit, "alice", None, None, Json::obj());
        let out = run(&mut s, "ec2metrics", &[]).unwrap();
        assert!(out.contains("telemetry level metrics"), "{out}");
        assert!(out.contains("jobs_submitted_total"), "{out}");
        let out = run(&mut s, "ec2metrics", &["-json"]).unwrap();
        let j = Json::parse(&out).unwrap();
        assert_eq!(j.opt_str("level").as_deref(), Some("metrics"));
        assert_eq!(j.get("events").and_then(Json::as_u64), Some(1));
        let out = run(&mut s, "ec2metrics", &["-prom"]).unwrap();
        assert!(out.contains("p2rac_jobs_submitted_total 1"), "{out}");
        // The level switch round-trips.
        run(&mut s, "ec2metrics", &["-level", "off"]).unwrap();
        assert!(!s.cloud.telemetry.on());
        assert!(run(&mut s, "ec2metrics", &["-level", "loud"]).is_err());
    }

    #[test]
    fn trace_command_summarises_and_exports() {
        let dir = std::env::temp_dir().join(format!("p2rac-cli-trace-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.jsonl");
        std::fs::write(
            &path,
            "{\"detail\":{},\"kind\":\"submit\",\"seq\":1,\"t_s\":0,\"tenant\":\"a\"}\n\
             {\"cluster\":\"fleet1\",\"detail\":{\"duration_s\":60,\"from_s\":5},\"job\":\"job-1\",\
             \"kind\":\"slice-complete\",\"seq\":2,\"t_s\":65,\"tenant\":\"a\"}\n",
        )
        .unwrap();
        let mut s = session();
        // No sink configured and no -file: a clean error.
        assert!(run(&mut s, "ec2trace", &[]).is_err());
        let p = path.to_str().unwrap();
        let out = run(&mut s, "ec2trace", &["-file", p]).unwrap();
        assert!(out.contains("2 events"), "{out}");
        let out = run(&mut s, "ec2trace", &["-file", p, "-json"]).unwrap();
        let j = Json::parse(&out).unwrap();
        assert_eq!(j.path(&["by_kind", "slice-complete"]).and_then(Json::as_u64), Some(1));
        let chrome = dir.join("t.chrome.json");
        let c = chrome.to_str().unwrap();
        run(&mut s, "ec2trace", &["-file", p, "-chrome", c]).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&chrome).unwrap()).unwrap();
        assert_eq!(doc.get("traceEvents").and_then(Json::as_arr).map(|a| a.len()), Some(2));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn snapshot_and_lsobjects_commands() {
        let mut s = session();
        run(&mut s, "ec2createcluster", &["-cname", "c", "-csize", "2"]).unwrap();
        let out = run(&mut s, "ec2snapshot", &["-cname", "c", "-desc", "state"]).unwrap();
        assert!(out.contains("created snapshot snap-"), "{out}");
        // Empty storage plane lists cleanly…
        let out = run(&mut s, "ec2lsobjects", &[]).unwrap();
        assert!(out.contains("no objects"), "{out}");
        // …and objects show up once something is stored.
        s.cloud
            .s3_put("p2rac-checkpoints", "job-1", b"{}".to_vec(), crate::simcloud::Link::Lan);
        let out = run(&mut s, "ec2lsobjects", &["-bucket", "p2rac-checkpoints"]).unwrap();
        assert!(out.contains("job-1") && out.contains("digest="), "{out}");
    }

    #[test]
    fn resident_submit_flag_reaches_the_queue() {
        let mut s = session();
        run(&mut s, "mkproject", &["-projectdir", "proj", "-kind", "sweep"]).unwrap();
        let mut js = JobScheduler::new(crate::jobs::AutoscalerConfig::default());
        let out = run_jobs(
            &mut s,
            &mut js,
            "ec2submitjob",
            &[
                "-projectdir",
                "proj",
                "-rscript",
                "sweep.json",
                "-runname",
                "r1",
                "-resident",
                "-analyst",
                "alice",
            ],
        )
        .unwrap();
        assert!(out.contains("resident"), "{out}");
        let job = js.queue.jobs().next().unwrap();
        assert!(job.resident);
        assert_eq!(job.analyst, "alice");
    }

    fn run_jobs(
        s: &mut Session,
        js: &mut JobScheduler,
        cmd: &str,
        args: &[&str],
    ) -> Result<String> {
        let spec = registry().into_iter().find(|c| c.name == cmd).unwrap();
        let p = spec.parse(args.iter().map(|a| a.to_string())).unwrap();
        apply_with_jobs(s, js, cmd, &p)
    }

    fn run_fns(
        s: &mut Session,
        quotas: &crate::jobs::QuotaBook,
        fns: &mut crate::jobs::FnPlatform,
        cmd: &str,
        args: &[&str],
    ) -> Result<String> {
        let spec = registry().into_iter().find(|c| c.name == cmd).unwrap();
        let p = spec.parse(args.iter().map(|a| a.to_string())).unwrap();
        apply_with_fns(s, quotas, fns, cmd, &p)
    }

    #[test]
    fn invoke_command_goes_cold_then_warm() {
        let mut s = session();
        run(&mut s, "mkproject", &["-projectdir", "proj", "-kind", "sweep"]).unwrap();
        let quotas = crate::jobs::QuotaBook::default();
        let mut fns = crate::jobs::FnPlatform::default();
        let out = run_fns(
            &mut s,
            &quotas,
            &mut fns,
            "ec2invoke",
            &["-fname", "score", "-projectdir", "proj", "-analyst", "alice", "-repeat", "3"],
        )
        .unwrap();
        assert!(out.contains("cold"), "{out}");
        assert!(out.contains("warm"), "{out}");
        assert_eq!(fns.invocations_total, 3);
        assert_eq!(fns.cold_total, 1, "repeats within the gap must stay warm");
        // A missing project is a clean error, not a provision.
        let err = run_fns(
            &mut s,
            &quotas,
            &mut fns,
            "ec2invoke",
            &["-fname", "score", "-projectdir", "nope"],
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("mkproject"), "{err}");
    }

    #[test]
    fn fnpool_command_configures_and_reports() {
        let mut s = session();
        run(&mut s, "mkproject", &["-projectdir", "proj", "-kind", "sweep"]).unwrap();
        let quotas = crate::jobs::QuotaBook::default();
        let mut fns = crate::jobs::FnPlatform::default();
        let out = run_fns(
            &mut s,
            &quotas,
            &mut fns,
            "ec2fnpool",
            &["-policy", "fixed", "-keepalive", "240", "-maxidlemb", "2048"],
        )
        .unwrap();
        assert!(out.contains("policy fixed (base 240s)"), "{out}");
        assert_eq!(fns.autoscaler.max_idle_mb, 2048);
        run_fns(
            &mut s,
            &quotas,
            &mut fns,
            "ec2invoke",
            &["-fname", "score", "-projectdir", "proj"],
        )
        .unwrap();
        let st = run_fns(&mut s, &quotas, &mut fns, "ec2fnpool", &["-drain", "-flush", "-json"])
            .unwrap();
        let j = Json::parse(&st).unwrap();
        assert_eq!(j.get("pool").and_then(Json::as_u64), Some(0));
        assert_eq!(j.get("evicted_total").and_then(Json::as_u64), Some(1));
        assert_eq!(j.get("invocations_total").and_then(Json::as_u64), Some(1));
        let bad = run_fns(&mut s, &quotas, &mut fns, "ec2fnpool", &["-policy", "lru"])
            .unwrap_err()
            .to_string();
        assert!(bad.contains("unknown keepalive policy"), "{bad}");
    }

    #[test]
    fn job_queue_cli_workflow() {
        let mut s = session();
        run(&mut s, "mkproject", &["-projectdir", "proj", "-kind", "sweep"]).unwrap();
        let mut js = JobScheduler::new(crate::jobs::AutoscalerConfig {
            min_clusters: 1,
            max_clusters: 1,
            ..Default::default()
        });
        let out = run_jobs(
            &mut s,
            &mut js,
            "ec2autoscale",
            &["-min", "1", "-max", "2", "-policy", "elastic", "-spot"],
        )
        .unwrap();
        assert!(out.contains("spot") && out.contains("elastic"));
        let out = run_jobs(
            &mut s,
            &mut js,
            "ec2submitjob",
            &["-projectdir", "proj", "-rscript", "sweep.json", "-runname", "r1", "-priority", "high"],
        )
        .unwrap();
        assert!(out.contains("submitted job-1"), "{out}");
        let out = run_jobs(&mut s, &mut js, "ec2jobqueue", &["-drain"]).unwrap();
        assert!(out.contains("queue drained"), "{out}");
        let out = run_jobs(&mut s, &mut js, "ec2jobstatus", &["-jobid", "1"]).unwrap();
        assert!(out.contains("completed"), "{out}");
        assert!(s.analyst.exists("proj_results/r1/summary.json"));
        let out = run_jobs(&mut s, &mut js, "ec2jobqueue", &["-shutdown"]).unwrap();
        assert!(out.contains("fleet released"), "{out}");
        assert!(s.cloud.live_instances().is_empty());
    }

    #[test]
    fn jobstatus_and_jobqueue_json_use_the_envelope() {
        let mut s = session();
        run(&mut s, "mkproject", &["-projectdir", "proj", "-kind", "sweep"]).unwrap();
        let mut js = JobScheduler::new(crate::jobs::AutoscalerConfig::default());
        run_jobs(
            &mut s,
            &mut js,
            "ec2submitjob",
            &["-projectdir", "proj", "-rscript", "sweep.json", "-runname", "r1"],
        )
        .unwrap();
        // Stable envelope keys: command, ok, data.
        let out = run_jobs(&mut s, &mut js, "ec2jobstatus", &["-json"]).unwrap();
        let j = Json::parse(&out).unwrap();
        assert_eq!(j.opt_str("command").as_deref(), Some("ec2jobstatus"));
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(j.path(&["data", "pending"]).and_then(Json::as_u64), Some(1));
        assert_eq!(
            j.path(&["data", "jobs"]).and_then(Json::as_arr).map(|a| a.len()),
            Some(1)
        );
        let out = run_jobs(&mut s, &mut js, "ec2jobstatus", &["-jobid", "1", "-json"]).unwrap();
        let j = Json::parse(&out).unwrap();
        assert_eq!(j.opt_str("command").as_deref(), Some("ec2jobstatus"));
        assert_eq!(j.path(&["data", "id"]).and_then(Json::as_u64), Some(1));
        let out = run_jobs(&mut s, &mut js, "ec2jobqueue", &["-json"]).unwrap();
        let j = Json::parse(&out).unwrap();
        assert_eq!(j.opt_str("command").as_deref(), Some("ec2jobqueue"));
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(j.path(&["data", "pending"]).and_then(Json::as_u64), Some(1));
        assert_eq!(j.path(&["data", "data_aware"]).and_then(Json::as_bool), Some(true));
        assert_eq!(j.path(&["data", "dag", "releases"]).and_then(Json::as_u64), Some(0));
        assert_eq!(j.path(&["data", "dag", "dedup_skips"]).and_then(Json::as_u64), Some(0));
    }

    #[test]
    fn quota_cli_sets_lists_clears_and_gates_cluster_creation() {
        let mut s = session();
        let mut js = JobScheduler::new(crate::jobs::AutoscalerConfig::default());
        // Set, show, update.
        let out = run_jobs(
            &mut s,
            &mut js,
            "ec2quota",
            &["-analyst", "alice", "-maxclusters", "1", "-maxqueued", "4"],
        )
        .unwrap();
        assert!(out.contains("maxclusters 1") && out.contains("maxqueued 4"), "{out}");
        assert!(out.contains("maxcentihour unlimited"), "{out}");
        let listing = run_jobs(&mut s, &mut js, "ec2quota", &[]).unwrap();
        assert!(listing.contains("alice"), "{listing}");
        // The create path is gated: alice may own one cluster, not two.
        run_jobs(
            &mut s,
            &mut js,
            "ec2createcluster",
            &["-cname", "a1", "-csize", "2", "-analyst", "alice"],
        )
        .unwrap();
        let err = run_jobs(
            &mut s,
            &mut js,
            "ec2createcluster",
            &["-cname", "a2", "-csize", "2", "-analyst", "alice"],
        )
        .unwrap_err()
        .to_string();
        assert!(
            err.contains("alice") && err.contains("limit 1") && err.contains("owns 1"),
            "the error must name the tenant, the limit and the usage: {err}"
        );
        assert!(!s.clusters_cfg.contains("a2"), "a refused cluster must not exist");
        // Other tenants (and untagged creates) are unaffected.
        run_jobs(
            &mut s,
            &mut js,
            "ec2createcluster",
            &["-cname", "b1", "-csize", "2", "-analyst", "bob"],
        )
        .unwrap();
        // Clear restores unlimited.
        let out = run_jobs(&mut s, &mut js, "ec2quota", &["-analyst", "alice", "-clear"]).unwrap();
        assert!(out.contains("cleared"), "{out}");
        run_jobs(
            &mut s,
            &mut js,
            "ec2createcluster",
            &["-cname", "a2", "-csize", "2", "-analyst", "alice"],
        )
        .unwrap();
        assert!(s.clusters_cfg.contains("a2"));
    }

    #[test]
    fn invoice_cli_renders_text_and_json() {
        let mut s = session();
        s.cloud.ledger.set_analyst("alice");
        s.cloud
            .ledger
            .bill_instance("i-1", "m2.2xlarge", 90, 0.0, 3600.0);
        s.cloud.ledger.set_analyst("");
        let out = run(&mut s, "ec2invoice", &["-analyst", "alice"]).unwrap();
        assert!(out.contains("invoice for tenant 'alice'"), "{out}");
        assert!(out.contains("on-demand instance-hours"), "{out}");
        assert!(out.contains("9000"), "exact centi-cents must render: {out}");
        let out = run(&mut s, "ec2invoice", &["-analyst", "alice", "-json"]).unwrap();
        let j = crate::util::json::Json::parse(&out).unwrap();
        assert_eq!(
            j.get("total_centi_cents").and_then(crate::util::json::Json::as_u64),
            Some(s.cloud.ledger.total_centi_cents_for("alice"))
        );
        // -analyst is required.
        assert!(run(&mut s, "ec2invoice", &[]).is_err());
    }

    #[test]
    fn report_and_jobstatus_carry_the_slo_rollup() {
        let mut s = session();
        run(&mut s, "mkproject", &["-projectdir", "proj", "-kind", "sweep"]).unwrap();
        let mut js = JobScheduler::new(crate::jobs::AutoscalerConfig {
            min_clusters: 1,
            max_clusters: 1,
            ..Default::default()
        });
        run_jobs(
            &mut s,
            &mut js,
            "ec2submitjob",
            &[
                "-projectdir",
                "proj",
                "-rscript",
                "sweep.json",
                "-runname",
                "r1",
                "-deadline",
                "86400",
                "-analyst",
                "alice",
            ],
        )
        .unwrap();
        let out = run_jobs(&mut s, &mut js, "ec2jobstatus", &[]).unwrap();
        assert!(out.contains("deadline SLOs by analyst:"), "{out}");
        assert!(out.contains("alice"), "{out}");
        let out = run_jobs(&mut s, &mut js, "report", &[]).unwrap();
        assert!(out.contains("deadline SLOs by analyst:"), "{out}");
        run_jobs(&mut s, &mut js, "ec2jobqueue", &["-drain"]).unwrap();
        let out = run_jobs(&mut s, &mut js, "report", &[]).unwrap();
        assert!(out.contains("met 1"), "{out}");
        // No deadlines anywhere -> no SLO section.
        let js2 = JobScheduler::new(crate::jobs::AutoscalerConfig::default());
        assert!(js2.slo_lines(&s).is_empty());
    }

    #[test]
    fn manual_documents_every_ec2_command() {
        // The operator manual must carry a `## `ec2…`` section for
        // every registered ec2* subcommand (CI runs the same check as
        // a grep so doc drift fails fast either way).
        let manual = std::fs::read_to_string(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/../docs/MANUAL.md"
        ))
        .expect("docs/MANUAL.md must exist");
        for c in registry() {
            if !c.name.starts_with("ec2") {
                continue;
            }
            assert!(
                manual.contains(&format!("## `{}`", c.name)),
                "docs/MANUAL.md has no section for {}",
                c.name
            );
        }
    }

    #[test]
    fn manual_coverage_script_agrees_with_the_registry() {
        // The CI manual-coverage gate lives in ci/check_manual.py;
        // this guard runs the same script so the workflow and the
        // test suite cannot drift. Skipped silently where python3 is
        // unavailable — the pure-Rust twin above still enforces the
        // invariant there.
        let root = concat!(env!("CARGO_MANIFEST_DIR"), "/..");
        let out = match std::process::Command::new("python3")
            .arg("ci/check_manual.py")
            .current_dir(root)
            .output()
        {
            Ok(o) => o,
            Err(_) => return, // no python3 on this machine
        };
        assert!(
            out.status.success(),
            "ci/check_manual.py failed:\n{}{}",
            String::from_utf8_lossy(&out.stdout),
            String::from_utf8_lossy(&out.stderr)
        );
    }

    #[test]
    fn submitjob_deadline_flag_validates_and_reaches_the_queue() {
        let mut s = session();
        run(&mut s, "mkproject", &["-projectdir", "proj", "-kind", "sweep"]).unwrap();
        let mut js = JobScheduler::new(crate::jobs::AutoscalerConfig::default());
        // A deadline before the virtual epoch can only be in the past.
        let err = run_jobs(
            &mut s,
            &mut js,
            "ec2submitjob",
            &[
                "-projectdir",
                "proj",
                "-rscript",
                "sweep.json",
                "-runname",
                "r0",
                "-deadline",
                "2011-12-31T00:00:00Z",
            ],
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("past"), "{err:#}");
        assert_eq!(js.queue.jobs().count(), 0, "a rejected job must not queue");
        // A sane relative deadline is echoed and lands on the job.
        let out = run_jobs(
            &mut s,
            &mut js,
            "ec2submitjob",
            &[
                "-projectdir",
                "proj",
                "-rscript",
                "sweep.json",
                "-runname",
                "r1",
                "-deadline",
                "86400",
            ],
        )
        .unwrap();
        assert!(out.contains("deadline t="), "{out}");
        let job = js.queue.jobs().next().unwrap();
        assert!(job.spec.deadline_s.is_some());
        // ec2jobstatus reports eta + margin from the estimator.
        let out = run_jobs(&mut s, &mut js, "ec2jobstatus", &["-jobid", "1"]).unwrap();
        assert!(out.contains("deadline t=") && out.contains("green"), "{out}");
    }

    #[test]
    fn autoscale_bid_and_work_policy_flags() {
        let mut s = session();
        let mut js = JobScheduler::new(crate::jobs::AutoscalerConfig::default());
        let out = run_jobs(
            &mut s,
            &mut js,
            "ec2autoscale",
            &["-policy", "work", "-target", "1800", "-bid", "forecast+margin", "-spot"],
        )
        .unwrap();
        assert!(out.contains("work") && out.contains("forecast+margin"), "{out}");
        assert_eq!(js.autoscaler.cfg.work_target_s, 1800.0);
        assert_eq!(js.autoscaler.cfg.bid, crate::jobs::BidStrategy::ForecastMargin);
        // Bad values are rejected cleanly.
        assert!(run_jobs(&mut s, &mut js, "ec2autoscale", &["-bid", "yolo"]).is_err());
        assert!(run_jobs(&mut s, &mut js, "ec2autoscale", &["-target", "0"]).is_err());
    }

    #[test]
    fn conflicting_placement_flags_rejected_by_parser() {
        let spec = registry()
            .into_iter()
            .find(|c| c.name == "ec2runoncluster")
            .unwrap();
        let err = spec
            .parse(["-runname", "r", "-bynode", "-byslot"].map(String::from))
            .unwrap_err();
        assert!(matches!(err, crate::util::argparse::ArgError::Exclusive(_)));
    }

    #[test]
    fn spot_switch_creates_spot_capacity() {
        let mut s = session();
        let out = run(&mut s, "ec2createcluster", &["-cname", "sc", "-csize", "2", "-spot"]).unwrap();
        assert!(out.contains("spot"), "{out}");
        let e = s.clusters_cfg.get("sc").unwrap().clone();
        for id in e.all_ids() {
            assert!(s.cloud.instance(&id).unwrap().is_spot());
        }
    }

    #[test]
    fn session_json_roundtrip_preserves_state() {
        let mut s = session();
        run(&mut s, "mkproject", &["-projectdir", "proj", "-kind", "sweep"]).unwrap();
        run(&mut s, "ec2createinstance", &["-iname", "i1", "-type", "m2.4xlarge"]).unwrap();
        run(&mut s, "ec2senddatatoinstance", &["-iname", "i1", "-projectdir", "proj"]).unwrap();
        let j = s.to_json();
        let s2 = Session::from_json(
            SimParams::default(),
            Box::new(MockEngine::new(100.0)),
            &j,
        )
        .unwrap();
        assert!(s2.instances_cfg.contains("i1"));
        assert_eq!(s2.cloud.clock.now_s(), s.cloud.clock.now_s());
        let id = s2.instances_cfg.get("i1").unwrap().instance_id.clone();
        let inst = s2.cloud.instance(&id).unwrap();
        assert!(inst.fs.exists("root/proj/sweep.json"));
        assert_eq!(
            inst.attached_volume,
            s.cloud.instance(&id).unwrap().attached_volume
        );
        // New resources after restore get fresh ids.
        let mut s3 = s2;
        run(&mut s3, "ec2createinstance", &["-iname", "i2"]).unwrap();
        let id2 = s3.instances_cfg.get("i2").unwrap().instance_id.clone();
        assert_ne!(id, id2);
    }
}
