//! Observability domain: per-tenant invoices from the usage ledger,
//! the telemetry bus's metrics snapshot, recorded-trace summaries and
//! export, and the batch runner's spec (its execution is intercepted
//! by the dispatcher before any state loads).

use super::commands::{CmdCtx, Command};
use crate::telemetry::{trace, EventKind, TelemetryLevel};
use crate::util::argparse::{CommandSpec, ParsedArgs};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};

/// The observability / billing command domain.
pub struct Obs;

impl Command for Obs {
    fn domain(&self) -> &'static str {
        "obs"
    }

    fn specs(&self) -> Vec<CommandSpec> {
        vec![
            CommandSpec::new("ec2invoice", "itemised per-tenant bill from the usage ledger")
                .value_arg("analyst", "tenant id to invoice (as tagged on jobs/resources)")
                .switch_arg("json", "emit the invoice as JSON instead of text"),
            CommandSpec::new("ec2metrics", "deterministic metrics snapshot from the telemetry bus")
                .value_arg("level", "set the recording level first: off | metrics | trace")
                .switch_arg("json", "emit the snapshot as JSON instead of text")
                .switch_arg("prom", "emit Prometheus-style exposition text")
                .exclusive(&["json", "prom"]),
            CommandSpec::new("ec2trace", "summarise or export a recorded JSONL telemetry trace")
                .value_arg("file", "trace file to read (default: the session's -trace sink)")
                .value_arg("chrome", "also write a Chrome trace-event JSON file to this path")
                .switch_arg("json", "emit the summary as JSON instead of text"),
            CommandSpec::new("batch", "run a file of p2rac commands (batch-mode execution)")
                .value_arg("file", "command file, one command per line"),
        ]
    }

    fn run(&self, ctx: CmdCtx<'_>, cmd: &str, p: &ParsedArgs) -> Result<String> {
        let CmdCtx { s, .. } = ctx;
        match cmd {
            "ec2invoice" => {
                let analyst = p.value("analyst").ok_or_else(|| {
                    anyhow!("-analyst is required (run `report` to see tenants with charges)")
                })?;
                let inv = s.cloud.ledger.invoice_for(analyst);
                if s.cloud.telemetry.on() {
                    s.cloud.telemetry.emit(
                        s.cloud.clock.now_s(),
                        EventKind::Invoice,
                        analyst,
                        None,
                        None,
                        Json::from_pairs(vec![
                            ("total_centi_cents", Json::num(inv.total_centi_cents() as f64)),
                            ("lines", Json::num(inv.lines().len() as f64)),
                        ]),
                    );
                }
                if p.switch("json") {
                    Ok(inv.to_json().to_string_pretty())
                } else {
                    Ok(inv.lines().join("\n"))
                }
            }
            "ec2metrics" => {
                if let Some(lvl) = p.value("level") {
                    let level = match lvl {
                        "off" => TelemetryLevel::Off,
                        "metrics" => TelemetryLevel::Metrics,
                        "trace" => TelemetryLevel::Trace,
                        other => bail!("unknown telemetry level '{other}' (off | metrics | trace)"),
                    };
                    s.cloud.telemetry.set_level(level);
                }
                if p.switch("json") {
                    Ok(s.cloud.telemetry.snapshot_json().to_string_pretty())
                } else if p.switch("prom") {
                    Ok(s.cloud.telemetry.prometheus_text())
                } else {
                    Ok(s.cloud.telemetry.text_lines().join("\n"))
                }
            }
            "ec2trace" => {
                let path = match p.value("file") {
                    Some(f) => f.to_string(),
                    None => s.cloud.telemetry.trace_path().ok_or_else(|| {
                        anyhow!(
                            "-file is required (this session has no -trace sink; \
                             record one with ec2genload -trace <path>)"
                        )
                    })?,
                };
                let text = std::fs::read_to_string(&path)
                    .map_err(|e| anyhow!("cannot read trace '{path}': {e}"))?;
                let summary = trace::TraceSummary::from_lines(text.lines())?;
                if let Some(out) = p.value("chrome") {
                    let doc = trace::chrome_from_lines(text.lines())?;
                    std::fs::write(out, doc.to_string_pretty())
                        .map_err(|e| anyhow!("cannot write '{out}': {e}"))?;
                    return Ok(format!(
                        "wrote Chrome trace ({} events) to {out}\nopen it in chrome://tracing or Perfetto",
                        summary.events
                    ));
                }
                if p.switch("json") {
                    Ok(summary.to_json().to_string_pretty())
                } else {
                    Ok(summary.lines().join("\n"))
                }
            }
            // `batch` executes before any state loads, so the
            // dispatcher intercepts it ahead of this routing layer.
            other => bail!("unhandled command '{other}'"),
        }
    }
}
